//! A residual block through the graph IR — the topology the sequential
//! `Vec<Layer>` API could never express: conv → {branch conv, identity}
//! → Add → relu, planned and served through the `Engine` facade.
//!
//! Prints what the pass pipeline did (conv+bias+relu fusion, dead-node
//! elimination has nothing to remove here) and the two memory figures
//! of the liveness pass:
//!
//! * workspace arena — max over planned conv nodes (the paper's rule);
//! * activation arena — max over *live sets*, not the sum of node
//!   outputs, so the skip connection costs only what it keeps alive.
//!
//! ```text
//! cargo run --release --example resnet_block
//! ```

use mec::bench::workload::{by_name, residual_block_model};
use mec::engine::Engine;
use mec::memory::measure_peak;
use mec::tensor::{Nhwc, Tensor};
use mec::util::stats::fmt_bytes;
use mec::util::Rng;
use std::time::Instant;

fn main() {
    // cv10 (28×28×128, 3×3) at scale 4 keeps the example quick while
    // staying a real paper shape.
    let w = by_name("cv10").unwrap();
    let scale = 4;
    let model = residual_block_model(&w, scale, 2017);
    let (h, ww, c) = model.input_hwc;
    let steps = model.exec().steps().len();
    println!(
        "residual block on {}: {}x{}x{} input, {} graph nodes -> {} steps after fusion",
        w.name,
        h,
        ww,
        c,
        model.node_count(),
        steps
    );
    assert_eq!(
        model.node_count() - 1,
        steps,
        "conv+bias+relu fusion should absorb the trailing relu"
    );

    let batch = 2;
    let engine = Engine::builder(model)
        .pin_batch_sizes(&[batch])
        .build()
        .expect("residual graph builds");
    for lp in engine.plan_report() {
        println!(
            "  conv node {}: {} ({} workspace)",
            lp.layer,
            lp.chosen.algo.name(),
            fmt_bytes(lp.chosen.workspace_bytes)
        );
    }
    let sum_of_outputs: usize = (0..engine.model().node_count())
        .map(|i| engine.model().exec().shape_of(i).len() * batch * 4)
        .sum();
    println!(
        "memory: workspace {} (max over convs) + activations {} (max live set; \
         node outputs sum to {})",
        fmt_bytes(engine.workspace_bytes()),
        fmt_bytes(engine.activation_bytes()),
        fmt_bytes(sum_of_outputs),
    );
    assert_eq!(
        engine.activation_bytes(),
        engine.model().max_live_bytes(batch),
        "liveness packing must hit the max-live lower bound on the diamond"
    );

    let mut rng = Rng::new(7);
    let input = Tensor::random(Nhwc::new(batch, h, ww, c), &mut rng);
    // First pass grows the session's arenas (tracked)...
    let (mut session, peak) = measure_peak(|| {
        let mut s = engine.session();
        s.infer_batch(&input).expect("input matches engine");
        s
    });
    println!("first-pass tracked peak: {}", fmt_bytes(peak));
    // ...steady state allocates nothing and the arenas never grow.
    let (ws0, act0) = (session.workspace_bytes(), session.activation_bytes());
    let reps = 10;
    let t0 = Instant::now();
    let mut out = None;
    for _ in 0..reps {
        out = Some(session.infer_batch(&input).expect("input matches engine"));
    }
    let ns = t0.elapsed().as_nanos() as f64 / reps as f64;
    assert_eq!(session.workspace_bytes(), ws0);
    assert_eq!(session.activation_bytes(), act0);
    let out = out.unwrap();
    assert!(out.data().iter().all(|&v| v >= 0.0), "relu output");
    println!(
        "steady state: {:.2} ms / batch-{batch} pass, arenas fixed at {} + {}",
        ns / 1e6,
        fmt_bytes(ws0),
        fmt_bytes(act0)
    );
}
