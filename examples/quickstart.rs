//! Quickstart: the paper's claim through the `Engine` facade.
//!
//! Builds two single-layer engines on cv6 (12×12×256 → 3×3×512, the
//! layer with the paper's biggest mobile speedup) — one pinned to
//! im2col, one to MEC — runs a session each, and prints the
//! memory-overhead ratio (Eq. 2 vs Eq. 3) and steady-state runtimes.
//! The two outputs must match: same convolution, a fraction of the
//! temporary memory.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use mec::bench::workload::by_name;
use mec::conv::AlgoKind;
use mec::engine::Engine;
use mec::memory::measure_peak;
use mec::tensor::Tensor;
use mec::util::stats::{fmt_bytes, fmt_ns};
use mec::util::{assert_allclose, Rng};
use std::time::Instant;

fn main() {
    let w = by_name("cv6").unwrap();
    let shape = w.shape(1, 1);
    println!("layer cv6: {}", shape.describe());
    println!(
        "analytic lowered sizes: im2col {} (Eq. 2)  vs  MEC {} (Eq. 3)",
        fmt_bytes(shape.im2col_lowered_elems() * 4),
        fmt_bytes(shape.mec_lowered_elems() * 4)
    );

    let mut rng = Rng::new(2017); // ICML 2017
    let input = Tensor::random(shape.input, &mut rng);

    let mut outputs = Vec::new();
    for kind in [AlgoKind::Im2col, AlgoKind::Mec] {
        // One builder call replaces the old planner + prepack + workspace
        // choreography: build() validates the override against the
        // geometry/precision/budget, plans the layer, and prepacks the
        // kernel. Same seed both times, so both engines hold the same
        // weights.
        let engine = Engine::builder(w.model(1, 2017))
            .pin_batch_sizes(&[1])
            .algo_override(0, kind)
            .build()
            .expect("cv6 supports both algorithms");
        // Peak temporary memory = the session arena growing to the
        // plan's layout on first use (the paper's memory-overhead)...
        let (mut session, peak) = measure_peak(|| {
            let mut s = engine.session();
            s.infer_batch(&input).expect("input matches engine");
            s
        });
        // ...and runtime in the steady state (the serving hot path:
        // prepacked kernel, pre-sized arena, no locks).
        let reps = 5;
        let t0 = Instant::now();
        let mut out = None;
        for _ in 0..reps {
            out = Some(session.infer_batch(&input).expect("input matches engine"));
        }
        let ns = t0.elapsed().as_nanos() as f64 / reps as f64;
        println!(
            "{:<8} memory-overhead {:>10}   runtime {:>10}",
            kind.name(),
            fmt_bytes(peak),
            fmt_ns(ns)
        );
        outputs.push(out.unwrap());
    }

    assert_allclose(outputs[1].data(), outputs[0].data(), 1e-4, "MEC vs im2col");
    println!(
        "outputs identical ✓  (same convolution, {}x less temporary memory)",
        shape.im2col_lowered_elems() / shape.mec_lowered_elems().max(1)
    );
}
