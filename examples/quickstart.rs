//! Quickstart: the paper's claim in 60 lines.
//!
//! Runs the cv6 benchmark layer (12×12×256 → 3×3×512, the layer with the
//! paper's biggest mobile speedup) through im2col and MEC, prints the
//! memory-overhead ratio (Eq. 2 vs Eq. 3) and runtimes, and verifies the
//! two outputs match bit-for-bit-ish.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use mec::bench::workload::by_name;
use mec::conv::{AlgoKind, ConvContext, Convolution};
use mec::memory::{measure_peak, Workspace};
use mec::tensor::{Kernel, Tensor};
use mec::util::stats::{fmt_bytes, fmt_ns};
use mec::util::{assert_allclose, Rng};
use std::time::Instant;

fn main() {
    let shape = by_name("cv6").unwrap().shape(1, 1);
    println!("layer cv6: {}", shape.describe());
    println!(
        "analytic lowered sizes: im2col {} (Eq. 2)  vs  MEC {} (Eq. 3)",
        fmt_bytes(shape.im2col_lowered_elems() * 4),
        fmt_bytes(shape.mec_lowered_elems() * 4)
    );

    let mut rng = Rng::new(2017); // ICML 2017
    let input = Tensor::random(shape.input, &mut rng);
    let kernel = Kernel::random(shape.kernel, &mut rng);
    let ctx = ConvContext::default();

    let mut outputs = Vec::new();
    for kind in [AlgoKind::Im2col, AlgoKind::Mec] {
        let algo = kind.build();
        let mut out = Tensor::zeros(shape.output());
        // Measure peak temporary memory on a cold workspace...
        let ((), peak) = measure_peak(|| {
            let mut ws = Workspace::new();
            algo.run(&ctx, &shape, &input, &kernel, &mut ws, &mut out);
        });
        // ...and runtime on a warm one (the serving steady state).
        let mut ws = Workspace::new();
        algo.run(&ctx, &shape, &input, &kernel, &mut ws, &mut out);
        let t0 = Instant::now();
        let reps = 5;
        for _ in 0..reps {
            algo.run(&ctx, &shape, &input, &kernel, &mut ws, &mut out);
        }
        let ns = t0.elapsed().as_nanos() as f64 / reps as f64;
        println!(
            "{:<8} memory-overhead {:>10}   runtime {:>10}",
            algo.name(),
            fmt_bytes(peak),
            fmt_ns(ns)
        );
        outputs.push(out);
    }

    assert_allclose(
        outputs[1].data(),
        outputs[0].data(),
        1e-4,
        "MEC vs im2col",
    );
    println!("outputs identical ✓  (same convolution, {}x less temporary memory)",
        shape.im2col_lowered_elems() / shape.mec_lowered_elems().max(1));
}
