//! Table 3 as a runnable example: ResNet-101's convolution inventory on
//! the Mobile configuration (1 thread, batch 1), weighted by how often
//! each layer shape occurs in the network.
//!
//! Each (layer, algorithm) cell is a single-layer `Engine` with an
//! `algo_override` — build validates and prepacks, a session gives the
//! steady-state runtime — so the comparison measures exactly what a
//! deployed engine would do.
//!
//! The paper reports Conv.cpu 203.6 MB / 1701.6 ms vs MEC.cpu 64.6 MB /
//! 1391.6 ms (ratios 3.2× memory, 1.2× runtime). Absolute milliseconds
//! are host-specific; the ratios are the reproduction target.
//!
//! ```text
//! cargo run --release --example resnet_mobile
//! ```

use mec::bench::workload::resnet101_table3;
use mec::conv::AlgoKind;
use mec::engine::Engine;
use mec::tensor::Tensor;
use mec::util::Rng;
use std::time::Instant;

fn main() {
    let mut rng = Rng::new(101);
    println!(
        "{:<6} {:>7} | {:>12} {:>12} | {:>12} {:>12}",
        "layer", "weight", "conv MB", "conv ms", "MEC MB", "MEC ms"
    );
    let mut totals = [0.0f64; 4]; // conv_mb, conv_ms, mec_mb, mec_ms
    for (w, weight) in resnet101_table3() {
        let shape = w.shape(1, 1);
        let input = Tensor::random(shape.input, &mut rng);
        let mut row = [0.0f64; 4];
        for (i, kind) in [AlgoKind::Im2col, AlgoKind::Mec].iter().enumerate() {
            let engine = Engine::builder(w.model(1, 101))
                .threads(1)
                .pin_batch_sizes(&[1])
                .algo_override(0, *kind)
                .build()
                .expect("table-3 layers run both algorithms");
            let mut session = engine.session();
            session.infer_batch(&input).expect("input matches"); // warm
            let t0 = Instant::now();
            session.infer_batch(&input).expect("input matches");
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            row[i * 2] = engine.plan_report()[0].chosen.workspace_bytes as f64 / 1e6;
            row[i * 2 + 1] = ms;
        }
        println!(
            "{:<6} {:>7} | {:>12.1} {:>12.1} | {:>12.1} {:>12.1}",
            w.name, weight, row[0], row[1], row[2], row[3]
        );
        for i in 0..4 {
            totals[i] += weight as f64 * row[i];
        }
    }
    println!(
        "{:<6} {:>7} | {:>12.1} {:>12.1} | {:>12.1} {:>12.1}",
        "SUM", "", totals[0], totals[1], totals[2], totals[3]
    );
    println!(
        "\nratios: memory {:.2}x (paper: 3.2x)   runtime {:.2}x (paper: 1.2x)",
        totals[0] / totals[2],
        totals[1] / totals[3]
    );
}
