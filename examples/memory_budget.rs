//! The memory-constrained-device story (paper §1) made interactive:
//! sweep a workspace budget from gigabytes down to zero on cv4 (ResNet's
//! biggest conv) and watch the engine builder walk down the algorithm
//! ladder — im2col → MEC → direct — trading speed for footprint. Each
//! budget is one `Engine::builder(..).budget(..).build()` call; the
//! chosen plan comes out of the engine's build report.
//!
//! ```text
//! cargo run --release --example memory_budget
//! ```

use mec::bench::workload::by_name;
use mec::conv::{AlgoKind, Convolution};
use mec::engine::Engine;
use mec::memory::Budget;
use mec::util::stats::fmt_bytes;

fn main() {
    let w = by_name("cv4").unwrap();
    let shape = w.shape(1, 1);
    println!("layer cv4: {}", shape.describe());
    println!(
        "workspace needs: im2col {}, winograd n/a (k=7), mec {}, fft {}, direct 0\n",
        fmt_bytes(shape.im2col_lowered_elems() * 4),
        fmt_bytes(shape.mec_lowered_elems() * 4),
        fmt_bytes(AlgoKind::Fft.build().workspace_bytes(&shape)),
    );
    println!(
        "{:>12} | {:<10} {:>14} {:>14}",
        "budget", "chosen", "workspace", "est time"
    );
    for budget_mb in [4096.0f64, 512.0, 160.0, 100.0, 50.0, 20.0, 1.0, 0.0] {
        let engine = Engine::builder(w.model(1, 101))
            .budget(Budget::new((budget_mb * 1e6) as usize))
            .build()
            .expect("direct is always admissible");
        let chosen = &engine.plan_report()[0].chosen;
        println!(
            "{:>12} | {:<10} {:>14} {:>12.1}ms",
            if budget_mb >= 1.0 {
                format!("{budget_mb:.0} MB")
            } else {
                format!("{:.0} B", budget_mb * 1e6)
            },
            chosen.algo.name(),
            fmt_bytes(chosen.workspace_bytes),
            chosen.est_ns / 1e6,
        );
    }
    println!(
        "\nEq. 4 in action: MEC stays admissible {}x deeper into the budget\n\
         curve than im2col, at (modelled) equal-or-better runtime.",
        shape.im2col_lowered_elems() / shape.mec_lowered_elems().max(1)
    );
}
