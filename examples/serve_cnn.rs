//! END-TO-END DRIVER (recorded in EXPERIMENTS.md §E2E): proves the
//! layers compose on a real workload.
//!
//! 1. Loads the CNN that `make artifacts` trained in JAX on the synthetic
//!    shapes dataset (`artifacts/model.mecw`, ~97% eval accuracy) and the
//!    held-out eval set (`artifacts/eval.bin`).
//! 2. Plans every conv layer with the memory-budgeted planner (MEC wins):
//!    algorithms chosen, kernels prepacked into ConvPlans, and the shared
//!    per-worker arena sized at the max over layers.
//! 3. Serves the eval set as individual requests through the coordinator
//!    (queue → dynamic batcher → workers → planned native engine),
//!    reporting accuracy, p50/p95/p99 latency, and throughput.
//! 4. With `--features pjrt`: cross-checks the native engine against the
//!    PJRT executor running the AOT JAX/Pallas HLO
//!    (`artifacts/model_fwd.hlo.txt`) on the same samples — the full
//!    Pallas ≡ rust proof, at serve time.
//!
//! ```text
//! make artifacts && cargo run --release --example serve_cnn
//! ```

use mec::conv::ConvContext;
use mec::coordinator::{BatchPolicy, Server, ServerConfig};
use mec::ensure;
use mec::memory::Budget;
use mec::model::{load_mecw, EvalSet};
use mec::planner::Planner;
use mec::util::error::Result;
use mec::util::stats::fmt_bytes;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    mec::util::logging::init();
    let dir = mec::runtime::artifacts::default_dir();
    ensure!(
        dir.join("model.mecw").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // ---- 1. load model + eval set -------------------------------------
    let mut model = load_mecw(dir.join("model.mecw")).map_err(|e| mec::format_err!("{e}"))?;
    let eval = EvalSet::load(dir.join("eval.bin"))?;
    println!(
        "model {:?}: {} layers / {} params; eval set: {} samples",
        model.name,
        model.layers.len(),
        model.param_count(),
        eval.len()
    );

    // ---- 2. plan under a mobile-ish budget ----------------------------
    let budget = Budget::new(2 << 20); // 2 MB workspace — phone territory
    let ctx = ConvContext::default();
    model.plan(&Planner::new(), &budget, &ctx, 32);
    for (i, algo) in model.plan_summary() {
        println!("  conv layer {i}: planned -> {}", algo.name());
    }
    println!(
        "  shared arena: {} per worker (max over planned layers)",
        fmt_bytes(model.planned_workspace_bytes())
    );

    // ---- 3. serve the eval set through the coordinator ----------------
    let model = Arc::new(model);
    let server = Server::start(
        Arc::clone(&model),
        ServerConfig {
            workers: 1,
            queue_capacity: 512,
            policy: BatchPolicy::new(32, Duration::from_millis(2)),
            ctx: ctx.clone(),
        },
    );
    let client = server.client();
    let t0 = Instant::now();
    let rxs: Vec<_> = eval
        .samples
        .iter()
        .map(|s| client.submit(s.clone()).expect("queue sized for eval set"))
        .collect();
    let mut correct = 0;
    let mut native_scores: Vec<Vec<f32>> = Vec::with_capacity(eval.len());
    for (rx, &label) in rxs.into_iter().zip(&eval.labels) {
        let resp = rx
            .recv()
            .map_err(|e| mec::format_err!("worker dropped: {e}"))?;
        if resp.class == label {
            correct += 1;
        }
        native_scores.push(resp.scores);
    }
    let wall = t0.elapsed();
    let metrics = server.shutdown();
    let acc = correct as f64 / eval.len() as f64;
    println!("\n== serving results ==");
    println!(
        "accuracy {}/{} = {:.1}%  (python trainer reported ~97%)",
        correct,
        eval.len(),
        100.0 * acc
    );
    println!("{}", metrics.report());
    println!(
        "wall time {:.2}s -> {:.1} req/s end-to-end",
        wall.as_secs_f64(),
        eval.len() as f64 / wall.as_secs_f64()
    );
    assert!(acc > 0.9, "accuracy regression: {acc}");

    // ---- 4. PJRT cross-check (needs --features pjrt) ------------------
    #[cfg(feature = "pjrt")]
    {
        use mec::runtime::{model_weight_inputs, Executor, Manifest, PjrtEngine, PjrtExecutor};
        use mec::tensor::{Nhwc, Tensor};
        use mec::util::assert_allclose;

        let manifest = Manifest::load(&dir)?;
        let engine = PjrtEngine::cpu()?;
        let mut pjrt = PjrtExecutor::from_artifact(&engine, &manifest, "model_fwd")?
            .with_weights(model_weight_inputs(&model))?;
        let b = pjrt.lowered_batch();
        let mut data = Vec::new();
        for s in &eval.samples[..b] {
            data.extend_from_slice(s);
        }
        let batch = Tensor::from_vec(Nhwc::new(b, eval.h, eval.w, eval.c), data);
        let pjrt_scores = pjrt.forward(&batch)?;
        let native_flat: Vec<f32> = native_scores[..b].concat();
        assert_allclose(&pjrt_scores, &native_flat, 1e-3, "pjrt vs native");
        println!(
            "\nPJRT cross-check ✓ — AOT JAX/Pallas HLO ({} platform) matches the \
             native rust engine on {} samples",
            engine.platform(),
            b
        );
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = &native_scores;
        println!(
            "\nPJRT cross-check skipped (build with --features pjrt and a vendored xla crate)"
        );
    }
    Ok(())
}
