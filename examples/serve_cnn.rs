//! END-TO-END DRIVER (recorded in EXPERIMENTS.md §E2E): proves the
//! layers compose on a real workload.
//!
//! 1. Builds an `Engine` straight from the `.mecw` the build-time JAX
//!    trainer produced (`artifacts/model.mecw`, ~97% eval accuracy) —
//!    one builder call owns the budget, batch pinning, planning, and
//!    kernel prepacking that used to be hand-assembled here.
//! 2. The build report shows the memory-budgeted choices (MEC wins) and
//!    the shared per-worker arena sizing (max over layers and pinned
//!    batches).
//! 3. Serves the held-out eval set (`artifacts/eval.bin`) as individual
//!    requests through the coordinator (queue → dynamic batcher →
//!    worker sessions), reporting accuracy, p50/p95/p99 latency, and
//!    throughput.
//! 4. With `--features pjrt`: cross-checks the native engine against the
//!    PJRT executor running the AOT JAX/Pallas HLO
//!    (`artifacts/model_fwd.hlo.txt`) on the same samples — the full
//!    Pallas ≡ rust proof, at serve time.
//!
//! ```text
//! make artifacts && cargo run --release --example serve_cnn
//! ```

use mec::coordinator::{Server, ServerConfig};
use mec::engine::Engine;
use mec::ensure;
use mec::memory::Budget;
use mec::model::EvalSet;
use mec::util::error::Result;
use mec::util::stats::fmt_bytes;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    mec::util::logging::init();
    let dir = mec::runtime::artifacts::default_dir();
    ensure!(
        dir.join("model.mecw").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // ---- 1. build the engine under a mobile-ish budget ----------------
    let engine = Engine::builder(dir.join("model.mecw"))
        .budget(Budget::new(2 << 20)) // 2 MB workspace — phone territory
        // A power-of-two ladder up to 32: the adaptive batcher only
        // dispatches pinned shapes, so the tail of the eval set runs as
        // 16/8/4/2/1 chunks instead of degenerating to singles.
        .pin_batch_sizes(&[1, 2, 4, 8, 16, 32])
        .build()
        .map_err(|e| mec::format_err!("{e}"))?;
    let eval = EvalSet::load(dir.join("eval.bin"))?;
    {
        let model = engine.model();
        println!(
            "model {:?}: {} nodes / {} params; eval set: {} samples",
            model.name,
            model.node_count(),
            model.param_count(),
            eval.len()
        );
    }

    // ---- 2. the build report: planned choices + arena sizing ----------
    for lp in engine.plan_report() {
        println!(
            "  conv layer {}: planned -> {}",
            lp.layer,
            lp.chosen.algo.name()
        );
    }
    println!(
        "  shared arena: {} per worker (max over planned layers and pinned batches)",
        fmt_bytes(engine.workspace_bytes())
    );

    // ---- 3. serve the eval set through the coordinator ----------------
    let engine = Arc::new(engine);
    let server = Server::start(
        Arc::clone(&engine),
        ServerConfig {
            workers: 1,
            queue_depth: 512,
            max_wait: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    )
    .map_err(|e| mec::format_err!("{e}"))?;
    let client = server.client();
    let t0 = Instant::now();
    let rxs: Vec<_> = eval
        .samples
        .iter()
        .map(|s| client.submit(s.clone()).expect("queue sized for eval set"))
        .collect();
    let mut correct = 0;
    let mut native_scores: Vec<Vec<f32>> = Vec::with_capacity(eval.len());
    for (rx, &label) in rxs.into_iter().zip(&eval.labels) {
        let resp = rx
            .recv()
            .map_err(|e| mec::format_err!("worker dropped: {e}"))?;
        let pred = resp
            .result
            .map_err(|e| mec::format_err!("request failed: {e}"))?;
        if pred.class == label {
            correct += 1;
        }
        native_scores.push(pred.scores);
    }
    let wall = t0.elapsed();
    let metrics = server.shutdown();
    let acc = correct as f64 / eval.len() as f64;
    println!("\n== serving results ==");
    println!(
        "accuracy {}/{} = {:.1}%  (python trainer reported ~97%)",
        correct,
        eval.len(),
        100.0 * acc
    );
    println!("{}", metrics.report());
    println!(
        "wall time {:.2}s -> {:.1} req/s end-to-end",
        wall.as_secs_f64(),
        eval.len() as f64 / wall.as_secs_f64()
    );
    assert!(acc > 0.9, "accuracy regression: {acc}");

    // ---- 4. PJRT cross-check (needs --features pjrt) ------------------
    #[cfg(feature = "pjrt")]
    {
        use mec::runtime::{model_weight_inputs, Executor, Manifest, PjrtEngine, PjrtExecutor};
        use mec::tensor::{Nhwc, Tensor};
        use mec::util::assert_allclose;

        let manifest = Manifest::load(&dir)?;
        let pjrt_engine = PjrtEngine::cpu()?;
        let mut pjrt = PjrtExecutor::from_artifact(&pjrt_engine, &manifest, "model_fwd")?
            .with_weights(model_weight_inputs(engine.model()))?;
        let b = pjrt.lowered_batch();
        let mut data = Vec::new();
        for s in &eval.samples[..b] {
            data.extend_from_slice(s);
        }
        let batch = Tensor::from_vec(Nhwc::new(b, eval.h, eval.w, eval.c), data);
        let pjrt_scores = pjrt.forward(&batch)?;
        let native_flat: Vec<f32> = native_scores[..b].concat();
        assert_allclose(&pjrt_scores, &native_flat, 1e-3, "pjrt vs native");
        println!(
            "\nPJRT cross-check ✓ — AOT JAX/Pallas HLO ({} platform) matches the \
             native rust engine on {} samples",
            pjrt_engine.platform(),
            b
        );
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = &native_scores;
        println!(
            "\nPJRT cross-check skipped (build with --features pjrt and a vendored xla crate)"
        );
    }
    Ok(())
}
