//! In-tree unsafe-code auditor for the `mec` crate.
//!
//! Scans every `.rs` file under `rust/src` with a small comment/string-aware
//! lexer (no rustc, no syn — the tool must build with zero dependencies)
//! and enforces the crate's unsafe policy:
//!
//! 1. **Justification** — every `unsafe` occurrence (block, `unsafe fn`,
//!    `unsafe impl`) must be immediately preceded by a comment run that
//!    contains `SAFETY` (conventional `// SAFETY: …`) or a `# Safety` doc
//!    section. A comment run may be shared by consecutive `unsafe impl`
//!    lines (the usual `Send`/`Sync` pairing) and may be interleaved with
//!    attributes.
//! 2. **Containment** — `unsafe` may appear only in the allowlisted
//!    modules: `threadpool`, `memory`, `gemm` (including `gemm::micro`),
//!    `conv::fft_conv`, and `tensor::quant`. Everything else is safe Rust
//!    by policy (most of it additionally carries `#![forbid(unsafe_code)]`;
//!    this tool is the guard for the files that cannot).
//!
//! Output: an inventory table of every unsafe site, per-module counts, and
//! a non-zero exit code listing each violation. CI runs this in the `lint`
//! job (`cargo run -p unsafe-audit`); the scanner itself is unit-tested,
//! including the "deleting a SAFETY comment makes the audit fail" case.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Path prefixes (relative to `rust/src`, `/`-separated) where unsafe code
/// is permitted. A plain name allows the whole module directory; a `.rs`
/// entry allows exactly that file.
const ALLOWLIST: &[&str] = &[
    "threadpool/",
    "threadpool.rs",
    "memory/",
    "memory.rs",
    "gemm/",
    "gemm.rs",
    "conv/fft_conv.rs",
    "tensor/quant.rs",
];

/// What kind of unsafe site a line contains (first occurrence wins).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SiteKind {
    /// `unsafe impl Trait for Type`
    Impl,
    /// `unsafe fn name(...)`
    Fn,
    /// `unsafe { ... }` expression/statement block
    Block,
}

impl SiteKind {
    fn label(self) -> &'static str {
        match self {
            SiteKind::Impl => "impl",
            SiteKind::Fn => "fn",
            SiteKind::Block => "block",
        }
    }
}

/// One `unsafe` occurrence found by the scanner.
#[derive(Debug)]
struct Site {
    /// Path relative to `rust/src`, `/`-separated.
    file: String,
    /// 1-based line number.
    line: usize,
    kind: SiteKind,
    /// Trimmed source line, for the inventory table.
    snippet: String,
    /// Whether a SAFETY justification precedes the site.
    justified: bool,
}

/// A source line split into its code part and its comment part by the
/// lexer. String-literal contents are blanked out of `code` so that
/// `"unsafe"` in a string never counts as a site.
#[derive(Debug, Default)]
struct LineInfo {
    code: String,
    comment: String,
}

/// Split `content` into per-line code/comment parts, tracking line
/// comments, (nested) block comments, string literals, raw strings, and
/// char literals.
fn lex(content: &str) -> Vec<LineInfo> {
    #[derive(PartialEq)]
    enum State {
        Code,
        LineComment,
        /// Nesting depth — Rust block comments nest.
        BlockComment(usize),
        Str,
        /// Number of `#` marks that close the raw string.
        RawStr(usize),
    }
    let mut lines = Vec::new();
    let mut cur = LineInfo::default();
    let mut state = State::Code;
    let chars: Vec<char> = content.chars().collect();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        let next = chars.get(i + 1).copied();
        if c == '\n' {
            if state == State::LineComment {
                state = State::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match state {
            State::Code => match c {
                '/' if next == Some('/') => {
                    state = State::LineComment;
                    cur.comment.push_str("//");
                    i += 2;
                }
                '/' if next == Some('*') => {
                    state = State::BlockComment(1);
                    i += 2;
                }
                '"' => {
                    state = State::Str;
                    cur.code.push(' ');
                    i += 1;
                }
                'r' if next == Some('"') || next == Some('#') => {
                    // Possible raw string r"…" / r#"…"# (also covers
                    // r##…). If the #-run is not followed by a quote this
                    // is ordinary code (e.g. `r#fn` raw identifiers).
                    let mut j = i + 1;
                    let mut hashes = 0;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        state = State::RawStr(hashes);
                        cur.code.push(' ');
                        i = j + 1;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                }
                '\'' => {
                    // Char literal vs lifetime. Escaped char ('\n', '\'')
                    // or one-char literal ('x') is consumed wholesale;
                    // anything else is a lifetime and passes through.
                    if next == Some('\\') {
                        let mut j = i + 2;
                        // Skip the escape body up to the closing quote.
                        while j < chars.len() && chars[j] != '\'' && chars[j] != '\n' {
                            j += 1;
                        }
                        cur.code.push(' ');
                        i = (j + 1).min(chars.len());
                    } else if chars.get(i + 2) == Some(&'\'') {
                        cur.code.push(' ');
                        i += 3;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                }
                _ => {
                    cur.code.push(c);
                    i += 1;
                }
            },
            State::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == '*' && next == Some('/') {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let mut ok = true;
                    for k in 0..hashes {
                        if chars.get(i + 1 + k) != Some(&'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        state = State::Code;
                        i += 1 + hashes;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
    lines.push(cur);
    lines
}

/// Whether `code` contains `unsafe` as a standalone token (not part of an
/// identifier like `unsafe_op_in_unsafe_fn`). Returns the byte offset of
/// the first occurrence.
fn find_unsafe_token(code: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find("unsafe") {
        let start = from + pos;
        let end = start + "unsafe".len();
        let before_ok = start == 0 || {
            let b = bytes[start - 1];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        let after_ok = end == code.len() || {
            let b = bytes[end];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        if before_ok && after_ok {
            return Some(start);
        }
        from = end;
    }
    None
}

/// Classify the unsafe site on a code line by what follows the token.
fn classify(code: &str, at: usize) -> SiteKind {
    let rest = code[at + "unsafe".len()..].trim_start();
    if rest.starts_with("impl") {
        SiteKind::Impl
    } else if rest.starts_with("fn") {
        SiteKind::Fn
    } else {
        SiteKind::Block
    }
}

/// Whether a comment string carries a safety justification: the
/// conventional `SAFETY` marker or a rustdoc `# Safety` section heading.
fn comment_justifies(comment: &str) -> bool {
    comment.contains("SAFETY") || comment.contains("# Safety")
}

/// Scan one file's content; `rel` is its path relative to `rust/src`.
fn audit_file(rel: &str, content: &str) -> Vec<Site> {
    let lines = lex(content);
    let raw: Vec<&str> = content.lines().collect();
    let mut sites = Vec::new();
    for (i, li) in lines.iter().enumerate() {
        let Some(at) = find_unsafe_token(&li.code) else {
            continue;
        };
        let kind = classify(&li.code, at);
        // Same-line trailing comment counts…
        let mut justified = comment_justifies(&li.comment);
        // …otherwise walk the preamble run directly above: pure-comment
        // lines, attributes, and earlier `unsafe impl` lines (so one
        // SAFETY note covers a Send/Sync pair). Stop at anything else —
        // adjacency is the point of the rule.
        let mut j = i;
        while !justified && j > 0 {
            j -= 1;
            let p = &lines[j];
            let code_trim = p.code.trim();
            let is_comment_only = code_trim.is_empty() && !p.comment.is_empty();
            let is_attr = code_trim.starts_with("#[") || code_trim.starts_with("#![");
            let is_chained_impl = find_unsafe_token(&p.code)
                .map(|a| classify(&p.code, a) == SiteKind::Impl)
                .unwrap_or(false);
            if is_comment_only {
                justified = comment_justifies(&p.comment);
                if justified {
                    break;
                }
            } else if !(is_attr || is_chained_impl) {
                break;
            }
        }
        sites.push(Site {
            file: rel.to_string(),
            line: i + 1,
            kind,
            snippet: raw.get(i).map_or("", |s| s.trim()).to_string(),
            justified,
        });
    }
    sites
}

/// Whether a file (path relative to `rust/src`) may contain unsafe code.
fn allowlisted(rel: &str) -> bool {
    ALLOWLIST
        .iter()
        .any(|p| if p.ends_with('/') { rel.starts_with(p) } else { rel == *p })
}

/// Collect every `.rs` file under `dir`, sorted for stable output.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// The workspace root: two levels above this tool's manifest, with a
/// cwd fallback so `./target/…/unsafe-audit` from the root also works.
fn workspace_root() -> PathBuf {
    if let Ok(md) = std::env::var("CARGO_MANIFEST_DIR") {
        let root = Path::new(&md).join("../..");
        if root.join("rust/src").is_dir() {
            return root;
        }
    }
    PathBuf::from(".")
}

fn main() -> ExitCode {
    let src = workspace_root().join("rust/src");
    if !src.is_dir() {
        eprintln!("unsafe-audit: cannot find rust/src (run from the workspace)");
        return ExitCode::from(2);
    }
    let mut files = Vec::new();
    if let Err(e) = collect_rs(&src, &mut files) {
        eprintln!("unsafe-audit: walking {}: {e}", src.display());
        return ExitCode::from(2);
    }

    let mut sites = Vec::new();
    let mut violations: Vec<String> = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&src)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let content = match std::fs::read_to_string(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("unsafe-audit: reading {rel}: {e}");
                return ExitCode::from(2);
            }
        };
        let file_sites = audit_file(&rel, &content);
        if !file_sites.is_empty() && !allowlisted(&rel) {
            violations.push(format!(
                "{rel}:{}: unsafe outside the allowlisted modules ({} site{})",
                file_sites[0].line,
                file_sites.len(),
                if file_sites.len() == 1 { "" } else { "s" }
            ));
        }
        for s in &file_sites {
            if !s.justified {
                violations.push(format!(
                    "{}:{}: unsafe {} without a preceding SAFETY comment",
                    s.file,
                    s.line,
                    s.kind.label()
                ));
            }
        }
        sites.extend(file_sites);
    }

    // Inventory table.
    println!("unsafe inventory ({} sites across {} files)", sites.len(), {
        let mut fs: Vec<&str> = sites.iter().map(|s| s.file.as_str()).collect();
        fs.dedup();
        fs.len()
    });
    let loc_w = sites
        .iter()
        .map(|s| s.file.len() + 1 + s.line.to_string().len())
        .max()
        .unwrap_or(8);
    for s in &sites {
        let loc = format!("{}:{}", s.file, s.line);
        let snippet: String = s.snippet.chars().take(72).collect();
        println!("  {loc:<loc_w$}  {:<5}  {snippet}", s.kind.label());
    }
    // Per-module counts (first path component, or the file itself).
    let mut counts: Vec<(String, usize)> = Vec::new();
    for s in &sites {
        let module = s.file.split('/').next().unwrap_or(&s.file).to_string();
        match counts.iter_mut().find(|(m, _)| *m == module) {
            Some((_, n)) => *n += 1,
            None => counts.push((module, 1)),
        }
    }
    println!("per-module:");
    for (m, n) in &counts {
        println!("  {m:<12} {n}");
    }

    if violations.is_empty() {
        println!("unsafe-audit: OK");
        ExitCode::SUCCESS
    } else {
        eprintln!("unsafe-audit: {} violation(s):", violations.len());
        for v in &violations {
            eprintln!("  {v}");
        }
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn documented_unsafe_block_passes() {
        let src = "fn f() {\n    // SAFETY: bounds checked above.\n    unsafe { g() }\n}\n";
        let sites = audit_file("memory/x.rs", src);
        assert_eq!(sites.len(), 1);
        assert!(sites[0].justified);
        assert_eq!(sites[0].kind, SiteKind::Block);
        assert_eq!(sites[0].line, 3);
    }

    #[test]
    fn deleting_the_safety_comment_flags_the_site() {
        // The self-test the policy demands: the justified snippet above,
        // minus its SAFETY line, must audit as a violation.
        let src = "fn f() {\n    unsafe { g() }\n}\n";
        let sites = audit_file("memory/x.rs", src);
        assert_eq!(sites.len(), 1);
        assert!(!sites[0].justified);
    }

    #[test]
    fn same_line_trailing_safety_counts() {
        let src = "let x = unsafe { p.read() }; // SAFETY: p is valid.\n";
        let sites = audit_file("memory/x.rs", src);
        assert_eq!(sites.len(), 1);
        assert!(sites[0].justified);
    }

    #[test]
    fn one_comment_covers_a_send_sync_pair_but_not_more() {
        let src = "// SAFETY: exclusively owned.\nunsafe impl Send for T {}\nunsafe impl Sync for T {}\n\nunsafe impl Send for U {}\n";
        let sites = audit_file("memory/x.rs", src);
        assert_eq!(sites.len(), 3);
        assert!(sites[0].justified);
        assert!(sites[1].justified, "comment run must cover chained impls");
        assert!(!sites[2].justified, "blank line breaks the run");
    }

    #[test]
    fn unsafe_fn_with_doc_safety_section_passes() {
        let src = "/// Does things.\n///\n/// # Safety\n/// Caller upholds X.\npub unsafe fn f() {}\n";
        let sites = audit_file("gemm/x.rs", src);
        assert_eq!(sites.len(), 1);
        assert!(sites[0].justified);
        assert_eq!(sites[0].kind, SiteKind::Fn);
    }

    #[test]
    fn attributes_between_comment_and_site_are_transparent() {
        let src = "// SAFETY: fine.\n#[inline(always)]\nunsafe fn f() {}\n";
        let sites = audit_file("gemm/x.rs", src);
        assert_eq!(sites.len(), 1);
        assert!(sites[0].justified);
    }

    #[test]
    fn strings_comments_and_lints_never_count_as_sites() {
        let src = concat!(
            "#![deny(unsafe_op_in_unsafe_fn)]\n",
            "#![forbid(unsafe_code)]\n",
            "// unsafe { in a comment }\n",
            "/* unsafe in a /* nested */ block comment */\n",
            "let a = \"unsafe\";\n",
            "let b = r#\"unsafe { }\"#;\n",
            "let c = '\"'; let d = \"unsafe\";\n",
        );
        assert!(audit_file("planner/x.rs", src).is_empty());
    }

    #[test]
    fn allowlist_matches_modules_and_exact_files() {
        assert!(allowlisted("threadpool/mod.rs"));
        assert!(allowlisted("memory/aligned.rs"));
        assert!(allowlisted("gemm/micro/avx2.rs"));
        assert!(allowlisted("conv/fft_conv.rs"));
        assert!(allowlisted("tensor/quant.rs"));
        assert!(!allowlisted("conv/mec.rs"));
        assert!(!allowlisted("tensor/mod.rs"));
        assert!(!allowlisted("planner/mod.rs"));
        assert!(!allowlisted("engine/mod.rs"));
    }

    #[test]
    fn real_tree_audits_clean_and_fails_when_a_safety_comment_is_removed() {
        // End-to-end self-test against the actual crate sources: the tree
        // must be clean, and deleting any one SAFETY comment from a real
        // file must produce a violation.
        let src_root = workspace_root().join("rust/src");
        assert!(src_root.is_dir(), "rust/src not found from the tool manifest");
        let mut files = Vec::new();
        collect_rs(&src_root, &mut files).unwrap();
        assert!(!files.is_empty());
        let mut total_sites = 0;
        for path in &files {
            let rel = path
                .strip_prefix(&src_root)
                .unwrap()
                .to_string_lossy()
                .replace('\\', "/");
            let content = std::fs::read_to_string(path).unwrap();
            let sites = audit_file(&rel, &content);
            if !sites.is_empty() {
                assert!(allowlisted(&rel), "{rel}: unsafe outside allowlist");
            }
            for s in &sites {
                assert!(s.justified, "{}:{} lacks a SAFETY comment", s.file, s.line);
            }
            total_sites += sites.len();
        }
        assert!(total_sites > 0, "expected unsafe sites in the tree");

        // Mutation leg: strip the first pure `// SAFETY:` comment line
        // from the threadpool and re-audit — the uncovered site must now
        // be reported.
        let victim = src_root.join("threadpool/mod.rs");
        let content = std::fs::read_to_string(&victim).unwrap();
        let mutated: Vec<&str> = content
            .lines()
            .filter({
                let mut dropped = false;
                move |l| {
                    let hit = !dropped && l.trim_start().starts_with("// SAFETY:");
                    if hit {
                        dropped = true;
                    }
                    !hit
                }
            })
            .collect();
        assert_eq!(mutated.len() + 1, content.lines().count());
        let sites = audit_file("threadpool/mod.rs", &mutated.join("\n"));
        assert!(
            sites.iter().any(|s| !s.justified),
            "removing a SAFETY comment must surface an unjustified site"
        );
    }
}
