//! Chaos suite: the fault-domain contract under seeded fault injection.
//!
//! Every test arms a deterministic [`ScopedFaults`] plan (the in-process
//! equivalent of `MEC_FAULTS=<seed>:<spec>`) and asserts the graceful-
//! degradation guarantees end to end:
//!
//! * **Conservation** — `requests == responses + rejected` holds no
//!   matter what faults fire; a panicked request still gets a typed
//!   reply and counts as a response.
//! * **Containment** — a forward-pass panic costs exactly its batch:
//!   typed [`ServeError::Panicked`] replies (with the layer attributed),
//!   then the worker rebuilds its session and keeps serving.
//! * **Supervision** — a worker that dies outside containment is
//!   respawned by the supervisor within the backoff bound, visible in
//!   [`Server::health`].
//! * **Degradation ladder** — a refused workspace reservation re-plans
//!   the engine onto the zero-workspace family; the degraded forward is
//!   bitwise-identical to a fresh zero-budget build, and the steady
//!   state afterwards is back to zero tracked allocation and zero OS
//!   thread spawns *between* faults.
//!
//! # Reproducing a failure
//!
//! The randomized soak derives its plan from `MEC_CHAOS_SEED` and prints
//! a ready-to-paste `MEC_FAULTS=…` replay line on failure — the same
//! discipline as `MEC_FUZZ_SEED` in the differential oracle.
//!
//! Tracker-sensitive work serializes on the tracker's global lock (via
//! `measure_peak`), *then* arms faults — every test takes the locks in
//! that order, so parallel test threads neither perturb the zero-alloc
//! assertions nor deadlock on the two global locks.

use mec::conv::AlgoKind;
use mec::coordinator::{RetryPolicy, ServeError, Server, ServerConfig, SubmitError};
use mec::engine::Engine;
use mec::fault::ScopedFaults;
use mec::memory::{self, measure_peak, Budget};
use mec::model::{Layer, Model};
use mec::serving::ShedReason;
use mec::tensor::{Kernel, KernelShape, Nhwc, Tensor};
use mec::util::Rng;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Run `f` holding the tracker's global lock, so engine-building tests
/// in this binary never perturb each other's tracked-allocation reads.
/// Lock order is fixed: tracker first, [`ScopedFaults`] second.
fn with_tracker_lock<T>(f: impl FnOnce() -> T) -> T {
    measure_peak(f).0
}

fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Ok(v) => {
            let t = v.trim();
            match t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
                Some(hex) => u64::from_str_radix(hex, 16).unwrap_or(default),
                None => t.parse().unwrap_or(default),
            }
        }
        Err(_) => default,
    }
}

/// 6×6×1 conv model for the serving tests (36-float samples).
fn serve_model() -> Model {
    let mut rng = Rng::new(0xc405);
    Model::new(
        "chaos-serve",
        (6, 6, 1),
        vec![
            Layer::Conv {
                kernel: Kernel::random(KernelShape::new(3, 3, 1, 2), &mut rng),
                bias: vec![0.0; 2],
                sh: 1,
                sw: 1,
                ph: 1,
                pw: 1,
            },
            Layer::Relu,
        ],
    )
}

/// 8×8×2 conv model for the degradation-ladder tests (MEC plans a real
/// workspace here, so there is something to degrade away from).
fn ladder_model() -> Model {
    let mut rng = Rng::new(0x1adde7);
    Model::new(
        "chaos-ladder",
        (8, 8, 2),
        vec![
            Layer::Conv {
                kernel: Kernel::random(KernelShape::new(3, 3, 2, 4), &mut rng),
                bias: vec![0.1; 4],
                sh: 1,
                sw: 1,
                ph: 1,
                pw: 1,
            },
            Layer::Relu,
        ],
    )
}

fn serve_engine() -> Arc<Engine> {
    Arc::new(
        Engine::builder(serve_model())
            .algo_override(0, AlgoKind::Mec)
            .pin_batch_sizes(&[1, 2, 4, 8])
            .build()
            .expect("serve model builds"),
    )
}

/// Conservation invariant: every request the server ever saw is either
/// a delivered response or a counted rejection — nothing vanishes.
fn assert_conservation(metrics: &mec::coordinator::Metrics, context: &str) {
    let requests = metrics.requests.load(Ordering::Relaxed);
    let responses = metrics.responses.load(Ordering::Relaxed);
    let rejected = metrics.rejected.load(Ordering::Relaxed);
    assert_eq!(
        requests,
        responses + rejected,
        "{context}: conservation violated — {requests} requests != \
         {responses} responses + {rejected} rejected"
    );
}

#[test]
fn injected_forward_panic_gets_a_typed_reply_and_the_worker_keeps_serving() {
    with_tracker_lock(|| {
        let engine = serve_engine();
        let _g = ScopedFaults::new(0xc0a5, "engine.forward=panic#1");
        let server =
            Server::start(Arc::clone(&engine), ServerConfig::default()).expect("server starts");
        let client = server.client();
        // First request: the forward pass panics at the injected site.
        // Containment converts that into a typed reply, not a lost
        // request and not a dead worker.
        let resp = client.infer(vec![0.2; 36]).expect("submit is accepted");
        match resp.result {
            Err(ServeError::Panicked { layer, ref payload }) => {
                assert!(
                    layer.is_some(),
                    "the executor's layer scope must attribute the panic"
                );
                assert!(
                    payload.contains("engine.forward"),
                    "payload names the fault site: {payload:?}"
                );
            }
            ref other => panic!("expected a Panicked reply, got {other:?}"),
        }
        // Same worker, fresh session: the very next request serves.
        assert!(client.infer(vec![0.2; 36]).unwrap().result.is_ok());
        let health = server.health();
        assert_eq!(health.panicked_requests, 1);
        assert_eq!(health.restarts, 0, "containment means no worker died");
        let metrics = server.shutdown();
        assert_eq!(metrics.requests.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.panicked.load(Ordering::Relaxed), 1);
        assert_conservation(&metrics, "panic containment");
    });
}

#[test]
fn dead_worker_is_respawned_within_the_backoff_bound() {
    with_tracker_lock(|| {
        let engine = serve_engine();
        // A panic *between* batches (the serve.worker site) escapes
        // per-request containment by design: it kills the whole worker
        // thread while it holds no requests. The supervisor must notice
        // and respawn it.
        let _g = ScopedFaults::new(0xdead, "serve.worker=panic#1");
        let server =
            Server::start(Arc::clone(&engine), ServerConfig::default()).expect("server starts");
        // First backoff is 10 ms + a 2 ms supervisor poll; 5 s is the
        // generous CI-machine bound, not the expectation.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let h = server.health();
            if h.restarts >= 1 && h.live_workers == h.workers {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "worker not respawned within the backoff bound: {h}"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        // The respawned worker serves (its faultpoint's #1 limit is
        // already spent).
        let client = server.client();
        assert!(client.infer(vec![0.4; 36]).unwrap().result.is_ok());
        let health = server.health();
        assert_eq!(health.restarts, 1, "exactly one death, one respawn");
        let metrics = server.shutdown();
        assert_conservation(&metrics, "worker respawn");
    });
}

#[test]
fn injected_alloc_refusal_walks_the_degradation_ladder() {
    with_tracker_lock(|| {
        let engine = Engine::builder(ladder_model())
            .algo_override(0, AlgoKind::Mec)
            .pin_batch_sizes(&[1, 2])
            .build()
            .expect("ladder model builds");
        assert!(engine.workspace_elems() > 0, "MEC plans a real workspace");
        let mut rng = Rng::new(3);
        let x = Tensor::random(Nhwc::new(2, 8, 8, 2), &mut rng);
        let mut session = engine.session();
        let degraded_out = {
            let _g = ScopedFaults::new(0x10ad, "memory.arena.grow=alloc#1");
            // The refused workspace reservation triggers one engine-wide
            // re-plan onto the zero-workspace family and a retry — the
            // caller sees a successful forward, not an error.
            session.infer_batch(&x).expect("degrade + retry serves the request")
        };
        assert!(engine.is_degraded());
        assert_eq!(engine.degrade_epoch(), 1);
        assert_eq!(engine.workspace_elems(), 0, "the fallback family needs no arena");
        let transitions = engine.degraded_layers();
        assert!(!transitions.is_empty(), "the MEC layer must have moved");
        for t in &transitions {
            assert_ne!(t.from, t.to, "a recorded transition must change the algorithm");
        }
        assert_eq!(transitions[0].from, AlgoKind::Mec);
        // LayerPlan reporting follows the ladder: the current report
        // shows the fallback with zero workspace, while the build-time
        // report still documents what was built.
        for lp in engine.plan_report_current() {
            assert_eq!(
                lp.chosen.workspace_bytes, 0,
                "layer {} still reports a workspace after degrade",
                lp.layer
            );
        }
        assert!(engine.plan_report()[0].chosen.workspace_bytes > 0);
        // Bitwise identity: the degraded forward equals a fresh engine
        // planned under a zero budget from the start (same planner, same
        // zero-workspace choices — not merely "close").
        let zero = Engine::builder(ladder_model())
            .budget(Budget::new(0))
            .pin_batch_sizes(&[1, 2])
            .build()
            .expect("zero-budget build");
        let reference = zero.session().infer_batch(&x).expect("reference forward");
        assert_eq!(
            degraded_out.data(),
            reference.data(),
            "degraded forward must be bitwise identical to the zero-budget plan"
        );
        // Steady state after the fault: zero tracked allocation. The
        // degraded plans own no lowering buffers, the activation arena
        // was pre-sized at session creation, and the memo re-warmed on
        // the retry.
        let before = memory::current_bytes();
        for rep in 0..10 {
            session.infer_batch(&x).expect("degraded steady state serves");
            assert_eq!(
                memory::current_bytes(),
                before,
                "rep {rep}: tracked allocation in degraded steady state"
            );
        }
    });
}

#[test]
fn server_reports_degradation_in_health_and_stays_quiet_between_faults() {
    with_tracker_lock(|| {
        let engine = Arc::new(
            Engine::builder(serve_model())
                .algo_override(0, AlgoKind::Mec)
                .pin_batch_sizes(&[1, 2, 4, 8])
                .threads(2)
                .build()
                .expect("serve model builds"),
        );
        let _g = ScopedFaults::new(0xf00d, "memory.arena.grow=alloc#1");
        let server =
            Server::start(Arc::clone(&engine), ServerConfig::default()).expect("server starts");
        let client = server.client();
        // The first forward hits the refusal, degrades, retries, and
        // still answers — the client never sees the fault.
        assert!(client.infer(vec![0.3; 36]).unwrap().result.is_ok());
        let health = server.health();
        assert!(health.degraded, "health must surface the ladder: {health}");
        assert!(!health.degraded_layers.is_empty());
        assert_eq!(health.live_workers, health.workers);
        assert_eq!(health.restarts, 0, "degradation is not a worker death");
        // Between faults the system is quiet: no tracked allocation, no
        // OS thread spawns, no respawns — just serving.
        for _ in 0..5 {
            assert!(client.infer(vec![0.3; 36]).unwrap().result.is_ok());
        }
        let bytes_before = memory::current_bytes();
        let spawned_before = engine.pool_threads_spawned();
        for rep in 0..20 {
            assert!(client.infer(vec![0.3; 36]).unwrap().result.is_ok());
            assert_eq!(
                memory::current_bytes(),
                bytes_before,
                "rep {rep}: tracked allocation between faults"
            );
            assert_eq!(
                engine.pool_threads_spawned(),
                spawned_before,
                "rep {rep}: OS thread spawned between faults"
            );
        }
        assert_eq!(server.health().restarts, 0);
        let metrics = server.shutdown();
        assert_conservation(&metrics, "degraded serving");
    });
}

#[test]
fn retry_schedule_is_deterministic_and_survives_backpressure() {
    with_tracker_lock(|| {
        let engine = serve_engine();
        // Stall the single worker for 400 ms before it consumes
        // anything, so a depth-1 queue stays full for the whole retry
        // schedule — deterministic backpressure without racing a drain.
        let _g = ScopedFaults::new(0xb0ff, "serve.worker=delay400#1");
        let server = Server::start(
            Arc::clone(&engine),
            ServerConfig { queue_depth: 1, ..ServerConfig::default() },
        )
        .expect("server starts");
        let client = server.client();
        let rx_first = client.submit(vec![0.5; 36]).expect("empty queue admits");
        // Every attempt sees the full queue; the recorded delays must be
        // exactly the policy's seeded schedule (no wall-clock sleeps —
        // the injected sleep only records).
        let policy = RetryPolicy::default();
        let mut recorded = Vec::new();
        let err = client
            .submit_with_retry_using(vec![0.5; 36], &policy, |d| recorded.push(d))
            .expect_err("backpressure outlives the retry budget");
        assert!(
            matches!(err, SubmitError::Shed(ShedReason::QueueFull { .. })),
            "got {err:?}"
        );
        let mut rng = Rng::new(policy.seed);
        let expected: Vec<Duration> = (0..policy.max_attempts - 1)
            .map(|i| policy.delay(i, &mut rng))
            .collect();
        assert_eq!(recorded, expected, "jittered schedule replays from the seed");
        // The stalled worker wakes, drains the queue, and the same
        // client recovers with real sleeps.
        assert!(rx_first.recv().expect("stalled request is served").result.is_ok());
        let rx = client
            .submit_with_retry(vec![0.5; 36], &policy)
            .expect("drained queue admits");
        assert!(rx.recv().expect("answered").result.is_ok());
        let metrics = server.shutdown();
        // 1 stalled + 4 shed attempts + 1 recovered.
        assert_eq!(metrics.requests.load(Ordering::Relaxed), 6);
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 4);
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 2);
        assert_conservation(&metrics, "retry under backpressure");
    });
}

/// Randomized soak: one seeded plan mixing alloc refusals, forward
/// panics, worker deaths, and dispatch delays under concurrent load.
/// Override the plan with `MEC_CHAOS_SEED=<u64>`; a failure prints the
/// `MEC_FAULTS=…` line that replays it bit-for-bit.
#[test]
fn randomized_chaos_soak_holds_conservation() {
    let seed = env_u64("MEC_CHAOS_SEED", 0xc4a0_5eed);
    let spec = "engine.forward=panic@0.04#3,memory.arena.grow=alloc@0.25#1,\
                serve.worker=panic@0.3#2,serve.dispatch=delay1@0.05";
    with_tracker_lock(|| {
        let engine = Arc::new(
            Engine::builder(serve_model())
                .algo_override(0, AlgoKind::Mec)
                .pin_batch_sizes(&[1, 2, 4, 8])
                .threads(2)
                .build()
                .expect("serve model builds"),
        );
        let g = ScopedFaults::new(seed, spec);
        let replay = format!(
            "chaos soak failed — replay with: {} cargo test --test chaos \
             randomized_chaos_soak (or MEC_CHAOS_SEED={seed:#x})",
            g.plan().replay_line()
        );
        let server = Server::start(
            Arc::clone(&engine),
            ServerConfig { workers: 2, queue_depth: 256, ..ServerConfig::default() },
        )
        .expect("server starts");
        let client = server.client();
        let mut submitted = 0u64;
        let mut shed_at_submit = 0u64;
        let mut rxs = Vec::new();
        for i in 0..120 {
            match client.submit(vec![0.1 + (i % 7) as f32 * 0.05; 36]) {
                Ok(rx) => {
                    submitted += 1;
                    rxs.push(rx);
                }
                Err(SubmitError::Shed(_)) => shed_at_submit += 1,
                Err(e) => panic!("{replay}\nunexpected submit error: {e}"),
            }
        }
        // Every admitted request gets a reply — success, typed engine
        // error, typed shed, or typed panic — within the respawn bound.
        let mut answered = 0u64;
        let mut panicked = 0u64;
        for rx in rxs {
            let resp = rx
                .recv_timeout(Duration::from_secs(10))
                .unwrap_or_else(|_| panic!("{replay}\nadmitted request never answered"));
            match resp.result {
                Ok(_) | Err(ServeError::Engine(_)) | Err(ServeError::Shed(_)) => {}
                Err(ServeError::Panicked { .. }) => panicked += 1,
            }
            answered += 1;
        }
        assert_eq!(answered, submitted, "{replay}");
        let health = server.health();
        let metrics = server.shutdown();
        let requests = metrics.requests.load(Ordering::Relaxed);
        let responses = metrics.responses.load(Ordering::Relaxed);
        let rejected = metrics.rejected.load(Ordering::Relaxed);
        assert_eq!(
            requests,
            responses + rejected,
            "{replay}\nconservation violated: {requests} != {responses} + {rejected}"
        );
        assert_eq!(responses, submitted, "{replay}");
        assert_eq!(rejected, shed_at_submit, "{replay}");
        assert_eq!(
            metrics.panicked.load(Ordering::Relaxed),
            panicked,
            "{replay}\npanicked counter disagrees with typed replies"
        );
        assert_eq!(
            health.panicked_requests, panicked,
            "{replay}\nhealth disagrees with typed replies"
        );
    });
}
