//! Graph IR contract tests — the acceptance criteria of the DAG + pass
//! pipeline redesign:
//!
//! * a `Graph::sequential` model is **bitwise identical** to a
//!   hand-rolled per-layer reference interpreter (same weights, same
//!   input), across random layer stacks and batch sizes — fusion,
//!   slot reuse, and in-place execution must never change a bit;
//! * conv+bias+relu fusion equals the unfused reference exactly;
//! * `Add`/`Concat` compute what they say;
//! * on a diamond (residual) graph the activation arena's tracked peak
//!   equals the liveness plan's **max live set** — not the sum of node
//!   outputs — and an `Engine`/`Session` serves the graph with zero
//!   tracked allocations in steady state.

use mec::conv::{convolve, AlgoKind, ConvContext};
use mec::gemm::{gemm_ex, MatMut, MatRef};
use mec::memory::{self, measure_peak};
use mec::model::{GraphBuilder, Layer, Model};
use mec::tensor::{ConvShape, Kernel, KernelShape, Nhwc, Tensor};
use mec::util::Rng;

/// Reference interpreter: evaluate `layers` sequentially with the
/// one-shot primitives (no graph, no fusion, no arena). Bitwise ground
/// truth for the compiled executor when the model pins `algo`.
fn reference_forward(
    layers: &[Layer],
    algo: AlgoKind,
    ctx: &ConvContext,
    input: &Tensor,
) -> Tensor {
    let mut x = input.clone();
    for layer in layers {
        x = match layer {
            Layer::Conv { kernel, bias, sh, sw, ph, pw } => {
                let padded = if *ph > 0 || *pw > 0 {
                    x.pad_spatial(*ph, *pw)
                } else {
                    x
                };
                let cs = ConvShape::new(padded.shape(), kernel.shape(), *sh, *sw);
                let mut out = convolve(algo, ctx, &cs, &padded, kernel);
                let kc = kernel.shape().kc;
                for chunk in out.data_mut().chunks_exact_mut(kc) {
                    for (v, b) in chunk.iter_mut().zip(bias) {
                        *v += b;
                    }
                }
                out
            }
            Layer::Relu => {
                let mut out = x;
                for v in out.data_mut() {
                    *v = v.max(0.0);
                }
                out
            }
            Layer::MaxPool { k, s } => {
                let sh = x.shape();
                let (oh, ow) = ((sh.h - k) / s + 1, (sh.w - k) / s + 1);
                let mut out = Tensor::zeros(Nhwc::new(sh.n, oh, ow, sh.c));
                for n in 0..sh.n {
                    for y in 0..oh {
                        for x0 in 0..ow {
                            for c in 0..sh.c {
                                let mut m = f32::NEG_INFINITY;
                                for dy in 0..*k {
                                    for dx in 0..*k {
                                        m = m.max(x.at(n, y * s + dy, x0 * s + dx, c));
                                    }
                                }
                                *out.at_mut(n, y, x0, c) = m;
                            }
                        }
                    }
                }
                out
            }
            Layer::Flatten => {
                let sh = x.shape();
                Tensor::from_vec(Nhwc::new(sh.n, 1, 1, sh.h * sh.w * sh.c), x.into_vec())
            }
            Layer::Dense { w, bias, d_in, d_out } => {
                let n = x.shape().n;
                let mut out = Tensor::zeros(Nhwc::new(n, 1, 1, *d_out));
                let a = MatRef::new(x.data(), n, *d_in);
                let b = MatRef::new(w, *d_in, *d_out);
                let mut c = MatMut::new(out.data_mut(), n, *d_out);
                gemm_ex(a, b, &mut c, 1.0, 0.0, &ctx.par, ctx.blocks);
                for row in out.data_mut().chunks_exact_mut(*d_out) {
                    for (v, bb) in row.iter_mut().zip(bias) {
                        *v += bb;
                    }
                }
                out
            }
            Layer::Softmax => {
                let mut out = x;
                let c = out.shape().c;
                for row in out.data_mut().chunks_exact_mut(c) {
                    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut sum = 0.0;
                    for v in row.iter_mut() {
                        *v = (*v - m).exp();
                        sum += *v;
                    }
                    for v in row.iter_mut() {
                        *v /= sum;
                    }
                }
                out
            }
        };
    }
    x
}

fn classifier_layers(rng: &mut Rng, ic: usize, hw: usize) -> Vec<Layer> {
    let kc = rng.range(2, 5);
    let pooled = hw / 2;
    let d_in = pooled * pooled * kc;
    let d_out = rng.range(2, 5);
    vec![
        Layer::Conv {
            kernel: Kernel::random(KernelShape::new(3, 3, ic, kc), rng),
            bias: {
                let mut b = vec![0.0; kc];
                rng.fill_uniform(&mut b, -0.2, 0.2);
                b
            },
            sh: 1,
            sw: 1,
            ph: 1,
            pw: 1,
        },
        Layer::Relu,
        Layer::MaxPool { k: 2, s: 2 },
        Layer::Flatten,
        Layer::Dense {
            w: {
                let mut w = vec![0.0; d_in * d_out];
                rng.fill_uniform(&mut w, -0.4, 0.4);
                w
            },
            bias: vec![0.1; d_out],
            d_in,
            d_out,
        },
        Layer::Softmax,
    ]
}

#[test]
fn sequential_graph_is_bitwise_identical_to_reference_interpreter() {
    let mut rng = Rng::new(0x6a1);
    let ctx = ConvContext::default();
    for case in 0..6 {
        let hw = [6usize, 8, 10][case % 3];
        let ic = rng.range(1, 4);
        let layers = classifier_layers(&mut rng, ic, hw);
        for algo in [AlgoKind::Direct, AlgoKind::Im2col, AlgoKind::Mec] {
            let mut m = Model::new("prop", (hw, hw, ic), layers.clone());
            m.pin_algo(algo);
            for batch in [1usize, 3] {
                let input = Tensor::random(Nhwc::new(batch, hw, hw, ic), &mut rng);
                let want = reference_forward(&layers, algo, &ctx, &input);
                let mut arena = mec::memory::Arena::new();
                let got = m.forward(&ctx, &input, &mut arena);
                assert_eq!(got.shape(), want.shape());
                assert_eq!(
                    got.data(),
                    want.data(),
                    "case {case} {} batch {batch}: graph executor diverged bitwise",
                    algo.name()
                );
            }
        }
    }
}

#[test]
fn fused_conv_relu_equals_unfused_reference() {
    // Fused (conv→relu absorbed into the epilogue) vs the same conv
    // model followed by a standalone relu-only model: bitwise equality
    // — comfortably inside any f32 ulp bound.
    let mut rng = Rng::new(0xf5e);
    let kernel = Kernel::random(KernelShape::new(3, 3, 2, 5), &mut rng);
    let bias = vec![-0.3, 0.2, 0.0, 0.1, -0.05];
    let conv = Layer::Conv {
        kernel,
        bias,
        sh: 1,
        sw: 1,
        ph: 1,
        pw: 1,
    };
    let fused = Model::new("fused", (9, 9, 2), vec![conv.clone(), Layer::Relu]);
    assert_eq!(
        fused.exec().steps().len(),
        1,
        "fusion pass should absorb the relu"
    );
    let conv_only = Model::new("conv", (9, 9, 2), vec![conv]);
    let relu_only = Model::new("relu", (9, 9, 5), vec![Layer::Relu]);
    assert_eq!(relu_only.exec().steps().len(), 1, "standalone relu executes");
    let ctx = ConvContext::default();
    let mut arena = mec::memory::Arena::new();
    let input = Tensor::random(Nhwc::new(2, 9, 9, 2), &mut rng);
    let a = fused.forward(&ctx, &input, &mut arena);
    let mid = conv_only.forward(&ctx, &input, &mut arena);
    let b = relu_only.forward(&ctx, &mid, &mut arena);
    assert_eq!(a.shape(), b.shape());
    assert_eq!(a.data(), b.data(), "fused epilogue diverged from relu∘conv");
}

#[test]
fn add_and_concat_compute_reference_values() {
    let mut rng = Rng::new(0xadc);
    let k1 = Kernel::random(KernelShape::new(1, 1, 2, 3), &mut rng);
    let k2 = Kernel::random(KernelShape::new(1, 1, 2, 3), &mut rng);

    // add(conv1(x), conv2(x)) — both 1×1 so shapes trivially agree.
    let mut b = GraphBuilder::new("add", (4, 4, 2));
    let x = b.input();
    let c1 = b.conv(x, k1.clone(), vec![0.0; 3], 1, 1, 0, 0);
    let c2 = b.conv(x, k2.clone(), vec![0.0; 3], 1, 1, 0, 0);
    let sum = b.add(&[c1, c2]);
    let m = Model::from_graph(b.finish(sum));
    let input = Tensor::random(Nhwc::new(2, 4, 4, 2), &mut rng);
    let ctx = ConvContext::default();
    let mut arena = mec::memory::Arena::new();
    let got = m.forward(&ctx, &input, &mut arena);
    let ref1 = reference_forward(
        &[Layer::Conv { kernel: k1.clone(), bias: vec![0.0; 3], sh: 1, sw: 1, ph: 0, pw: 0 }],
        AlgoKind::Mec,
        &ctx,
        &input,
    );
    let ref2 = reference_forward(
        &[Layer::Conv { kernel: k2.clone(), bias: vec![0.0; 3], sh: 1, sw: 1, ph: 0, pw: 0 }],
        AlgoKind::Mec,
        &ctx,
        &input,
    );
    let want: Vec<f32> = ref1
        .data()
        .iter()
        .zip(ref2.data())
        .map(|(a, b)| a + b)
        .collect();
    assert_eq!(got.data(), &want[..], "add mismatch");

    // concat(conv1(x), conv2(x)) interleaves channels per (n, h, w).
    let mut b = GraphBuilder::new("concat", (4, 4, 2));
    let x = b.input();
    let c1 = b.conv(x, k1, vec![0.0; 3], 1, 1, 0, 0);
    let c2 = b.conv(x, k2, vec![0.0; 3], 1, 1, 0, 0);
    let cat = b.concat(&[c1, c2]);
    let m = Model::from_graph(b.finish(cat));
    assert_eq!(m.validate(), Nhwc::new(1, 4, 4, 6));
    let got = m.forward(&ctx, &input, &mut arena);
    for r in 0..2 * 4 * 4 {
        assert_eq!(&got.data()[r * 6..r * 6 + 3], &ref1.data()[r * 3..r * 3 + 3]);
        assert_eq!(&got.data()[r * 6 + 3..r * 6 + 6], &ref2.data()[r * 3..r * 3 + 3]);
    }
}

/// The diamond of the acceptance criteria: conv → relu → {branch conv,
/// identity} → add → relu, through the bench workload helper.
fn diamond() -> Model {
    let w = mec::bench::workload::by_name("cv10").unwrap();
    mec::bench::workload::residual_block_model(&w, 16, 0x1e5)
}

#[test]
fn diamond_activation_arena_peak_equals_max_live_set() {
    let m = diamond();
    let batch = 2;
    // Analytic: the packing hit the interval-coloring lower bound, and
    // that bound is strictly below the sum of node outputs (what the
    // old per-node allocation paid).
    assert_eq!(m.activation_bytes(batch), m.max_live_bytes(batch));
    let sum_of_outputs: usize = (0..m.node_count())
        .map(|i| m.exec().shape_of(i).len() * batch * 4)
        .sum();
    assert!(
        m.activation_bytes(batch) < sum_of_outputs,
        "liveness plan ({}) should beat sum-over-nodes ({})",
        m.activation_bytes(batch),
        sum_of_outputs
    );
    // Measured: a forward's tracked activation peak equals the plan.
    let mut m = m;
    m.plan(
        &mec::planner::Planner::new(),
        &mec::memory::Budget::unlimited(),
        &ConvContext::default(),
        batch,
    );
    let (h, w, c) = m.input_hwc;
    let mut rng = Rng::new(5);
    let input = Tensor::random(Nhwc::new(batch, h, w, c), &mut rng);
    let ((), peak) = measure_peak(|| {
        let mut arena = m.sized_arena();
        let _ = m.forward(&ConvContext::default(), &input, &mut arena);
    });
    assert_eq!(
        peak,
        m.planned_workspace_bytes() + m.activation_bytes(batch),
        "tracked peak must be workspace max + max-live activations"
    );
}

#[test]
fn diamond_serves_through_engine_with_zero_steady_state_allocations() {
    let m = diamond();
    let batch = 2;
    let engine = mec::engine::Engine::builder(m)
        .pin_batch_sizes(&[1, batch])
        .build()
        .expect("residual graph builds through the facade");
    assert_eq!(
        engine.activation_bytes(),
        engine.model().max_live_bytes(batch),
        "engine sizes sessions at the liveness plan's max live set"
    );
    let (h, w, c) = engine.input_hwc();
    let mut rng = Rng::new(9);
    let input = Tensor::random(Nhwc::new(batch, h, w, c), &mut rng);
    let mut sample = vec![0.0f32; h * w * c];
    rng.fill_uniform(&mut sample, -1.0, 1.0);
    // Hold the tracker lock (via measure_peak) so parallel tests don't
    // interfere with the steady-state deltas.
    let ((), _peak) = measure_peak(|| {
        let mut session = engine.session();
        let want_batch = session.infer_batch(&input).unwrap();
        let want_one = session.infer(&sample).unwrap();
        let before = memory::current_bytes();
        for rep in 0..3 {
            let got = session.infer_batch(&input).unwrap();
            assert_eq!(got.data(), want_batch.data(), "rep {rep}: batch diverged");
            let got = session.infer(&sample).unwrap();
            assert_eq!(got, want_one, "rep {rep}: single-sample diverged");
            assert_eq!(
                memory::current_bytes(),
                before,
                "rep {rep}: tracked allocation in steady state"
            );
        }
        assert_eq!(session.activation_bytes(), engine.activation_bytes());
        assert_eq!(session.workspace_bytes(), engine.workspace_bytes());
    });
}

#[test]
fn in_place_relu_does_not_clobber_a_live_flatten_alias() {
    // c = conv(x); f = flatten(c); r = relu(c); out = add(f, flatten(r)).
    // The flatten aliases c's slot, so the relu must NOT run in place on
    // that slot even though c dies at the relu — an in-place write would
    // corrupt f's data before the add reads it.
    let mut rng = Rng::new(0xc10b);
    let mut b = GraphBuilder::new("alias-hazard", (4, 4, 1));
    let x = b.input();
    let kernel = Kernel::random(KernelShape::new(1, 1, 1, 2), &mut rng);
    let c = b.conv(x, kernel.clone(), vec![0.0; 2], 1, 1, 0, 0);
    let f = b.flatten(c);
    let r = b.relu(c);
    let fr = b.flatten(r);
    let sum = b.add(&[f, fr]);
    let m = Model::from_graph(b.finish(sum));
    let ctx = ConvContext::default();
    let input = Tensor::random(Nhwc::new(2, 4, 4, 1), &mut rng);
    let mut arena = mec::memory::Arena::new();
    let got = m.forward(&ctx, &input, &mut arena);
    // Reference: conv once, then c + relu(c) elementwise.
    let conv = reference_forward(
        &[Layer::Conv { kernel, bias: vec![0.0; 2], sh: 1, sw: 1, ph: 0, pw: 0 }],
        AlgoKind::Mec,
        &ctx,
        &input,
    );
    let want: Vec<f32> = conv.data().iter().map(|&v| v + v.max(0.0)).collect();
    assert_eq!(got.data(), &want[..], "in-place relu clobbered the alias");
}

#[test]
fn graph_builder_rejects_bad_shapes() {
    // Residual add across mismatched channel counts must fail at finish
    // (shape inference), not at execute.
    let result = std::panic::catch_unwind(|| {
        let mut rng = Rng::new(1);
        let mut b = GraphBuilder::new("bad", (4, 4, 2));
        let x = b.input();
        let c1 = b.conv(
            x,
            Kernel::random(KernelShape::new(1, 1, 2, 3), &mut rng),
            vec![0.0; 3],
            1,
            1,
            0,
            0,
        );
        let c2 = b.conv(
            x,
            Kernel::random(KernelShape::new(1, 1, 2, 4), &mut rng),
            vec![0.0; 4],
            1,
            1,
            0,
            0,
        );
        let s = b.add(&[c1, c2]);
        b.finish(s)
    });
    assert!(result.is_err(), "mismatched add shapes must be rejected");
}
