//! Coordinator stress: many producers hammering the bounded queue while
//! batcher-consumers drain it and the queue closes mid-stream.
//!
//! The contract under test is exactly the serving guarantee the
//! coordinator advertises: every submitted request is either **answered
//! exactly once** (accepted by `push`) or **rejected** (backpressure
//! `Full` / shutdown `Closed`) — no request is lost after acceptance, no
//! request is answered twice, and nothing hangs.

use mec::coordinator::{BatchPolicy, Batcher, Request, RequestQueue, Response};
use mec::engine::Prediction;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

const PRODUCERS: usize = 8;
const CONSUMERS: usize = 2;
const PER_PRODUCER: usize = 250;

#[test]
fn multi_producer_close_midstream_answers_exactly_once_or_rejects() {
    let queue = Arc::new(RequestQueue::new(32)); // small: forces Full paths
    let accepted = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));
    let replied = Arc::new(AtomicUsize::new(0));

    // Consumers: drain batches, answer each request exactly once.
    let mut consumers = Vec::new();
    for _ in 0..CONSUMERS {
        let queue = Arc::clone(&queue);
        consumers.push(std::thread::spawn(move || {
            let batcher = Batcher::new(&queue, BatchPolicy::new(8, Duration::from_millis(1)));
            let mut served = 0usize;
            while let Some(batch) = batcher.next_batch() {
                for req in batch {
                    let resp = Response {
                        id: req.id,
                        batch_size: 1,
                        result: Ok(Prediction {
                            scores: vec![1.0],
                            class: 0,
                        }),
                    };
                    // Receiver may have gone away; the send itself must
                    // still be the one and only reply attempt.
                    let _ = req.reply.send(resp);
                    served += 1;
                }
            }
            served
        }));
    }

    // Producers: one push attempt per request (Full = load shed, the
    // queue's documented backpressure), then verify every accepted
    // request is answered exactly once.
    let mut producers = Vec::new();
    for p in 0..PRODUCERS {
        let queue = Arc::clone(&queue);
        let accepted = Arc::clone(&accepted);
        let rejected = Arc::clone(&rejected);
        let replied = Arc::clone(&replied);
        producers.push(std::thread::spawn(move || {
            let mut receivers = Vec::new();
            for i in 0..PER_PRODUCER {
                let (tx, rx) = mpsc::channel();
                let req = Request {
                    id: (p * PER_PRODUCER + i) as u64,
                    sample: vec![],
                    enqueued_at: Instant::now(),
                    deadline: None,
                    reply: tx,
                };
                match queue.push(req) {
                    Ok(()) => {
                        accepted.fetch_add(1, Ordering::SeqCst);
                        receivers.push((rx, (p * PER_PRODUCER + i) as u64));
                    }
                    Err(_) => {
                        rejected.fetch_add(1, Ordering::SeqCst);
                    }
                }
                if i % 16 == 0 {
                    std::thread::yield_now();
                }
            }
            for (rx, id) in receivers {
                // Exactly-once, part 1: an accepted request MUST receive
                // one reply (drain-on-close semantics; a hang here is the
                // bug this test exists to catch).
                let resp = rx
                    .recv_timeout(Duration::from_secs(30))
                    .unwrap_or_else(|e| panic!("accepted request {id} never answered: {e:?}"));
                assert_eq!(resp.id, id, "reply routed to the wrong request");
                replied.fetch_add(1, Ordering::SeqCst);
                // Exactly-once, part 2: no second reply may ever arrive —
                // the worker dropped its sender after the single send.
                match rx.try_recv() {
                    Err(mpsc::TryRecvError::Empty) | Err(mpsc::TryRecvError::Disconnected) => {}
                    Ok(dup) => panic!("request {id} answered twice: {dup:?}"),
                }
            }
        }));
    }

    // Close mid-stream while producers are still pushing: later pushes
    // are rejected with Closed, already-accepted requests still drain.
    // Gate the close on the first accepted push (not a fixed sleep) so a
    // loaded runner that delays producer scheduling can't close an
    // untouched queue and trip the accepted>0 assertion below.
    while accepted.load(Ordering::SeqCst) == 0 {
        std::thread::yield_now();
    }
    std::thread::sleep(Duration::from_millis(1));
    queue.close();

    for h in producers {
        h.join().expect("producer panicked");
    }
    let served: usize = consumers
        .into_iter()
        .map(|h| h.join().expect("consumer panicked"))
        .sum();

    let accepted = accepted.load(Ordering::SeqCst);
    let rejected = rejected.load(Ordering::SeqCst);
    let replied = replied.load(Ordering::SeqCst);
    // Conservation: every request has exactly one fate.
    assert_eq!(accepted + rejected, PRODUCERS * PER_PRODUCER);
    // Every accepted request was served exactly once and replied exactly
    // once (the per-request double-reply check ran inside the producers).
    assert_eq!(served, accepted);
    assert_eq!(replied, accepted);
    // The close is gated on the first accept, so accepted > 0 is
    // deterministic. Rejections (Full backpressure / post-close Closed)
    // are all but certain with a cap-32 queue under 2000 pushes, but a
    // degenerate scheduling where everything lands before the close is
    // conservation-clean too, so no hard rejected>0 assert (it would be
    // the one flaky line in an otherwise deterministic contract).
    assert!(accepted > 0, "close raced ahead of every producer");
    // Queue is fully drained.
    assert!(queue.is_empty());
}

#[test]
fn graceful_drain_under_full_server_load() {
    // The same exactly-once contract, end to end through the real
    // Server: producers hammer `Client::submit` while the main thread
    // shuts the server down mid-stream. Every accepted request must be
    // answered (drain semantics), every post-shutdown submit must fail
    // with `ShuttingDown` (or queue-full backpressure before the close
    // lands), and the metrics conservation must hold.
    use mec::conv::AlgoKind;
    use mec::coordinator::{Server, ServerConfig, SubmitError};
    use mec::engine::Engine;
    use mec::model::{Layer, Model};
    use mec::tensor::{Kernel, KernelShape};
    use mec::util::Rng;

    let mut rng = Rng::new(0x5EED);
    let model = Model::new(
        "drain-stress",
        (6, 6, 1),
        vec![
            Layer::Conv {
                kernel: Kernel::random(KernelShape::new(3, 3, 1, 2), &mut rng),
                bias: vec![0.0; 2],
                sh: 1,
                sw: 1,
                ph: 1,
                pw: 1,
            },
            Layer::Relu,
        ],
    );
    let engine = Arc::new(
        Engine::builder(model)
            .algo_override(0, AlgoKind::Mec)
            .pin_batch_sizes(&[1, 4, 8])
            .threads(2)
            .build()
            .expect("model builds"),
    );
    let server = Server::start(
        engine,
        ServerConfig {
            workers: 2,
            queue_depth: 64,
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    )
    .expect("server starts");
    let client = server.client();

    let accepted = Arc::new(AtomicUsize::new(0));
    let shed = Arc::new(AtomicUsize::new(0));
    let after_close = Arc::new(AtomicUsize::new(0));
    let mut producers = Vec::new();
    for _ in 0..4 {
        let client = client.clone();
        let accepted = Arc::clone(&accepted);
        let shed = Arc::clone(&shed);
        let after_close = Arc::clone(&after_close);
        producers.push(std::thread::spawn(move || {
            let mut receivers = Vec::new();
            for i in 0..200 {
                match client.submit(vec![0.4f32; 36]) {
                    Ok(rx) => {
                        accepted.fetch_add(1, Ordering::SeqCst);
                        receivers.push(rx);
                    }
                    Err(SubmitError::Shed(_)) => {
                        shed.fetch_add(1, Ordering::SeqCst);
                    }
                    Err(SubmitError::ShuttingDown) => {
                        after_close.fetch_add(1, Ordering::SeqCst);
                    }
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
                if i % 32 == 0 {
                    std::thread::yield_now();
                }
            }
            // Every accepted request gets exactly one reply, even though
            // the server shut down mid-stream.
            for rx in receivers {
                let resp = rx
                    .recv_timeout(Duration::from_secs(30))
                    .expect("accepted request must be answered during drain");
                assert!(resp.result.is_ok(), "valid sample must serve: {resp:?}");
            }
        }));
    }

    // Shut down while producers are mid-stream (gated on first accept).
    while accepted.load(Ordering::SeqCst) == 0 {
        std::thread::yield_now();
    }
    std::thread::sleep(Duration::from_millis(2));
    let metrics = server.shutdown();

    for h in producers {
        h.join().expect("producer panicked");
    }
    // Post-shutdown submits fail fast with the typed shutdown error.
    assert_eq!(
        client.submit(vec![0.4f32; 36]).unwrap_err(),
        SubmitError::ShuttingDown
    );
    let accepted = accepted.load(Ordering::SeqCst);
    assert!(accepted > 0, "shutdown raced ahead of every producer");
    // Conservation across the whole run (the one post-shutdown submit
    // above is included: it counted requests+1 and rejected+1).
    assert_eq!(
        metrics.requests.load(Ordering::Relaxed),
        metrics.responses.load(Ordering::Relaxed) + metrics.rejected.load(Ordering::Relaxed)
    );
    // Everything accepted was served.
    assert_eq!(metrics.responses.load(Ordering::Relaxed) as usize, accepted);
}

#[test]
fn consumers_unblock_on_close_with_empty_queue() {
    // Regression: consumers long-polling an empty queue must wake and
    // exit when it closes, not wait out their poll deadline forever.
    let queue = Arc::new(RequestQueue::new(4));
    let qc = Arc::clone(&queue);
    let t0 = Instant::now();
    let consumer = std::thread::spawn(move || {
        let batcher = Batcher::new(&qc, BatchPolicy::default());
        batcher.next_batch() // must be None once closed
    });
    std::thread::sleep(Duration::from_millis(20));
    queue.close();
    let got = consumer.join().expect("consumer panicked");
    assert!(got.is_none());
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "consumer failed to unblock on close"
    );
}
