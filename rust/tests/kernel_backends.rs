//! Cross-backend equivalence suite for the runtime-dispatched GEMM
//! micro-kernels.
//!
//! Every SIMD backend the host can run (`KernelBackend::all_available`,
//! always including the portable scalar fallback) must agree with the
//! scalar reference through the full prepacked-GEMM stack — serial,
//! threaded, and batched — over randomized geometries that exercise
//! every edge-tile height (`mr` in `1..=MR`) and both strip widths.
//!
//! Tolerances:
//! * **f32: ≤ 4 ULP.** Every backend walks K in the same order, so the
//!   only divergence is FMA (one rounding) vs mul+add (two). Operands
//!   are drawn non-negative so the reduction stays well-conditioned and
//!   that difference is a few ULP of the result, not of a cancelled
//!   residual.
//! * **i16: bitwise.** The rounded-Q15 product `(a·b + 2¹⁴) >> 15` is
//!   exactly what `mulhrs`/`vqrdmulh` compute for operands ≥ −32767, and
//!   the i32 accumulation is exact — so f32 outputs must be identical
//!   down to the bit, per-column epilogue included.
//!
//! Forcing a backend via `MEC_KERNEL` is process-global (one-time
//! detection), so that path is covered by the CI leg that reruns the
//! whole suite under `MEC_KERNEL=scalar` rather than by an in-process
//! test.

use mec::gemm::micro::MR;
use mec::gemm::{
    gemm_prepacked, gemm_prepacked_batch, gemm_prepacked_batch_i16, gemm_prepacked_ex,
    gemm_prepacked_ex_i16, gemm_prepacked_i16, BlockSizes, KernelBackend, MatMut, MatRef,
    MatRefI16, PackedB, PackedBI16, Q16Epilogue,
};
use mec::threadpool::Parallelism;
use mec::util::Rng;

/// Geometries spanning the interesting structure: every edge-tile height
/// (m % MR over 0..MR), sub-strip and multi-strip n for both nr widths
/// (8 and 16), and K crossing the KC=256 cache-block boundary.
fn geometries(rng: &mut Rng) -> Vec<(usize, usize, usize)> {
    let mut gs: Vec<(usize, usize, usize)> = (1..=MR).map(|m| (m, 17, 19)).collect();
    gs.extend_from_slice(&[
        (13, 1, 1),
        (29, 7, 8),
        (21, 300, 33), // K spans two KC blocks
        (64, 96, 16),
    ]);
    for _ in 0..4 {
        gs.push((rng.range(1, 70), rng.range(1, 130), rng.range(1, 50)));
    }
    gs
}

fn fill_f32(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    // Non-negative: see the module docs on conditioning.
    rng.fill_uniform(&mut v, 0.05, 1.0);
    v
}

fn fill_i16(rng: &mut Rng, len: usize) -> Vec<i16> {
    let mut f = vec![0.0f32; len];
    rng.fill_uniform(&mut f, -1.0, 1.0);
    f.into_iter().map(|x| (x * 32767.0) as i16).collect()
}

/// Distance in representable-float steps (monotone order-preserving map
/// of the IEEE-754 bit patterns; finite inputs only).
fn ulp_diff(a: f32, b: f32) -> u64 {
    fn ordered(x: f32) -> i64 {
        let bits = x.to_bits() as i64;
        if bits & 0x8000_0000 != 0 {
            0x8000_0000 - bits
        } else {
            bits
        }
    }
    (ordered(a) - ordered(b)).unsigned_abs()
}

fn assert_ulp_close(got: &[f32], want: &[f32], max_ulp: u64, tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: length");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.is_finite() && w.is_finite(),
            "{tag}: non-finite at {i}: {g} vs {w}"
        );
        let d = ulp_diff(g, w);
        assert!(d <= max_ulp, "{tag}: elem {i}: {g} vs {w} differ by {d} ULP");
    }
}

/// Scalar-packed serial result — the reference every backend is held to.
fn scalar_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let pb = PackedB::pack_with(MatRef::new(b, k, n), BlockSizes::default(), KernelBackend::Scalar);
    let mut c = vec![0.0f32; m * n];
    gemm_prepacked(MatRef::new(a, m, k), &pb, &mut MatMut::new(&mut c, m, n));
    c
}

fn scalar_i16(a: &[i16], b: &[i16], m: usize, k: usize, n: usize, ep: Q16Epilogue<'_>) -> Vec<f32> {
    let pb =
        PackedBI16::pack_with(MatRefI16::new(b, k, n), BlockSizes::default(), KernelBackend::Scalar);
    let mut c = vec![0.0f32; m * n];
    gemm_prepacked_i16(MatRefI16::new(a, m, k), &pb, &mut MatMut::new(&mut c, m, n), ep);
    c
}

#[test]
fn f32_serial_matches_scalar_within_4_ulp_on_every_backend() {
    let mut rng = Rng::new(0xbac ^ 0x6ec);
    for (m, k, n) in geometries(&mut rng) {
        let a = fill_f32(&mut rng, m * k);
        let b = fill_f32(&mut rng, k * n);
        let want = scalar_f32(&a, &b, m, k, n);
        // Sanity-pin the reference itself to an f64 oracle so a bug
        // shared by every f32 backend cannot self-certify.
        for r in 0..m {
            for c in 0..n {
                let exact: f64 =
                    (0..k).map(|p| a[r * k + p] as f64 * b[p * n + c] as f64).sum();
                let got = want[r * n + c] as f64;
                assert!(
                    (got - exact).abs() <= 1e-4 * exact.abs().max(1.0),
                    "scalar reference off f64 oracle at ({r},{c}): {got} vs {exact}"
                );
            }
        }
        for backend in KernelBackend::all_available() {
            let pb = PackedB::pack_with(MatRef::new(&b, k, n), BlockSizes::default(), backend);
            let mut c = vec![0.0f32; m * n];
            gemm_prepacked(MatRef::new(&a, m, k), &pb, &mut MatMut::new(&mut c, m, n));
            assert_ulp_close(&c, &want, 4, &format!("{backend} serial {m}x{k}x{n}"));
        }
    }
}

#[test]
fn f32_threaded_and_batched_match_scalar_within_4_ulp() {
    let mut rng = Rng::new(0x517);
    let par = Parallelism::new(3);
    for (m, k, n) in geometries(&mut rng) {
        let b = fill_f32(&mut rng, k * n);
        let batch: Vec<Vec<f32>> = (0..3).map(|_| fill_f32(&mut rng, m * k)).collect();
        let want: Vec<Vec<f32>> =
            batch.iter().map(|a| scalar_f32(a, &b, m, k, n)).collect();
        for backend in KernelBackend::all_available() {
            let pb = PackedB::pack_with(MatRef::new(&b, k, n), BlockSizes::default(), backend);
            // Threaded: row panels must partition identically to serial.
            let mut c = vec![0.0f32; m * n];
            gemm_prepacked_ex(
                MatRef::new(&batch[0], m, k),
                &pb,
                &mut MatMut::new(&mut c, m, n),
                &par,
            );
            assert_ulp_close(&c, &want[0], 4, &format!("{backend} threaded {m}x{k}x{n}"));
            // Batched: the batch loop rides inside the tile loops.
            let mut outs = vec![vec![0.0f32; m * n]; 3];
            {
                let avs: Vec<MatRef<'_>> =
                    batch.iter().map(|a| MatRef::new(a, m, k)).collect();
                let mut cvs: Vec<MatMut<'_>> =
                    outs.iter_mut().map(|o| MatMut::new(o, m, n)).collect();
                gemm_prepacked_batch(&avs, &pb, &mut cvs);
            }
            for (i, o) in outs.iter().enumerate() {
                assert_ulp_close(o, &want[i], 4, &format!("{backend} batch[{i}] {m}x{k}x{n}"));
            }
        }
    }
}

#[test]
fn i16_serial_is_bitwise_identical_across_backends() {
    let mut rng = Rng::new(0x161);
    for (m, k, n) in geometries(&mut rng) {
        let a = fill_i16(&mut rng, m * k);
        let b = fill_i16(&mut rng, k * n);
        // Per-column epilogue scales: the per-output-channel kernel
        // scales the conv layer folds in ride this exact path.
        let col_scales: Vec<f32> = (0..n).map(|c| 0.5 + 0.01 * c as f32).collect();
        for ep in [
            Q16Epilogue::uniform(3.7e-4),
            Q16Epilogue { global: 2.1e-4, per_col: Some(&col_scales) },
        ] {
            let want = scalar_i16(&a, &b, m, k, n, ep);
            for backend in KernelBackend::all_available() {
                let pb = PackedBI16::pack_with(
                    MatRefI16::new(&b, k, n),
                    BlockSizes::default(),
                    backend,
                );
                let mut c = vec![0.0f32; m * n];
                gemm_prepacked_i16(
                    MatRefI16::new(&a, m, k),
                    &pb,
                    &mut MatMut::new(&mut c, m, n),
                    ep,
                );
                assert_eq!(c, want, "{backend} i16 serial {m}x{k}x{n} not bitwise");
            }
        }
    }
}

#[test]
fn i16_threaded_and_batched_are_bitwise_identical_across_backends() {
    let mut rng = Rng::new(0x171);
    let par = Parallelism::new(3);
    for (m, k, n) in geometries(&mut rng) {
        let b = fill_i16(&mut rng, k * n);
        let batch: Vec<Vec<i16>> = (0..2).map(|_| fill_i16(&mut rng, m * k)).collect();
        let ep = Q16Epilogue::uniform(2.9e-4);
        let want: Vec<Vec<f32>> =
            batch.iter().map(|a| scalar_i16(a, &b, m, k, n, ep)).collect();
        for backend in KernelBackend::all_available() {
            let pb =
                PackedBI16::pack_with(MatRefI16::new(&b, k, n), BlockSizes::default(), backend);
            let mut c = vec![0.0f32; m * n];
            gemm_prepacked_ex_i16(
                MatRefI16::new(&batch[0], m, k),
                &pb,
                &mut MatMut::new(&mut c, m, n),
                ep,
                &par,
            );
            assert_eq!(c, want[0], "{backend} i16 threaded {m}x{k}x{n} not bitwise");
            let mut outs = vec![vec![0.0f32; m * n]; 2];
            {
                let avs: Vec<MatRefI16<'_>> =
                    batch.iter().map(|a| MatRefI16::new(a, m, k)).collect();
                let mut cvs: Vec<MatMut<'_>> =
                    outs.iter_mut().map(|o| MatMut::new(o, m, n)).collect();
                gemm_prepacked_batch_i16(&avs, &pb, &mut cvs, ep);
            }
            for (i, o) in outs.iter().enumerate() {
                assert_eq!(o, &want[i], "{backend} i16 batch[{i}] {m}x{k}x{n} not bitwise");
            }
        }
    }
}

#[test]
fn conv_plans_carry_the_backend_their_pack_was_built_for() {
    use mec::conv::{AlgoKind, ConvContext, Convolution};
    use mec::tensor::{ConvShape, Kernel, KernelShape, Nhwc, Precision, Tensor};
    let shape = ConvShape::new(
        Nhwc::new(1, 12, 12, 3),
        KernelShape::new(3, 3, 3, 8),
        1,
        1,
    );
    let mut rng = Rng::new(9);
    let input = Tensor::random(shape.input, &mut rng);
    let kernel = Kernel::random(shape.kernel, &mut rng);
    for precision in [Precision::F32, Precision::Q16] {
        let ctx = ConvContext::server().with_precision(precision);
        for kind in [AlgoKind::Mec, AlgoKind::Im2col] {
            let plan = kind.build().plan(&ctx, &shape, &kernel);
            assert_eq!(
                plan.kernel_backend(),
                Some(KernelBackend::active()),
                "{kind:?}/{precision} plan backend"
            );
            // And the plan still computes: smoke-execute through the
            // public path so a backend/pack mismatch would assert.
            let mut arena = mec::memory::Arena::new();
            let mut out = Tensor::zeros(shape.output());
            plan.execute(&input, &mut arena, &mut out);
            assert!(out.data().iter().all(|v| v.is_finite()));
        }
    }
}
