//! Planner + autotuner over the paper workloads: budgets must be
//! honored, the memory-constrained story (paper §1) must hold end to
//! end, and tuned plans must actually be runnable.

use mec::bench::workload::{by_name, suite};
use mec::conv::{AlgoKind, ConvContext, Convolution};
use mec::memory::{Budget, Workspace};
use mec::planner::{AutoTuner, Planner};
use mec::tensor::{Kernel, Tensor};
use mec::util::Rng;

const SCALE: usize = 8;

#[test]
fn plans_fit_budget_across_suite() {
    let planner = Planner::new();
    let ctx = ConvContext::default();
    for w in suite() {
        let shape = w.shape(1, SCALE);
        for budget_bytes in [0usize, 64 << 10, 1 << 20, usize::MAX] {
            let budget = Budget::new(budget_bytes);
            let plan = planner.plan(&shape, &budget, &ctx);
            assert!(
                plan.workspace_bytes <= budget_bytes,
                "{}: plan {} ws {} > budget {}",
                w.name,
                plan.algo.name(),
                plan.workspace_bytes,
                budget_bytes
            );
        }
    }
}

#[test]
fn tightening_budget_degrades_gracefully_to_zero_workspace() {
    // As the budget shrinks, the planner must keep returning *some* valid
    // plan, ending in the zero-workspace tier — the memory-constrained-
    // device story of the paper's introduction. Since the menu grew
    // kn2row and SMM-Conv, "zero bytes" no longer means the direct loop
    // nest: the planner may keep GEMM compute all the way down.
    let planner = Planner::new();
    let ctx = ConvContext::default();
    let shape = by_name("cv6").unwrap().shape(1, SCALE);
    let unlimited = planner.plan(&shape, &Budget::unlimited(), &ctx);
    assert_ne!(unlimited.algo, AlgoKind::Direct);
    let zero = planner.plan(&shape, &Budget::new(0), &ctx);
    assert!(
        matches!(
            zero.algo,
            AlgoKind::Direct | AlgoKind::Kn2row | AlgoKind::SmmConv
        ),
        "{zero:?}"
    );
    assert_eq!(zero.workspace_bytes, 0);
    // MEC must be admissible in budgets where im2col is not (Eq. 4).
    let mec_ws = AlgoKind::Mec.build().workspace_bytes(&shape);
    let i2c_ws = AlgoKind::Im2col.build().workspace_bytes(&shape);
    assert!(mec_ws < i2c_ws);
    let squeezed = planner.plan(&shape, &Budget::new(mec_ws), &ctx);
    assert_ne!(squeezed.algo, AlgoKind::Im2col);
    assert!(squeezed.workspace_bytes <= mec_ws);
}

#[test]
fn tuned_plan_is_runnable_and_respects_budget() {
    let mut tuner = AutoTuner::new();
    let ctx = ConvContext::default();
    let shape = by_name("cv11").unwrap().shape(1, SCALE);
    let budget = Budget::new(AlgoKind::Mec.build().workspace_bytes(&shape));
    let plan = tuner.tune(&shape, &budget, &ctx);
    assert!(plan.workspace_bytes <= budget.limit());
    // Execute the tuned plan.
    let mut rng = Rng::new(1);
    let input = Tensor::random(shape.input, &mut rng);
    let kernel = Kernel::random(shape.kernel, &mut rng);
    let mut out = Tensor::zeros(shape.output());
    let mut ws = Workspace::new();
    plan.algo
        .build()
        .run(&ctx, &shape, &input, &kernel, &mut ws, &mut out);
    assert!(out.data().iter().any(|&v| v != 0.0));
}

#[test]
fn cost_model_prefers_mec_over_im2col_on_every_cv_layer() {
    // The paper's Fig. 4c/4d claim (MEC ≥ Conv.cpu everywhere) should be
    // reflected by the analytic model on all 12 layers.
    let planner = Planner::new();
    for w in suite() {
        let shape = w.shape(1, 1);
        let mec_est = planner.cost.estimate_ns(AlgoKind::Mec, &shape);
        let i2c_est = planner.cost.estimate_ns(AlgoKind::Im2col, &shape);
        assert!(
            mec_est <= i2c_est * 1.05,
            "{}: cost model says MEC {mec_est} vs im2col {i2c_est}",
            w.name
        );
    }
}

#[test]
fn autotune_cache_hit_is_stable() {
    let mut tuner = AutoTuner::new();
    let ctx = ConvContext::default();
    let shape = by_name("cv12").unwrap().shape(1, SCALE);
    let p1 = tuner.tune(&shape, &Budget::unlimited(), &ctx);
    let p2 = tuner.tune(&shape, &Budget::unlimited(), &ctx);
    assert_eq!(p1.algo, p2.algo);
    assert_eq!(tuner.cached_plans(), 1);
    // Different budget = different cache entry.
    let _ = tuner.tune(&shape, &Budget::new(0), &ctx);
    assert_eq!(tuner.cached_plans(), 2);
}
