//! Cross-algorithm correctness on the paper's workload geometries
//! (channel-scaled so the suite runs in seconds): every algorithm must
//! agree with direct convolution, and measured workspace must equal the
//! analytic Eq. (2)/(3) formulas.

use mec::bench::workload::suite;
use mec::conv::{AlgoKind, ConvContext, ConvPlan, Convolution};
use mec::memory::{measure_peak, Workspace};
use mec::tensor::{Kernel, Tensor};
use mec::util::{assert_allclose, Rng};

/// Channel scale for tests: cv layers shrink ~8x in channels.
const SCALE: usize = 8;

#[test]
fn all_algorithms_match_direct_on_cv_suite() {
    let mut rng = Rng::new(0xC0);
    for w in suite() {
        let shape = w.shape(1, SCALE);
        // Crop the 224/227-pixel layers to keep direct-conv oracle time
        // reasonable; kernel/stride geometry (what the algorithms care
        // about) is preserved.
        let shape = if shape.input.h > 64 {
            let cropped = mec::tensor::Nhwc::new(1, 64, 64, shape.input.c);
            if 64 < shape.kernel.kh {
                continue;
            }
            mec::tensor::ConvShape::new(cropped, shape.kernel, shape.sh, shape.sw)
        } else {
            shape
        };
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let ctx = ConvContext::default();
        let mut want = Tensor::zeros(shape.output());
        let mut ws = Workspace::new();
        AlgoKind::Direct
            .build()
            .run(&ctx, &shape, &input, &kernel, &mut ws, &mut want);
        for kind in [
            AlgoKind::Im2col,
            AlgoKind::Mec,
            AlgoKind::MecSolutionA,
            AlgoKind::MecSolutionB,
            AlgoKind::Winograd,
            AlgoKind::Fft,
        ] {
            let algo = kind.build();
            if !algo.supports(&shape) {
                continue;
            }
            let mut got = Tensor::zeros(shape.output());
            algo.run(&ctx, &shape, &input, &kernel, &mut ws, &mut got);
            let tol = if kind == AlgoKind::Fft || kind == AlgoKind::Winograd {
                2e-3
            } else {
                1e-4
            };
            assert_allclose(
                got.data(),
                want.data(),
                tol,
                &format!("{} on {} ({})", algo.name(), w.name, shape.describe()),
            );
        }
    }
}

#[test]
fn measured_workspace_equals_analytic_for_lowering_algorithms() {
    let mut rng = Rng::new(0xC1);
    for w in suite() {
        let shape = w.shape(1, SCALE);
        if shape.input.h > 64 {
            continue; // formulas covered by unit tests; avoid big allocs
        }
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let ctx = ConvContext::default();
        // im2col/MEC have no kernel-side precomputation, so the tracked
        // scratch equals the analytic Eq. (2)/(3) formulas exactly.
        for kind in [AlgoKind::Im2col, AlgoKind::Mec] {
            let algo = kind.build();
            let mut out = Tensor::zeros(shape.output());
            let ((), peak) = measure_peak(|| {
                let mut ws = Workspace::new();
                algo.run(&ctx, &shape, &input, &kernel, &mut ws, &mut out);
            });
            assert_eq!(
                peak,
                algo.workspace_bytes(&shape),
                "{} on {}: measured {} != analytic {}",
                algo.name(),
                w.name,
                peak,
                algo.workspace_bytes(&shape)
            );
        }
        // Winograd's transformed filters U are plan-resident (untracked
        // model memory), so tracked scratch + resident must cover the
        // analytic U+V+M total instead.
        let wino = AlgoKind::Winograd.build();
        if wino.supports(&shape) {
            let plan = wino.plan(&ctx, &shape, &kernel);
            let mut out = Tensor::zeros(shape.output());
            let ((), peak) = measure_peak(|| {
                let mut arena = mec::memory::Arena::new();
                plan.execute(&input, &mut arena, &mut out);
            });
            assert_eq!(
                peak + plan.resident_bytes(),
                wino.workspace_bytes(&shape),
                "winograd on {}: scratch {} + resident {} != analytic {}",
                w.name,
                peak,
                plan.resident_bytes(),
                wino.workspace_bytes(&shape)
            );
        }
    }
}

#[test]
fn mec_memory_win_matches_eq4_sign_across_suite() {
    // Every cv layer has k_h > s_h, so MEC must win memory on all of them
    // (paper Fig. 4b: always-less-than-Conv).
    for w in suite() {
        let shape = w.shape(1, 1);
        assert!(
            shape.mec_wins_memory(),
            "{}: k={} s={} should overlap",
            w.name,
            w.kh,
            w.s
        );
        assert!(shape.mec_lowered_elems() < shape.im2col_lowered_elems());
    }
}

#[test]
fn batch_dimension_consistency() {
    // Batched runs must equal per-sample runs stacked (both solutions).
    let binding = suite();
    let w = &binding[5]; // cv6
    let shape_b = w.shape(3, SCALE);
    let mut rng = Rng::new(0xC2);
    let input = Tensor::random(shape_b.input, &mut rng);
    let kernel = Kernel::random(shape_b.kernel, &mut rng);
    let ctx = ConvContext::default();
    let mut ws = Workspace::new();

    for kind in [AlgoKind::MecSolutionA, AlgoKind::MecSolutionB, AlgoKind::Im2col] {
        let algo = kind.build();
        let mut batched = Tensor::zeros(shape_b.output());
        algo.run(&ctx, &shape_b, &input, &kernel, &mut ws, &mut batched);
        // Per-sample.
        let shape_1 = w.shape(1, SCALE);
        for n in 0..3 {
            let single = Tensor::from_vec(shape_1.input, input.sample(n).to_vec());
            let mut out1 = Tensor::zeros(shape_1.output());
            algo.run(&ctx, &shape_1, &single, &kernel, &mut ws, &mut out1);
            assert_allclose(
                batched.sample(n),
                out1.data(),
                1e-5,
                &format!("{} sample {n}", algo.name()),
            );
        }
    }
}

#[test]
fn threads_do_not_change_results() {
    let binding = suite();
    let w = &binding[4]; // cv5
    let shape = w.shape(2, SCALE);
    let mut rng = Rng::new(0xC3);
    let input = Tensor::random(shape.input, &mut rng);
    let kernel = Kernel::random(shape.kernel, &mut rng);
    let mut ws = Workspace::new();
    for kind in [AlgoKind::Mec, AlgoKind::Im2col, AlgoKind::Winograd] {
        let algo = kind.build();
        if !algo.supports(&shape) {
            continue;
        }
        let mut o1 = Tensor::zeros(shape.output());
        let mut o4 = Tensor::zeros(shape.output());
        algo.run(
            &ConvContext::default(),
            &shape,
            &input,
            &kernel,
            &mut ws,
            &mut o1,
        );
        algo.run(
            &ConvContext::default().with_threads(4),
            &shape,
            &input,
            &kernel,
            &mut ws,
            &mut o4,
        );
        assert_eq!(o1.data(), o4.data(), "{} thread-count variance", algo.name());
    }
}
