//! End-to-end over the build artifacts: load the JAX-trained `.mecw`
//! model, run the held-out eval set through the native engine, and check
//! the accuracy the python trainer reported. Skips (with a message) when
//! `make artifacts` has not run.

use mec::conv::{AlgoKind, ConvContext};
use mec::memory::{Arena, Budget};
use mec::model::{load_mecw, EvalSet};
use mec::planner::Planner;
use mec::tensor::{Nhwc, Tensor};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = mec::runtime::artifacts::default_dir();
    if dir.join("model.mecw").exists() && dir.join("eval.bin").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing — run `make artifacts` first");
        None
    }
}

#[test]
fn trained_model_loads_and_validates() {
    let Some(dir) = artifacts_dir() else { return };
    let model = load_mecw(dir.join("model.mecw")).expect("load model.mecw");
    assert_eq!(model.input_hwc, (28, 28, 1));
    let out = model.validate();
    assert_eq!(out.c, 3);
    assert!(model.param_count() > 1000);
}

#[test]
fn eval_accuracy_matches_training_report() {
    let Some(dir) = artifacts_dir() else { return };
    let mut model = load_mecw(dir.join("model.mecw")).unwrap();
    let eval = EvalSet::load(dir.join("eval.bin")).unwrap();
    assert!(eval.len() >= 100);
    model.plan(
        &Planner::new(),
        &Budget::unlimited(),
        &ConvContext::default(),
        32,
    );
    let ctx = ConvContext::default();
    let mut arena = model.sized_arena();
    let mut correct = 0;
    for chunk in eval
        .samples
        .chunks(32)
        .zip(eval.labels.chunks(32))
        .map(|(s, l)| (s, l))
    {
        let (samples, labels) = chunk;
        let n = samples.len();
        let mut data = Vec::with_capacity(n * eval.h * eval.w * eval.c);
        for s in samples {
            data.extend_from_slice(s);
        }
        let batch = Tensor::from_vec(Nhwc::new(n, eval.h, eval.w, eval.c), data);
        let preds = model.predict(&ctx, &batch, &mut arena);
        correct += preds
            .iter()
            .zip(labels)
            .filter(|(p, l)| *p == *l)
            .count();
    }
    let acc = correct as f64 / eval.len() as f64;
    // Python reported ~0.97; the engine must reproduce it (same weights,
    // same math). Loose lower bound guards against layout bugs.
    assert!(acc > 0.9, "eval accuracy {acc} too low — layout/format bug?");
}

#[test]
fn all_conv_algorithms_give_same_predictions() {
    let Some(dir) = artifacts_dir() else { return };
    let mut model = load_mecw(dir.join("model.mecw")).unwrap();
    let eval = EvalSet::load(dir.join("eval.bin")).unwrap();
    let n = 16.min(eval.len());
    let mut data = Vec::new();
    for s in &eval.samples[..n] {
        data.extend_from_slice(s);
    }
    let batch = Tensor::from_vec(Nhwc::new(n, eval.h, eval.w, eval.c), data);
    let ctx = ConvContext::default();
    let mut arena = Arena::new();
    let mut all: Vec<Vec<usize>> = Vec::new();
    for algo in [
        AlgoKind::Direct,
        AlgoKind::Im2col,
        AlgoKind::Mec,
        AlgoKind::MecSolutionA,
        AlgoKind::MecSolutionB,
        AlgoKind::Winograd,
    ] {
        model.pin_algo(algo);
        all.push(model.predict(&ctx, &batch, &mut arena));
    }
    for (i, preds) in all.iter().enumerate().skip(1) {
        assert_eq!(preds, &all[0], "algorithm #{i} disagrees on predictions");
    }
}

#[test]
fn serving_under_memory_budget_still_accurate() {
    // Plan with a budget that excludes im2col on the big conv layer —
    // the paper's mobile deployment — and confirm accuracy is unchanged.
    let Some(dir) = artifacts_dir() else { return };
    let mut model = load_mecw(dir.join("model.mecw")).unwrap();
    let eval = EvalSet::load(dir.join("eval.bin")).unwrap();
    model.plan(
        &Planner::new(),
        &Budget::new(512 << 10), // 512 KB workspace cap
        &ConvContext::default(),
        8,
    );
    let ctx = ConvContext::default();
    let mut arena = model.sized_arena();
    let n = 64.min(eval.len());
    let mut data = Vec::new();
    for s in &eval.samples[..n] {
        data.extend_from_slice(s);
    }
    let batch = Tensor::from_vec(Nhwc::new(n, eval.h, eval.w, eval.c), data);
    let preds = model.predict(&ctx, &batch, &mut arena);
    let acc = preds
        .iter()
        .zip(&eval.labels[..n])
        .filter(|(p, l)| *p == *l)
        .count() as f64
        / n as f64;
    assert!(acc > 0.85, "budgeted accuracy {acc}");
}
