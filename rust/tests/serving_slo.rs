//! SLO-serving integration contract: the acceptance criteria of the
//! serving subsystem.
//!
//! * shedding is **typed** — a hopeless deadline and a full queue each
//!   produce their own [`ShedReason`], never a panic or a silent drop;
//! * under nominal load with a lax SLO, **everything** is served on
//!   time (attainment 1.0, zero shed);
//! * the steady-state serving path performs **zero tracked allocation**
//!   and spawns **zero OS threads** — the engine's pre-sized arenas,
//!   plan memos, and persistent pool absorb the whole hot path;
//! * `BENCH_serving.json` carries real measurements: when the committed
//!   seed still says `"status":"pending"`, a smoke sweep regenerates it
//!   here so the trajectory file never ships fabricated numbers.
//!
//! Tracker-sensitive work runs inside `measure_peak`, which serializes
//! on the tracker's global lock, so parallel test threads don't
//! interfere. Every engine-building test in this binary takes the lock
//! for that reason — tracked allocation anywhere in the process would
//! perturb the zero-alloc assertion.

use mec::conv::AlgoKind;
use mec::coordinator::{Server, ServerConfig, SubmitError};
use mec::engine::Engine;
use mec::memory::{self, measure_peak};
use mec::model::{Layer, Model};
use mec::serving::{loadgen, LoadConfig, LoadMode, ShedReason};
use mec::tensor::{Kernel, KernelShape};
use mec::util::Rng;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Run `f` holding the tracker's global lock (via `measure_peak`), so
/// tests in this binary never see each other's tracked allocations. Do
/// NOT nest — the lock is not reentrant.
fn with_tracker_lock<T>(f: impl FnOnce() -> T) -> T {
    measure_peak(f).0
}

fn tiny_model() -> Model {
    let mut rng = Rng::new(0x510);
    Model::new(
        "slo-test",
        (6, 6, 1),
        vec![
            Layer::Conv {
                kernel: Kernel::random(KernelShape::new(3, 3, 1, 2), &mut rng),
                bias: vec![0.0; 2],
                sh: 1,
                sw: 1,
                ph: 1,
                pw: 1,
            },
            Layer::Relu,
        ],
    )
}

fn tiny_engine() -> Arc<Engine> {
    Arc::new(
        Engine::builder(tiny_model())
            .algo_override(0, AlgoKind::Mec)
            .pin_batch_sizes(&[1, 2, 4, 8])
            .threads(2)
            .build()
            .expect("tiny model builds"),
    )
}

#[test]
fn hopeless_deadline_sheds_typed_feasible_deadline_serves() {
    with_tracker_lock(|| {
        let server =
            Server::start(tiny_engine(), ServerConfig::default()).expect("server starts");
        let client = server.client();
        // A deadline already in the past can never be met — admission
        // refuses it with the typed reason, before it burns queue space.
        let err = client
            .submit_with_deadline(vec![0.2; 36], Some(Instant::now()))
            .unwrap_err();
        match err {
            SubmitError::Shed(ShedReason::DeadlineInfeasible { needed_ns, budget_ns }) => {
                assert!(
                    needed_ns > budget_ns,
                    "shed payload must explain itself: need {needed_ns} > budget {budget_ns}"
                );
            }
            other => panic!("expected DeadlineInfeasible, got {other:?}"),
        }
        // The same sample with a generous deadline serves fine.
        let rx = client
            .submit_with_deadline(vec![0.2; 36], Some(Instant::now() + Duration::from_secs(30)))
            .expect("feasible deadline admits");
        assert!(rx.recv().expect("answered").result.is_ok());
        let metrics = server.shutdown();
        assert_eq!(metrics.shed_deadline.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 1);
        // Conservation: requests = responses + rejected.
        assert_eq!(metrics.requests.load(Ordering::Relaxed), 2);
    });
}

#[test]
fn full_queue_sheds_typed_with_capacity_in_payload() {
    with_tracker_lock(|| {
        let server = Server::start(
            tiny_engine(),
            ServerConfig {
                workers: 1,
                queue_depth: 2,
                // Slow consumption: a long collect window.
                max_wait: Duration::from_millis(30),
                ..ServerConfig::default()
            },
        )
        .expect("server starts");
        let client = server.client();
        let mut shed = 0u64;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            match client.submit(vec![0.1; 36]) {
                Ok(rx) => rxs.push(rx),
                Err(SubmitError::Shed(ShedReason::QueueFull { depth, capacity })) => {
                    assert_eq!(capacity, 2, "payload carries the configured capacity");
                    assert!(depth >= capacity, "shed at depth {depth} below cap {capacity}");
                    shed += 1;
                }
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        for rx in rxs {
            assert!(rx.recv().expect("accepted request answered").result.is_ok());
        }
        let metrics = server.shutdown();
        assert!(shed > 0, "a depth-2 queue under a 64-burst must shed");
        assert_eq!(metrics.shed_queue_full.load(Ordering::Relaxed), shed);
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), shed);
    });
}

#[test]
fn nominal_load_meets_a_lax_slo_with_zero_shed() {
    with_tracker_lock(|| {
        let server = Server::start(
            tiny_engine(),
            ServerConfig {
                workers: 2,
                queue_depth: 256,
                max_wait: Duration::from_millis(1),
                ..ServerConfig::default()
            },
        )
        .expect("server starts");
        let report = loadgen::run(
            &server,
            &[0.3; 36],
            &LoadConfig {
                mode: LoadMode::Closed { clients: 4 },
                requests: 80,
                slo: Some(Duration::from_secs(2)),
            },
        );
        server.shutdown();
        // Closed-loop offered load self-regulates to capacity: with a
        // 2 s deadline on a microsecond model, everything serves on
        // time and nothing sheds.
        assert_eq!(report.submitted, 80);
        assert_eq!(report.served, 80, "nominal load must fully serve: {report:?}");
        assert_eq!(report.shed, 0);
        assert_eq!(report.errors, 0);
        assert!(
            (report.slo_attainment - 1.0).abs() < 1e-9,
            "attainment {} under a 2s SLO",
            report.slo_attainment
        );
        assert!(report.p50_ms > 0.0 && report.p50_ms <= report.p99_ms);
        assert!(report.p99_ms <= 2_000.0, "p99 {} ms blew the SLO", report.p99_ms);
        assert!(report.throughput_rps > 0.0);
    });
}

#[test]
fn steady_state_serving_allocates_nothing_and_spawns_nothing() {
    with_tracker_lock(|| {
        let engine = tiny_engine();
        let server = Server::start(
            Arc::clone(&engine),
            ServerConfig::default(),
        )
        .expect("server starts");
        let client = server.client();
        // Warm-up: grows the worker session's arena to its pre-sized
        // bound and fills the plan memo for the batch-1 path.
        for _ in 0..10 {
            assert!(client.infer(vec![0.4; 36]).unwrap().result.is_ok());
        }
        // Steady state: the serving hot path (queue → batcher → session
        // forward → histogram record → reply) must not touch the
        // tracker or the pool. Each `infer` blocks until the reply, so
        // the worker is quiescent at every gauge read.
        let bytes_before = memory::current_bytes();
        let spawned_before = engine.pool_threads_spawned();
        for rep in 0..30 {
            assert!(client.infer(vec![0.4; 36]).unwrap().result.is_ok());
            assert_eq!(
                memory::current_bytes(),
                bytes_before,
                "rep {rep}: tracked allocation in serving steady state"
            );
            assert_eq!(
                engine.pool_threads_spawned(),
                spawned_before,
                "rep {rep}: steady-state serving spawned an OS thread"
            );
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 40);
    });
}

/// Regenerate `BENCH_serving.json` with real measurements when the
/// committed seed still says `"status":"pending"` (or the file is
/// missing). The full sweep lives in `cargo bench --bench serving`;
/// this smoke version keeps the trajectory file honest on any machine
/// that only runs the test suite. Never overwrites real measurements.
#[test]
fn bench_serving_seed_carries_real_measurements() {
    let path = std::path::Path::new("BENCH_serving.json");
    let existing = std::fs::read_to_string(path).unwrap_or_default();
    if !existing.is_empty() && !existing.contains("\"status\":\"pending\"") {
        assert!(
            existing.starts_with("{\"bench\":\"serving\""),
            "BENCH_serving.json exists but is not the serving schema"
        );
        return;
    }
    let reports = with_tracker_lock(|| {
        let engine = tiny_engine();
        let slo = Some(Duration::from_millis(250));
        let mut reports = Vec::new();
        for cfg in [
            LoadConfig { mode: LoadMode::Closed { clients: 1 }, requests: 40, slo },
            LoadConfig { mode: LoadMode::Closed { clients: 2 }, requests: 40, slo },
            LoadConfig { mode: LoadMode::Open { rps: 200.0 }, requests: 40, slo },
            LoadConfig { mode: LoadMode::Open { rps: 400.0 }, requests: 40, slo },
        ] {
            let server = Server::start(
                Arc::clone(&engine),
                ServerConfig {
                    workers: 2,
                    queue_depth: 256,
                    max_wait: Duration::from_millis(1),
                    ..ServerConfig::default()
                },
            )
            .expect("server starts");
            reports.push(loadgen::run(&server, &[0.25; 36], &cfg));
            server.shutdown();
        }
        // Degraded-mode point: the same smoke load against an engine
        // forced down the degradation ladder (every conv layer on the
        // zero-workspace family), so the trajectory records what the
        // fallback costs with real measurements.
        let degraded_engine = tiny_engine();
        degraded_engine.degrade();
        let server = Server::start(
            Arc::clone(&degraded_engine),
            ServerConfig {
                workers: 2,
                queue_depth: 256,
                max_wait: Duration::from_millis(1),
                ..ServerConfig::default()
            },
        )
        .expect("server starts");
        let mut degraded = loadgen::run(
            &server,
            &[0.25; 36],
            &LoadConfig { mode: LoadMode::Closed { clients: 2 }, requests: 40, slo },
        );
        server.shutdown();
        degraded.label = format!("degraded-{}", degraded.label);
        reports.push(degraded);
        reports
    });
    let json = loadgen::render_json(250.0, 2, &[1, 2, 4, 8], &reports);
    std::fs::write(path, &json).expect("write BENCH_serving.json");
    let written = std::fs::read_to_string(path).expect("read back");
    assert!(written.starts_with("{\"bench\":\"serving\""));
    assert!(!written.contains("\"status\":\"pending\""));
    assert_eq!(written.matches("\"label\":").count(), 5);
    assert!(written.contains("\"label\":\"degraded-closed-2\""));
}
