//! `.mecw` wire-format compatibility tests:
//!
//! * a **v1 sequential fixture** (checked into `rust/tests/fixtures/`,
//!   written by the historical format) loads, executes, and — because
//!   sequential graphs still save as v1 — round-trips **byte-identically**;
//! * a branching graph saves as **v2** (edges on the wire) and
//!   round-trips with its topology, weights, and numerics intact.

use mec::conv::{AlgoKind, ConvContext};
use mec::memory::Arena;
use mec::model::{load_mecw, save_mecw, GraphBuilder, Model, Src};
use mec::tensor::{Kernel, KernelShape, Nhwc, Tensor};
use mec::util::Rng;
use std::path::PathBuf;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/v1_sequential.mecw")
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("mecw_v2_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn v1_fixture_loads_and_roundtrips_byte_identically() {
    let fixture = std::fs::read(fixture_path()).expect("fixture checked in");
    assert_eq!(&fixture[..8], b"MECW0001");
    let model = load_mecw(fixture_path()).expect("v1 file loads via the compatibility path");
    assert_eq!(model.name, "v1fix");
    assert_eq!(model.input_hwc, (4, 4, 1));
    assert_eq!(model.node_count(), 5, "conv, relu, flatten, dense, softmax");
    assert_eq!(model.param_count(), 8 + 2 + 36 + 2);
    // It executes: conv(2×2, 2ch) → relu → flatten(18) → dense(2) → softmax.
    let input = Tensor::from_fn(Nhwc::new(1, 4, 4, 1), |_, h, w, _| (h * 4 + w) as f32 * 0.1);
    let out = model.forward(&ConvContext::default(), &input, &mut Arena::new());
    assert_eq!(out.shape(), Nhwc::new(1, 1, 1, 2));
    let sum: f32 = out.data().iter().sum();
    assert!((sum - 1.0).abs() < 1e-5, "softmax row sums to {sum}");
    // Sequential models keep writing v1 — byte-identical with the old
    // writer's output.
    let path = tmp("v1_roundtrip.mecw");
    save_mecw(&model, &path).unwrap();
    let rewritten = std::fs::read(&path).unwrap();
    assert_eq!(rewritten, fixture, "v1 round trip must be byte-identical");
}

fn branching_model(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new("branchy", (6, 6, 2));
    let x = b.input();
    let trunk = b.conv(
        x,
        Kernel::random(KernelShape::new(3, 3, 2, 4), &mut rng),
        vec![0.1; 4],
        1,
        1,
        1,
        1,
    );
    let trunk = b.relu(trunk);
    let left = b.conv(
        trunk,
        Kernel::random(KernelShape::new(3, 3, 4, 4), &mut rng),
        vec![0.0; 4],
        1,
        1,
        1,
        1,
    );
    let right = b.max_pool(trunk, 1, 1); // identity-shaped pool branch
    let merged = b.add(&[left, right]);
    let cat = b.concat(&[merged, trunk]);
    let out = b.relu(cat);
    Model::from_graph(b.finish(out))
}

#[test]
fn branching_graph_roundtrips_through_v2() {
    let m = branching_model(0xb2a);
    let path = tmp("branchy.mecw");
    save_mecw(&m, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(&bytes[..8], b"MECW0002", "branching graphs use the v2 wire");

    let loaded = load_mecw(&path).expect("v2 file loads");
    assert_eq!(loaded.name, m.name);
    assert_eq!(loaded.input_hwc, m.input_hwc);
    // Topology preserved exactly: ops, edges, and the output value.
    assert_eq!(loaded.graph(), m.graph());
    assert_eq!(loaded.graph().output(), m.graph().output());
    assert!(matches!(loaded.graph().node(4).srcs[0], Src::Node(2)));

    // Numerics preserved: same weights ⇒ bitwise-identical forwards.
    let mut rng = Rng::new(3);
    let input = Tensor::random(Nhwc::new(2, 6, 6, 2), &mut rng);
    let ctx = ConvContext::default();
    let mut a_model = m;
    let mut b_model = loaded;
    a_model.pin_algo(AlgoKind::Mec);
    b_model.pin_algo(AlgoKind::Mec);
    let mut arena = Arena::new();
    let a = a_model.forward(&ctx, &input, &mut arena);
    let b = b_model.forward(&ctx, &input, &mut arena);
    assert_eq!(a.data(), b.data(), "v2 round trip changed the numerics");
}

#[test]
fn v2_shape_inconsistent_graph_errors_instead_of_aborting() {
    // An Add whose sources have different channel counts is trivially
    // encodable on the v2 wire; loading must return a typed error — a
    // serving binary must never abort on a corrupt model file.
    let m = branching_model(0xbad);
    let path = tmp("bad_geometry.mecw");
    save_mecw(&m, &path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Node 4 is add([Node(2), Node(3)]); rewire its second source to the
    // graph input (6×6×2), which cannot match the 6×6×4 left branch.
    // The add record is `tag=6, n_srcs=2, src0, src1`; find it by its
    // unique prefix and patch src1 to SRC_INPUT.
    let needle: Vec<u8> = [6u32, 2, 2, 3]
        .iter()
        .flat_map(|v| v.to_le_bytes())
        .collect();
    let pos = bytes
        .windows(needle.len())
        .position(|w| w == needle)
        .expect("add record on the wire");
    bytes[pos + 12..pos + 16].copy_from_slice(&u32::MAX.to_le_bytes());
    let bad = tmp("bad_geometry_patched.mecw");
    std::fs::write(&bad, &bytes).unwrap();
    match load_mecw(&bad) {
        Err(mec::model::LoadError::Malformed(msg)) => {
            assert!(msg.contains("add"), "unexpected message: {msg}")
        }
        Err(other) => panic!("expected Malformed, got {other:?}"),
        Ok(_) => panic!("shape-inconsistent file loaded successfully"),
    }
}

#[test]
fn v2_rejects_malformed_edges() {
    // A v2 file whose node references a later node must error cleanly.
    let path = tmp("bad_edge.mecw");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"MECW0002");
    bytes.extend_from_slice(&2u32.to_le_bytes()); // name len
    bytes.extend_from_slice(b"xx");
    for v in [4u32, 4, 1, 1] {
        bytes.extend_from_slice(&v.to_le_bytes()); // h, w, c, node count
    }
    bytes.extend_from_slice(&1u32.to_le_bytes()); // tag: relu
    bytes.extend_from_slice(&1u32.to_le_bytes()); // 1 src
    bytes.extend_from_slice(&7u32.to_le_bytes()); // forward reference!
    bytes.extend_from_slice(&0u32.to_le_bytes()); // output
    std::fs::write(&path, &bytes).unwrap();
    assert!(load_mecw(&path).is_err(), "forward edge must be rejected");
}
