//! Property-based tests (our `util::prop` harness) over randomly
//! generated convolution geometries — the invariants the paper proves:
//!
//! * MEC ≡ im2col ≡ direct numerically (no approximation, §2.2/§3.2).
//! * Eq. (4): MEC's lowered matrix is smaller iff `k_h > s_h` (given
//!   `i_h > k_h`), equal/bigger otherwise.
//! * Solution A ≡ Solution B for every geometry where A is available.
//! * The lowering is a *projection*: every element of L appears in I.

use mec::conv::{AlgoKind, ConvContext, Convolution};
use mec::memory::Workspace;
use mec::tensor::{ConvShape, Kernel, KernelShape, Nhwc, Tensor};
use mec::util::prop::{check_with, shrink_usizes, Config};
use mec::util::{diff, Rng};

/// Random geometry: [n, ih, iw, ic, kh, kw, kc, sh, sw] with kh<=ih etc.
fn gen_geometry(r: &mut Rng) -> Vec<usize> {
    let ih = r.range(3, 14);
    let iw = r.range(3, 14);
    vec![
        r.range(1, 4),            // n
        ih,
        iw,
        r.range(1, 5),            // ic
        r.range(1, ih.min(5) + 1), // kh
        r.range(1, iw.min(5) + 1), // kw
        r.range(1, 6),            // kc
        r.range(1, 4),            // sh
        r.range(1, 4),            // sw
    ]
}

/// Build a shape, or None if the (possibly shrunken) vector is invalid
/// (e.g. kernel larger than input after shrinking) — such candidates are
/// treated as vacuously passing.
fn try_shape(g: &[usize]) -> Option<ConvShape> {
    if g[4] > g[1] || g[5] > g[2] || g.iter().any(|&v| v == 0) {
        return None;
    }
    Some(ConvShape::new(
        Nhwc::new(g[0], g[1], g[2], g[3]),
        KernelShape::new(g[4], g[5], g[3], g[6]),
        g[7],
        g[8],
    ))
}

fn run_algo(kind: AlgoKind, shape: &ConvShape, input: &Tensor, kernel: &Kernel) -> Tensor {
    let mut ws = Workspace::new();
    let mut out = Tensor::zeros(shape.output());
    kind.build()
        .run(&ConvContext::default(), shape, input, kernel, &mut ws, &mut out);
    out
}

#[test]
fn prop_mec_equals_direct_and_im2col() {
    let cfg = Config { cases: 48, ..Config::default() };
    check_with(
        &cfg,
        gen_geometry,
        |g| {
            let Some(shape) = try_shape(g) else { return Ok(()) };
            let mut rng = Rng::new(g.iter().sum::<usize>() as u64);
            let input = Tensor::random(shape.input, &mut rng);
            let kernel = Kernel::random(shape.kernel, &mut rng);
            let want = run_algo(AlgoKind::Direct, &shape, &input, &kernel);
            for kind in [AlgoKind::Mec, AlgoKind::Im2col] {
                let got = run_algo(kind, &shape, &input, &kernel);
                let d = diff(got.data(), want.data());
                if d.rel_l2 > 1e-4 {
                    return Err(format!(
                        "{} differs from direct by rel_l2={:.2e} on {}",
                        kind.name(),
                        d.rel_l2,
                        shape.describe()
                    ));
                }
            }
            Ok(())
        },
        |g| shrink_usizes(g, &[1, 1, 1, 1, 1, 1, 1, 1, 1]),
    );
}

#[test]
fn prop_eq4_memory_sign() {
    let cfg = Config { cases: 128, ..Config::default() };
    check_with(
        &cfg,
        gen_geometry,
        |g| {
            let Some(shape) = try_shape(g) else { return Ok(()) };
            let (kh, sh, ih) = (shape.kernel.kh, shape.sh, shape.input.h);
            let r = shape.im2col_lowered_elems() as i128 - shape.mec_lowered_elems() as i128;
            // Paper §3.4: R = i_n·o_w·k_w·i_c·(i_h − k_h)(k_h/s_h − 1)
            // => R > 0 iff k_h > s_h and i_h > k_h.
            //
            // REPRODUCTION FINDING (recorded in EXPERIMENTS.md): the
            // derivation substitutes o_h·k_h − i_h = (i_h−k_h)(k_h/s_h − 1)
            // which assumes s_h | (i_h − k_h). With floor division there
            // can be dangling input rows that no kernel instance touches;
            // MEC's L still copies them (it copies all i_h rows) while
            // im2col does not, so the claim needs the divisibility
            // hypothesis. This property asserts the corrected statement.
            let exact = (ih - kh) % sh == 0;
            if kh > sh && ih > kh && exact && r <= 0 {
                return Err(format!("expected MEC win, got R={r} on {}", shape.describe()));
            }
            if kh <= sh && r > 0 {
                return Err(format!("expected no win (k<=s), got R={r} on {}", shape.describe()));
            }
            Ok(())
        },
        |g| shrink_usizes(g, &[1, 1, 1, 1, 1, 1, 1, 1, 1]),
    );
}

#[test]
fn prop_solution_a_equals_solution_b() {
    let cfg = Config { cases: 32, ..Config::default() };
    check_with(
        &cfg,
        gen_geometry,
        |g| {
            let Some(shape) = try_shape(g) else { return Ok(()) };
            let mut rng = Rng::new(0xAB ^ g.iter().sum::<usize>() as u64);
            let input = Tensor::random(shape.input, &mut rng);
            let kernel = Kernel::random(shape.kernel, &mut rng);
            let a = run_algo(AlgoKind::MecSolutionA, &shape, &input, &kernel);
            let b = run_algo(AlgoKind::MecSolutionB, &shape, &input, &kernel);
            let d = diff(a.data(), b.data());
            if d.rel_l2 > 1e-5 {
                return Err(format!("A vs B rel_l2={:.2e} on {}", d.rel_l2, shape.describe()));
            }
            Ok(())
        },
        |g| shrink_usizes(g, &[1, 1, 1, 1, 1, 1, 1, 1, 1]),
    );
}

#[test]
fn prop_lowering_is_projection_of_input() {
    // Every element of L equals the input element the paper's Algorithm 2
    // line 5 says it copies.
    let cfg = Config { cases: 32, ..Config::default() };
    check_with(
        &cfg,
        gen_geometry,
        |g| {
            let Some(shape) = try_shape(g) else { return Ok(()) };
            let mut rng = Rng::new(0xE4 ^ g.iter().sum::<usize>() as u64);
            let input = Tensor::random(shape.input, &mut rng);
            let mut l = vec![0.0f32; shape.mec_lowered_elems()];
            mec::conv::mec::Mec::lower(&ConvContext::default(), &shape, &input, &mut l);
            let (ow, k, ish) = (shape.ow(), shape.kernel, shape.input);
            for n in 0..ish.n {
                for w in 0..ow {
                    for h in 0..ish.h {
                        for kw in 0..k.kw {
                            for c in 0..k.ic {
                                let li = ((((n * ow + w) * ish.h) + h) * k.kw + kw) * k.ic + c;
                                let want = input.at(n, h, shape.sw * w + kw, c);
                                if l[li] != want {
                                    return Err(format!(
                                        "L[{n},{w},{h},{kw},{c}] = {} != I = {want} on {}",
                                        l[li],
                                        shape.describe()
                                    ));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        },
        |g| shrink_usizes(g, &[1, 1, 1, 1, 1, 1, 1, 1, 1]),
    );
}

#[test]
fn prop_workspace_formula_exact_under_measurement() {
    let cfg = Config { cases: 24, ..Config::default() };
    check_with(
        &cfg,
        gen_geometry,
        |g| {
            let Some(shape) = try_shape(g) else { return Ok(()) };
            let mut rng = Rng::new(0x77 ^ g.iter().sum::<usize>() as u64);
            let input = Tensor::random(shape.input, &mut rng);
            let kernel = Kernel::random(shape.kernel, &mut rng);
            for kind in [AlgoKind::Mec, AlgoKind::Im2col] {
                let algo = kind.build();
                let mut out = Tensor::zeros(shape.output());
                let ((), peak) = mec::memory::measure_peak(|| {
                    let mut ws = Workspace::new();
                    algo.run(&ConvContext::default(), &shape, &input, &kernel, &mut ws, &mut out);
                });
                if peak != algo.workspace_bytes(&shape) {
                    return Err(format!(
                        "{}: measured {peak} != analytic {} on {}",
                        kind.name(),
                        algo.workspace_bytes(&shape),
                        shape.describe()
                    ));
                }
            }
            Ok(())
        },
        |g| shrink_usizes(g, &[1, 1, 1, 1, 1, 1, 1, 1, 1]),
    );
}
