//! Cross-algorithm differential fuzz oracle.
//!
//! Every concrete [`AlgoKind`] — the paper's five systems, MEC's pinned
//! A/B variants, and the related-work menu (indirect, kn2row, SMM) — is
//! driven over ~200 seeded random geometries and compared against ONE
//! reference: a locally written direct convolution that accumulates in
//! f64. Each algorithm runs in f32 serial, f32 threaded, and (where it
//! has a fixed-point path) q16 serial + threaded, so a single failing
//! geometry pins down *which* lowering diverges, not just that two of
//! them disagree.
//!
//! # Tolerance table (THE single source — do not scatter bounds)
//!
//! f32 comparisons assert `rel_l2(got, ref₆₄) ≤ rtol` (`util::diff`'s
//! reference-normalized L2, the same metric `conv_correctness.rs` has
//! always used, so these numbers carry its precedent). Per algorithm:
//!
//! | algorithm                               | rtol  | why                                    |
//! |-----------------------------------------|-------|----------------------------------------|
//! | direct, smm                             | 1e-4  | plain f32 accumulation; smm is
//! |                                         |       | additionally asserted **bitwise** equal
//! |                                         |       | to direct (same term order by design)  |
//! | im2col, mec, mec-a, mec-b, indirect,    | 1e-4  | blocked-GEMM reassociation only        |
//! | kn2row                                  |       |                                        |
//! | winograd, winograd-chunked              | 2e-3  | 4×4 tile transform conditioning        |
//! | fft                                     | 2e-3  | padded spectral round-trip — error
//! |                                         |       | scales with image area, which rel_l2's
//! |                                         |       | normalization absorbs                  |
//!
//! q16 comparisons reuse the analytic max-abs quantization bound derived
//! in `q16_properties.rs` (operand rounding + Q15 product shift + 1.5×
//! accumulation headroom).
//!
//! # Reproducing a failure
//!
//! Each case derives its RNG from `base_seed ⊕ splitmix(case)`, so one
//! index replays standalone. Failures print a ready-to-paste line:
//!
//! ```text
//! replay: MEC_FUZZ_SEED=0x... MEC_FUZZ_CASE=N cargo test --test algo_differential
//! ```
//!
//! Knobs: `MEC_FUZZ_SEED` (u64, `0x` hex accepted), `MEC_FUZZ_CASES`
//! (default 200), `MEC_FUZZ_CASE` (run exactly one index).

use mec::bench::harness::bench_fn;
use mec::bench::BenchOpts;
use mec::conv::{AlgoKind, ConvContext, ConvPlan, Convolution};
use mec::memory::{Arena, Budget};
use mec::planner::Planner;
use mec::tensor::quant::QParams;
use mec::tensor::{ConvShape, Kernel, KernelShape, Nhwc, Precision, Tensor};
use mec::util::{diff, Rng};
use std::time::Duration;

/// f32 rel_l2 tolerance per algorithm — see the module-level table.
fn f32_rtol(kind: AlgoKind) -> f64 {
    match kind {
        AlgoKind::Direct
        | AlgoKind::SmmConv
        | AlgoKind::Im2col
        | AlgoKind::Mec
        | AlgoKind::MecSolutionA
        | AlgoKind::MecSolutionB
        | AlgoKind::Indirect
        | AlgoKind::Kn2row => 1e-4,
        AlgoKind::Winograd | AlgoKind::WinogradChunked | AlgoKind::Fft => 2e-3,
    }
}

/// The q16 analytic bound (derived and unit-tested in
/// `q16_properties.rs`; duplicated here because test binaries cannot
/// share items).
fn q16_bound(shape: &ConvShape, input: &Tensor, kernel: &Kernel) -> f64 {
    let qa = QParams::from_slice(input.data());
    let qk = QParams::from_slice(kernel.data());
    let amax = max_abs(input.data());
    let kmax = max_abs(kernel.data());
    let (sa, sk) = (qa.scale as f64, qk.scale as f64);
    let kdim = (shape.kernel.kh * shape.kernel.kw * shape.kernel.ic) as f64;
    1.5 * kdim * (amax * sk * 0.5 + kmax * sa * 0.5 + sa * sk * 0.25 + sa * sk * 16384.0) + 1e-6
}

fn max_abs(v: &[f32]) -> f64 {
    v.iter().fold(0.0f64, |m, &x| m.max(x.abs() as f64))
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 - y as f64).abs())
        .fold(0.0, f64::max)
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|s| {
            let t = s.trim();
            match t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
                Some(hex) => u64::from_str_radix(hex, 16).ok(),
                None => t.parse().ok(),
            }
        })
        .unwrap_or(default)
}

/// Random geometry for case `case`. Buckets guarantee the degenerate
/// corners show up deterministically instead of by luck:
/// * `case % 8 == 0` — pointwise 1×1 kernels (kn2row's single-GEMM
///   degeneration, indirect's trivial offset table), strides up to 2;
/// * `case % 8 == 1` — kernel spans the whole image (`k_h = i_h`,
///   `k_w = i_w`, so `o_h = o_w = 1` — the k≥h corner where MEC's
///   partition logic and the gather strips collapse);
/// * `case % 8 == 2` — 3×3 stride-1 with padding, so both Winograd
///   variants are exercised on a fixed fraction of cases;
/// * otherwise — free-form (same distribution the q16 grid uses):
///   rectangular kernels, strides 1–3, zero padding 0–2 per side.
///
/// Returns (unpadded input shape, ph, pw, ConvShape over padded input) —
/// the stack's pre-applied-padding convention (paper §2.1).
fn gen_geometry(case: usize, r: &mut Rng) -> (Nhwc, usize, usize, ConvShape) {
    match case % 8 {
        0 => {
            let (ih, iw) = (r.range(2, 10), r.range(2, 10));
            let ic = r.range(1, 7);
            let shape = ConvShape::new(
                Nhwc::new(r.range(1, 4), ih, iw, ic),
                KernelShape::new(1, 1, ic, r.range(1, 9)),
                r.range(1, 3),
                r.range(1, 3),
            );
            (shape.input, 0, 0, shape)
        }
        1 => {
            let (h, w) = (r.range(2, 8), r.range(2, 8));
            let ic = r.range(1, 5);
            let shape = ConvShape::new(
                Nhwc::new(r.range(1, 3), h, w, ic),
                KernelShape::new(h, w, ic, r.range(1, 6)),
                1,
                1,
            );
            (shape.input, 0, 0, shape)
        }
        2 => {
            let (ih, iw) = (r.range(3, 12), r.range(3, 12));
            let ic = r.range(1, 5);
            let (ph, pw) = (r.range(0, 2), r.range(0, 2));
            let shape = ConvShape::new(
                Nhwc::new(r.range(1, 3), ih + 2 * ph, iw + 2 * pw, ic),
                KernelShape::new(3, 3, ic, r.range(1, 7)),
                1,
                1,
            );
            (Nhwc::new(shape.input.n, ih, iw, ic), ph, pw, shape)
        }
        _ => {
            let (ih, iw) = (r.range(3, 13), r.range(3, 13));
            let ic = r.range(1, 5);
            let (ph, pw) = (r.range(0, 3), r.range(0, 3));
            let (h, w) = (ih + 2 * ph, iw + 2 * pw);
            let kh = r.range(1, h.min(5) + 1);
            let kw = r.range(1, w.min(5) + 1);
            let shape = ConvShape::new(
                Nhwc::new(r.range(1, 4), h, w, ic),
                KernelShape::new(kh, kw, ic, r.range(1, 6)),
                r.range(1, 4),
                r.range(1, 4),
            );
            (Nhwc::new(shape.input.n, ih, iw, ic), ph, pw, shape)
        }
    }
}

/// The oracle: direct convolution with f64 accumulation, written from
/// the definition with no shared code paths (no GEMM, no packing), so a
/// systematic bug in the library's substrate cannot cancel out.
fn direct_f64(shape: &ConvShape, input: &Tensor, kernel: &Kernel) -> Vec<f32> {
    let (ish, k) = (shape.input, shape.kernel);
    let (oh, ow) = (shape.oh(), shape.ow());
    let (ind, kd) = (input.data(), kernel.data());
    let mut out = Vec::with_capacity(ish.n * oh * ow * k.kc);
    for n in 0..ish.n {
        for y in 0..oh {
            for x in 0..ow {
                for o in 0..k.kc {
                    let mut acc = 0.0f64;
                    for u in 0..k.kh {
                        for v in 0..k.kw {
                            for i in 0..k.ic {
                                let a = ind[ish.index(n, y * shape.sh + u, x * shape.sw + v, i)];
                                acc += a as f64 * kd[k.index(u, v, i, o)] as f64;
                            }
                        }
                    }
                    out.push(acc as f32);
                }
            }
        }
    }
    out
}

#[test]
fn differential_fuzz_oracle() {
    let seed = env_u64("MEC_FUZZ_SEED", 0x6ec_d1ff);
    let cases = env_u64("MEC_FUZZ_CASES", 200) as usize;
    let only = std::env::var("MEC_FUZZ_CASE")
        .ok()
        .and_then(|s| s.parse::<usize>().ok());
    for case in 0..cases {
        if only.is_some_and(|c| c != case) {
            continue;
        }
        let mut rng = Rng::new(seed ^ 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(case as u64 + 1));
        let (raw_shape, ph, pw, shape) = gen_geometry(case, &mut rng);
        let raw = Tensor::random(raw_shape, &mut rng);
        let input = if ph > 0 || pw > 0 {
            raw.pad_spatial(ph, pw)
        } else {
            raw
        };
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let want = direct_f64(&shape, &input, &kernel);
        let replay = format!(
            "replay: MEC_FUZZ_SEED={seed:#x} MEC_FUZZ_CASE={case} \
             cargo test --test algo_differential differential_fuzz_oracle"
        );

        // Library f32 direct, kept for the smm bitwise-identity row.
        let mut direct_f32 = Tensor::zeros(shape.output());
        AlgoKind::Direct
            .build()
            .plan(&ConvContext::default(), &shape, &kernel)
            .execute(&input, &mut Arena::new(), &mut direct_f32);

        for kind in AlgoKind::ALL {
            let algo = kind.build();
            if !algo.supports(&shape) {
                continue;
            }
            for threads in [1usize, 2] {
                let ctx = ConvContext::default().with_threads(threads);
                let mut got = Tensor::zeros(shape.output());
                algo.plan(&ctx, &shape, &kernel)
                    .execute(&input, &mut Arena::new(), &mut got);
                let d = diff(got.data(), &want);
                assert!(
                    d.rel_l2 <= f32_rtol(kind),
                    "case {case}: {} f32 t={threads} on {} (pad {ph},{pw}): \
                     rel_l2={:.3e} > rtol={:.1e} (max_abs={:.3e})\n{replay}",
                    kind.name(),
                    shape.describe(),
                    d.rel_l2,
                    f32_rtol(kind),
                    d.max_abs
                );
                if kind == AlgoKind::SmmConv {
                    assert_eq!(
                        got.data(),
                        direct_f32.data(),
                        "case {case}: smm t={threads} not bitwise-equal to direct\n{replay}"
                    );
                }
                if kind.supports_precision(Precision::Q16) && kind != AlgoKind::Direct {
                    let qctx = ConvContext::default()
                        .with_threads(threads)
                        .with_precision(Precision::Q16);
                    let mut q = Tensor::zeros(shape.output());
                    algo.plan(&qctx, &shape, &kernel)
                        .execute(&input, &mut Arena::new(), &mut q);
                    let qb = q16_bound(&shape, &input, &kernel);
                    let qd = max_abs_diff(q.data(), &want);
                    assert!(
                        qd <= qb,
                        "case {case}: {} q16 t={threads} on {}: \
                         max_abs={qd:.3e} > bound={qb:.3e}\n{replay}",
                        kind.name(),
                        shape.describe()
                    );
                }
            }
        }
    }
}

/// Cost-model honesty: on fixtures spanning the menu's regimes, the
/// algorithm `Auto` (the planner under an unlimited budget) selects must
/// measure within 1.5× of the measured-fastest menu entry. Debug builds
/// skew constant factors the release-tuned model cannot see (and tier-1
/// runs tests unoptimized), so the contract is enforced at 1.5× in
/// release and relaxed to 4× under `debug_assertions` — the release CI
/// leg (`cargo test --release --test algo_differential`) is the
/// authoritative run. 3×3 stride-1 fixtures are deliberately absent:
/// there Winograd's asymptotic win is real but tile-count-sensitive, and
/// the paper's own Fig. 4 treats it as a separate system.
#[test]
fn auto_selection_is_near_the_measured_fastest() {
    let slack = if cfg!(debug_assertions) { 4.0 } else { 1.5 };
    let opts = BenchOpts {
        warmup: 1,
        min_reps: 3,
        max_reps: 8,
        target_time: Duration::from_millis(30),
    };
    let ctx = ConvContext::default();
    let planner = Planner::new();
    let mut rng = Rng::new(0xfa57);
    let fixtures = [
        (
            "gemm-heavy-5x5",
            ConvShape::new(Nhwc::new(1, 32, 32, 8), KernelShape::new(5, 5, 8, 16), 1, 1),
        ),
        (
            "pointwise",
            ConvShape::new(Nhwc::new(1, 20, 20, 32), KernelShape::new(1, 1, 32, 64), 1, 1),
        ),
        (
            "strided-7x7",
            ConvShape::new(Nhwc::new(1, 40, 40, 4), KernelShape::new(7, 7, 4, 8), 2, 2),
        ),
    ];
    for (name, shape) in fixtures {
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let chosen = planner.plan(&shape, &Budget::unlimited(), &ctx).algo;
        let mut best = f64::INFINITY;
        let mut fastest = AlgoKind::Direct;
        let mut chosen_ns = None;
        for kind in AlgoKind::MENU {
            let algo = kind.build();
            if !algo.supports(&shape) {
                continue;
            }
            let plan = algo.plan(&ctx, &shape, &kernel);
            let mut arena = Arena::new();
            let mut out = Tensor::zeros(shape.output());
            plan.execute(&input, &mut arena, &mut out); // pre-size the arena
            let r = bench_fn(kind.name(), &opts, || {
                plan.execute(&input, &mut arena, &mut out)
            });
            if kind == chosen {
                chosen_ns = Some(r.median_ns());
            }
            if r.median_ns() < best {
                best = r.median_ns();
                fastest = kind;
            }
        }
        let chosen_ns = chosen_ns.expect("planner chose an algorithm outside AlgoKind::MENU");
        assert!(
            chosen_ns <= slack * best,
            "{name}: Auto picked {chosen} at {chosen_ns:.0} ns but {fastest} \
             measured {best:.0} ns — off by more than {slack:.1}x"
        );
    }
}
