//! PJRT path: load the AOT HLO artifacts (JAX/Pallas lowered at build
//! time) and cross-check their numerics against the native rust engine.
//! This closes the three-layer loop: Pallas kernel ≡ rust engine ≡ the
//! HLO the server executes. Skips when artifacts are missing.
//!
//! Compiled only with `--features pjrt` (needs a vendored `xla` crate —
//! see Cargo.toml).
#![cfg(feature = "pjrt")]

use mec::conv::{AlgoKind, ConvContext, Convolution};
use mec::memory::{Budget, Workspace};
use mec::model::{load_mecw, EvalSet};
use mec::planner::Planner;
use mec::runtime::{model_weight_inputs, Executor, Manifest, NativeExecutor, PjrtEngine, PjrtExecutor};
use mec::tensor::{ConvShape, Kernel, KernelShape, Nhwc, Tensor};
use mec::util::{assert_allclose, Rng};

fn manifest() -> Option<Manifest> {
    let dir = mec::runtime::artifacts::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: no artifacts manifest — run `make artifacts`");
            None
        }
    }
}

#[test]
fn conv_artifacts_match_native_engine() {
    let Some(manifest) = manifest() else { return };
    let engine = PjrtEngine::cpu().expect("pjrt client");
    let mut checked = 0;
    for art in &manifest.artifacts {
        if !art.name.starts_with("conv_") {
            continue;
        }
        let comp = engine.load_hlo_text(&art.file).expect("compile artifact");
        let xs = &art.input_shapes[0];
        let ks = &art.input_shapes[1];
        let mut rng = Rng::new(42 + checked as u64);
        let mut x = vec![0.0f32; xs.iter().product()];
        let mut k = vec![0.0f32; ks.iter().product()];
        rng.fill_uniform(&mut x, -1.0, 1.0);
        rng.fill_uniform(&mut k, -1.0, 1.0);

        // PJRT result (the Pallas-lowered HLO).
        let got = comp
            .run_f32(&[(&x, xs), (&k, ks)])
            .expect("execute artifact");

        // Native engine result. Conv artifacts have stride in their
        // geometry: recover it from shapes via Eq. (1).
        let input_shape = Nhwc::new(xs[0], xs[1], xs[2], xs[3]);
        let kern_shape = KernelShape::new(ks[0], ks[1], ks[2], ks[3]);
        let os = &art.output_shapes[0];
        // s = (i - k) / (o - 1) when o > 1.
        let sh = if os[1] > 1 { (xs[1] - ks[0]) / (os[1] - 1) } else { 1 };
        let sw = if os[2] > 1 { (xs[2] - ks[1]) / (os[2] - 1) } else { 1 };
        let shape = ConvShape::new(input_shape, kern_shape, sh, sw);
        let input = Tensor::from_vec(shape.input, x);
        let kernel = Kernel::from_vec(shape.kernel, k);
        let mut want = Tensor::zeros(shape.output());
        let mut ws = Workspace::new();
        AlgoKind::Mec.build().run(
            &ConvContext::default(),
            &shape,
            &input,
            &kernel,
            &mut ws,
            &mut want,
        );
        assert_eq!(got.len(), want.len(), "{}: output size", art.name);
        assert_allclose(&got, want.data(), 1e-4, &format!("pjrt {}", art.name));
        checked += 1;
    }
    assert!(checked >= 3, "expected ≥3 conv artifacts, found {checked}");
}

#[test]
fn model_fwd_artifact_matches_native_model_and_labels() {
    let Some(manifest) = manifest() else { return };
    let dir = mec::runtime::artifacts::default_dir();
    let engine = PjrtEngine::cpu().expect("pjrt client");
    let mut model = load_mecw(dir.join("model.mecw")).unwrap();
    let mut pjrt = PjrtExecutor::from_artifact(&engine, &manifest, "model_fwd")
        .expect("model_fwd")
        .with_weights(model_weight_inputs(&model))
        .expect("weights");
    model.plan(
        &Planner::new(),
        &Budget::unlimited(),
        &ConvContext::default(),
        pjrt.lowered_batch(),
    );
    let mut native = NativeExecutor::new(std::sync::Arc::new(model), ConvContext::default());

    let eval = EvalSet::load(dir.join("eval.bin")).unwrap();
    let b = pjrt.lowered_batch();
    let mut data = Vec::new();
    for s in &eval.samples[..b] {
        data.extend_from_slice(s);
    }
    let batch = Tensor::from_vec(Nhwc::new(b, eval.h, eval.w, eval.c), data);

    let scores_pjrt = pjrt.forward(&batch).expect("pjrt forward");
    let scores_native = native.forward(&batch).expect("native forward");
    assert_eq!(scores_pjrt.len(), b * 3);
    // Same weights, same math — two completely independent stacks
    // (JAX/Pallas HLO via PJRT vs rust engine) must agree closely.
    assert_allclose(&scores_pjrt, &scores_native, 1e-3, "pjrt vs native model");

    // And both should classify the eval samples correctly (trained net).
    let correct = scores_pjrt
        .chunks_exact(3)
        .zip(&eval.labels[..b])
        .filter(|(row, &l)| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                == Some(l)
        })
        .count();
    assert!(correct * 10 >= b * 8, "pjrt accuracy {correct}/{b}");
}

#[test]
fn partial_batch_is_padded_and_truncated() {
    let Some(manifest) = manifest() else { return };
    let dir = mec::runtime::artifacts::default_dir();
    let engine = PjrtEngine::cpu().expect("pjrt client");
    let model = load_mecw(dir.join("model.mecw")).unwrap();
    let mut pjrt = PjrtExecutor::from_artifact(&engine, &manifest, "model_fwd")
        .expect("model_fwd")
        .with_weights(model_weight_inputs(&model))
        .expect("weights");
    let b = pjrt.lowered_batch();
    assert!(b >= 2);
    let (h, w, c) = pjrt.input_hwc();
    let mut rng = Rng::new(9);
    let mut full = vec![0.0f32; b * h * w * c];
    rng.fill_uniform(&mut full, 0.0, 1.0);
    let full_t = Tensor::from_vec(Nhwc::new(b, h, w, c), full.clone());
    let full_scores = pjrt.forward(&full_t).unwrap();
    // Run just the first 3 samples as a partial batch.
    let part_t = Tensor::from_vec(
        Nhwc::new(3, h, w, c),
        full[..3 * h * w * c].to_vec(),
    );
    let part_scores = pjrt.forward(&part_t).unwrap();
    assert_eq!(part_scores.len(), 3 * pjrt.output_features());
    assert_allclose(
        &part_scores,
        &full_scores[..3 * pjrt.output_features()],
        1e-5,
        "partial batch",
    );
}
