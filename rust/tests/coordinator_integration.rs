//! Coordinator under concurrent load: correctness of responses, metric
//! invariants, backpressure, and property tests on the batcher.

use mec::conv::AlgoKind;
use mec::coordinator::{BatchPolicy, RequestQueue, Server, ServerConfig, SubmitError};
use mec::engine::Engine;
use mec::model::{Layer, Model};
use mec::serving::ShedReason;
use mec::tensor::{Kernel, KernelShape};
use mec::util::prop::{check, Config};
use mec::util::Rng;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn tiny_model() -> Model {
    let mut rng = Rng::new(0xBEEF);
    Model::new(
        "itest",
        (8, 8, 1),
        vec![
            Layer::Conv {
                kernel: Kernel::random(KernelShape::new(3, 3, 1, 4), &mut rng),
                bias: vec![0.05; 4],
                sh: 1,
                sw: 1,
                ph: 1,
                pw: 1,
            },
            Layer::Relu,
            Layer::MaxPool { k: 2, s: 2 },
            Layer::Flatten,
            Layer::Dense {
                w: {
                    let mut w = vec![0.0; 64 * 3];
                    rng.fill_uniform(&mut w, -0.4, 0.4);
                    w
                },
                bias: vec![0.0; 3],
                d_in: 64,
                d_out: 3,
            },
            Layer::Softmax,
        ],
    )
}

fn tiny_engine() -> Arc<Engine> {
    Arc::new(
        Engine::builder(tiny_model())
            .algo_override(0, AlgoKind::Mec)
            .pin_batch_sizes(&[1, 8])
            .threads(2)
            .build()
            .expect("tiny model builds"),
    )
}

#[test]
fn concurrent_clients_all_served_consistently() {
    let server = Server::start(
        tiny_engine(),
        ServerConfig {
            workers: 2,
            queue_depth: 512,
            max_wait: Duration::from_millis(5),
            ..ServerConfig::default()
        },
    )
    .expect("server starts");
    let client = server.client();
    let n_threads = 4;
    let per_thread = 25;
    let handles: Vec<_> = (0..n_threads)
        .map(|t| {
            let client = client.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(t as u64);
                let mut ok = 0;
                for _ in 0..per_thread {
                    let mut s = vec![0.0f32; 64];
                    rng.fill_uniform(&mut s, 0.0, 1.0);
                    match client.infer(s.clone()) {
                        Ok(resp) => {
                            // Scores are a probability row.
                            let pred = resp.result.expect("valid request succeeds");
                            let sum: f32 = pred.scores.iter().sum();
                            assert!((sum - 1.0).abs() < 1e-4);
                            ok += 1;
                        }
                        Err(SubmitError::Shed(ShedReason::QueueFull { .. })) => {}
                        Err(e) => panic!("unexpected {e}"),
                    }
                }
                ok
            })
        })
        .collect();
    let total_ok: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let metrics = server.shutdown();
    assert_eq!(
        metrics.responses.load(Ordering::Relaxed) as usize,
        total_ok
    );
    assert!(total_ok > 0);
    // Conservation: requests = responses + rejected.
    assert_eq!(
        metrics.requests.load(Ordering::Relaxed),
        metrics.responses.load(Ordering::Relaxed) + metrics.rejected.load(Ordering::Relaxed)
    );
}

#[test]
fn backpressure_sheds_typed_when_queue_small() {
    let server = Server::start(
        tiny_engine(),
        ServerConfig {
            workers: 1,
            queue_depth: 2,
            // Slow consumption: a long collect window.
            max_wait: Duration::from_millis(30),
            ..ServerConfig::default()
        },
    )
    .expect("server starts");
    let client = server.client();
    let mut rejected = 0;
    let mut rxs = Vec::new();
    for _ in 0..64 {
        match client.submit(vec![0.2; 64]) {
            Ok(rx) => rxs.push(rx),
            Err(SubmitError::Shed(reason)) => {
                assert!(
                    matches!(reason, ShedReason::QueueFull { capacity: 2, .. }),
                    "expected QueueFull at capacity 2, got {reason:?}"
                );
                rejected += 1;
            }
            Err(e) => panic!("{e}"),
        }
    }
    for rx in rxs {
        let _ = rx.recv();
    }
    let metrics = server.shutdown();
    assert!(rejected > 0, "tiny queue should shed load");
    assert_eq!(metrics.rejected.load(Ordering::Relaxed) as usize, rejected);
    assert_eq!(
        metrics.shed_queue_full.load(Ordering::Relaxed) as usize,
        rejected
    );
}

#[test]
fn prop_batcher_never_exceeds_max_batch_and_preserves_fifo() {
    let cfg = Config { cases: 16, ..Config::default() };
    check(
        &cfg,
        |r: &mut Rng| (r.range(1, 9), r.range(1, 40)),
        |&(max_batch, n_reqs)| {
            let q = RequestQueue::new(64);
            let (tx, _rx) = std::sync::mpsc::channel();
            for i in 0..n_reqs as u64 {
                q.push(mec::coordinator::Request {
                    id: i,
                    sample: vec![],
                    enqueued_at: Instant::now(),
                    deadline: None,
                    reply: tx.clone(),
                })
                .map_err(|e| e.to_string())?;
            }
            q.close();
            let b = mec::coordinator::Batcher::new(
                &q,
                BatchPolicy::new(max_batch, Duration::ZERO),
            );
            let mut seen: Vec<u64> = Vec::new();
            while let Some(batch) = b.next_batch() {
                if batch.len() > max_batch {
                    return Err(format!("batch {} > max {}", batch.len(), max_batch));
                }
                seen.extend(batch.iter().map(|r| r.id));
            }
            let want: Vec<u64> = (0..n_reqs as u64).collect();
            if seen != want {
                return Err(format!("order violated: {seen:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn metrics_percentiles_are_monotone_under_load() {
    let server =
        Server::start(tiny_engine(), ServerConfig::default()).expect("server starts");
    let client = server.client();
    let mut rxs = Vec::new();
    for _ in 0..40 {
        if let Ok(rx) = client.submit(vec![0.3; 64]) {
            rxs.push(rx);
        }
    }
    for rx in rxs {
        let _ = rx.recv();
    }
    let m = server.shutdown();
    let p50 = m.latency_percentile(50.0);
    let p95 = m.latency_percentile(95.0);
    let p99 = m.latency_percentile(99.0);
    assert!(p50 > 0.0);
    assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
    assert!(m.throughput_rps() > 0.0);
    assert!(m.mean_batch_size() >= 1.0);
    // The serving snapshot agrees on volume and renders.
    let snap = m.snapshot();
    assert_eq!(snap.served, m.responses.load(Ordering::Relaxed));
    assert!(snap.render().contains("serving metrics"));
}
