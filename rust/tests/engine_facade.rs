//! Engine/Session facade contract tests — the acceptance criteria of
//! the builder-configured front door:
//!
//! * concurrent `Session`s on one `Engine` produce **bitwise-identical**
//!   outputs to a solo session;
//! * per-batch-size plans behind one engine share kernel prepacks by
//!   **pointer equality** (pinned batches prepack eagerly, once);
//! * builder misconfiguration (q16 + Winograd override, a budget too
//!   small for the overridden algorithm, bad knobs, missing model file)
//!   returns a typed [`EngineError`] rather than panicking;
//! * session input validation returns errors, never aborts a thread.

use mec::conv::AlgoKind;
use mec::engine::{Engine, EngineError};
use mec::memory::Budget;
use mec::model::{Layer, Model};
use mec::planner::PlanError;
use mec::tensor::{Kernel, KernelShape, Nhwc, Precision, Tensor};
use mec::util::Rng;
use std::sync::Arc;

/// Conv → relu → pool → dense → softmax, the shape of the serving models.
fn classifier_model(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    Model::new(
        "facade-test",
        (8, 8, 1),
        vec![
            Layer::Conv {
                kernel: Kernel::random(KernelShape::new(3, 3, 1, 4), &mut rng),
                bias: vec![0.05; 4],
                sh: 1,
                sw: 1,
                ph: 1,
                pw: 1,
            },
            Layer::Relu,
            Layer::MaxPool { k: 2, s: 2 },
            Layer::Flatten,
            Layer::Dense {
                w: {
                    let mut w = vec![0.0; 64 * 3];
                    rng.fill_uniform(&mut w, -0.4, 0.4);
                    w
                },
                bias: vec![0.0; 3],
                d_in: 64,
                d_out: 3,
            },
            Layer::Softmax,
        ],
    )
}

#[test]
fn concurrent_sessions_match_solo_session_bitwise() {
    let engine = Arc::new(
        Engine::builder(classifier_model(1))
            .pin_batch_sizes(&[4])
            .build()
            .unwrap(),
    );
    let mut rng = Rng::new(11);
    let batch = Arc::new(Tensor::random(Nhwc::new(4, 8, 8, 1), &mut rng));
    let want = engine.session().infer_batch(&batch).unwrap();

    let handles: Vec<_> = (0..4)
        .map(|_| {
            let engine = Arc::clone(&engine);
            let batch = Arc::clone(&batch);
            std::thread::spawn(move || {
                let mut session = engine.session();
                // Several passes per session: steady state included.
                let mut out = session.infer_batch(&batch).unwrap();
                for _ in 0..3 {
                    out = session.infer_batch(&batch).unwrap();
                }
                out
            })
        })
        .collect();
    for h in handles {
        let got = h.join().expect("session thread panicked");
        assert_eq!(got.data(), want.data(), "concurrent != solo (bitwise)");
    }
}

#[test]
fn pinned_batches_share_prepacks_by_pointer_before_any_inference() {
    let engine = Engine::builder(classifier_model(2))
        .pin_batch_sizes(&[2, 5])
        .build()
        .unwrap();
    // Eager plan + prepack: both geometries cached at build time...
    let plans = engine.model().cached_plans_for_layer(0);
    assert_eq!(plans.len(), 2, "one plan per pinned batch size");
    // ...sharing ONE kernel-side prepack — pointer equality, not just
    // equal bytes.
    assert_eq!(engine.model().cached_prepacks(), 1);
    let a = plans[0].shared_prepack().expect("plan exposes its prepack");
    let b = plans[1].shared_prepack().expect("plan exposes its prepack");
    assert!(Arc::ptr_eq(&a, &b), "prepack duplicated across batch sizes");
    // Sessions at both batch sizes agree with each other sample-wise
    // (allclose, not bitwise: MEC's Solution A/B dispatch is a
    // batch-size question, so the summation *grouping* may differ).
    let mut rng = Rng::new(23);
    let big = Tensor::random(Nhwc::new(5, 8, 8, 1), &mut rng);
    let mut s1 = engine.session();
    let mut s2 = engine.session();
    let full = s1.infer_batch(&big).unwrap();
    for i in 0..5 {
        let pred = s2.infer(big.sample(i)).unwrap();
        mec::util::assert_allclose(
            &pred.scores,
            full.sample(i),
            1e-4,
            "batched vs single sample",
        );
    }
}

#[test]
fn q16_winograd_override_is_a_typed_build_error() {
    let err = Engine::builder(classifier_model(3))
        .precision(Precision::Q16)
        .algo_override(0, AlgoKind::Winograd)
        .build()
        .unwrap_err();
    assert!(
        matches!(
            err,
            EngineError::Plan {
                layer: 0,
                source: PlanError::UnsupportedPrecision {
                    algo: AlgoKind::Winograd,
                    precision: Precision::Q16,
                },
            }
        ),
        "{err:?}"
    );
    // Without the override the q16 build succeeds: the planner falls
    // back to the quantized GEMM family.
    let engine = Engine::builder(classifier_model(3))
        .precision(Precision::Q16)
        .build()
        .unwrap();
    assert!(engine.plan_summary()[0].1.supports_precision(Precision::Q16));
}

#[test]
fn budget_too_small_for_overridden_algorithm_is_a_typed_build_error() {
    let err = Engine::builder(classifier_model(4))
        .budget(Budget::new(16)) // 16 B: no lowering algorithm fits
        .algo_override(0, AlgoKind::Mec)
        .build()
        .unwrap_err();
    match err {
        EngineError::Plan {
            layer: 0,
            source: PlanError::BudgetExceeded { algo, workspace_bytes, limit },
        } => {
            assert_eq!(algo, AlgoKind::Mec);
            assert_eq!(limit, 16);
            assert!(workspace_bytes > 16);
        }
        other => panic!("expected BudgetExceeded, got {other:?}"),
    }
    // The same tiny budget without an override still builds: the
    // zero-workspace tier (direct, and since the menu grew, kn2row/SMM)
    // is always admissible.
    let engine = Engine::builder(classifier_model(4))
        .budget(Budget::new(16))
        .build()
        .unwrap();
    assert!(
        matches!(
            engine.plan_summary()[0].1,
            AlgoKind::Direct | AlgoKind::Kn2row | AlgoKind::SmmConv
        ),
        "{:?}",
        engine.plan_summary()[0].1
    );
    assert_eq!(engine.plan_report()[0].chosen.workspace_bytes, 0);
}

#[test]
fn session_input_validation_returns_errors_not_panics() {
    let engine = Engine::builder(classifier_model(5)).build().unwrap();
    let mut session = engine.session();
    let err = session.infer(&[0.0; 7]).unwrap_err();
    assert_eq!(err, EngineError::SampleSize { expected: 64, got: 7 });
    let bad = Tensor::zeros(Nhwc::new(1, 4, 4, 1));
    let err = session.infer_batch(&bad).unwrap_err();
    assert_eq!(
        err,
        EngineError::BatchShape {
            expected: (8, 8, 1),
            got: (4, 4, 1),
        }
    );
    // The session survives and still answers valid inputs.
    let pred = session.infer(&[0.1; 64]).unwrap();
    assert_eq!(pred.scores.len(), 3);
    assert!(pred.class < 3);
    let sum: f32 = pred.scores.iter().sum();
    assert!((sum - 1.0).abs() < 1e-4, "softmax row sums to {sum}");
}

#[test]
fn autotuned_engine_matches_cost_model_engine_numerically() {
    let cost = Engine::builder(classifier_model(6)).build().unwrap();
    let tuned = Engine::builder(classifier_model(6))
        .autotune(true)
        .build()
        .unwrap();
    // The autotuner records its measurements in the build report.
    let report = &tuned.plan_report()[0];
    let ms = report.measurements.as_ref().expect("autotune measured");
    assert!(!ms.is_empty());
    assert!(ms.iter().any(|m| m.algo == report.chosen.algo));
    // Whatever each selector picked, the numerics agree.
    let mut rng = Rng::new(17);
    let batch = Tensor::random(Nhwc::new(1, 8, 8, 1), &mut rng);
    let a = cost.session().infer_batch(&batch).unwrap();
    let b = tuned.session().infer_batch(&batch).unwrap();
    mec::util::assert_allclose(a.data(), b.data(), 1e-3, "autotune vs cost model");
}

#[test]
fn override_on_a_dead_conv_node_is_a_typed_build_error() {
    // A conv branch the pass pipeline eliminates as dead would pass the
    // is-it-a-conv check yet never be validated or applied — that must
    // be a build error, not a silent no-op.
    use mec::model::{GraphBuilder, Model};
    let mut rng = Rng::new(0xdead);
    let mut b = GraphBuilder::new("dead-override", (6, 6, 1));
    let x = b.input();
    let live = b.conv(
        x,
        Kernel::random(KernelShape::new(3, 3, 1, 2), &mut rng),
        vec![0.0; 2],
        1,
        1,
        0,
        0,
    );
    let _dead = b.conv(
        x,
        Kernel::random(KernelShape::new(3, 3, 1, 4), &mut rng),
        vec![0.0; 4],
        1,
        1,
        0,
        0,
    );
    let model = Model::from_graph(b.finish(live));
    let err = Engine::builder(model)
        .algo_override(1, AlgoKind::Mec) // node 1 is the dead conv
        .build()
        .unwrap_err();
    assert!(matches!(err, EngineError::InvalidConfig(_)), "{err:?}");
}

#[test]
fn calibrated_q16_engine_uses_static_activation_scales() {
    use mec::model::EvalSet;
    let mut rng = Rng::new(0xca1);
    let mut sample = vec![0.0f32; 64];
    rng.fill_uniform(&mut sample, -1.0, 1.0);
    let eval = EvalSet {
        h: 8,
        w: 8,
        c: 1,
        samples: vec![sample.clone()],
        labels: vec![0],
    };

    // q16 + calibration: the build records a static scale per conv node.
    let calibrated = Engine::builder(classifier_model(8))
        .precision(Precision::Q16)
        .calibration(eval.clone())
        .build()
        .unwrap();
    let report = &calibrated.plan_report()[0];
    let qp = report
        .act_qparams
        .expect("calibrated q16 build bakes an activation scale");
    assert!(qp.scale > 0.0);
    assert_eq!(
        calibrated.model().activation_qparams(report.layer),
        Some(qp)
    );

    // On the calibration sample itself the static scale equals the
    // dynamic abs-max, so the two engines agree bitwise.
    let dynamic = Engine::builder(classifier_model(8))
        .precision(Precision::Q16)
        .build()
        .unwrap();
    assert!(dynamic.plan_report()[0].act_qparams.is_none());
    let a = calibrated.session().infer(&sample).unwrap();
    let b = dynamic.session().infer(&sample).unwrap();
    assert_eq!(a.scores, b.scores, "static scale diverged on its own sample");

    // Other inputs stay within the q16 grid of each other (the scales
    // differ only by the inputs' abs-max ratio).
    let mut other = vec![0.0f32; 64];
    rng.fill_uniform(&mut other, -0.9, 0.9);
    let a = calibrated.session().infer(&other).unwrap();
    let b = dynamic.session().infer(&other).unwrap();
    mec::util::assert_allclose(&a.scores, &b.scores, 5e-2, "calibrated vs dynamic");

    // f32 builds ignore calibration (the scale is meaningless there)...
    let f32_engine = Engine::builder(classifier_model(8))
        .calibration(eval)
        .build()
        .unwrap();
    assert!(f32_engine.plan_report()[0].act_qparams.is_none());

    // ...and a shape-mismatched calibration set is a typed config error.
    let bad = EvalSet {
        h: 4,
        w: 4,
        c: 1,
        samples: vec![vec![0.0; 16]],
        labels: vec![0],
    };
    let err = Engine::builder(classifier_model(8))
        .precision(Precision::Q16)
        .calibration(bad)
        .build()
        .unwrap_err();
    assert!(matches!(err, EngineError::InvalidConfig(_)), "{err:?}");
}

#[test]
fn engine_is_immutable_and_shareable_across_threads() {
    // Engine: Send + Sync by construction (compile-time check), and the
    // same Arc serves sessions from many threads at once.
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Engine>();
    let engine = Arc::new(
        Engine::builder(classifier_model(7))
            .threads(2)
            .pin_batch_sizes(&[1, 3])
            .build()
            .unwrap(),
    );
    assert_eq!(engine.pinned_batch_sizes(), &[1, 3]);
    assert_eq!(engine.context().threads(), 2);
    let mut rng = Rng::new(29);
    let sample = {
        let mut s = vec![0.0f32; 64];
        rng.fill_uniform(&mut s, 0.0, 1.0);
        s
    };
    let solo = engine.session().infer(&sample).unwrap();
    let handles: Vec<_> = (0..3)
        .map(|_| {
            let engine = Arc::clone(&engine);
            let sample = sample.clone();
            std::thread::spawn(move || engine.session().infer(&sample).unwrap())
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap(), solo, "prediction differs across threads");
    }
}
