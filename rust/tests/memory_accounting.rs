//! Memory-overhead accounting across the suite — the paper's Fig. 4b/4e
//! numbers are allocator facts; these tests pin them down exactly.

use mec::bench::workload::{by_name, resnet101_table3, suite};
use mec::conv::{AlgoKind, Convolution};
use mec::memory::{tracker, Budget, Workspace};

#[test]
fn fig4b_memory_ratios_have_paper_shape() {
    // Paper: MEC reduces memory-overhead vs Conv.cpu by ~3.2x on average
    // (mobile, batch 1), and cv6-cv12 vs Wino.cpu by ~5.9x on average.
    let mut conv_ratio_sum = 0.0;
    let mut conv_count = 0.0;
    let mut wino_ratio_sum = 0.0;
    let mut wino_count = 0.0;
    for w in suite() {
        let shape = w.shape(1, 1);
        let mec = AlgoKind::Mec.build().workspace_bytes(&shape) as f64;
        let i2c = AlgoKind::Im2col.build().workspace_bytes(&shape) as f64;
        conv_ratio_sum += i2c / mec;
        conv_count += 1.0;
        // Paper's Wino.cpu is the memory-optimized (tile-chunked) variant.
        let wino = AlgoKind::WinogradChunked.build();
        if wino.supports(&shape) {
            wino_ratio_sum += wino.workspace_bytes(&shape) as f64 / mec;
            wino_count += 1.0;
        }
    }
    let conv_avg = conv_ratio_sum / conv_count;
    let wino_avg = wino_ratio_sum / wino_count;
    // Shape, not exact numbers: MEC wins clearly against both.
    assert!(
        conv_avg > 2.0 && conv_avg < 4.5,
        "avg im2col/MEC ratio {conv_avg} out of paper's ballpark (3.2x)"
    );
    // Paper reports 5.9x for their chunked Wino.cpu; our chunk size and
    // counting differ in constants, so assert the regime, not the digit.
    assert!(
        wino_avg > 0.2 && wino_avg < 20.0,
        "avg Wino.cpu/MEC ratio {wino_avg}, paper reports ~5.9x (our per-layer\n     spread 0.1x..38x is dominated by the irreducible 16·kc·ic transformed-kernel plane)"
    );
    // The GPU formulation (all U/V/M live) must be strictly hungrier.
    let full: f64 = suite()
        .iter()
        .filter(|w| w.kh == 3 && w.s == 1)
        .map(|w| {
            let shape = w.shape(1, 1);
            AlgoKind::Winograd.build().workspace_bytes(&shape) as f64
                / AlgoKind::WinogradChunked.build().workspace_bytes(&shape) as f64
        })
        .sum::<f64>();
    assert!(full > 7.0, "full Winograd should dwarf chunked, got sum-ratio {full}");
}

#[test]
fn fig4e_fft_has_largest_overhead_on_small_kernels() {
    // Paper Fig. 4e: FFT.gpu requires substantially more memory than all
    // others on the 3x3 layers.
    for name in ["cv7", "cv9", "cv10", "cv11", "cv12"] {
        let shape = by_name(name).unwrap().shape(1, 1);
        let fft = AlgoKind::Fft.build().workspace_bytes(&shape);
        let i2c = AlgoKind::Im2col.build().workspace_bytes(&shape);
        let mec = AlgoKind::Mec.build().workspace_bytes(&shape);
        assert!(fft > i2c, "{name}: fft {fft} <= im2col {i2c}");
        assert!(fft > mec, "{name}: fft {fft} <= mec {mec}");
    }
}

#[test]
fn table3_weighted_memory_ratio_reproduces() {
    // Paper Table 3: weighted sum over ResNet-101 layers gives Conv.cpu
    // 203.6 MB vs MEC.cpu 64.6 MB => ratio 3.2.
    let mut conv_total = 0.0;
    let mut mec_total = 0.0;
    for (w, weight) in resnet101_table3() {
        let shape = w.shape(1, 1);
        conv_total +=
            weight as f64 * AlgoKind::Im2col.build().workspace_bytes(&shape) as f64;
        mec_total += weight as f64 * AlgoKind::Mec.build().workspace_bytes(&shape) as f64;
    }
    let ratio = conv_total / mec_total;
    assert!(
        ratio > 2.8 && ratio < 3.8,
        "Table 3 memory ratio {ratio:.2}, paper says 3.2"
    );
    // Absolute scale sanity: paper's MEM column is ~200 MB for Conv.
    let conv_mb = conv_total / 1e6;
    assert!(
        conv_mb > 150.0 && conv_mb < 260.0,
        "Conv.cpu weighted memory {conv_mb:.1} MB vs paper's 203.6 MB"
    );
}

#[test]
fn algorithm_menu_workspace_relations_hold_across_fixtures() {
    // The expanded menu's memory claims, pinned per fixture geometry
    // (cv1–cv12 plus the pointwise anchors):
    //  * indirect's lane strips never exceed im2col's Eq. 2 lowering —
    //    they are at most GATHER_LANES of its i_n·o_h row blocks;
    //  * kn2row and SMM-Conv are exactly zero-workspace, like direct;
    //  * under q16 the indirect gather strips halve (to the f32-slot
    //    granularity of the arena), like im2col's lowered matrix.
    use mec::bench::workload::extras;
    use mec::tensor::Precision;
    for w in suite().into_iter().chain(extras()) {
        let shape = w.shape(1, 1);
        let ind = AlgoKind::Indirect.build().workspace_bytes(&shape);
        let i2c = AlgoKind::Im2col.build().workspace_bytes(&shape);
        assert!(ind <= i2c, "{}: indirect {ind} > im2col {i2c}", w.name);
        assert_eq!(AlgoKind::Kn2row.build().workspace_bytes(&shape), 0, "{}", w.name);
        assert_eq!(AlgoKind::SmmConv.build().workspace_bytes(&shape), 0, "{}", w.name);
        let ind_q16 = AlgoKind::Indirect
            .build()
            .workspace_bytes_prec(&shape, Precision::Q16);
        assert!(
            ind_q16 <= ind / 2 + 4,
            "{}: q16 gather {ind_q16} not halved vs f32 {ind}",
            w.name
        );
    }
    // And the sharpest contrast, on cv1's big-image geometry: the
    // indirection buffer bounds gather memory far below the lowering
    // family (the acceptance fixture of the planner's indirect pick).
    let cv1 = by_name("cv1").unwrap().shape(1, 1);
    let ind = AlgoKind::Indirect.build().workspace_bytes(&cv1);
    assert!(ind * 6 < AlgoKind::Im2col.build().workspace_bytes(&cv1));
    assert!(ind * 2 < AlgoKind::Mec.build().workspace_bytes(&cv1));
}

#[test]
fn kn2row_resident_prepack_is_kernel_sized() {
    // kn2row trades workspace for k_h·k_w prepacked pointwise operands:
    // the plan's resident bytes must stay within a small blocking-padding
    // factor of the kernel itself (O(k²·i_c·k_c) — no hidden lowering).
    use mec::conv::{ConvContext, ConvPlan};
    use mec::tensor::Kernel;
    for name in ["cv2", "cv6", "pw1"] {
        let shape = by_name(name).unwrap().shape(1, 4);
        let kernel = Kernel::zeros(shape.kernel);
        let plan = AlgoKind::Kn2row
            .build()
            .plan(&ConvContext::default(), &shape, &kernel);
        let kernel_bytes = shape.kernel.len() * 4;
        assert!(plan.resident_bytes() >= kernel_bytes, "{name}");
        assert!(
            plan.resident_bytes() <= 4 * kernel_bytes,
            "{name}: resident {} vs kernel {kernel_bytes}",
            plan.resident_bytes()
        );
        assert_eq!(plan.workspace_bytes(), 0, "{name}");
    }
}

#[test]
fn tracker_balances_after_workspace_churn() {
    let before = tracker::current_bytes();
    for _ in 0..10 {
        let mut ws = Workspace::new();
        ws.reserve(4096);
        let _ = ws.take(1024);
    }
    assert_eq!(tracker::current_bytes(), before, "leaked tracked bytes");
}

#[test]
fn budget_rejections_are_exact_at_the_boundary() {
    let shape = by_name("cv6").unwrap().shape(1, 1);
    let mec_bytes = AlgoKind::Mec.build().workspace_bytes(&shape);
    let budget = Budget::new(mec_bytes);
    assert!(budget.check(mec_bytes).is_ok());
    assert!(budget.check(mec_bytes + 1).is_err());
    let err = budget.check(mec_bytes + 1).unwrap_err();
    assert_eq!(err.requested, mec_bytes + 1);
    assert_eq!(err.limit, mec_bytes);
}

#[test]
fn eq4_closed_form_equals_measured_difference() {
    // R (Eq. 4) = im2col bytes - MEC bytes, in elements, for every layer.
    for w in suite() {
        let shape = w.shape(1, 1);
        let r = shape.eq4_difference();
        let direct =
            shape.im2col_lowered_elems() as i128 - shape.mec_lowered_elems() as i128;
        assert_eq!(r, direct, "{}", w.name);
        // Closed form from the paper's derivation:
        // i_n·o_w·k_w·i_c·(o_h·k_h − i_h)
        let closed = (shape.input.n * shape.ow() * shape.kernel.kw * shape.kernel.ic) as i128
            * (shape.oh() as i128 * shape.kernel.kh as i128 - shape.input.h as i128);
        assert_eq!(r, closed, "{} closed form", w.name);
    }
}
