//! Q16 fixed-point path — correctness and memory contracts.
//!
//! The paper evaluates every algorithm in f32 AND 16-bit fixed point
//! (§4). These tests pin the reproduction's q16 grid to the f32 direct
//! reference with an **analytic** error bound, and assert the memory
//! story that motivates it: the q16 lowering buffers occupy half the
//! bytes of the f32 plan's, and the q16 hot path allocates nothing in
//! steady state (mirroring `plan_execute.rs`).
//!
//! # The error bound
//!
//! With symmetric per-tensor scales (round-to-nearest), input quantized
//! as `a = â·s_a + Δa` (|Δa| ≤ s_a/2) and kernel likewise, one output is
//! a K-term dot product (K = k_h·k_w·i_c). Three error sources, summed
//! per term:
//!
//! * operand quantization: `|a·Δk| + |k·Δa| + |Δa·Δk|`
//!   ≤ `amax·s_k/2 + kmax·s_a/2 + s_a·s_k/4`;
//! * the Q15 product shift: each widened product is rounded-shifted by
//!   2¹⁵ before i32 accumulation (overflow-proof for K ≤ 2¹⁵), adding at
//!   most `0.5 · s_a·s_k·2¹⁵` per term;
//! * f32 accumulation noise in both paths — absorbed by a 1.5× headroom.
//!
//! So: `|q16 − direct| ≤ 1.5 · K · (amax·s_k/2 + kmax·s_a/2 + s_a·s_k/4
//! + s_a·s_k·2¹⁴) + ε`. The randomized grid below asserts the max-abs
//! deviation against exactly this bound.

use mec::conv::{AlgoKind, ConvContext, ConvPlan, Convolution};
use mec::memory::{self, measure_peak, Arena, Budget};
use mec::model::{Layer, Model};
use mec::planner::Planner;
use mec::tensor::quant::QParams;
use mec::tensor::{ConvShape, Kernel, KernelShape, Nhwc, Precision, Tensor};
use mec::util::Rng;

/// The q16 algorithms under test (direct is the oracle, not a subject).
/// Indirect quantizes while gathering exactly like im2col quantizes
/// while lowering, so the analytic bound below covers it unchanged.
const Q16_ALGOS: [AlgoKind; 5] = [
    AlgoKind::Mec,
    AlgoKind::MecSolutionA,
    AlgoKind::MecSolutionB,
    AlgoKind::Im2col,
    AlgoKind::Indirect,
];

/// Run `f` holding the tracker's global lock (via `measure_peak`): tests
/// in this binary allocate tracked arenas, so they serialize against the
/// steady-state test's `current_bytes` assertions. Do NOT nest.
fn with_tracker_lock<T>(f: impl FnOnce() -> T) -> T {
    measure_peak(f).0
}

/// Random geometry with explicit zero padding: returns the unpadded
/// input, the padding, and the ConvShape on the padded input (the stack's
/// pre-applied-padding convention, paper §2.1).
fn gen_case(r: &mut Rng) -> (Nhwc, usize, usize, ConvShape) {
    let ih = r.range(3, 13);
    let iw = r.range(3, 13);
    let ic = r.range(1, 5);
    let (ph, pw) = (r.range(0, 3), r.range(0, 3));
    let (h, w) = (ih + 2 * ph, iw + 2 * pw);
    let kh = r.range(1, h.min(5) + 1);
    let kw = r.range(1, w.min(5) + 1);
    let shape = ConvShape::new(
        Nhwc::new(r.range(1, 4), h, w, ic),
        KernelShape::new(kh, kw, ic, r.range(1, 6)),
        r.range(1, 4),
        r.range(1, 4),
    );
    (Nhwc::new(shape.input.n, ih, iw, ic), ph, pw, shape)
}

/// The documented analytic bound (see module docs).
fn q16_error_bound(shape: &ConvShape, input: &Tensor, kernel: &Kernel) -> f64 {
    let qa = QParams::from_slice(input.data());
    let qk = QParams::from_slice(kernel.data());
    let amax = input.data().iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
    let kmax = kernel.data().iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
    let (sa, sk) = (qa.scale as f64, qk.scale as f64);
    let kdim = (shape.kernel.kh * shape.kernel.kw * shape.kernel.ic) as f64;
    1.5 * kdim * (amax * sk * 0.5 + kmax * sa * 0.5 + sa * sk * 0.25 + sa * sk * 16384.0) + 1e-6
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 - y as f64).abs())
        .fold(0.0, f64::max)
}

#[test]
fn q16_matches_f32_direct_within_analytic_bound() {
    with_tracker_lock(bound_grid_body);
}

fn bound_grid_body() {
    let mut rng = Rng::new(0x9160);
    let f32_ctx = ConvContext::default();
    for case in 0..32 {
        let (raw_shape, ph, pw, shape) = gen_case(&mut rng);
        let raw = Tensor::random(raw_shape, &mut rng);
        let input = if ph > 0 || pw > 0 {
            raw.pad_spatial(ph, pw)
        } else {
            raw
        };
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut want = Tensor::zeros(shape.output());
        let direct = AlgoKind::Direct.build().plan(&f32_ctx, &shape, &kernel);
        direct.execute(&input, &mut Arena::new(), &mut want);

        let bound = q16_error_bound(&shape, &input, &kernel);
        for kind in Q16_ALGOS {
            for threads in [1usize, 3] {
                let ctx = ConvContext::default()
                    .with_threads(threads)
                    .with_precision(Precision::Q16);
                let plan = kind.build().plan(&ctx, &shape, &kernel);
                let mut arena = Arena::new();
                let mut got = Tensor::zeros(shape.output());
                plan.execute(&input, &mut arena, &mut got);
                let d = max_abs_diff(got.data(), want.data());
                assert!(
                    d <= bound,
                    "case {case} {} t={threads}: max_abs={d:.3e} > bound={bound:.3e} on {} (pad {ph},{pw})",
                    kind.name(),
                    shape.describe()
                );
            }
        }
    }
}

#[test]
fn q16_lowering_buffers_use_at_most_half_the_f32_bytes() {
    let mut rng = Rng::new(0x9161);
    // Includes strided and odd geometries; element counts here are even,
    // so "half" is exact (odd counts round up by one f32 slot).
    for (n, ih, iw, ic, kh, kw, kc, sh, sw) in [
        (1usize, 7, 7, 2, 3, 3, 4, 1, 1),
        (2, 12, 10, 3, 5, 3, 2, 2, 2),
        (1, 9, 14, 4, 3, 2, 6, 1, 3),
    ] {
        let shape = ConvShape::new(
            Nhwc::new(n, ih, iw, ic),
            KernelShape::new(kh, kw, ic, kc),
            sh,
            sw,
        );
        let kernel = Kernel::random(shape.kernel, &mut rng);
        for kind in [AlgoKind::Mec, AlgoKind::Im2col] {
            let f32_plan = kind.build().plan(&ConvContext::default(), &shape, &kernel);
            let q16_plan = kind.build().plan(
                &ConvContext::default().with_precision(Precision::Q16),
                &shape,
                &kernel,
            );
            let f32_lowered = f32_plan.layout().region("lowered").unwrap().elems * 4;
            let q16_lowered = q16_plan.layout().region("lowered").unwrap().elems * 4;
            assert!(
                q16_lowered <= f32_lowered / 2 + 4,
                "{}: q16 lowered {q16_lowered} B vs f32 {f32_lowered} B on {}",
                kind.name(),
                shape.describe()
            );
            // The prepacked kernel halves too.
            assert!(q16_plan.resident_bytes() <= f32_plan.resident_bytes() / 2 + 4);
        }
    }
}

#[test]
fn q16_execute_allocates_zero_tracked_bytes_in_steady_state() {
    // Each per-algorithm block runs inside measure_peak (which holds the
    // global tracker lock), so the current_bytes deltas are ours alone.
    let mut rng = Rng::new(0x9162);
    let shape = ConvShape::new(Nhwc::new(2, 11, 9, 3), KernelShape::new(3, 3, 3, 4), 1, 2);
    let input = Tensor::random(shape.input, &mut rng);
    let kernel = Kernel::random(shape.kernel, &mut rng);
    let ctx = ConvContext::default().with_precision(Precision::Q16);
    for kind in Q16_ALGOS {
        let plan = kind.build().plan(&ctx, &shape, &kernel);
        let ((), _peak) = measure_peak(|| {
            let mut arena = Arena::new();
            let mut out = Tensor::zeros(shape.output());
            plan.execute(&input, &mut arena, &mut out); // first: arena grows
            let bytes_after_first = memory::current_bytes();
            assert_eq!(arena.bytes(), plan.workspace_bytes(), "{}", kind.name());
            for rep in 0..4 {
                plan.execute(&input, &mut arena, &mut out);
                assert_eq!(
                    memory::current_bytes(),
                    bytes_after_first,
                    "{} rep {rep}: tracked allocation in q16 steady state",
                    kind.name()
                );
            }
        });
    }
}

#[test]
fn q16_plan_execute_is_deterministic() {
    with_tracker_lock(determinism_body);
}

fn determinism_body() {
    // Same plan, same input -> bitwise-identical output across repeats
    // and across a rebuilt plan (quantization is deterministic).
    let mut rng = Rng::new(0x9163);
    let shape = ConvShape::new(Nhwc::new(1, 10, 10, 2), KernelShape::new(3, 3, 2, 3), 1, 1);
    let input = Tensor::random(shape.input, &mut rng);
    let kernel = Kernel::random(shape.kernel, &mut rng);
    let ctx = ConvContext::default().with_precision(Precision::Q16);
    let plan = AlgoKind::Mec.build().plan(&ctx, &shape, &kernel);
    let mut arena = Arena::new();
    let mut a = Tensor::zeros(shape.output());
    let mut b = Tensor::zeros(shape.output());
    plan.execute(&input, &mut arena, &mut a);
    plan.execute(&input, &mut arena, &mut b);
    assert_eq!(a.data(), b.data());
    let rebuilt = AlgoKind::Mec.build().plan(&ctx, &shape, &kernel);
    rebuilt.execute(&input, &mut arena, &mut b);
    assert_eq!(a.data(), b.data());
}

#[test]
fn env_selected_precision_plans_and_executes() {
    // The CI matrix runs the suite under MEC_BENCH_PRECISION={f32,q16};
    // this test picks up whichever grid the leg selected (same parsing
    // the benches use) and drives a planned convolution end to end under
    // it, so the q16 leg genuinely exercises the env-var-driven path.
    with_tracker_lock(|| {
        let precision = mec::bench::bench_precision();
        let ctx = ConvContext::default().with_precision(precision);
        let shape = ConvShape::new(Nhwc::new(2, 9, 9, 3), KernelShape::new(3, 3, 3, 4), 1, 1);
        let mut rng = Rng::new(0x9165);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut want = Tensor::zeros(shape.output());
        AlgoKind::Direct
            .build()
            .plan(&ConvContext::default(), &shape, &kernel)
            .execute(&input, &mut Arena::new(), &mut want);
        let plan = AlgoKind::Mec.build().plan(&ctx, &shape, &kernel);
        let mut got = Tensor::zeros(shape.output());
        plan.execute(&input, &mut Arena::new(), &mut got);
        let bound = match precision {
            Precision::F32 => 1e-4,
            Precision::Q16 => q16_error_bound(&shape, &input, &kernel),
        };
        let d = max_abs_diff(got.data(), want.data());
        assert!(d <= bound, "{precision}: max_abs={d:.3e} > {bound:.3e}");
    });
}

#[test]
fn q16_model_plans_quantized_family_and_tracks_f32_forward() {
    with_tracker_lock(model_q16_body);
}

fn model_q16_body() {
    let mut rng = Rng::new(0x9164);
    let mut m = Model::new(
        "q16-test",
        (10, 10, 2),
        vec![
            Layer::Conv {
                kernel: Kernel::random(KernelShape::new(3, 3, 2, 6), &mut rng),
                bias: vec![0.05; 6],
                sh: 1,
                sw: 1,
                ph: 1,
                pw: 1,
            },
            Layer::Relu,
            Layer::Conv {
                kernel: Kernel::random(KernelShape::new(3, 3, 6, 4), &mut rng),
                bias: vec![0.0; 4],
                sh: 1,
                sw: 1,
                ph: 0,
                pw: 0,
            },
        ],
    );
    let batch = Tensor::random(Nhwc::new(2, 10, 10, 2), &mut rng);

    let f32_ctx = ConvContext::default();
    m.plan(&Planner::new(), &Budget::unlimited(), &f32_ctx, 2);
    let mut arena = m.sized_arena();
    let want = m.forward(&f32_ctx, &batch, &mut arena);

    let q16_ctx = ConvContext::default().with_precision(Precision::Q16);
    m.plan(&Planner::new(), &Budget::unlimited(), &q16_ctx, 2);
    // The q16 planner must only pick algorithms with a q16 path.
    for (i, algo) in m.plan_summary() {
        assert!(
            algo.supports_precision(Precision::Q16),
            "layer {i} planned {algo:?} under q16"
        );
    }
    let mut arena = m.sized_arena();
    let got = m.forward(&q16_ctx, &batch, &mut arena);
    // Whole-model drift stays small (per-layer bounds compose; ReLU is
    // 1-Lipschitz). Loose relative tolerance, not bitwise.
    mec::util::assert_allclose(got.data(), want.data(), 2e-2, "q16 model forward");
    // And the planned q16 arena is no bigger than the f32 one would be —
    // the halved lowering buffers shrink the max-over-layers.
    let q16_ws = m.planned_workspace_bytes();
    m.plan(&Planner::new(), &Budget::unlimited(), &f32_ctx, 2);
    assert!(
        q16_ws <= m.planned_workspace_bytes(),
        "q16 arena {} > f32 arena {}",
        q16_ws,
        m.planned_workspace_bytes()
    );
}
