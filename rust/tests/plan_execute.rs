//! Plan/execute contract tests — the acceptance criteria of the
//! plan-once / execute-many refactor:
//!
//! * plan-once + execute-many output is **bitwise identical** to the
//!   one-shot `convolve` path, for every algorithm, across random
//!   geometries;
//! * repeated `execute` calls perform **zero tracked allocation** after
//!   the first (no kernel repacking, no workspace growth) — asserted
//!   against the memory tracker;
//! * a whole model's shared arena peaks at the **max** (not the sum) of
//!   per-layer workspaces.
//!
//! Tracker-sensitive tests run inside `measure_peak`, which serializes on
//! the tracker's global lock, so parallel test threads don't interfere.

use mec::conv::{convolve, AlgoKind, ConvContext, ConvPlan, Convolution};
use mec::memory::{self, measure_peak, Arena, Budget};
use mec::model::{Layer, Model};
use mec::planner::Planner;
use mec::tensor::{ConvShape, Kernel, KernelShape, Nhwc, Tensor};
use mec::util::Rng;

/// Run `f` holding the tracker's global lock (via `measure_peak`), so
/// tests in this binary never see each other's tracked allocations. Do
/// NOT nest — the lock is not reentrant.
fn with_tracker_lock<T>(f: impl FnOnce() -> T) -> T {
    measure_peak(f).0
}

/// Random geometry: [n, ih, iw, ic, kh, kw, kc, sh, sw] (same generator
/// family as conv_properties).
fn gen_geometry(r: &mut Rng) -> ConvShape {
    let ih = r.range(3, 14);
    let iw = r.range(3, 14);
    let ic = r.range(1, 5);
    let kh = r.range(1, ih.min(5) + 1);
    let kw = r.range(1, iw.min(5) + 1);
    ConvShape::new(
        Nhwc::new(r.range(1, 4), ih, iw, ic),
        KernelShape::new(kh, kw, ic, r.range(1, 6)),
        r.range(1, 4),
        r.range(1, 4),
    )
}

#[test]
fn plan_once_execute_many_is_bitwise_identical_to_convolve() {
    with_tracker_lock(plan_once_execute_many_body);
}

fn plan_once_execute_many_body() {
    let mut rng = Rng::new(0x9a7);
    let ctx = ConvContext::default();
    for case in 0..24 {
        let shape = gen_geometry(&mut rng);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        for kind in AlgoKind::ALL {
            let algo = kind.build();
            if !algo.supports(&shape) {
                continue;
            }
            let oneshot = convolve(kind, &ctx, &shape, &input, &kernel);
            let plan = algo.plan(&ctx, &shape, &kernel);
            let mut arena = Arena::new();
            let mut out = Tensor::zeros(shape.output());
            for rep in 0..3 {
                plan.execute(&input, &mut arena, &mut out);
                assert_eq!(
                    out.data(),
                    oneshot.data(),
                    "case {case} rep {rep}: {} not bitwise-identical on {}",
                    kind.name(),
                    shape.describe()
                );
            }
        }
    }
}

#[test]
fn plan_execute_bitwise_identical_under_threads() {
    with_tracker_lock(plan_execute_threaded_body);
}

fn plan_execute_threaded_body() {
    // The threaded execute paths must agree with the one-shot threaded
    // run too (same partitioning by construction).
    let mut rng = Rng::new(0x517);
    let ctx = ConvContext::default().with_threads(4);
    let shape = ConvShape::new(Nhwc::new(2, 12, 11, 3), KernelShape::new(3, 3, 3, 5), 1, 1);
    let input = Tensor::random(shape.input, &mut rng);
    let kernel = Kernel::random(shape.kernel, &mut rng);
    for kind in AlgoKind::ALL {
        let algo = kind.build();
        if !algo.supports(&shape) {
            continue;
        }
        let oneshot = convolve(kind, &ctx, &shape, &input, &kernel);
        let plan = algo.plan(&ctx, &shape, &kernel);
        let mut arena = Arena::new();
        let mut out = Tensor::zeros(shape.output());
        plan.execute(&input, &mut arena, &mut out);
        assert_eq!(out.data(), oneshot.data(), "{} threaded", kind.name());
    }
}

#[test]
fn repeated_execute_allocates_zero_tracked_bytes_after_first() {
    let mut rng = Rng::new(0xa110c);
    let ctx = ConvContext::default();
    for shape in [
        ConvShape::new(Nhwc::new(1, 9, 9, 2), KernelShape::new(3, 3, 2, 4), 1, 1),
        ConvShape::new(Nhwc::new(2, 12, 10, 3), KernelShape::new(5, 3, 3, 2), 2, 1),
    ] {
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        for kind in AlgoKind::ALL {
            let algo = kind.build();
            if !algo.supports(&shape) {
                continue;
            }
            let plan = algo.plan(&ctx, &shape, &kernel);
            // Inside measure_peak: holds the tracker lock, so the
            // current-bytes deltas below are ours alone.
            let ((), _peak) = measure_peak(|| {
                let mut arena = Arena::new();
                let mut out = Tensor::zeros(shape.output());
                plan.execute(&input, &mut arena, &mut out); // first: arena grows
                let bytes_after_first = memory::current_bytes();
                let cap_after_first = arena.capacity();
                assert_eq!(arena.bytes(), plan.workspace_bytes(), "{}", kind.name());
                for rep in 0..4 {
                    plan.execute(&input, &mut arena, &mut out);
                    assert_eq!(
                        memory::current_bytes(),
                        bytes_after_first,
                        "{} rep {rep}: tracked allocation in steady state on {}",
                        kind.name(),
                        shape.describe()
                    );
                    assert_eq!(arena.capacity(), cap_after_first, "{}", kind.name());
                }
            });
        }
    }
}

#[test]
fn first_execute_peak_equals_plan_workspace() {
    // The arena's tracked growth is exactly the plan's layout total — the
    // plan-level analogue of the measured==analytic workspace tests.
    let mut rng = Rng::new(0xbeef);
    let ctx = ConvContext::default();
    let shape = ConvShape::new(Nhwc::new(1, 10, 10, 3), KernelShape::new(3, 3, 3, 4), 1, 1);
    let input = Tensor::random(shape.input, &mut rng);
    let kernel = Kernel::random(shape.kernel, &mut rng);
    for kind in [AlgoKind::Im2col, AlgoKind::Mec, AlgoKind::Winograd] {
        let algo = kind.build();
        let plan = algo.plan(&ctx, &shape, &kernel);
        let mut out = Tensor::zeros(shape.output());
        let ((), peak) = measure_peak(|| {
            let mut arena = Arena::new();
            plan.execute(&input, &mut arena, &mut out);
        });
        assert_eq!(peak, plan.workspace_bytes(), "{}", kind.name());
    }
}

fn two_conv_model() -> Model {
    let mut rng = Rng::new(0x2c);
    Model::new(
        "arena-test",
        (12, 12, 2),
        vec![
            // Layer 0: 3x3x2 -> 8 channels (bigger workspace).
            Layer::Conv {
                kernel: Kernel::random(KernelShape::new(3, 3, 2, 8), &mut rng),
                bias: vec![0.0; 8],
                sh: 1,
                sw: 1,
                ph: 1,
                pw: 1,
            },
            Layer::Relu,
            // Layer 2: 3x3x8 -> 4 channels on the same spatial grid.
            Layer::Conv {
                kernel: Kernel::random(KernelShape::new(3, 3, 8, 4), &mut rng),
                bias: vec![0.0; 4],
                sh: 1,
                sw: 1,
                ph: 1,
                pw: 1,
            },
        ],
    )
}

#[test]
fn plans_share_one_prepack_across_batch_sizes() {
    with_tracker_lock(prepack_sharing_body);
}

fn prepack_sharing_body() {
    // The kernel-side prepack (PackedB / Winograd U / FFT spectra /
    // direct's kernel copy) is batch-independent: building it once and
    // plan_shared-ing it into plans for two batch sizes must (a) be the
    // same allocation by pointer, and (b) execute correctly for both.
    let mut rng = Rng::new(0x5a5);
    let ctx = ConvContext::default();
    let small = ConvShape::new(Nhwc::new(1, 10, 10, 3), KernelShape::new(3, 3, 3, 4), 1, 1);
    let big = ConvShape::new(Nhwc::new(3, 10, 10, 3), KernelShape::new(3, 3, 3, 4), 1, 1);
    let kernel = Kernel::random(small.kernel, &mut rng);
    for kind in AlgoKind::ALL {
        let algo = kind.build();
        if !algo.supports(&small) {
            continue;
        }
        let prepack = algo.prepack(&ctx, &small, &kernel);
        let plan_small = algo.plan_shared(&ctx, &small, std::sync::Arc::clone(&prepack));
        let plan_big = algo.plan_shared(&ctx, &big, std::sync::Arc::clone(&prepack));
        let a = plan_small.shared_prepack().expect("plan exposes prepack");
        let b = plan_big.shared_prepack().expect("plan exposes prepack");
        assert!(
            std::sync::Arc::ptr_eq(&a, &b),
            "{}: prepack duplicated across batch sizes",
            kind.name()
        );
        // Shared-prepack plans still agree with the one-shot path.
        for shape in [small, big] {
            let input = Tensor::random(shape.input, &mut rng);
            let want = convolve(kind, &ctx, &shape, &input, &kernel);
            let plan = if shape.input.n == 1 { &plan_small } else { &plan_big };
            let mut arena = Arena::new();
            let mut out = Tensor::zeros(shape.output());
            plan.execute(&input, &mut arena, &mut out);
            assert_eq!(out.data(), want.data(), "{} n={}", kind.name(), shape.input.n);
        }
    }
}

#[test]
fn model_arena_peak_is_max_not_sum_of_layer_workspaces() {
    let mut m = two_conv_model();
    let ctx = ConvContext::default();
    let batch = 2;
    m.plan(&Planner::new(), &Budget::unlimited(), &ctx, batch);

    let per_layer = m.planned_layer_workspaces();
    assert_eq!(per_layer.len(), 2, "both conv layers planned");
    let max: usize = per_layer.iter().map(|(_, b)| *b).max().unwrap();
    let sum: usize = per_layer.iter().map(|(_, b)| *b).sum();
    assert_eq!(m.planned_workspace_bytes(), max);
    assert!(
        max < sum,
        "layers should differ so max ({max}) < sum ({sum}) is meaningful"
    );

    // Tracker assertion: a forward pass peaks at exactly the workspace
    // max plus the liveness plan's activation arena — never the sum of
    // per-layer workspaces, and never the sum of node outputs.
    let mut rng = Rng::new(7);
    let input = Tensor::random(Nhwc::new(batch, 12, 12, 2), &mut rng);
    let (out, peak) = measure_peak(|| {
        let mut arena = m.sized_arena();
        m.forward(&ctx, &input, &mut arena)
    });
    assert_eq!(out.shape().c, 4);
    assert_eq!(
        peak,
        max + m.activation_bytes(batch),
        "peak must equal workspace max + planned activation arena"
    );
    // And the activation arena itself hit the liveness lower bound.
    assert_eq!(m.activation_bytes(batch), m.max_live_bytes(batch));
}

#[test]
fn facade_session_steady_state_allocates_zero_tracked_bytes() {
    // The Engine/Session facade inherits the plan/execute contract: an
    // engine-sized session performs zero tracked allocation in steady
    // state, for both `infer` (single sample) and `infer_batch`.
    let engine = mec::engine::Engine::builder(two_conv_model())
        .pin_batch_sizes(&[1, 2])
        .build()
        .expect("facade builds");
    let mut rng = Rng::new(0xfa);
    let input = Tensor::random(Nhwc::new(2, 12, 12, 2), &mut rng);
    let mut sample = vec![0.0f32; 12 * 12 * 2];
    rng.fill_uniform(&mut sample, -1.0, 1.0);
    with_tracker_lock(|| {
        let mut session = engine.session();
        assert_eq!(
            session.workspace_bytes(),
            engine.workspace_bytes(),
            "session arena pre-sized by the engine"
        );
        // Warm both entry points once (plans are already cached for the
        // pinned batches; this fills the session's memo).
        let _ = session.infer_batch(&input).unwrap();
        let _ = session.infer(&sample).unwrap();
        // Steady state: zero tracked allocation, no arena growth.
        let before = memory::current_bytes();
        for rep in 0..3 {
            let _ = session.infer_batch(&input).unwrap();
            let _ = session.infer(&sample).unwrap();
            assert_eq!(
                memory::current_bytes(),
                before,
                "rep {rep}: tracked allocation in facade steady state"
            );
            assert_eq!(session.workspace_bytes(), engine.workspace_bytes());
        }
    });
}

#[test]
fn facade_session_steady_state_spawns_zero_os_threads() {
    // The threading analogue of the zero-tracked-alloc invariant: the
    // engine's persistent pool is built once at `build()` (threads - 1
    // workers), and repeated `Session::infer`/`infer_batch` calls in
    // steady state spawn NO further OS threads — the pool spawn counter
    // stays flat. (Per-engine counter, so parallel tests that build
    // their own pools cannot perturb it.)
    let engine = mec::engine::Engine::builder(two_conv_model())
        .threads(4)
        .pin_batch_sizes(&[1, 2])
        .build()
        .expect("facade builds");
    assert_eq!(
        engine.pool_threads_spawned(),
        3,
        "pool workers spawned once, at engine build"
    );
    let mut rng = Rng::new(0x5541);
    let input = Tensor::random(Nhwc::new(2, 12, 12, 2), &mut rng);
    let mut sample = vec![0.0f32; 12 * 12 * 2];
    rng.fill_uniform(&mut sample, -1.0, 1.0);
    let mut session = engine.session();
    // Warm both entry points (plan memo + arena growth happen here).
    let _ = session.infer_batch(&input).unwrap();
    let _ = session.infer(&sample).unwrap();
    let spawned = engine.pool_threads_spawned();
    for rep in 0..5 {
        let _ = session.infer_batch(&input).unwrap();
        let _ = session.infer(&sample).unwrap();
        assert_eq!(
            engine.pool_threads_spawned(),
            spawned,
            "rep {rep}: steady-state inference spawned an OS thread"
        );
    }
    // A second session shares the same pool: still no spawns.
    let mut other = engine.session();
    let _ = other.infer(&sample).unwrap();
    assert_eq!(engine.pool_threads_spawned(), spawned);
}

#[test]
fn planned_model_forward_does_not_grow_arena() {
    let mut m = two_conv_model();
    let ctx = ConvContext::default();
    m.plan(&Planner::new(), &Budget::unlimited(), &ctx, 3);
    let mut rng = Rng::new(8);
    let input = Tensor::random(Nhwc::new(3, 12, 12, 2), &mut rng);
    let small = Tensor::random(Nhwc::new(1, 12, 12, 2), &mut rng);
    with_tracker_lock(|| {
        let mut arena = m.sized_arena();
        let before = arena.bytes();
        for _ in 0..3 {
            let _ = m.forward(&ctx, &input, &mut arena);
            assert_eq!(arena.bytes(), before, "forward grew the planned arena");
        }
        // Smaller batches fit inside the planned arena too.
        let _ = m.forward(&ctx, &small, &mut arena);
        assert_eq!(arena.bytes(), before);
    });
}
