//! Persistent-pool contract tests — the acceptance criteria of the
//! parked-worker-pool refactor:
//!
//! * **Pooled vs. inline bitwise identity**: every algorithm, at thread
//!   budgets {1, 2, 8} and in both precisions, produces bit-identical
//!   outputs whether its loops run on pool workers or inline. (Each
//!   output element's accumulation order is independent of the loop
//!   partitioning by construction; this pins that.)
//! * **Concurrent sessions share one pool**: simultaneous sessions of
//!   one engine agree with a solo session and never spawn OS threads
//!   beyond the pool built at `Engine::build`.
//! * **No leaks**: dropping the last handle to a pool joins every
//!   worker.

use mec::conv::{convolve, AlgoKind, ConvContext, ConvPlan, Convolution};
use mec::engine::Engine;
use mec::memory::Arena;
use mec::model::{Layer, Model};
use mec::tensor::{ConvShape, Kernel, KernelShape, Nhwc, Precision, Tensor};
use mec::util::{assert_allclose, Rng};
use std::sync::Arc;

fn test_shapes() -> Vec<ConvShape> {
    vec![
        // 3x3/s1: every algorithm (incl. Winograd) supports it.
        ConvShape::new(Nhwc::new(2, 12, 11, 3), KernelShape::new(3, 3, 3, 5), 1, 1),
        // Strided + rectangular kernel: GEMM family + direct + FFT.
        ConvShape::new(Nhwc::new(1, 10, 13, 2), KernelShape::new(5, 3, 2, 4), 2, 1),
    ]
}

#[test]
fn pooled_execution_is_bitwise_identical_to_inline() {
    let mut rng = Rng::new(0x9001);
    for precision in [Precision::F32, Precision::Q16] {
        for shape in test_shapes() {
            let input = Tensor::random(shape.input, &mut rng);
            let kernel = Kernel::random(shape.kernel, &mut rng);
            for kind in AlgoKind::ALL {
                if !kind.supports_precision(precision) {
                    continue;
                }
                let algo = kind.build();
                if !algo.supports(&shape) {
                    continue;
                }
                // Budget 1 = fully inline, no pool: the reference.
                let ctx1 = ConvContext::default().with_precision(precision);
                let plan1 = algo.plan(&ctx1, &shape, &kernel);
                let mut want = Tensor::zeros(shape.output());
                let mut scratch = vec![0.0f32; plan1.workspace_elems()];
                plan1.execute_in(&input, &mut scratch, &mut want);
                for threads in [2usize, 8] {
                    let ctx =
                        ConvContext::default().with_precision(precision).with_threads(threads);
                    let plan = algo.plan(&ctx, &shape, &kernel);
                    let mut got = Tensor::zeros(shape.output());
                    let mut scratch = vec![0.0f32; plan.workspace_elems()];
                    for rep in 0..2 {
                        plan.execute_in(&input, &mut scratch, &mut got);
                        assert_eq!(
                            got.data(),
                            want.data(),
                            "{} {precision} t={threads} rep={rep} on {}: pooled \
                             execution must be bitwise identical to inline",
                            kind.name(),
                            shape.describe()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn pooled_one_shot_convolve_matches_plan_path() {
    // The one-shot path under a pooled context stays on the same code as
    // plan/execute (regression guard for the context plumbing).
    let mut rng = Rng::new(0x77aa);
    let shape = ConvShape::new(Nhwc::new(2, 9, 9, 2), KernelShape::new(3, 3, 2, 4), 1, 1);
    let input = Tensor::random(shape.input, &mut rng);
    let kernel = Kernel::random(shape.kernel, &mut rng);
    let ctx = ConvContext::default().with_threads(4);
    for kind in AlgoKind::ALL {
        let algo = kind.build();
        if !algo.supports(&shape) {
            continue;
        }
        let oneshot = convolve(kind, &ctx, &shape, &input, &kernel);
        let plan = algo.plan(&ctx, &shape, &kernel);
        let mut arena = Arena::new();
        let mut out = Tensor::zeros(shape.output());
        plan.execute(&input, &mut arena, &mut out);
        assert_eq!(out.data(), oneshot.data(), "{} pooled", kind.name());
    }
}

fn engine_model(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    Model::new(
        "pool-test",
        (10, 10, 2),
        vec![
            Layer::Conv {
                kernel: Kernel::random(KernelShape::new(3, 3, 2, 6), &mut rng),
                bias: vec![0.05; 6],
                sh: 1,
                sw: 1,
                ph: 1,
                pw: 1,
            },
            Layer::Relu,
            Layer::Conv {
                kernel: Kernel::random(KernelShape::new(3, 3, 6, 4), &mut rng),
                bias: vec![0.0; 4],
                sh: 1,
                sw: 1,
                ph: 0,
                pw: 0,
            },
            Layer::Flatten,
            Layer::Dense {
                w: {
                    let mut w = vec![0.0; 8 * 8 * 4 * 3];
                    rng.fill_uniform(&mut w, -0.2, 0.2);
                    w
                },
                bias: vec![0.0; 3],
                d_in: 8 * 8 * 4,
                d_out: 3,
            },
            Layer::Softmax,
        ],
    )
}

#[test]
fn concurrent_sessions_share_one_pool_and_agree_with_solo() {
    let engine =
        Arc::new(Engine::builder(engine_model(0xc0)).threads(4).build().expect("engine builds"));
    assert_eq!(engine.pool_threads_spawned(), 3, "pool = threads - 1");
    let mut rng = Rng::new(0xc1);
    let samples: Vec<Vec<f32>> = (0..6)
        .map(|_| {
            let mut s = vec![0.0f32; 10 * 10 * 2];
            rng.fill_uniform(&mut s, -1.0, 1.0);
            s
        })
        .collect();
    let solo: Vec<_> = {
        let mut session = engine.session();
        samples.iter().map(|s| session.infer(s).unwrap()).collect()
    };
    let spawned = engine.pool_threads_spawned();
    // 4 sessions hammer the shared pool at once; each must agree with
    // the solo pass exactly (a busy pool degrades to inline, which is
    // bitwise identical).
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let engine = Arc::clone(&engine);
            let samples = &samples;
            let solo = &solo;
            scope.spawn(move || {
                let mut session = engine.session();
                for _ in 0..5 {
                    for (s, want) in samples.iter().zip(solo) {
                        let got = session.infer(s).unwrap();
                        assert_eq!(got.class, want.class);
                        assert_allclose(&got.scores, &want.scores, 1e-6, "shared pool");
                    }
                }
            });
        }
    });
    assert_eq!(
        engine.pool_threads_spawned(),
        spawned,
        "concurrent serving must not spawn OS threads"
    );
}

#[test]
fn dropping_the_engine_joins_its_pool_workers() {
    let engine = Engine::builder(engine_model(0xd0)).threads(6).build().expect("engine builds");
    let pool = Arc::clone(engine.context().par.pool().expect("pooled"));
    assert_eq!(pool.live_workers(), 5);
    let mut session = engine.session();
    let sample = vec![0.1f32; 10 * 10 * 2];
    let _ = session.infer(&sample).unwrap();
    // Sessions hold context clones -> the pool outlives the engine until
    // the last session is gone.
    drop(engine);
    let _ = session.infer(&sample).unwrap();
    drop(session);
    // Our Arc is now the only handle keeping the Pool struct alive, but
    // engine/session drops don't shut it down until the last ctx clone
    // goes; shutting down explicitly must join every worker.
    pool.shutdown();
    assert_eq!(pool.live_workers(), 0, "shutdown leaked workers");
}
