//! SLO-aware serving: the scheduling layer in front of the
//! [`Engine`](crate::engine::Engine).
//!
//! MEC's memory win only turns into throughput if concurrent
//! single-sample requests actually coalesce into the batch sizes the
//! engine pre-planned. This module owns that policy; the
//! [`coordinator`](crate::coordinator) owns the mechanism (queue,
//! worker threads, reply channels) and is rewired on top of it.
//!
//! Pieces:
//! * [`batcher`] — the deadline-driven adaptive batcher: collect until
//!   `min(batch_full, oldest_deadline − est_compute − margin)`, then
//!   dispatch as a padding-free split over the engine's pinned batch
//!   sizes (largest-first). Decision logic is pure functions over
//!   explicit `Instant`s, so it unit-tests with a virtual clock.
//! * [`admission`] — typed load shedding at enqueue: a request is
//!   rejected immediately ([`ShedReason::QueueFull`] /
//!   [`ShedReason::DeadlineInfeasible`]) when the bounded queue is at
//!   capacity or its deadline cannot be met given the estimated queue
//!   wait plus the cost model's compute estimate.
//! * [`cost`] — per-pinned-batch compute estimates, seeded from the
//!   planner cost model at engine build and refined online by an EWMA
//!   of measured forward times (lock-free, f64-in-AtomicU64).
//! * [`histogram`] — lock-free HDR-style log-bucketed latency
//!   histograms (16 linear sub-buckets per power of two, ≤ 6.25 %
//!   relative error) — the recording side of the metrics surface.
//! * [`metrics`] — per-worker queue-wait / compute / total recording
//!   plus mergeable snapshots ([`ServingSnapshot`]: p50/p90/p99,
//!   served/shed counters, SLO attainment).
//! * [`loadgen`] — closed-loop and open-loop load generators driving a
//!   [`Client`](crate::coordinator::Client); `benches/serving.rs` uses
//!   them to record the `BENCH_serving.json` trajectory.

// Scheduling policy is safe Rust only: no unsafe, ever (enforced — see
// the crate-level unsafe policy and tools/unsafe-audit).
#![forbid(unsafe_code)]

pub mod admission;
pub mod batcher;
pub mod cost;
pub mod histogram;
pub mod loadgen;
pub mod metrics;

pub use admission::AdmissionPolicy;
pub use batcher::{AdaptiveBatcher, SloPolicy};
pub use cost::BatchCosts;
pub use histogram::{AtomicHistogram, HistSnapshot};
pub use loadgen::{LoadConfig, LoadMode, LoadReport};
pub use metrics::{Dist, RawSnapshot, ServingSnapshot, WorkerMetrics};

/// Why the serving layer refused to run a request. Carried by
/// [`SubmitError::Shed`](crate::coordinator::SubmitError) when shed at
/// enqueue, and by an error
/// [`Response`](crate::coordinator::Response) when a worker sheds at
/// dispatch time (the queue wait consumed the deadline after
/// admission).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The bounded queue is at capacity — classic backpressure.
    QueueFull { depth: usize, capacity: usize },
    /// The deadline cannot be met: estimated queue wait + compute
    /// (`needed_ns`) exceeds the time remaining until the deadline
    /// (`budget_ns`).
    DeadlineInfeasible { needed_ns: u64, budget_ns: u64 },
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShedReason::QueueFull { depth, capacity } => {
                write!(f, "shed: queue full ({depth}/{capacity})")
            }
            ShedReason::DeadlineInfeasible { needed_ns, budget_ns } => write!(
                f,
                "shed: deadline infeasible (need ~{needed_ns} ns, budget {budget_ns} ns)"
            ),
        }
    }
}

/// `--slo-ms` knob: an optional latency objective in milliseconds with
/// a `FromStr`/`Display` round trip (`"none"` ⇄ no SLO, `"8"` ⇄ 8 ms,
/// `"2.5"` ⇄ 2.5 ms). Lives here — not in the CLI — so every front end
/// parses the knob identically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloMs(pub Option<f64>);

impl SloMs {
    /// The objective as a [`Duration`](std::time::Duration), if set.
    pub fn duration(&self) -> Option<std::time::Duration> {
        self.0.map(|ms| std::time::Duration::from_secs_f64(ms / 1e3))
    }
}

/// Typed parse failure for [`SloMs`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseSloError(pub String);

impl std::fmt::Display for ParseSloError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "invalid SLO {:?} (expected a positive millisecond count or \"none\")",
            self.0
        )
    }
}

impl std::error::Error for ParseSloError {}

impl std::str::FromStr for SloMs {
    type Err = ParseSloError;

    fn from_str(s: &str) -> Result<SloMs, ParseSloError> {
        let t = s.trim().to_ascii_lowercase();
        if t == "none" || t == "off" {
            return Ok(SloMs(None));
        }
        match t.parse::<f64>() {
            Ok(v) if v.is_finite() && v > 0.0 => Ok(SloMs(Some(v))),
            _ => Err(ParseSloError(s.to_string())),
        }
    }
}

impl std::fmt::Display for SloMs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.0 {
            None => write!(f, "none"),
            Some(v) if v.fract() == 0.0 => write!(f, "{v:.0}"),
            Some(v) => write!(f, "{v}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn slo_ms_round_trips() {
        for s in ["none", "8", "2.5", "250", "0.25"] {
            let v: SloMs = s.parse().unwrap();
            assert_eq!(v.to_string(), s, "round trip of {s:?}");
            let v2: SloMs = v.to_string().parse().unwrap();
            assert_eq!(v, v2);
        }
        // "off" normalizes to "none" (one canonical rendering).
        let v: SloMs = "off".parse().unwrap();
        assert_eq!(v, SloMs(None));
        assert_eq!(v.to_string(), "none");
    }

    #[test]
    fn slo_ms_rejects_garbage() {
        for s in ["", "fast", "-3", "0", "nan", "inf"] {
            assert!(s.parse::<SloMs>().is_err(), "{s:?} must not parse");
        }
    }

    #[test]
    fn slo_ms_duration() {
        assert_eq!(SloMs(None).duration(), None);
        assert_eq!(
            SloMs(Some(8.0)).duration(),
            Some(Duration::from_millis(8))
        );
        assert_eq!(
            SloMs(Some(0.5)).duration(),
            Some(Duration::from_micros(500))
        );
    }

    #[test]
    fn shed_reason_displays() {
        let s = ShedReason::QueueFull { depth: 4, capacity: 4 }.to_string();
        assert!(s.contains("queue full"));
        let s = ShedReason::DeadlineInfeasible { needed_ns: 10, budget_ns: 3 }.to_string();
        assert!(s.contains("infeasible"));
    }
}
