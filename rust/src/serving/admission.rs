//! Admission control: typed load shedding at the front door.
//!
//! Rejecting a request that cannot make its deadline *at enqueue* is
//! strictly better than serving it late: the client learns immediately
//! (and can retry elsewhere), and the queue capacity it would have
//! burned goes to a request that can still win. The policy here is the
//! standard one: a hard capacity bound, a high watermark above which
//! deadline checks get a 2× safety factor (shed earlier as the queue
//! saturates), and a feasibility test comparing the deadline budget
//! against estimated queue wait + compute from [`BatchCosts`].
//!
//! Pure decision logic over explicit `Instant`s — the unit tests drive
//! it with a virtual clock.

use super::cost::BatchCosts;
use super::ShedReason;
use std::time::{Duration, Instant};

/// Enqueue-time shedding policy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdmissionPolicy {
    /// Hard queue bound; depth at capacity ⇒ [`ShedReason::QueueFull`].
    pub capacity: usize,
    /// Depth at/above which the deadline feasibility check applies a 2×
    /// safety factor (requests with thin slack shed before the queue is
    /// hard-full, protecting the deadlines already admitted).
    pub high_watermark: usize,
    /// Fixed scheduling margin added to every feasibility estimate
    /// (covers batcher collect windows and wake-up jitter).
    pub margin: Duration,
}

impl AdmissionPolicy {
    /// Policy for a queue of `capacity`: watermark at 3/4 depth, 200 µs
    /// margin.
    pub fn for_capacity(capacity: usize) -> AdmissionPolicy {
        let capacity = capacity.max(1);
        AdmissionPolicy {
            capacity,
            high_watermark: (capacity * 3 / 4).max(1),
            margin: Duration::from_micros(200),
        }
    }

    /// Estimated queue wait + compute (ns) for a request arriving at
    /// queue depth `depth`, served by `workers` workers dispatching at
    /// the largest pinned batch. The request's own batch is included,
    /// so the figure is "submit → reply" — directly comparable to a
    /// deadline budget.
    pub fn estimated_turnaround_ns(
        &self,
        depth: usize,
        workers: usize,
        costs: &BatchCosts,
    ) -> f64 {
        let largest = costs.largest().max(1);
        let batches_ahead = (depth + 1).div_ceil(largest);
        batches_ahead as f64 * costs.estimate_ns(largest) / workers.max(1) as f64
    }

    /// Admit or shed a request arriving `now` at queue `depth` with an
    /// optional `deadline`. No deadline ⇒ only the capacity bound
    /// applies (plain bounded-queue backpressure, the pre-SLO
    /// behaviour).
    pub fn admit(
        &self,
        depth: usize,
        workers: usize,
        costs: &BatchCosts,
        now: Instant,
        deadline: Option<Instant>,
    ) -> Result<(), ShedReason> {
        if depth >= self.capacity {
            return Err(ShedReason::QueueFull { depth, capacity: self.capacity });
        }
        let Some(deadline) = deadline else {
            return Ok(());
        };
        let factor = if depth >= self.high_watermark { 2.0 } else { 1.0 };
        let needed_ns = (factor * self.estimated_turnaround_ns(depth, workers, costs)
            + self.margin.as_nanos() as f64) as u64;
        let budget_ns = deadline.saturating_duration_since(now).as_nanos() as u64;
        if needed_ns > budget_ns {
            return Err(ShedReason::DeadlineInfeasible { needed_ns, budget_ns });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs() -> BatchCosts {
        // 1 ms per unit batch, 4 ms per batch of 8.
        BatchCosts::new(&[(1, 1_000_000.0), (8, 4_000_000.0)])
    }

    #[test]
    fn full_queue_sheds_typed() {
        let p = AdmissionPolicy::for_capacity(4);
        let now = Instant::now();
        let err = p.admit(4, 1, &costs(), now, None).unwrap_err();
        assert_eq!(err, ShedReason::QueueFull { depth: 4, capacity: 4 });
        // Below capacity, a deadline-free request always gets in.
        assert!(p.admit(3, 1, &costs(), now, None).is_ok());
    }

    #[test]
    fn infeasible_deadline_sheds_with_budget_figures() {
        let p = AdmissionPolicy::for_capacity(64);
        let now = Instant::now();
        // Empty queue: turnaround ≈ one 8-batch ≈ 4 ms. A 1 ms deadline
        // cannot be met; a 100 ms deadline can.
        let err = p
            .admit(0, 1, &costs(), now, Some(now + Duration::from_millis(1)))
            .unwrap_err();
        match err {
            ShedReason::DeadlineInfeasible { needed_ns, budget_ns } => {
                assert!(needed_ns > budget_ns);
                assert!(needed_ns >= 4_000_000, "includes the compute estimate");
            }
            other => panic!("expected DeadlineInfeasible, got {other:?}"),
        }
        assert!(p
            .admit(0, 1, &costs(), now, Some(now + Duration::from_millis(100)))
            .is_ok());
    }

    #[test]
    fn queue_wait_scales_with_depth_and_workers() {
        let p = AdmissionPolicy::for_capacity(1024);
        let c = costs();
        // 31 ahead + self = 4 batches of 8 ⇒ 16 ms on one worker.
        let one = p.estimated_turnaround_ns(31, 1, &c);
        assert!((one - 16_000_000.0).abs() < 1.0, "{one}");
        // Two workers halve it.
        let two = p.estimated_turnaround_ns(31, 2, &c);
        assert!((two - 8_000_000.0).abs() < 1.0, "{two}");
        // A deadline feasible at depth 0 becomes infeasible deep in the
        // queue.
        let now = Instant::now();
        let d = Some(now + Duration::from_millis(6));
        assert!(p.admit(0, 1, &c, now, d).is_ok());
        assert!(matches!(
            p.admit(31, 1, &c, now, d),
            Err(ShedReason::DeadlineInfeasible { .. })
        ));
    }

    #[test]
    fn watermark_doubles_the_required_slack() {
        let mut p = AdmissionPolicy::for_capacity(16);
        p.high_watermark = 8;
        p.margin = Duration::ZERO;
        let c = BatchCosts::new(&[(1, 1_000_000.0)]);
        let now = Instant::now();
        // Depth 7 (< watermark): 8 batches ⇒ 8 ms needed; 10 ms budget ok.
        let d = Some(now + Duration::from_millis(10));
        assert!(p.admit(7, 1, &c, now, d).is_ok());
        // Depth 8 (>= watermark): 9 batches × 2 ⇒ 18 ms needed; shed.
        assert!(matches!(
            p.admit(8, 1, &c, now, d),
            Err(ShedReason::DeadlineInfeasible { .. })
        ));
    }
}
