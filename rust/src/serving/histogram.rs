//! Lock-free HDR-style latency histogram.
//!
//! The coordinator's original histogram sat behind a `Mutex` — fine for
//! one worker, a contention point the moment every reply on every
//! worker records three durations. This one is an array of relaxed
//! `AtomicU64` bucket counters: `record` is wait-free (one `fetch_add`
//! per counter touched), readers take a [`snapshot`](AtomicHistogram::snapshot)
//! and compute percentiles offline.
//!
//! Bucketing is the HDR scheme: within each power of two the range is
//! cut into `2^SUB_BITS = 16` linear sub-buckets, so the relative
//! quantization error is bounded by `2^-SUB_BITS` (6.25 %) at every
//! magnitude — equally sharp at 3 µs and 3 s, unlike fixed-width or
//! purely geometric buckets. Values are nanoseconds; the table spans
//! 1 ns to ~2^40 ns (≈ 18 min), everything above clamps into the last
//! bucket.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Linear sub-buckets per power of two, as a bit count.
pub const SUB_BITS: u32 = 4;
const SUB: usize = 1 << SUB_BITS; // 16
/// Powers of two above the linear range covered before clamping.
const OCTAVES: usize = 36;
/// Total bucket count.
pub const BUCKETS: usize = SUB * (OCTAVES + 1);

/// Bucket index for a nanosecond value (see module docs for the scheme).
fn bucket_index(ns: u64) -> usize {
    let v = ns.max(1);
    let msb = 63 - v.leading_zeros();
    let idx = if msb < SUB_BITS {
        v as usize
    } else {
        let sub = ((v >> (msb - SUB_BITS)) as usize) - SUB;
        ((msb - SUB_BITS + 1) as usize) * SUB + sub
    };
    idx.min(BUCKETS - 1)
}

/// Upper bound (inclusive, ns) of bucket `idx` — the value percentiles
/// report, so quantization always errs pessimistic (never under-reports
/// a latency).
fn bucket_bound(idx: usize) -> u64 {
    if idx < SUB {
        idx as u64
    } else {
        let octave = idx / SUB; // >= 1
        let sub = (idx % SUB) as u64;
        ((SUB as u64 + sub + 1) << (octave - 1)) - 1
    }
}

/// Wait-free concurrent histogram of nanosecond durations.
pub struct AtomicHistogram {
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl AtomicHistogram {
    pub fn new() -> AtomicHistogram {
        AtomicHistogram {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    /// Record one duration. Wait-free; relaxed ordering (the counters
    /// are monotone statistics, not synchronization).
    pub fn record(&self, ns: u64) {
        self.counts[bucket_index(ns)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum_ns.fetch_add(ns, Relaxed);
        self.max_ns.fetch_max(ns, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    /// A point-in-time copy for offline percentile math. Concurrent
    /// recording makes the copy *approximately* consistent (bucket
    /// counts may straddle an in-flight record) — fine for monitoring,
    /// which is the only consumer.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            counts: self.counts.iter().map(|c| c.load(Relaxed)).collect(),
            count: self.count.load(Relaxed),
            sum_ns: self.sum_ns.load(Relaxed),
            max_ns: self.max_ns.load(Relaxed),
        }
    }
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        AtomicHistogram::new()
    }
}

/// An owned copy of a histogram: mergeable across workers, subtractable
/// against a baseline (interval measurements), percentile queries.
#[derive(Debug, Clone)]
pub struct HistSnapshot {
    counts: Vec<u64>,
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl HistSnapshot {
    pub fn empty() -> HistSnapshot {
        HistSnapshot {
            counts: vec![0; BUCKETS],
            count: 0,
            sum_ns: 0,
            max_ns: 0,
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Fold another worker's snapshot into this one.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// `self − baseline`, bucket-wise: the distribution of everything
    /// recorded *after* the baseline was taken. The load generator uses
    /// this for per-sweep-point percentiles. `max_ns` keeps the later
    /// snapshot's value (an upper bound for the interval).
    pub fn diff(&self, baseline: &HistSnapshot) -> HistSnapshot {
        HistSnapshot {
            counts: self
                .counts
                .iter()
                .zip(&baseline.counts)
                .map(|(a, b)| a.saturating_sub(*b))
                .collect(),
            count: self.count.saturating_sub(baseline.count),
            sum_ns: self.sum_ns.saturating_sub(baseline.sum_ns),
            max_ns: self.max_ns,
        }
    }

    /// Percentile in ns (`p` in 0..=100). Reports the upper bound of
    /// the bucket holding the target rank — pessimistic by at most
    /// `2^-SUB_BITS`. Empty snapshot → 0.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_bound(idx);
            }
        }
        bucket_bound(BUCKETS - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_continuous() {
        // Every value maps to a bucket whose bound is >= the value
        // (pessimistic), and indices never decrease with the value.
        let mut prev = 0usize;
        for v in 1..4096u64 {
            let idx = bucket_index(v);
            assert!(idx >= prev, "index regressed at {v}");
            assert!(bucket_bound(idx) >= v, "bound({idx}) < {v}");
            prev = idx;
        }
        // Spot-check the bound error stays within 1/16 at large values.
        for v in [1u64 << 20, (1u64 << 30) + 12345, 7_777_777_777] {
            let b = bucket_bound(bucket_index(v));
            assert!(b >= v);
            assert!((b - v) as f64 <= v as f64 / 16.0 + 1.0, "v={v} bound={b}");
        }
    }

    #[test]
    fn huge_values_clamp_into_last_bucket() {
        let idx = bucket_index(u64::MAX);
        assert_eq!(idx, BUCKETS - 1);
        let h = AtomicHistogram::new();
        h.record(u64::MAX);
        assert_eq!(h.snapshot().percentile(99.0), bucket_bound(BUCKETS - 1));
    }

    #[test]
    fn percentiles_match_exact_within_bucket_error() {
        let h = AtomicHistogram::new();
        for i in 1..=1000u64 {
            h.record(i * 1000); // 1µs..1ms uniform
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 1000);
        let p50 = s.percentile(50.0) as f64;
        let p99 = s.percentile(99.0) as f64;
        assert!((p50 - 500_000.0).abs() / 500_000.0 < 0.10, "p50={p50}");
        assert!((p99 - 990_000.0).abs() / 990_000.0 < 0.10, "p99={p99}");
        assert!(s.percentile(50.0) <= s.percentile(90.0));
        assert!(s.percentile(90.0) <= s.percentile(99.0));
        assert!(s.percentile(99.0) <= s.max_ns());
        assert!((s.mean_ns() - 500_500.0).abs() < 1.0);
    }

    #[test]
    fn merge_and_diff_are_inverse_ish() {
        let a = AtomicHistogram::new();
        let b = AtomicHistogram::new();
        for i in 0..100u64 {
            a.record(1000 + i);
            b.record(2_000_000 + i);
        }
        let sa = a.snapshot();
        let sb = b.snapshot();
        let mut merged = HistSnapshot::empty();
        merged.merge(&sa);
        merged.merge(&sb);
        assert_eq!(merged.count(), 200);
        let back = merged.diff(&sa);
        assert_eq!(back.count(), 100);
        // Everything left is from b's magnitude.
        assert!(back.percentile(50.0) >= 1_000_000);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let h = std::sync::Arc::new(AtomicHistogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = std::sync::Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record((t + 1) * 1000 + i % 7);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
        assert_eq!(h.snapshot().count(), 40_000);
    }
}
