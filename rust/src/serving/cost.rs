//! Per-pinned-batch compute estimates for the scheduler.
//!
//! The admission controller and the adaptive batcher both need "how
//! long will a batch of n take?" answered in nanoseconds, cheaply and
//! from any thread. Estimates are seeded from the planner cost model at
//! engine build ([`Engine::batch_cost_estimates`]) — the same
//! calibrated coefficients that rank algorithms — and refined online by
//! an EWMA of the forward times workers actually measure, so the
//! scheduler's notion of compute tracks the host it is running on, not
//! the model's abstract-ns units.

use crate::engine::Engine;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// EWMA weight of a new measurement (old estimates decay with 1 − α).
const ALPHA: f64 = 0.25;

/// Thread-safe per-pinned-batch compute estimates (f64 ns stored as
/// bits in `AtomicU64` — updates are racy-by-design lost-update
/// tolerant: the value is a smoothed estimate, not an invariant).
pub struct BatchCosts {
    /// Pinned batch sizes, ascending (mirrors
    /// [`Engine::pinned_batch_sizes`]).
    sizes: Vec<usize>,
    /// Estimated forward ns per batch, same order as `sizes`.
    est_ns: Vec<AtomicU64>,
}

impl BatchCosts {
    /// Seed from explicit `(batch, ns)` pairs (ascending batch order is
    /// established here).
    pub fn new(seed: &[(usize, f64)]) -> BatchCosts {
        let mut pairs: Vec<(usize, f64)> = seed.to_vec();
        pairs.sort_by_key(|&(b, _)| b);
        pairs.dedup_by_key(|&mut (b, _)| b);
        if pairs.is_empty() {
            pairs.push((1, 0.0));
        }
        BatchCosts {
            sizes: pairs.iter().map(|&(b, _)| b).collect(),
            est_ns: pairs
                .iter()
                .map(|&(_, ns)| AtomicU64::new(ns.max(0.0).to_bits()))
                .collect(),
        }
    }

    /// Seed from an engine's build-time cost-model estimates.
    pub fn from_engine(engine: &Engine) -> BatchCosts {
        BatchCosts::new(engine.batch_cost_estimates())
    }

    /// Pinned batch sizes, ascending.
    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// The largest pinned batch — the adaptive batcher's collect cap.
    pub fn largest(&self) -> usize {
        *self.sizes.last().expect("sizes is non-empty")
    }

    /// The smallest pinned batch that covers `n` requests, or the
    /// largest pinned size when `n` overflows every pinned shape.
    pub fn covering(&self, n: usize) -> usize {
        self.sizes
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.largest())
    }

    /// Estimated forward ns for a batch of `n`: exact for pinned sizes,
    /// linearly scaled from the nearest pinned size otherwise.
    pub fn estimate_ns(&self, n: usize) -> f64 {
        let n = n.max(1);
        if let Some(i) = self.sizes.iter().position(|&b| b == n) {
            return f64::from_bits(self.est_ns[i].load(Relaxed));
        }
        let (i, &b) = self
            .sizes
            .iter()
            .enumerate()
            .min_by_key(|&(_, &b)| b.abs_diff(n))
            .expect("sizes is non-empty");
        f64::from_bits(self.est_ns[i].load(Relaxed)) * n as f64 / b.max(1) as f64
    }

    /// Fold a measured forward time for an exact pinned batch into the
    /// estimate (EWMA; measurements for non-pinned sizes are ignored —
    /// they only occur on the lazy-plan slow path). A zero seed (e.g. a
    /// conv-free model the cost model prices at 0) is replaced outright
    /// by the first measurement.
    pub fn observe(&self, n: usize, measured_ns: f64) {
        if !(measured_ns.is_finite() && measured_ns >= 0.0) {
            return;
        }
        if let Some(i) = self.sizes.iter().position(|&b| b == n) {
            let old = f64::from_bits(self.est_ns[i].load(Relaxed));
            let new = if old == 0.0 {
                measured_ns
            } else {
                (1.0 - ALPHA) * old + ALPHA * measured_ns
            };
            self.est_ns[i].store(new.to_bits(), Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_sorts_and_dedups() {
        let c = BatchCosts::new(&[(8, 800.0), (1, 100.0), (8, 999.0)]);
        assert_eq!(c.sizes(), &[1, 8]);
        assert_eq!(c.largest(), 8);
        assert!((c.estimate_ns(1) - 100.0).abs() < 1e-9);
        assert!((c.estimate_ns(8) - 800.0).abs() < 1e-9);
    }

    #[test]
    fn covering_picks_smallest_fit() {
        let c = BatchCosts::new(&[(1, 1.0), (4, 4.0), (8, 8.0)]);
        assert_eq!(c.covering(1), 1);
        assert_eq!(c.covering(3), 4);
        assert_eq!(c.covering(8), 8);
        assert_eq!(c.covering(50), 8, "overflow clamps to largest");
    }

    #[test]
    fn estimate_scales_from_nearest_pinned() {
        let c = BatchCosts::new(&[(1, 100.0), (8, 640.0)]);
        // 2 is nearest to 1: 100 * 2/1.
        assert!((c.estimate_ns(2) - 200.0).abs() < 1e-9);
        // 6 is nearest to 8: 640 * 6/8.
        assert!((c.estimate_ns(6) - 480.0).abs() < 1e-9);
        assert!((c.estimate_ns(16) - 1280.0).abs() < 1e-9);
    }

    #[test]
    fn observe_ewma_converges_and_replaces_zero_seed() {
        let c = BatchCosts::new(&[(1, 0.0)]);
        c.observe(1, 1000.0);
        assert!((c.estimate_ns(1) - 1000.0).abs() < 1e-9, "zero seed replaced");
        for _ in 0..64 {
            c.observe(1, 2000.0);
        }
        assert!((c.estimate_ns(1) - 2000.0).abs() < 1.0, "EWMA converges");
        // Non-pinned and garbage observations are ignored.
        c.observe(7, 1e12);
        c.observe(1, f64::NAN);
        assert!((c.estimate_ns(1) - 2000.0).abs() < 1.0);
    }
}
