//! Closed-loop and open-loop load generation against a running
//! [`Server`].
//!
//! The two loops answer different questions. A **closed** loop (N
//! clients, each submit-wait-repeat) measures capacity: offered load
//! self-regulates to what the server sustains, so throughput climbs
//! with clients until compute saturates. An **open** loop submits on a
//! fixed schedule regardless of completions — the honest model of
//! internet traffic, and the one that exposes queueing collapse:
//! past saturation, latency and shed rate blow up instead of the
//! throughput figure politely flattening (coordinated omission).
//!
//! Latency percentiles come from the *server-side* per-worker
//! histograms ([`Metrics::raw_snapshot`] diffed against a baseline
//! taken before the run), not from client-side timing — an open-loop
//! client that measures at drain time would overstate tail latency,
//! and a closed-loop one understates offered load.

use crate::coordinator::{Server, ServeError, SubmitError};
use crate::serving::metrics::RawSnapshot;
use std::time::{Duration, Instant};

/// How traffic is offered.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LoadMode {
    /// `clients` concurrent submit-wait loops.
    Closed { clients: usize },
    /// Fixed-rate submission, `rps` requests per second, independent of
    /// completions.
    Open { rps: f64 },
}

/// One load-generation run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadConfig {
    pub mode: LoadMode,
    /// Total requests to offer.
    pub requests: usize,
    /// Per-request deadline: submit time + `slo`. `None` = best-effort.
    pub slo: Option<Duration>,
}

/// Outcome of one run. Counters are client-observed; percentiles and
/// SLO attainment are server-side (histogram diff over the run
/// interval).
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub label: String,
    /// Requests/s actually offered (submitted / wall for closed, the
    /// configured rate for open).
    pub offered_rps: f64,
    pub wall_s: f64,
    pub submitted: u64,
    /// Requests answered with a prediction.
    pub served: u64,
    /// Requests shed, at submit or at dispatch.
    pub shed: u64,
    /// Non-shed failures (engine errors, disconnects) — 0 in a healthy
    /// run.
    pub errors: u64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub throughput_rps: f64,
    pub shed_rate: f64,
    pub slo_attainment: f64,
}

/// Tally of one client loop's outcomes.
#[derive(Default, Clone, Copy)]
struct Tally {
    submitted: u64,
    served: u64,
    shed: u64,
    errors: u64,
}

impl Tally {
    fn absorb(&mut self, o: Tally) {
        self.submitted += o.submitted;
        self.served += o.served;
        self.shed += o.shed;
        self.errors += o.errors;
    }
}

/// Drive `cfg` worth of traffic at `server` and report.
pub fn run(server: &Server, sample: &[f32], cfg: &LoadConfig) -> LoadReport {
    let metrics = server.metrics();
    let baseline = metrics.raw_snapshot();
    let t0 = Instant::now();
    let tally = match cfg.mode {
        LoadMode::Closed { clients } => run_closed(server, sample, cfg, clients.max(1)),
        LoadMode::Open { rps } => run_open(server, sample, cfg, rps),
    };
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    let interval = metrics.raw_snapshot().diff(&baseline);
    report(cfg, tally, wall_s, &interval)
}

fn run_closed(server: &Server, sample: &[f32], cfg: &LoadConfig, clients: usize) -> Tally {
    let mut total = Tally::default();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|i| {
                // Spread the remainder so exactly cfg.requests go out.
                let n = cfg.requests / clients + usize::from(i < cfg.requests % clients);
                let client = server.client();
                scope.spawn(move || {
                    let mut t = Tally::default();
                    for _ in 0..n {
                        t.submitted += 1;
                        let deadline = cfg.slo.map(|s| Instant::now() + s);
                        match client.submit_with_deadline(sample.to_vec(), deadline) {
                            Ok(rx) => absorb_reply(&mut t, rx.recv()),
                            Err(SubmitError::Shed(_)) => t.shed += 1,
                            Err(_) => t.errors += 1,
                        }
                    }
                    t
                })
            })
            .collect();
        for h in handles {
            total.absorb(h.join().expect("load client panicked"));
        }
    });
    total
}

fn run_open(server: &Server, sample: &[f32], cfg: &LoadConfig, rps: f64) -> Tally {
    let mut t = Tally::default();
    let client = server.client();
    let interval = Duration::from_secs_f64(1.0 / rps.max(1e-3));
    let mut next = Instant::now();
    let mut pending = Vec::with_capacity(cfg.requests);
    for _ in 0..cfg.requests {
        let now = Instant::now();
        if next > now {
            std::thread::sleep(next - now);
        }
        next += interval;
        t.submitted += 1;
        let deadline = cfg.slo.map(|s| Instant::now() + s);
        match client.submit_with_deadline(sample.to_vec(), deadline) {
            Ok(rx) => pending.push(rx),
            Err(SubmitError::Shed(_)) => t.shed += 1,
            Err(_) => t.errors += 1,
        }
    }
    for rx in pending {
        absorb_reply(&mut t, rx.recv());
    }
    t
}

fn absorb_reply(
    t: &mut Tally,
    reply: Result<crate::coordinator::Response, std::sync::mpsc::RecvError>,
) {
    match reply {
        Ok(resp) => match resp.result {
            Ok(_) => t.served += 1,
            Err(ServeError::Shed(_)) => t.shed += 1,
            Err(ServeError::Engine(_)) => t.errors += 1,
        },
        Err(_) => t.errors += 1,
    }
}

fn report(cfg: &LoadConfig, t: Tally, wall_s: f64, interval: &RawSnapshot) -> LoadReport {
    let (label, offered_rps) = match cfg.mode {
        LoadMode::Closed { clients } => {
            (format!("closed-{clients}"), t.submitted as f64 / wall_s)
        }
        LoadMode::Open { rps } => (format!("open-{rps:.0}"), rps),
    };
    let deadlined = interval.on_time + interval.late;
    LoadReport {
        label,
        offered_rps,
        wall_s,
        submitted: t.submitted,
        served: t.served,
        shed: t.shed,
        errors: t.errors,
        p50_ms: interval.total.percentile(50.0) as f64 / 1e6,
        p90_ms: interval.total.percentile(90.0) as f64 / 1e6,
        p99_ms: interval.total.percentile(99.0) as f64 / 1e6,
        throughput_rps: t.served as f64 / wall_s,
        shed_rate: if t.submitted == 0 {
            0.0
        } else {
            t.shed as f64 / t.submitted as f64
        },
        slo_attainment: if deadlined == 0 {
            1.0
        } else {
            interval.on_time as f64 / deadlined as f64
        },
    }
}

/// Render a sweep of [`LoadReport`]s as the `BENCH_serving.json`
/// document. Shared by `benches/serving.rs` and the seed-trajectory
/// test in `serving_slo.rs`, so the file's schema has exactly one
/// producer.
pub fn render_json(
    slo_ms: f64,
    workers: usize,
    pinned: &[usize],
    reports: &[LoadReport],
) -> String {
    let mut json = format!(
        "{{\"bench\":\"serving\",\"slo_ms\":{slo_ms},\"workers\":{workers},\"pinned\":["
    );
    for (i, p) in pinned.iter().enumerate() {
        if i > 0 {
            json.push(',');
        }
        json.push_str(&p.to_string());
    }
    json.push_str("],\"results\":[");
    for (i, r) in reports.iter().enumerate() {
        if i > 0 {
            json.push(',');
        }
        json.push_str(&format!(
            "{{\"label\":\"{}\",\"offered_rps\":{:.2},\"throughput_rps\":{:.2},\
             \"p50_ms\":{:.4},\"p90_ms\":{:.4},\"p99_ms\":{:.4},\
             \"shed_rate\":{:.4},\"slo_attainment\":{:.4},\
             \"submitted\":{},\"served\":{},\"shed\":{},\"errors\":{},\
             \"wall_s\":{:.3}}}",
            r.label,
            r.offered_rps,
            r.throughput_rps,
            r.p50_ms,
            r.p90_ms,
            r.p99_ms,
            r.shed_rate,
            r.slo_attainment,
            r.submitted,
            r.served,
            r.shed,
            r.errors,
            r.wall_s
        ));
    }
    json.push_str("]}\n");
    json
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::AlgoKind;
    use crate::coordinator::ServerConfig;
    use crate::engine::Engine;
    use crate::model::{Layer, Model};
    use crate::tensor::{Kernel, KernelShape};
    use crate::util::Rng;
    use std::sync::Arc;

    fn tiny_server() -> Server {
        let mut rng = Rng::new(11);
        let model = Model::new(
            "loadgen-test",
            (6, 6, 1),
            vec![
                Layer::Conv {
                    kernel: Kernel::random(KernelShape::new(3, 3, 1, 2), &mut rng),
                    bias: vec![0.0; 2],
                    sh: 1,
                    sw: 1,
                    ph: 1,
                    pw: 1,
                },
                Layer::Relu,
            ],
        );
        let engine = Arc::new(
            Engine::builder(model)
                .algo_override(0, AlgoKind::Mec)
                .pin_batch_sizes(&[1, 2, 4])
                .build()
                .expect("tiny model builds"),
        );
        Server::start(engine, ServerConfig::default()).expect("server starts")
    }

    #[test]
    fn closed_loop_serves_everything_under_lax_slo() {
        let server = tiny_server();
        let report = run(
            &server,
            &[0.3; 36],
            &LoadConfig {
                mode: LoadMode::Closed { clients: 2 },
                requests: 9,
                slo: Some(Duration::from_secs(30)),
            },
        );
        server.shutdown();
        assert_eq!(report.submitted, 9, "remainder split covers all requests");
        assert_eq!(report.served, 9);
        assert_eq!(report.shed, 0);
        assert_eq!(report.errors, 0);
        assert!(report.throughput_rps > 0.0);
        assert!(report.p50_ms > 0.0 && report.p50_ms <= report.p99_ms);
        assert!((report.slo_attainment - 1.0).abs() < 1e-9);
        assert_eq!(report.label, "closed-2");
    }

    #[test]
    fn open_loop_paces_and_drains() {
        let server = tiny_server();
        let report = run(
            &server,
            &[0.1; 36],
            &LoadConfig {
                mode: LoadMode::Open { rps: 200.0 },
                requests: 10,
                slo: None,
            },
        );
        server.shutdown();
        assert_eq!(report.submitted, 10);
        assert_eq!(report.served + report.shed + report.errors, 10);
        assert_eq!(report.errors, 0);
        // Pacing: 10 requests at 200/s take at least ~45 ms of schedule.
        assert!(report.wall_s >= 0.040, "wall={}", report.wall_s);
        assert_eq!(report.label, "open-200");
    }

    #[test]
    fn render_json_emits_every_report() {
        let r = LoadReport {
            label: "closed-2".to_string(),
            offered_rps: 100.0,
            wall_s: 1.0,
            submitted: 100,
            served: 98,
            shed: 2,
            errors: 0,
            p50_ms: 1.5,
            p90_ms: 2.5,
            p99_ms: 4.0,
            throughput_rps: 98.0,
            shed_rate: 0.02,
            slo_attainment: 0.98,
        };
        let json = render_json(50.0, 2, &[1, 2, 4], &[r.clone(), r]);
        assert!(json.starts_with("{\"bench\":\"serving\""));
        assert_eq!(json.matches("\"label\":\"closed-2\"").count(), 2);
        assert!(json.contains("\"pinned\":[1,2,4]"));
        assert!(json.contains("\"slo_ms\":50"));
        assert!(json.ends_with("]}\n"));
    }
}
