//! Deadline-driven adaptive batching.
//!
//! The static batcher's trade-off (wait `max_delay`, cap at
//! `max_batch`) ignores what the requests themselves need. This one
//! collects until
//! `min(batch_full, oldest_deadline − est_compute − margin)`: a batch
//! under deadline pressure dispatches exactly early enough to make its
//! tightest deadline, while deadline-free traffic still gets the full
//! collect window. Dispatch shapes are the engine's pinned batch sizes
//! only — [`split_into_pinned`] cuts an oversized collect into
//! padding-free pinned chunks (largest-first), so steady-state serving
//! never touches a lazily-planned geometry and stays zero-alloc.
//!
//! The decision logic ([`dispatch_deadline`], [`infeasible`],
//! [`split_into_pinned`]) is pure functions over explicit `Instant`s —
//! unit-tested with a virtual clock, no sleeps.

use super::cost::BatchCosts;
use crate::coordinator::queue::RequestQueue;
use crate::coordinator::Request;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Scheduling knobs for the adaptive batcher.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SloPolicy {
    /// Default latency objective applied at submit (requests without an
    /// explicit deadline get `now + slo`); `None` = no deadlines.
    pub slo: Option<Duration>,
    /// Collect window when no deadline presses (the static batcher's
    /// `max_delay` role).
    pub max_wait: Duration,
    /// Safety margin subtracted from deadline-driven dispatch times
    /// (scheduling jitter, reply-path cost).
    pub margin: Duration,
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy {
            slo: None,
            max_wait: Duration::from_millis(2),
            margin: Duration::from_micros(200),
        }
    }
}

/// Cut `n` collected requests into padding-free pinned batch shapes,
/// largest-first (`sizes` ascending, as
/// [`Engine::pinned_batch_sizes`](crate::engine::Engine::pinned_batch_sizes)
/// returns them). Greedy is optimal for the chain-of-multiples sizes
/// serving pins in practice (1,2,4,8,…); for arbitrary sets it is still
/// correct (a unit batch is always pinned — the server enforces that at
/// start) and at worst dispatches a few extra small chunks.
pub fn split_into_pinned(n: usize, sizes: &[usize]) -> Vec<usize> {
    let mut out = Vec::new();
    let mut left = n;
    while left > 0 {
        let take = sizes
            .iter()
            .rev()
            .copied()
            .find(|&b| b <= left)
            .unwrap_or_else(|| sizes.first().copied().unwrap_or(1));
        // A smallest-pinned size larger than the remainder would pad;
        // the server rejects engines without a unit pin, so `take <=
        // left` always holds here. Defend anyway (degenerate sizes in
        // tests): dispatch the remainder as-is rather than loop.
        if take > left {
            out.push(left);
            break;
        }
        out.push(take);
        left -= take;
    }
    out
}

/// The earliest deadline in a batch (`None` when no request carries
/// one).
pub fn earliest_deadline(batch: &[Request]) -> Option<Instant> {
    batch.iter().filter_map(|r| r.deadline).min()
}

/// When to stop collecting and dispatch: the earlier of the collect
/// window (`collect_start + max_wait`) and the deadline-driven point
/// (`oldest_deadline − est_compute − margin`). A deadline already too
/// close clamps to `collect_start` (dispatch immediately).
pub fn dispatch_deadline(
    collect_start: Instant,
    oldest: Option<Instant>,
    est_compute: Duration,
    policy: &SloPolicy,
) -> Instant {
    let window = collect_start + policy.max_wait;
    match oldest {
        None => window,
        Some(d) => {
            let driven = d
                .checked_sub(est_compute)
                .and_then(|t| t.checked_sub(policy.margin))
                .unwrap_or(collect_start);
            window.min(driven.max(collect_start))
        }
    }
}

/// Is a request already doomed at dispatch time? (`now + est_compute`
/// past the deadline ⇒ running it wastes compute that on-time requests
/// could use — shed with a typed reason instead.)
pub fn infeasible(now: Instant, deadline: Option<Instant>, est_compute: Duration) -> bool {
    match deadline {
        None => false,
        Some(d) => now + est_compute > d,
    }
}

/// Pulls deadline-aware batches off the coordinator queue. One per
/// worker thread; the shared [`BatchCosts`] supplies compute estimates.
pub struct AdaptiveBatcher<'q> {
    queue: &'q RequestQueue,
    costs: Arc<BatchCosts>,
    policy: SloPolicy,
}

impl<'q> AdaptiveBatcher<'q> {
    pub fn new(
        queue: &'q RequestQueue,
        costs: Arc<BatchCosts>,
        policy: SloPolicy,
    ) -> AdaptiveBatcher<'q> {
        AdaptiveBatcher { queue, costs, policy }
    }

    pub fn policy(&self) -> &SloPolicy {
        &self.policy
    }

    /// Form the next batch: long-poll for the first request(s), then
    /// collect until the batch is full (largest pinned size) or the
    /// dispatch deadline — whichever comes first. `None` = queue closed
    /// and drained.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let max = self.costs.largest();
        let mut batch = loop {
            match self
                .queue
                .pop_up_to(max, Instant::now() + Duration::from_millis(50))
            {
                None => return None,
                Some(v) if v.is_empty() => continue,
                Some(v) => break v,
            }
        };
        let collect_start = Instant::now();
        while batch.len() < max {
            // Estimate compute for the pinned shape the batch would
            // dispatch as right now — the figure the tightest deadline
            // must leave room for.
            let est = Duration::from_nanos(
                self.costs.estimate_ns(self.costs.covering(batch.len())).max(0.0) as u64,
            );
            let dd = dispatch_deadline(collect_start, earliest_deadline(&batch), est, &self.policy);
            if Instant::now() >= dd {
                break;
            }
            match self.queue.pop_up_to(max - batch.len(), dd) {
                // Closed: dispatch what we have; the next call returns None.
                None => break,
                Some(v) if v.is_empty() => break, // dispatch deadline hit
                Some(mut v) => batch.append(&mut v),
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64, deadline: Option<Instant>) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id,
            sample: vec![],
            enqueued_at: Instant::now(),
            deadline,
            reply: tx,
        }
    }

    fn costs() -> Arc<BatchCosts> {
        Arc::new(BatchCosts::new(&[
            (1, 1_000_000.0),
            (4, 2_500_000.0),
            (8, 4_000_000.0),
        ]))
    }

    #[test]
    fn split_is_padding_free_and_largest_first() {
        let sizes = [1usize, 2, 4, 8];
        assert_eq!(split_into_pinned(8, &sizes), vec![8]);
        assert_eq!(split_into_pinned(13, &sizes), vec![8, 4, 1]);
        assert_eq!(split_into_pinned(3, &sizes), vec![2, 1]);
        assert_eq!(split_into_pinned(1, &sizes), vec![1]);
        assert_eq!(split_into_pinned(0, &sizes), Vec::<usize>::new());
        // Sparse pins still sum exactly (never pad).
        assert_eq!(split_into_pinned(7, &[1, 8]), vec![1; 7]);
        for n in 1..40 {
            let total: usize = split_into_pinned(n, &sizes).iter().sum();
            assert_eq!(total, n, "split must cover exactly {n}");
        }
    }

    // -- virtual-clock tests of the dispatch decision -------------------

    #[test]
    fn deadline_triggers_early_dispatch_virtual_clock() {
        let policy = SloPolicy {
            slo: None,
            max_wait: Duration::from_millis(100),
            margin: Duration::from_micros(500),
        };
        let t0 = Instant::now();
        let est = Duration::from_millis(4);
        // No deadline: the full collect window applies.
        assert_eq!(
            dispatch_deadline(t0, None, est, &policy),
            t0 + Duration::from_millis(100)
        );
        // Deadline at t0+10ms: dispatch at 10ms − 4ms − 0.5ms = 5.5ms,
        // well before the window.
        let dd = dispatch_deadline(t0, Some(t0 + Duration::from_millis(10)), est, &policy);
        assert_eq!(dd, t0 + Duration::from_micros(5_500));
        // A deadline tighter than est_compute clamps to "now" (dispatch
        // immediately, don't wait at all).
        let dd = dispatch_deadline(t0, Some(t0 + Duration::from_millis(2)), est, &policy);
        assert_eq!(dd, t0);
        // A lax deadline never extends past the collect window.
        let dd = dispatch_deadline(t0, Some(t0 + Duration::from_secs(10)), est, &policy);
        assert_eq!(dd, t0 + Duration::from_millis(100));
    }

    #[test]
    fn infeasible_is_exactly_the_deadline_test_virtual_clock() {
        let t0 = Instant::now();
        let est = Duration::from_millis(4);
        assert!(!infeasible(t0, None, est));
        assert!(!infeasible(t0, Some(t0 + Duration::from_millis(5)), est));
        assert!(infeasible(t0, Some(t0 + Duration::from_millis(3)), est));
        assert!(infeasible(t0, Some(t0), est));
    }

    #[test]
    fn earliest_deadline_ignores_none() {
        let t0 = Instant::now();
        let batch = vec![
            req(0, None),
            req(1, Some(t0 + Duration::from_millis(9))),
            req(2, Some(t0 + Duration::from_millis(3))),
        ];
        assert_eq!(earliest_deadline(&batch), Some(t0 + Duration::from_millis(3)));
        assert_eq!(earliest_deadline(&[req(0, None)]), None);
    }

    // -- driver tests against a real queue ------------------------------

    #[test]
    fn full_batch_dispatches_immediately() {
        let q = RequestQueue::new(64);
        for i in 0..10 {
            q.push(req(i, None)).unwrap();
        }
        let b = AdaptiveBatcher::new(
            &q,
            costs(),
            SloPolicy { max_wait: Duration::from_secs(10), ..SloPolicy::default() },
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 8, "largest pinned size caps the batch");
        assert_eq!(batch[0].id, 0, "FIFO preserved");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "a full batch must not wait out the collect window"
        );
    }

    #[test]
    fn tight_deadline_cuts_the_collect_window_short() {
        let q = RequestQueue::new(8);
        // One request whose deadline leaves no room to wait (est compute
        // for batch 1 is 1 ms, margin 200 µs).
        q.push(req(0, Some(Instant::now() + Duration::from_millis(2)))).unwrap();
        let b = AdaptiveBatcher::new(
            &q,
            costs(),
            SloPolicy { max_wait: Duration::from_secs(30), ..SloPolicy::default() },
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "deadline-driven dispatch must beat the 30 s collect window"
        );
    }

    #[test]
    fn closed_queue_ends_batching() {
        let q = RequestQueue::new(8);
        q.close();
        let b = AdaptiveBatcher::new(&q, costs(), SloPolicy::default());
        assert!(b.next_batch().is_none());
    }
}
