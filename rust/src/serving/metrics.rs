//! Per-worker latency recording and mergeable serving snapshots.
//!
//! Each worker thread owns a [`WorkerMetrics`]: three lock-free
//! histograms (queue wait, compute, total submit→reply) plus
//! served/on-time/late counters. Nothing is shared between workers on
//! the record path — a reply costs a handful of relaxed atomic adds.
//! Readers merge all workers into a [`RawSnapshot`] (subtractable
//! against a baseline for interval measurements — the load generator's
//! per-sweep-point percentiles) and render a [`ServingSnapshot`] with
//! p50/p90/p99 figures for humans and the bench JSON.

use super::histogram::{AtomicHistogram, HistSnapshot};
use crate::util::stats::fmt_ns;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Duration;

/// One worker thread's latency recording surface. Lock-free; the worker
/// is the only writer, snapshot readers race benignly.
pub struct WorkerMetrics {
    /// Submit → dispatch (time spent waiting in the queue + collect).
    pub queue_wait: AtomicHistogram,
    /// Dispatch → forward done, amortized per request (batch forward
    /// time is attributed to every request in the batch — it is the
    /// latency each of them observed).
    pub compute: AtomicHistogram,
    /// Submit → reply, the figure SLOs are written against.
    pub total: AtomicHistogram,
    pub served: AtomicU64,
    /// Served with a deadline, reply beat it.
    pub on_time: AtomicU64,
    /// Served with a deadline, reply missed it (admitted but late —
    /// distinct from shed, which never ran).
    pub late: AtomicU64,
}

impl WorkerMetrics {
    pub fn new() -> WorkerMetrics {
        WorkerMetrics {
            queue_wait: AtomicHistogram::new(),
            compute: AtomicHistogram::new(),
            total: AtomicHistogram::new(),
            served: AtomicU64::new(0),
            on_time: AtomicU64::new(0),
            late: AtomicU64::new(0),
        }
    }

    /// Record one served request. `met_deadline` is `None` for
    /// deadline-free requests (they count toward neither on-time nor
    /// late).
    pub fn record_served(
        &self,
        queue_wait: Duration,
        compute: Duration,
        total: Duration,
        met_deadline: Option<bool>,
    ) {
        self.queue_wait.record(queue_wait.as_nanos() as u64);
        self.compute.record(compute.as_nanos() as u64);
        self.total.record(total.as_nanos() as u64);
        self.served.fetch_add(1, Relaxed);
        match met_deadline {
            Some(true) => {
                self.on_time.fetch_add(1, Relaxed);
            }
            Some(false) => {
                self.late.fetch_add(1, Relaxed);
            }
            None => {}
        }
    }

    pub fn snapshot(&self) -> RawSnapshot {
        RawSnapshot {
            queue_wait: self.queue_wait.snapshot(),
            compute: self.compute.snapshot(),
            total: self.total.snapshot(),
            served: self.served.load(Relaxed),
            on_time: self.on_time.load(Relaxed),
            late: self.late.load(Relaxed),
            shed_queue_full: 0,
            shed_deadline: 0,
        }
    }
}

impl Default for WorkerMetrics {
    fn default() -> Self {
        WorkerMetrics::new()
    }
}

/// Full-resolution serving state: merged worker histograms plus
/// counters. Subtract a baseline with [`diff`](RawSnapshot::diff) to
/// measure an interval; summarize with
/// [`ServingSnapshot::from_raw`].
#[derive(Debug, Clone)]
pub struct RawSnapshot {
    pub queue_wait: HistSnapshot,
    pub compute: HistSnapshot,
    pub total: HistSnapshot,
    pub served: u64,
    pub on_time: u64,
    pub late: u64,
    pub shed_queue_full: u64,
    pub shed_deadline: u64,
}

impl RawSnapshot {
    pub fn empty() -> RawSnapshot {
        RawSnapshot {
            queue_wait: HistSnapshot::empty(),
            compute: HistSnapshot::empty(),
            total: HistSnapshot::empty(),
            served: 0,
            on_time: 0,
            late: 0,
            shed_queue_full: 0,
            shed_deadline: 0,
        }
    }

    /// Fold another snapshot (typically one worker's) into this one.
    pub fn merge(&mut self, other: &RawSnapshot) {
        self.queue_wait.merge(&other.queue_wait);
        self.compute.merge(&other.compute);
        self.total.merge(&other.total);
        self.served += other.served;
        self.on_time += other.on_time;
        self.late += other.late;
        self.shed_queue_full += other.shed_queue_full;
        self.shed_deadline += other.shed_deadline;
    }

    /// Everything recorded after `baseline` was taken.
    pub fn diff(&self, baseline: &RawSnapshot) -> RawSnapshot {
        RawSnapshot {
            queue_wait: self.queue_wait.diff(&baseline.queue_wait),
            compute: self.compute.diff(&baseline.compute),
            total: self.total.diff(&baseline.total),
            served: self.served.saturating_sub(baseline.served),
            on_time: self.on_time.saturating_sub(baseline.on_time),
            late: self.late.saturating_sub(baseline.late),
            shed_queue_full: self.shed_queue_full.saturating_sub(baseline.shed_queue_full),
            shed_deadline: self.shed_deadline.saturating_sub(baseline.shed_deadline),
        }
    }
}

/// Summary statistics of one latency distribution (ns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Dist {
    pub count: u64,
    pub p50_ns: u64,
    pub p90_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
    pub mean_ns: f64,
}

impl Dist {
    pub fn from_hist(h: &HistSnapshot) -> Dist {
        Dist {
            count: h.count(),
            p50_ns: h.percentile(50.0),
            p90_ns: h.percentile(90.0),
            p99_ns: h.percentile(99.0),
            max_ns: h.max_ns(),
            mean_ns: h.mean_ns(),
        }
    }
}

/// The human/JSON-facing metrics surface: percentile summaries of the
/// three latency components plus served/shed counters and SLO
/// attainment.
#[derive(Debug, Clone)]
pub struct ServingSnapshot {
    pub served: u64,
    pub shed_queue_full: u64,
    pub shed_deadline: u64,
    /// on_time / (on_time + late); 1.0 when no request carried a
    /// deadline (vacuously attained).
    pub slo_attainment: f64,
    pub queue_wait: Dist,
    pub compute: Dist,
    pub total: Dist,
}

impl ServingSnapshot {
    pub fn from_raw(raw: &RawSnapshot) -> ServingSnapshot {
        let deadlined = raw.on_time + raw.late;
        ServingSnapshot {
            served: raw.served,
            shed_queue_full: raw.shed_queue_full,
            shed_deadline: raw.shed_deadline,
            slo_attainment: if deadlined == 0 {
                1.0
            } else {
                raw.on_time as f64 / deadlined as f64
            },
            queue_wait: Dist::from_hist(&raw.queue_wait),
            compute: Dist::from_hist(&raw.compute),
            total: Dist::from_hist(&raw.total),
        }
    }

    /// Render as an aligned table for the CLI.
    pub fn render(&self) -> String {
        let row = |name: &str, d: &Dist| {
            format!(
                "  {name:<11} p50 {:>10}  p90 {:>10}  p99 {:>10}  max {:>10}\n",
                fmt_ns(d.p50_ns as f64),
                fmt_ns(d.p90_ns as f64),
                fmt_ns(d.p99_ns as f64),
                fmt_ns(d.max_ns as f64),
            )
        };
        let mut out = String::new();
        out.push_str("serving metrics\n");
        out.push_str(&format!(
            "  served {}  shed(queue-full) {}  shed(deadline) {}  slo-attainment {:.4}\n",
            self.served, self.shed_queue_full, self.shed_deadline, self.slo_attainment
        ));
        out.push_str(&row("queue-wait", &self.queue_wait));
        out.push_str(&row("compute", &self.compute));
        out.push_str(&row("total", &self.total));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn record_and_snapshot_counts() {
        let w = WorkerMetrics::new();
        w.record_served(ms(1), ms(2), ms(3), Some(true));
        w.record_served(ms(1), ms(2), ms(3), Some(false));
        w.record_served(ms(1), ms(2), ms(3), None);
        let s = w.snapshot();
        assert_eq!(s.served, 3);
        assert_eq!(s.on_time, 1);
        assert_eq!(s.late, 1);
        assert_eq!(s.total.count(), 3);
        assert_eq!(s.queue_wait.count(), 3);
        assert_eq!(s.compute.count(), 3);
    }

    #[test]
    fn merge_and_diff_track_intervals() {
        let a = WorkerMetrics::new();
        a.record_served(ms(1), ms(1), ms(2), Some(true));
        let baseline = a.snapshot();
        a.record_served(ms(1), ms(1), ms(2), Some(false));
        a.record_served(ms(1), ms(1), ms(2), Some(true));
        let interval = a.snapshot().diff(&baseline);
        assert_eq!(interval.served, 2);
        assert_eq!(interval.on_time, 1);
        assert_eq!(interval.late, 1);
        assert_eq!(interval.total.count(), 2);

        let mut merged = RawSnapshot::empty();
        let b = WorkerMetrics::new();
        b.record_served(ms(4), ms(4), ms(8), None);
        merged.merge(&interval);
        merged.merge(&b.snapshot());
        assert_eq!(merged.served, 3);
        assert_eq!(merged.total.count(), 3);
    }

    #[test]
    fn snapshot_summarizes_attainment() {
        let w = WorkerMetrics::new();
        for i in 0..10 {
            w.record_served(ms(1), ms(2), ms(3), Some(i < 9));
        }
        let mut raw = w.snapshot();
        raw.shed_deadline = 5;
        let s = ServingSnapshot::from_raw(&raw);
        assert_eq!(s.served, 10);
        assert_eq!(s.shed_deadline, 5);
        assert!((s.slo_attainment - 0.9).abs() < 1e-9);
        // ~3 ms total latency within the 6.25 % bucket error.
        let p50 = s.total.p50_ns as f64;
        assert!((p50 - 3.0e6).abs() / 3.0e6 < 0.10, "p50={p50}");
        // No deadlines anywhere → vacuous attainment of 1.0.
        let v = ServingSnapshot::from_raw(&WorkerMetrics::new().snapshot());
        assert!((v.slo_attainment - 1.0).abs() < 1e-9);
    }

    #[test]
    fn render_mentions_all_sections() {
        let w = WorkerMetrics::new();
        w.record_served(ms(1), ms(2), ms(3), Some(true));
        let text = ServingSnapshot::from_raw(&w.snapshot()).render();
        for needle in ["served 1", "queue-wait", "compute", "total", "slo-attainment"] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }
}
