//! [`EngineBuilder`] — the one place the whole serving configuration is
//! assembled and validated.
//!
//! `build()` is ordered so that *every* configuration error surfaces
//! before any expensive work: resolve the model, check the knobs, walk
//! the conv geometry validating overrides under the budget/precision,
//! and only then plan + prepack each layer for every pinned batch size.

use super::{Engine, EngineError, LayerPlan};
use crate::conv::{AlgoKind, ConvContext};
use crate::gemm::KernelBackend;
use crate::memory::Budget;
use crate::model::{load_mecw, EvalSet, Model};
use crate::planner::{AutoTuner, Plan, Planner};
use crate::tensor::quant::QParams;
use crate::tensor::{Nhwc, Precision, Tensor};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Cap on samples consumed from a calibration set: activation ranges
/// stabilize quickly, and build time should not scale with eval size.
const MAX_CALIBRATION_SAMPLES: usize = 256;

/// Where [`Engine::builder`] gets its model: an in-memory [`Model`] or a
/// `.mecw` path (loaded at `build()`, failures reported as
/// [`EngineError::ModelLoad`]).
pub enum ModelSource {
    Owned(Model),
    Path(PathBuf),
}

impl From<Model> for ModelSource {
    fn from(m: Model) -> ModelSource {
        ModelSource::Owned(m)
    }
}

impl From<PathBuf> for ModelSource {
    fn from(p: PathBuf) -> ModelSource {
        ModelSource::Path(p)
    }
}

impl From<&Path> for ModelSource {
    fn from(p: &Path) -> ModelSource {
        ModelSource::Path(p.to_path_buf())
    }
}

impl From<&str> for ModelSource {
    fn from(p: &str) -> ModelSource {
        ModelSource::Path(PathBuf::from(p))
    }
}

impl From<String> for ModelSource {
    fn from(p: String) -> ModelSource {
        ModelSource::Path(PathBuf::from(p))
    }
}

/// Builder for an immutable [`Engine`]. Obtain via [`Engine::builder`].
pub struct EngineBuilder {
    source: ModelSource,
    precision: Precision,
    budget: Budget,
    threads: usize,
    pinned: Vec<usize>,
    autotune: bool,
    overrides: Vec<(usize, AlgoKind)>,
    calibration: Option<EvalSet>,
}

impl EngineBuilder {
    pub(crate) fn new(source: ModelSource) -> EngineBuilder {
        EngineBuilder {
            source,
            precision: Precision::F32,
            budget: Budget::unlimited(),
            threads: 1,
            pinned: vec![1],
            autotune: false,
            overrides: Vec::new(),
            calibration: None,
        }
    }

    /// Execution precision (default [`Precision::F32`]). Under
    /// [`Precision::Q16`] the planner excludes Winograd/FFT; a q16
    /// engine with a Winograd override fails `build()`.
    pub fn precision(mut self, p: Precision) -> EngineBuilder {
        self.precision = p;
        self
    }

    /// Workspace budget the planner selects under (default unlimited).
    pub fn budget(mut self, b: Budget) -> EngineBuilder {
        self.budget = b;
        self
    }

    /// Worker threads per convolution call (default 1, the paper's
    /// Mobile platform). Zero is a configuration error.
    pub fn threads(mut self, t: usize) -> EngineBuilder {
        self.threads = t;
        self
    }

    /// Batch sizes to plan + prepack eagerly (default `[1]`). Algorithms
    /// are chosen on the largest; the session arena is sized at the max
    /// over all of them. Other batch sizes still work — their plans
    /// build lazily on first sight, sharing the kernel prepacks. At most
    /// [`MAX_CACHED_GEOMETRIES_PER_LAYER`](crate::model::MAX_CACHED_GEOMETRIES_PER_LAYER)
    /// distinct sizes can be pinned (the per-layer plan-cache bound);
    /// more is a configuration error.
    pub fn pin_batch_sizes(mut self, batches: &[usize]) -> EngineBuilder {
        self.pinned = batches.to_vec();
        self
    }

    /// Replace the cost model with measured selection: every admissible
    /// algorithm is timed on the real geometry at build, and the
    /// measurements are kept in the [`LayerPlan`] report.
    pub fn autotune(mut self, on: bool) -> EngineBuilder {
        self.autotune = on;
        self
    }

    /// Force `algo` for conv node `layer` (bench/bringup use). The
    /// choice is validated up front: unsupported geometry/precision or a
    /// budget-exceeding workspace fails `build()` with a typed error.
    pub fn algo_override(mut self, layer: usize, algo: AlgoKind) -> EngineBuilder {
        self.overrides.push((layer, algo));
        self
    }

    /// Calibrate static per-node activation scales from `eval` (the q16
    /// follow-up from the roadmap): a q16 `build()` runs up to
    /// [`MAX_CALIBRATION_SAMPLES`] samples through the planned model,
    /// records each conv node's input abs-max, and rebuilds the plans
    /// with the scale baked in — serving then skips the per-execute
    /// abs-max pass. Uncalibrated engines (or f32 builds, where the
    /// scale is meaningless) keep the dynamic fallback.
    pub fn calibration(mut self, eval: EvalSet) -> EngineBuilder {
        self.calibration = Some(eval);
        self
    }

    /// Validate the whole configuration, then plan + prepack every conv
    /// layer for every pinned batch size. On success the returned
    /// [`Engine`] is immutable and `Arc`-shareable; per-thread work goes
    /// through [`Engine::session`].
    pub fn build(self) -> Result<Engine, EngineError> {
        // -- resolve the model ------------------------------------------
        let mut model = match self.source {
            ModelSource::Owned(m) => m,
            ModelSource::Path(p) => load_mecw(&p).map_err(|e| EngineError::ModelLoad {
                path: p.display().to_string(),
                reason: e.to_string(),
            })?,
        };

        // -- validate knobs ---------------------------------------------
        if self.threads == 0 {
            return Err(EngineError::InvalidConfig("threads must be >= 1".into()));
        }
        let mut pinned = self.pinned;
        if pinned.is_empty() {
            pinned.push(1);
        }
        if pinned.contains(&0) {
            return Err(EngineError::InvalidConfig(
                "pinned batch sizes must be >= 1".into(),
            ));
        }
        pinned.sort_unstable();
        pinned.dedup();
        // The model caches at most MAX_CACHED_GEOMETRIES_PER_LAYER plans
        // per conv layer; more pinned batches than that could not all
        // stay resident, which would silently void the eager-prepack and
        // lock-free steady-state contract for the overflow sizes.
        if pinned.len() > crate::model::MAX_CACHED_GEOMETRIES_PER_LAYER {
            return Err(EngineError::InvalidConfig(format!(
                "{} pinned batch sizes exceed the {} cached geometries kept per layer",
                pinned.len(),
                crate::model::MAX_CACHED_GEOMETRIES_PER_LAYER
            )));
        }
        let ctx = ConvContext::default()
            .with_threads(self.threads)
            .with_precision(self.precision);

        // -- validate overrides -----------------------------------------
        let mut forced: HashMap<usize, AlgoKind> = HashMap::new();
        for (layer, algo) in &self.overrides {
            if !model.is_conv(*layer) {
                return Err(EngineError::NotAConvLayer {
                    layer: *layer,
                    n_layers: model.node_count(),
                });
            }
            if let Some(prev) = forced.insert(*layer, *algo) {
                if prev != *algo {
                    return Err(EngineError::InvalidConfig(format!(
                        "conflicting algo_override for layer {layer}: {} vs {}",
                        prev.name(),
                        algo.name()
                    )));
                }
            }
        }

        // -- choose per-layer algorithms on the largest pinned batch ----
        let planner = Planner::new();
        let tuner = AutoTuner::new();
        let plan_batch = *pinned.last().expect("pinned is non-empty");
        let mut report: Vec<LayerPlan> = Vec::new();
        let mut chosen: HashMap<usize, AlgoKind> = HashMap::new();
        for (i, cs) in model.conv_shapes(plan_batch) {
            let (picked, measurements) = if let Some(&algo) = forced.get(&i) {
                let plan = planner
                    .validate_choice(algo, &cs, &self.budget, &ctx)
                    .map_err(|source| EngineError::Plan { layer: i, source })?;
                (plan, None)
            } else if self.autotune {
                let ms = tuner.measure_all(&cs, &self.budget, &ctx);
                let best = ms
                    .iter()
                    .min_by(|a, b| a.median_ns.total_cmp(&b.median_ns))
                    .expect("direct is always admissible");
                let plan = Plan {
                    algo: best.algo,
                    workspace_bytes: best.workspace_bytes,
                    est_ns: best.median_ns,
                };
                (plan, Some(ms))
            } else {
                (planner.plan(&cs, &self.budget, &ctx), None)
            };
            chosen.insert(i, picked.algo);
            report.push(LayerPlan {
                layer: i,
                shape: cs,
                chosen: picked,
                candidates: planner.admissible(&cs, &self.budget, &ctx),
                measurements,
                act_qparams: None,
                backend: KernelBackend::active(),
            });
        }
        // Every override must have reached the loop above: a conv node
        // the pass pipeline eliminated as dead would otherwise pass
        // `is_conv` yet silently never be validated or applied.
        for (&layer, &algo) in &forced {
            if !chosen.contains_key(&layer) {
                return Err(EngineError::InvalidConfig(format!(
                    "algo_override({layer}, {}) targets a conv node that is \
                     unreachable from the graph output (dead code)",
                    algo.name()
                )));
            }
        }

        // -- plan + prepack eagerly for every pinned batch --------------
        model.plan_with(&ctx, plan_batch, |i, _| chosen[&i]);

        // -- calibration: static activation scales (q16 serving) --------
        if let Some(eval) = &self.calibration {
            if self.precision == Precision::Q16 {
                let scales = calibrate(&model, &ctx, eval, plan_batch)?;
                model.set_activation_qparams(scales);
                // Rebuild the plans with the static scales baked in (the
                // chosen algorithms are unchanged — only the epilogue
                // scale moved from execute time to plan time).
                model.plan_with(&ctx, plan_batch, |i, _| chosen[&i]);
                for lp in &mut report {
                    lp.act_qparams = model.activation_qparams(lp.layer);
                }
            }
        }

        // Record the backend each built plan's GEMMs actually dispatch
        // to (the packed kernel knows; plans without a packed operand
        // keep the host-detected default set above).
        for lp in &mut report {
            if let Some(b) = model
                .cached_plans_for_layer(lp.layer)
                .iter()
                .find_map(|p| p.kernel_backend())
            {
                lp.backend = b;
            }
        }

        let mut ws_elems = model.planned_workspace_elems();
        for &b in pinned.iter().filter(|&&b| b != plan_batch) {
            ws_elems = ws_elems.max(model.prepare_batch(b));
        }
        // Activation slots scale linearly with the batch dim, so sizing
        // at the largest pinned batch covers every smaller one.
        let max_batch = *pinned.last().expect("pinned is non-empty");
        let act_slots: Vec<usize> = model
            .exec()
            .slot_elems()
            .iter()
            .map(|e| e * max_batch)
            .collect();

        // Seed the serving scheduler's per-pinned-batch compute
        // estimates from the same cost model that just ranked the
        // algorithms, with the planner's thread discount applied so the
        // figures are comparable to wall-clock on this engine.
        let discount = 1.0 + 0.75 * (self.threads as f64 - 1.0);
        let mut batch_costs = Vec::with_capacity(pinned.len());
        for &b in &pinned {
            let mut total = 0.0;
            for (i, cs) in model.conv_shapes(b) {
                total += planner.cost.estimate_ns_prec(chosen[&i], &cs, self.precision);
            }
            batch_costs.push((b, total / discount));
        }

        let model = Arc::new(model);
        let degrade = Arc::new(super::DegradeCtl::new(
            Arc::clone(&model),
            ctx.clone(),
            pinned.clone(),
            ws_elems,
        ));
        Ok(Engine {
            model,
            ctx,
            budget: self.budget,
            act_slots,
            pinned,
            report,
            batch_costs,
            degrade,
        })
    }
}

/// Run up to [`MAX_CALIBRATION_SAMPLES`] eval samples through the
/// planned model, recording each conv node's input abs-max — exactly
/// the quantity the dynamic q16 path computes per execute.
fn calibrate(
    model: &Model,
    ctx: &ConvContext,
    eval: &EvalSet,
    batch: usize,
) -> Result<HashMap<usize, QParams>, EngineError> {
    let (h, w, c) = model.input_hwc;
    if (eval.h, eval.w, eval.c) != (h, w, c) {
        return Err(EngineError::InvalidConfig(format!(
            "calibration samples are {}x{}x{}, engine input is {h}x{w}x{c}",
            eval.h, eval.w, eval.c
        )));
    }
    if eval.is_empty() {
        return Err(EngineError::InvalidConfig(
            "calibration set is empty".into(),
        ));
    }
    let cap = eval.len().min(MAX_CALIBRATION_SAMPLES);
    let mut maxima: HashMap<usize, f32> = HashMap::new();
    let mut ws = model.sized_arena();
    let mut acts = model.sized_activation_arena(batch);
    for chunk in eval.samples[..cap].chunks(batch.max(1)) {
        let n = chunk.len();
        let mut data = Vec::with_capacity(n * h * w * c);
        for s in chunk {
            data.extend_from_slice(s);
        }
        let input = Tensor::from_vec(Nhwc::new(n, h, w, c), data);
        model.forward_observing(ctx, &input, &mut ws, &mut acts, &mut |node, t| {
            let m = t.data().iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let e = maxima.entry(node).or_insert(0.0);
            *e = e.max(m);
        });
    }
    Ok(maxima
        .into_iter()
        .map(|(node, m)| (node, QParams::from_abs_max(m)))
        .collect())
}
