//! [`Session`] — cheap per-thread execution state over a shared
//! [`Engine`](crate::engine::Engine).
//!
//! A session owns the two things a forward pass mutates — the workspace
//! [`Arena`] (pre-sized by the engine to the max over pinned batches)
//! and a [`PlanMemo`] in front of the model's locked plan cache — so the
//! steady-state hot path takes no locks and performs zero tracked
//! allocation. Everything read-only (planned `ConvPlan`s, shared kernel
//! prepacks, weights) stays in the engine's `Arc<Model>`.

use super::{DegradeCtl, EngineError};
use crate::conv::ConvContext;
use crate::memory::{ActivationArena, Arena};
use crate::model::{Model, PlanMemo};
use crate::tensor::{Nhwc, Tensor};
use std::sync::Arc;

/// The per-sample result of [`Session::infer`].
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    /// Final activation row (class probabilities if the model ends in
    /// softmax, logits otherwise).
    pub scores: Vec<f32>,
    /// Argmax class.
    pub class: usize,
}

impl Prediction {
    /// Build from one output row. NaN-safe argmax: non-finite scores
    /// simply never win, so a degenerate row yields class 0 instead of a
    /// comparator panic.
    pub fn from_scores(scores: Vec<f32>) -> Prediction {
        let mut class = 0;
        let mut best = f32::NEG_INFINITY;
        for (i, &v) in scores.iter().enumerate() {
            if v > best {
                best = v;
                class = i;
            }
        }
        Prediction { scores, class }
    }
}

/// Per-thread inference handle; create one per worker with
/// [`Engine::session`](crate::engine::Engine::session).
pub struct Session {
    model: Arc<Model>,
    ctx: ConvContext,
    arena: Arena,
    /// Activation slots from the graph's liveness plan, pre-sized by the
    /// engine to the largest pinned batch — the counterpart of the
    /// workspace arena for everything that is *not* a lowering buffer.
    acts: ActivationArena,
    memo: PlanMemo,
    input_hwc: (usize, usize, usize),
    /// Shared degradation state (see `engine::DegradeCtl`). Each forward
    /// starts by resyncing against its epoch, so a re-plan by any session
    /// invalidates every other session's memo before its next use.
    degrade: Arc<DegradeCtl>,
    /// Last degradation epoch this session synced its memo/targets to.
    epoch_seen: u64,
    /// Workspace floats to (fallibly) reserve before each forward —
    /// follows the engine-wide target across degradations.
    ws_target: usize,
}

impl Session {
    pub(crate) fn new(
        model: Arc<Model>,
        ctx: ConvContext,
        act_slots: &[usize],
        degrade: Arc<DegradeCtl>,
    ) -> Session {
        let input_hwc = model.input_hwc;
        let epoch_seen = degrade.epoch();
        let ws_target = degrade.ws_elems();
        Session {
            model,
            ctx,
            arena: Arena::with_capacity(ws_target),
            acts: ActivationArena::with_slots(act_slots),
            memo: PlanMemo::new(),
            input_hwc,
            degrade,
            epoch_seen,
            ws_target,
        }
    }

    /// Pick up an engine-wide re-plan: clear the memo (its entries point
    /// at the superseded plans) and reload the workspace target. Cheap in
    /// steady state — one atomic load and a branch.
    fn resync(&mut self) {
        let epoch = self.degrade.epoch();
        if epoch != self.epoch_seen {
            self.memo.clear();
            self.ws_target = self.degrade.ws_elems();
            self.epoch_seen = epoch;
        }
    }

    /// Fallibly reserve everything a forward will touch, then run it.
    /// All growth happens here, typed; the executor below never allocates
    /// for pinned batch sizes.
    fn try_forward(&mut self, input: &Tensor) -> Result<Tensor, EngineError> {
        self.arena
            .try_reserve(self.ws_target)
            .map_err(EngineError::Alloc)?;
        let n = input.shape().n;
        for (i, &e) in self.model.exec().slot_elems().iter().enumerate() {
            self.acts
                .try_ensure(i, e * n)
                .map_err(EngineError::Alloc)?;
        }
        Ok(self.model.forward_with(
            &self.ctx,
            input,
            &mut self.arena,
            &mut self.acts,
            Some(&mut self.memo),
        ))
    }

    /// The degradation ladder's session-side rung: a refused *workspace*
    /// reservation triggers one engine-wide re-plan onto the
    /// zero-workspace family and a single retry (which cannot need the
    /// refused bytes — the degraded target is workspace-free). Activation
    /// refusals are not helped by re-planning (activation demand is set
    /// by the graph, not the algorithm choice), so they surface typed to
    /// this one request.
    fn forward(&mut self, input: &Tensor) -> Result<Tensor, EngineError> {
        self.resync();
        match self.try_forward(input) {
            Err(EngineError::Alloc(e)) if e.site != "memory.activation.grow" => {
                self.degrade.degrade();
                self.resync();
                self.try_forward(input)
            }
            other => other,
        }
    }

    /// Classify one sample (`h·w·c` floats, the engine's input shape).
    pub fn infer(&mut self, sample: &[f32]) -> Result<Prediction, EngineError> {
        let (h, w, c) = self.input_hwc;
        let expected = h * w * c;
        if sample.len() != expected {
            return Err(EngineError::SampleSize {
                expected,
                got: sample.len(),
            });
        }
        let input = Tensor::from_vec(Nhwc::new(1, h, w, c), sample.to_vec());
        let out = self.forward(&input)?;
        Ok(Prediction::from_scores(out.into_vec()))
    }

    /// Run a full batch, returning the final activation tensor.
    pub fn infer_batch(&mut self, batch: &Tensor) -> Result<Tensor, EngineError> {
        let sh = batch.shape();
        let (h, w, c) = self.input_hwc;
        if (sh.h, sh.w, sh.c) != (h, w, c) {
            return Err(EngineError::BatchShape {
                expected: (h, w, c),
                got: (sh.h, sh.w, sh.c),
            });
        }
        self.forward(batch)
    }

    /// [`Session::infer_batch`] plus per-sample argmax — what the
    /// serving workers reply with.
    pub fn predict_batch(&mut self, batch: &Tensor) -> Result<Vec<Prediction>, EngineError> {
        let out = self.infer_batch(batch)?;
        let n = out.shape().n;
        Ok((0..n)
            .map(|i| Prediction::from_scores(out.sample(i).to_vec()))
            .collect())
    }

    /// The execution context this session runs under (fixed at build).
    pub fn context(&self) -> &ConvContext {
        &self.ctx
    }

    /// Current workspace footprint — equals the engine's arena sizing,
    /// and never grows in steady state.
    pub fn workspace_bytes(&self) -> usize {
        self.arena.bytes()
    }

    /// Current activation-arena footprint (Σ liveness slots at the
    /// largest batch seen) — never grows past the engine's sizing in
    /// steady state.
    pub fn activation_bytes(&self) -> usize {
        self.acts.bytes()
    }

    /// Plans memoized locally so far (observability for the lock-free
    /// hot-path claim).
    pub fn memoized_plans(&self) -> usize {
        self.memo.len()
    }
}
