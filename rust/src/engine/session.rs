//! [`Session`] — cheap per-thread execution state over a shared
//! [`Engine`](crate::engine::Engine).
//!
//! A session owns the two things a forward pass mutates — the workspace
//! [`Arena`] (pre-sized by the engine to the max over pinned batches)
//! and a [`PlanMemo`] in front of the model's locked plan cache — so the
//! steady-state hot path takes no locks and performs zero tracked
//! allocation. Everything read-only (planned `ConvPlan`s, shared kernel
//! prepacks, weights) stays in the engine's `Arc<Model>`.

use super::EngineError;
use crate::conv::ConvContext;
use crate::memory::{ActivationArena, Arena};
use crate::model::{Model, PlanMemo};
use crate::tensor::{Nhwc, Tensor};
use std::sync::Arc;

/// The per-sample result of [`Session::infer`].
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    /// Final activation row (class probabilities if the model ends in
    /// softmax, logits otherwise).
    pub scores: Vec<f32>,
    /// Argmax class.
    pub class: usize,
}

impl Prediction {
    /// Build from one output row. NaN-safe argmax: non-finite scores
    /// simply never win, so a degenerate row yields class 0 instead of a
    /// comparator panic.
    pub fn from_scores(scores: Vec<f32>) -> Prediction {
        let mut class = 0;
        let mut best = f32::NEG_INFINITY;
        for (i, &v) in scores.iter().enumerate() {
            if v > best {
                best = v;
                class = i;
            }
        }
        Prediction { scores, class }
    }
}

/// Per-thread inference handle; create one per worker with
/// [`Engine::session`](crate::engine::Engine::session).
pub struct Session {
    model: Arc<Model>,
    ctx: ConvContext,
    arena: Arena,
    /// Activation slots from the graph's liveness plan, pre-sized by the
    /// engine to the largest pinned batch — the counterpart of the
    /// workspace arena for everything that is *not* a lowering buffer.
    acts: ActivationArena,
    memo: PlanMemo,
    input_hwc: (usize, usize, usize),
}

impl Session {
    pub(crate) fn new(
        model: Arc<Model>,
        ctx: ConvContext,
        ws_elems: usize,
        act_slots: &[usize],
    ) -> Session {
        let input_hwc = model.input_hwc;
        Session {
            model,
            ctx,
            arena: Arena::with_capacity(ws_elems),
            acts: ActivationArena::with_slots(act_slots),
            memo: PlanMemo::new(),
            input_hwc,
        }
    }

    /// Classify one sample (`h·w·c` floats, the engine's input shape).
    pub fn infer(&mut self, sample: &[f32]) -> Result<Prediction, EngineError> {
        let (h, w, c) = self.input_hwc;
        let expected = h * w * c;
        if sample.len() != expected {
            return Err(EngineError::SampleSize {
                expected,
                got: sample.len(),
            });
        }
        let input = Tensor::from_vec(Nhwc::new(1, h, w, c), sample.to_vec());
        let out = self.model.forward_with(
            &self.ctx,
            &input,
            &mut self.arena,
            &mut self.acts,
            Some(&mut self.memo),
        );
        Ok(Prediction::from_scores(out.into_vec()))
    }

    /// Run a full batch, returning the final activation tensor.
    pub fn infer_batch(&mut self, batch: &Tensor) -> Result<Tensor, EngineError> {
        let sh = batch.shape();
        let (h, w, c) = self.input_hwc;
        if (sh.h, sh.w, sh.c) != (h, w, c) {
            return Err(EngineError::BatchShape {
                expected: (h, w, c),
                got: (sh.h, sh.w, sh.c),
            });
        }
        Ok(self.model.forward_with(
            &self.ctx,
            batch,
            &mut self.arena,
            &mut self.acts,
            Some(&mut self.memo),
        ))
    }

    /// [`Session::infer_batch`] plus per-sample argmax — what the
    /// serving workers reply with.
    pub fn predict_batch(&mut self, batch: &Tensor) -> Result<Vec<Prediction>, EngineError> {
        let out = self.infer_batch(batch)?;
        let n = out.shape().n;
        Ok((0..n)
            .map(|i| Prediction::from_scores(out.sample(i).to_vec()))
            .collect())
    }

    /// The execution context this session runs under (fixed at build).
    pub fn context(&self) -> &ConvContext {
        &self.ctx
    }

    /// Current workspace footprint — equals the engine's arena sizing,
    /// and never grows in steady state.
    pub fn workspace_bytes(&self) -> usize {
        self.arena.bytes()
    }

    /// Current activation-arena footprint (Σ liveness slots at the
    /// largest batch seen) — never grows past the engine's sizing in
    /// steady state.
    pub fn activation_bytes(&self) -> usize {
        self.acts.bytes()
    }

    /// Plans memoized locally so far (observability for the lock-free
    /// hot-path claim).
    pub fn memoized_plans(&self) -> usize {
        self.memo.len()
    }
}
