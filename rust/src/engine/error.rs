//! [`EngineError`] — the facade's single typed error.
//!
//! Before the engine existed, misconfiguration surfaced as a mix of
//! panics (`assert_eq!` on sample sizes aborting a worker thread),
//! `process::exit` calls in library-adjacent code, and ad-hoc strings.
//! Every way an [`Engine`](crate::engine::Engine) build or a
//! [`Session`](crate::engine::Session) call can fail is now one variant
//! here, checked up front where possible (the builder validates the
//! whole configuration before any kernel is prepacked).

use crate::planner::PlanError;
use std::fmt;

/// Everything that can go wrong building an engine or running a session.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineError {
    /// The model file could not be loaded (missing, bad magic,
    /// truncated...). `reason` carries the loader's message.
    ModelLoad { path: String, reason: String },
    /// The builder configuration is inconsistent: zero threads, a pinned
    /// batch size of 0, conflicting overrides for one layer, ...
    InvalidConfig(String),
    /// An `algo_override` targets a layer index that is not a
    /// convolution (or is out of range).
    NotAConvLayer { layer: usize, n_layers: usize },
    /// A conv layer cannot be planned as configured: the override's
    /// algorithm does not support the geometry or precision, or its
    /// workspace exceeds the budget.
    Plan { layer: usize, source: PlanError },
    /// A sample handed to [`Session::infer`](crate::engine::Session::infer)
    /// (or a serving request) has the wrong number of values.
    SampleSize { expected: usize, got: usize },
    /// A batch tensor's per-sample (h, w, c) does not match the engine's
    /// input shape.
    BatchShape {
        expected: (usize, usize, usize),
        got: (usize, usize, usize),
    },
    /// A workspace or activation growth was refused (real memory
    /// pressure, or the fault-injection harness). Workspace refusals are
    /// normally absorbed by the degradation ladder
    /// ([`Engine::degrade`](crate::engine::Engine::degrade)) and retried;
    /// this surfaces only when degradation cannot help (activation
    /// growth, or a second refusal after degrading).
    Alloc(crate::memory::AllocError),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::ModelLoad { path, reason } => {
                write!(f, "cannot load model {path:?}: {reason}")
            }
            EngineError::InvalidConfig(msg) => {
                write!(f, "invalid engine configuration: {msg}")
            }
            EngineError::NotAConvLayer { layer, n_layers } => write!(
                f,
                "algo_override targets layer {layer}, which is not a convolution \
                 (model has {n_layers} layers)"
            ),
            EngineError::Plan { layer, source } => {
                write!(f, "cannot plan conv layer {layer}: {source}")
            }
            EngineError::SampleSize { expected, got } => write!(
                f,
                "sample has {got} values, engine input needs {expected}"
            ),
            EngineError::BatchShape { expected, got } => write!(
                f,
                "batch samples are {}x{}x{}, engine input is {}x{}x{}",
                got.0, got.1, got.2, expected.0, expected.1, expected.2
            ),
            EngineError::Alloc(e) => write!(f, "memory pressure: {e}"),
        }
    }
}

impl std::error::Error for EngineError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::AlgoKind;

    #[test]
    fn errors_render_readable_messages() {
        let e = EngineError::SampleSize { expected: 64, got: 3 };
        assert!(e.to_string().contains("64"));
        assert!(e.to_string().contains('3'));
        let e = EngineError::Plan {
            layer: 2,
            source: PlanError::BudgetExceeded {
                algo: AlgoKind::Mec,
                workspace_bytes: 1000,
                limit: 10,
            },
        };
        let s = e.to_string();
        assert!(s.contains("layer 2"), "{s}");
        assert!(s.contains("mec"), "{s}");
        assert!(s.contains("budget"), "{s}");
    }
}
