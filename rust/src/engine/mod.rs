//! The library's front door: a builder-configured, immutable [`Engine`]
//! plus cheap per-thread [`Session`]s.
//!
//! The paper's pitch is a *deployment* trade-off — compact lowering buys
//! memory headroom that turns into latency on real serving hardware —
//! and production inference APIs (cuDNN graphs, the operator-setup/run
//! split of the Indirect Convolution Algorithm) all converge on the same
//! shape: an expensive, fully-validated, fully-planned setup object,
//! and cheap per-thread execution state. This module is that shape for
//! the MEC stack:
//!
//! * [`Engine::builder`] takes a [`Model`](crate::model::Model) (or a
//!   `.mecw` path) plus the whole serving configuration — precision,
//!   workspace [`Budget`], threads, pinned batch sizes, autotune,
//!   per-layer overrides — and `build()` validates everything **up
//!   front**, returning a typed [`EngineError`] instead of a mid-run
//!   panic. On success every conv layer is planned and its kernel
//!   prepacked (once per layer, `Arc`-shared across batch sizes), and
//!   the shared-arena requirement (max over layers and pinned batches)
//!   is fixed.
//! * [`Engine::session`] hands out [`Session`]s: each owns its arena and
//!   a plan memo, so the steady-state hot path takes **no locks** and
//!   performs **zero tracked allocations**. One engine serves any number
//!   of concurrent sessions (`Engine` is `Arc`-shareable).
//!
//! ```text
//! let engine = Engine::builder(model)          // or a .mecw path
//!     .precision(Precision::F32)
//!     .budget("16MB".parse()?)
//!     .threads(4)
//!     .pin_batch_sizes(&[1, 32])
//!     .build()?;                               // typed EngineError
//! let engine = Arc::new(engine);
//! let mut session = engine.session();          // one per thread
//! let pred = session.infer(&sample)?;          // -> Prediction
//! ```

// The front door is safe Rust only: no unsafe, ever (enforced — see
// the crate-level unsafe policy and tools/unsafe-audit).
#![forbid(unsafe_code)]

mod builder;
mod error;
mod session;

pub use builder::{EngineBuilder, ModelSource};
pub use error::EngineError;
pub use session::{Prediction, Session};

use crate::conv::{AlgoKind, ConvContext};
use crate::gemm::KernelBackend;
use crate::memory::Budget;
use crate::model::Model;
use crate::planner::{Measurement, Plan, Planner};
use crate::tensor::quant::QParams;
use crate::tensor::ConvShape;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// One conv node's planning outcome, recorded by
/// [`EngineBuilder::build`] — what the CLI `plan`/`tune` subcommands and
/// the examples print.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    /// Node id in the model graph (equals the historical layer index
    /// for sequential models).
    pub layer: usize,
    /// Exact batched geometry the choice was made on (largest pinned
    /// batch, padding applied).
    pub shape: ConvShape,
    /// The chosen algorithm with its budgeted workspace; `est_ns` is the
    /// cost-model estimate, or the measured median under autotune.
    pub chosen: Plan,
    /// Every algorithm admissible under the budget/context, with
    /// cost-model estimates.
    pub candidates: Vec<Plan>,
    /// Per-candidate measurements when `.autotune(true)` built this
    /// node (`None` for cost-model or overridden nodes).
    pub measurements: Option<Vec<Measurement>>,
    /// Calibrated static activation scale (q16 engines built with a
    /// [`EngineBuilder::calibration`] set); `None` → dynamic abs-max.
    pub act_qparams: Option<QParams>,
    /// The micro-kernel backend the built plan's GEMMs dispatch to
    /// (from the plan's packed kernel where it has one, else the
    /// host-detected [`KernelBackend::active`]).
    pub backend: KernelBackend,
}

/// One conv layer's transition onto the zero-workspace family, recorded
/// when the engine degrades (see [`Engine::degrade`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DegradedLayer {
    /// Node id in the model graph.
    pub layer: usize,
    /// Algorithm the layer was built with.
    pub from: AlgoKind,
    /// Zero-workspace algorithm it now runs.
    pub to: AlgoKind,
}

/// Engine-wide degradation state, shared by every [`Session`].
///
/// The fault-domain contract (ARCHITECTURE.md, "Fault domains & the
/// degradation ladder"): when a session's workspace reservation is
/// refused — real memory pressure or the `memory.arena.grow` /
/// `memory.workspace.grow` fault sites — the engine re-plans every conv
/// layer under a **zero** workspace budget. The planner then only
/// considers the zero-workspace family (kn2row, smm, direct; "direct is
/// always admissible"), whose arena demand is 0 floats, so the retry
/// cannot need the refused bytes. Sessions observe the transition
/// through `epoch`: one atomic load per forward, memo cleared on change.
pub(crate) struct DegradeCtl {
    model: Arc<Model>,
    ctx: ConvContext,
    pinned: Vec<usize>,
    /// Bumped once per completed re-plan (0 = never degraded).
    epoch: AtomicU64,
    /// Current per-session workspace requirement in floats — the build
    /// figure until a degrade drops it.
    ws_elems: AtomicUsize,
    /// Transitions recorded by the re-plan (empty until degraded).
    degraded: RwLock<Vec<DegradedLayer>>,
    /// Serializes the re-plan so concurrently failing sessions degrade
    /// the model once, not once each.
    replan: Mutex<()>,
}

impl DegradeCtl {
    fn new(model: Arc<Model>, ctx: ConvContext, pinned: Vec<usize>, ws_elems: usize) -> DegradeCtl {
        DegradeCtl {
            model,
            ctx,
            pinned,
            epoch: AtomicU64::new(0),
            ws_elems: AtomicUsize::new(ws_elems),
            degraded: RwLock::new(Vec::new()),
            replan: Mutex::new(()),
        }
    }

    pub(crate) fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    pub(crate) fn ws_elems(&self) -> usize {
        self.ws_elems.load(Ordering::Acquire)
    }

    pub(crate) fn is_degraded(&self) -> bool {
        self.epoch() > 0
    }

    pub(crate) fn degraded_layers(&self) -> Vec<DegradedLayer> {
        self.degraded.read().unwrap().clone()
    }

    /// Re-plan every conv layer under a zero workspace budget and
    /// publish the new epoch. Idempotent: once degraded, later calls
    /// (other sessions racing on the same refusal) return the recorded
    /// transitions without touching the model again.
    pub(crate) fn degrade(&self) -> Vec<DegradedLayer> {
        // A panic mid-replan (fault injection) must not wedge every
        // future degrade behind a poisoned mutex; replan_with republishes
        // plans atomically, so recovering the guard is sound.
        let _g = self
            .replan
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        if self.is_degraded() {
            return self.degraded_layers();
        }
        let before: HashMap<usize, AlgoKind> = self.model.plan_summary().into_iter().collect();
        let planner = Planner::new();
        let zero = Budget::new(0);
        let plan_batch = self.pinned.last().copied().unwrap_or(1);
        let mut ws = self
            .model
            .replan_with(plan_batch, |_, cs| planner.plan(cs, &zero, &self.ctx).algo);
        for &b in self.pinned.iter().filter(|&&b| b != plan_batch) {
            ws = ws.max(self.model.prepare_batch(b));
        }
        let transitions: Vec<DegradedLayer> = self
            .model
            .plan_summary()
            .into_iter()
            .filter_map(|(layer, to)| {
                let from = before.get(&layer).copied()?;
                (from != to).then_some(DegradedLayer { layer, from, to })
            })
            .collect();
        *self.degraded.write().unwrap() = transitions.clone();
        self.ws_elems.store(ws, Ordering::Release);
        self.epoch.fetch_add(1, Ordering::Release);
        transitions
    }
}

/// An immutable, fully-planned inference engine. Build with
/// [`Engine::builder`]; execute through [`Engine::session`].
pub struct Engine {
    model: Arc<Model>,
    ctx: ConvContext,
    budget: Budget,
    /// Activation-slot floats per session (liveness plan at the largest
    /// pinned batch).
    act_slots: Vec<usize>,
    pinned: Vec<usize>,
    report: Vec<LayerPlan>,
    /// Cost-model compute estimate (ns) per pinned batch size, thread
    /// discount applied — the serving scheduler's seed figures.
    batch_costs: Vec<(usize, f64)>,
    /// Degradation ladder state shared with every session (holds the
    /// current workspace target: the build-time max over conv nodes and
    /// pinned batches, dropping to zero after a degrade).
    degrade: Arc<DegradeCtl>,
}

impl Engine {
    /// Start configuring an engine from an in-memory
    /// [`Model`](crate::model::Model) or a `.mecw` path.
    pub fn builder(model_or_path: impl Into<ModelSource>) -> EngineBuilder {
        EngineBuilder::new(model_or_path.into())
    }

    /// A new per-thread session: its workspace arena and activation
    /// slots are pre-sized to this engine's requirements, its plan memo
    /// starts empty and warms on first use. Sessions share the engine's
    /// persistent worker pool — steady-state inference never spawns OS
    /// threads.
    pub fn session(&self) -> Session {
        Session::new(
            Arc::clone(&self.model),
            self.ctx.clone(),
            &self.act_slots,
            Arc::clone(&self.degrade),
        )
    }

    /// Like [`Engine::session`] but capped at `threads` loop
    /// participants (clamped to `1..=self.context().threads()`), still
    /// sharing the engine's pool. The serving coordinator uses this to
    /// divide the pool across its workers instead of multiplying
    /// worker-count × intra-op threads.
    pub fn session_with_threads(&self, threads: usize) -> Session {
        let ctx = self
            .ctx
            .clone()
            .with_parallelism(self.ctx.par.with_budget(threads));
        Session::new(Arc::clone(&self.model), ctx, &self.act_slots, Arc::clone(&self.degrade))
    }

    /// OS threads the engine's pool has spawned so far — constant after
    /// `build()`; the steady-state tests assert it stays flat across
    /// inference (the threading analogue of zero tracked allocation).
    pub fn pool_threads_spawned(&self) -> usize {
        self.ctx.par.pool().map(|p| p.threads_spawned()).unwrap_or(0)
    }

    /// The planned model (read-only; shared by every session).
    pub fn model(&self) -> &Arc<Model> {
        &self.model
    }

    /// The execution context every session runs under.
    pub fn context(&self) -> &ConvContext {
        &self.ctx
    }

    /// The workspace budget the engine was planned under.
    pub fn budget(&self) -> &Budget {
        &self.budget
    }

    /// Per-sample input shape (h, w, c).
    pub fn input_hwc(&self) -> (usize, usize, usize) {
        self.model.input_hwc
    }

    /// Batch sizes planned + prepacked eagerly at build (sorted,
    /// deduplicated).
    pub fn pinned_batch_sizes(&self) -> &[usize] {
        &self.pinned
    }

    /// Workspace floats each session's arena is pre-sized to — the
    /// build-time max over conv nodes and pinned batches, dropping to
    /// zero once the engine has degraded onto the zero-workspace family.
    pub fn workspace_elems(&self) -> usize {
        self.degrade.ws_elems()
    }

    /// Same in bytes.
    pub fn workspace_bytes(&self) -> usize {
        self.workspace_elems() * std::mem::size_of::<f32>()
    }

    /// Activation-arena bytes each session is pre-sized to (Σ liveness
    /// slots at the largest pinned batch — max over live sets, not sum
    /// over node outputs).
    pub fn activation_bytes(&self) -> usize {
        self.act_slots.iter().sum::<usize>() * std::mem::size_of::<f32>()
    }

    /// Per-layer planning outcomes recorded at build time.
    pub fn plan_report(&self) -> &[LayerPlan] {
        &self.report
    }

    /// [`Engine::plan_report`] with the degradation ladder's transitions
    /// applied: a degraded layer's `chosen` plan is replaced by its
    /// zero-workspace fallback (taken from the recorded `candidates` —
    /// the family is admissible under any budget — or synthesized with a
    /// zero workspace when the build report predates the candidate).
    /// Identical to the build report until [`Engine::degrade`] fires.
    pub fn plan_report_current(&self) -> Vec<LayerPlan> {
        let degraded = self.degrade.degraded_layers();
        let mut report = self.report.clone();
        for d in &degraded {
            if let Some(lp) = report.iter_mut().find(|lp| lp.layer == d.layer) {
                lp.chosen = lp
                    .candidates
                    .iter()
                    .find(|c| c.algo == d.to)
                    .cloned()
                    .unwrap_or(Plan {
                        algo: d.to,
                        workspace_bytes: 0,
                        est_ns: lp.chosen.est_ns,
                    });
                lp.measurements = None;
            }
        }
        report
    }

    /// Force the degradation ladder now (operational use: shed workspace
    /// ahead of anticipated memory pressure). Atomically re-plans every
    /// conv layer onto the zero-workspace family {kn2row, smm, direct}
    /// and returns the transitions; idempotent — once degraded, later
    /// calls return the recorded transitions without re-planning. The
    /// same path runs automatically when a session's workspace
    /// reservation is refused.
    pub fn degrade(&self) -> Vec<DegradedLayer> {
        self.degrade.degrade()
    }

    /// Whether the engine has degraded onto the zero-workspace family.
    pub fn is_degraded(&self) -> bool {
        self.degrade.is_degraded()
    }

    /// Degradation epoch: 0 until the first (and only) degrade, then 1.
    /// Sessions resync their plan memos against this.
    pub fn degrade_epoch(&self) -> u64 {
        self.degrade.epoch()
    }

    /// Layer transitions recorded by the degrade (empty while healthy).
    pub fn degraded_layers(&self) -> Vec<DegradedLayer> {
        self.degrade.degraded_layers()
    }

    /// Chosen algorithm per conv layer (delegates to the model).
    pub fn plan_summary(&self) -> Vec<(usize, AlgoKind)> {
        self.model.plan_summary()
    }

    /// Cost-model compute estimate (ns) for each pinned batch size,
    /// ascending, with the planner's thread discount applied. The
    /// serving layer seeds its
    /// [`BatchCosts`](crate::serving::BatchCosts) from this and refines
    /// online from measured forwards.
    pub fn batch_cost_estimates(&self) -> &[(usize, f64)] {
        &self.batch_costs
    }

    /// Estimated forward ns for a batch of `n`: exact for pinned sizes,
    /// linearly scaled from the nearest pinned size otherwise.
    pub fn estimate_batch_ns(&self, n: usize) -> f64 {
        let n = n.max(1);
        if let Some(&(_, ns)) = self.batch_costs.iter().find(|&&(b, _)| b == n) {
            return ns;
        }
        match self
            .batch_costs
            .iter()
            .min_by_key(|&&(b, _)| b.abs_diff(n))
        {
            Some(&(b, ns)) => ns * n as f64 / b.max(1) as f64,
            None => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Layer;
    use crate::tensor::{Kernel, KernelShape, Nhwc, Precision, Tensor};
    use crate::util::Rng;

    fn conv_model(seed: u64) -> Model {
        let mut rng = Rng::new(seed);
        Model::new(
            "engine-unit",
            (8, 8, 2),
            vec![
                Layer::Conv {
                    kernel: Kernel::random(KernelShape::new(3, 3, 2, 4), &mut rng),
                    bias: vec![0.1; 4],
                    sh: 1,
                    sw: 1,
                    ph: 1,
                    pw: 1,
                },
                Layer::Relu,
            ],
        )
    }

    #[test]
    fn builder_defaults_produce_a_working_engine() {
        let engine = Engine::builder(conv_model(1)).build().unwrap();
        assert_eq!(engine.pinned_batch_sizes(), &[1]);
        assert_eq!(engine.context().threads(), 1);
        assert_eq!(engine.pool_threads_spawned(), 0, "threads(1) spawns no pool");
        assert_eq!(engine.context().precision, Precision::F32);
        assert_eq!(engine.plan_report().len(), 1);
        assert!(engine.workspace_bytes() > 0);
        let mut s = engine.session();
        let mut rng = Rng::new(9);
        let x = Tensor::random(Nhwc::new(1, 8, 8, 2), &mut rng);
        let out = s.infer_batch(&x).unwrap();
        assert_eq!(out.shape(), Nhwc::new(1, 8, 8, 4));
    }

    #[test]
    fn pinned_batches_are_planned_eagerly_and_size_the_arena() {
        let engine = Engine::builder(conv_model(2))
            .pin_batch_sizes(&[4, 1, 4])
            .build()
            .unwrap();
        assert_eq!(engine.pinned_batch_sizes(), &[1, 4], "sorted + deduped");
        // Both geometries are cached before any inference runs, sharing
        // one kernel prepack.
        assert_eq!(engine.model().cached_plans_for_layer(0).len(), 2);
        assert_eq!(engine.model().cached_prepacks(), 1);
        // The arena covers the largest pinned batch.
        let solo = Engine::builder(conv_model(2))
            .pin_batch_sizes(&[4])
            .build()
            .unwrap();
        assert_eq!(engine.workspace_elems(), solo.workspace_elems());
    }

    #[test]
    fn algo_override_is_validated_and_applied() {
        let engine = Engine::builder(conv_model(3))
            .algo_override(0, AlgoKind::Im2col)
            .build()
            .unwrap();
        assert_eq!(engine.plan_summary(), vec![(0, AlgoKind::Im2col)]);
        // Duplicate identical override is tolerated; conflicting is not.
        assert!(Engine::builder(conv_model(3))
            .algo_override(0, AlgoKind::Im2col)
            .algo_override(0, AlgoKind::Im2col)
            .build()
            .is_ok());
        let err = Engine::builder(conv_model(3))
            .algo_override(0, AlgoKind::Im2col)
            .algo_override(0, AlgoKind::Mec)
            .build()
            .unwrap_err();
        assert!(matches!(err, EngineError::InvalidConfig(_)), "{err:?}");
    }

    #[test]
    fn session_with_threads_clamps_and_shares_the_engine_pool() {
        let engine = Engine::builder(conv_model(5)).threads(4).build().unwrap();
        assert_eq!(engine.pool_threads_spawned(), 3, "pool = threads - 1");
        let s = engine.session_with_threads(2);
        assert_eq!(s.context().threads(), 2);
        assert!(
            std::sync::Arc::ptr_eq(
                engine.context().par.pool().unwrap(),
                s.context().par.pool().unwrap()
            ),
            "capped session must share the engine pool, not spawn its own"
        );
        assert_eq!(engine.session_with_threads(0).context().threads(), 1);
        assert_eq!(engine.session_with_threads(99).context().threads(), 4);
        assert_eq!(engine.pool_threads_spawned(), 3, "sessions spawn nothing");
    }

    #[test]
    fn batch_cost_estimates_cover_pinned_sizes_and_interpolate() {
        let engine = Engine::builder(conv_model(6))
            .pin_batch_sizes(&[1, 4])
            .build()
            .unwrap();
        let costs = engine.batch_cost_estimates();
        assert_eq!(costs.len(), 2);
        assert_eq!(costs[0].0, 1);
        assert_eq!(costs[1].0, 4);
        let one = engine.estimate_batch_ns(1);
        let four = engine.estimate_batch_ns(4);
        assert!(one > 0.0, "conv model must cost something: {one}");
        assert!(four > one, "larger batch costs more: {four} vs {one}");
        // Non-pinned sizes scale linearly from the nearest pinned one.
        let two = engine.estimate_batch_ns(2);
        assert!((two - one * 2.0).abs() < 1e-6, "2 scales from 1: {two}");
        // More threads discount the estimate.
        let mt = Engine::builder(conv_model(6))
            .pin_batch_sizes(&[1, 4])
            .threads(4)
            .build()
            .unwrap();
        assert!(mt.estimate_batch_ns(4) < four);
    }

    #[test]
    fn degrade_replans_onto_the_zero_workspace_family() {
        let engine = Engine::builder(conv_model(7)).build().unwrap();
        assert!(!engine.is_degraded());
        assert!(engine.workspace_elems() > 0, "3x3 conv plans a workspace");
        let transitions = engine.degrade();
        assert!(engine.is_degraded());
        assert_eq!(engine.degrade_epoch(), 1);
        assert_eq!(
            engine.workspace_elems(),
            0,
            "the zero-workspace family needs no arena"
        );
        assert!(
            !transitions.is_empty(),
            "a workspace-hungry plan must have moved"
        );
        for lp in engine.plan_report_current() {
            assert_eq!(
                lp.chosen.workspace_bytes, 0,
                "layer {} still reports a workspace after degrade",
                lp.layer
            );
        }
        // Build-time report is untouched (it documents what was built).
        assert!(engine.plan_report()[0].chosen.workspace_bytes > 0);
        // Idempotent: a second degrade re-plans nothing and reports the
        // same transitions.
        assert_eq!(engine.degrade(), transitions);
        assert_eq!(engine.degrade_epoch(), 1);
    }

    #[test]
    fn degraded_outputs_match_a_zero_budget_build_bitwise() {
        let mut rng = Rng::new(11);
        let x = Tensor::random(Nhwc::new(2, 8, 8, 2), &mut rng);
        let engine = Engine::builder(conv_model(8)).build().unwrap();
        let mut s = engine.session();
        let healthy = s.infer_batch(&x).unwrap();
        engine.degrade();
        // The same session picks the re-plan up on its next forward (its
        // memo resyncs against the degrade epoch).
        let degraded = s.infer_batch(&x).unwrap();
        assert_eq!(healthy.shape(), degraded.shape());
        let zero = Engine::builder(conv_model(8))
            .budget(Budget::new(0))
            .build()
            .unwrap();
        let reference = zero.session().infer_batch(&x).unwrap();
        assert_eq!(
            degraded.data(),
            reference.data(),
            "degraded forward must be bitwise identical to a fresh \
             zero-budget plan"
        );
    }

    #[test]
    fn invalid_knobs_fail_fast() {
        let err = Engine::builder(conv_model(4)).threads(0).build().unwrap_err();
        assert!(matches!(err, EngineError::InvalidConfig(_)), "{err:?}");
        let err = Engine::builder(conv_model(4))
            .pin_batch_sizes(&[2, 0])
            .build()
            .unwrap_err();
        assert!(matches!(err, EngineError::InvalidConfig(_)), "{err:?}");
        // More pinned sizes than the per-layer plan cache can keep
        // resident would silently void the eager-prepack contract.
        let err = Engine::builder(conv_model(4))
            .pin_batch_sizes(&[1, 2, 3, 4, 5, 6, 7, 8, 9])
            .build()
            .unwrap_err();
        assert!(matches!(err, EngineError::InvalidConfig(_)), "{err:?}");
        let err = Engine::builder(conv_model(4))
            .algo_override(1, AlgoKind::Mec) // layer 1 is Relu
            .build()
            .unwrap_err();
        assert!(
            matches!(err, EngineError::NotAConvLayer { layer: 1, n_layers: 2 }),
            "{err:?}"
        );
        let err = Engine::builder("/no/such/model.mecw").build().unwrap_err();
        assert!(matches!(err, EngineError::ModelLoad { .. }), "{err:?}");
    }
}
