//! Bounded request queue with backpressure.
//!
//! `std::sync::mpsc` is unbounded (or rendezvous with `sync_channel`'s
//! per-send blocking semantics we don't want for try-enqueue), so we keep
//! our own Mutex+Condvar deque: `push` fails fast when full (the caller
//! sheds load), `pop_up_to` blocks with a deadline — exactly the
//! primitive the dynamic batcher needs.

use super::Request;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueError {
    Full(usize),
    Closed,
}

impl std::fmt::Display for QueueError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueueError::Full(cap) => write!(f, "queue full (capacity {cap})"),
            QueueError::Closed => write!(f, "queue closed"),
        }
    }
}

impl std::error::Error for QueueError {}

struct Inner {
    items: VecDeque<Request>,
    closed: bool,
}

/// MPMC bounded FIFO of [`Request`]s.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    available: Condvar,
    capacity: usize,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(capacity),
                closed: false,
            }),
            available: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking enqueue; `Err(Full)` applies backpressure to clients.
    pub fn push(&self, req: Request) -> Result<(), QueueError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(QueueError::Closed);
        }
        if g.items.len() >= self.capacity {
            return Err(QueueError::Full(self.capacity));
        }
        g.items.push_back(req);
        drop(g);
        self.available.notify_one();
        Ok(())
    }

    /// Pop 1..=max requests. Blocks until at least one is available or
    /// the deadline passes (returns empty vec) or the queue is closed and
    /// drained (returns None).
    pub fn pop_up_to(&self, max: usize, deadline: Instant) -> Option<Vec<Request>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.items.is_empty() {
                let take = max.min(g.items.len()).max(1);
                return Some(g.items.drain(..take).collect());
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return Some(Vec::new());
            }
            let (ng, timeout) = self
                .available
                .wait_timeout(g, deadline.duration_since(now))
                .unwrap();
            g = ng;
            if timeout.timed_out() && g.items.is_empty() {
                return if g.closed { None } else { Some(Vec::new()) };
            }
        }
    }

    /// Blocking pop of exactly one (no deadline) — tests/tools.
    pub fn pop_blocking(&self) -> Option<Request> {
        loop {
            match self.pop_up_to(1, Instant::now() + Duration::from_secs(3600)) {
                None => return None,
                Some(mut v) if !v.is_empty() => return v.pop(),
                Some(_) => continue,
            }
        }
    }

    /// Close: producers get `Closed`, consumers drain then get `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.available.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{mpsc, Arc};

    fn req(id: u64) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id,
            sample: vec![],
            enqueued_at: Instant::now(),
            deadline: None,
            reply: tx,
        }
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(8);
        for i in 0..5 {
            q.push(req(i)).unwrap();
        }
        let batch = q.pop_up_to(3, Instant::now()).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        let batch = q.pop_up_to(10, Instant::now()).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn backpressure_when_full() {
        let q = RequestQueue::new(2);
        q.push(req(0)).unwrap();
        q.push(req(1)).unwrap();
        assert_eq!(q.push(req(2)), Err(QueueError::Full(2)));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn deadline_returns_empty() {
        let q = RequestQueue::new(2);
        let t0 = Instant::now();
        let got = q.pop_up_to(4, t0 + Duration::from_millis(30)).unwrap();
        assert!(got.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn close_rejects_producers_and_drains() {
        let q = RequestQueue::new(4);
        q.push(req(1)).unwrap();
        q.close();
        assert_eq!(q.push(req(2)).unwrap_err(), QueueError::Closed);
        // Drains the remaining item, then None.
        let got = q.pop_up_to(4, Instant::now() + Duration::from_millis(10)).unwrap();
        assert_eq!(got.len(), 1);
        assert!(q.pop_up_to(4, Instant::now() + Duration::from_millis(10)).is_none());
    }

    #[test]
    fn cross_thread_handoff() {
        let q = Arc::new(RequestQueue::new(16));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            for i in 0..100 {
                while q2.push(req(i)).is_err() {
                    std::thread::yield_now();
                }
            }
            q2.close();
        });
        let mut seen = 0;
        loop {
            match q.pop_up_to(7, Instant::now() + Duration::from_secs(5)) {
                None => break,
                Some(batch) => seen += batch.len(),
            }
        }
        h.join().unwrap();
        assert_eq!(seen, 100);
    }
}
