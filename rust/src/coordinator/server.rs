//! The serving loop: worker threads drain the queue through the model.
//!
//! Ownership layout: the [`Model`] is shared read-only (`Arc`) and holds
//! the prepacked per-layer [`ConvPlan`](crate::conv::ConvPlan)s; each
//! worker owns a shared [`Arena`] pre-sized by the planner to the max
//! per-layer workspace, so the hot path allocates nothing but
//! activations — no kernel repacking, no workspace growth.

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::queue::{QueueError, RequestQueue};
use super::{assemble_batch, Request, Response};
use crate::conv::ConvContext;
use crate::memory::Arena;
use crate::model::Model;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    pub queue_capacity: usize,
    pub policy: BatchPolicy,
    pub ctx: ConvContext,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 1,
            queue_capacity: 256,
            policy: BatchPolicy::default(),
            ctx: ConvContext::default(),
        }
    }
}

/// Handle for submitting requests to a running server.
#[derive(Clone)]
pub struct Client {
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    next_id: Arc<AtomicU64>,
    hwc: (usize, usize, usize),
}

impl Client {
    /// Submit one sample; returns a receiver for the response.
    pub fn submit(&self, sample: Vec<f32>) -> Result<mpsc::Receiver<Response>, QueueError> {
        let (h, w, c) = self.hwc;
        assert_eq!(sample.len(), h * w * c, "sample size mismatch");
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            sample,
            enqueued_at: Instant::now(),
            reply: tx,
        };
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        match self.queue.push(req) {
            Ok(()) => Ok(rx),
            Err(e) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Submit and block for the answer.
    pub fn infer(&self, sample: Vec<f32>) -> Result<Response, QueueError> {
        let rx = self.submit(sample)?;
        rx.recv().map_err(|_| QueueError::Closed)
    }
}

/// A running inference server.
pub struct Server {
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    hwc: (usize, usize, usize),
    next_id: Arc<AtomicU64>,
}

impl Server {
    /// Start worker threads over a planned model.
    pub fn start(model: Arc<Model>, cfg: ServerConfig) -> Server {
        let queue = Arc::new(RequestQueue::new(cfg.queue_capacity));
        let metrics = Arc::new(Metrics::new());
        let hwc = model.input_hwc;
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let queue = Arc::clone(&queue);
            let metrics = Arc::clone(&metrics);
            let model = Arc::clone(&model);
            let policy = cfg.policy.clone();
            let ctx = cfg.ctx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("mec-serve-{wid}"))
                    .spawn(move || {
                        worker_loop(&queue, &metrics, &model, policy, ctx);
                    })
                    .expect("spawn server worker"),
            );
        }
        Server {
            queue,
            metrics,
            workers,
            hwc,
            next_id: Arc::new(AtomicU64::new(0)),
        }
    }

    pub fn client(&self) -> Client {
        Client {
            queue: Arc::clone(&self.queue),
            metrics: Arc::clone(&self.metrics),
            next_id: Arc::clone(&self.next_id),
            hwc: self.hwc,
        }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Stop accepting, drain, and join workers.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        Arc::clone(&self.metrics)
    }
}

fn worker_loop(
    queue: &RequestQueue,
    metrics: &Metrics,
    model: &Model,
    policy: BatchPolicy,
    ctx: ConvContext,
) {
    let batcher = Batcher::new(queue, policy);
    // Planner-sized shared arena: max (not sum) over planned layers.
    // Batches at or below the planned size never grow it.
    let mut arena = model.sized_arena();
    while let Some(batch) = batcher.next_batch() {
        if batch.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        let input = assemble_batch(model.input_hwc, &batch);
        let out = model.forward(&ctx, &input, &mut arena);
        let forward_ns = t0.elapsed().as_nanos() as f64;
        metrics.record_batch(batch.len(), forward_ns);
        let classes = out.shape().c;
        for (i, req) in batch.iter().enumerate() {
            let scores = out.data()[i * classes..(i + 1) * classes].to_vec();
            let class = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(c, _)| c)
                .unwrap_or(0);
            let resp = Response {
                id: req.id,
                scores,
                class,
                batch_size: batch.len(),
            };
            metrics.record_latency(req.enqueued_at.elapsed().as_nanos() as f64);
            let _ = req.reply.send(resp); // receiver may have given up
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::AlgoKind;
    use crate::model::{Layer, Model};
    use crate::tensor::{Kernel, KernelShape};
    use crate::util::Rng;
    use std::time::Duration;

    fn tiny_model() -> Model {
        let mut rng = Rng::new(77);
        let mut m = Model::new(
            "serve-test",
            (6, 6, 1),
            vec![
                Layer::Conv {
                    kernel: Kernel::random(KernelShape::new(3, 3, 1, 2), &mut rng),
                    bias: vec![0.0; 2],
                    sh: 1,
                    sw: 1,
                    ph: 1,
                    pw: 1,
                },
                Layer::Relu,
                Layer::Flatten,
                Layer::Dense {
                    w: {
                        let mut w = vec![0.0; 72 * 3];
                        rng.fill_uniform(&mut w, -0.3, 0.3);
                        w
                    },
                    bias: vec![0.0; 3],
                    d_in: 72,
                    d_out: 3,
                },
                Layer::Softmax,
            ],
        );
        m.pin_algo(AlgoKind::Mec);
        m
    }

    #[test]
    fn serves_and_answers() {
        let server = Server::start(Arc::new(tiny_model()), ServerConfig::default());
        let client = server.client();
        let mut rng = Rng::new(1);
        let mut sample = vec![0.0; 36];
        rng.fill_uniform(&mut sample, 0.0, 1.0);
        let resp = client.infer(sample).unwrap();
        assert_eq!(resp.scores.len(), 3);
        assert!(resp.class < 3);
        let metrics = server.shutdown();
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn batch_answers_match_standalone_forward() {
        // Responses through the server must equal a direct model call.
        let model = Arc::new(tiny_model());
        let server = Server::start(
            Arc::clone(&model),
            ServerConfig {
                policy: BatchPolicy::new(8, Duration::from_millis(20)),
                ..ServerConfig::default()
            },
        );
        let client = server.client();
        let mut rng = Rng::new(5);
        let samples: Vec<Vec<f32>> = (0..6)
            .map(|_| {
                let mut s = vec![0.0; 36];
                rng.fill_uniform(&mut s, -1.0, 1.0);
                s
            })
            .collect();
        let rxs: Vec<_> = samples
            .iter()
            .map(|s| client.submit(s.clone()).unwrap())
            .collect();
        let responses: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        server.shutdown();
        // Standalone forward, batch of 1 each (batch-size independent).
        let ctx = ConvContext::default();
        let mut arena = crate::memory::Arena::new();
        for (s, resp) in samples.iter().zip(&responses) {
            let t = crate::tensor::Tensor::from_vec(
                crate::tensor::Nhwc::new(1, 6, 6, 1),
                s.clone(),
            );
            let want = model.forward(&ctx, &t, &mut arena);
            crate::util::assert_allclose(&resp.scores, want.data(), 1e-4, "server vs direct");
        }
    }

    #[test]
    fn dynamic_batching_groups_requests() {
        let server = Server::start(
            Arc::new(tiny_model()),
            ServerConfig {
                policy: BatchPolicy::new(16, Duration::from_millis(50)),
                ..ServerConfig::default()
            },
        );
        let client = server.client();
        let rxs: Vec<_> = (0..8)
            .map(|_| client.submit(vec![0.5; 36]).unwrap())
            .collect();
        let batch_sizes: Vec<usize> = rxs.into_iter().map(|rx| rx.recv().unwrap().batch_size).collect();
        let metrics = server.shutdown();
        // All 8 should have been served; at least one batch had > 1 request.
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 8);
        assert!(
            batch_sizes.iter().any(|&b| b > 1),
            "expected dynamic batching to form a multi-request batch, got {batch_sizes:?}"
        );
    }

    #[test]
    fn shutdown_is_clean_under_load() {
        let server = Server::start(Arc::new(tiny_model()), ServerConfig::default());
        let client = server.client();
        for _ in 0..20 {
            let _ = client.submit(vec![0.1; 36]);
        }
        let metrics = server.shutdown();
        // Everything accepted was answered (drain semantics).
        assert_eq!(
            metrics.responses.load(Ordering::Relaxed)
                + metrics.rejected.load(Ordering::Relaxed),
            metrics.requests.load(Ordering::Relaxed)
        );
    }
}
