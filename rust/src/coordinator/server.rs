//! The serving loop: worker threads drain the queue through per-worker
//! [`Session`]s of one shared [`Engine`].
//!
//! Ownership layout: the `Engine` is shared read-only (`Arc`) and holds
//! the planned model — prepacked per-layer
//! [`ConvPlan`](crate::conv::ConvPlan)s, shared kernel prepacks, the
//! arena sizing. Each worker owns a `Session` whose arena is pre-sized
//! to the engine's max-over-pinned-batches requirement and whose plan
//! memo makes the steady state lock-free: the hot path allocates
//! nothing but activations — no kernel repacking, no workspace growth,
//! no plan-cache lock.

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::queue::{QueueError, RequestQueue};
use super::{assemble_batch, Request, Response, SubmitError};
use crate::engine::{Engine, EngineError};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Server configuration. The execution context (threads, precision,
/// budget) lives in the [`Engine`] — the server only decides how
/// requests are queued and batched.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    pub queue_capacity: usize,
    pub policy: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 1,
            queue_capacity: 256,
            policy: BatchPolicy::default(),
        }
    }
}

/// Handle for submitting requests to a running server.
#[derive(Clone)]
pub struct Client {
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    next_id: Arc<AtomicU64>,
    hwc: (usize, usize, usize),
}

impl Client {
    /// Submit one sample; returns a receiver for the response. Sample
    /// size is validated here, at enqueue — a malformed request is
    /// rejected with [`SubmitError::Invalid`] instead of ever reaching
    /// (and formerly aborting) a worker thread.
    pub fn submit(&self, sample: Vec<f32>) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let (h, w, c) = self.hwc;
        let expected = h * w * c;
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        if sample.len() != expected {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Invalid(EngineError::SampleSize {
                expected,
                got: sample.len(),
            }));
        }
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            sample,
            enqueued_at: Instant::now(),
            reply: tx,
        };
        match self.queue.push(req) {
            Ok(()) => Ok(rx),
            Err(e) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Queue(e))
            }
        }
    }

    /// Submit and block for the answer.
    pub fn infer(&self, sample: Vec<f32>) -> Result<Response, SubmitError> {
        let rx = self.submit(sample)?;
        rx.recv().map_err(|_| SubmitError::Queue(QueueError::Closed))
    }
}

/// A running inference server over a shared [`Engine`].
pub struct Server {
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    hwc: (usize, usize, usize),
    next_id: Arc<AtomicU64>,
}

impl Server {
    /// Start worker threads; each owns a [`Session`](crate::engine::Session)
    /// of `engine`.
    ///
    /// Intra-op parallelism is divided, not multiplied: the engine's
    /// thread budget is split across the workers
    /// (`engine threads / workers`, min 1), and every session shares the
    /// engine's one persistent pool — `workers × per-session threads`
    /// never exceeds the pool the engine was built with, where each
    /// worker session previously defaulted to `available_parallelism`
    /// of its own.
    pub fn start(engine: Arc<Engine>, cfg: ServerConfig) -> Server {
        let queue = Arc::new(RequestQueue::new(cfg.queue_capacity));
        let metrics = Arc::new(Metrics::new());
        let hwc = engine.input_hwc();
        let n_workers = cfg.workers.max(1);
        let per_worker_threads = (engine.context().threads() / n_workers).max(1);
        let mut workers = Vec::new();
        for wid in 0..n_workers {
            let queue = Arc::clone(&queue);
            let metrics = Arc::clone(&metrics);
            let engine = Arc::clone(&engine);
            let policy = cfg.policy.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("mec-serve-{wid}"))
                    .spawn(move || {
                        worker_loop(&queue, &metrics, &engine, policy, per_worker_threads);
                    })
                    .expect("spawn server worker"),
            );
        }
        Server {
            queue,
            metrics,
            workers,
            hwc,
            next_id: Arc::new(AtomicU64::new(0)),
        }
    }

    pub fn client(&self) -> Client {
        Client {
            queue: Arc::clone(&self.queue),
            metrics: Arc::clone(&self.metrics),
            next_id: Arc::clone(&self.next_id),
            hwc: self.hwc,
        }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Stop accepting, drain, and join workers.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        Arc::clone(&self.metrics)
    }
}

fn worker_loop(
    queue: &RequestQueue,
    metrics: &Metrics,
    engine: &Engine,
    policy: BatchPolicy,
    threads: usize,
) {
    // Per-worker session: engine-sized arena, lock-free steady state,
    // thread budget = its share of the engine's pool.
    let batcher = Batcher::new(queue, policy);
    let mut session = engine.session_with_threads(threads);
    let (h, w, c) = engine.input_hwc();
    let per = h * w * c;
    while let Some(batch) = batcher.next_batch() {
        if batch.is_empty() {
            continue;
        }
        // Defensive re-validation: `Client::submit` rejects malformed
        // samples at enqueue, but requests can be pushed onto the queue
        // directly. A bad one gets an error reply — never a worker
        // abort.
        let mut valid = Vec::with_capacity(batch.len());
        for req in batch {
            if req.sample.len() != per {
                let resp = Response {
                    id: req.id,
                    batch_size: 0,
                    result: Err(EngineError::SampleSize {
                        expected: per,
                        got: req.sample.len(),
                    }),
                };
                // This request bypassed Client::submit (which would have
                // rejected it at enqueue), so the client-side counters
                // never saw it: account it here as a rejected request —
                // not a served response — to keep the
                // `requests == responses + rejected` conservation and
                // the throughput figure honest for every ingress path.
                metrics.requests.fetch_add(1, Ordering::Relaxed);
                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(resp);
            } else {
                valid.push(req);
            }
        }
        if valid.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        let outcome = assemble_batch((h, w, c), &valid)
            .and_then(|input| session.predict_batch(&input));
        match outcome {
            Ok(preds) => {
                let forward_ns = t0.elapsed().as_nanos() as f64;
                metrics.record_batch(valid.len(), forward_ns);
                for (req, pred) in valid.iter().zip(preds) {
                    let resp = Response {
                        id: req.id,
                        batch_size: valid.len(),
                        result: Ok(pred),
                    };
                    metrics.record_latency(req.enqueued_at.elapsed().as_nanos() as f64);
                    let _ = req.reply.send(resp); // receiver may have given up
                }
            }
            // Unreachable after the per-request validation above, but a
            // worker must survive anything: reply the typed error.
            Err(e) => {
                for req in &valid {
                    let resp = Response {
                        id: req.id,
                        batch_size: 0,
                        result: Err(e.clone()),
                    };
                    metrics.record_latency(req.enqueued_at.elapsed().as_nanos() as f64);
                    let _ = req.reply.send(resp);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::AlgoKind;
    use crate::model::{Layer, Model};
    use crate::tensor::{Kernel, KernelShape};
    use crate::util::Rng;
    use std::time::Duration;

    fn tiny_model() -> Model {
        let mut rng = Rng::new(77);
        Model::new(
            "serve-test",
            (6, 6, 1),
            vec![
                Layer::Conv {
                    kernel: Kernel::random(KernelShape::new(3, 3, 1, 2), &mut rng),
                    bias: vec![0.0; 2],
                    sh: 1,
                    sw: 1,
                    ph: 1,
                    pw: 1,
                },
                Layer::Relu,
                Layer::Flatten,
                Layer::Dense {
                    w: {
                        let mut w = vec![0.0; 72 * 3];
                        rng.fill_uniform(&mut w, -0.3, 0.3);
                        w
                    },
                    bias: vec![0.0; 3],
                    d_in: 72,
                    d_out: 3,
                },
                Layer::Softmax,
            ],
        )
    }

    fn tiny_engine() -> Arc<Engine> {
        Arc::new(
            Engine::builder(tiny_model())
                .algo_override(0, AlgoKind::Mec)
                .build()
                .expect("tiny model builds"),
        )
    }

    #[test]
    fn serves_and_answers() {
        let server = Server::start(tiny_engine(), ServerConfig::default());
        let client = server.client();
        let mut rng = Rng::new(1);
        let mut sample = vec![0.0; 36];
        rng.fill_uniform(&mut sample, 0.0, 1.0);
        let resp = client.infer(sample).unwrap();
        let pred = resp.result.expect("valid request succeeds");
        assert_eq!(pred.scores.len(), 3);
        assert!(pred.class < 3);
        let metrics = server.shutdown();
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn batch_answers_match_standalone_session() {
        // Responses through the server must equal a solo session.
        let engine = tiny_engine();
        let server = Server::start(
            Arc::clone(&engine),
            ServerConfig {
                policy: BatchPolicy::new(8, Duration::from_millis(20)),
                ..ServerConfig::default()
            },
        );
        let client = server.client();
        let mut rng = Rng::new(5);
        let samples: Vec<Vec<f32>> = (0..6)
            .map(|_| {
                let mut s = vec![0.0; 36];
                rng.fill_uniform(&mut s, -1.0, 1.0);
                s
            })
            .collect();
        let rxs: Vec<_> = samples
            .iter()
            .map(|s| client.submit(s.clone()).unwrap())
            .collect();
        let responses: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        server.shutdown();
        let mut solo = engine.session();
        for (s, resp) in samples.iter().zip(&responses) {
            let got = resp.prediction().expect("valid request succeeds");
            let want = solo.infer(s).unwrap();
            crate::util::assert_allclose(&got.scores, &want.scores, 1e-4, "server vs solo");
        }
    }

    #[test]
    fn malformed_submit_is_rejected_at_enqueue() {
        let server = Server::start(tiny_engine(), ServerConfig::default());
        let client = server.client();
        let err = client.submit(vec![0.0; 7]).unwrap_err();
        assert_eq!(
            err,
            SubmitError::Invalid(EngineError::SampleSize { expected: 36, got: 7 })
        );
        // A valid request still works afterwards.
        assert!(client.infer(vec![0.1; 36]).unwrap().result.is_ok());
        let metrics = server.shutdown();
        // Conservation: the malformed request counts as rejected.
        assert_eq!(metrics.requests.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn malformed_direct_push_gets_error_response_and_worker_survives() {
        // Bypass the client's validation by pushing onto the queue
        // directly: the worker must answer with an error Response (not
        // abort) and keep serving valid requests afterwards.
        let server = Server::start(tiny_engine(), ServerConfig::default());
        let (tx, rx) = mpsc::channel();
        server
            .queue
            .push(Request {
                id: 999,
                sample: vec![0.0; 5],
                enqueued_at: Instant::now(),
                reply: tx,
            })
            .unwrap();
        let resp = rx.recv().expect("malformed request still gets a reply");
        assert_eq!(resp.id, 999);
        assert_eq!(resp.batch_size, 0);
        assert_eq!(
            resp.result,
            Err(EngineError::SampleSize { expected: 36, got: 5 })
        );
        // The worker thread is alive and serving.
        let client = server.client();
        assert!(client.infer(vec![0.2; 36]).unwrap().result.is_ok());
        let metrics = server.shutdown();
        // Conservation holds even for the direct-ingress path: the
        // worker accounted the malformed request as rejected, not as a
        // served response.
        assert_eq!(metrics.requests.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn dynamic_batching_groups_requests() {
        let server = Server::start(
            tiny_engine(),
            ServerConfig {
                policy: BatchPolicy::new(16, Duration::from_millis(50)),
                ..ServerConfig::default()
            },
        );
        let client = server.client();
        let rxs: Vec<_> = (0..8)
            .map(|_| client.submit(vec![0.5; 36]).unwrap())
            .collect();
        let batch_sizes: Vec<usize> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().batch_size).collect();
        let metrics = server.shutdown();
        // All 8 should have been served; at least one batch had > 1 request.
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 8);
        assert!(
            batch_sizes.iter().any(|&b| b > 1),
            "expected dynamic batching to form a multi-request batch, got {batch_sizes:?}"
        );
    }

    #[test]
    fn workers_share_one_engine_pool_and_spawn_nothing_in_steady_state() {
        // Oversubscription fix: a 4-thread engine serving through 2
        // workers gives each session a 2-thread share of the ONE engine
        // pool, and serving traffic never spawns OS threads beyond the
        // pool built at engine build time.
        let engine = Arc::new(
            Engine::builder(tiny_model())
                .algo_override(0, AlgoKind::Mec)
                .threads(4)
                .build()
                .expect("tiny model builds"),
        );
        assert_eq!(engine.pool_threads_spawned(), 3, "pool = threads - 1");
        let server = Server::start(
            Arc::clone(&engine),
            ServerConfig {
                workers: 2,
                ..ServerConfig::default()
            },
        );
        let client = server.client();
        for _ in 0..4 {
            assert!(client.infer(vec![0.3; 36]).unwrap().result.is_ok());
        }
        let spawned = engine.pool_threads_spawned();
        for _ in 0..8 {
            assert!(client.infer(vec![0.3; 36]).unwrap().result.is_ok());
        }
        assert_eq!(
            engine.pool_threads_spawned(),
            spawned,
            "steady-state serving must not spawn OS threads"
        );
        server.shutdown();
    }

    #[test]
    fn shutdown_is_clean_under_load() {
        let server = Server::start(tiny_engine(), ServerConfig::default());
        let client = server.client();
        for _ in 0..20 {
            let _ = client.submit(vec![0.1; 36]);
        }
        let metrics = server.shutdown();
        // Everything accepted was answered (drain semantics).
        assert_eq!(
            metrics.responses.load(Ordering::Relaxed)
                + metrics.rejected.load(Ordering::Relaxed),
            metrics.requests.load(Ordering::Relaxed)
        );
    }
}
