//! The serving loop: worker threads drain the queue through per-worker
//! [`Session`]s of one shared [`Engine`].
//!
//! Ownership layout: the `Engine` is shared read-only (`Arc`) and holds
//! the planned model — prepacked per-layer
//! [`ConvPlan`](crate::conv::ConvPlan)s, shared kernel prepacks, the
//! arena sizing. Each worker owns a `Session` whose arena is pre-sized
//! to the engine's max-over-pinned-batches requirement and whose plan
//! memo makes the steady state lock-free: the hot path allocates
//! nothing but activations — no kernel repacking, no workspace growth,
//! no plan-cache lock.
//!
//! Scheduling policy comes from [`serving`](crate::serving): admission
//! control at [`Client::submit`] (typed [`ShedReason`] rejection when
//! the queue is full or a deadline is infeasible), the deadline-driven
//! [`AdaptiveBatcher`] in each worker, and a padding-free split of each
//! collected batch into the engine's pinned shapes.

use super::metrics::Metrics;
use super::queue::{QueueError, RequestQueue};
use super::retry::{retryable, RetryPolicy};
use super::{assemble_batch, Request, Response, ServeError, SubmitError};
use crate::engine::{DegradedLayer, Engine, EngineError};
use crate::serving::batcher::{infeasible, split_into_pinned, AdaptiveBatcher, SloPolicy};
use crate::serving::{AdmissionPolicy, BatchCosts, ShedReason};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Server configuration. The execution context (threads, precision,
/// budget) lives in the [`Engine`] — the server only decides how
/// requests are queued, admitted, and batched. The maximum batch size
/// is not configured here: it is the engine's largest pinned batch
/// (serving never dispatches a shape the engine didn't pre-plan).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    /// Bounded queue capacity; at capacity, submits shed with
    /// [`ShedReason::QueueFull`].
    pub queue_depth: usize,
    /// Default latency objective: submits without an explicit deadline
    /// get `now + slo`. `None` = best-effort serving, no deadlines.
    pub slo: Option<Duration>,
    /// Batcher collect window when no deadline presses.
    pub max_wait: Duration,
    /// Scheduling slack subtracted from deadline-driven decisions.
    pub margin: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 1,
            queue_depth: 256,
            slo: None,
            max_wait: Duration::from_millis(2),
            margin: Duration::from_micros(200),
        }
    }
}

/// Why [`Server::start`] refused a configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServerError {
    /// `workers == 0` — nothing would ever drain the queue.
    NoWorkers,
    /// More workers than engine threads: at least one worker would get
    /// a zero-thread share of the pool. Build the engine with
    /// `.threads(>= workers)` or reduce `workers`.
    InsufficientThreads { workers: usize, threads: usize },
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServerError::NoWorkers => write!(f, "server config has zero workers"),
            ServerError::InsufficientThreads { workers, threads } => write!(
                f,
                "{workers} workers cannot share a {threads}-thread engine \
                 (each worker needs at least one thread)"
            ),
        }
    }
}

impl std::error::Error for ServerError {}

/// Handle for submitting requests to a running server.
#[derive(Clone)]
pub struct Client {
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    next_id: Arc<AtomicU64>,
    hwc: (usize, usize, usize),
    costs: Arc<BatchCosts>,
    admission: AdmissionPolicy,
    workers: usize,
    slo: Option<Duration>,
}

impl Client {
    /// Submit one sample; returns a receiver for the response. Sample
    /// size is validated here, at enqueue — a malformed request is
    /// rejected with [`SubmitError::Invalid`] instead of ever reaching
    /// (and formerly aborting) a worker thread. The server's default
    /// SLO (if any) becomes the request deadline.
    pub fn submit(&self, sample: Vec<f32>) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let deadline = self.slo.map(|s| Instant::now() + s);
        self.submit_with_deadline(sample, deadline)
    }

    /// Submit with an explicit completion deadline (overrides the
    /// server SLO; `None` = best-effort). Admission control runs here:
    /// a request the scheduler already knows it cannot serve in time is
    /// refused immediately with a typed [`ShedReason`] instead of
    /// burning queue capacity and dying later.
    pub fn submit_with_deadline(
        &self,
        sample: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let (h, w, c) = self.hwc;
        let expected = h * w * c;
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        if sample.len() != expected {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Invalid(EngineError::SampleSize {
                expected,
                got: sample.len(),
            }));
        }
        if let Err(reason) = self.admission.admit(
            self.queue.len(),
            self.workers,
            &self.costs,
            Instant::now(),
            deadline,
        ) {
            self.metrics.record_submit_shed(reason);
            return Err(SubmitError::Shed(reason));
        }
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            sample,
            enqueued_at: Instant::now(),
            deadline,
            reply: tx,
        };
        match self.queue.push(req) {
            Ok(()) => Ok(rx),
            // The admission check raced a fill-up: same typed shed as if
            // admission had caught it.
            Err(QueueError::Full(capacity)) => {
                let reason = ShedReason::QueueFull {
                    depth: self.queue.len(),
                    capacity,
                };
                self.metrics.record_submit_shed(reason);
                Err(SubmitError::Shed(reason))
            }
            Err(QueueError::Closed) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::ShuttingDown)
            }
        }
    }

    /// Submit and block for the answer.
    pub fn infer(&self, sample: Vec<f32>) -> Result<Response, SubmitError> {
        let rx = self.submit(sample)?;
        rx.recv().map_err(|_| SubmitError::ShuttingDown)
    }

    /// Submit with retries under `policy`: *retryable* rejections
    /// ([`ShedReason::QueueFull`] — transient backpressure that drains)
    /// back off with deterministic jittered exponential delays and try
    /// again; terminal ones (deadline-infeasible, invalid sample,
    /// shutting down) return immediately. See
    /// [`retry`](super::retry) for the classification rationale.
    pub fn submit_with_retry(
        &self,
        sample: Vec<f32>,
        policy: &RetryPolicy,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.submit_with_retry_using(sample, policy, std::thread::sleep)
    }

    /// [`submit_with_retry`](Client::submit_with_retry) with an
    /// injectable sleep. Tests pass a recording closure (which may also
    /// drain the queue to unblock the next attempt) so the full retry
    /// schedule is exercised without ever touching the wall clock.
    pub fn submit_with_retry_using(
        &self,
        sample: Vec<f32>,
        policy: &RetryPolicy,
        mut sleep: impl FnMut(Duration),
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let mut rng = crate::util::Rng::new(policy.seed);
        let mut attempt: u32 = 0;
        loop {
            match self.submit(sample.clone()) {
                Ok(rx) => return Ok(rx),
                Err(e) if retryable(&e) && attempt + 1 < policy.max_attempts => {
                    sleep(policy.delay(attempt, &mut rng));
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }
}

/// Everything a worker thread needs — cloneable so the supervisor can
/// respawn a dead worker with the identical context.
#[derive(Clone)]
struct WorkerCtx {
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    engine: Arc<Engine>,
    costs: Arc<BatchCosts>,
    policy: SloPolicy,
    threads: usize,
}

/// Shared supervisor state: the live-worker gauge, the respawn counter,
/// and the shutdown latch that stops respawning during drain.
struct Supervision {
    restarts: AtomicU64,
    live: AtomicUsize,
    shutdown: AtomicBool,
}

/// RAII live-worker gauge: armed at the top of the worker closure,
/// decrements on *any* exit — clean drain or panic unwind — so
/// [`Server::health`] always sees the true count.
struct LiveGuard(Arc<Supervision>);

impl LiveGuard {
    fn arm(sup: &Arc<Supervision>) -> LiveGuard {
        sup.live.fetch_add(1, Ordering::AcqRel);
        LiveGuard(Arc::clone(sup))
    }
}

impl Drop for LiveGuard {
    fn drop(&mut self) {
        self.0.live.fetch_sub(1, Ordering::AcqRel);
    }
}

fn spawn_worker(
    ctx: &WorkerCtx,
    sup: &Arc<Supervision>,
    wid: usize,
) -> std::thread::JoinHandle<()> {
    let ctx = ctx.clone();
    let sup = Arc::clone(sup);
    std::thread::Builder::new()
        .name(format!("mec-serve-{wid}"))
        .spawn(move || {
            let _live = LiveGuard::arm(&sup);
            worker_loop(&ctx, wid);
        })
        .expect("spawn server worker")
}

/// How often the supervisor checks for dead workers.
const SUPERVISOR_POLL: Duration = Duration::from_millis(2);
/// First respawn delay; doubles per death up to [`BACKOFF_CAP`].
const BACKOFF_BASE: Duration = Duration::from_millis(10);
/// Restart-storm ceiling: a worker that dies on every spawn costs at
/// most one respawn per second.
const BACKOFF_CAP: Duration = Duration::from_secs(1);
/// A death-free interval this long resets the backoff to
/// [`BACKOFF_BASE`].
const BACKOFF_QUIET: Duration = Duration::from_secs(5);

/// Worker supervision: poll the handles, reap any worker that died (a
/// panic that escaped per-request containment — e.g. an injected
/// `serve.worker` fault between batches), and respawn it with
/// exponential backoff so a crash loop cannot become a spawn storm.
/// On shutdown, stop respawning and join everyone (drain semantics:
/// the join blocks until the queue is served dry).
fn supervisor_loop(
    ctx: WorkerCtx,
    sup: Arc<Supervision>,
    mut handles: Vec<Option<std::thread::JoinHandle<()>>>,
) {
    let mut backoff = BACKOFF_BASE;
    let mut last_death: Option<Instant> = None;
    while !sup.shutdown.load(Ordering::Acquire) {
        for wid in 0..handles.len() {
            let dead = handles[wid].as_ref().is_some_and(|h| h.is_finished());
            if !dead {
                continue;
            }
            // Reap. The worker's own loop only exits on queue close, so
            // death before shutdown means an un-contained panic; its
            // payload already printed at the panic site.
            let _ = handles[wid].take().unwrap().join();
            if let Some(t) = last_death {
                if t.elapsed() >= BACKOFF_QUIET {
                    backoff = BACKOFF_BASE;
                }
            }
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(BACKOFF_CAP);
            last_death = Some(Instant::now());
            if sup.shutdown.load(Ordering::Acquire) {
                // Drain began while we backed off: the remaining workers
                // finish the queue; don't spawn into shutdown.
                break;
            }
            sup.restarts.fetch_add(1, Ordering::AcqRel);
            handles[wid] = Some(spawn_worker(&ctx, &sup, wid));
        }
        std::thread::sleep(SUPERVISOR_POLL);
    }
    for h in handles.iter_mut().filter_map(|h| h.take()) {
        let _ = h.join();
    }
}

/// Point-in-time fault-domain health, from [`Server::health`].
#[derive(Debug, Clone)]
pub struct HealthSnapshot {
    /// Configured worker count.
    pub workers: usize,
    /// Workers alive right now (dips transiently while the supervisor
    /// backs off before a respawn).
    pub live_workers: usize,
    /// Supervisor respawns since start.
    pub restarts: u64,
    /// Requests answered with [`ServeError::Panicked`].
    pub panicked_requests: u64,
    /// Has the engine taken the degradation ladder (replanned onto the
    /// zero-workspace algorithm family after memory pressure)?
    pub degraded: bool,
    /// The per-layer algorithm transitions, when degraded.
    pub degraded_layers: Vec<DegradedLayer>,
    /// Requests currently queued.
    pub queue_depth: usize,
}

impl std::fmt::Display for HealthSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "workers {}/{} live | restarts={} panicked={} queue_depth={} | ",
            self.live_workers, self.workers, self.restarts, self.panicked_requests,
            self.queue_depth,
        )?;
        if self.degraded {
            let list: Vec<String> = self
                .degraded_layers
                .iter()
                .map(|d| format!("layer{} {:?}->{:?}", d.layer, d.from, d.to))
                .collect();
            write!(f, "degraded [{}]", list.join(", "))
        } else {
            write!(f, "healthy")
        }
    }
}

/// A running inference server over a shared [`Engine`].
pub struct Server {
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    engine: Arc<Engine>,
    hwc: (usize, usize, usize),
    next_id: Arc<AtomicU64>,
    costs: Arc<BatchCosts>,
    admission: AdmissionPolicy,
    n_workers: usize,
    slo: Option<Duration>,
    sup: Arc<Supervision>,
    supervisor: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start worker threads; each owns a [`Session`](crate::engine::Session)
    /// of `engine`.
    ///
    /// Intra-op parallelism is divided, not multiplied: the engine's
    /// thread budget is split across the workers (rounding *up*, so the
    /// pool stays fully subscribed when the division is uneven), and
    /// every session shares the engine's one persistent pool. A config
    /// that would hand any worker a zero-thread share is refused with a
    /// typed [`ServerError`] instead of being silently clamped.
    pub fn start(engine: Arc<Engine>, cfg: ServerConfig) -> Result<Server, ServerError> {
        if cfg.workers == 0 {
            return Err(ServerError::NoWorkers);
        }
        let threads = engine.context().threads();
        if threads < cfg.workers {
            return Err(ServerError::InsufficientThreads {
                workers: cfg.workers,
                threads,
            });
        }
        let per_worker_threads = threads.div_ceil(cfg.workers);
        let queue = Arc::new(RequestQueue::new(cfg.queue_depth));
        let metrics = Arc::new(Metrics::with_workers(cfg.workers));
        let costs = Arc::new(BatchCosts::from_engine(&engine));
        let admission = AdmissionPolicy {
            margin: cfg.margin,
            ..AdmissionPolicy::for_capacity(cfg.queue_depth)
        };
        let policy = SloPolicy {
            slo: cfg.slo,
            max_wait: cfg.max_wait,
            margin: cfg.margin,
        };
        let hwc = engine.input_hwc();
        let ctx = WorkerCtx {
            queue: Arc::clone(&queue),
            metrics: Arc::clone(&metrics),
            engine: Arc::clone(&engine),
            costs: Arc::clone(&costs),
            policy,
            threads: per_worker_threads,
        };
        let sup = Arc::new(Supervision {
            restarts: AtomicU64::new(0),
            live: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        });
        let handles: Vec<Option<std::thread::JoinHandle<()>>> =
            (0..cfg.workers).map(|wid| Some(spawn_worker(&ctx, &sup, wid))).collect();
        let supervisor = {
            let sup = Arc::clone(&sup);
            std::thread::Builder::new()
                .name("mec-serve-supervisor".into())
                .spawn(move || supervisor_loop(ctx, sup, handles))
                .expect("spawn server supervisor")
        };
        Ok(Server {
            queue,
            metrics,
            engine,
            hwc,
            next_id: Arc::new(AtomicU64::new(0)),
            costs,
            admission,
            n_workers: cfg.workers,
            slo: cfg.slo,
            sup,
            supervisor: Some(supervisor),
        })
    }

    pub fn client(&self) -> Client {
        Client {
            queue: Arc::clone(&self.queue),
            metrics: Arc::clone(&self.metrics),
            next_id: Arc::clone(&self.next_id),
            hwc: self.hwc,
            costs: Arc::clone(&self.costs),
            admission: self.admission.clone(),
            workers: self.n_workers,
            slo: self.slo,
        }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Fault-domain health: live/configured workers, respawn count,
    /// panicked-request count, degradation state, queue depth.
    pub fn health(&self) -> HealthSnapshot {
        HealthSnapshot {
            workers: self.n_workers,
            live_workers: self.sup.live.load(Ordering::Acquire),
            restarts: self.sup.restarts.load(Ordering::Acquire),
            panicked_requests: self.metrics.panicked.load(Ordering::Relaxed),
            degraded: self.engine.is_degraded(),
            degraded_layers: self.engine.degraded_layers(),
            queue_depth: self.queue.len(),
        }
    }

    /// Graceful drain: stop accepting (subsequent submits get
    /// [`SubmitError::ShuttingDown`]), stop respawning, serve everything
    /// already admitted, join the supervisor (which joins the workers).
    pub fn shutdown(mut self) -> Arc<Metrics> {
        self.sup.shutdown.store(true, Ordering::Release);
        self.queue.close();
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
        Arc::clone(&self.metrics)
    }
}

/// Stringify a caught panic payload (`&'static str` from `panic!(".."),`
/// `String` from a formatted `panic!`, opaque otherwise).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn worker_loop(ctx: &WorkerCtx, wid: usize) {
    let WorkerCtx { queue, metrics, engine, costs, policy, threads } = ctx;
    // Per-worker session: engine-sized arena, lock-free steady state,
    // thread budget = its share of the engine's pool.
    let wm = metrics.worker(wid);
    let batcher = AdaptiveBatcher::new(queue, Arc::clone(costs), policy.clone());
    let mut session = engine.session_with_threads(*threads);
    let (h, w, c) = engine.input_hwc();
    let per = h * w * c;
    loop {
        // Fault site: a panic here kills the whole worker thread
        // *between* batches — it holds no requests at this point, so
        // conservation is untouched, and the supervisor observes a
        // clean death to respawn from.
        crate::faultpoint!("serve.worker");
        let Some(batch) = batcher.next_batch() else { break };
        if batch.is_empty() {
            continue;
        }
        // Defensive re-validation: `Client::submit` rejects malformed
        // samples at enqueue, but requests can be pushed onto the queue
        // directly. A bad one gets an error reply — never a worker
        // abort.
        let mut valid = Vec::with_capacity(batch.len());
        for req in batch {
            if req.sample.len() != per {
                let resp = Response {
                    id: req.id,
                    batch_size: 0,
                    result: Err(ServeError::Engine(EngineError::SampleSize {
                        expected: per,
                        got: req.sample.len(),
                    })),
                };
                // This request bypassed Client::submit (which would have
                // rejected it at enqueue), so the client-side counters
                // never saw it: account it here as a rejected request —
                // not a served response — to keep the
                // `requests == responses + rejected` conservation and
                // the throughput figure honest for every ingress path.
                metrics.requests.fetch_add(1, Ordering::Relaxed);
                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(resp);
            } else {
                valid.push(req);
            }
        }
        // Dispatch-time shedding: a deadline that was feasible at
        // admission can die waiting in the queue. Running it anyway
        // would be compute spent on a reply nobody can use — shed with
        // the same typed reason admission uses.
        let now = Instant::now();
        let est =
            Duration::from_nanos(costs.estimate_ns(costs.covering(valid.len())).max(0.0) as u64);
        let mut feasible = Vec::with_capacity(valid.len());
        for req in valid {
            if infeasible(now, req.deadline, est) {
                let budget_ns = req
                    .deadline
                    .map(|d| d.saturating_duration_since(now).as_nanos() as u64)
                    .unwrap_or(0);
                let reason = ShedReason::DeadlineInfeasible {
                    needed_ns: est.as_nanos() as u64,
                    budget_ns,
                };
                metrics.record_shed_response(reason);
                let _ = req.reply.send(Response {
                    id: req.id,
                    batch_size: 0,
                    result: Err(ServeError::Shed(reason)),
                });
            } else {
                feasible.push(req);
            }
        }
        if feasible.is_empty() {
            continue;
        }
        // Padding-free dispatch: cut the collected batch into the
        // engine's pinned shapes (largest first) so every forward runs
        // a pre-planned geometry.
        let mut remaining = feasible;
        for chunk_len in split_into_pinned(remaining.len(), costs.sizes()) {
            let chunk: Vec<Request> = remaining.drain(..chunk_len).collect();
            // Fault site: compute-delay injection just before dispatch
            // (models a stalled worker without killing anything).
            crate::faultpoint!("serve.dispatch");
            let dispatch_start = Instant::now();
            // Per-request panic containment: the forward pass runs under
            // `catch_unwind`, so a panicking layer (a kernel bug, or an
            // injected `engine.forward` fault) costs exactly this chunk —
            // every request of it still gets a typed reply, and the
            // worker rebuilds its session and keeps serving. The engine's
            // thread pool survives the unwind un-wedged (its submit path
            // re-raises only after releasing the dispatch lock).
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                assemble_batch((h, w, c), &chunk).and_then(|input| session.predict_batch(&input))
            }));
            match outcome {
                Err(payload) => {
                    let layer = crate::fault::take_panic_layer();
                    let msg = panic_message(payload.as_ref());
                    for req in &chunk {
                        metrics.record_panicked_response();
                        let _ = req.reply.send(Response {
                            id: req.id,
                            batch_size: 0,
                            result: Err(ServeError::Panicked {
                                layer,
                                payload: msg.clone(),
                            }),
                        });
                    }
                    // The unwind may have left activation slots checked
                    // out of the session's arena (take/put is not
                    // unwind-safe by design); a fresh session is cheap —
                    // plans and prepacks are shared via the engine.
                    session = engine.session_with_threads(*threads);
                }
                Ok(Ok(preds)) => {
                    let compute = dispatch_start.elapsed();
                    let forward_ns = compute.as_nanos() as f64;
                    metrics.record_batch(chunk_len, forward_ns);
                    // Refine the scheduler's estimate with reality.
                    costs.observe(chunk_len, forward_ns);
                    for (req, pred) in chunk.iter().zip(preds) {
                        let queue_wait =
                            dispatch_start.saturating_duration_since(req.enqueued_at);
                        let total = req.enqueued_at.elapsed();
                        let met = req.deadline.map(|d| Instant::now() <= d);
                        wm.record_served(queue_wait, compute, total, met);
                        metrics.record_latency(total.as_nanos() as f64);
                        let _ = req.reply.send(Response {
                            id: req.id,
                            batch_size: chunk_len,
                            result: Ok(pred),
                        }); // receiver may have given up
                    }
                }
                // Unreachable after the per-request validation above, but
                // a worker must survive anything: reply the typed error.
                Ok(Err(e)) => {
                    for req in &chunk {
                        metrics.record_latency(req.enqueued_at.elapsed().as_nanos() as f64);
                        let _ = req.reply.send(Response {
                            id: req.id,
                            batch_size: 0,
                            result: Err(ServeError::Engine(e.clone())),
                        });
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::AlgoKind;
    use crate::model::{Layer, Model};
    use crate::tensor::{Kernel, KernelShape};
    use crate::util::Rng;

    fn tiny_model() -> Model {
        let mut rng = Rng::new(77);
        Model::new(
            "serve-test",
            (6, 6, 1),
            vec![
                Layer::Conv {
                    kernel: Kernel::random(KernelShape::new(3, 3, 1, 2), &mut rng),
                    bias: vec![0.0; 2],
                    sh: 1,
                    sw: 1,
                    ph: 1,
                    pw: 1,
                },
                Layer::Relu,
                Layer::Flatten,
                Layer::Dense {
                    w: {
                        let mut w = vec![0.0; 72 * 3];
                        rng.fill_uniform(&mut w, -0.3, 0.3);
                        w
                    },
                    bias: vec![0.0; 3],
                    d_in: 72,
                    d_out: 3,
                },
                Layer::Softmax,
            ],
        )
    }

    fn tiny_engine() -> Arc<Engine> {
        Arc::new(
            Engine::builder(tiny_model())
                .algo_override(0, AlgoKind::Mec)
                .pin_batch_sizes(&[1, 2, 4, 8])
                .build()
                .expect("tiny model builds"),
        )
    }

    #[test]
    fn serves_and_answers() {
        let server = Server::start(tiny_engine(), ServerConfig::default()).expect("server starts");
        let client = server.client();
        let mut rng = Rng::new(1);
        let mut sample = vec![0.0; 36];
        rng.fill_uniform(&mut sample, 0.0, 1.0);
        let resp = client.infer(sample).unwrap();
        let pred = resp.result.expect("valid request succeeds");
        assert_eq!(pred.scores.len(), 3);
        assert!(pred.class < 3);
        let metrics = server.shutdown();
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn zero_workers_is_a_typed_error() {
        let err = Server::start(
            tiny_engine(),
            ServerConfig { workers: 0, ..ServerConfig::default() },
        )
        .unwrap_err();
        assert_eq!(err, ServerError::NoWorkers);
    }

    #[test]
    fn more_workers_than_threads_is_a_typed_error() {
        // A 1-thread engine cannot give 4 workers a thread each — the
        // old behaviour silently clamped every worker to 1 thread and
        // oversubscribed the pool 4×.
        let err = Server::start(
            tiny_engine(),
            ServerConfig { workers: 4, ..ServerConfig::default() },
        )
        .unwrap_err();
        assert_eq!(err, ServerError::InsufficientThreads { workers: 4, threads: 1 });
    }

    #[test]
    fn batch_answers_match_standalone_session() {
        // Responses through the server must equal a solo session.
        let engine = tiny_engine();
        let server = Server::start(
            Arc::clone(&engine),
            ServerConfig {
                max_wait: Duration::from_millis(20),
                ..ServerConfig::default()
            },
        )
        .expect("server starts");
        let client = server.client();
        let mut rng = Rng::new(5);
        let samples: Vec<Vec<f32>> = (0..6)
            .map(|_| {
                let mut s = vec![0.0; 36];
                rng.fill_uniform(&mut s, -1.0, 1.0);
                s
            })
            .collect();
        let rxs: Vec<_> = samples
            .iter()
            .map(|s| client.submit(s.clone()).unwrap())
            .collect();
        let responses: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        server.shutdown();
        let mut solo = engine.session();
        for (s, resp) in samples.iter().zip(&responses) {
            let got = resp.prediction().expect("valid request succeeds");
            let want = solo.infer(s).unwrap();
            crate::util::assert_allclose(&got.scores, &want.scores, 1e-4, "server vs solo");
        }
    }

    #[test]
    fn malformed_submit_is_rejected_at_enqueue() {
        let server = Server::start(tiny_engine(), ServerConfig::default()).expect("server starts");
        let client = server.client();
        let err = client.submit(vec![0.0; 7]).unwrap_err();
        assert_eq!(
            err,
            SubmitError::Invalid(EngineError::SampleSize { expected: 36, got: 7 })
        );
        // A valid request still works afterwards.
        assert!(client.infer(vec![0.1; 36]).unwrap().result.is_ok());
        let metrics = server.shutdown();
        // Conservation: the malformed request counts as rejected.
        assert_eq!(metrics.requests.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn malformed_direct_push_gets_error_response_and_worker_survives() {
        // Bypass the client's validation by pushing onto the queue
        // directly: the worker must answer with an error Response (not
        // abort) and keep serving valid requests afterwards.
        let server = Server::start(tiny_engine(), ServerConfig::default()).expect("server starts");
        let (tx, rx) = mpsc::channel();
        server
            .queue
            .push(Request {
                id: 999,
                sample: vec![0.0; 5],
                enqueued_at: Instant::now(),
                deadline: None,
                reply: tx,
            })
            .unwrap();
        let resp = rx.recv().expect("malformed request still gets a reply");
        assert_eq!(resp.id, 999);
        assert_eq!(resp.batch_size, 0);
        assert_eq!(
            resp.result,
            Err(ServeError::Engine(EngineError::SampleSize { expected: 36, got: 5 }))
        );
        // The worker thread is alive and serving.
        let client = server.client();
        assert!(client.infer(vec![0.2; 36]).unwrap().result.is_ok());
        let metrics = server.shutdown();
        // Conservation holds even for the direct-ingress path: the
        // worker accounted the malformed request as rejected, not as a
        // served response.
        assert_eq!(metrics.requests.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn dynamic_batching_groups_requests() {
        let server = Server::start(
            tiny_engine(),
            ServerConfig {
                max_wait: Duration::from_millis(50),
                ..ServerConfig::default()
            },
        )
        .expect("server starts");
        let client = server.client();
        let rxs: Vec<_> = (0..8)
            .map(|_| client.submit(vec![0.5; 36]).unwrap())
            .collect();
        let batch_sizes: Vec<usize> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().batch_size).collect();
        let metrics = server.shutdown();
        // All 8 should have been served; at least one batch had > 1 request.
        assert_eq!(metrics.responses.load(Ordering::Relaxed), 8);
        assert!(
            batch_sizes.iter().any(|&b| b > 1),
            "expected dynamic batching to form a multi-request batch, got {batch_sizes:?}"
        );
        // Every batch size the server dispatched is a pinned shape.
        assert!(
            batch_sizes.iter().all(|b| [1, 2, 4, 8].contains(b)),
            "non-pinned dispatch shape in {batch_sizes:?}"
        );
    }

    #[test]
    fn workers_share_one_engine_pool_and_spawn_nothing_in_steady_state() {
        // Oversubscription fix: a 4-thread engine serving through 2
        // workers gives each session a 2-thread share of the ONE engine
        // pool, and serving traffic never spawns OS threads beyond the
        // pool built at engine build time.
        let engine = Arc::new(
            Engine::builder(tiny_model())
                .algo_override(0, AlgoKind::Mec)
                .pin_batch_sizes(&[1, 2, 4, 8])
                .threads(4)
                .build()
                .expect("tiny model builds"),
        );
        assert_eq!(engine.pool_threads_spawned(), 3, "pool = threads - 1");
        let server = Server::start(
            Arc::clone(&engine),
            ServerConfig {
                workers: 2,
                ..ServerConfig::default()
            },
        )
        .expect("server starts");
        let client = server.client();
        for _ in 0..4 {
            assert!(client.infer(vec![0.3; 36]).unwrap().result.is_ok());
        }
        let spawned = engine.pool_threads_spawned();
        for _ in 0..8 {
            assert!(client.infer(vec![0.3; 36]).unwrap().result.is_ok());
        }
        assert_eq!(
            engine.pool_threads_spawned(),
            spawned,
            "steady-state serving must not spawn OS threads"
        );
        server.shutdown();
    }

    #[test]
    fn shutdown_is_clean_under_load() {
        let server = Server::start(tiny_engine(), ServerConfig::default()).expect("server starts");
        let client = server.client();
        for _ in 0..20 {
            let _ = client.submit(vec![0.1; 36]);
        }
        let metrics = server.shutdown();
        // Everything accepted was answered (drain semantics).
        assert_eq!(
            metrics.responses.load(Ordering::Relaxed)
                + metrics.rejected.load(Ordering::Relaxed),
            metrics.requests.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn submit_after_shutdown_says_shutting_down() {
        let server = Server::start(tiny_engine(), ServerConfig::default()).expect("server starts");
        let client = server.client();
        server.shutdown();
        assert_eq!(
            client.submit(vec![0.1; 36]).unwrap_err(),
            SubmitError::ShuttingDown
        );
    }

    #[test]
    fn hopeless_deadline_is_shed_at_submit() {
        let server = Server::start(tiny_engine(), ServerConfig::default()).expect("server starts");
        let client = server.client();
        // A deadline already in the past can never be met: admission
        // must shed it deterministically (the margin alone exceeds the
        // zero budget).
        let err = client
            .submit_with_deadline(vec![0.1; 36], Some(Instant::now()))
            .unwrap_err();
        assert!(
            matches!(err, SubmitError::Shed(ShedReason::DeadlineInfeasible { .. })),
            "got {err:?}"
        );
        let metrics = server.shutdown();
        assert_eq!(metrics.shed_deadline.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 1);
    }
}
