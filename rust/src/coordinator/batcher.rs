//! Dynamic batching policy (legacy static variant).
//!
//! Classic serving trade-off: larger batches amortize per-call overhead
//! (and steer MEC toward its Solution A regime), smaller batches cut
//! tail latency. The batcher waits at most `max_delay` for up to
//! `max_batch` requests — whichever fills first wins.
//!
//! The server path has moved to the deadline-driven
//! [`AdaptiveBatcher`](crate::serving::AdaptiveBatcher), which replaces
//! the fixed `max_batch`/`max_delay` pair with per-request deadlines
//! and the engine's pinned batch shapes. This static batcher stays as
//! the policy-free baseline for stress and property tests.

use super::queue::RequestQueue;
use super::Request;
use std::time::{Duration, Instant};

/// Batch-forming policy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Hard cap on batch size (paper's Server runs use 32).
    pub max_batch: usize,
    /// Max time the first request of a batch may wait for company.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_delay: Duration::from_millis(2),
        }
    }
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_delay: Duration) -> BatchPolicy {
        BatchPolicy {
            max_batch: max_batch.max(1),
            max_delay,
        }
    }

    /// Mobile-style: no batching at all.
    pub fn no_batching() -> BatchPolicy {
        BatchPolicy::new(1, Duration::ZERO)
    }
}

/// Pulls batches off a queue according to a policy.
pub struct Batcher<'q> {
    queue: &'q RequestQueue,
    policy: BatchPolicy,
}

impl<'q> Batcher<'q> {
    pub fn new(queue: &'q RequestQueue, policy: BatchPolicy) -> Batcher<'q> {
        Batcher { queue, policy }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Form the next batch: block for the first request (long poll),
    /// then top up until `max_batch` or `max_delay` from the first
    /// request's arrival. `None` = queue closed and drained.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        // Long-poll for the first request(s).
        let mut batch = loop {
            match self
                .queue
                .pop_up_to(self.policy.max_batch, Instant::now() + Duration::from_millis(50))
            {
                None => return None,
                Some(v) if v.is_empty() => continue,
                Some(v) => break v,
            }
        };
        // Top up until the delay budget expires.
        let deadline = Instant::now() + self.policy.max_delay;
        while batch.len() < self.policy.max_batch {
            match self.queue.pop_up_to(self.policy.max_batch - batch.len(), deadline) {
                None => break,
                Some(v) if v.is_empty() => break, // deadline hit
                Some(mut v) => batch.append(&mut v),
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{mpsc, Arc};

    fn req(id: u64) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id,
            sample: vec![],
            enqueued_at: Instant::now(),
            deadline: None,
            reply: tx,
        }
    }

    #[test]
    fn batches_cap_at_max_batch() {
        let q = RequestQueue::new(64);
        for i in 0..10 {
            q.push(req(i)).unwrap();
        }
        let b = Batcher::new(&q, BatchPolicy::new(4, Duration::ZERO));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].id, 0);
    }

    #[test]
    fn no_batching_policy_returns_singletons() {
        let q = RequestQueue::new(8);
        for i in 0..3 {
            q.push(req(i)).unwrap();
        }
        let b = Batcher::new(&q, BatchPolicy::no_batching());
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert_eq!(b.next_batch().unwrap().len(), 1);
    }

    #[test]
    fn delay_tops_up_late_arrivals() {
        let q = Arc::new(RequestQueue::new(8));
        q.push(req(0)).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.push(req(1)).unwrap();
        });
        let b = Batcher::new(&q, BatchPolicy::new(8, Duration::from_millis(200)));
        let batch = b.next_batch().unwrap();
        h.join().unwrap();
        assert_eq!(batch.len(), 2, "late arrival should join the batch");
    }

    #[test]
    fn closed_queue_ends_batching() {
        let q = RequestQueue::new(8);
        q.close();
        let b = Batcher::new(&q, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }
}
