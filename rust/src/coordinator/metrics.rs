//! Serving metrics: lock-free counters + per-worker latency histograms.
//!
//! The record path is wait-free end to end: global counters are relaxed
//! atomics, the global latency/forward histograms are
//! [`AtomicHistogram`]s (the old `Mutex<Histogram>` serialized every
//! reply across all workers), and each worker additionally owns a
//! [`WorkerMetrics`] recording queue-wait / compute / total separately.
//! Readers merge everything into a [`RawSnapshot`] /
//! [`ServingSnapshot`].
//!
//! Conservation invariant: `requests == responses + rejected` once the
//! server has drained — submit-time sheds count as `rejected`,
//! dispatch-time sheds get an error reply and count as `responses`.

use crate::serving::histogram::AtomicHistogram;
use crate::serving::metrics::{RawSnapshot, ServingSnapshot, WorkerMetrics};
use crate::serving::ShedReason;
use crate::util::stats::fmt_ns;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Thread-safe serving metrics.
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    /// Requests shed with [`ShedReason::QueueFull`].
    pub shed_queue_full: AtomicU64,
    /// Requests shed with [`ShedReason::DeadlineInfeasible`] (at submit
    /// or at dispatch).
    pub shed_deadline: AtomicU64,
    /// Requests answered with [`ServeError::Panicked`](super::ServeError::Panicked)
    /// — the forward pass panicked and containment converted the panic
    /// into a typed reply. Counted inside `responses` (conservation
    /// holds: a panicked request was still answered).
    pub panicked: AtomicU64,
    batch_size_sum: AtomicU64,
    /// End-to-end latency (enqueue -> reply), ns.
    latency: AtomicHistogram,
    /// Model forward time per batch, ns.
    forward: AtomicHistogram,
    /// One per worker thread (empty for bare `Metrics::new()`).
    workers: Vec<Arc<WorkerMetrics>>,
    started: std::time::Instant,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::with_workers(0)
    }

    /// Metrics surface for a server with `workers` worker threads.
    pub fn with_workers(workers: usize) -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            shed_queue_full: AtomicU64::new(0),
            shed_deadline: AtomicU64::new(0),
            panicked: AtomicU64::new(0),
            batch_size_sum: AtomicU64::new(0),
            latency: AtomicHistogram::new(),
            forward: AtomicHistogram::new(),
            workers: (0..workers).map(|_| Arc::new(WorkerMetrics::new())).collect(),
            started: std::time::Instant::now(),
        }
    }

    /// Worker `wid`'s private recording surface.
    pub fn worker(&self, wid: usize) -> Arc<WorkerMetrics> {
        Arc::clone(&self.workers[wid])
    }

    pub fn record_batch(&self, batch_size: usize, forward_ns: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_size_sum
            .fetch_add(batch_size as u64, Ordering::Relaxed);
        self.forward.record(forward_ns.max(0.0) as u64);
    }

    pub fn record_latency(&self, ns: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.latency.record(ns.max(0.0) as u64);
    }

    /// A request shed at submit: it never entered the queue, so it
    /// counts as `rejected` (conservation: not a response).
    pub fn record_submit_shed(&self, reason: ShedReason) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        self.shed_counter(reason).fetch_add(1, Ordering::Relaxed);
    }

    /// A request shed at dispatch (admitted, then its deadline died in
    /// the queue): the worker replies with a typed error, so it counts
    /// as a response.
    pub fn record_shed_response(&self, reason: ShedReason) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.shed_counter(reason).fetch_add(1, Ordering::Relaxed);
    }

    /// A request whose forward pass panicked: containment replied with
    /// [`ServeError::Panicked`](super::ServeError::Panicked), so it
    /// counts as a response (conservation) *and* bumps the dedicated
    /// `panicked` counter (observability — `Server::health` surfaces
    /// it).
    pub fn record_panicked_response(&self) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.panicked.fetch_add(1, Ordering::Relaxed);
    }

    fn shed_counter(&self, reason: ShedReason) -> &AtomicU64 {
        match reason {
            ShedReason::QueueFull { .. } => &self.shed_queue_full,
            ShedReason::DeadlineInfeasible { .. } => &self.shed_deadline,
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batch_size_sum.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.latency.snapshot().percentile(p) as f64
    }

    pub fn forward_percentile(&self, p: f64) -> f64 {
        self.forward.snapshot().percentile(p) as f64
    }

    /// Served requests per second since start.
    pub fn throughput_rps(&self) -> f64 {
        let served = self.responses.load(Ordering::Relaxed) as f64;
        served / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// Merge all workers' histograms + the shed counters into one
    /// full-resolution snapshot. Baseline-subtractable — the load
    /// generator diffs two of these per sweep point.
    pub fn raw_snapshot(&self) -> RawSnapshot {
        let mut raw = RawSnapshot::empty();
        for w in &self.workers {
            raw.merge(&w.snapshot());
        }
        raw.shed_queue_full = self.shed_queue_full.load(Ordering::Relaxed);
        raw.shed_deadline = self.shed_deadline.load(Ordering::Relaxed);
        raw
    }

    /// Percentile summary of [`raw_snapshot`](Metrics::raw_snapshot) —
    /// what the CLI prints.
    pub fn snapshot(&self) -> ServingSnapshot {
        ServingSnapshot::from_raw(&self.raw_snapshot())
    }

    /// Multi-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "requests={} responses={} rejected={} batches={} mean_batch={:.2}\n\
             shed: queue-full={} deadline={} | panicked={}\n\
             latency p50={} p95={} p99={} | forward p50={} p95={}\n\
             throughput={:.1} req/s",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.shed_queue_full.load(Ordering::Relaxed),
            self.shed_deadline.load(Ordering::Relaxed),
            self.panicked.load(Ordering::Relaxed),
            fmt_ns(self.latency_percentile(50.0)),
            fmt_ns(self.latency_percentile(95.0)),
            fmt_ns(self.latency_percentile(99.0)),
            fmt_ns(self.forward_percentile(50.0)),
            fmt_ns(self.forward_percentile(95.0)),
            self.throughput_rps(),
        )
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4, 1e6);
        m.record_batch(8, 2e6);
        assert_eq!(m.batches.load(Ordering::Relaxed), 2);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn latency_percentiles_populate() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record_latency(i as f64 * 1e5);
        }
        assert!(m.latency_percentile(50.0) > 0.0);
        assert!(m.latency_percentile(99.0) >= m.latency_percentile(50.0));
        assert_eq!(m.responses.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn report_renders() {
        let m = Metrics::new();
        m.record_batch(2, 5e5);
        m.record_latency(1e6);
        let r = m.report();
        assert!(r.contains("mean_batch=2.00"));
        assert!(r.contains("latency"));
    }

    #[test]
    fn shed_accounting_keeps_conservation() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_submit_shed(ShedReason::QueueFull { depth: 1, capacity: 1 });
        m.record_shed_response(ShedReason::DeadlineInfeasible { needed_ns: 2, budget_ns: 1 });
        m.record_latency(1e6);
        assert_eq!(m.shed_queue_full.load(Ordering::Relaxed), 1);
        assert_eq!(m.shed_deadline.load(Ordering::Relaxed), 1);
        assert_eq!(
            m.requests.load(Ordering::Relaxed),
            m.responses.load(Ordering::Relaxed) + m.rejected.load(Ordering::Relaxed)
        );
        let snap = m.snapshot();
        assert_eq!(snap.shed_queue_full, 1);
        assert_eq!(snap.shed_deadline, 1);
    }

    #[test]
    fn worker_histograms_merge_into_snapshot() {
        let m = Metrics::with_workers(2);
        m.worker(0).record_served(
            Duration::from_millis(1),
            Duration::from_millis(2),
            Duration::from_millis(3),
            Some(true),
        );
        m.worker(1).record_served(
            Duration::from_millis(1),
            Duration::from_millis(2),
            Duration::from_millis(3),
            Some(false),
        );
        let raw = m.raw_snapshot();
        assert_eq!(raw.served, 2);
        assert_eq!(raw.total.count(), 2);
        let snap = m.snapshot();
        assert!((snap.slo_attainment - 0.5).abs() < 1e-9);
    }
}
