//! Serving metrics: counters + latency histograms, shared across workers.

use crate::util::stats::{fmt_ns, Histogram};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Thread-safe serving metrics.
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    batch_size_sum: AtomicU64,
    /// End-to-end latency (enqueue -> reply), ns.
    latency: Mutex<Histogram>,
    /// Model forward time per batch, ns.
    forward: Mutex<Histogram>,
    started: std::time::Instant,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_size_sum: AtomicU64::new(0),
            latency: Mutex::new(Histogram::latency_ns()),
            forward: Mutex::new(Histogram::latency_ns()),
            started: std::time::Instant::now(),
        }
    }

    pub fn record_batch(&self, batch_size: usize, forward_ns: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_size_sum
            .fetch_add(batch_size as u64, Ordering::Relaxed);
        self.forward.lock().unwrap().record(forward_ns);
    }

    pub fn record_latency(&self, ns: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.latency.lock().unwrap().record(ns);
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batch_size_sum.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.latency.lock().unwrap().percentile(p)
    }

    pub fn forward_percentile(&self, p: f64) -> f64 {
        self.forward.lock().unwrap().percentile(p)
    }

    /// Served requests per second since start.
    pub fn throughput_rps(&self) -> f64 {
        let served = self.responses.load(Ordering::Relaxed) as f64;
        served / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// Multi-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "requests={} responses={} rejected={} batches={} mean_batch={:.2}\n\
             latency p50={} p95={} p99={} | forward p50={} p95={}\n\
             throughput={:.1} req/s",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            fmt_ns(self.latency_percentile(50.0)),
            fmt_ns(self.latency_percentile(95.0)),
            fmt_ns(self.latency_percentile(99.0)),
            fmt_ns(self.forward_percentile(50.0)),
            fmt_ns(self.forward_percentile(95.0)),
            self.throughput_rps(),
        )
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4, 1e6);
        m.record_batch(8, 2e6);
        assert_eq!(m.batches.load(Ordering::Relaxed), 2);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn latency_percentiles_populate() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record_latency(i as f64 * 1e5);
        }
        assert!(m.latency_percentile(50.0) > 0.0);
        assert!(m.latency_percentile(99.0) >= m.latency_percentile(50.0));
        assert_eq!(m.responses.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn report_renders() {
        let m = Metrics::new();
        m.record_batch(2, 5e5);
        m.record_latency(1e6);
        let r = m.report();
        assert!(r.contains("mean_batch=2.00"));
        assert!(r.contains("latency"));
    }
}
