//! Inference-serving coordinator.
//!
//! The deployment story the paper's intro motivates ("minimize response
//! delay ... on end-point devices"): an always-on server that accepts
//! single-image classification requests, groups them into mini-batches
//! (MEC's Solution A/B dispatch is exactly a batch-size question), runs
//! them through per-worker [`Session`](crate::engine::Session)s of a
//! shared [`Engine`](crate::engine::Engine), and reports
//! latency/throughput.
//!
//! This module owns the *mechanism* — queue, worker threads, reply
//! channels; the scheduling *policy* (deadline-driven batching,
//! admission control, latency histograms) lives in
//! [`serving`](crate::serving) and is wired in by [`server`].
//!
//! Pieces:
//! * [`queue`]  — bounded MPSC request queue with backpressure.
//! * [`server`] — worker threads draining deadline-aware batches
//!   through per-worker engine sessions (shared plans/prepacks, private
//!   arenas), with admission control at submit.
//! * [`metrics`] — lock-free counters + per-worker latency histograms.
//! * [`retry`]  — client-side jittered-backoff retry over retryable
//!   submit rejections (queue-full backpressure).
//! * [`batcher`] — the legacy static batcher (fixed `max_batch` /
//!   `max_delay`), kept for stress tests; the server path uses
//!   [`AdaptiveBatcher`](crate::serving::AdaptiveBatcher).
//!
//! Malformed requests never abort a worker: [`Client::submit`] validates
//! at enqueue ([`SubmitError::Invalid`]), and anything malformed that
//! reaches a worker anyway (e.g. pushed onto the queue directly) is
//! answered with an error [`Response`] instead of panicking.

// Serving plumbing is safe Rust only: no unsafe, ever (enforced — see
// the crate-level unsafe policy and tools/unsafe-audit).
#![forbid(unsafe_code)]

pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod retry;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use queue::{QueueError, RequestQueue};
pub use retry::{retryable, RetryPolicy};
pub use server::{Client, HealthSnapshot, Server, ServerConfig, ServerError};

use crate::engine::{EngineError, Prediction};
use crate::serving::ShedReason;
use crate::tensor::Tensor;
use std::sync::mpsc;
use std::time::Instant;

/// A single inference request: one sample (h·w·c floats), an optional
/// completion deadline, and a oneshot channel for the reply.
pub struct Request {
    pub id: u64,
    pub sample: Vec<f32>,
    pub enqueued_at: Instant,
    /// Absolute completion deadline (submit time + SLO). `None` =
    /// best-effort; the batcher never dispatches early for it and the
    /// server never sheds it on time grounds.
    pub deadline: Option<Instant>,
    pub reply: mpsc::Sender<Response>,
}

/// The server's answer: the prediction, or the typed reason the request
/// could not run.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    /// Batch this request was served in (observability; 0 when the
    /// request never reached a forward pass).
    pub batch_size: usize,
    pub result: Result<Prediction, ServeError>,
}

impl Response {
    /// The prediction, if the request succeeded.
    pub fn prediction(&self) -> Option<&Prediction> {
        self.result.as_ref().ok()
    }
}

/// Why an *admitted* request came back without a prediction.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// The engine refused or failed the forward pass.
    Engine(EngineError),
    /// Shed after admission: the queue wait consumed the deadline
    /// budget, so the worker dropped the request at dispatch instead of
    /// serving it late (always [`ShedReason::DeadlineInfeasible`]).
    Shed(ShedReason),
    /// The forward pass panicked. Containment caught it at the session
    /// boundary: every request of the batch gets this typed reply (it
    /// still counts as a response for the conservation invariant), the
    /// worker rebuilds its session and keeps serving.
    Panicked {
        /// Graph node the panic was attributed to, when the executor's
        /// layer scope recorded one (`None` for panics outside a layer).
        layer: Option<usize>,
        /// The panic payload, stringified (`"..."` from `panic!`).
        payload: String,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Engine(e) => write!(f, "{e}"),
            ServeError::Shed(r) => write!(f, "{r}"),
            ServeError::Panicked { layer: Some(l), payload } => {
                write!(f, "forward panicked at layer {l}: {payload}")
            }
            ServeError::Panicked { layer: None, payload } => {
                write!(f, "forward panicked: {payload}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Why [`Client::submit`] refused a request.
#[derive(Debug, Clone, PartialEq)]
pub enum SubmitError {
    /// Admission control refused the request: queue at capacity, or the
    /// deadline cannot be met given estimated queue wait + compute.
    Shed(ShedReason),
    /// The server is draining; no new work is accepted.
    ShuttingDown,
    /// The sample does not match the engine input — caught at enqueue,
    /// before a worker thread ever sees it.
    Invalid(EngineError),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Shed(r) => write!(f, "{r}"),
            SubmitError::ShuttingDown => write!(f, "server is shutting down"),
            SubmitError::Invalid(e) => write!(f, "invalid request: {e}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Assemble a batch tensor from requests (NHWC, n = requests.len()).
/// Every request must carry exactly h·w·c floats; the first mismatch is
/// reported instead of panicking — the server validates at enqueue and
/// filters defensively before calling this, so one malformed request
/// can never abort a worker thread.
pub fn assemble_batch(
    hwc: (usize, usize, usize),
    requests: &[Request],
) -> Result<Tensor, EngineError> {
    let (h, w, c) = hwc;
    let per = h * w * c;
    let mut data = Vec::with_capacity(requests.len() * per);
    for r in requests {
        if r.sample.len() != per {
            return Err(EngineError::SampleSize {
                expected: per,
                got: r.sample.len(),
            });
        }
        data.extend_from_slice(&r.sample);
    }
    Ok(Tensor::from_vec(
        crate::tensor::Nhwc::new(requests.len(), h, w, c),
        data,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, sample: Vec<f32>) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                id,
                sample,
                enqueued_at: Instant::now(),
                deadline: None,
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn assemble_batch_layout() {
        let reqs: Vec<Request> = (0..3).map(|i| req(i, vec![i as f32; 4]).0).collect();
        let t = assemble_batch((2, 2, 1), &reqs).unwrap();
        assert_eq!(t.shape().n, 3);
        assert_eq!(t.sample(0), &[0.0; 4]);
        assert_eq!(t.sample(2), &[2.0; 4]);
    }

    #[test]
    fn assemble_batch_reports_size_mismatch_instead_of_panicking() {
        let reqs = vec![req(0, vec![0.0; 4]).0, req(1, vec![0.0; 3]).0];
        let err = assemble_batch((2, 2, 1), &reqs).unwrap_err();
        assert_eq!(err, EngineError::SampleSize { expected: 4, got: 3 });
    }
}
