//! Inference-serving coordinator.
//!
//! The deployment story the paper's intro motivates ("minimize response
//! delay ... on end-point devices"): an always-on server that accepts
//! single-image classification requests, groups them into mini-batches
//! (MEC's Solution A/B dispatch is exactly a batch-size question), runs
//! the planned engine, and reports latency/throughput.
//!
//! Pieces:
//! * [`queue`]  — bounded MPSC request queue with backpressure.
//! * [`batcher`] — dynamic batching: wait up to `max_delay` to fill a
//!   batch of `max_batch` (vLLM/Triton-style).
//! * [`server`] — worker threads draining batches through a shared
//!   [`Model`](crate::model::Model), per-worker reusable workspaces.
//! * [`metrics`] — latency histograms + counters.

pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use queue::{QueueError, RequestQueue};
pub use server::{Server, ServerConfig};

use crate::tensor::Tensor;
use std::sync::mpsc;

/// A single inference request: one sample (h·w·c floats) plus a oneshot
/// channel for the reply.
pub struct Request {
    pub id: u64,
    pub sample: Vec<f32>,
    pub enqueued_at: std::time::Instant,
    pub reply: mpsc::Sender<Response>,
}

/// The server's answer.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    /// Class probabilities (or logits if the model has no softmax).
    pub scores: Vec<f32>,
    /// Argmax class.
    pub class: usize,
    /// Batch this request was served in (observability).
    pub batch_size: usize,
}

/// Assemble a batch tensor from requests (NHWC, n = requests.len()).
pub fn assemble_batch(hwc: (usize, usize, usize), requests: &[Request]) -> Tensor {
    let (h, w, c) = hwc;
    let per = h * w * c;
    let mut data = Vec::with_capacity(requests.len() * per);
    for r in requests {
        assert_eq!(r.sample.len(), per, "request {} has wrong sample size", r.id);
        data.extend_from_slice(&r.sample);
    }
    Tensor::from_vec(crate::tensor::Nhwc::new(requests.len(), h, w, c), data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn assemble_batch_layout() {
        let (tx, _rx) = mpsc::channel();
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request {
                id: i,
                sample: vec![i as f32; 4],
                enqueued_at: Instant::now(),
                reply: tx.clone(),
            })
            .collect();
        let t = assemble_batch((2, 2, 1), &reqs);
        assert_eq!(t.shape().n, 3);
        assert_eq!(t.sample(0), &[0.0; 4]);
        assert_eq!(t.sample(2), &[2.0; 4]);
    }
}
