//! Client-side retry: jittered exponential backoff over *retryable*
//! submit failures.
//!
//! Classification — the only transient rejection is
//! [`ShedReason::QueueFull`]: the queue drains as workers serve, so
//! backing off and resubmitting is productive. Everything else is
//! terminal: a deadline-infeasible rejection only gets *worse* with
//! time (the budget shrinks while the estimate doesn't),
//! [`SubmitError::Invalid`] is a caller bug no retry fixes, and
//! [`SubmitError::ShuttingDown`] never reverses.
//!
//! Determinism — jitter draws from a seeded
//! [`SplitMix64`](crate::util::Rng) stream owned by the retry call, not
//! from the wall clock or a global RNG, so a test (or an incident
//! replay) reproduces the exact delay schedule from the seed. The
//! sleeps themselves are injectable
//! ([`Client::submit_with_retry_using`](super::Client::submit_with_retry_using)),
//! so the schedule is testable without ever sleeping.

use super::SubmitError;
use crate::serving::ShedReason;
use crate::util::Rng;
use std::time::Duration;

/// Jittered exponential backoff: `delay(n) = min(base · 2ⁿ, cap)`
/// scaled by a uniform factor in `[1 − jitter, 1 + jitter]`.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts including the first (`1` = no retries).
    pub max_attempts: u32,
    /// Delay before the first retry.
    pub base: Duration,
    /// Upper bound on any single (pre-jitter) delay.
    pub cap: Duration,
    /// Jitter fraction in `[0, 1]`: decorrelates clients that were all
    /// shed by the same full queue, so they don't retry in lockstep and
    /// re-create the spike that shed them.
    pub jitter: f32,
    /// Seed for the deterministic jitter stream.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(50),
            jitter: 0.5,
            seed: 0x5eed,
        }
    }
}

impl RetryPolicy {
    /// The delay before retry number `attempt` (0-based), drawing the
    /// jitter factor from `rng`.
    pub fn delay(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let exp = self.base.saturating_mul(1u32 << attempt.min(20));
        let capped = exp.min(self.cap);
        let factor = 1.0 + self.jitter.clamp(0.0, 1.0) * (2.0 * rng.f32() - 1.0);
        capped.mul_f64(factor.max(0.0) as f64)
    }
}

/// Is this submit failure worth retrying? Only queue-full backpressure
/// — see the module docs for why the rest are terminal.
pub fn retryable(err: &SubmitError) -> bool {
    matches!(err, SubmitError::Shed(ShedReason::QueueFull { .. }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineError;

    #[test]
    fn delays_grow_exponentially_and_cap() {
        let p = RetryPolicy {
            jitter: 0.0, // isolate the exponential schedule
            ..RetryPolicy::default()
        };
        let mut rng = Rng::new(1);
        assert_eq!(p.delay(0, &mut rng), Duration::from_millis(1));
        assert_eq!(p.delay(1, &mut rng), Duration::from_millis(2));
        assert_eq!(p.delay(2, &mut rng), Duration::from_millis(4));
        // Past the cap, the schedule flattens.
        assert_eq!(p.delay(9, &mut rng), p.cap);
        assert_eq!(p.delay(63, &mut rng), p.cap, "huge attempt indices must not overflow");
    }

    #[test]
    fn jitter_is_seed_deterministic_and_bounded() {
        let p = RetryPolicy::default();
        let seq = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..8).map(|i| p.delay(i, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(seq(7), seq(7), "same seed, same schedule");
        assert_ne!(seq(7), seq(8), "different seed, different schedule");
        let mut rng = Rng::new(7);
        for i in 0..8 {
            let d = p.delay(i, &mut rng);
            let nominal = p.base.saturating_mul(1 << i).min(p.cap);
            assert!(d >= nominal.mul_f64(0.5) && d <= nominal.mul_f64(1.5), "jitter within ±50%");
        }
    }

    #[test]
    fn only_queue_full_is_retryable() {
        assert!(retryable(&SubmitError::Shed(ShedReason::QueueFull {
            depth: 4,
            capacity: 4
        })));
        assert!(!retryable(&SubmitError::Shed(ShedReason::DeadlineInfeasible {
            needed_ns: 10,
            budget_ns: 1
        })));
        assert!(!retryable(&SubmitError::ShuttingDown));
        assert!(!retryable(&SubmitError::Invalid(EngineError::SampleSize {
            expected: 4,
            got: 3
        })));
    }
}
