//! The model — a compiled graph IR plus the per-node conv planning
//! machinery and forward executors.
//!
//! The model core is a [`Graph`] (see [`graph_ir`](crate::model::graph_ir)):
//! a DAG of `NodeId`-addressed ops compiled once through the pass
//! pipeline (shape inference → conv+bias+relu fusion → dead-node
//! elimination → liveness). Convolutions are planned per node (once, at
//! load): the [`Planner`](crate::planner::Planner) picks the algorithm
//! under the device [`Budget`], then [`Convolution::plan`] prepacks the
//! node's kernel and fixes its
//! [`WorkspaceLayout`](crate::memory::WorkspaceLayout). The resulting
//! [`ConvPlan`]s are held by the model and reused for every request —
//! the hot path performs no kernel repacking, no filter transforms, and
//! no allocation at all once a batch size has been seen: workspaces come
//! from one shared [`Arena`] sized at the **max** (not the sum) of the
//! per-node workspaces, and activations come from an
//! [`ActivationArena`] whose slots the liveness pass sized at the max
//! over live sets (not the sum over node outputs).
//!
//! Dynamic batching can present batch sizes other than the planned one;
//! plans for those geometries are built lazily on first sight and cached
//! (cuDNN-graph style: one executable per shape).

use crate::conv::{AlgoKind, ConvContext, ConvPlan, Convolution, KernelPrepack};
use crate::memory::{ActivationArena, Arena, Budget};
use crate::model::graph_ir::{ExecGraph, Graph, NodeId, Op};
use crate::model::layer::Layer;
use crate::planner::Planner;
use crate::tensor::quant::QParams;
use crate::tensor::{ConvShape, Kernel, Nhwc, Precision, Tensor};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

/// A CNN over a compiled [`Graph`] with planned convolution algorithms
/// and prepacked per-node [`ConvPlan`]s.
pub struct Model {
    pub name: String,
    /// Spatial input shape per sample (h, w, c); batch dim comes from the
    /// request.
    pub input_hwc: (usize, usize, usize),
    graph: Graph,
    /// The compiled pass-pipeline output: step list + activation slots.
    exec: ExecGraph,
    /// Chosen conv algorithm per node id (None for non-conv nodes).
    /// Behind an `RwLock` so the degradation ladder
    /// ([`Model::replan_with`]) can swap algorithm choices on a shared
    /// (`Arc`ed) model while sessions keep serving; the steady-state
    /// forward never touches it (plans resolve through the session memo).
    plans: RwLock<Vec<Option<AlgoKind>>>,
    /// Prepared plans keyed by (node id, exact conv geometry, build
    /// precision). The planned batch size is populated eagerly by
    /// [`Model::plan`]; other batch sizes (dynamic batching remainders)
    /// fill in lazily. Precision is in the key because a pinned/unplanned
    /// model builds under the caller's context: a q16 forward must never
    /// hand back an f32-planned node or vice versa.
    plan_cache: RwLock<HashMap<(NodeId, ConvShape, Precision), Arc<dyn ConvPlan>>>,
    /// Batch-independent kernel-side prepacks (PackedKernel, Winograd U,
    /// FFT spectra), keyed by (node id, algorithm, build precision):
    /// built once per conv node and `Arc`-shared into every
    /// per-batch-size plan above.
    prepack_cache: RwLock<HashMap<(NodeId, AlgoKind, Precision), Arc<dyn KernelPrepack>>>,
    /// Shared-arena requirement at the planned batch: max over planned
    /// conv nodes of `ConvPlan::workspace_elems`. Atomic so
    /// [`Model::replan_with`] can shrink it on a shared model.
    planned_ws_elems: AtomicUsize,
    /// The context [`Model::plan`] ran under. Lazily-built plans (other
    /// batch sizes) reuse it, so every conv node executes under ONE
    /// consistent context regardless of batch size; `forward`'s ctx then
    /// only affects non-conv ops. `None` until planned (or after
    /// `pin_algo`): plans build under the caller's forward context.
    planned_ctx: Option<ConvContext>,
    /// Calibrated static activation scales per conv node (q16 serving).
    /// When present, the node's plans are built with the scale baked in,
    /// so execute skips the per-call abs-max pass; absent → dynamic.
    act_qparams: HashMap<NodeId, QParams>,
}

/// Cap on cached geometries per conv node: the planned batch size plus
/// a handful of dynamic-batching remainders. Beyond this, plans for
/// unusual batch sizes are built transiently (executed, not cached) so
/// serving memory stays bounded — each cached plan holds its own
/// prepacked kernel operands.
pub const MAX_CACHED_GEOMETRIES_PER_LAYER: usize = 8;

/// A session-local memo of resolved `(node, geometry, precision) →
/// plan` bindings. The model's own plan cache sits behind an `RwLock`
/// (it is shared by every session); a memo in front of it makes a
/// session's steady-state forward lock-free — after the first pass at a
/// batch size, every lookup is a plain `HashMap` hit on thread-owned
/// state. Keyed by the same build precision as the model cache, so a
/// memo reused across contexts can never hand a q16-packed plan to an
/// f32 forward (or vice versa); bounded per node like the model cache.
#[derive(Default)]
pub struct PlanMemo {
    map: HashMap<(NodeId, ConvShape, Precision), Arc<dyn ConvPlan>>,
}

impl PlanMemo {
    pub fn new() -> PlanMemo {
        PlanMemo::default()
    }

    /// Number of memoized (node, geometry) plan bindings.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Drop every memoized binding. Sessions call this when the engine's
    /// degradation epoch moves: the entries point at superseded plans,
    /// and the next forward re-resolves through the model's re-planned
    /// cache (then memoizes again — one locked pass, lock-free after).
    pub fn clear(&mut self) {
        self.map.clear();
    }
}

impl Model {
    /// Compatibility constructor: a sequential chain of `layers` (the
    /// historical `Vec<Layer>` API) — node ids equal layer indices.
    pub fn new(name: &str, input_hwc: (usize, usize, usize), layers: Vec<Layer>) -> Model {
        Model::from_graph(Graph::sequential(name, input_hwc, layers))
    }

    /// The real constructor: compile `graph` through the pass pipeline
    /// (shape inference validates every edge; fusion, DCE and the
    /// liveness pass fix the execution schedule and activation slots).
    pub fn from_graph(graph: Graph) -> Model {
        let exec = graph.compile();
        let plans = RwLock::new(vec![None; graph.node_count()]);
        Model {
            name: graph.name.clone(),
            input_hwc: graph.input_hwc,
            graph,
            exec,
            plans,
            plan_cache: RwLock::new(HashMap::new()),
            prepack_cache: RwLock::new(HashMap::new()),
            planned_ws_elems: AtomicUsize::new(0),
            planned_ctx: None,
            act_qparams: HashMap::new(),
        }
    }

    /// The underlying graph IR (read-only).
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// The compiled execution schedule + activation-slot plan.
    pub fn exec(&self) -> &ExecGraph {
        &self.exec
    }

    /// Number of nodes in the graph (the historical "layer count").
    pub fn node_count(&self) -> usize {
        self.graph.node_count()
    }

    /// Whether node `id` is a convolution (planner/override targets).
    pub fn is_conv(&self, id: NodeId) -> bool {
        id < self.graph.node_count()
            && matches!(self.graph.node(id).op, Op::Layer(Layer::Conv { .. }))
    }

    fn conv_kernel(&self, id: NodeId) -> &Kernel {
        match &self.graph.node(id).op {
            Op::Layer(Layer::Conv { kernel, .. }) => kernel,
            other => panic!("node {id} is {}, not a conv", other.kind()),
        }
    }

    /// Validate the graph by propagating a batch-1 shape; returns the
    /// final output shape.
    pub fn validate(&self) -> Nhwc {
        self.graph.validate()
    }

    /// Output features per sample.
    pub fn output_features(&self) -> usize {
        let s = self.validate();
        s.h * s.w * s.c
    }

    pub fn param_count(&self) -> usize {
        self.graph.param_count()
    }

    /// The exact conv geometry of every compiled conv node at batch size
    /// `batch` (padding applied), in execution order: what the
    /// planner/engine choose algorithms on. Non-conv nodes are skipped.
    pub fn conv_shapes(&self, batch: usize) -> Vec<(NodeId, ConvShape)> {
        self.exec.conv_shapes(&self.graph, batch)
    }

    /// Plan every conv node under `budget` for batch size `batch`: the
    /// planner picks the algorithm on the true batched geometry, then the
    /// algorithm prepacks the node's kernel into a reusable
    /// [`ConvPlan`]. Also sizes the shared arena (max over nodes).
    pub fn plan(&mut self, planner: &Planner, budget: &Budget, ctx: &ConvContext, batch: usize) {
        self.plan_with(ctx, batch, |_, cs| planner.plan(cs, budget, ctx).algo);
    }

    /// [`Model::plan`] with the algorithm choice delegated to `choose`
    /// (node id + exact batched geometry → algorithm). This is the
    /// engine builder's entry point: the choice may come from the cost
    /// model, the autotuner, or a validated per-node override — the
    /// prepack/plan/arena machinery is identical either way.
    pub fn plan_with(
        &mut self,
        ctx: &ConvContext,
        batch: usize,
        choose: impl FnMut(NodeId, &ConvShape) -> AlgoKind,
    ) {
        self.planned_ctx = Some(ctx.clone());
        self.replan_with(batch, choose);
    }

    /// Re-run the prepack/plan/arena-sizing round through a **shared**
    /// reference — the degradation ladder's entry point
    /// ([`Engine::degrade`](crate::engine::Engine::degrade) re-plans the
    /// conv nodes of an `Arc`-shared model onto the zero-workspace
    /// family while sessions keep serving). Plans build under the
    /// context of the original planning round ([`Model::plan_with`] must
    /// have run; falls back to the default context otherwise, matching
    /// [`Model::plan_for`]). Caches are cleared first, so in-flight
    /// forwards resolving a node mid-swap lazily rebuild it under the
    /// new choice; sessions holding memoized plans stay self-consistent
    /// until they observe the engine's degrade epoch and drop the memo.
    /// Returns the new shared-arena requirement (max over conv nodes).
    pub fn replan_with(
        &self,
        batch: usize,
        mut choose: impl FnMut(NodeId, &ConvShape) -> AlgoKind,
    ) -> usize {
        let ctx = self.planned_ctx.clone().unwrap_or_default();
        self.plan_cache.write().unwrap().clear();
        self.prepack_cache.write().unwrap().clear();
        self.planned_ws_elems.store(0, Ordering::Release);
        // Reset stale choices (e.g. a previous pin) so the summary only
        // ever reports what this planning round actually chose.
        let mut new_plans = vec![None; self.graph.node_count()];
        let mut max_ws = 0usize;
        let mut prepared: Vec<((NodeId, ConvShape, Precision), Arc<dyn ConvPlan>)> = Vec::new();
        let mut prepacks: Vec<((NodeId, AlgoKind, Precision), Arc<dyn KernelPrepack>)> = Vec::new();
        for (i, cs) in self.conv_shapes(batch) {
            let chosen = choose(i, &cs);
            new_plans[i] = Some(chosen);
            let kernel = self.conv_kernel(i);
            let algo_impl = chosen.build();
            let node_ctx = self.node_ctx(i, &ctx);
            // One batch-independent prepack per node; every batch size
            // this node ever plans for shares it.
            let pk = algo_impl.prepack(&node_ctx, &cs, kernel);
            let conv_plan: Arc<dyn ConvPlan> =
                Arc::from(algo_impl.plan_shared(&node_ctx, &cs, Arc::clone(&pk)));
            max_ws = max_ws.max(conv_plan.workspace_elems());
            prepared.push(((i, cs, ctx.precision), conv_plan));
            prepacks.push(((i, chosen, ctx.precision), pk));
        }
        *self.plans.write().unwrap() = new_plans;
        self.plan_cache.write().unwrap().extend(prepared);
        self.prepack_cache.write().unwrap().extend(prepacks);
        self.planned_ws_elems.store(max_ws, Ordering::Release);
        max_ws
    }

    /// Pin a single algorithm for all compiled (live) conv nodes
    /// (benchmark mode). Invalidates any prepared plans; they rebuild
    /// lazily.
    pub fn pin_algo(&mut self, algo: AlgoKind) {
        self.plan_cache.write().unwrap().clear();
        self.prepack_cache.write().unwrap().clear();
        self.planned_ws_elems.store(0, Ordering::Release);
        self.planned_ctx = None;
        let mut plans = vec![None; self.graph.node_count()];
        for step in self.exec.steps() {
            if matches!(self.graph.node(step.node).op, Op::Layer(Layer::Conv { .. })) {
                plans[step.node] = Some(algo);
            }
        }
        *self.plans.write().unwrap() = plans;
    }

    /// Install calibrated per-node activation scales (q16 serving): the
    /// plans rebuild with the static scale baked in, so execute skips
    /// the per-call abs-max pass. Clears prepared plans — callers replan
    /// (the engine builder does) or let them rebuild lazily.
    pub fn set_activation_qparams(&mut self, qparams: HashMap<NodeId, QParams>) {
        self.plan_cache.write().unwrap().clear();
        self.prepack_cache.write().unwrap().clear();
        self.act_qparams = qparams;
    }

    /// The calibrated activation scale for conv node `id`, if any.
    pub fn activation_qparams(&self, id: NodeId) -> Option<QParams> {
        self.act_qparams.get(&id).copied()
    }

    /// The context plans for node `id` build under: the planning (or
    /// caller) context plus the node's calibrated activation scale.
    fn node_ctx(&self, id: NodeId, base: &ConvContext) -> ConvContext {
        match self.act_qparams.get(&id) {
            Some(q) => base.clone().with_act_qparams(*q),
            None => base.clone(),
        }
    }

    /// Chosen algorithm per conv node (for reports).
    pub fn plan_summary(&self) -> Vec<(NodeId, AlgoKind)> {
        self.plans
            .read()
            .unwrap()
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.map(|a| (i, a)))
            .collect()
    }

    /// Workspace bytes per prepared conv node (node id, bytes) —
    /// the quantities whose **max** sizes the shared arena.
    pub fn planned_layer_workspaces(&self) -> Vec<(NodeId, usize)> {
        let cache = self.plan_cache.read().unwrap();
        let mut out: Vec<(NodeId, usize)> = cache
            .iter()
            .map(|((i, _, _), p)| (*i, p.workspace_bytes()))
            .collect();
        out.sort_unstable();
        out
    }

    /// Shared-arena floats required at the planned batch size (0 if
    /// [`Model::plan`] has not run — the arena then grows on demand).
    pub fn planned_workspace_elems(&self) -> usize {
        self.planned_ws_elems.load(Ordering::Acquire)
    }

    /// Same in bytes.
    pub fn planned_workspace_bytes(&self) -> usize {
        self.planned_workspace_elems() * std::mem::size_of::<f32>()
    }

    /// An [`Arena`] pre-sized for this model's planned conv nodes — what
    /// each serving worker owns. Peak tracked bytes of the workspace side
    /// of a forward pass equal the max (not the sum) of per-node
    /// workspaces.
    pub fn sized_arena(&self) -> Arena {
        Arena::with_capacity(self.planned_workspace_elems())
    }

    /// Activation-arena floats the liveness plan needs at `batch`
    /// (Σ over slots; slots scale linearly with the batch dim).
    pub fn activation_elems(&self, batch: usize) -> usize {
        self.exec.arena_elems(batch)
    }

    /// Same in bytes.
    pub fn activation_bytes(&self, batch: usize) -> usize {
        self.activation_elems(batch) * std::mem::size_of::<f32>()
    }

    /// The liveness plan's max live-set bytes at `batch` — the analytic
    /// lower bound the slot packing is asserted against (diamond test).
    pub fn max_live_bytes(&self, batch: usize) -> usize {
        self.exec.max_live_elems(batch) * std::mem::size_of::<f32>()
    }

    /// An [`ActivationArena`] pre-sized for batch size `batch`.
    pub fn sized_activation_arena(&self, batch: usize) -> ActivationArena {
        let n = batch.max(1);
        let slots: Vec<usize> = self.exec.slot_elems().iter().map(|e| e * n).collect();
        ActivationArena::with_slots(&slots)
    }

    /// Eagerly build (and cache) every conv node's plan for batch size
    /// `batch`, sharing the per-node kernel prepacks already in the
    /// cache. Returns the max workspace elems over conv nodes at that
    /// batch — what an engine pinning several batch sizes folds into its
    /// arena sizing. Plans build under the planning context, so
    /// [`Model::plan`]/[`Model::plan_with`] must have run first.
    pub fn prepare_batch(&self, batch: usize) -> usize {
        let ctx = self.planned_ctx.clone().unwrap_or_default();
        let mut max_ws = 0usize;
        for (i, cs) in self.conv_shapes(batch) {
            let kernel = self.conv_kernel(i);
            let plan = self.plan_for(i, &cs, &ctx, kernel);
            max_ws = max_ws.max(plan.workspace_elems());
        }
        max_ws
    }

    /// Fetch (or lazily build) the prepared plan for conv node `idx` on
    /// geometry `cs`. The kernel-side prepack is fetched from (or
    /// inserted into) the per-node prepack cache, so every geometry of a
    /// node — including transient over-cap ones — shares one prepacked
    /// copy.
    fn plan_for(
        &self,
        idx: NodeId,
        cs: &ConvShape,
        ctx: &ConvContext,
        kernel: &Kernel,
    ) -> Arc<dyn ConvPlan> {
        // Build under the planning context so cached and lazily-built
        // plans agree on threads / MEC T / FFT cache cap / precision.
        let build_ctx = self.planned_ctx.as_ref().unwrap_or(ctx);
        let key = (idx, *cs, build_ctx.precision);
        if let Some(p) = self.plan_cache.read().unwrap().get(&key) {
            return Arc::clone(p);
        }
        let algo = self.plans.read().unwrap()[idx].unwrap_or(AlgoKind::Mec);
        let algo_impl = algo.build();
        let node_ctx = self.node_ctx(idx, build_ctx);
        let pk_key = (idx, algo, build_ctx.precision);
        let pk = {
            let cached = self.prepack_cache.read().unwrap().get(&pk_key).cloned();
            match cached {
                Some(p) => p,
                None => {
                    let built = algo_impl.prepack(&node_ctx, cs, kernel);
                    let mut cache = self.prepack_cache.write().unwrap();
                    Arc::clone(cache.entry(pk_key).or_insert(built))
                }
            }
        };
        let built: Arc<dyn ConvPlan> = Arc::from(algo_impl.plan_shared(&node_ctx, cs, pk));
        let mut cache = self.plan_cache.write().unwrap();
        if !cache.contains_key(&key)
            && cache.keys().filter(|(i, _, _)| *i == idx).count()
                >= MAX_CACHED_GEOMETRIES_PER_LAYER
        {
            // Bounded cache: execute this one transiently instead of
            // holding yet another plan per odd batch size (its prepack is
            // still the shared one).
            return built;
        }
        Arc::clone(cache.entry(key).or_insert(built))
    }

    /// Prepared plans for conv node `idx`, one per cached geometry
    /// (tests/observability — the prepack-sharing assertions compare
    /// their [`ConvPlan::shared_prepack`] pointers).
    pub fn cached_plans_for_layer(&self, idx: NodeId) -> Vec<Arc<dyn ConvPlan>> {
        self.plan_cache
            .read()
            .unwrap()
            .iter()
            .filter(|((i, _, _), _)| *i == idx)
            .map(|(_, p)| Arc::clone(p))
            .collect()
    }

    /// Number of cached kernel-side prepacks (≤ one per conv node).
    pub fn cached_prepacks(&self) -> usize {
        self.prepack_cache.read().unwrap().len()
    }

    /// Run a forward pass on a batch. Returns the final activation
    /// (logits or probabilities, depending on the graph output). Conv
    /// workspaces come out of `arena`; activations come out of a
    /// transient [`ActivationArena`] (tracked, then released) — callers
    /// on the serving path hold a persistent one via
    /// [`Model::forward_with`] so steady state allocates nothing.
    pub fn forward(&self, ctx: &ConvContext, batch: &Tensor, arena: &mut Arena) -> Tensor {
        let mut acts = ActivationArena::new();
        self.forward_with(ctx, batch, arena, &mut acts, None)
    }

    /// [`Model::forward`] with a caller-owned [`PlanMemo`] in front of
    /// the model's `RwLock`ed plan cache: once the memo has seen a batch
    /// size, the pass resolves every conv plan with a plain `HashMap`
    /// lookup — no locks on the hot path.
    pub fn forward_memo(
        &self,
        ctx: &ConvContext,
        batch: &Tensor,
        arena: &mut Arena,
        memo: &mut PlanMemo,
    ) -> Tensor {
        let mut acts = ActivationArena::new();
        self.forward_with(ctx, batch, arena, &mut acts, Some(memo))
    }

    /// The full-control forward: caller-owned workspace arena,
    /// activation arena, and (optionally) plan memo. This is what
    /// [`Session`](crate::engine::Session) runs — with all three
    /// persistent, the steady-state hot path takes no locks and performs
    /// zero tracked allocations.
    pub fn forward_with(
        &self,
        ctx: &ConvContext,
        batch: &Tensor,
        arena: &mut Arena,
        acts: &mut ActivationArena,
        memo: Option<&mut PlanMemo>,
    ) -> Tensor {
        self.run(ctx, batch, arena, acts, memo, None)
    }

    /// [`Model::forward_with`] that also hands every conv node's input
    /// tensor to `observe` before it is lowered — the calibration hook
    /// the engine builder uses to record per-node activation ranges.
    pub fn forward_observing(
        &self,
        ctx: &ConvContext,
        batch: &Tensor,
        arena: &mut Arena,
        acts: &mut ActivationArena,
        observe: &mut dyn FnMut(NodeId, &Tensor),
    ) -> Tensor {
        self.run(ctx, batch, arena, acts, None, Some(observe))
    }

    fn run(
        &self,
        ctx: &ConvContext,
        batch: &Tensor,
        arena: &mut Arena,
        acts: &mut ActivationArena,
        mut memo: Option<&mut PlanMemo>,
        observe: Option<&mut dyn FnMut(NodeId, &Tensor)>,
    ) -> Tensor {
        let prec = self.planned_ctx.as_ref().unwrap_or(ctx).precision;
        let mut resolve = |idx: NodeId, cs: &ConvShape, kernel: &Kernel| -> Arc<dyn ConvPlan> {
            match memo.as_deref_mut() {
                Some(memo) => {
                    // Same build precision plan_for would resolve, so the
                    // memo key agrees with the model cache.
                    match memo.map.get(&(idx, *cs, prec)) {
                        Some(p) => Arc::clone(p),
                        None => {
                            let p = self.plan_for(idx, cs, ctx, kernel);
                            // Same per-node bound as the model cache:
                            // odd batch sizes beyond it stay transient.
                            if memo.map.keys().filter(|(i, _, _)| *i == idx).count()
                                < MAX_CACHED_GEOMETRIES_PER_LAYER
                            {
                                memo.map.insert((idx, *cs, prec), Arc::clone(&p));
                            }
                            p
                        }
                    }
                }
                None => self.plan_for(idx, cs, ctx, kernel),
            }
        };
        self.exec
            .run(&self.graph, ctx, batch, arena, acts, &mut resolve, observe)
    }

    /// Argmax class per sample of the final activation.
    pub fn predict(&self, ctx: &ConvContext, batch: &Tensor, arena: &mut Arena) -> Vec<usize> {
        let out = self.forward(ctx, batch, arena);
        let c = out.shape().c;
        out.data()
            .chunks_exact(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::graph_ir::GraphBuilder;
    use crate::tensor::{Kernel, KernelShape};
    use crate::util::Rng;

    fn tiny_model() -> Model {
        let mut rng = Rng::new(3);
        Model::new(
            "tiny",
            (8, 8, 1),
            vec![
                Layer::Conv {
                    kernel: Kernel::random(KernelShape::new(3, 3, 1, 4), &mut rng),
                    bias: vec![0.1; 4],
                    sh: 1,
                    sw: 1,
                    ph: 1,
                    pw: 1,
                },
                Layer::Relu,
                Layer::MaxPool { k: 2, s: 2 },
                Layer::Flatten,
                Layer::Dense {
                    w: {
                        let mut w = vec![0.0; 4 * 4 * 4 * 3];
                        rng.fill_uniform(&mut w, -0.5, 0.5);
                        w
                    },
                    bias: vec![0.0; 3],
                    d_in: 64,
                    d_out: 3,
                },
                Layer::Softmax,
            ],
        )
    }

    #[test]
    fn validate_chains_shapes() {
        let m = tiny_model();
        assert_eq!(m.validate(), Nhwc::new(1, 1, 1, 3));
        assert_eq!(m.output_features(), 3);
        assert!(m.param_count() > 0);
    }

    #[test]
    fn forward_produces_probabilities() {
        let mut m = tiny_model();
        m.plan(
            &Planner::new(),
            &Budget::unlimited(),
            &ConvContext::default(),
            2,
        );
        let mut rng = Rng::new(9);
        let batch = Tensor::random(Nhwc::new(2, 8, 8, 1), &mut rng);
        let mut arena = m.sized_arena();
        let out = m.forward(&ConvContext::default(), &batch, &mut arena);
        assert_eq!(out.shape(), Nhwc::new(2, 1, 1, 3));
        for row in out.data().chunks_exact(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "softmax row sums to {sum}");
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
        // Planning sized the arena once; the pass must not have grown it.
        assert_eq!(arena.bytes(), m.planned_workspace_bytes());
    }

    #[test]
    fn algorithm_choice_does_not_change_outputs() {
        let mut m = tiny_model();
        let mut rng = Rng::new(11);
        let batch = Tensor::random(Nhwc::new(3, 8, 8, 1), &mut rng);
        let ctx = ConvContext::default();
        let mut arena = Arena::new();
        let mut outs = Vec::new();
        for algo in [AlgoKind::Direct, AlgoKind::Im2col, AlgoKind::Mec, AlgoKind::Winograd] {
            m.pin_algo(algo);
            outs.push(m.forward(&ctx, &batch, &mut arena));
        }
        for o in &outs[1..] {
            crate::util::assert_allclose(o.data(), outs[0].data(), 1e-3, "algo equivalence");
        }
    }

    #[test]
    fn predict_returns_classes() {
        let mut m = tiny_model();
        m.pin_algo(AlgoKind::Mec);
        let mut rng = Rng::new(13);
        let batch = Tensor::random(Nhwc::new(4, 8, 8, 1), &mut rng);
        let preds = m.predict(&ConvContext::default(), &batch, &mut Arena::new());
        assert_eq!(preds.len(), 4);
        assert!(preds.iter().all(|&p| p < 3));
    }

    #[test]
    fn plan_assigns_conv_nodes_only() {
        let mut m = tiny_model();
        m.plan(
            &Planner::new(),
            &Budget::unlimited(),
            &ConvContext::default(),
            1,
        );
        let summary = m.plan_summary();
        assert_eq!(summary.len(), 1);
        assert_eq!(summary[0].0, 0);
        // The conv node's plan is prepared eagerly and sizes the arena.
        assert_eq!(m.planned_layer_workspaces().len(), 1);
        assert_eq!(
            m.planned_workspace_bytes(),
            m.planned_layer_workspaces()[0].1
        );
    }

    #[test]
    fn per_batch_plans_share_one_kernel_prepack() {
        // Two geometries of the same node (planned batch + a dynamic
        // batching remainder) must hold the SAME prepacked kernel
        // allocation — pointer equality, not just equal bytes.
        let mut m = tiny_model();
        let ctx = ConvContext::default();
        m.plan(&Planner::new(), &Budget::unlimited(), &ctx, 4);
        let mut rng = Rng::new(23);
        let full = Tensor::random(Nhwc::new(4, 8, 8, 1), &mut rng);
        let remainder = Tensor::random(Nhwc::new(3, 8, 8, 1), &mut rng);
        let mut arena = m.sized_arena();
        let _ = m.forward(&ctx, &full, &mut arena);
        let _ = m.forward(&ctx, &remainder, &mut arena); // lazily plans n=3
        let plans = m.cached_plans_for_layer(0);
        assert_eq!(plans.len(), 2, "expected planned + lazily-built geometry");
        assert_eq!(m.cached_prepacks(), 1, "one prepack per conv node");
        let a = plans[0].shared_prepack().expect("plan exposes its prepack");
        let b = plans[1].shared_prepack().expect("plan exposes its prepack");
        assert!(Arc::ptr_eq(&a, &b), "prepack duplicated across batch sizes");
        // And the refcount proves the cache + both plans hold one copy.
        assert!(Arc::strong_count(&a) >= 3);
    }

    #[test]
    fn pinned_model_does_not_leak_precision_across_forwards() {
        // pin_algo leaves planned_ctx=None, so lazily-built plans follow
        // each forward's context — the cache key carries the precision,
        // so a q16 forward must never hand its quantized plan to a later
        // f32 forward (and vice versa).
        use crate::tensor::Precision;
        let mut m = tiny_model();
        m.pin_algo(AlgoKind::Mec);
        let mut rng = Rng::new(29);
        let batch = Tensor::random(Nhwc::new(1, 8, 8, 1), &mut rng);
        let mut arena = Arena::new();
        let q16_ctx = ConvContext::default().with_precision(Precision::Q16);
        let f32_ctx = ConvContext::default();
        let a_q16 = m.forward(&q16_ctx, &batch, &mut arena);
        let a_f32 = m.forward(&f32_ctx, &batch, &mut arena);
        // The q16 plan is still cached and reproduces itself exactly.
        let b_q16 = m.forward(&q16_ctx, &batch, &mut arena);
        assert_eq!(a_q16.data(), b_q16.data());
        // The f32 forward must equal a never-quantized model bitwise —
        // i.e. it did NOT silently reuse the q16-packed plan.
        let mut fresh = tiny_model();
        fresh.pin_algo(AlgoKind::Mec);
        let want = fresh.forward(&f32_ctx, &batch, &mut arena);
        assert_eq!(a_f32.data(), want.data());
    }

    #[test]
    fn forward_memo_matches_forward_bitwise_and_memoizes() {
        let mut m = tiny_model();
        let ctx = ConvContext::default();
        m.plan(&Planner::new(), &Budget::unlimited(), &ctx, 2);
        let mut rng = Rng::new(31);
        let batch = Tensor::random(Nhwc::new(2, 8, 8, 1), &mut rng);
        let mut arena = m.sized_arena();
        let want = m.forward(&ctx, &batch, &mut arena);
        let mut memo = PlanMemo::new();
        assert!(memo.is_empty());
        let a = m.forward_memo(&ctx, &batch, &mut arena, &mut memo);
        assert_eq!(memo.len(), 1, "one conv node memoized");
        // Second pass resolves through the memo alone (same plan, so
        // bitwise-identical again).
        let b = m.forward_memo(&ctx, &batch, &mut arena, &mut memo);
        assert_eq!(memo.len(), 1);
        assert_eq!(a.data(), want.data());
        assert_eq!(b.data(), want.data());
    }

    #[test]
    fn forward_memo_does_not_leak_precision_across_contexts() {
        // One memo reused under q16 then f32 contexts must not hand the
        // quantized plan to the f32 forward — the memo key carries the
        // build precision exactly like the model's plan cache.
        use crate::tensor::Precision;
        let mut m = tiny_model();
        m.pin_algo(AlgoKind::Mec);
        let mut rng = Rng::new(37);
        let batch = Tensor::random(Nhwc::new(1, 8, 8, 1), &mut rng);
        let mut arena = Arena::new();
        let mut memo = PlanMemo::new();
        let q16_ctx = ConvContext::default().with_precision(Precision::Q16);
        let f32_ctx = ConvContext::default();
        let a_q16 = m.forward_memo(&q16_ctx, &batch, &mut arena, &mut memo);
        let a_f32 = m.forward_memo(&f32_ctx, &batch, &mut arena, &mut memo);
        assert_eq!(memo.len(), 2, "one memo entry per precision");
        let mut fresh = tiny_model();
        fresh.pin_algo(AlgoKind::Mec);
        let want = fresh.forward(&f32_ctx, &batch, &mut arena);
        assert_eq!(a_f32.data(), want.data(), "memo leaked the q16 plan");
        let b_q16 = m.forward_memo(&q16_ctx, &batch, &mut arena, &mut memo);
        assert_eq!(a_q16.data(), b_q16.data());
    }

    #[test]
    fn conv_shapes_walks_padded_geometry() {
        let m = tiny_model();
        let shapes = m.conv_shapes(3);
        assert_eq!(shapes.len(), 1);
        let (idx, cs) = shapes[0];
        assert_eq!(idx, 0);
        // 8x8 input with 1px padding at batch 3.
        assert_eq!(cs.input, Nhwc::new(3, 10, 10, 1));
        assert_eq!(cs.output(), Nhwc::new(3, 8, 8, 4));
    }

    #[test]
    fn prepare_batch_caches_extra_geometry_sharing_prepacks() {
        let mut m = tiny_model();
        let ctx = ConvContext::default();
        m.plan(&Planner::new(), &Budget::unlimited(), &ctx, 4);
        let ws4 = m.planned_workspace_elems();
        let ws2 = m.prepare_batch(2);
        assert!(ws2 <= ws4, "smaller batch needs no more workspace");
        let plans = m.cached_plans_for_layer(0);
        assert_eq!(plans.len(), 2, "planned batch + prepared batch");
        assert_eq!(m.cached_prepacks(), 1, "prepack shared, not rebuilt");
    }

    #[test]
    fn repinning_invalidates_prepared_plans() {
        let mut m = tiny_model();
        m.pin_algo(AlgoKind::Im2col);
        let mut rng = Rng::new(17);
        let batch = Tensor::random(Nhwc::new(1, 8, 8, 1), &mut rng);
        let ctx = ConvContext::default();
        let mut arena = Arena::new();
        let a = m.forward(&ctx, &batch, &mut arena);
        // Re-pin to a different algorithm: stale plans must not be reused.
        m.pin_algo(AlgoKind::Direct);
        assert!(m.planned_layer_workspaces().is_empty());
        let b = m.forward(&ctx, &batch, &mut arena);
        crate::util::assert_allclose(a.data(), b.data(), 1e-4, "repin equivalence");
    }

    #[test]
    fn residual_graph_plans_and_executes() {
        // conv → {conv branch, identity} → add → relu: the diamond the
        // sequential API could never express.
        let mut rng = Rng::new(41);
        let mut b = GraphBuilder::new("residual", (6, 6, 2));
        let x = b.input();
        let trunk = b.conv(
            x,
            Kernel::random(KernelShape::new(3, 3, 2, 4), &mut rng),
            vec![0.1; 4],
            1,
            1,
            1,
            1,
        );
        let branch = b.conv(
            trunk,
            Kernel::random(KernelShape::new(3, 3, 4, 4), &mut rng),
            vec![0.0; 4],
            1,
            1,
            1,
            1,
        );
        let sum = b.add(&[branch, trunk]);
        let out = b.relu(sum);
        let mut m = Model::from_graph(b.finish(out));
        m.plan(
            &Planner::new(),
            &Budget::unlimited(),
            &ConvContext::default(),
            2,
        );
        assert_eq!(m.plan_summary().len(), 2, "both convs planned");
        let batch = Tensor::random(Nhwc::new(2, 6, 6, 2), &mut rng);
        let mut arena = m.sized_arena();
        let got = m.forward(&ConvContext::default(), &batch, &mut arena);
        assert_eq!(got.shape(), Nhwc::new(2, 6, 6, 4));
        assert!(got.data().iter().all(|&v| v >= 0.0), "relu output");
        // The residual actually fed through: output != branch alone.
        assert!(got.data().iter().any(|&v| v > 0.0));
    }

    #[test]
    fn dead_nodes_are_eliminated_from_execution() {
        let mut rng = Rng::new(43);
        let mut b = GraphBuilder::new("dce", (5, 5, 1));
        let x = b.input();
        let live = b.conv(
            x,
            Kernel::random(KernelShape::new(3, 3, 1, 2), &mut rng),
            vec![0.0; 2],
            1,
            1,
            0,
            0,
        );
        // A dead branch: built, validated, never executed.
        let _dead = b.conv(
            x,
            Kernel::random(KernelShape::new(5, 5, 1, 8), &mut rng),
            vec![0.0; 8],
            1,
            1,
            0,
            0,
        );
        let m = Model::from_graph(b.finish(live));
        assert_eq!(m.exec().steps().len(), 1, "dead conv got a step");
        assert_eq!(m.conv_shapes(1).len(), 1, "dead conv got planned");
    }

    #[test]
    fn fused_conv_relu_matches_unfused_reference() {
        // Same weights through (a) conv+relu as separate graph nodes
        // (fusion absorbs the relu) and (b) conv then a relu forced to
        // stay separate by a second consumer of the conv value.
        let mut rng = Rng::new(47);
        let kernel = Kernel::random(KernelShape::new(3, 3, 1, 3), &mut rng);
        let bias = vec![-0.2, 0.1, 0.0];

        let mut fused_b = GraphBuilder::new("fused", (7, 7, 1));
        let x = fused_b.input();
        let c = fused_b.conv(x, kernel.clone(), bias.clone(), 1, 1, 1, 1);
        let r = fused_b.relu(c);
        let fused = Model::from_graph(fused_b.finish(r));
        assert_eq!(fused.exec().steps().len(), 1, "relu absorbed into conv");

        let mut plain_b = GraphBuilder::new("plain", (7, 7, 1));
        let x = plain_b.input();
        let c = plain_b.conv(x, kernel, bias, 1, 1, 1, 1);
        let _r = plain_b.relu(c);
        // Second consumer of the conv value blocks fusion; add(relu,
        // 0·conv)… simpler: concat is unnecessary — just verify the
        // unfused path via a model whose output is the conv itself run
        // through a manual relu.
        let plain = Model::from_graph(plain_b.finish(c));
        let batch = Tensor::random(Nhwc::new(2, 7, 7, 1), &mut rng);
        let mut arena = Arena::new();
        let a = fused.forward(&ConvContext::default(), &batch, &mut arena);
        let mut want = plain.forward(&ConvContext::default(), &batch, &mut arena);
        for v in want.data_mut() {
            *v = v.max(0.0);
        }
        assert_eq!(a.data(), want.data(), "fused epilogue must be bitwise relu∘conv");
    }
}
