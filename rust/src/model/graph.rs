//! The model graph + forward executor.
//!
//! Convolutions are planned per layer (once, at load): the
//! [`Planner`](crate::planner::Planner) picks the algorithm under the
//! device [`Budget`], then [`Convolution::plan`] prepacks the layer's
//! kernel and fixes its [`WorkspaceLayout`](crate::memory::WorkspaceLayout). The resulting
//! [`ConvPlan`]s are held by the model and reused for every request —
//! the hot path performs no kernel repacking, no filter transforms, and
//! no workspace allocation: all layers execute out of one shared
//! [`Arena`] sized at the **max** (not the sum) of the per-layer
//! workspaces.
//!
//! Dynamic batching can present batch sizes other than the planned one;
//! plans for those geometries are built lazily on first sight and cached
//! (cuDNN-graph style: one executable per shape).

use crate::conv::{AlgoKind, ConvContext, ConvPlan, Convolution};
use crate::gemm::{gemm_ex, MatMut, MatRef};
use crate::memory::{Arena, Budget};
use crate::model::layer::Layer;
use crate::planner::Planner;
use crate::tensor::{ConvShape, Nhwc, Tensor};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

/// A sequential CNN with planned convolution algorithms and prepacked
/// per-layer [`ConvPlan`]s.
pub struct Model {
    pub name: String,
    /// Spatial input shape per sample (h, w, c); batch dim comes from the
    /// request.
    pub input_hwc: (usize, usize, usize),
    pub layers: Vec<Layer>,
    /// Chosen conv algorithm per layer index (None for non-conv layers).
    plans: Vec<Option<AlgoKind>>,
    /// Prepared plans keyed by (layer index, exact conv geometry). The
    /// planned batch size is populated eagerly by [`Model::plan`]; other
    /// batch sizes (dynamic batching remainders) fill in lazily.
    plan_cache: RwLock<HashMap<(usize, ConvShape), Arc<dyn ConvPlan>>>,
    /// Shared-arena requirement at the planned batch: max over planned
    /// conv layers of `ConvPlan::workspace_elems`.
    planned_ws_elems: usize,
    /// The context [`Model::plan`] ran under. Lazily-built plans (other
    /// batch sizes) reuse it, so every conv layer executes under ONE
    /// consistent context regardless of batch size; `forward`'s ctx then
    /// only affects non-conv layers. `None` until planned (or after
    /// `pin_algo`): plans build under the caller's forward context.
    planned_ctx: Option<ConvContext>,
}

/// Cap on cached geometries per conv layer: the planned batch size plus
/// a handful of dynamic-batching remainders. Beyond this, plans for
/// unusual batch sizes are built transiently (executed, not cached) so
/// serving memory stays bounded — each cached plan holds its own
/// prepacked kernel operands.
const MAX_CACHED_GEOMETRIES_PER_LAYER: usize = 8;

impl Model {
    pub fn new(name: &str, input_hwc: (usize, usize, usize), layers: Vec<Layer>) -> Model {
        let plans = vec![None; layers.len()];
        Model {
            name: name.to_string(),
            input_hwc,
            layers,
            plans,
            plan_cache: RwLock::new(HashMap::new()),
            planned_ws_elems: 0,
            planned_ctx: None,
        }
    }

    /// Validate layer chaining by propagating a batch-1 shape; returns
    /// the final output shape.
    pub fn validate(&self) -> Nhwc {
        let (h, w, c) = self.input_hwc;
        let mut shape = Nhwc::new(1, h, w, c);
        for layer in &self.layers {
            shape = layer.output_shape(shape);
        }
        shape
    }

    /// Output features per sample.
    pub fn output_features(&self) -> usize {
        let s = self.validate();
        s.h * s.w * s.c
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Plan every conv layer under `budget` for batch size `batch`: the
    /// planner picks the algorithm on the true batched geometry, then the
    /// algorithm prepacks the layer's kernel into a reusable
    /// [`ConvPlan`]. Also sizes the shared arena (max over layers).
    pub fn plan(&mut self, planner: &Planner, budget: &Budget, ctx: &ConvContext, batch: usize) {
        self.plan_cache.write().unwrap().clear();
        self.planned_ws_elems = 0;
        self.planned_ctx = Some(ctx.clone());
        let (h, w, c) = self.input_hwc;
        let mut shape = Nhwc::new(batch.max(1), h, w, c);
        let mut max_ws = 0usize;
        let mut prepared: Vec<((usize, ConvShape), Arc<dyn ConvPlan>)> = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            if let Layer::Conv {
                kernel, sh, sw, ph, pw, ..
            } = layer
            {
                let padded = Nhwc::new(shape.n, shape.h + 2 * ph, shape.w + 2 * pw, shape.c);
                let cs = ConvShape::new(padded, kernel.shape(), *sh, *sw);
                let chosen = planner.plan(&cs, budget, ctx).algo;
                self.plans[i] = Some(chosen);
                let conv_plan: Arc<dyn ConvPlan> =
                    Arc::from(chosen.build().plan(ctx, &cs, kernel));
                max_ws = max_ws.max(conv_plan.workspace_elems());
                prepared.push(((i, cs), conv_plan));
            }
            shape = layer.output_shape(shape);
        }
        self.plan_cache.write().unwrap().extend(prepared);
        self.planned_ws_elems = max_ws;
    }

    /// Pin a single algorithm for all conv layers (benchmark mode).
    /// Invalidates any prepared plans; they rebuild lazily.
    pub fn pin_algo(&mut self, algo: AlgoKind) {
        self.plan_cache.write().unwrap().clear();
        self.planned_ws_elems = 0;
        self.planned_ctx = None;
        for (i, layer) in self.layers.iter().enumerate() {
            if matches!(layer, Layer::Conv { .. }) {
                self.plans[i] = Some(algo);
            }
        }
    }

    /// Chosen algorithm per conv layer (for reports).
    pub fn plan_summary(&self) -> Vec<(usize, AlgoKind)> {
        self.plans
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.map(|a| (i, a)))
            .collect()
    }

    /// Workspace bytes per prepared conv layer (layer index, bytes) —
    /// the quantities whose **max** sizes the shared arena.
    pub fn planned_layer_workspaces(&self) -> Vec<(usize, usize)> {
        let cache = self.plan_cache.read().unwrap();
        let mut out: Vec<(usize, usize)> = cache
            .iter()
            .map(|((i, _), p)| (*i, p.workspace_bytes()))
            .collect();
        out.sort_unstable();
        out
    }

    /// Shared-arena floats required at the planned batch size (0 if
    /// [`Model::plan`] has not run — the arena then grows on demand).
    pub fn planned_workspace_elems(&self) -> usize {
        self.planned_ws_elems
    }

    /// Same in bytes.
    pub fn planned_workspace_bytes(&self) -> usize {
        self.planned_ws_elems * std::mem::size_of::<f32>()
    }

    /// An [`Arena`] pre-sized for this model's planned layers — what each
    /// serving worker owns. Peak tracked bytes of a forward pass through
    /// it equal the max (not the sum) of per-layer workspaces.
    pub fn sized_arena(&self) -> Arena {
        Arena::with_capacity(self.planned_ws_elems)
    }

    /// Fetch (or lazily build) the prepared plan for conv layer `idx` on
    /// geometry `cs`.
    fn plan_for(
        &self,
        idx: usize,
        cs: &ConvShape,
        ctx: &ConvContext,
        kernel: &crate::tensor::Kernel,
    ) -> Arc<dyn ConvPlan> {
        let key = (idx, *cs);
        if let Some(p) = self.plan_cache.read().unwrap().get(&key) {
            return Arc::clone(p);
        }
        // Build under the planning context so cached and lazily-built
        // plans agree on threads / MEC T / FFT cache cap.
        let build_ctx = self.planned_ctx.as_ref().unwrap_or(ctx);
        let algo = self.plans[idx].unwrap_or(AlgoKind::Mec);
        let built: Arc<dyn ConvPlan> = Arc::from(algo.build().plan(build_ctx, cs, kernel));
        let mut cache = self.plan_cache.write().unwrap();
        if !cache.contains_key(&key)
            && cache.keys().filter(|(i, _)| *i == idx).count() >= MAX_CACHED_GEOMETRIES_PER_LAYER
        {
            // Bounded cache: execute this one transiently instead of
            // holding yet another prepacked copy per odd batch size.
            return built;
        }
        Arc::clone(cache.entry(key).or_insert(built))
    }

    /// Run a forward pass on a batch. Returns the final activation
    /// (logits or probabilities, depending on the last layer). All conv
    /// layers execute out of `arena`; after the first pass at a given
    /// batch size the hot path performs no tracked allocation.
    pub fn forward(&self, ctx: &ConvContext, batch: &Tensor, arena: &mut Arena) -> Tensor {
        let mut x = batch.clone();
        for (i, layer) in self.layers.iter().enumerate() {
            x = self.forward_layer(i, layer, ctx, x, arena);
        }
        x
    }

    fn forward_layer(
        &self,
        idx: usize,
        layer: &Layer,
        ctx: &ConvContext,
        x: Tensor,
        arena: &mut Arena,
    ) -> Tensor {
        match layer {
            Layer::Conv {
                kernel, bias, sh, sw, ph, pw,
            } => {
                let padded = if *ph > 0 || *pw > 0 {
                    x.pad_spatial(*ph, *pw)
                } else {
                    x
                };
                let cs = ConvShape::new(padded.shape(), kernel.shape(), *sh, *sw);
                let plan = self.plan_for(idx, &cs, ctx, kernel);
                let mut out = Tensor::zeros(cs.output());
                plan.execute(&padded, arena, &mut out);
                // Bias add (per output channel).
                let kc = kernel.shape().kc;
                for chunk in out.data_mut().chunks_exact_mut(kc) {
                    for (v, b) in chunk.iter_mut().zip(bias) {
                        *v += b;
                    }
                }
                out
            }
            Layer::Relu => {
                let mut out = x;
                for v in out.data_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                out
            }
            Layer::MaxPool { k, s } => max_pool(&x, *k, *s),
            Layer::Flatten => {
                let sh = x.shape();
                Tensor::from_vec(
                    Nhwc::new(sh.n, 1, 1, sh.h * sh.w * sh.c),
                    x.into_vec(),
                )
            }
            Layer::Dense { w, bias, d_in, d_out } => {
                let sh = x.shape();
                let n = sh.n;
                assert_eq!(sh.h * sh.w * sh.c, *d_in);
                let mut out = Tensor::zeros(Nhwc::new(n, 1, 1, *d_out));
                let a = MatRef::new(x.data(), n, *d_in);
                let b = MatRef::new(w, *d_in, *d_out);
                let mut c = MatMut::new(out.data_mut(), n, *d_out);
                gemm_ex(a, b, &mut c, 1.0, 0.0, ctx.threads, ctx.blocks);
                for row in out.data_mut().chunks_exact_mut(*d_out) {
                    for (v, bb) in row.iter_mut().zip(bias) {
                        *v += bb;
                    }
                }
                out
            }
            Layer::Softmax => {
                let mut out = x;
                let c = out.shape().c;
                for row in out.data_mut().chunks_exact_mut(c) {
                    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut sum = 0.0;
                    for v in row.iter_mut() {
                        *v = (*v - m).exp();
                        sum += *v;
                    }
                    for v in row.iter_mut() {
                        *v /= sum;
                    }
                }
                out
            }
        }
    }

    /// Argmax class per sample of the final activation.
    pub fn predict(&self, ctx: &ConvContext, batch: &Tensor, arena: &mut Arena) -> Vec<usize> {
        let out = self.forward(ctx, batch, arena);
        let c = out.shape().c;
        out.data()
            .chunks_exact(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}

fn max_pool(x: &Tensor, k: usize, s: usize) -> Tensor {
    let sh = x.shape();
    let oh = (sh.h - k) / s + 1;
    let ow = (sh.w - k) / s + 1;
    let out_shape = Nhwc::new(sh.n, oh, ow, sh.c);
    let mut out = Tensor::zeros(out_shape);
    for n in 0..sh.n {
        for y in 0..oh {
            for x0 in 0..ow {
                for c in 0..sh.c {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..k {
                        for dx in 0..k {
                            m = m.max(x.at(n, y * s + dy, x0 * s + dx, c));
                        }
                    }
                    *out.at_mut(n, y, x0, c) = m;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Kernel, KernelShape};
    use crate::util::Rng;

    fn tiny_model() -> Model {
        let mut rng = Rng::new(3);
        Model::new(
            "tiny",
            (8, 8, 1),
            vec![
                Layer::Conv {
                    kernel: Kernel::random(KernelShape::new(3, 3, 1, 4), &mut rng),
                    bias: vec![0.1; 4],
                    sh: 1,
                    sw: 1,
                    ph: 1,
                    pw: 1,
                },
                Layer::Relu,
                Layer::MaxPool { k: 2, s: 2 },
                Layer::Flatten,
                Layer::Dense {
                    w: {
                        let mut w = vec![0.0; 4 * 4 * 4 * 3];
                        rng.fill_uniform(&mut w, -0.5, 0.5);
                        w
                    },
                    bias: vec![0.0; 3],
                    d_in: 64,
                    d_out: 3,
                },
                Layer::Softmax,
            ],
        )
    }

    #[test]
    fn validate_chains_shapes() {
        let m = tiny_model();
        assert_eq!(m.validate(), Nhwc::new(1, 1, 1, 3));
        assert_eq!(m.output_features(), 3);
        assert!(m.param_count() > 0);
    }

    #[test]
    fn forward_produces_probabilities() {
        let mut m = tiny_model();
        m.plan(
            &Planner::new(),
            &Budget::unlimited(),
            &ConvContext::default(),
            2,
        );
        let mut rng = Rng::new(9);
        let batch = Tensor::random(Nhwc::new(2, 8, 8, 1), &mut rng);
        let mut arena = m.sized_arena();
        let out = m.forward(&ConvContext::default(), &batch, &mut arena);
        assert_eq!(out.shape(), Nhwc::new(2, 1, 1, 3));
        for row in out.data().chunks_exact(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "softmax row sums to {sum}");
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
        // Planning sized the arena once; the pass must not have grown it.
        assert_eq!(arena.bytes(), m.planned_workspace_bytes());
    }

    #[test]
    fn algorithm_choice_does_not_change_outputs() {
        let mut m = tiny_model();
        let mut rng = Rng::new(11);
        let batch = Tensor::random(Nhwc::new(3, 8, 8, 1), &mut rng);
        let ctx = ConvContext::default();
        let mut arena = Arena::new();
        let mut outs = Vec::new();
        for algo in [AlgoKind::Direct, AlgoKind::Im2col, AlgoKind::Mec, AlgoKind::Winograd] {
            m.pin_algo(algo);
            outs.push(m.forward(&ctx, &batch, &mut arena));
        }
        for o in &outs[1..] {
            crate::util::assert_allclose(o.data(), outs[0].data(), 1e-3, "algo equivalence");
        }
    }

    #[test]
    fn predict_returns_classes() {
        let mut m = tiny_model();
        m.pin_algo(AlgoKind::Mec);
        let mut rng = Rng::new(13);
        let batch = Tensor::random(Nhwc::new(4, 8, 8, 1), &mut rng);
        let preds = m.predict(&ConvContext::default(), &batch, &mut Arena::new());
        assert_eq!(preds.len(), 4);
        assert!(preds.iter().all(|&p| p < 3));
    }

    #[test]
    fn max_pool_values() {
        let x = Tensor::from_fn(Nhwc::new(1, 4, 4, 1), |_, h, w, _| (h * 4 + w) as f32);
        let p = max_pool(&x, 2, 2);
        assert_eq!(p.shape(), Nhwc::new(1, 2, 2, 1));
        assert_eq!(p.data(), &[5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn plan_assigns_conv_layers_only() {
        let mut m = tiny_model();
        m.plan(
            &Planner::new(),
            &Budget::unlimited(),
            &ConvContext::default(),
            1,
        );
        let summary = m.plan_summary();
        assert_eq!(summary.len(), 1);
        assert_eq!(summary[0].0, 0);
        // The conv layer's plan is prepared eagerly and sizes the arena.
        assert_eq!(m.planned_layer_workspaces().len(), 1);
        assert_eq!(
            m.planned_workspace_bytes(),
            m.planned_layer_workspaces()[0].1
        );
    }

    #[test]
    fn repinning_invalidates_prepared_plans() {
        let mut m = tiny_model();
        m.pin_algo(AlgoKind::Im2col);
        let mut rng = Rng::new(17);
        let batch = Tensor::random(Nhwc::new(1, 8, 8, 1), &mut rng);
        let ctx = ConvContext::default();
        let mut arena = Arena::new();
        let a = m.forward(&ctx, &batch, &mut arena);
        // Re-pin to a different algorithm: stale plans must not be reused.
        m.pin_algo(AlgoKind::Direct);
        assert!(m.planned_layer_workspaces().is_empty());
        let b = m.forward(&ctx, &batch, &mut arena);
        crate::util::assert_allclose(a.data(), b.data(), 1e-4, "repin equivalence");
    }
}
