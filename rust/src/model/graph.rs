//! The model graph + forward executor.
//!
//! Convolutions are planned per layer (once, at load) by the
//! [`Planner`](crate::planner::Planner) under the device [`Budget`]; the
//! chosen algorithm and its workspace are reused for every request — the
//! hot path performs no allocation beyond first-call workspace growth.

use crate::conv::{AlgoKind, ConvContext, Convolution};
use crate::gemm::{gemm_ex, MatMut, MatRef};
use crate::memory::{Budget, Workspace};
use crate::model::layer::Layer;
use crate::planner::Planner;
use crate::tensor::{ConvShape, Nhwc, Tensor};

/// A sequential CNN with planned convolution algorithms.
pub struct Model {
    pub name: String,
    /// Spatial input shape per sample (h, w, c); batch dim comes from the
    /// request.
    pub input_hwc: (usize, usize, usize),
    pub layers: Vec<Layer>,
    /// Chosen conv algorithm per layer index (None for non-conv layers).
    plans: Vec<Option<AlgoKind>>,
}

impl Model {
    pub fn new(name: &str, input_hwc: (usize, usize, usize), layers: Vec<Layer>) -> Model {
        let plans = vec![None; layers.len()];
        Model {
            name: name.to_string(),
            input_hwc,
            layers,
            plans,
        }
    }

    /// Validate layer chaining by propagating a batch-1 shape; returns
    /// the final output shape.
    pub fn validate(&self) -> Nhwc {
        let (h, w, c) = self.input_hwc;
        let mut shape = Nhwc::new(1, h, w, c);
        for layer in &self.layers {
            shape = layer.output_shape(shape);
        }
        shape
    }

    /// Output features per sample.
    pub fn output_features(&self) -> usize {
        let s = self.validate();
        s.h * s.w * s.c
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Plan every conv layer under `budget` for batch size `batch`
    /// (the planner sees the true batched geometry).
    pub fn plan(&mut self, planner: &Planner, budget: &Budget, ctx: &ConvContext, batch: usize) {
        let (h, w, c) = self.input_hwc;
        let mut shape = Nhwc::new(batch.max(1), h, w, c);
        for (i, layer) in self.layers.iter().enumerate() {
            if let Layer::Conv {
                kernel, sh, sw, ph, pw, ..
            } = layer
            {
                let padded = Nhwc::new(shape.n, shape.h + 2 * ph, shape.w + 2 * pw, shape.c);
                let cs = ConvShape::new(padded, kernel.shape(), *sh, *sw);
                self.plans[i] = Some(planner.plan(&cs, budget, ctx).algo);
            }
            shape = layer.output_shape(shape);
        }
    }

    /// Pin a single algorithm for all conv layers (benchmark mode).
    pub fn pin_algo(&mut self, algo: AlgoKind) {
        for (i, layer) in self.layers.iter().enumerate() {
            if matches!(layer, Layer::Conv { .. }) {
                self.plans[i] = Some(algo);
            }
        }
    }

    /// Chosen algorithm per conv layer (for reports).
    pub fn plan_summary(&self) -> Vec<(usize, AlgoKind)> {
        self.plans
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.map(|a| (i, a)))
            .collect()
    }

    /// Run a forward pass on a batch. Returns the final activation
    /// (logits or probabilities, depending on the last layer).
    pub fn forward(&self, ctx: &ConvContext, batch: &Tensor, ws: &mut Workspace) -> Tensor {
        let mut x = batch.clone();
        for (i, layer) in self.layers.iter().enumerate() {
            x = self.forward_layer(i, layer, ctx, x, ws);
        }
        x
    }

    fn forward_layer(
        &self,
        idx: usize,
        layer: &Layer,
        ctx: &ConvContext,
        x: Tensor,
        ws: &mut Workspace,
    ) -> Tensor {
        match layer {
            Layer::Conv {
                kernel, bias, sh, sw, ph, pw,
            } => {
                let padded = if *ph > 0 || *pw > 0 {
                    x.pad_spatial(*ph, *pw)
                } else {
                    x
                };
                let cs = ConvShape::new(padded.shape(), kernel.shape(), *sh, *sw);
                let algo: Box<dyn Convolution> = self.plans[idx]
                    .unwrap_or(AlgoKind::Mec)
                    .build();
                let mut out = Tensor::zeros(cs.output());
                algo.run(ctx, &cs, &padded, kernel, ws, &mut out);
                // Bias add (per output channel).
                let kc = kernel.shape().kc;
                for chunk in out.data_mut().chunks_exact_mut(kc) {
                    for (v, b) in chunk.iter_mut().zip(bias) {
                        *v += b;
                    }
                }
                out
            }
            Layer::Relu => {
                let mut out = x;
                for v in out.data_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                out
            }
            Layer::MaxPool { k, s } => max_pool(&x, *k, *s),
            Layer::Flatten => {
                let sh = x.shape();
                Tensor::from_vec(
                    Nhwc::new(sh.n, 1, 1, sh.h * sh.w * sh.c),
                    x.into_vec(),
                )
            }
            Layer::Dense { w, bias, d_in, d_out } => {
                let sh = x.shape();
                let n = sh.n;
                assert_eq!(sh.h * sh.w * sh.c, *d_in);
                let mut out = Tensor::zeros(Nhwc::new(n, 1, 1, *d_out));
                let a = MatRef::new(x.data(), n, *d_in);
                let b = MatRef::new(w, *d_in, *d_out);
                let mut c = MatMut::new(out.data_mut(), n, *d_out);
                gemm_ex(a, b, &mut c, 1.0, 0.0, ctx.threads, ctx.blocks);
                for row in out.data_mut().chunks_exact_mut(*d_out) {
                    for (v, bb) in row.iter_mut().zip(bias) {
                        *v += bb;
                    }
                }
                out
            }
            Layer::Softmax => {
                let mut out = x;
                let c = out.shape().c;
                for row in out.data_mut().chunks_exact_mut(c) {
                    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut sum = 0.0;
                    for v in row.iter_mut() {
                        *v = (*v - m).exp();
                        sum += *v;
                    }
                    for v in row.iter_mut() {
                        *v /= sum;
                    }
                }
                out
            }
        }
    }

    /// Argmax class per sample of the final activation.
    pub fn predict(&self, ctx: &ConvContext, batch: &Tensor, ws: &mut Workspace) -> Vec<usize> {
        let out = self.forward(ctx, batch, ws);
        let c = out.shape().c;
        out.data()
            .chunks_exact(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}

fn max_pool(x: &Tensor, k: usize, s: usize) -> Tensor {
    let sh = x.shape();
    let oh = (sh.h - k) / s + 1;
    let ow = (sh.w - k) / s + 1;
    let out_shape = Nhwc::new(sh.n, oh, ow, sh.c);
    let mut out = Tensor::zeros(out_shape);
    for n in 0..sh.n {
        for y in 0..oh {
            for x0 in 0..ow {
                for c in 0..sh.c {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..k {
                        for dx in 0..k {
                            m = m.max(x.at(n, y * s + dy, x0 * s + dx, c));
                        }
                    }
                    *out.at_mut(n, y, x0, c) = m;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Kernel, KernelShape};
    use crate::util::Rng;

    fn tiny_model() -> Model {
        let mut rng = Rng::new(3);
        Model::new(
            "tiny",
            (8, 8, 1),
            vec![
                Layer::Conv {
                    kernel: Kernel::random(KernelShape::new(3, 3, 1, 4), &mut rng),
                    bias: vec![0.1; 4],
                    sh: 1,
                    sw: 1,
                    ph: 1,
                    pw: 1,
                },
                Layer::Relu,
                Layer::MaxPool { k: 2, s: 2 },
                Layer::Flatten,
                Layer::Dense {
                    w: {
                        let mut w = vec![0.0; 4 * 4 * 4 * 3];
                        rng.fill_uniform(&mut w, -0.5, 0.5);
                        w
                    },
                    bias: vec![0.0; 3],
                    d_in: 64,
                    d_out: 3,
                },
                Layer::Softmax,
            ],
        )
    }

    #[test]
    fn validate_chains_shapes() {
        let m = tiny_model();
        assert_eq!(m.validate(), Nhwc::new(1, 1, 1, 3));
        assert_eq!(m.output_features(), 3);
        assert!(m.param_count() > 0);
    }

    #[test]
    fn forward_produces_probabilities() {
        let mut m = tiny_model();
        m.plan(
            &Planner::new(),
            &Budget::unlimited(),
            &ConvContext::default(),
            2,
        );
        let mut rng = Rng::new(9);
        let batch = Tensor::random(Nhwc::new(2, 8, 8, 1), &mut rng);
        let mut ws = Workspace::new();
        let out = m.forward(&ConvContext::default(), &batch, &mut ws);
        assert_eq!(out.shape(), Nhwc::new(2, 1, 1, 3));
        for row in out.data().chunks_exact(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "softmax row sums to {sum}");
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn algorithm_choice_does_not_change_outputs() {
        let mut m = tiny_model();
        let mut rng = Rng::new(11);
        let batch = Tensor::random(Nhwc::new(3, 8, 8, 1), &mut rng);
        let ctx = ConvContext::default();
        let mut ws = Workspace::new();
        let mut outs = Vec::new();
        for algo in [AlgoKind::Direct, AlgoKind::Im2col, AlgoKind::Mec, AlgoKind::Winograd] {
            m.pin_algo(algo);
            outs.push(m.forward(&ctx, &batch, &mut ws));
        }
        for o in &outs[1..] {
            crate::util::assert_allclose(o.data(), outs[0].data(), 1e-3, "algo equivalence");
        }
    }

    #[test]
    fn predict_returns_classes() {
        let mut m = tiny_model();
        m.pin_algo(AlgoKind::Mec);
        let mut rng = Rng::new(13);
        let batch = Tensor::random(Nhwc::new(4, 8, 8, 1), &mut rng);
        let preds = m.predict(&ConvContext::default(), &batch, &mut Workspace::new());
        assert_eq!(preds.len(), 4);
        assert!(preds.iter().all(|&p| p < 3));
    }

    #[test]
    fn max_pool_values() {
        let x = Tensor::from_fn(Nhwc::new(1, 4, 4, 1), |_, h, w, _| (h * 4 + w) as f32);
        let p = max_pool(&x, 2, 2);
        assert_eq!(p.shape(), Nhwc::new(1, 2, 2, 1));
        assert_eq!(p.data(), &[5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn plan_assigns_conv_layers_only() {
        let mut m = tiny_model();
        m.plan(
            &Planner::new(),
            &Budget::unlimited(),
            &ConvContext::default(),
            1,
        );
        let summary = m.plan_summary();
        assert_eq!(summary.len(), 1);
        assert_eq!(summary[0].0, 0);
    }
}
