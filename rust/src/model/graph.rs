//! The model graph + forward executor.
//!
//! Convolutions are planned per layer (once, at load): the
//! [`Planner`](crate::planner::Planner) picks the algorithm under the
//! device [`Budget`], then [`Convolution::plan`] prepacks the layer's
//! kernel and fixes its [`WorkspaceLayout`](crate::memory::WorkspaceLayout). The resulting
//! [`ConvPlan`]s are held by the model and reused for every request —
//! the hot path performs no kernel repacking, no filter transforms, and
//! no workspace allocation: all layers execute out of one shared
//! [`Arena`] sized at the **max** (not the sum) of the per-layer
//! workspaces.
//!
//! Dynamic batching can present batch sizes other than the planned one;
//! plans for those geometries are built lazily on first sight and cached
//! (cuDNN-graph style: one executable per shape).

use crate::conv::{AlgoKind, ConvContext, ConvPlan, Convolution, KernelPrepack};
use crate::gemm::{gemm_ex, MatMut, MatRef};
use crate::memory::{Arena, Budget};
use crate::model::layer::Layer;
use crate::planner::Planner;
use crate::tensor::{ConvShape, Nhwc, Precision, Tensor};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

/// A sequential CNN with planned convolution algorithms and prepacked
/// per-layer [`ConvPlan`]s.
pub struct Model {
    pub name: String,
    /// Spatial input shape per sample (h, w, c); batch dim comes from the
    /// request.
    pub input_hwc: (usize, usize, usize),
    pub layers: Vec<Layer>,
    /// Chosen conv algorithm per layer index (None for non-conv layers).
    plans: Vec<Option<AlgoKind>>,
    /// Prepared plans keyed by (layer index, exact conv geometry, build
    /// precision). The planned batch size is populated eagerly by
    /// [`Model::plan`]; other batch sizes (dynamic batching remainders)
    /// fill in lazily. Precision is in the key because a pinned/unplanned
    /// model builds under the caller's context: a q16 forward must never
    /// hand back an f32-planned layer or vice versa.
    plan_cache: RwLock<HashMap<(usize, ConvShape, Precision), Arc<dyn ConvPlan>>>,
    /// Batch-independent kernel-side prepacks (PackedKernel, Winograd U,
    /// FFT spectra), keyed by (layer index, algorithm, build precision):
    /// built once per layer and `Arc`-shared into every per-batch-size
    /// plan above, so dynamic batching stops duplicating prepacked
    /// weights per cached geometry.
    prepack_cache: RwLock<HashMap<(usize, AlgoKind, Precision), Arc<dyn KernelPrepack>>>,
    /// Shared-arena requirement at the planned batch: max over planned
    /// conv layers of `ConvPlan::workspace_elems`.
    planned_ws_elems: usize,
    /// The context [`Model::plan`] ran under. Lazily-built plans (other
    /// batch sizes) reuse it, so every conv layer executes under ONE
    /// consistent context regardless of batch size; `forward`'s ctx then
    /// only affects non-conv layers. `None` until planned (or after
    /// `pin_algo`): plans build under the caller's forward context.
    planned_ctx: Option<ConvContext>,
}

/// Cap on cached geometries per conv layer: the planned batch size plus
/// a handful of dynamic-batching remainders. Beyond this, plans for
/// unusual batch sizes are built transiently (executed, not cached) so
/// serving memory stays bounded — each cached plan holds its own
/// prepacked kernel operands.
pub const MAX_CACHED_GEOMETRIES_PER_LAYER: usize = 8;

/// A session-local memo of resolved `(layer, geometry, precision) →
/// plan` bindings. The model's own plan cache sits behind an `RwLock`
/// (it is shared by every session); a memo in front of it makes a
/// session's steady-state forward lock-free — after the first pass at a
/// batch size, every lookup is a plain `HashMap` hit on thread-owned
/// state. Keyed by the same build precision as the model cache, so a
/// memo reused across contexts can never hand a q16-packed plan to an
/// f32 forward (or vice versa); bounded per layer like the model cache.
#[derive(Default)]
pub struct PlanMemo {
    map: HashMap<(usize, ConvShape, Precision), Arc<dyn ConvPlan>>,
}

impl PlanMemo {
    pub fn new() -> PlanMemo {
        PlanMemo::default()
    }

    /// Number of memoized (layer, geometry) plan bindings.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

impl Model {
    pub fn new(name: &str, input_hwc: (usize, usize, usize), layers: Vec<Layer>) -> Model {
        let plans = vec![None; layers.len()];
        Model {
            name: name.to_string(),
            input_hwc,
            layers,
            plans,
            plan_cache: RwLock::new(HashMap::new()),
            prepack_cache: RwLock::new(HashMap::new()),
            planned_ws_elems: 0,
            planned_ctx: None,
        }
    }

    /// Validate layer chaining by propagating a batch-1 shape; returns
    /// the final output shape.
    pub fn validate(&self) -> Nhwc {
        let (h, w, c) = self.input_hwc;
        let mut shape = Nhwc::new(1, h, w, c);
        for layer in &self.layers {
            shape = layer.output_shape(shape);
        }
        shape
    }

    /// Output features per sample.
    pub fn output_features(&self) -> usize {
        let s = self.validate();
        s.h * s.w * s.c
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// The exact conv geometry of every conv layer at batch size `batch`
    /// (padding applied), in layer order: what the planner/engine choose
    /// algorithms on. Non-conv layers are skipped.
    pub fn conv_shapes(&self, batch: usize) -> Vec<(usize, ConvShape)> {
        let (h, w, c) = self.input_hwc;
        let mut shape = Nhwc::new(batch.max(1), h, w, c);
        let mut out = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            if let Layer::Conv {
                kernel, sh, sw, ph, pw, ..
            } = layer
            {
                let padded = Nhwc::new(shape.n, shape.h + 2 * ph, shape.w + 2 * pw, shape.c);
                out.push((i, ConvShape::new(padded, kernel.shape(), *sh, *sw)));
            }
            shape = layer.output_shape(shape);
        }
        out
    }

    /// Plan every conv layer under `budget` for batch size `batch`: the
    /// planner picks the algorithm on the true batched geometry, then the
    /// algorithm prepacks the layer's kernel into a reusable
    /// [`ConvPlan`]. Also sizes the shared arena (max over layers).
    pub fn plan(&mut self, planner: &Planner, budget: &Budget, ctx: &ConvContext, batch: usize) {
        self.plan_with(ctx, batch, |_, cs| planner.plan(cs, budget, ctx).algo);
    }

    /// [`Model::plan`] with the algorithm choice delegated to `choose`
    /// (layer index + exact batched geometry → algorithm). This is the
    /// engine builder's entry point: the choice may come from the cost
    /// model, the autotuner, or a validated per-layer override — the
    /// prepack/plan/arena machinery is identical either way.
    pub fn plan_with(
        &mut self,
        ctx: &ConvContext,
        batch: usize,
        mut choose: impl FnMut(usize, &ConvShape) -> AlgoKind,
    ) {
        self.plan_cache.write().unwrap().clear();
        self.prepack_cache.write().unwrap().clear();
        self.planned_ws_elems = 0;
        self.planned_ctx = Some(ctx.clone());
        let (h, w, c) = self.input_hwc;
        let mut shape = Nhwc::new(batch.max(1), h, w, c);
        let mut max_ws = 0usize;
        let mut prepared: Vec<((usize, ConvShape, Precision), Arc<dyn ConvPlan>)> = Vec::new();
        let mut prepacks: Vec<((usize, AlgoKind, Precision), Arc<dyn KernelPrepack>)> = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            if let Layer::Conv {
                kernel, sh, sw, ph, pw, ..
            } = layer
            {
                let padded = Nhwc::new(shape.n, shape.h + 2 * ph, shape.w + 2 * pw, shape.c);
                let cs = ConvShape::new(padded, kernel.shape(), *sh, *sw);
                let chosen = choose(i, &cs);
                self.plans[i] = Some(chosen);
                let algo_impl = chosen.build();
                // One batch-independent prepack per layer; every batch
                // size this layer ever plans for shares it.
                let pk = algo_impl.prepack(ctx, &cs, kernel);
                let conv_plan: Arc<dyn ConvPlan> =
                    Arc::from(algo_impl.plan_shared(ctx, &cs, Arc::clone(&pk)));
                max_ws = max_ws.max(conv_plan.workspace_elems());
                prepared.push(((i, cs, ctx.precision), conv_plan));
                prepacks.push(((i, chosen, ctx.precision), pk));
            }
            shape = layer.output_shape(shape);
        }
        self.plan_cache.write().unwrap().extend(prepared);
        self.prepack_cache.write().unwrap().extend(prepacks);
        self.planned_ws_elems = max_ws;
    }

    /// Pin a single algorithm for all conv layers (benchmark mode).
    /// Invalidates any prepared plans; they rebuild lazily.
    pub fn pin_algo(&mut self, algo: AlgoKind) {
        self.plan_cache.write().unwrap().clear();
        self.prepack_cache.write().unwrap().clear();
        self.planned_ws_elems = 0;
        self.planned_ctx = None;
        for (i, layer) in self.layers.iter().enumerate() {
            if matches!(layer, Layer::Conv { .. }) {
                self.plans[i] = Some(algo);
            }
        }
    }

    /// Chosen algorithm per conv layer (for reports).
    pub fn plan_summary(&self) -> Vec<(usize, AlgoKind)> {
        self.plans
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.map(|a| (i, a)))
            .collect()
    }

    /// Workspace bytes per prepared conv layer (layer index, bytes) —
    /// the quantities whose **max** sizes the shared arena.
    pub fn planned_layer_workspaces(&self) -> Vec<(usize, usize)> {
        let cache = self.plan_cache.read().unwrap();
        let mut out: Vec<(usize, usize)> = cache
            .iter()
            .map(|((i, _, _), p)| (*i, p.workspace_bytes()))
            .collect();
        out.sort_unstable();
        out
    }

    /// Shared-arena floats required at the planned batch size (0 if
    /// [`Model::plan`] has not run — the arena then grows on demand).
    pub fn planned_workspace_elems(&self) -> usize {
        self.planned_ws_elems
    }

    /// Same in bytes.
    pub fn planned_workspace_bytes(&self) -> usize {
        self.planned_ws_elems * std::mem::size_of::<f32>()
    }

    /// An [`Arena`] pre-sized for this model's planned layers — what each
    /// serving worker owns. Peak tracked bytes of a forward pass through
    /// it equal the max (not the sum) of per-layer workspaces.
    pub fn sized_arena(&self) -> Arena {
        Arena::with_capacity(self.planned_ws_elems)
    }

    /// Eagerly build (and cache) every conv layer's plan for batch size
    /// `batch`, sharing the per-layer kernel prepacks already in the
    /// cache. Returns the max workspace elems over conv layers at that
    /// batch — what an engine pinning several batch sizes folds into its
    /// arena sizing. Plans build under the planning context, so
    /// [`Model::plan`]/[`Model::plan_with`] must have run first.
    pub fn prepare_batch(&self, batch: usize) -> usize {
        let ctx = self.planned_ctx.clone().unwrap_or_default();
        let mut max_ws = 0usize;
        for (i, cs) in self.conv_shapes(batch) {
            if let Layer::Conv { kernel, .. } = &self.layers[i] {
                let plan = self.plan_for(i, &cs, &ctx, kernel);
                max_ws = max_ws.max(plan.workspace_elems());
            }
        }
        max_ws
    }

    /// Fetch (or lazily build) the prepared plan for conv layer `idx` on
    /// geometry `cs`. The kernel-side prepack is fetched from (or
    /// inserted into) the per-layer prepack cache, so every geometry of a
    /// layer — including transient over-cap ones — shares one prepacked
    /// copy.
    fn plan_for(
        &self,
        idx: usize,
        cs: &ConvShape,
        ctx: &ConvContext,
        kernel: &crate::tensor::Kernel,
    ) -> Arc<dyn ConvPlan> {
        // Build under the planning context so cached and lazily-built
        // plans agree on threads / MEC T / FFT cache cap / precision.
        let build_ctx = self.planned_ctx.as_ref().unwrap_or(ctx);
        let key = (idx, *cs, build_ctx.precision);
        if let Some(p) = self.plan_cache.read().unwrap().get(&key) {
            return Arc::clone(p);
        }
        let algo = self.plans[idx].unwrap_or(AlgoKind::Mec);
        let algo_impl = algo.build();
        let pk_key = (idx, algo, build_ctx.precision);
        let pk = {
            let cached = self.prepack_cache.read().unwrap().get(&pk_key).cloned();
            match cached {
                Some(p) => p,
                None => {
                    let built = algo_impl.prepack(build_ctx, cs, kernel);
                    let mut cache = self.prepack_cache.write().unwrap();
                    Arc::clone(cache.entry(pk_key).or_insert(built))
                }
            }
        };
        let built: Arc<dyn ConvPlan> = Arc::from(algo_impl.plan_shared(build_ctx, cs, pk));
        let mut cache = self.plan_cache.write().unwrap();
        if !cache.contains_key(&key)
            && cache.keys().filter(|(i, _, _)| *i == idx).count()
                >= MAX_CACHED_GEOMETRIES_PER_LAYER
        {
            // Bounded cache: execute this one transiently instead of
            // holding yet another plan per odd batch size (its prepack is
            // still the shared one).
            return built;
        }
        Arc::clone(cache.entry(key).or_insert(built))
    }

    /// Prepared plans for conv layer `idx`, one per cached geometry
    /// (tests/observability — the prepack-sharing assertions compare
    /// their [`ConvPlan::shared_prepack`] pointers).
    pub fn cached_plans_for_layer(&self, idx: usize) -> Vec<Arc<dyn ConvPlan>> {
        self.plan_cache
            .read()
            .unwrap()
            .iter()
            .filter(|((i, _, _), _)| *i == idx)
            .map(|(_, p)| Arc::clone(p))
            .collect()
    }

    /// Number of cached kernel-side prepacks (≤ one per conv layer).
    pub fn cached_prepacks(&self) -> usize {
        self.prepack_cache.read().unwrap().len()
    }

    /// Run a forward pass on a batch. Returns the final activation
    /// (logits or probabilities, depending on the last layer). All conv
    /// layers execute out of `arena`; after the first pass at a given
    /// batch size the hot path performs no tracked allocation.
    pub fn forward(&self, ctx: &ConvContext, batch: &Tensor, arena: &mut Arena) -> Tensor {
        let mut x = batch.clone();
        for (i, layer) in self.layers.iter().enumerate() {
            x = self.forward_layer(i, layer, ctx, x, arena, None);
        }
        x
    }

    /// [`Model::forward`] with a caller-owned [`PlanMemo`] in front of
    /// the model's `RwLock`ed plan cache: once the memo has seen a batch
    /// size, the pass resolves every conv plan with a plain `HashMap`
    /// lookup — no locks on the hot path. This is what
    /// [`Session`](crate::engine::Session) runs.
    pub fn forward_memo(
        &self,
        ctx: &ConvContext,
        batch: &Tensor,
        arena: &mut Arena,
        memo: &mut PlanMemo,
    ) -> Tensor {
        let mut x = batch.clone();
        for (i, layer) in self.layers.iter().enumerate() {
            x = self.forward_layer(i, layer, ctx, x, arena, Some(&mut *memo));
        }
        x
    }

    fn forward_layer(
        &self,
        idx: usize,
        layer: &Layer,
        ctx: &ConvContext,
        x: Tensor,
        arena: &mut Arena,
        memo: Option<&mut PlanMemo>,
    ) -> Tensor {
        match layer {
            Layer::Conv {
                kernel, bias, sh, sw, ph, pw,
            } => {
                let padded = if *ph > 0 || *pw > 0 {
                    x.pad_spatial(*ph, *pw)
                } else {
                    x
                };
                let cs = ConvShape::new(padded.shape(), kernel.shape(), *sh, *sw);
                let plan = match memo {
                    Some(memo) => {
                        // Same build precision plan_for would resolve,
                        // so the memo key agrees with the model cache.
                        let prec = self.planned_ctx.as_ref().unwrap_or(ctx).precision;
                        match memo.map.get(&(idx, cs, prec)) {
                            Some(p) => Arc::clone(p),
                            None => {
                                let p = self.plan_for(idx, &cs, ctx, kernel);
                                // Same per-layer bound as the model cache:
                                // odd batch sizes beyond it stay transient.
                                if memo.map.keys().filter(|(i, _, _)| *i == idx).count()
                                    < MAX_CACHED_GEOMETRIES_PER_LAYER
                                {
                                    memo.map.insert((idx, cs, prec), Arc::clone(&p));
                                }
                                p
                            }
                        }
                    }
                    None => self.plan_for(idx, &cs, ctx, kernel),
                };
                let mut out = Tensor::zeros(cs.output());
                plan.execute(&padded, arena, &mut out);
                // Bias add (per output channel).
                let kc = kernel.shape().kc;
                for chunk in out.data_mut().chunks_exact_mut(kc) {
                    for (v, b) in chunk.iter_mut().zip(bias) {
                        *v += b;
                    }
                }
                out
            }
            Layer::Relu => {
                let mut out = x;
                for v in out.data_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                out
            }
            Layer::MaxPool { k, s } => max_pool(&x, *k, *s),
            Layer::Flatten => {
                let sh = x.shape();
                Tensor::from_vec(
                    Nhwc::new(sh.n, 1, 1, sh.h * sh.w * sh.c),
                    x.into_vec(),
                )
            }
            Layer::Dense { w, bias, d_in, d_out } => {
                let sh = x.shape();
                let n = sh.n;
                assert_eq!(sh.h * sh.w * sh.c, *d_in);
                let mut out = Tensor::zeros(Nhwc::new(n, 1, 1, *d_out));
                let a = MatRef::new(x.data(), n, *d_in);
                let b = MatRef::new(w, *d_in, *d_out);
                let mut c = MatMut::new(out.data_mut(), n, *d_out);
                gemm_ex(a, b, &mut c, 1.0, 0.0, ctx.threads, ctx.blocks);
                for row in out.data_mut().chunks_exact_mut(*d_out) {
                    for (v, bb) in row.iter_mut().zip(bias) {
                        *v += bb;
                    }
                }
                out
            }
            Layer::Softmax => {
                let mut out = x;
                let c = out.shape().c;
                for row in out.data_mut().chunks_exact_mut(c) {
                    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut sum = 0.0;
                    for v in row.iter_mut() {
                        *v = (*v - m).exp();
                        sum += *v;
                    }
                    for v in row.iter_mut() {
                        *v /= sum;
                    }
                }
                out
            }
        }
    }

    /// Argmax class per sample of the final activation.
    pub fn predict(&self, ctx: &ConvContext, batch: &Tensor, arena: &mut Arena) -> Vec<usize> {
        let out = self.forward(ctx, batch, arena);
        let c = out.shape().c;
        out.data()
            .chunks_exact(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}

fn max_pool(x: &Tensor, k: usize, s: usize) -> Tensor {
    let sh = x.shape();
    let oh = (sh.h - k) / s + 1;
    let ow = (sh.w - k) / s + 1;
    let out_shape = Nhwc::new(sh.n, oh, ow, sh.c);
    let mut out = Tensor::zeros(out_shape);
    for n in 0..sh.n {
        for y in 0..oh {
            for x0 in 0..ow {
                for c in 0..sh.c {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..k {
                        for dx in 0..k {
                            m = m.max(x.at(n, y * s + dy, x0 * s + dx, c));
                        }
                    }
                    *out.at_mut(n, y, x0, c) = m;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Kernel, KernelShape};
    use crate::util::Rng;

    fn tiny_model() -> Model {
        let mut rng = Rng::new(3);
        Model::new(
            "tiny",
            (8, 8, 1),
            vec![
                Layer::Conv {
                    kernel: Kernel::random(KernelShape::new(3, 3, 1, 4), &mut rng),
                    bias: vec![0.1; 4],
                    sh: 1,
                    sw: 1,
                    ph: 1,
                    pw: 1,
                },
                Layer::Relu,
                Layer::MaxPool { k: 2, s: 2 },
                Layer::Flatten,
                Layer::Dense {
                    w: {
                        let mut w = vec![0.0; 4 * 4 * 4 * 3];
                        rng.fill_uniform(&mut w, -0.5, 0.5);
                        w
                    },
                    bias: vec![0.0; 3],
                    d_in: 64,
                    d_out: 3,
                },
                Layer::Softmax,
            ],
        )
    }

    #[test]
    fn validate_chains_shapes() {
        let m = tiny_model();
        assert_eq!(m.validate(), Nhwc::new(1, 1, 1, 3));
        assert_eq!(m.output_features(), 3);
        assert!(m.param_count() > 0);
    }

    #[test]
    fn forward_produces_probabilities() {
        let mut m = tiny_model();
        m.plan(
            &Planner::new(),
            &Budget::unlimited(),
            &ConvContext::default(),
            2,
        );
        let mut rng = Rng::new(9);
        let batch = Tensor::random(Nhwc::new(2, 8, 8, 1), &mut rng);
        let mut arena = m.sized_arena();
        let out = m.forward(&ConvContext::default(), &batch, &mut arena);
        assert_eq!(out.shape(), Nhwc::new(2, 1, 1, 3));
        for row in out.data().chunks_exact(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "softmax row sums to {sum}");
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
        // Planning sized the arena once; the pass must not have grown it.
        assert_eq!(arena.bytes(), m.planned_workspace_bytes());
    }

    #[test]
    fn algorithm_choice_does_not_change_outputs() {
        let mut m = tiny_model();
        let mut rng = Rng::new(11);
        let batch = Tensor::random(Nhwc::new(3, 8, 8, 1), &mut rng);
        let ctx = ConvContext::default();
        let mut arena = Arena::new();
        let mut outs = Vec::new();
        for algo in [AlgoKind::Direct, AlgoKind::Im2col, AlgoKind::Mec, AlgoKind::Winograd] {
            m.pin_algo(algo);
            outs.push(m.forward(&ctx, &batch, &mut arena));
        }
        for o in &outs[1..] {
            crate::util::assert_allclose(o.data(), outs[0].data(), 1e-3, "algo equivalence");
        }
    }

    #[test]
    fn predict_returns_classes() {
        let mut m = tiny_model();
        m.pin_algo(AlgoKind::Mec);
        let mut rng = Rng::new(13);
        let batch = Tensor::random(Nhwc::new(4, 8, 8, 1), &mut rng);
        let preds = m.predict(&ConvContext::default(), &batch, &mut Arena::new());
        assert_eq!(preds.len(), 4);
        assert!(preds.iter().all(|&p| p < 3));
    }

    #[test]
    fn max_pool_values() {
        let x = Tensor::from_fn(Nhwc::new(1, 4, 4, 1), |_, h, w, _| (h * 4 + w) as f32);
        let p = max_pool(&x, 2, 2);
        assert_eq!(p.shape(), Nhwc::new(1, 2, 2, 1));
        assert_eq!(p.data(), &[5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn plan_assigns_conv_layers_only() {
        let mut m = tiny_model();
        m.plan(
            &Planner::new(),
            &Budget::unlimited(),
            &ConvContext::default(),
            1,
        );
        let summary = m.plan_summary();
        assert_eq!(summary.len(), 1);
        assert_eq!(summary[0].0, 0);
        // The conv layer's plan is prepared eagerly and sizes the arena.
        assert_eq!(m.planned_layer_workspaces().len(), 1);
        assert_eq!(
            m.planned_workspace_bytes(),
            m.planned_layer_workspaces()[0].1
        );
    }

    #[test]
    fn per_batch_plans_share_one_kernel_prepack() {
        // Two geometries of the same layer (planned batch + a dynamic
        // batching remainder) must hold the SAME prepacked kernel
        // allocation — pointer equality, not just equal bytes.
        let mut m = tiny_model();
        let ctx = ConvContext::default();
        m.plan(&Planner::new(), &Budget::unlimited(), &ctx, 4);
        let mut rng = Rng::new(23);
        let full = Tensor::random(Nhwc::new(4, 8, 8, 1), &mut rng);
        let remainder = Tensor::random(Nhwc::new(3, 8, 8, 1), &mut rng);
        let mut arena = m.sized_arena();
        let _ = m.forward(&ctx, &full, &mut arena);
        let _ = m.forward(&ctx, &remainder, &mut arena); // lazily plans n=3
        let plans = m.cached_plans_for_layer(0);
        assert_eq!(plans.len(), 2, "expected planned + lazily-built geometry");
        assert_eq!(m.cached_prepacks(), 1, "one prepack per conv layer");
        let a = plans[0].shared_prepack().expect("plan exposes its prepack");
        let b = plans[1].shared_prepack().expect("plan exposes its prepack");
        assert!(Arc::ptr_eq(&a, &b), "prepack duplicated across batch sizes");
        // And the refcount proves the cache + both plans hold one copy.
        assert!(Arc::strong_count(&a) >= 3);
    }

    #[test]
    fn pinned_model_does_not_leak_precision_across_forwards() {
        // pin_algo leaves planned_ctx=None, so lazily-built plans follow
        // each forward's context — the cache key carries the precision,
        // so a q16 forward must never hand its quantized plan to a later
        // f32 forward (and vice versa).
        use crate::tensor::Precision;
        let mut m = tiny_model();
        m.pin_algo(AlgoKind::Mec);
        let mut rng = Rng::new(29);
        let batch = Tensor::random(Nhwc::new(1, 8, 8, 1), &mut rng);
        let mut arena = Arena::new();
        let q16_ctx = ConvContext::default().with_precision(Precision::Q16);
        let f32_ctx = ConvContext::default();
        let a_q16 = m.forward(&q16_ctx, &batch, &mut arena);
        let a_f32 = m.forward(&f32_ctx, &batch, &mut arena);
        // The q16 plan is still cached and reproduces itself exactly.
        let b_q16 = m.forward(&q16_ctx, &batch, &mut arena);
        assert_eq!(a_q16.data(), b_q16.data());
        // The f32 forward must equal a never-quantized model bitwise —
        // i.e. it did NOT silently reuse the q16-packed plan.
        let mut fresh = tiny_model();
        fresh.pin_algo(AlgoKind::Mec);
        let want = fresh.forward(&f32_ctx, &batch, &mut arena);
        assert_eq!(a_f32.data(), want.data());
    }

    #[test]
    fn forward_memo_matches_forward_bitwise_and_memoizes() {
        let mut m = tiny_model();
        let ctx = ConvContext::default();
        m.plan(&Planner::new(), &Budget::unlimited(), &ctx, 2);
        let mut rng = Rng::new(31);
        let batch = Tensor::random(Nhwc::new(2, 8, 8, 1), &mut rng);
        let mut arena = m.sized_arena();
        let want = m.forward(&ctx, &batch, &mut arena);
        let mut memo = PlanMemo::new();
        assert!(memo.is_empty());
        let a = m.forward_memo(&ctx, &batch, &mut arena, &mut memo);
        assert_eq!(memo.len(), 1, "one conv layer memoized");
        // Second pass resolves through the memo alone (same plan, so
        // bitwise-identical again).
        let b = m.forward_memo(&ctx, &batch, &mut arena, &mut memo);
        assert_eq!(memo.len(), 1);
        assert_eq!(a.data(), want.data());
        assert_eq!(b.data(), want.data());
    }

    #[test]
    fn forward_memo_does_not_leak_precision_across_contexts() {
        // One memo reused under q16 then f32 contexts must not hand the
        // quantized plan to the f32 forward — the memo key carries the
        // build precision exactly like the model's plan cache.
        use crate::tensor::Precision;
        let mut m = tiny_model();
        m.pin_algo(AlgoKind::Mec);
        let mut rng = Rng::new(37);
        let batch = Tensor::random(Nhwc::new(1, 8, 8, 1), &mut rng);
        let mut arena = Arena::new();
        let mut memo = PlanMemo::new();
        let q16_ctx = ConvContext::default().with_precision(Precision::Q16);
        let f32_ctx = ConvContext::default();
        let a_q16 = m.forward_memo(&q16_ctx, &batch, &mut arena, &mut memo);
        let a_f32 = m.forward_memo(&f32_ctx, &batch, &mut arena, &mut memo);
        assert_eq!(memo.len(), 2, "one memo entry per precision");
        let mut fresh = tiny_model();
        fresh.pin_algo(AlgoKind::Mec);
        let want = fresh.forward(&f32_ctx, &batch, &mut arena);
        assert_eq!(a_f32.data(), want.data(), "memo leaked the q16 plan");
        let b_q16 = m.forward_memo(&q16_ctx, &batch, &mut arena, &mut memo);
        assert_eq!(a_q16.data(), b_q16.data());
    }

    #[test]
    fn conv_shapes_walks_padded_geometry() {
        let m = tiny_model();
        let shapes = m.conv_shapes(3);
        assert_eq!(shapes.len(), 1);
        let (idx, cs) = shapes[0];
        assert_eq!(idx, 0);
        // 8x8 input with 1px padding at batch 3.
        assert_eq!(cs.input, Nhwc::new(3, 10, 10, 1));
        assert_eq!(cs.output(), Nhwc::new(3, 8, 8, 4));
    }

    #[test]
    fn prepare_batch_caches_extra_geometry_sharing_prepacks() {
        let mut m = tiny_model();
        let ctx = ConvContext::default();
        m.plan(&Planner::new(), &Budget::unlimited(), &ctx, 4);
        let ws4 = m.planned_workspace_elems();
        let ws2 = m.prepare_batch(2);
        assert!(ws2 <= ws4, "smaller batch needs no more workspace");
        let plans = m.cached_plans_for_layer(0);
        assert_eq!(plans.len(), 2, "planned batch + prepared batch");
        assert_eq!(m.cached_prepacks(), 1, "prepack shared, not rebuilt");
    }

    #[test]
    fn repinning_invalidates_prepared_plans() {
        let mut m = tiny_model();
        m.pin_algo(AlgoKind::Im2col);
        let mut rng = Rng::new(17);
        let batch = Tensor::random(Nhwc::new(1, 8, 8, 1), &mut rng);
        let ctx = ConvContext::default();
        let mut arena = Arena::new();
        let a = m.forward(&ctx, &batch, &mut arena);
        // Re-pin to a different algorithm: stale plans must not be reused.
        m.pin_algo(AlgoKind::Direct);
        assert!(m.planned_layer_workspaces().is_empty());
        let b = m.forward(&ctx, &batch, &mut arena);
        crate::util::assert_allclose(a.data(), b.data(), 1e-4, "repin equivalence");
    }
}
