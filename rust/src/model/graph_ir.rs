//! Graph IR — the DAG core of the model layer.
//!
//! The sequential `Vec<Layer>` executor could only express straight-line
//! networks and allocated every activation per request. This module
//! replaces that core with a typed DAG plus an explicit pass pipeline,
//! extending the paper's planning thesis — workspace footprints are a
//! *plan-time* quantity, sized by a max over live buffers rather than a
//! sum over allocations (§3.4) — from lowering buffers to activations:
//!
//! * [`Graph`] / [`GraphBuilder`] — `NodeId`-addressed ops ([`Op`]):
//!   every [`Layer`] op plus [`Op::Add`] and [`Op::Concat`] for
//!   residual / branching topologies. [`Graph::sequential`] is the
//!   compatibility constructor: every `Vec<Layer>` call site builds the
//!   same chain it always did, with node ids equal to the old layer
//!   indices (the graph input is a [`Src`], not a node).
//! * Pass pipeline, run once by [`Graph::compile`]: shape inference
//!   (validates every edge), conv+bias+relu fusion (a conv whose sole
//!   consumer is a relu absorbs it into its bias epilogue), dead-node
//!   elimination, then the **liveness pass**.
//! * The liveness pass assigns every intermediate activation a slot in
//!   the shared [`ActivationArena`](crate::memory::ActivationArena) by
//!   interval coloring: values interfere only while both are live, so
//!   the arena's footprint is the max over live sets — not the sum over
//!   node outputs — mirroring the max-over-layers workspace rule.
//! * [`ExecGraph::run`] executes the compiled steps with **zero tracked
//!   allocations** in steady state: activations come out of the arena's
//!   slots (moved into [`Tensor`]s and back without copying), conv
//!   padding is written into a planned pad slot instead of a fresh
//!   tensor, and workspaces come from the caller's [`Arena`].

use crate::conv::{ConvContext, ConvPlan};
use crate::gemm::{gemm_ex, MatMut, MatRef};
use crate::memory::{ActivationArena, Arena};
use crate::model::layer::Layer;
use crate::tensor::{ConvShape, Kernel, Nhwc, Tensor};
use std::sync::Arc;

/// Index of a node in its [`Graph`]. For graphs built by
/// [`Graph::sequential`] this equals the historical layer index.
pub type NodeId = usize;

/// A value source: the graph's external input batch, or another node's
/// output. Keeping the input out of the node table preserves the old
/// layer numbering for every sequential call site.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Src {
    /// The external NHWC input batch.
    Input,
    /// The output of node `NodeId`.
    Node(NodeId),
}

/// One graph operation. Every sequential [`Layer`] is an op; `Add` and
/// `Concat` are the multi-input ops that make residual and branching
/// topologies expressible.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// A classic layer (conv / relu / maxpool / flatten / dense /
    /// softmax) with exactly one input edge.
    Layer(Layer),
    /// Elementwise sum of ≥ 2 same-shaped inputs (residual connections).
    Add,
    /// Channel-axis concatenation of ≥ 2 inputs sharing (h, w)
    /// (Inception/DenseNet-style branching).
    Concat,
}

impl Op {
    /// Short tag for display/metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Layer(l) => l.kind(),
            Op::Add => "add",
            Op::Concat => "concat",
        }
    }

    /// Parameter count (weights + biases).
    pub fn param_count(&self) -> usize {
        match self {
            Op::Layer(l) => l.param_count(),
            Op::Add | Op::Concat => 0,
        }
    }

    /// Output shape from the input shapes. Panics on arity or geometry
    /// mismatch (caught at [`GraphBuilder::finish`]; the model loader
    /// goes through [`Op::try_output_shape`] instead).
    pub fn output_shape(&self, inputs: &[Nhwc]) -> Nhwc {
        self.try_output_shape(inputs).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`Op::output_shape`] with mismatches reported as `Err` instead of
    /// a panic — a corrupt `.mecw` file must error, never abort.
    pub fn try_output_shape(&self, inputs: &[Nhwc]) -> Result<Nhwc, String> {
        match self {
            Op::Layer(l) => {
                if inputs.len() != 1 {
                    return Err(format!("{} takes one input", self.kind()));
                }
                l.try_output_shape(inputs[0])
            }
            Op::Add => {
                if inputs.len() < 2 {
                    return Err("add needs >= 2 inputs".to_string());
                }
                for s in &inputs[1..] {
                    if *s != inputs[0] {
                        return Err(format!(
                            "add inputs must share a shape ({} vs {})",
                            s, inputs[0]
                        ));
                    }
                }
                Ok(inputs[0])
            }
            Op::Concat => {
                if inputs.len() < 2 {
                    return Err("concat needs >= 2 inputs".to_string());
                }
                let first = inputs[0];
                let mut c = 0;
                for s in inputs {
                    if (s.n, s.h, s.w) != (first.n, first.h, first.w) {
                        return Err(format!(
                            "concat inputs must share (n, h, w) ({} vs {})",
                            s, first
                        ));
                    }
                    c += s.c;
                }
                Ok(Nhwc::new(first.n, first.h, first.w, c))
            }
        }
    }
}

/// One node: an op plus its input edges.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub op: Op,
    pub srcs: Vec<Src>,
}

/// A typed DAG of ops over one external input. Construct with
/// [`GraphBuilder`] (or [`Graph::sequential`] for chains); node order is
/// topological by construction, and [`Graph::compile`] runs the pass
/// pipeline producing an [`ExecGraph`].
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    pub name: String,
    /// Per-sample input shape (h, w, c); batch dim comes from requests.
    pub input_hwc: (usize, usize, usize),
    nodes: Vec<Node>,
    output: Src,
}

impl Graph {
    /// Compatibility constructor: chain `layers` input → L0 → L1 → … so
    /// node ids equal the historical layer indices.
    pub fn sequential(name: &str, input_hwc: (usize, usize, usize), layers: Vec<Layer>) -> Graph {
        Graph::try_sequential(name, input_hwc, layers).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`Graph::sequential`] with chaining mismatches reported as `Err`
    /// instead of a panic (the v1 loader path).
    pub fn try_sequential(
        name: &str,
        input_hwc: (usize, usize, usize),
        layers: Vec<Layer>,
    ) -> Result<Graph, String> {
        let mut b = GraphBuilder::new(name, input_hwc);
        let mut at = b.input();
        for layer in layers {
            at = b.layer(at, layer);
        }
        b.try_finish(at)
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// The value the graph returns.
    pub fn output(&self) -> Src {
        self.output
    }

    pub fn param_count(&self) -> usize {
        self.nodes.iter().map(|n| n.op.param_count()).sum()
    }

    /// If the graph is a pure chain of single-input layer ops ending at
    /// the output, the layers in order — what the `.mecw` v1 writer and
    /// the AOT weight-order path consume. `None` for branching graphs.
    pub fn as_sequential_layers(&self) -> Option<Vec<Layer>> {
        let mut layers = Vec::with_capacity(self.nodes.len());
        for (i, node) in self.nodes.iter().enumerate() {
            let want = if i == 0 { Src::Input } else { Src::Node(i - 1) };
            if node.srcs.as_slice() != [want] {
                return None;
            }
            match &node.op {
                Op::Layer(l) => layers.push(l.clone()),
                _ => return None,
            }
        }
        let last_ok = match self.output {
            Src::Node(v) => v + 1 == self.nodes.len(),
            Src::Input => self.nodes.is_empty(),
        };
        if last_ok {
            Some(layers)
        } else {
            None
        }
    }

    /// Per-node output shapes at batch size `batch`, in node order.
    /// Panics on any edge mismatch — this *is* the shape-inference pass.
    pub fn infer_shapes(&self, batch: usize) -> Vec<Nhwc> {
        self.try_infer_shapes(batch).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`Graph::infer_shapes`] with mismatches as `Err` (loader path).
    pub fn try_infer_shapes(&self, batch: usize) -> Result<Vec<Nhwc>, String> {
        let (h, w, c) = self.input_hwc;
        let input = Nhwc::new(batch.max(1), h, w, c);
        let mut shapes: Vec<Nhwc> = Vec::with_capacity(self.nodes.len());
        for (i, node) in self.nodes.iter().enumerate() {
            let ins: Vec<Nhwc> = node
                .srcs
                .iter()
                .map(|s| match s {
                    Src::Input => input,
                    Src::Node(v) => shapes[*v],
                })
                .collect();
            let shape = node
                .op
                .try_output_shape(&ins)
                .map_err(|e| format!("node {i}: {e}"))?;
            shapes.push(shape);
        }
        Ok(shapes)
    }

    /// Validate every edge by propagating a batch-1 shape; returns the
    /// output shape.
    pub fn validate(&self) -> Nhwc {
        let shapes = self.infer_shapes(1);
        match self.output {
            Src::Input => {
                let (h, w, c) = self.input_hwc;
                Nhwc::new(1, h, w, c)
            }
            Src::Node(v) => shapes[v],
        }
    }

    /// Run the pass pipeline: shape inference → conv+bias+relu fusion →
    /// dead-node elimination → liveness slot assignment.
    pub fn compile(&self) -> ExecGraph {
        compile(self)
    }
}

/// Builder for a [`Graph`]. Sources must refer to the input or to
/// already-built nodes, so node order is topological by construction.
pub struct GraphBuilder {
    name: String,
    input_hwc: (usize, usize, usize),
    nodes: Vec<Node>,
}

impl GraphBuilder {
    pub fn new(name: &str, input_hwc: (usize, usize, usize)) -> GraphBuilder {
        GraphBuilder {
            name: name.to_string(),
            input_hwc,
            nodes: Vec::new(),
        }
    }

    /// The graph's external input.
    pub fn input(&self) -> Src {
        Src::Input
    }

    fn push(&mut self, op: Op, srcs: Vec<Src>) -> Src {
        for s in &srcs {
            if let Src::Node(v) = s {
                assert!(*v < self.nodes.len(), "source node {v} not built yet");
            }
        }
        self.nodes.push(Node { op, srcs });
        Src::Node(self.nodes.len() - 1)
    }

    /// Append any single-input layer op.
    pub fn layer(&mut self, src: Src, layer: Layer) -> Src {
        self.push(Op::Layer(layer), vec![src])
    }

    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        &mut self,
        src: Src,
        kernel: Kernel,
        bias: Vec<f32>,
        sh: usize,
        sw: usize,
        ph: usize,
        pw: usize,
    ) -> Src {
        self.layer(src, Layer::Conv { kernel, bias, sh, sw, ph, pw })
    }

    pub fn relu(&mut self, src: Src) -> Src {
        self.layer(src, Layer::Relu)
    }

    pub fn max_pool(&mut self, src: Src, k: usize, s: usize) -> Src {
        self.layer(src, Layer::MaxPool { k, s })
    }

    pub fn flatten(&mut self, src: Src) -> Src {
        self.layer(src, Layer::Flatten)
    }

    pub fn dense(
        &mut self,
        src: Src,
        w: Vec<f32>,
        bias: Vec<f32>,
        d_in: usize,
        d_out: usize,
    ) -> Src {
        self.layer(src, Layer::Dense { w, bias, d_in, d_out })
    }

    pub fn softmax(&mut self, src: Src) -> Src {
        self.layer(src, Layer::Softmax)
    }

    /// Elementwise sum (residual connection).
    #[allow(clippy::should_implement_trait)]
    pub fn add(&mut self, srcs: &[Src]) -> Src {
        assert!(srcs.len() >= 2, "add needs >= 2 inputs");
        self.push(Op::Add, srcs.to_vec())
    }

    /// Channel-axis concatenation.
    pub fn concat(&mut self, srcs: &[Src]) -> Src {
        assert!(srcs.len() >= 2, "concat needs >= 2 inputs");
        self.push(Op::Concat, srcs.to_vec())
    }

    /// Seal the graph with `output` as its returned value; validates
    /// every edge via shape inference. Panics on mismatch (the in-memory
    /// construction path; the loader uses [`GraphBuilder::try_finish`]).
    pub fn finish(self, output: Src) -> Graph {
        self.try_finish(output).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`GraphBuilder::finish`] with validation failures as `Err`
    /// instead of a panic — a corrupt `.mecw` file must error, never
    /// abort the loading process.
    pub fn try_finish(self, output: Src) -> Result<Graph, String> {
        if let Src::Node(v) = output {
            if v >= self.nodes.len() {
                return Err(format!("output node {v} not built"));
            }
        }
        let g = Graph {
            name: self.name,
            input_hwc: self.input_hwc,
            nodes: self.nodes,
            output,
        };
        g.try_infer_shapes(1)?;
        Ok(g)
    }
}

/// One executable step of a compiled graph.
#[derive(Debug, Clone)]
pub struct Step {
    /// The node whose op this step runs (for fused conv+relu this is the
    /// conv; the absorbed relu has no step).
    pub node: NodeId,
    /// Input values, post-fusion.
    pub srcs: Vec<Src>,
    /// The value this step produces (the relu's id when fused, else
    /// `node`) — what downstream `srcs` refer to.
    pub out_value: NodeId,
    /// Arena slot holding the produced value.
    pub out_slot: usize,
    /// Conv only: slot the padded input is written into (`None` when the
    /// conv is unpadded).
    pub pad_slot: Option<usize>,
    /// Conv only: apply `max(0, ·)` in the bias epilogue (fusion pass).
    pub fused_relu: bool,
}

/// A compiled graph: the executable step list plus the liveness pass's
/// activation-slot plan. All sizes are per sample; they scale linearly
/// with the batch dimension.
#[derive(Debug, Clone)]
pub struct ExecGraph {
    steps: Vec<Step>,
    /// Per-sample (batch-1) output shape per node id.
    shapes: Vec<Nhwc>,
    /// Per-sample slot sizes — Σ is the activation arena requirement.
    slot_elems: Vec<usize>,
    /// Slot of each live value (indexed by value/node id).
    value_slot: Vec<Option<usize>>,
    /// Per-sample max over step live sets (the interval-coloring lower
    /// bound the slot packing is asserted against).
    max_live_elems: usize,
    output: Src,
}

impl ExecGraph {
    pub fn steps(&self) -> &[Step] {
        &self.steps
    }

    /// Per-sample activation slot sizes (floats).
    pub fn slot_elems(&self) -> &[usize] {
        &self.slot_elems
    }

    /// Activation-arena floats required at `batch` (Σ over slots).
    pub fn arena_elems(&self, batch: usize) -> usize {
        self.slot_elems.iter().sum::<usize>() * batch.max(1)
    }

    /// Max live-set floats at `batch` — what the arena footprint is
    /// asserted equal to on packing-friendly graphs (and can never be
    /// beaten by any allocator).
    pub fn max_live_elems(&self, batch: usize) -> usize {
        self.max_live_elems * batch.max(1)
    }

    /// Per-sample output shape of `node` (n = 1).
    pub fn shape_of(&self, node: NodeId) -> Nhwc {
        self.shapes[node]
    }

    /// The conv geometry each compiled conv step plans on at `batch`
    /// (padding applied), in execution order.
    pub fn conv_shapes(&self, graph: &Graph, batch: usize) -> Vec<(NodeId, ConvShape)> {
        let mut out = Vec::new();
        for step in &self.steps {
            if let Op::Layer(Layer::Conv { kernel, sh, sw, ph, pw, .. }) = &graph.node(step.node).op
            {
                let in_shape = self.src_shape(graph, step.srcs[0], batch.max(1));
                let padded =
                    Nhwc::new(in_shape.n, in_shape.h + 2 * ph, in_shape.w + 2 * pw, in_shape.c);
                out.push((step.node, ConvShape::new(padded, kernel.shape(), *sh, *sw)));
            }
        }
        out
    }

    fn src_shape(&self, graph: &Graph, src: Src, n: usize) -> Nhwc {
        match src {
            Src::Input => {
                let (h, w, c) = graph.input_hwc;
                Nhwc::new(n, h, w, c)
            }
            Src::Node(v) => at_batch(self.shapes[v], n),
        }
    }

    /// Execute the compiled steps on `batch`. Workspaces come from `ws`,
    /// activations from `acts` (grown — tracked — on first sight of a
    /// batch size, then reused); `resolve` maps a conv node + geometry to
    /// its prepared plan; `observe` (calibration) sees every conv input
    /// before it is lowered.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        graph: &Graph,
        ctx: &ConvContext,
        batch: &Tensor,
        ws: &mut Arena,
        acts: &mut ActivationArena,
        resolve: &mut dyn FnMut(NodeId, &ConvShape, &Kernel) -> Arc<dyn ConvPlan>,
        mut observe: Option<&mut dyn FnMut(NodeId, &Tensor)>,
    ) -> Tensor {
        let n = batch.shape().n;
        // Grow every slot to this batch's requirement up front (tracked
        // once; later passes at ≤ this batch size are allocation-free).
        for (i, &elems) in self.slot_elems.iter().enumerate() {
            acts.ensure(i, elems * n);
        }
        for step in &self.steps {
            // Breadcrumb for panic containment: if this step unwinds
            // (kernel bug, or the `engine.forward` fault site below),
            // the scope's Drop records the node index so the serving
            // boundary can report WHICH layer died in its typed error.
            let _layer = crate::fault::LayerScope::enter(step.node);
            crate::faultpoint!("engine.forward");
            self.run_step(step, graph, ctx, batch, ws, acts, resolve, &mut observe, n);
        }
        match self.output {
            Src::Input => batch.clone(),
            Src::Node(v) => {
                let shape = at_batch(self.shapes[v], n);
                let slot = self.value_slot[v].expect("output value has a slot");
                Tensor::from_vec(shape, acts.data(slot)[..shape.len()].to_vec())
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn run_step(
        &self,
        step: &Step,
        graph: &Graph,
        ctx: &ConvContext,
        batch: &Tensor,
        ws: &mut Arena,
        acts: &mut ActivationArena,
        resolve: &mut dyn FnMut(NodeId, &ConvShape, &Kernel) -> Arc<dyn ConvPlan>,
        observe: &mut Option<&mut dyn FnMut(NodeId, &Tensor)>,
        n: usize,
    ) {
        let out_shape = at_batch(self.shapes[step.out_value], n);
        match &graph.node(step.node).op {
            Op::Layer(Layer::Conv { kernel, bias, sh, sw, ph, pw }) => {
                let src = step.srcs[0];
                let in_shape = self.src_shape(graph, src, n);
                // Move the producing slot's buffer into a Tensor (no
                // copy); `Src::Input` reads the caller's batch directly.
                let src_t = self.take_src(acts, src, in_shape, batch);
                let pad_t = step.pad_slot.map(|ps| {
                    let padded_shape =
                        Nhwc::new(n, in_shape.h + 2 * ph, in_shape.w + 2 * pw, in_shape.c);
                    let mut t = take_tensor(acts, ps, padded_shape);
                    pad_into(src_t.tensor(), *ph, *pw, &mut t);
                    t
                });
                let conv_in: &Tensor = pad_t.as_ref().unwrap_or_else(|| src_t.tensor());
                let cs = ConvShape::new(conv_in.shape(), kernel.shape(), *sh, *sw);
                let plan = resolve(step.node, &cs, kernel);
                if let Some(obs) = observe.as_mut() {
                    obs(step.node, conv_in);
                }
                let mut out = take_tensor(acts, step.out_slot, out_shape);
                // Route the session's (possibly capped) thread budget to
                // the plan, so `Engine::session_with_threads` is exact.
                plan.execute_par(conv_in, ws, &mut out, &ctx.par);
                // Bias (+ fused relu) epilogue: one pass over the output.
                let kc = kernel.shape().kc;
                if step.fused_relu {
                    for chunk in out.data_mut().chunks_exact_mut(kc) {
                        for (v, b) in chunk.iter_mut().zip(bias) {
                            *v = (*v + b).max(0.0);
                        }
                    }
                } else {
                    for chunk in out.data_mut().chunks_exact_mut(kc) {
                        for (v, b) in chunk.iter_mut().zip(bias) {
                            *v += b;
                        }
                    }
                }
                put_tensor(acts, step.out_slot, out);
                if let Some(t) = pad_t {
                    put_tensor(acts, step.pad_slot.unwrap(), t);
                }
                src_t.put_back(acts);
            }
            Op::Layer(Layer::Relu) => {
                self.unary_map(step, acts, batch, n, |v| v.max(0.0));
            }
            Op::Layer(Layer::Softmax) => {
                let c = out_shape.c;
                self.unary_rows(step, acts, batch, n, c, |row| {
                    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut sum = 0.0;
                    for v in row.iter_mut() {
                        *v = (*v - m).exp();
                        sum += *v;
                    }
                    for v in row.iter_mut() {
                        *v /= sum;
                    }
                });
            }
            Op::Layer(Layer::MaxPool { k, s }) => {
                let src = step.srcs[0];
                let in_shape = self.src_shape(graph, src, n);
                let src_t = self.take_src(acts, src, in_shape, batch);
                let mut out = take_tensor(acts, step.out_slot, out_shape);
                max_pool_into(src_t.tensor(), *k, *s, &mut out);
                put_tensor(acts, step.out_slot, out);
                src_t.put_back(acts);
            }
            Op::Layer(Layer::Flatten) => {
                match step.srcs[0] {
                    // Aliased: the data is already in `out_slot`; the
                    // reshape lives in the value's recorded shape.
                    Src::Node(v) if self.value_slot[v] == Some(step.out_slot) => {}
                    src => {
                        let in_shape = self.src_shape(graph, src, n);
                        let src_t = self.take_src(acts, src, in_shape, batch);
                        let mut out = take_tensor(acts, step.out_slot, out_shape);
                        out.data_mut().copy_from_slice(src_t.tensor().data());
                        put_tensor(acts, step.out_slot, out);
                        src_t.put_back(acts);
                    }
                }
            }
            Op::Layer(Layer::Dense { w, bias, d_in, d_out }) => {
                let src = step.srcs[0];
                let in_shape = self.src_shape(graph, src, n);
                assert_eq!(in_shape.h * in_shape.w * in_shape.c, *d_in);
                let src_t = self.take_src(acts, src, in_shape, batch);
                let mut out = take_tensor(acts, step.out_slot, out_shape);
                let a = MatRef::new(src_t.tensor().data(), n, *d_in);
                let b = MatRef::new(w, *d_in, *d_out);
                let mut c = MatMut::new(out.data_mut(), n, *d_out);
                gemm_ex(a, b, &mut c, 1.0, 0.0, &ctx.par, ctx.blocks);
                for row in out.data_mut().chunks_exact_mut(*d_out) {
                    for (v, bb) in row.iter_mut().zip(bias) {
                        *v += bb;
                    }
                }
                put_tensor(acts, step.out_slot, out);
                src_t.put_back(acts);
            }
            Op::Add => {
                let srcs = &step.srcs;
                let mut out = take_tensor(acts, step.out_slot, out_shape);
                let first = self.take_src(acts, srcs[0], out_shape, batch);
                out.data_mut().copy_from_slice(first.tensor().data());
                first.put_back(acts);
                for &src in &srcs[1..] {
                    let t = self.take_src(acts, src, out_shape, batch);
                    for (o, v) in out.data_mut().iter_mut().zip(t.tensor().data()) {
                        *o += v;
                    }
                    t.put_back(acts);
                }
                put_tensor(acts, step.out_slot, out);
            }
            Op::Concat => {
                let mut out = take_tensor(acts, step.out_slot, out_shape);
                let rows = out_shape.n * out_shape.h * out_shape.w;
                let total_c = out_shape.c;
                let mut off = 0;
                for &src in &step.srcs {
                    let in_shape = self.src_shape(graph, src, n);
                    let ci = in_shape.c;
                    let t = self.take_src(acts, src, in_shape, batch);
                    let data = t.tensor().data();
                    for r in 0..rows {
                        out.data_mut()[r * total_c + off..r * total_c + off + ci]
                            .copy_from_slice(&data[r * ci..(r + 1) * ci]);
                    }
                    t.put_back(acts);
                    off += ci;
                }
                put_tensor(acts, step.out_slot, out);
            }
        }
    }

    /// Elementwise unary op, in-place when the liveness pass aliased the
    /// output onto its (dying) input slot.
    fn unary_map(
        &self,
        step: &Step,
        acts: &mut ActivationArena,
        batch: &Tensor,
        n: usize,
        f: impl Fn(f32) -> f32,
    ) {
        let out_shape = at_batch(self.shapes[step.out_value], n);
        match step.srcs[0] {
            Src::Node(v) if self.value_slot[v] == Some(step.out_slot) => {
                let mut t = take_tensor(acts, step.out_slot, out_shape);
                for v in t.data_mut() {
                    *v = f(*v);
                }
                put_tensor(acts, step.out_slot, t);
            }
            src => {
                let src_t = self.take_src(acts, src, out_shape, batch);
                let mut out = take_tensor(acts, step.out_slot, out_shape);
                for (o, v) in out.data_mut().iter_mut().zip(src_t.tensor().data()) {
                    *o = f(*v);
                }
                put_tensor(acts, step.out_slot, out);
                src_t.put_back(acts);
            }
        }
    }

    /// Row-wise unary op (softmax), with the same in-place rule.
    fn unary_rows(
        &self,
        step: &Step,
        acts: &mut ActivationArena,
        batch: &Tensor,
        n: usize,
        c: usize,
        f: impl Fn(&mut [f32]),
    ) {
        let out_shape = at_batch(self.shapes[step.out_value], n);
        match step.srcs[0] {
            Src::Node(v) if self.value_slot[v] == Some(step.out_slot) => {
                let mut t = take_tensor(acts, step.out_slot, out_shape);
                for row in t.data_mut().chunks_exact_mut(c) {
                    f(row);
                }
                put_tensor(acts, step.out_slot, t);
            }
            src => {
                let src_t = self.take_src(acts, src, out_shape, batch);
                let mut out = take_tensor(acts, step.out_slot, out_shape);
                out.data_mut().copy_from_slice(src_t.tensor().data());
                for row in out.data_mut().chunks_exact_mut(c) {
                    f(row);
                }
                put_tensor(acts, step.out_slot, out);
                src_t.put_back(acts);
            }
        }
    }

    fn take_src<'a>(
        &self,
        acts: &mut ActivationArena,
        src: Src,
        shape: Nhwc,
        batch: &'a Tensor,
    ) -> SrcTensor<'a> {
        match src {
            Src::Input => SrcTensor::External(batch),
            Src::Node(v) => {
                let slot = self.value_slot[v].expect("live value has a slot");
                SrcTensor::Slot(slot, take_tensor(acts, slot, shape))
            }
        }
    }
}

/// A step input: either the caller's batch (borrowed) or a slot buffer
/// moved into a Tensor for the duration of the step.
enum SrcTensor<'a> {
    External(&'a Tensor),
    Slot(usize, Tensor),
}

impl SrcTensor<'_> {
    fn tensor(&self) -> &Tensor {
        match self {
            SrcTensor::External(t) => t,
            SrcTensor::Slot(_, t) => t,
        }
    }

    fn put_back(self, acts: &mut ActivationArena) {
        if let SrcTensor::Slot(slot, t) = self {
            put_tensor(acts, slot, t);
        }
    }
}

fn at_batch(per_sample: Nhwc, n: usize) -> Nhwc {
    Nhwc::new(n, per_sample.h, per_sample.w, per_sample.c)
}

/// Move slot `slot`'s buffer out of the arena and into a Tensor of
/// `shape` — no copy; the length is adjusted within the slot's reserved
/// capacity (no allocation once the arena has seen the batch size).
fn take_tensor(acts: &mut ActivationArena, slot: usize, shape: Nhwc) -> Tensor {
    let mut v = acts.take(slot);
    debug_assert!(v.capacity() >= shape.len(), "slot under-reserved");
    v.resize(shape.len(), 0.0);
    Tensor::from_vec(shape, v)
}

/// Return a slot buffer taken by [`take_tensor`].
fn put_tensor(acts: &mut ActivationArena, slot: usize, t: Tensor) {
    acts.put(slot, t.into_vec());
}

/// Write `src` zero-padded by (`ph`, `pw`) into `dst` (shape checked).
fn pad_into(src: &Tensor, ph: usize, pw: usize, dst: &mut Tensor) {
    let s = src.shape();
    let d = dst.shape();
    assert_eq!((d.n, d.h, d.w, d.c), (s.n, s.h + 2 * ph, s.w + 2 * pw, s.c));
    // The slot may hold stale bytes from a previous owner: zero the halo
    // rows/cols, then copy the interior rows contiguously.
    dst.data_mut().fill(0.0);
    let row = s.w * s.c;
    let drow = d.w * d.c;
    for n in 0..s.n {
        for h in 0..s.h {
            let src_off = (n * s.h + h) * row;
            let dst_off = (n * d.h + h + ph) * drow + pw * s.c;
            dst.data_mut()[dst_off..dst_off + row]
                .copy_from_slice(&src.data()[src_off..src_off + row]);
        }
    }
}

/// Max-pool `src` into `dst` over `k × k` windows with stride `s`.
fn max_pool_into(src: &Tensor, k: usize, s: usize, dst: &mut Tensor) {
    let sh = src.shape();
    let d = dst.shape();
    assert_eq!((d.h, d.w), ((sh.h - k) / s + 1, (sh.w - k) / s + 1));
    for n in 0..sh.n {
        for y in 0..d.h {
            for x0 in 0..d.w {
                for c in 0..sh.c {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..k {
                        for dx in 0..k {
                            m = m.max(src.at(n, y * s + dy, x0 * s + dx, c));
                        }
                    }
                    *dst.at_mut(n, y, x0, c) = m;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// The pass pipeline.
// ---------------------------------------------------------------------

/// Best-fit slot allocation for the liveness pass: the smallest free
/// slot that already fits `elems`; else grow the largest free slot;
/// else open a new one.
fn alloc_slot(
    elems: usize,
    slot_elems: &mut Vec<usize>,
    free: &mut Vec<usize>,
    slot_live: &mut Vec<usize>,
) -> usize {
    let fit = free
        .iter()
        .enumerate()
        .filter(|(_, s)| slot_elems[**s] >= elems)
        .min_by_key(|(_, s)| slot_elems[**s])
        .map(|(i, _)| i);
    let pick = fit.or_else(|| {
        free.iter()
            .enumerate()
            .max_by_key(|(_, s)| slot_elems[**s])
            .map(|(i, _)| i)
    });
    match pick {
        Some(i) => {
            let s = free.swap_remove(i);
            slot_elems[s] = slot_elems[s].max(elems);
            slot_live[s] += 1;
            s
        }
        None => {
            slot_elems.push(elems);
            slot_live.push(1);
            slot_elems.len() - 1
        }
    }
}

fn compile(graph: &Graph) -> ExecGraph {
    let shapes = graph.infer_shapes(1);
    let n_nodes = graph.node_count();

    // -- dead-node elimination: walk back from the output --------------
    let mut live = vec![false; n_nodes];
    let mut stack: Vec<NodeId> = Vec::new();
    if let Src::Node(v) = graph.output() {
        stack.push(v);
    }
    while let Some(v) = stack.pop() {
        if live[v] {
            continue;
        }
        live[v] = true;
        for s in &graph.node(v).srcs {
            if let Src::Node(u) = s {
                stack.push(*u);
            }
        }
    }

    // -- fusion: conv absorbed into its sole relu consumer -------------
    // consumers[v] = total consumptions of v among live nodes (+1 if v is
    // the graph output).
    let mut consumers = vec![0usize; n_nodes];
    for (i, node) in graph.nodes().iter().enumerate() {
        if !live[i] {
            continue;
        }
        for s in &node.srcs {
            if let Src::Node(u) = s {
                consumers[*u] += 1;
            }
        }
    }
    if let Src::Node(v) = graph.output() {
        consumers[v] += 1;
    }
    // fused_into[relu_id] = conv_id, absorbed_by[conv_id] = relu_id for
    // every absorbed relu (two directions of the same pairing).
    let mut fused_into: Vec<Option<NodeId>> = vec![None; n_nodes];
    let mut absorbed_by: Vec<Option<NodeId>> = vec![None; n_nodes];
    for (r, node) in graph.nodes().iter().enumerate() {
        if !live[r] || !matches!(node.op, Op::Layer(Layer::Relu)) {
            continue;
        }
        if let [Src::Node(c)] = node.srcs.as_slice() {
            let is_conv = matches!(graph.node(*c).op, Op::Layer(Layer::Conv { .. }));
            if is_conv && consumers[*c] == 1 {
                fused_into[r] = Some(*c);
                absorbed_by[*c] = Some(r);
            }
        }
    }

    // -- build the step list (node order is already topological) -------
    struct ProtoStep {
        node: NodeId,
        srcs: Vec<Src>,
        out_value: NodeId,
        fused_relu: bool,
        pad: Option<usize>, // per-sample padded elems
    }
    let mut protos: Vec<ProtoStep> = Vec::new();
    for (i, node) in graph.nodes().iter().enumerate() {
        if !live[i] || fused_into[i].is_some() {
            continue;
        }
        // A conv step may carry an absorbed relu.
        let absorbed = absorbed_by[i];
        let out_value = absorbed.unwrap_or(i);
        let pad = match &node.op {
            Op::Layer(Layer::Conv { ph, pw, .. }) if *ph > 0 || *pw > 0 => {
                let in_shape = match node.srcs[0] {
                    Src::Input => {
                        let (h, w, c) = graph.input_hwc;
                        Nhwc::new(1, h, w, c)
                    }
                    Src::Node(v) => shapes[v],
                };
                Some(Nhwc::new(1, in_shape.h + 2 * ph, in_shape.w + 2 * pw, in_shape.c).len())
            }
            _ => None,
        };
        protos.push(ProtoStep {
            node: i,
            srcs: node.srcs.clone(),
            out_value,
            fused_relu: absorbed.is_some(),
            pad,
        });
    }

    // -- liveness: remaining-use counts per value ----------------------
    let mut uses = vec![0usize; n_nodes];
    for p in &protos {
        for s in &p.srcs {
            if let Src::Node(v) = s {
                uses[*v] += 1;
            }
        }
    }
    let output = graph.output();
    let out_value_id = match output {
        Src::Node(v) => Some(v),
        Src::Input => None,
    };

    // -- slot assignment: best-fit interval coloring -------------------
    let mut slot_elems: Vec<usize> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    // How many live values currently share each slot (aliases share).
    let mut slot_live: Vec<usize> = Vec::new();
    let mut value_slot: Vec<Option<usize>> = vec![None; n_nodes];

    // Independent live-set accounting (values, alias groups counted
    // once) — the lower bound the packing is compared against.
    let mut live_elems = 0usize;
    let mut max_live = 0usize;
    // alias_root[v] = the value whose storage v shares (itself usually).
    let mut alias_root: Vec<NodeId> = (0..n_nodes).collect();
    let mut root_live: std::collections::HashMap<NodeId, usize> = std::collections::HashMap::new();

    let mut steps: Vec<Step> = Vec::new();
    let mut remaining = uses.clone();
    for p in &protos {
        let node = graph.node(p.node);
        let out_elems = shapes[p.out_value].len();
        // Pad buffer lives only during this step.
        let pad_slot = p.pad.map(|elems| {
            live_elems += elems;
            alloc_slot(elems, &mut slot_elems, &mut free, &mut slot_live)
        });
        // Alias / in-place decisions:
        //  * flatten over a node value is a pure reshape — share the slot
        //    (read-only, so sharing is always safe);
        //  * relu/softmax run in place only when their input dies here
        //    AND no other live value (e.g. a flatten alias) shares the
        //    slot — an in-place write would clobber that value.
        let alias_src = match (&node.op, p.srcs.as_slice()) {
            (Op::Layer(Layer::Flatten), [Src::Node(v)]) => Some(*v),
            (Op::Layer(Layer::Relu | Layer::Softmax), [Src::Node(v)])
                if remaining[*v] == 1
                    && Some(*v) != out_value_id
                    && slot_live[value_slot[*v].expect("live value has a slot")] == 1 =>
            {
                Some(*v)
            }
            _ => None,
        };
        let out_slot = match alias_src {
            Some(v) => {
                let s = value_slot[v].expect("alias source is live");
                slot_live[s] += 1;
                alias_root[p.out_value] = alias_root[v];
                s
            }
            None => {
                live_elems += out_elems;
                alloc_slot(out_elems, &mut slot_elems, &mut free, &mut slot_live)
            }
        };
        value_slot[p.out_value] = Some(out_slot);
        *root_live.entry(alias_root[p.out_value]).or_insert(0) += 1;
        max_live = max_live.max(live_elems);

        steps.push(Step {
            node: p.node,
            srcs: p.srcs.clone(),
            out_value: p.out_value,
            out_slot,
            pad_slot,
            fused_relu: p.fused_relu,
        });

        // Deaths after the step: consumed values whose uses hit zero
        // (the output value never dies), and the pad buffer.
        if let Some(ps) = pad_slot {
            live_elems -= p.pad.unwrap();
            slot_live[ps] -= 1;
            if slot_live[ps] == 0 {
                free.push(ps);
            }
        }
        for s in &p.srcs {
            if let Src::Node(v) = s {
                remaining[*v] -= 1;
                if remaining[*v] == 0 && Some(*v) != out_value_id {
                    let slot = value_slot[*v].expect("dying value had a slot");
                    slot_live[slot] -= 1;
                    if slot_live[slot] == 0 {
                        free.push(slot);
                    }
                    let root = alias_root[*v];
                    let rc = root_live.get_mut(&root).expect("root accounted");
                    *rc -= 1;
                    if *rc == 0 {
                        live_elems -= shapes[root].len();
                    }
                }
            }
        }
    }

    ExecGraph {
        steps,
        shapes,
        slot_elems,
        value_slot,
        max_live_elems: max_live,
        output,
    }
}
