//! CNN model graph: layers, forward executor, and the `.mecw` weight
//! format produced by the build-time JAX trainer
//! (`python/compile/trainer.py`).
//!
//! The executor is the library's deployment story: every convolution goes
//! through the [`planner`](crate::planner) under the device's memory
//! budget, workspaces are reused across layers and requests, and the same
//! graph can also be executed through the PJRT path
//! ([`runtime`](crate::runtime)) for cross-checking against the JAX
//! artifacts.

pub mod evalset;
pub mod graph;
pub mod layer;
pub mod loader;

pub use evalset::EvalSet;
pub use graph::{Model, PlanMemo, MAX_CACHED_GEOMETRIES_PER_LAYER};
pub use layer::Layer;
pub use loader::{load_mecw, save_mecw, LoadError};
