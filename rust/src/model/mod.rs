//! CNN model layer: the graph IR ([`graph_ir`]), the planned forward
//! executor ([`graph`]), and the `.mecw` weight format produced by the
//! build-time JAX trainer (`python/compile/trainer.py`).
//!
//! The executor is the library's deployment story: the graph compiles
//! once through a pass pipeline (shape inference, conv+bias+relu
//! fusion, dead-node elimination, activation liveness), every
//! convolution goes through the [`planner`](crate::planner) under the
//! device's memory budget, workspaces *and* activations are reused
//! across nodes and requests, and the same graph can also be executed
//! through the PJRT path ([`runtime`](crate::runtime)) for
//! cross-checking against the JAX artifacts.

// The model/graph layer builds on safe substrates only: no unsafe, ever
// (enforced — see the crate-level unsafe policy and tools/unsafe-audit).
#![forbid(unsafe_code)]

pub mod evalset;
pub mod graph;
pub mod graph_ir;
pub mod layer;
pub mod loader;

pub use evalset::EvalSet;
pub use graph::{Model, PlanMemo, MAX_CACHED_GEOMETRIES_PER_LAYER};
pub use graph_ir::{ExecGraph, Graph, GraphBuilder, Node, NodeId, Op, Src};
pub use layer::Layer;
pub use loader::{load_mecw, save_mecw, LoadError};
