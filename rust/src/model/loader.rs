//! `.mecw` — the weight interchange format between the build-time JAX
//! trainer and the rust executor. Hand-rolled little-endian binary (serde
//! is not in the offline registry), with a mirrored writer in
//! `python/compile/trainer.py`.
//!
//! Two wire versions share the reader:
//!
//! * **v1** (`MECW0001`) — the historical sequential format. Loading
//!   builds the same chain through [`Graph::sequential`], so v1 files
//!   keep working unchanged, and [`save_mecw`] still emits v1 bytes for
//!   purely sequential models (byte-identical round trips with old
//!   files).
//! * **v2** (`MECW0002`) — the graph format: nodes carry explicit input
//!   edges, so residual/branching topologies (`Add`, `Concat`)
//!   serialize. Saving picks v2 automatically whenever the graph is not
//!   a chain.
//!
//! ```text
//! v1: magic   8 B   "MECW0001"
//!     name    u32 len + utf-8 bytes
//!     input   u32 h, u32 w, u32 c
//!     layers  u32 count, then per layer:
//!       tag u32: 0=conv 1=relu 2=maxpool 3=flatten 4=dense 5=softmax
//!       conv:    u32 kh,kw,ic,kc,sh,sw,ph,pw; f32[kh·kw·ic·kc] weights
//!                (row-major khkwic×kc, exactly the GEMM layout); f32[kc] bias
//!       maxpool: u32 k, s
//!       dense:   u32 d_in, d_out; f32[d_in·d_out] (row-major); f32[d_out]
//!
//! v2: magic   8 B   "MECW0002"
//!     name, input as v1
//!     nodes   u32 count, then per node:
//!       tag u32: v1 tags, plus 6=add 7=concat
//!       srcs    u32 count, then u32 each (0xFFFF_FFFF = graph input,
//!               else node id — must be < this node's id)
//!       payload as v1 per tag (add/concat carry none)
//!     output  u32 (0xFFFF_FFFF = graph input, else node id)
//! ```

use crate::model::graph_ir::{Graph, GraphBuilder, Node, Op, Src};
use crate::model::layer::Layer;
use crate::model::Model;
use crate::tensor::{Kernel, KernelShape};
use std::io::{Read, Write};
use std::path::Path;

pub const MAGIC: &[u8; 8] = b"MECW0001";
pub const MAGIC_V2: &[u8; 8] = b"MECW0002";

/// Wire encoding of [`Src::Input`].
const SRC_INPUT: u32 = u32::MAX;

#[derive(Debug)]
pub enum LoadError {
    Io(std::io::Error),
    BadMagic,
    UnknownTag(u32),
    Malformed(String),
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::Io(e) => write!(f, "io error: {e}"),
            LoadError::BadMagic => write!(f, "bad magic (not a .mecw file)"),
            LoadError::UnknownTag(t) => write!(f, "unknown layer tag {t}"),
            LoadError::Malformed(m) => write!(f, "malformed file: {m}"),
        }
    }
}

impl std::error::Error for LoadError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LoadError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for LoadError {
    fn from(e: std::io::Error) -> LoadError {
        LoadError::Io(e)
    }
}

struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn u32(&mut self) -> Result<u32, LoadError> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    fn usize(&mut self) -> Result<usize, LoadError> {
        Ok(self.u32()? as usize)
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>, LoadError> {
        let mut bytes = vec![0u8; n * 4];
        self.r.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn string(&mut self) -> Result<String, LoadError> {
        let n = self.usize()?;
        if n > 1 << 20 {
            return Err(LoadError::Malformed(format!("string length {n}")));
        }
        let mut b = vec![0u8; n];
        self.r.read_exact(&mut b)?;
        String::from_utf8(b).map_err(|e| LoadError::Malformed(e.to_string()))
    }

    /// The per-tag payload shared by both wire versions.
    fn layer(&mut self, tag: u32) -> Result<Layer, LoadError> {
        Ok(match tag {
            0 => {
                let (kh, kw, ic, kc) = (self.usize()?, self.usize()?, self.usize()?, self.usize()?);
                let (sh, sw, ph, pw) = (self.usize()?, self.usize()?, self.usize()?, self.usize()?);
                let shape = KernelShape::new(kh, kw, ic, kc);
                let weights = self.f32_vec(shape.len())?;
                let bias = self.f32_vec(kc)?;
                Layer::Conv {
                    kernel: Kernel::from_vec(shape, weights),
                    bias,
                    sh,
                    sw,
                    ph,
                    pw,
                }
            }
            1 => Layer::Relu,
            2 => {
                let (k, s) = (self.usize()?, self.usize()?);
                Layer::MaxPool { k, s }
            }
            3 => Layer::Flatten,
            4 => {
                let (d_in, d_out) = (self.usize()?, self.usize()?);
                let w = self.f32_vec(d_in * d_out)?;
                let bias = self.f32_vec(d_out)?;
                Layer::Dense { w, bias, d_in, d_out }
            }
            5 => Layer::Softmax,
            t => return Err(LoadError::UnknownTag(t)),
        })
    }
}

fn decode_src(raw: u32, before: usize) -> Result<Src, LoadError> {
    if raw == SRC_INPUT {
        Ok(Src::Input)
    } else if (raw as usize) < before {
        Ok(Src::Node(raw as usize))
    } else {
        Err(LoadError::Malformed(format!(
            "source {raw} is not an earlier node (building node {before})"
        )))
    }
}

/// Load a model from a `.mecw` file (either wire version).
pub fn load_mecw(path: impl AsRef<Path>) -> Result<Model, LoadError> {
    let f = std::fs::File::open(path)?;
    let mut r = Reader {
        r: std::io::BufReader::new(f),
    };
    let mut magic = [0u8; 8];
    r.r.read_exact(&mut magic)?;
    let v2 = match &magic {
        m if m == MAGIC => false,
        m if m == MAGIC_V2 => true,
        _ => return Err(LoadError::BadMagic),
    };
    let name = r.string()?;
    let (h, w, c) = (r.usize()?, r.usize()?, r.usize()?);
    let n_nodes = r.usize()?;
    if n_nodes > 10_000 {
        return Err(LoadError::Malformed(format!("{n_nodes} nodes")));
    }
    let graph = if v2 {
        let mut b = GraphBuilder::new(&name, (h, w, c));
        for i in 0..n_nodes {
            let tag = r.u32()?;
            let n_srcs = r.usize()?;
            // Sources may repeat (add(&[x, x]) is legal), so bound by a
            // hard cap rather than the node count.
            if n_srcs > 10_000 {
                return Err(LoadError::Malformed(format!("{n_srcs} sources")));
            }
            let mut srcs = Vec::with_capacity(n_srcs);
            for _ in 0..n_srcs {
                srcs.push(decode_src(r.u32()?, i)?);
            }
            match tag {
                6 => {
                    if srcs.len() < 2 {
                        return Err(LoadError::Malformed("add with < 2 inputs".into()));
                    }
                    b.add(&srcs);
                }
                7 => {
                    if srcs.len() < 2 {
                        return Err(LoadError::Malformed("concat with < 2 inputs".into()));
                    }
                    b.concat(&srcs);
                }
                t => {
                    let layer = r.layer(t)?;
                    if srcs.len() != 1 {
                        return Err(LoadError::Malformed(format!(
                            "layer tag {t} with {} inputs",
                            srcs.len()
                        )));
                    }
                    b.layer(srcs[0], layer);
                }
            }
        }
        let output = decode_src(r.u32()?, n_nodes)?;
        // Shape inference over the decoded edges: a geometry-inconsistent
        // file is a typed error, never an abort.
        b.try_finish(output).map_err(LoadError::Malformed)?
    } else {
        let mut layers = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            let tag = r.u32()?;
            layers.push(r.layer(tag)?);
        }
        Graph::try_sequential(&name, (h, w, c), layers).map_err(LoadError::Malformed)?
    };
    Ok(Model::from_graph(graph))
}

/// Save a model to `.mecw`. Sequential chains keep emitting the v1 wire
/// format (byte-identical with historical files); branching graphs emit
/// v2 with explicit edges.
pub fn save_mecw(model: &Model, path: impl AsRef<Path>) -> Result<(), LoadError> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    let graph = model.graph();
    match graph.as_sequential_layers() {
        Some(layers) => write_v1(&mut w, &model.name, model.input_hwc, &layers)?,
        None => write_v2(&mut w, graph)?,
    }
    Ok(())
}

fn write_v1<W: Write>(
    w: &mut W,
    name: &str,
    (h, ww, c): (usize, usize, usize),
    layers: &[Layer],
) -> std::io::Result<()> {
    w.write_all(MAGIC)?;
    write_str(w, name)?;
    for v in [h, ww, c, layers.len()] {
        w.write_all(&(v as u32).to_le_bytes())?;
    }
    for layer in layers {
        write_layer(w, layer)?;
    }
    Ok(())
}

fn write_v2<W: Write>(w: &mut W, graph: &Graph) -> std::io::Result<()> {
    w.write_all(MAGIC_V2)?;
    write_str(w, &graph.name)?;
    let (h, ww, c) = graph.input_hwc;
    for v in [h, ww, c, graph.node_count()] {
        w.write_all(&(v as u32).to_le_bytes())?;
    }
    for Node { op, srcs } in graph.nodes() {
        let tag: u32 = match op {
            Op::Layer(l) => layer_tag(l),
            Op::Add => 6,
            Op::Concat => 7,
        };
        w.write_all(&tag.to_le_bytes())?;
        w.write_all(&(srcs.len() as u32).to_le_bytes())?;
        for s in srcs {
            w.write_all(&encode_src(*s).to_le_bytes())?;
        }
        match op {
            Op::Layer(l) => write_layer_payload(w, l)?,
            Op::Add | Op::Concat => {}
        }
    }
    w.write_all(&encode_src(graph.output()).to_le_bytes())?;
    Ok(())
}

fn encode_src(s: Src) -> u32 {
    match s {
        Src::Input => SRC_INPUT,
        Src::Node(v) => v as u32,
    }
}

/// The one wire-tag table (shared by the v1 and v2 writers; the reader
/// mirrors it in `Reader::layer`).
fn layer_tag(layer: &Layer) -> u32 {
    match layer {
        Layer::Conv { .. } => 0,
        Layer::Relu => 1,
        Layer::MaxPool { .. } => 2,
        Layer::Flatten => 3,
        Layer::Dense { .. } => 4,
        Layer::Softmax => 5,
    }
}

/// v1 layer record: tag + payload.
fn write_layer<W: Write>(w: &mut W, layer: &Layer) -> std::io::Result<()> {
    w.write_all(&layer_tag(layer).to_le_bytes())?;
    write_layer_payload(w, layer)
}

/// The tag-specific payload shared by v1 and v2 records.
fn write_layer_payload<W: Write>(w: &mut W, layer: &Layer) -> std::io::Result<()> {
    match layer {
        Layer::Conv {
            kernel, bias, sh, sw, ph, pw,
        } => {
            let ks = kernel.shape();
            for v in [ks.kh, ks.kw, ks.ic, ks.kc, *sh, *sw, *ph, *pw] {
                w.write_all(&(v as u32).to_le_bytes())?;
            }
            write_f32s(w, kernel.data())?;
            write_f32s(w, bias)?;
        }
        Layer::MaxPool { k, s } => {
            w.write_all(&(*k as u32).to_le_bytes())?;
            w.write_all(&(*s as u32).to_le_bytes())?;
        }
        Layer::Dense { w: dw, bias, d_in, d_out } => {
            w.write_all(&(*d_in as u32).to_le_bytes())?;
            w.write_all(&(*d_out as u32).to_le_bytes())?;
            write_f32s(w, dw)?;
            write_f32s(w, bias)?;
        }
        Layer::Relu | Layer::Flatten | Layer::Softmax => {}
    }
    Ok(())
}

fn write_str<W: Write>(w: &mut W, s: &str) -> std::io::Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> std::io::Result<()> {
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_model() -> Model {
        let mut rng = Rng::new(5);
        Model::new(
            "roundtrip",
            (6, 6, 2),
            vec![
                Layer::Conv {
                    kernel: Kernel::random(KernelShape::new(3, 3, 2, 4), &mut rng),
                    bias: vec![0.5, -0.5, 0.25, 0.0],
                    sh: 1,
                    sw: 1,
                    ph: 1,
                    pw: 1,
                },
                Layer::Relu,
                Layer::MaxPool { k: 2, s: 2 },
                Layer::Flatten,
                Layer::Dense {
                    w: (0..36 * 3).map(|i| i as f32 * 0.01).collect(),
                    bias: vec![1.0, 2.0, 3.0],
                    d_in: 36,
                    d_out: 3,
                },
                Layer::Softmax,
            ],
        )
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let m = sample_model();
        let dir = std::env::temp_dir().join("mecw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.mecw");
        save_mecw(&m, &path).unwrap();
        let loaded = load_mecw(&path).unwrap();
        assert_eq!(loaded.name, "roundtrip");
        assert_eq!(loaded.input_hwc, (6, 6, 2));
        assert_eq!(loaded.graph(), m.graph());
    }

    #[test]
    fn sequential_models_still_write_v1_bytes() {
        let m = sample_model();
        let dir = std::env::temp_dir().join("mecw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.mecw");
        save_mecw(&m, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], MAGIC, "sequential graphs keep the v1 magic");
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("mecw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.mecw");
        std::fs::write(&path, b"NOTMECW!xxxx").unwrap();
        assert!(matches!(load_mecw(&path), Err(LoadError::BadMagic)));
    }

    #[test]
    fn truncated_file_errors_not_panics() {
        let m = sample_model();
        let dir = std::env::temp_dir().join("mecw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.mecw");
        save_mecw(&m, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let cut = dir.join("cut.mecw");
        std::fs::write(&cut, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load_mecw(&cut).is_err());
    }
}
