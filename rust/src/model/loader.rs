//! `.mecw` — the weight interchange format between the build-time JAX
//! trainer and the rust executor. Hand-rolled little-endian binary (serde
//! is not in the offline registry), with a mirrored writer in
//! `python/compile/trainer.py`.
//!
//! ```text
//! magic   8 B   "MECW0001"
//! name    u32 len + utf-8 bytes
//! input   u32 h, u32 w, u32 c
//! layers  u32 count, then per layer:
//!   tag u32: 0=conv 1=relu 2=maxpool 3=flatten 4=dense 5=softmax
//!   conv:    u32 kh,kw,ic,kc,sh,sw,ph,pw; f32[kh·kw·ic·kc] weights
//!            (row-major khkwic×kc, exactly the GEMM layout); f32[kc] bias
//!   maxpool: u32 k, s
//!   dense:   u32 d_in, d_out; f32[d_in·d_out] (row-major); f32[d_out]
//! ```

use crate::model::layer::Layer;
use crate::model::Model;
use crate::tensor::{Kernel, KernelShape};
use std::io::{Read, Write};
use std::path::Path;

pub const MAGIC: &[u8; 8] = b"MECW0001";

#[derive(Debug)]
pub enum LoadError {
    Io(std::io::Error),
    BadMagic,
    UnknownTag(u32),
    Malformed(String),
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::Io(e) => write!(f, "io error: {e}"),
            LoadError::BadMagic => write!(f, "bad magic (not a .mecw file)"),
            LoadError::UnknownTag(t) => write!(f, "unknown layer tag {t}"),
            LoadError::Malformed(m) => write!(f, "malformed file: {m}"),
        }
    }
}

impl std::error::Error for LoadError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LoadError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for LoadError {
    fn from(e: std::io::Error) -> LoadError {
        LoadError::Io(e)
    }
}

struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn u32(&mut self) -> Result<u32, LoadError> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    fn usize(&mut self) -> Result<usize, LoadError> {
        Ok(self.u32()? as usize)
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>, LoadError> {
        let mut bytes = vec![0u8; n * 4];
        self.r.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn string(&mut self) -> Result<String, LoadError> {
        let n = self.usize()?;
        if n > 1 << 20 {
            return Err(LoadError::Malformed(format!("string length {n}")));
        }
        let mut b = vec![0u8; n];
        self.r.read_exact(&mut b)?;
        String::from_utf8(b).map_err(|e| LoadError::Malformed(e.to_string()))
    }
}

/// Load a model from a `.mecw` file.
pub fn load_mecw(path: impl AsRef<Path>) -> Result<Model, LoadError> {
    let f = std::fs::File::open(path)?;
    let mut r = Reader {
        r: std::io::BufReader::new(f),
    };
    let mut magic = [0u8; 8];
    r.r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(LoadError::BadMagic);
    }
    let name = r.string()?;
    let (h, w, c) = (r.usize()?, r.usize()?, r.usize()?);
    let n_layers = r.usize()?;
    if n_layers > 10_000 {
        return Err(LoadError::Malformed(format!("{n_layers} layers")));
    }
    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let tag = r.u32()?;
        layers.push(match tag {
            0 => {
                let (kh, kw, ic, kc) = (r.usize()?, r.usize()?, r.usize()?, r.usize()?);
                let (sh, sw, ph, pw) = (r.usize()?, r.usize()?, r.usize()?, r.usize()?);
                let shape = KernelShape::new(kh, kw, ic, kc);
                let weights = r.f32_vec(shape.len())?;
                let bias = r.f32_vec(kc)?;
                Layer::Conv {
                    kernel: Kernel::from_vec(shape, weights),
                    bias,
                    sh,
                    sw,
                    ph,
                    pw,
                }
            }
            1 => Layer::Relu,
            2 => {
                let (k, s) = (r.usize()?, r.usize()?);
                Layer::MaxPool { k, s }
            }
            3 => Layer::Flatten,
            4 => {
                let (d_in, d_out) = (r.usize()?, r.usize()?);
                let w = r.f32_vec(d_in * d_out)?;
                let bias = r.f32_vec(d_out)?;
                Layer::Dense { w, bias, d_in, d_out }
            }
            5 => Layer::Softmax,
            t => return Err(LoadError::UnknownTag(t)),
        });
    }
    let model = Model::new(&name, (h, w, c), layers);
    model.validate(); // panics on inconsistent chaining — fail fast at load
    Ok(model)
}

/// Save a model to `.mecw` (round-trip testing; the production writer is
/// the python trainer).
pub fn save_mecw(model: &Model, path: impl AsRef<Path>) -> Result<(), LoadError> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_str(&mut w, &model.name)?;
    let (h, ww, c) = model.input_hwc;
    for v in [h, ww, c, model.layers.len()] {
        w.write_all(&(v as u32).to_le_bytes())?;
    }
    for layer in &model.layers {
        match layer {
            Layer::Conv {
                kernel, bias, sh, sw, ph, pw,
            } => {
                w.write_all(&0u32.to_le_bytes())?;
                let ks = kernel.shape();
                for v in [ks.kh, ks.kw, ks.ic, ks.kc, *sh, *sw, *ph, *pw] {
                    w.write_all(&(v as u32).to_le_bytes())?;
                }
                write_f32s(&mut w, kernel.data())?;
                write_f32s(&mut w, bias)?;
            }
            Layer::Relu => w.write_all(&1u32.to_le_bytes())?,
            Layer::MaxPool { k, s } => {
                w.write_all(&2u32.to_le_bytes())?;
                w.write_all(&(*k as u32).to_le_bytes())?;
                w.write_all(&(*s as u32).to_le_bytes())?;
            }
            Layer::Flatten => w.write_all(&3u32.to_le_bytes())?,
            Layer::Dense { w: dw, bias, d_in, d_out } => {
                w.write_all(&4u32.to_le_bytes())?;
                w.write_all(&(*d_in as u32).to_le_bytes())?;
                w.write_all(&(*d_out as u32).to_le_bytes())?;
                write_f32s(&mut w, dw)?;
                write_f32s(&mut w, bias)?;
            }
            Layer::Softmax => w.write_all(&5u32.to_le_bytes())?,
        }
    }
    Ok(())
}

fn write_str<W: Write>(w: &mut W, s: &str) -> std::io::Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> std::io::Result<()> {
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_model() -> Model {
        let mut rng = Rng::new(5);
        Model::new(
            "roundtrip",
            (6, 6, 2),
            vec![
                Layer::Conv {
                    kernel: Kernel::random(KernelShape::new(3, 3, 2, 4), &mut rng),
                    bias: vec![0.5, -0.5, 0.25, 0.0],
                    sh: 1,
                    sw: 1,
                    ph: 1,
                    pw: 1,
                },
                Layer::Relu,
                Layer::MaxPool { k: 2, s: 2 },
                Layer::Flatten,
                Layer::Dense {
                    w: (0..36 * 3).map(|i| i as f32 * 0.01).collect(),
                    bias: vec![1.0, 2.0, 3.0],
                    d_in: 36,
                    d_out: 3,
                },
                Layer::Softmax,
            ],
        )
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let m = sample_model();
        let dir = std::env::temp_dir().join("mecw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.mecw");
        save_mecw(&m, &path).unwrap();
        let loaded = load_mecw(&path).unwrap();
        assert_eq!(loaded.name, "roundtrip");
        assert_eq!(loaded.input_hwc, (6, 6, 2));
        assert_eq!(loaded.layers, m.layers);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("mecw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.mecw");
        std::fs::write(&path, b"NOTMECW!xxxx").unwrap();
        assert!(matches!(load_mecw(&path), Err(LoadError::BadMagic)));
    }

    #[test]
    fn truncated_file_errors_not_panics() {
        let m = sample_model();
        let dir = std::env::temp_dir().join("mecw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.mecw");
        save_mecw(&m, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let cut = dir.join("cut.mecw");
        std::fs::write(&cut, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load_mecw(&cut).is_err());
    }
}
