//! Layer definitions for the CNN executor.

use crate::tensor::{Kernel, KernelShape, Nhwc};

/// One layer of the network. Weights are owned (loaded from `.mecw`).
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    /// 2-D convolution with symmetric zero padding `(ph, pw)` applied
    /// before the conv (the paper assumes pre-applied padding, §2.1) and
    /// a per-output-channel bias.
    Conv {
        kernel: Kernel,
        bias: Vec<f32>,
        sh: usize,
        sw: usize,
        ph: usize,
        pw: usize,
    },
    /// Elementwise max(0, x).
    Relu,
    /// Max pooling over `k × k` windows with stride `s`.
    MaxPool { k: usize, s: usize },
    /// Flatten NHWC -> (N, H·W·C).
    Flatten,
    /// Fully connected: y = x·W + b, W is (in × out) row-major.
    Dense {
        w: Vec<f32>,
        bias: Vec<f32>,
        d_in: usize,
        d_out: usize,
    },
    /// Row-wise softmax (numerically stable).
    Softmax,
}

impl Layer {
    /// Short tag for display/metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            Layer::Conv { .. } => "conv",
            Layer::Relu => "relu",
            Layer::MaxPool { .. } => "maxpool",
            Layer::Flatten => "flatten",
            Layer::Dense { .. } => "dense",
            Layer::Softmax => "softmax",
        }
    }

    /// Output shape for a given input shape. Panics on geometry mismatch
    /// (the in-memory construction path; the model loader goes through
    /// [`Layer::try_output_shape`] so a corrupt file errors instead).
    pub fn output_shape(&self, input: Nhwc) -> Nhwc {
        self.try_output_shape(input).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`Layer::output_shape`] with geometry mismatches reported as
    /// `Err` instead of a panic — what file loading validates with.
    pub fn try_output_shape(&self, input: Nhwc) -> Result<Nhwc, String> {
        Ok(match self {
            Layer::Conv {
                kernel, sh, sw, ph, pw, ..
            } => {
                let ks: KernelShape = kernel.shape();
                if input.c != ks.ic {
                    return Err(format!("conv expects {} channels, got {}", ks.ic, input.c));
                }
                let h = input.h + 2 * ph;
                let w = input.w + 2 * pw;
                if h < ks.kh || w < ks.kw || *sh == 0 || *sw == 0 {
                    return Err(format!(
                        "conv kernel {}x{} stride {}x{} does not fit a {h}x{w} input",
                        ks.kh, ks.kw, sh, sw
                    ));
                }
                Nhwc::new(
                    input.n,
                    (h - ks.kh) / sh + 1,
                    (w - ks.kw) / sw + 1,
                    ks.kc,
                )
            }
            Layer::Relu | Layer::Softmax => input,
            Layer::MaxPool { k, s } => {
                if input.h < *k || input.w < *k || *k == 0 || *s == 0 {
                    return Err(format!(
                        "maxpool {k}x{k}/{s} does not fit a {}x{} input",
                        input.h, input.w
                    ));
                }
                Nhwc::new(
                    input.n,
                    (input.h - k) / s + 1,
                    (input.w - k) / s + 1,
                    input.c,
                )
            }
            Layer::Flatten => Nhwc::new(input.n, 1, 1, input.h * input.w * input.c),
            Layer::Dense { d_in, d_out, .. } => {
                if input.h * input.w * input.c != *d_in {
                    return Err(format!("dense expects {d_in} features"));
                }
                Nhwc::new(input.n, 1, 1, *d_out)
            }
        })
    }

    /// Parameter count (weights + biases).
    pub fn param_count(&self) -> usize {
        match self {
            Layer::Conv { kernel, bias, .. } => kernel.shape().len() + bias.len(),
            Layer::Dense { w, bias, .. } => w.len() + bias.len(),
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn conv_output_shape_with_padding() {
        let mut rng = Rng::new(1);
        let l = Layer::Conv {
            kernel: Kernel::random(KernelShape::new(3, 3, 2, 8), &mut rng),
            bias: vec![0.0; 8],
            sh: 1,
            sw: 1,
            ph: 1,
            pw: 1,
        };
        // SAME padding: 16x16 stays 16x16.
        assert_eq!(
            l.output_shape(Nhwc::new(4, 16, 16, 2)),
            Nhwc::new(4, 16, 16, 8)
        );
        assert_eq!(l.param_count(), 3 * 3 * 2 * 8 + 8);
    }

    #[test]
    fn pool_flatten_dense_shapes() {
        let pool = Layer::MaxPool { k: 2, s: 2 };
        assert_eq!(
            pool.output_shape(Nhwc::new(1, 8, 8, 4)),
            Nhwc::new(1, 4, 4, 4)
        );
        let flat = Layer::Flatten;
        assert_eq!(
            flat.output_shape(Nhwc::new(2, 4, 4, 4)),
            Nhwc::new(2, 1, 1, 64)
        );
        let dense = Layer::Dense {
            w: vec![0.0; 64 * 10],
            bias: vec![0.0; 10],
            d_in: 64,
            d_out: 10,
        };
        assert_eq!(
            dense.output_shape(Nhwc::new(2, 1, 1, 64)),
            Nhwc::new(2, 1, 1, 10)
        );
    }

    #[test]
    #[should_panic(expected = "dense expects")]
    fn dense_shape_mismatch_panics() {
        let dense = Layer::Dense {
            w: vec![0.0; 10],
            bias: vec![0.0; 10],
            d_in: 1,
            d_out: 10,
        };
        let _ = dense.output_shape(Nhwc::new(1, 2, 2, 2));
    }
}
