//! Reader for `artifacts/eval.bin` — the held-out eval set the python
//! trainer exports for the end-to-end serving example.
//!
//! Format (little-endian): `u32 n, h, w, c`, then per sample
//! `f32[h·w·c]` pixels + `u32` label.

use std::io::Read;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct EvalSet {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Row-major per-sample pixels, `n × (h·w·c)`.
    pub samples: Vec<Vec<f32>>,
    pub labels: Vec<usize>,
}

impl EvalSet {
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn load(path: impl AsRef<Path>) -> std::io::Result<EvalSet> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut hdr = [0u8; 16];
        f.read_exact(&mut hdr)?;
        let rd = |i: usize| u32::from_le_bytes(hdr[i * 4..i * 4 + 4].try_into().unwrap()) as usize;
        let (n, h, w, c) = (rd(0), rd(1), rd(2), rd(3));
        let per = h * w * c;
        let mut samples = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        let mut px = vec![0u8; per * 4];
        let mut lb = [0u8; 4];
        for _ in 0..n {
            f.read_exact(&mut px)?;
            samples.push(
                px.chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect(),
            );
            f.read_exact(&mut lb)?;
            labels.push(u32::from_le_bytes(lb) as usize);
        }
        Ok(EvalSet {
            h,
            w,
            c,
            samples,
            labels,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn reads_synthetic_file() {
        let dir = std::env::temp_dir().join("mec_evalset");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.bin");
        let mut f = std::fs::File::create(&path).unwrap();
        for v in [2u32, 1, 2, 1] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        for (pix, label) in [([1.0f32, 2.0], 0u32), ([3.0, 4.0], 2)] {
            for p in pix {
                f.write_all(&p.to_le_bytes()).unwrap();
            }
            f.write_all(&label.to_le_bytes()).unwrap();
        }
        drop(f);
        let es = EvalSet::load(&path).unwrap();
        assert_eq!(es.len(), 2);
        assert_eq!((es.h, es.w, es.c), (1, 2, 1));
        assert_eq!(es.samples[0], vec![1.0, 2.0]);
        assert_eq!(es.labels, vec![0, 2]);
    }
}
