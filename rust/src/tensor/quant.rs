//! 16-bit fixed-point quantization — the paper's second precision grid.
//!
//! §4 of the paper evaluates every algorithm "in both 32-bit floating
//! point and 16-bit fixed point", noting that MEC's compact lowering
//! compounds with lower precision: the memory sub-system moves half the
//! bytes through the same L. This module is the dtype layer that makes
//! that grid expressible end to end:
//!
//! * [`Precision`] — the execution dtype carried by
//!   [`ConvContext`](crate::conv::ConvContext) and the planner.
//! * [`QParams`] — symmetric per-tensor scale with round-to-nearest
//!   quantize/dequantize (`q = round(x / scale)`, `x ≈ q · scale`,
//!   `|q| ≤ 32767`).
//! * [`f32_as_i16_mut`] / [`i16_slots`] — how q16 plans carve i16 storage
//!   out of the shared f32 [`Arena`](crate::memory::Arena): two i16 lanes
//!   per f32 slot, so the lowering buffers genuinely halve.
//!
//! Activations are quantized dynamically (per-execute abs-max); kernels
//! are quantized once at plan time (see `ARCHITECTURE.md` §Precision).

use std::fmt;

/// Execution precision for the GEMM-lowering convolution family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    /// 32-bit float — the paper's default grid and the reference path.
    #[default]
    F32,
    /// 16-bit fixed point: i16 storage, i32 accumulation (Q15 product
    /// shifts), symmetric per-tensor scales.
    Q16,
}

impl Precision {
    /// Storage bytes per element of the lowered/packed operands.
    pub fn bytes_per_elem(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::Q16 => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Q16 => "q16",
        }
    }

    /// Case-insensitive name lookup (CLI `--precision`, env
    /// `MEC_BENCH_PRECISION`).
    pub fn parse(s: &str) -> Option<Precision> {
        Some(match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float" | "float32" => Precision::F32,
            "q16" | "i16" | "int16" | "fixed16" => Precision::Q16,
            _ => return None,
        })
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Symmetric per-tensor quantization parameters: `x ≈ q · scale` with
/// `q ∈ [-32767, 32767]` (the value -32768 is never produced, keeping the
/// grid symmetric so negation is exact).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f32,
}

impl QParams {
    /// Largest representable magnitude in quantized units.
    pub const QMAX: i32 = 32767;

    /// Scale such that `abs_max` maps to `QMAX`. Zero / non-finite
    /// abs-max falls back to scale 1 (everything quantizes to 0 anyway).
    pub fn from_abs_max(abs_max: f32) -> QParams {
        let m = if abs_max.is_finite() && abs_max > 0.0 {
            abs_max
        } else {
            1.0
        };
        QParams {
            scale: m / Self::QMAX as f32,
        }
    }

    /// Per-tensor scale from a buffer's absolute maximum.
    pub fn from_slice(data: &[f32]) -> QParams {
        Self::from_abs_max(data.iter().fold(0.0f32, |m, &v| m.max(v.abs())))
    }

    /// Round-to-nearest quantization, clamped to the symmetric range.
    #[inline(always)]
    pub fn quantize(&self, v: f32) -> i16 {
        let q = (v / self.scale).round();
        q.clamp(-(Self::QMAX as f32), Self::QMAX as f32) as i16
    }

    #[inline(always)]
    pub fn dequantize(&self, q: i16) -> f32 {
        q as f32 * self.scale
    }

    /// Quantize `src` into `dst` (equal lengths).
    pub fn quantize_slice(&self, src: &[f32], dst: &mut [i16]) {
        assert_eq!(src.len(), dst.len());
        for (d, &v) in dst.iter_mut().zip(src) {
            *d = self.quantize(v);
        }
    }
}

/// f32 arena slots needed to store `elems` i16 values (two lanes per
/// slot, rounded up) — what
/// [`WorkspaceLayout::push_i16`](crate::memory::WorkspaceLayout::push_i16)
/// reserves.
pub fn i16_slots(elems: usize) -> usize {
    elems.div_ceil(2)
}

/// Reinterpret an f32 scratch region as i16 storage (`2 · len` values).
pub fn f32_as_i16_mut(buf: &mut [f32]) -> &mut [i16] {
    // SAFETY: `f32` is 4-byte aligned ≥ `i16`'s 2, both are plain-old-data
    // with no invalid bit patterns, the new length 2·len covers exactly the
    // same bytes, and the borrow of `buf` pins the region for the returned
    // lifetime. The q16 consumers fully overwrite before reading (the same
    // contract the f32 lowering buffers already rely on).
    unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut i16, buf.len() * 2) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_names_roundtrip() {
        for p in [Precision::F32, Precision::Q16] {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::parse("Q16"), Some(Precision::Q16));
        assert_eq!(Precision::parse(" FP32 "), Some(Precision::F32));
        assert_eq!(Precision::parse("bf16"), None);
        assert_eq!(Precision::default(), Precision::F32);
        assert_eq!(Precision::F32.bytes_per_elem(), 4);
        assert_eq!(Precision::Q16.bytes_per_elem(), 2);
        assert_eq!(format!("{}", Precision::Q16), "q16");
    }

    #[test]
    fn quantize_roundtrip_within_half_scale() {
        let qp = QParams::from_abs_max(1.0);
        for v in [-1.0f32, -0.73, -1.0 / 3.0, 0.0, 1e-4, 0.5, 0.9999, 1.0] {
            let q = qp.quantize(v);
            let back = qp.dequantize(q);
            assert!(
                (back - v).abs() <= qp.scale * 0.5 + f32::EPSILON,
                "v={v} back={back} scale={}",
                qp.scale
            );
        }
        // Extremes hit the symmetric grid ends exactly.
        assert_eq!(qp.quantize(1.0), 32767);
        assert_eq!(qp.quantize(-1.0), -32767);
    }

    #[test]
    fn out_of_range_values_clamp() {
        let qp = QParams::from_abs_max(1.0);
        assert_eq!(qp.quantize(5.0), 32767);
        assert_eq!(qp.quantize(-5.0), -32767);
    }

    #[test]
    fn degenerate_scales_fall_back() {
        let qp = QParams::from_slice(&[0.0, 0.0]);
        assert_eq!(qp.scale, 1.0 / 32767.0);
        assert_eq!(qp.quantize(0.0), 0);
        let qp = QParams::from_abs_max(f32::NAN);
        assert!(qp.scale.is_finite() && qp.scale > 0.0);
    }

    #[test]
    fn from_slice_uses_abs_max() {
        let qp = QParams::from_slice(&[0.25, -2.0, 1.0]);
        assert_eq!(qp.scale, 2.0 / 32767.0);
        let mut q = [0i16; 3];
        qp.quantize_slice(&[0.25, -2.0, 1.0], &mut q);
        assert_eq!(q[1], -32767);
    }

    #[test]
    fn i16_slots_round_up() {
        assert_eq!(i16_slots(0), 0);
        assert_eq!(i16_slots(1), 1);
        assert_eq!(i16_slots(2), 1);
        assert_eq!(i16_slots(7), 4);
        assert_eq!(i16_slots(8), 4);
    }

    #[test]
    fn f32_buffer_reinterprets_as_i16() {
        let mut buf = vec![0.0f32; 3];
        {
            let lanes = f32_as_i16_mut(&mut buf);
            assert_eq!(lanes.len(), 6);
            for (i, v) in lanes.iter_mut().enumerate() {
                *v = i as i16 - 2;
            }
        }
        // Re-borrow sees the same storage.
        assert_eq!(f32_as_i16_mut(&mut buf)[3], 1);
    }
}
