//! Shapes for the NHWC tensors used throughout the engine, plus the
//! convolution-geometry arithmetic from the paper (Table 1 / Eq. 1).

use std::fmt;

/// 4-D NHWC shape: `n × h × w × c`, row-major (C convention, paper §2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Nhwc {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Nhwc {
    pub fn new(n: usize, h: usize, w: usize, c: usize) -> Nhwc {
        Nhwc { n, h, w, c }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.n * self.h * self.w * self.c
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Row-major linear index of `[n, h, w, c]`.
    #[inline(always)]
    pub fn index(&self, n: usize, h: usize, w: usize, c: usize) -> usize {
        debug_assert!(n < self.n && h < self.h && w < self.w && c < self.c);
        ((n * self.h + h) * self.w + w) * self.c + c
    }
}

impl fmt::Display for Nhwc {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}×{}×{}×{}", self.n, self.h, self.w, self.c)
    }
}

/// Kernel tensor shape `k_h × k_w × i_c × k_c` (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KernelShape {
    pub kh: usize,
    pub kw: usize,
    pub ic: usize,
    pub kc: usize,
}

impl KernelShape {
    pub fn new(kh: usize, kw: usize, ic: usize, kc: usize) -> KernelShape {
        KernelShape { kh, kw, ic, kc }
    }

    pub fn len(&self) -> usize {
        self.kh * self.kw * self.ic * self.kc
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Row-major linear index of `[kh, kw, ic, kc]`.
    #[inline(always)]
    pub fn index(&self, h: usize, w: usize, i: usize, o: usize) -> usize {
        debug_assert!(h < self.kh && w < self.kw && i < self.ic && o < self.kc);
        ((h * self.kw + w) * self.ic + i) * self.kc + o
    }
}

impl fmt::Display for KernelShape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}×{}×{}×{}", self.kh, self.kw, self.ic, self.kc)
    }
}

/// The full geometry of one convolution problem (paper §2.1): input,
/// kernel, strides. Padding is assumed pre-applied to the input, exactly
/// as the paper states ("any padding with zeroes is assumed to have been
/// already applied").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ConvShape {
    pub input: Nhwc,
    pub kernel: KernelShape,
    pub sh: usize,
    pub sw: usize,
}

impl ConvShape {
    pub fn new(input: Nhwc, kernel: KernelShape, sh: usize, sw: usize) -> ConvShape {
        assert_eq!(input.c, kernel.ic, "input channels {} != kernel ic {}", input.c, kernel.ic);
        assert!(sh >= 1 && sw >= 1, "strides must be >= 1");
        assert!(
            input.h >= kernel.kh && input.w >= kernel.kw,
            "kernel {}x{} larger than input {}x{}",
            kernel.kh,
            kernel.kw,
            input.h,
            input.w
        );
        ConvShape { input, kernel, sh, sw }
    }

    /// Output height `o_h = (i_h - k_h)/s_h + 1` (Eq. 1).
    pub fn oh(&self) -> usize {
        (self.input.h - self.kernel.kh) / self.sh + 1
    }

    /// Output width `o_w = (i_w - k_w)/s_w + 1` (Eq. 1).
    pub fn ow(&self) -> usize {
        (self.input.w - self.kernel.kw) / self.sw + 1
    }

    /// Output tensor shape `i_n × o_h × o_w × k_c`.
    pub fn output(&self) -> Nhwc {
        Nhwc::new(self.input.n, self.oh(), self.ow(), self.kernel.kc)
    }

    /// Multiply-accumulate count of the convolution (same for every exact
    /// algorithm in the direct/im2col/MEC family, paper §3.2).
    pub fn macs(&self) -> usize {
        self.output().len() * self.kernel.kh * self.kernel.kw * self.kernel.ic
    }

    /// FLOPs = 2 × MACs.
    pub fn flops(&self) -> usize {
        2 * self.macs()
    }

    /// im2col lowered-matrix element count: `i_n·o_h·o_w × k_h·k_w·i_c` (Eq. 2).
    pub fn im2col_lowered_elems(&self) -> usize {
        self.input.n * self.oh() * self.ow() * self.kernel.kh * self.kernel.kw * self.kernel.ic
    }

    /// MEC lowered-matrix element count: `i_n·o_w·i_h·k_w·i_c` (Eq. 3).
    pub fn mec_lowered_elems(&self) -> usize {
        self.input.n * self.ow() * self.input.h * self.kernel.kw * self.kernel.ic
    }

    /// Eq. (4): element-count difference R between im2col and MEC lowered
    /// matrices — positive iff `k_h > s_h` (and `i_h > k_h`).
    pub fn eq4_difference(&self) -> i128 {
        self.im2col_lowered_elems() as i128 - self.mec_lowered_elems() as i128
    }

    /// Whether the MEC lowering is strictly smaller (paper §3.4: requires
    /// kernel overlap, `k_h > s_h`).
    pub fn mec_wins_memory(&self) -> bool {
        self.eq4_difference() > 0
    }

    /// A human-readable one-liner like the paper's Table 2 rows.
    pub fn describe(&self) -> String {
        format!(
            "in={}x{}x{} k={}x{}x{} s={}({}) out={}x{}x{}",
            self.input.h,
            self.input.w,
            self.input.c,
            self.kernel.kh,
            self.kernel.kw,
            self.kernel.kc,
            self.sh,
            self.sw,
            self.oh(),
            self.ow(),
            self.kernel.kc
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cv_like() -> ConvShape {
        // Paper Fig. 1 geometry: 7x7 input, 3x3 kernel, stride 1.
        ConvShape::new(Nhwc::new(1, 7, 7, 1), KernelShape::new(3, 3, 1, 1), 1, 1)
    }

    #[test]
    fn eq1_output_dims() {
        let s = cv_like();
        assert_eq!(s.oh(), 5);
        assert_eq!(s.ow(), 5);
        assert_eq!(s.output(), Nhwc::new(1, 5, 5, 1));
    }

    #[test]
    fn fig1_lowered_sizes() {
        // Paper §3.2: im2col L is 25x9 = 225; MEC L is 5x21 = 105 (54% smaller).
        let s = cv_like();
        assert_eq!(s.im2col_lowered_elems(), 225);
        assert_eq!(s.mec_lowered_elems(), 105);
        assert!(s.mec_wins_memory());
    }

    #[test]
    fn eq4_closed_form_matches() {
        // R = i_n·k_c·o_w·k_w·(i_h - k_h)(k_h/s_h - 1) — check against the
        // direct difference on a handful of geometries.
        for (ih, iw, ic, kh, kw, kc, s) in [
            (7usize, 7, 1, 3, 3, 1, 1),
            (227, 227, 3, 11, 11, 96, 4),
            (24, 24, 96, 5, 5, 256, 1),
            (14, 14, 256, 3, 3, 256, 1),
        ] {
            let cs = ConvShape::new(
                Nhwc::new(2, ih, iw, ic),
                KernelShape::new(kh, kw, ic, kc),
                s,
                s,
            );
            // Closed form (per output channel count NOT included: L has k_c
            // only through the kernel matrix, not the lowered input; the
            // paper's Eq. 4 carries k_c because it compares total temp
            // including per-channel copies; element counts here exclude k_c
            // consistently on both sides).
            let r_direct = cs.eq4_difference();
            let oh = cs.oh() as i128;
            let ow = cs.ow() as i128;
            let closed = 2 * ow * (oh * kh as i128 - ih as i128) * kw as i128 * ic as i128;
            assert_eq!(r_direct, closed, "geometry {ih}x{iw} k{kh} s{s}");
        }
    }

    #[test]
    fn no_overlap_no_win() {
        // k_h <= s_h -> no redundancy to remove (paper §3.4).
        let s = ConvShape::new(Nhwc::new(1, 12, 12, 1), KernelShape::new(3, 3, 1, 1), 3, 3);
        assert!(s.eq4_difference() <= 0);
        let s2 = ConvShape::new(Nhwc::new(1, 12, 12, 1), KernelShape::new(3, 3, 1, 1), 4, 4);
        assert!(s2.eq4_difference() <= 0);
    }

    #[test]
    fn indexing_row_major() {
        let s = Nhwc::new(2, 3, 4, 5);
        assert_eq!(s.index(0, 0, 0, 0), 0);
        assert_eq!(s.index(0, 0, 0, 4), 4);
        assert_eq!(s.index(0, 0, 1, 0), 5);
        assert_eq!(s.index(0, 1, 0, 0), 20);
        assert_eq!(s.index(1, 0, 0, 0), 60);
        assert_eq!(s.index(1, 2, 3, 4), 119);
        assert_eq!(s.len(), 120);
    }

    #[test]
    fn kernel_indexing() {
        let k = KernelShape::new(3, 3, 2, 4);
        assert_eq!(k.index(0, 0, 0, 0), 0);
        assert_eq!(k.index(0, 0, 0, 3), 3);
        assert_eq!(k.index(0, 0, 1, 0), 4);
        assert_eq!(k.index(0, 1, 0, 0), 8);
        assert_eq!(k.index(2, 2, 1, 3), 71);
        assert_eq!(k.len(), 72);
    }

    #[test]
    fn macs_count() {
        let s = cv_like();
        assert_eq!(s.macs(), 25 * 9);
        assert_eq!(s.flops(), 2 * 25 * 9);
    }

    #[test]
    #[should_panic(expected = "channels")]
    fn channel_mismatch_panics() {
        let _ = ConvShape::new(Nhwc::new(1, 7, 7, 2), KernelShape::new(3, 3, 1, 1), 1, 1);
    }
}
