//! NHWC tensors and convolution geometry (paper §2.1, Table 1).

pub mod shape;
#[allow(clippy::module_inception)]
pub mod tensor;

pub use shape::{ConvShape, KernelShape, Nhwc};
pub use tensor::{Kernel, Tensor};
