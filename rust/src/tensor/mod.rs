//! NHWC tensors and convolution geometry (paper §2.1, Table 1), plus the
//! 16-bit fixed-point dtype layer ([`quant`]).

pub mod quant;
pub mod shape;
#[allow(clippy::module_inception)]
pub mod tensor;

pub use quant::{Precision, QParams};
pub use shape::{ConvShape, KernelShape, Nhwc};
pub use tensor::{Kernel, Tensor};
