//! Dense `f32` NHWC tensors. Row-major (C convention, paper §2.1), so a
//! tensor can be reinterpreted as matrices of various shapes without moving
//! data — the property both im2col and MEC exploit.

use super::shape::{KernelShape, Nhwc};
use crate::util::Rng;

/// Owned 4-D NHWC tensor of `f32`.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Nhwc,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: Nhwc) -> Tensor {
        Tensor {
            shape,
            data: vec![0.0; shape.len()],
        }
    }

    /// Build from an existing buffer (must match the shape's length).
    pub fn from_vec(shape: Nhwc, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.len(), data.len(), "shape {shape} != buffer {}", data.len());
        Tensor { shape, data }
    }

    /// Element-wise construction from indices.
    pub fn from_fn<F: FnMut(usize, usize, usize, usize) -> f32>(shape: Nhwc, mut f: F) -> Tensor {
        let mut data = Vec::with_capacity(shape.len());
        for n in 0..shape.n {
            for h in 0..shape.h {
                for w in 0..shape.w {
                    for c in 0..shape.c {
                        data.push(f(n, h, w, c));
                    }
                }
            }
        }
        Tensor { shape, data }
    }

    /// Uniform random in `[-1, 1)` from a deterministic RNG.
    pub fn random(shape: Nhwc, rng: &mut Rng) -> Tensor {
        let mut data = vec![0.0; shape.len()];
        rng.fill_uniform(&mut data, -1.0, 1.0);
        Tensor { shape, data }
    }

    pub fn shape(&self) -> Nhwc {
        self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Element access.
    #[inline(always)]
    pub fn at(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        self.data[self.shape.index(n, h, w, c)]
    }

    /// Mutable element access.
    #[inline(always)]
    pub fn at_mut(&mut self, n: usize, h: usize, w: usize, c: usize) -> &mut f32 {
        let i = self.shape.index(n, h, w, c);
        &mut self.data[i]
    }

    /// The `n`-th sample as a contiguous slice (`h·w·c` elements).
    pub fn sample(&self, n: usize) -> &[f32] {
        let sz = self.shape.h * self.shape.w * self.shape.c;
        &self.data[n * sz..(n + 1) * sz]
    }

    /// Zero-pad spatially by `(ph, pw)` on each side — the paper assumes
    /// padding is pre-applied (§2.1); this is the pre-application.
    pub fn pad_spatial(&self, ph: usize, pw: usize) -> Tensor {
        let s = self.shape;
        let out_shape = Nhwc::new(s.n, s.h + 2 * ph, s.w + 2 * pw, s.c);
        let mut out = Tensor::zeros(out_shape);
        for n in 0..s.n {
            for h in 0..s.h {
                let src = &self.data[s.index(n, h, 0, 0)..s.index(n, h, 0, 0) + s.w * s.c];
                let dst_off = out_shape.index(n, h + ph, pw, 0);
                out.data[dst_off..dst_off + s.w * s.c].copy_from_slice(src);
            }
        }
        out
    }

    /// Bytes of payload.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Owned convolution kernel tensor, `k_h × k_w × i_c × k_c` row-major —
/// i.e. already in the `(k_h·k_w·i_c) × k_c` matrix layout that both
/// im2col and MEC multiply against (paper Algorithm 2 line 7).
#[derive(Debug, Clone, PartialEq)]
pub struct Kernel {
    shape: KernelShape,
    data: Vec<f32>,
}

impl Kernel {
    pub fn zeros(shape: KernelShape) -> Kernel {
        Kernel {
            shape,
            data: vec![0.0; shape.len()],
        }
    }

    pub fn from_vec(shape: KernelShape, data: Vec<f32>) -> Kernel {
        assert_eq!(shape.len(), data.len());
        Kernel { shape, data }
    }

    pub fn from_fn<F: FnMut(usize, usize, usize, usize) -> f32>(
        shape: KernelShape,
        mut f: F,
    ) -> Kernel {
        let mut data = Vec::with_capacity(shape.len());
        for h in 0..shape.kh {
            for w in 0..shape.kw {
                for i in 0..shape.ic {
                    for o in 0..shape.kc {
                        data.push(f(h, w, i, o));
                    }
                }
            }
        }
        Kernel { shape, data }
    }

    pub fn random(shape: KernelShape, rng: &mut Rng) -> Kernel {
        let mut data = vec![0.0; shape.len()];
        rng.fill_uniform(&mut data, -1.0, 1.0);
        Kernel { shape, data }
    }

    pub fn shape(&self) -> KernelShape {
        self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline(always)]
    pub fn at(&self, h: usize, w: usize, i: usize, o: usize) -> f32 {
        self.data[self.shape.index(h, w, i, o)]
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_fn_row_major() {
        let t = Tensor::from_fn(Nhwc::new(1, 2, 2, 2), |_, h, w, c| (h * 4 + w * 2 + c) as f32);
        assert_eq!(t.data(), &[0., 1., 2., 3., 4., 5., 6., 7.]);
        assert_eq!(t.at(0, 1, 0, 1), 5.0);
    }

    #[test]
    fn pad_spatial_places_content() {
        let t = Tensor::from_fn(Nhwc::new(1, 2, 2, 1), |_, h, w, _| (h * 2 + w + 1) as f32);
        let p = t.pad_spatial(1, 1);
        assert_eq!(p.shape(), Nhwc::new(1, 4, 4, 1));
        assert_eq!(p.at(0, 0, 0, 0), 0.0);
        assert_eq!(p.at(0, 1, 1, 0), 1.0);
        assert_eq!(p.at(0, 2, 2, 0), 4.0);
        assert_eq!(p.at(0, 3, 3, 0), 0.0);
        // Padded mass equals original mass.
        let sum: f32 = p.data().iter().sum();
        assert_eq!(sum, 1.0 + 2.0 + 3.0 + 4.0);
    }

    #[test]
    fn sample_slices() {
        let t = Tensor::from_fn(Nhwc::new(2, 1, 2, 1), |n, _, w, _| (n * 10 + w) as f32);
        assert_eq!(t.sample(0), &[0.0, 1.0]);
        assert_eq!(t.sample(1), &[10.0, 11.0]);
    }

    #[test]
    fn random_is_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = Tensor::random(Nhwc::new(1, 3, 3, 2), &mut r1);
        let b = Tensor::random(Nhwc::new(1, 3, 3, 2), &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn kernel_matrix_layout() {
        // Kernel [kh,kw,ic,kc] row-major == (kh·kw·ic) × kc matrix: the
        // element (row r, col o) with r = (h·kw + w)·ic + i must be at
        // linear r·kc + o.
        let k = Kernel::from_fn(KernelShape::new(2, 2, 3, 4), |h, w, i, o| {
            (((h * 2 + w) * 3 + i) * 4 + o) as f32
        });
        for (lin, &v) in k.data().iter().enumerate() {
            assert_eq!(lin as f32, v);
        }
    }

    #[test]
    fn bytes_reported() {
        let t = Tensor::zeros(Nhwc::new(1, 2, 2, 1));
        assert_eq!(t.bytes(), 16);
    }
}
