//! Artifact manifest: what `make artifacts` produced and how to call it.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt`, one line per
//! artifact (hand-rolled format; serde is unavailable offline):
//!
//! ```text
//! # comment
//! name=conv_cv6 file=conv_cv6.hlo.txt inputs=1,12,12,256;3,3,256,512 outputs=1,10,10,512
//! ```

use crate::util::error::{Context, Result};
use std::path::{Path, PathBuf};

/// One artifact entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shapes: Vec<Vec<usize>>,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<Artifact>,
}

fn parse_shapes(s: &str) -> Result<Vec<Vec<usize>>> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(';')
        .map(|shape| {
            shape
                .split(',')
                .map(|d| d.trim().parse::<usize>().context("bad dim"))
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Load `dir/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        Manifest::parse(&text, dir)
    }

    /// Parse manifest text (testable without files).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut name = None;
            let mut file = None;
            let mut inputs = Vec::new();
            let mut outputs = Vec::new();
            for field in line.split_whitespace() {
                let Some((k, v)) = field.split_once('=') else {
                    crate::bail!("manifest line {}: bad field {:?}", lineno + 1, field);
                };
                match k {
                    "name" => name = Some(v.to_string()),
                    "file" => file = Some(v.to_string()),
                    "inputs" => inputs = parse_shapes(v)?,
                    "outputs" => outputs = parse_shapes(v)?,
                    _ => crate::bail!("manifest line {}: unknown key {:?}", lineno + 1, k),
                }
            }
            let (Some(name), Some(file)) = (name, file) else {
                crate::bail!("manifest line {}: missing name/file", lineno + 1);
            };
            artifacts.push(Artifact {
                name,
                file: dir.join(file),
                input_shapes: inputs,
                output_shapes: outputs,
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }
}

/// Default artifacts directory: `$MEC_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("MEC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# artifacts built 2026-07-11
name=model_fwd file=model_fwd.hlo.txt inputs=8,28,28,1 outputs=8,3
name=conv_cv6 file=conv_cv6.hlo.txt inputs=1,12,12,256;3,3,256,512 outputs=1,10,10,512
";

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let cv6 = m.find("conv_cv6").unwrap();
        assert_eq!(cv6.file, PathBuf::from("/a/conv_cv6.hlo.txt"));
        assert_eq!(cv6.input_shapes.len(), 2);
        assert_eq!(cv6.input_shapes[1], vec![3, 3, 256, 512]);
        assert_eq!(cv6.output_shapes[0], vec![1, 10, 10, 512]);
        assert!(m.find("nope").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("name=x", PathBuf::new()).is_err()); // no file
        assert!(Manifest::parse("garbage line", PathBuf::new()).is_err());
        assert!(Manifest::parse("name=x file=y unknown=z", PathBuf::new()).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let m = Manifest::parse("# only comments\n\n", PathBuf::new()).unwrap();
        assert!(m.artifacts.is_empty());
    }
}
