//! The execution backends behind one trait: the native rust engine
//! (planned model — prepacked ConvPlans + shared arena) and, behind the
//! `pjrt` feature, the PJRT path (AOT JAX/Pallas HLO).
//! `examples/serve_cnn.rs` cross-checks them numerically.

use crate::conv::ConvContext;
use crate::memory::Arena;
use crate::model::Model;
use crate::tensor::Tensor;
use crate::util::error::Result;

/// A batched forward executor: NHWC batch in, (n × classes) scores out.
///
/// Not `Send`: the PJRT client wraps host resources in `Rc`. Construct
/// executors inside the thread that uses them (the serve example builds
/// its PJRT cross-check executor on the main thread).
pub trait Executor {
    fn name(&self) -> &str;
    /// Expected per-sample (h, w, c).
    fn input_hwc(&self) -> (usize, usize, usize);
    /// Run a forward pass; returns row-major (n × features).
    fn forward(&mut self, batch: &Tensor) -> Result<Vec<f32>>;
    /// Features per sample in the output.
    fn output_features(&self) -> usize;
}

/// Native engine executor over a planned [`Model`]: holds the shared
/// arena the planner sized, executes the model's prepacked plans.
pub struct NativeExecutor {
    pub model: std::sync::Arc<Model>,
    pub ctx: ConvContext,
    arena: Arena,
}

impl NativeExecutor {
    pub fn new(model: std::sync::Arc<Model>, ctx: ConvContext) -> NativeExecutor {
        // Pre-sized to the planned max; grows only if the model was
        // never planned (then it high-waters on first batches).
        let arena = model.sized_arena();
        NativeExecutor { model, ctx, arena }
    }

    /// Tracked bytes of the executor's shared arena.
    pub fn arena_bytes(&self) -> usize {
        self.arena.bytes()
    }
}

impl Executor for NativeExecutor {
    fn name(&self) -> &str {
        "native"
    }

    fn input_hwc(&self) -> (usize, usize, usize) {
        self.model.input_hwc
    }

    fn forward(&mut self, batch: &Tensor) -> Result<Vec<f32>> {
        let out = self.model.forward(&self.ctx, batch, &mut self.arena);
        Ok(out.into_vec())
    }

    fn output_features(&self) -> usize {
        self.model.output_features()
    }
}

/// Extract weight tensors from a loaded model in the AOT `weight_order`:
/// per conv node (kernel, bias), then dense (w, bias), in node order.
pub fn model_weight_inputs(model: &Model) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    for node in model.graph().nodes() {
        match &node.op {
            crate::model::Op::Layer(crate::model::Layer::Conv { kernel, bias, .. }) => {
                out.push(kernel.data().to_vec());
                out.push(bias.clone());
            }
            crate::model::Op::Layer(crate::model::Layer::Dense { w, bias, .. }) => {
                out.push(w.clone());
                out.push(bias.clone());
            }
            _ => {}
        }
    }
    out
}

/// PJRT executor over a compiled artifact. The artifact was lowered for a
/// fixed batch size (XLA staticness); callers must match it — the serve
/// example pads the final partial batch.
///
/// Weights travel as runtime parameters, not baked constants: the pinned
/// xla_extension 0.5.1 HLO-text parser silently mis-parses jax ≥0.8's
/// multi-dimensional f32 constant literals (found by the cross-check
/// test; see EXPERIMENTS.md §Findings). Input 0 is the image batch; the
/// remaining manifest inputs are weights supplied via [`Self::with_weights`]
/// or extracted from a loaded [`Model`] via [`model_weight_inputs`].
#[cfg(feature = "pjrt")]
pub struct PjrtExecutor {
    computation: super::Computation,
    hwc: (usize, usize, usize),
    batch: usize,
    features: usize,
    weight_shapes: Vec<Vec<usize>>,
    weights: Vec<Vec<f32>>,
}

#[cfg(feature = "pjrt")]
impl PjrtExecutor {
    /// Build from an engine + manifest entry named `name`: input 0 is the
    /// NHWC image batch, inputs 1.. are weight tensors, single output
    /// `n × f`.
    pub fn from_artifact(
        engine: &super::PjrtEngine,
        manifest: &super::Manifest,
        name: &str,
    ) -> Result<PjrtExecutor> {
        let art = manifest
            .find(name)
            .ok_or_else(|| crate::format_err!("artifact {name:?} not in manifest"))?;
        crate::ensure!(
            !art.input_shapes.is_empty() && art.input_shapes[0].len() == 4,
            "artifact {name:?}: expected NHWC input 0, got {:?}",
            art.input_shapes
        );
        let ishape = &art.input_shapes[0];
        let oshape = &art.output_shapes[0];
        let computation = engine.load_hlo_text(&art.file)?;
        Ok(PjrtExecutor {
            computation,
            hwc: (ishape[1], ishape[2], ishape[3]),
            batch: ishape[0],
            features: oshape.iter().skip(1).product(),
            weight_shapes: art.input_shapes[1..].to_vec(),
            weights: Vec::new(),
        })
    }

    /// Supply the weight tensors (order/shape per the manifest).
    pub fn with_weights(mut self, weights: Vec<Vec<f32>>) -> Result<PjrtExecutor> {
        crate::ensure!(
            weights.len() == self.weight_shapes.len(),
            "expected {} weight tensors, got {}",
            self.weight_shapes.len(),
            weights.len()
        );
        for (w, s) in weights.iter().zip(&self.weight_shapes) {
            let want: usize = s.iter().product();
            crate::ensure!(w.len() == want, "weight shape {:?} vs {} elems", s, w.len());
        }
        self.weights = weights;
        Ok(self)
    }

    /// The fixed batch size this executable was lowered for.
    pub fn lowered_batch(&self) -> usize {
        self.batch
    }

    fn run_batch(&self, data: &[f32], n: usize) -> Result<Vec<f32>> {
        let (h, w, c) = self.hwc;
        let xshape = [n, h, w, c];
        let mut inputs: Vec<(&[f32], &[usize])> = Vec::with_capacity(1 + self.weights.len());
        inputs.push((data, &xshape));
        for (wv, ws) in self.weights.iter().zip(&self.weight_shapes) {
            inputs.push((wv, ws));
        }
        self.computation.run_f32(&inputs)
    }
}

#[cfg(feature = "pjrt")]
impl Executor for PjrtExecutor {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn input_hwc(&self) -> (usize, usize, usize) {
        self.hwc
    }

    fn forward(&mut self, batch: &Tensor) -> Result<Vec<f32>> {
        let shape: crate::tensor::Nhwc = batch.shape();
        let (h, w, c) = self.hwc;
        crate::ensure!(
            (shape.h, shape.w, shape.c) == (h, w, c),
            "batch hwc {:?} vs lowered {:?}",
            (shape.h, shape.w, shape.c),
            self.hwc
        );
        let n = shape.n;
        if n == self.batch {
            return self.run_batch(batch.data(), n);
        }
        crate::ensure!(
            n < self.batch,
            "batch {n} exceeds lowered batch {}",
            self.batch
        );
        // Pad the partial batch with zeros, truncate the scores.
        let mut padded = vec![0.0f32; self.batch * h * w * c];
        padded[..batch.data().len()].copy_from_slice(batch.data());
        let out = self.run_batch(&padded, self.batch)?;
        Ok(out[..n * self.features].to_vec())
    }

    fn output_features(&self) -> usize {
        self.features
    }
}
