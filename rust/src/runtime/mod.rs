//! Execution runtime: the native plan/execute engine behind the
//! [`Executor`] trait, plus (feature-gated) the PJRT path that loads and
//! executes the AOT artifacts produced by the build-time JAX/Pallas
//! pipeline (`python/compile/aot.py`).
//!
//! The PJRT pieces need the `xla` crate, which is not in the offline
//! registry — they compile only with `--features pjrt` after vendoring
//! it. Everything else (manifest parsing, the native executor) is
//! dependency-free and always available.
//!
//! Interchange format is **HLO text** — jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. Python runs exactly once at build time; this
//! module is the only thing touching the artifacts at serve time.

pub mod artifacts;
pub mod executor;

pub use artifacts::{Artifact, Manifest};
pub use executor::{model_weight_inputs, Executor, NativeExecutor};
#[cfg(feature = "pjrt")]
pub use executor::PjrtExecutor;

#[cfg(feature = "pjrt")]
pub use pjrt::{Computation, PjrtEngine};

#[cfg(feature = "pjrt")]
mod pjrt {
    use crate::util::error::{Context, Result};
    use std::path::Path;

    /// A PJRT CPU client wrapping the `xla` crate.
    pub struct PjrtEngine {
        client: xla::PjRtClient,
    }

    /// One compiled computation ready to execute.
    pub struct Computation {
        exe: xla::PjRtLoadedExecutable,
        /// Artifact stem (jax lowers with `return_tuple=True`; all our
        /// artifacts return one array).
        pub name: String,
    }

    impl PjrtEngine {
        /// Create the CPU client.
        pub fn cpu() -> Result<PjrtEngine> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(PjrtEngine { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn device_count(&self) -> usize {
            self.client.device_count()
        }

        /// Load + compile an HLO-text artifact.
        pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Computation> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {}", path.display()))?;
            Ok(Computation {
                exe,
                name: path
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_default(),
            })
        }
    }

    impl Computation {
        /// Execute with f32 inputs of the given shapes; returns the
        /// flattened f32 outputs (the single tuple element — our
        /// artifacts return one array; extend to `to_tuple` when a model
        /// needs more).
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                    let expect: usize = dims.iter().product();
                    crate::ensure!(
                        expect == data.len(),
                        "input buffer {} elems vs shape {:?}",
                        data.len(),
                        dims
                    );
                    xla::Literal::vec1(data)
                        .reshape(&dims_i64)
                        .context("reshape input literal")
                })
                .collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .context("execute")?;
            let out = result[0][0]
                .to_literal_sync()
                .context("fetch result")?
                .to_tuple1()
                .context("unwrap result tuple")?;
            out.to_vec::<f32>().context("read result as f32")
        }
    }

    #[cfg(test)]
    mod tests {
        // PJRT integration tests live in rust/tests/runtime_pjrt.rs (they
        // need the artifacts built by `make artifacts`). Here: client
        // smoke.
        use super::*;

        #[test]
        fn cpu_client_comes_up() {
            let engine = PjrtEngine::cpu().expect("pjrt cpu client");
            assert!(engine.device_count() >= 1);
            assert!(!engine.platform().is_empty());
        }
    }
}
