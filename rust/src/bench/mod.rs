//! Benchmark support: the paper's workload tables, a timing harness
//! (criterion is unavailable offline), and paper-style report printing.
//! One binary per paper artifact lives in `rust/benches/`.

// Measurement code must not need unsafe: no unsafe, ever (enforced —
// see the crate-level unsafe policy and tools/unsafe-audit).
#![forbid(unsafe_code)]

pub mod harness;
pub mod workload;

pub use harness::{
    bench_fn, bench_mode, bench_precision, layer_builder, BenchMode, BenchOpts, BenchResult,
};
pub use workload::{resnet101_table3, suite, Platform, Workload};

use crate::conv::{ConvContext, ConvPlan, Convolution};
use crate::memory::{Arena, Workspace};
use crate::tensor::{ConvShape, Kernel, Tensor};

/// Time one convolution according to [`bench_mode`]:
///
/// * **Amortized** (default): build the [`ConvPlan`](crate::conv::ConvPlan)
///   once outside the timed region and time repeated `execute` calls
///   against a pre-sized arena — the steady-state serving cost, with
///   kernel packing/transform paid at "model load" like production
///   frameworks do. This is what the Fig. 4 runtime numbers reflect.
/// * **Oneshot**: time `Convolution::run` (plan + execute per call) with
///   a reused workspace — the cold-path cost.
#[allow(clippy::too_many_arguments)]
pub fn bench_conv(
    name: &str,
    opts: &BenchOpts,
    algo: &dyn Convolution,
    ctx: &ConvContext,
    shape: &ConvShape,
    input: &Tensor,
    kernel: &Kernel,
    out: &mut Tensor,
) -> BenchResult {
    match bench_mode() {
        BenchMode::Amortized => {
            let plan = algo.plan(ctx, shape, kernel);
            let mut arena = Arena::with_capacity(plan.workspace_elems());
            bench_fn(name, opts, || {
                plan.execute(input, &mut arena, out);
            })
        }
        BenchMode::Oneshot => {
            let mut ws = Workspace::new();
            bench_fn(name, opts, || {
                algo.run(ctx, shape, input, kernel, &mut ws, out);
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::AlgoKind;
    use crate::tensor::{KernelShape, Nhwc};
    use crate::util::Rng;
    use std::time::Duration;

    #[test]
    fn bench_conv_times_both_modes_equivalently() {
        // Smoke: bench_conv produces a timing and a correct output in the
        // default (amortized) mode.
        let shape = ConvShape::new(Nhwc::new(1, 8, 8, 2), KernelShape::new(3, 3, 2, 3), 1, 1);
        let mut rng = Rng::new(4);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut out = Tensor::zeros(shape.output());
        let opts = BenchOpts {
            warmup: 0,
            min_reps: 1,
            max_reps: 2,
            target_time: Duration::from_millis(1),
        };
        let algo = AlgoKind::Mec.build();
        let ctx = ConvContext::default();
        let r = bench_conv("smoke", &opts, &*algo, &ctx, &shape, &input, &kernel, &mut out);
        assert!(r.median_ns() > 0.0);
        let want = crate::conv::convolve(AlgoKind::Mec, &ctx, &shape, &input, &kernel);
        assert_eq!(out.data(), want.data());
    }
}
