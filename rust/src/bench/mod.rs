//! Benchmark support: the paper's workload tables, a timing harness
//! (criterion is unavailable offline), and paper-style report printing.
//! One binary per paper artifact lives in `rust/benches/`.

pub mod harness;
pub mod workload;

pub use harness::{bench_fn, BenchOpts, BenchResult};
pub use workload::{resnet101_table3, suite, Platform, Workload};
