//! The paper's benchmark workloads.
//!
//! * [`suite`] — Table 2: cv1–cv12, twelve convolution layers drawn from
//!   AlexNet/OverFeat/VGG/GoogLeNet/ResNet.
//! * [`resnet101_table3`] — Table 3's weighted layer inventory for the
//!   ResNet-101 mobile experiment.
//!
//! `scale` lets the harness shrink channel counts uniformly when a quick
//! run is wanted (`MEC_BENCH_SCALE`); shapes stay faithful at scale=1.

use crate::model::{GraphBuilder, Layer, Model};
use crate::tensor::{ConvShape, Kernel, KernelShape, Nhwc};
use crate::util::Rng;

/// One named benchmark layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Workload {
    pub name: &'static str,
    pub ih: usize,
    pub iw: usize,
    pub ic: usize,
    pub kh: usize,
    pub kw: usize,
    pub kc: usize,
    pub s: usize,
}

impl Workload {
    /// ConvShape for a batch size, with channels divided by `scale`
    /// (floored at 1). scale=1 reproduces the paper exactly.
    pub fn shape(&self, batch: usize, scale: usize) -> ConvShape {
        let sc = scale.max(1);
        let ic = (self.ic / sc).max(1);
        let kc = (self.kc / sc).max(1);
        ConvShape::new(
            Nhwc::new(batch.max(1), self.ih, self.iw, ic),
            KernelShape::new(self.kh, self.kw, ic, kc),
            self.s,
            self.s,
        )
    }

    /// k/s ratio — the quantity Eq. (4) says drives MEC's advantage.
    pub fn k_over_s(&self) -> f64 {
        self.kh as f64 / self.s as f64
    }

    /// A single-conv-layer [`Model`] of this workload (random weights
    /// from `seed`, zero bias, no padding — workloads are stored
    /// unpadded), so the CLI, benches, and examples can drive one
    /// benchmark layer through the [`Engine`](crate::engine::Engine)
    /// facade. Batch size comes from the engine's pinned batches, not
    /// the model; at a given batch the model's conv geometry equals
    /// [`Workload::shape`] exactly.
    pub fn model(&self, scale: usize, seed: u64) -> Model {
        let sc = scale.max(1);
        let ic = (self.ic / sc).max(1);
        let kc = (self.kc / sc).max(1);
        let mut rng = Rng::new(seed);
        Model::new(
            self.name,
            (self.ih, self.iw, ic),
            vec![Layer::Conv {
                kernel: Kernel::random(KernelShape::new(self.kh, self.kw, ic, kc), &mut rng),
                bias: vec![0.0; kc],
                sh: self.s,
                sw: self.s,
                ph: 0,
                pw: 0,
            }],
        )
    }
}

/// A residual block over one paper workload: conv → relu → {3×3 branch
/// conv, identity} → add → relu — the diamond topology the sequential
/// model API could not express, with a fusable conv+relu pair on the
/// trunk. Stride is forced to 1 and SAME padding applied so the skip
/// connection's shapes line up. Used by the `resnet_block` example and
/// the graph-IR tests.
pub fn residual_block_model(w: &Workload, scale: usize, seed: u64) -> Model {
    let sc = scale.max(1);
    let ic = (w.ic / sc).max(1);
    let kc = (w.kc / sc).max(1);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(w.name, (w.ih, w.iw, ic));
    let x = b.input();
    let trunk = b.conv(
        x,
        Kernel::random(KernelShape::new(3, 3, ic, kc), &mut rng),
        vec![0.05; kc],
        1,
        1,
        1,
        1,
    );
    // Sole consumer of the trunk conv is this relu → the fusion pass
    // absorbs it into the conv's bias epilogue.
    let trunk = b.relu(trunk);
    let branch = b.conv(
        trunk,
        Kernel::random(KernelShape::new(3, 3, kc, kc), &mut rng),
        vec![0.0; kc],
        1,
        1,
        1,
        1,
    );
    let sum = b.add(&[branch, trunk]);
    let out = b.relu(sum);
    Model::from_graph(b.finish(out))
}

/// Paper Table 2: cv1–cv12.
pub fn suite() -> Vec<Workload> {
    vec![
        Workload { name: "cv1", ih: 227, iw: 227, ic: 3, kh: 11, kw: 11, kc: 96, s: 4 },
        Workload { name: "cv2", ih: 231, iw: 231, ic: 3, kh: 11, kw: 11, kc: 96, s: 4 },
        Workload { name: "cv3", ih: 227, iw: 227, ic: 3, kh: 7, kw: 7, kc: 64, s: 2 },
        Workload { name: "cv4", ih: 224, iw: 224, ic: 64, kh: 7, kw: 7, kc: 64, s: 2 },
        Workload { name: "cv5", ih: 24, iw: 24, ic: 96, kh: 5, kw: 5, kc: 256, s: 1 },
        Workload { name: "cv6", ih: 12, iw: 12, ic: 256, kh: 3, kw: 3, kc: 512, s: 1 },
        Workload { name: "cv7", ih: 224, iw: 224, ic: 3, kh: 3, kw: 3, kc: 64, s: 1 },
        Workload { name: "cv8", ih: 112, iw: 112, ic: 64, kh: 3, kw: 3, kc: 128, s: 1 },
        Workload { name: "cv9", ih: 56, iw: 56, ic: 64, kh: 3, kw: 3, kc: 64, s: 1 },
        Workload { name: "cv10", ih: 28, iw: 28, ic: 128, kh: 3, kw: 3, kc: 128, s: 1 },
        Workload { name: "cv11", ih: 14, iw: 14, ic: 256, kh: 3, kw: 3, kc: 256, s: 1 },
        Workload { name: "cv12", ih: 7, iw: 7, ic: 512, kh: 3, kw: 3, kc: 512, s: 1 },
    ]
}

/// Non-paper fixtures where the related-work algorithms should win —
/// kept outside [`suite`] so the Table 2 artifacts stay paper-exact.
/// These anchor the cost model the way cv1–cv12 anchor Eq. 2/3: if the
/// planner stops picking the expected winner here, an entry went stale.
///
/// * `pw1` — a GoogLeNet-style 1×1 channel-reduction layer: kn2row's
///   decomposition degenerates to a single unshifted GEMM, so it gets
///   im2col's compute with zero lowered copy.
/// * `pw2` — a ResNet-style 1×1 expansion (cv12's grid, 4× channel
///   growth): same story at a heavier channel count.
pub fn extras() -> Vec<Workload> {
    vec![
        Workload { name: "pw1", ih: 28, iw: 28, ic: 512, kh: 1, kw: 1, kc: 128, s: 1 },
        Workload { name: "pw2", ih: 7, iw: 7, ic: 512, kh: 1, kw: 1, kc: 2048, s: 1 },
    ]
}

/// Look up one workload by name — the paper suite plus [`extras`].
pub fn by_name(name: &str) -> Option<Workload> {
    suite().into_iter().chain(extras()).find(|w| w.name == name)
}

/// Paper Table 3: ResNet-101 layers with occurrence weights.
pub fn resnet101_table3() -> Vec<(Workload, usize)> {
    let get = |n: &str| by_name(n).unwrap();
    vec![
        (get("cv4"), 1),
        (get("cv9"), 3),
        (get("cv10"), 4),
        (get("cv11"), 23),
        (get("cv12"), 3),
    ]
}

/// The two platforms of §4, as engine configurations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Platform {
    /// ARM7 phone: 1 thread, mini-batch 1.
    Mobile,
    /// Server CPU: all cores, mini-batch 32.
    ServerCpu,
    /// Server GPU simulated by the batched-gemm path (see DESIGN.md §3):
    /// memory numbers are exact, runtimes are CPU-host stand-ins.
    ServerGpuSim,
}

impl Platform {
    pub fn batch(&self) -> usize {
        match self {
            Platform::Mobile => 1,
            _ => 32,
        }
    }

    /// Server platforms honor the `MEC_THREADS` pin (see
    /// [`bench_threads`](crate::bench::harness::bench_threads)); Mobile
    /// is the paper's single-core configuration and stays at 1.
    pub fn threads(&self) -> usize {
        match self {
            Platform::Mobile => 1,
            _ => crate::bench::harness::bench_threads().unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            }),
        }
    }

    pub fn ctx(&self) -> crate::conv::ConvContext {
        crate::conv::ConvContext::default().with_threads(self.threads())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_is_faithful() {
        let s = suite();
        assert_eq!(s.len(), 12);
        // Spot-check against the paper's Table 2.
        let cv1 = &s[0];
        assert_eq!((cv1.ih, cv1.iw, cv1.ic), (227, 227, 3));
        assert_eq!((cv1.kh, cv1.kc, cv1.s), (11, 96, 4));
        let cv6 = by_name("cv6").unwrap();
        assert_eq!((cv6.ih, cv6.ic, cv6.kh, cv6.kc, cv6.s), (12, 256, 3, 512, 1));
        let cv12 = by_name("cv12").unwrap();
        assert_eq!((cv12.ih, cv12.ic, cv12.kc), (7, 512, 512));
    }

    #[test]
    fn workload_model_reproduces_the_conv_shape() {
        let w = by_name("cv6").unwrap();
        let m = w.model(4, 7);
        let shapes = m.conv_shapes(3);
        assert_eq!(shapes.len(), 1);
        assert_eq!(shapes[0].1, w.shape(3, 4));
    }

    #[test]
    fn shapes_compute_eq1() {
        // cv1: (227-11)/4+1 = 55.
        let cv1 = by_name("cv1").unwrap().shape(1, 1);
        assert_eq!((cv1.oh(), cv1.ow()), (55, 55));
        // cv4: (224-7)/2+1 = 109 (paper uses it in ResNet table).
        let cv4 = by_name("cv4").unwrap().shape(1, 1);
        assert_eq!(cv4.oh(), 109);
    }

    #[test]
    fn scaling_shrinks_channels_only() {
        let full = by_name("cv6").unwrap().shape(1, 1);
        let s4 = by_name("cv6").unwrap().shape(1, 4);
        assert_eq!(full.input.h, s4.input.h);
        assert_eq!(s4.input.c, 64);
        assert_eq!(s4.kernel.kc, 128);
    }

    #[test]
    fn extras_stay_out_of_the_paper_suite() {
        // Table 2 artifacts iterate suite(); the related-work fixtures
        // must not leak into them.
        assert_eq!(suite().len(), 12);
        assert!(suite().iter().all(|w| !w.name.starts_with("pw")));
        let pw1 = by_name("pw1").unwrap();
        assert_eq!((pw1.kh, pw1.kw), (1, 1));
        let shape = pw1.shape(1, 1);
        assert_eq!((shape.oh(), shape.ow()), (28, 28));
        assert!(by_name("pw2").is_some());
    }

    #[test]
    fn table3_weights_match_paper() {
        let t = resnet101_table3();
        let weights: Vec<usize> = t.iter().map(|(_, w)| *w).collect();
        assert_eq!(weights, vec![1, 3, 4, 23, 3]);
        assert_eq!(t[0].0.name, "cv4");
        assert_eq!(t[3].0.name, "cv11");
    }

    #[test]
    fn eq2_eq3_on_cv1_mobile() {
        // Fig 4a anchor: cv1 im2col vs MEC lowered sizes at stride 4.
        let cv1 = by_name("cv1").unwrap().shape(1, 1);
        let ratio = cv1.im2col_lowered_elems() as f64 / cv1.mec_lowered_elems() as f64;
        // k_h/s_h = 11/4 = 2.75 -> ratio should be near (o_h·k_h)/i_h ≈ 2.67.
        assert!(ratio > 2.0 && ratio < 3.0, "ratio={ratio}");
    }

    #[test]
    fn platforms() {
        assert_eq!(Platform::Mobile.batch(), 1);
        assert_eq!(Platform::Mobile.threads(), 1);
        assert_eq!(Platform::ServerCpu.batch(), 32);
    }
}
