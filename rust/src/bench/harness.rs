//! Timing harness (criterion replacement).
//!
//! Adaptive: measures once, picks a repetition count targeting
//! `target_time`, reports median/MAD over the reps. Honors four env vars
//! so `cargo bench` stays usable on slow hosts:
//! * `MEC_BENCH_SCALE`  — channel divisor for the paper workloads (default 1)
//! * `MEC_BENCH_FAST`   — if set, caps reps at 3 and target time at 200 ms
//! * `MEC_BENCH_MODE`   — `amortized` (default: plan built once, only
//!   `execute` timed — steady-state serving cost) or `oneshot` (plan +
//!   execute per call — cold-path cost, the pre-plan/execute behaviour)
//! * `MEC_BENCH_PRECISION` — `f32` (default) or `q16`: the paper's two §4
//!   grids, so the float-vs-fixed comparison is one env var

use crate::bench::workload::Workload;
use crate::engine::{Engine, EngineBuilder};
use crate::tensor::quant::Precision;
use crate::util::stats::{fmt_ns, Summary};
use std::time::{Duration, Instant};

/// Harness options.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup: usize,
    pub min_reps: usize,
    pub max_reps: usize,
    pub target_time: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        if std::env::var_os("MEC_BENCH_FAST").is_some() {
            BenchOpts {
                warmup: 1,
                min_reps: 2,
                max_reps: 3,
                target_time: Duration::from_millis(200),
            }
        } else {
            BenchOpts {
                warmup: 1,
                min_reps: 3,
                max_reps: 10,
                target_time: Duration::from_secs(1),
            }
        }
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        self.summary.median
    }

    pub fn median_ms(&self) -> f64 {
        self.summary.median / 1e6
    }

    pub fn display(&self) -> String {
        format!(
            "{:<24} {:>12} ± {:<10} (n={})",
            self.name,
            fmt_ns(self.summary.median),
            fmt_ns(self.summary.mad),
            self.summary.n
        )
    }
}

/// Time `f` adaptively. The closure should perform one full operation.
pub fn bench_fn(name: &str, opts: &BenchOpts, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..opts.warmup {
        f();
    }
    // Pilot run to size the repetition count.
    let t0 = Instant::now();
    f();
    let pilot = t0.elapsed().as_nanos().max(1) as f64;
    let want = (opts.target_time.as_nanos() as f64 / pilot).ceil() as usize;
    let reps = want.clamp(opts.min_reps, opts.max_reps);
    let mut samples = Vec::with_capacity(reps + 1);
    samples.push(pilot);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::from(&samples),
    }
}

/// The env-var workload scale (`MEC_BENCH_SCALE`, default 1 = paper-exact).
pub fn bench_scale() -> usize {
    std::env::var("MEC_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// How convolution benches time the algorithms (`MEC_BENCH_MODE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BenchMode {
    /// Plan once at setup, time only `ConvPlan::execute` — the
    /// steady-state serving cost the Fig. 4 numbers should reflect.
    Amortized,
    /// Plan + execute inside the timed region — the cold, one-shot cost.
    Oneshot,
}

impl BenchMode {
    pub fn label(self) -> &'static str {
        match self {
            BenchMode::Amortized => "plan-amortized (set MEC_BENCH_MODE=oneshot for cold)",
            BenchMode::Oneshot => "oneshot (plan+execute per call)",
        }
    }
}

/// The env-var execution precision (`MEC_BENCH_PRECISION`, default f32).
/// Case-insensitive; warns on stderr for unrecognized values instead of
/// silently falling back.
pub fn bench_precision() -> Precision {
    match std::env::var("MEC_BENCH_PRECISION") {
        Ok(v) => match Precision::parse(&v) {
            Some(p) => p,
            None => {
                eprintln!(
                    "warning: unrecognized MEC_BENCH_PRECISION={v:?} (expected \
                     'f32' or 'q16'); using f32"
                );
                Precision::F32
            }
        },
        Err(_) => Precision::F32,
    }
}

/// The env-var thread pin (`MEC_THREADS`): when set (≥ 1), the paper
/// benches run at exactly this thread budget instead of their platform
/// default ([`ConvContext::server`](crate::conv::ConvContext::server)
/// honors it directly; Mobile-platform benches apply it explicitly).
/// Warns on stderr for unparsable values instead of silently ignoring
/// them.
pub fn bench_threads() -> Option<usize> {
    let parsed = crate::conv::threads_env();
    if parsed.is_none() {
        if let Ok(v) = std::env::var("MEC_THREADS") {
            eprintln!(
                "warning: unrecognized MEC_THREADS={v:?} (expected an integer >= 1); \
                 using the platform default"
            );
        }
    }
    parsed
}

/// Bench-header line describing the thread pinning in force (parses
/// silently — the consumer that actually applied the pin already warned
/// about invalid values).
pub fn threads_label(threads: usize) -> String {
    match crate::conv::threads_env() {
        Some(_) => format!("{threads} threads (pinned via MEC_THREADS)"),
        None => format!("{threads} threads (platform default; set MEC_THREADS to pin)"),
    }
}

/// Bench-header line describing the micro-kernel backend in force and
/// its register tile (one-time detection; `MEC_KERNEL` forces a
/// backend, see `gemm::micro`).
pub fn kernel_label() -> String {
    let b = crate::gemm::KernelBackend::active();
    format!(
        "{} ({}x{} tile; set MEC_KERNEL=scalar|avx2|avx512|neon to force)",
        b.name(),
        crate::gemm::micro::MR,
        b.nr()
    )
}

/// The env-var bench mode (`MEC_BENCH_MODE`, default amortized).
/// Case-insensitive; warns on stderr for unrecognized values instead of
/// silently falling back.
pub fn bench_mode() -> BenchMode {
    match std::env::var("MEC_BENCH_MODE") {
        Ok(v) => match v.trim().to_ascii_lowercase().as_str() {
            "oneshot" | "one-shot" | "cold" => BenchMode::Oneshot,
            "" | "amortized" | "amortised" | "plan-amortized" | "warm" => BenchMode::Amortized,
            other => {
                eprintln!(
                    "warning: unrecognized MEC_BENCH_MODE={other:?} (expected \
                     'amortized' or 'oneshot'); using amortized"
                );
                BenchMode::Amortized
            }
        },
        Err(_) => BenchMode::Amortized,
    }
}

/// An [`EngineBuilder`] over a single-conv-layer model of `workload`,
/// pinned to `batch` — the bridge the CLI subcommands, examples, and
/// bench drivers use to put one paper layer behind the
/// [`Engine`](crate::engine::Engine) facade. Callers chain the remaining
/// knobs (`.precision`, `.budget`, `.threads`, `.algo_override(0, ..)`,
/// `.autotune`) and `build()`.
pub fn layer_builder(workload: &Workload, batch: usize, scale: usize) -> EngineBuilder {
    Engine::builder(workload.model(scale, 0x6ec)).pin_batch_sizes(&[batch])
}

/// Print a report table header + rows, paper-figure style.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_counts_reps() {
        let mut calls = 0usize;
        let opts = BenchOpts {
            warmup: 1,
            min_reps: 2,
            max_reps: 4,
            target_time: Duration::from_millis(1),
        };
        let r = bench_fn("noop", &opts, || {
            calls += 1;
        });
        // warmup(1) + pilot(1) + reps(2..=4)
        assert!(calls >= 4 && calls <= 6, "calls={calls}");
        assert!(r.summary.median >= 0.0);
        assert!(r.display().contains("noop"));
    }

    #[test]
    fn bench_measures_sleep_duration() {
        let opts = BenchOpts {
            warmup: 0,
            min_reps: 2,
            max_reps: 2,
            target_time: Duration::from_millis(1),
        };
        let r = bench_fn("sleep", &opts, || {
            std::thread::sleep(Duration::from_millis(5));
        });
        assert!(r.median_ms() >= 4.0, "median={}ms", r.median_ms());
    }

    #[test]
    fn layer_builder_drives_one_workload_through_the_facade() {
        use crate::bench::workload::by_name;
        use crate::conv::AlgoKind;
        use crate::tensor::Tensor;
        use crate::util::Rng;
        let w = by_name("cv6").unwrap();
        let scale = 16; // keep the unit test light
        let engine = layer_builder(&w, 2, scale)
            .algo_override(0, AlgoKind::Mec)
            .build()
            .expect("cv6 runs MEC");
        assert_eq!(engine.plan_report()[0].shape, w.shape(2, scale));
        let mut rng = Rng::new(3);
        let input = Tensor::random(w.shape(2, scale).input, &mut rng);
        let out = engine.session().infer_batch(&input).unwrap();
        assert_eq!(out.shape(), w.shape(2, scale).output());
    }

    #[test]
    fn scale_default_is_one() {
        // (env not set in tests)
        if std::env::var_os("MEC_BENCH_SCALE").is_none() {
            assert_eq!(bench_scale(), 1);
        }
    }
}
