//! The MR×NR register micro-kernel.
//!
//! Written so LLVM auto-vectorizes the inner NR-wide loop into SIMD f32
//! lanes; MR×NR accumulators live in registers across the whole K loop.
//! This is the single hottest loop in the repository — every convolution
//! algorithm except `direct` funnels >95% of its FLOPs through here.

/// Rows per micro-tile.
pub const MR: usize = 8;
/// Columns per micro-tile (one or two SIMD vectors of f32).
pub const NR: usize = 8;

/// Compute `acc[r][c] = sum_k ap[k·MR + r] · bp[k·NR + c]`.
///
/// * `ap`: packed A strip, `kb·MR` floats, column-of-strip major.
/// * `bp`: packed B strip, `kb·NR` floats, row-of-strip major.
/// * The caller adds `acc` into C (applying alpha and edge masking).
#[inline(always)]
pub fn kernel(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR]) {
    kernel_rows::<MR>(ap, bp, kb, acc);
}

/// Edge variant: compute only the first `mr` rows. MEC's Solution A/B
/// gemms have `m = o_w` (often 5–14, paper Table 2), so the MR-strip
/// tail is a large fraction of the work — computing padded rows cost
/// ~35% on cv6 before this was added (§Perf iteration 2).
///
/// `mr` must be in `1..=MR`: every macro-kernel strip has at least one
/// real row. `mr == 0` used to fall through to the full-MR kernel and
/// compute 8 rows of garbage; it now zeroes `acc` (debug builds assert).
#[inline(always)]
pub fn kernel_edge(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR], mr: usize) {
    debug_assert!((1..=MR).contains(&mr), "kernel_edge: mr={mr} out of range 1..=MR");
    match mr {
        0 => acc.fill(0.0),
        1 => kernel_rows::<1>(ap, bp, kb, acc),
        2 => kernel_rows::<2>(ap, bp, kb, acc),
        3 => kernel_rows::<3>(ap, bp, kb, acc),
        4 => kernel_rows::<4>(ap, bp, kb, acc),
        5 => kernel_rows::<5>(ap, bp, kb, acc),
        6 => kernel_rows::<6>(ap, bp, kb, acc),
        7 => kernel_rows::<7>(ap, bp, kb, acc),
        _ => kernel_rows::<MR>(ap, bp, kb, acc),
    }
}

#[inline(always)]
fn kernel_rows<const R: usize>(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    // Local accumulators: LLVM keeps these in vector registers.
    let mut c = [[0.0f32; NR]; R];
    // Fixed-size array windows (`&[f32; MR]`/`&[f32; NR]`) over slices
    // pre-cut to exactly kb: the iterators carry the trip count and the
    // window length checks fold away, leaving the inner loops with no
    // bounds checks at all. 4-way K unroll kept: fewer loop-carried
    // dependencies, better ILP.
    let kb4 = kb - kb % 4;
    for (a, b) in ap[..kb4 * MR]
        .chunks_exact(4 * MR)
        .zip(bp[..kb4 * NR].chunks_exact(4 * NR))
    {
        for kk in 0..4 {
            let a: &[f32; MR] = a[kk * MR..(kk + 1) * MR].try_into().unwrap();
            let b: &[f32; NR] = b[kk * NR..(kk + 1) * NR].try_into().unwrap();
            for r in 0..R {
                let ar = a[r];
                for j in 0..NR {
                    c[r][j] += ar * b[j];
                }
            }
        }
    }
    for (a, b) in ap[kb4 * MR..kb * MR]
        .chunks_exact(MR)
        .zip(bp[kb4 * NR..kb * NR].chunks_exact(NR))
    {
        let a: &[f32; MR] = a.try_into().unwrap();
        let b: &[f32; NR] = b.try_into().unwrap();
        for r in 0..R {
            let ar = a[r];
            for j in 0..NR {
                c[r][j] += ar * b[j];
            }
        }
    }
    for (dst, src) in acc.chunks_exact_mut(NR).zip(c.iter()) {
        dst.copy_from_slice(src);
    }
}

/// Q15 fixed-point variant of [`kernel`]: i16 operands, i32 accumulators.
///
/// `acc[r][c] = Σ_k (ap[k·MR+r] · bp[k·NR+c] + 2¹⁴) >> 15` — each widened
/// product is rounded-shifted back into Q15 before accumulation, so the
/// running sum stays within i32 for any realistic K (the packers assert
/// `K ≤ 2¹⁵`). The caller folds the 2¹⁵ into its dequantization scale
/// (`scale_a · scale_b · 32768`).
#[inline(always)]
pub fn kernel_i16(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR]) {
    kernel_rows_i16::<MR>(ap, bp, kb, acc);
}

/// Edge variant of [`kernel_i16`]: compute only the first `mr` rows.
/// Same `1..=MR` contract as [`kernel_edge`].
#[inline(always)]
pub fn kernel_edge_i16(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR], mr: usize) {
    debug_assert!(
        (1..=MR).contains(&mr),
        "kernel_edge_i16: mr={mr} out of range 1..=MR"
    );
    match mr {
        0 => acc.fill(0),
        1 => kernel_rows_i16::<1>(ap, bp, kb, acc),
        2 => kernel_rows_i16::<2>(ap, bp, kb, acc),
        3 => kernel_rows_i16::<3>(ap, bp, kb, acc),
        4 => kernel_rows_i16::<4>(ap, bp, kb, acc),
        5 => kernel_rows_i16::<5>(ap, bp, kb, acc),
        6 => kernel_rows_i16::<6>(ap, bp, kb, acc),
        7 => kernel_rows_i16::<7>(ap, bp, kb, acc),
        _ => kernel_rows_i16::<MR>(ap, bp, kb, acc),
    }
}

#[inline(always)]
fn kernel_rows_i16<const R: usize>(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    let mut c = [[0i32; NR]; R];
    // Same bounds-check-free array-window shape as the f32 kernel.
    let kb4 = kb - kb % 4;
    for (a, b) in ap[..kb4 * MR]
        .chunks_exact(4 * MR)
        .zip(bp[..kb4 * NR].chunks_exact(4 * NR))
    {
        for kk in 0..4 {
            let a: &[i16; MR] = a[kk * MR..(kk + 1) * MR].try_into().unwrap();
            let b: &[i16; NR] = b[kk * NR..(kk + 1) * NR].try_into().unwrap();
            for r in 0..R {
                let ar = a[r] as i32;
                for j in 0..NR {
                    c[r][j] += (ar * b[j] as i32 + (1 << 14)) >> 15;
                }
            }
        }
    }
    for (a, b) in ap[kb4 * MR..kb * MR]
        .chunks_exact(MR)
        .zip(bp[kb4 * NR..kb * NR].chunks_exact(NR))
    {
        let a: &[i16; MR] = a.try_into().unwrap();
        let b: &[i16; NR] = b.try_into().unwrap();
        for r in 0..R {
            let ar = a[r] as i32;
            for j in 0..NR {
                c[r][j] += (ar * b[j] as i32 + (1 << 14)) >> 15;
            }
        }
    }
    for (dst, src) in acc.chunks_exact_mut(NR).zip(c.iter()) {
        dst.copy_from_slice(src);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_matches_naive() {
        let kb = 13;
        let mut ap = vec![0.0f32; kb * MR];
        let mut bp = vec![0.0f32; kb * NR];
        for (i, v) in ap.iter_mut().enumerate() {
            *v = (i % 7) as f32 - 3.0;
        }
        for (i, v) in bp.iter_mut().enumerate() {
            *v = (i % 5) as f32 * 0.5 - 1.0;
        }
        let mut acc = [0.0f32; MR * NR];
        kernel(&ap, &bp, kb, &mut acc);
        for r in 0..MR {
            for c in 0..NR {
                let want: f32 = (0..kb).map(|k| ap[k * MR + r] * bp[k * NR + c]).sum();
                assert!(
                    (acc[r * NR + c] - want).abs() < 1e-4,
                    "r={r} c={c}: {} vs {want}",
                    acc[r * NR + c]
                );
            }
        }
    }

    #[test]
    fn kernel_zero_k() {
        let mut acc = [1.0f32; MR * NR];
        kernel(&[], &[], 0, &mut acc);
        assert!(acc.iter().all(|&v| v == 0.0));
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "kernel_edge: mr=0"))]
    fn kernel_edge_rejects_zero_rows() {
        // Debug builds assert; release builds must zero the accumulator
        // instead of computing MR garbage rows (the old fall-through bug).
        let mut acc = [7.0f32; MR * NR];
        kernel_edge(&[1.0; MR], &[1.0; NR], 1, &mut acc, 0);
        assert!(acc.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn kernel_edge_all_valid_rows_match_full() {
        let kb = 9;
        let mut ap = vec![0.0f32; kb * MR];
        let mut bp = vec![0.0f32; kb * NR];
        for (i, v) in ap.iter_mut().enumerate() {
            *v = ((i * 7) % 11) as f32 - 5.0;
        }
        for (i, v) in bp.iter_mut().enumerate() {
            *v = ((i * 3) % 13) as f32 * 0.25 - 1.5;
        }
        let mut full = [0.0f32; MR * NR];
        kernel(&ap, &bp, kb, &mut full);
        for mr in 1..=MR {
            let mut edge = [f32::NAN; MR * NR];
            kernel_edge(&ap, &bp, kb, &mut edge, mr);
            for r in 0..mr {
                assert_eq!(&edge[r * NR..r * NR + NR], &full[r * NR..r * NR + NR], "mr={mr} r={r}");
            }
        }
    }

    #[test]
    fn kernel_i16_matches_naive_shifted_sum() {
        let kb = 13;
        let mut ap = vec![0i16; kb * MR];
        let mut bp = vec![0i16; kb * NR];
        for (i, v) in ap.iter_mut().enumerate() {
            *v = ((i as i32 * 2477) % 65535 - 32767) as i16;
        }
        for (i, v) in bp.iter_mut().enumerate() {
            *v = ((i as i32 * 4391) % 65535 - 32767) as i16;
        }
        let mut acc = [0i32; MR * NR];
        kernel_i16(&ap, &bp, kb, &mut acc);
        for r in 0..MR {
            for c in 0..NR {
                let want: i32 = (0..kb)
                    .map(|k| (ap[k * MR + r] as i32 * bp[k * NR + c] as i32 + (1 << 14)) >> 15)
                    .sum();
                assert_eq!(acc[r * NR + c], want, "r={r} c={c}");
            }
        }
    }

    #[test]
    fn kernel_edge_i16_matches_full_rows() {
        let kb = 6;
        let ap: Vec<i16> = (0..kb * MR).map(|i| (i as i32 * 911 % 3000 - 1500) as i16).collect();
        let bp: Vec<i16> = (0..kb * NR).map(|i| (i as i32 * 577 % 3000 - 1500) as i16).collect();
        let mut full = [0i32; MR * NR];
        kernel_i16(&ap, &bp, kb, &mut full);
        for mr in 1..=MR {
            let mut edge = [0i32; MR * NR];
            kernel_edge_i16(&ap, &bp, kb, &mut edge, mr);
            for r in 0..mr {
                assert_eq!(&edge[r * NR..r * NR + NR], &full[r * NR..r * NR + NR], "mr={mr}");
            }
        }
    }
}
