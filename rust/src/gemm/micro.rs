//! The MR×NR register micro-kernel.
//!
//! Written so LLVM auto-vectorizes the inner NR-wide loop into SIMD f32
//! lanes; MR×NR accumulators live in registers across the whole K loop.
//! This is the single hottest loop in the repository — every convolution
//! algorithm except `direct` funnels >95% of its FLOPs through here.

/// Rows per micro-tile.
pub const MR: usize = 8;
/// Columns per micro-tile (one or two SIMD vectors of f32).
pub const NR: usize = 8;

/// Compute `acc[r][c] = sum_k ap[k·MR + r] · bp[k·NR + c]`.
///
/// * `ap`: packed A strip, `kb·MR` floats, column-of-strip major.
/// * `bp`: packed B strip, `kb·NR` floats, row-of-strip major.
/// * The caller adds `acc` into C (applying alpha and edge masking).
#[inline(always)]
pub fn kernel(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR]) {
    kernel_rows::<MR>(ap, bp, kb, acc);
}

/// Edge variant: compute only the first `mr` rows. MEC's Solution A/B
/// gemms have `m = o_w` (often 5–14, paper Table 2), so the MR-strip
/// tail is a large fraction of the work — computing padded rows cost
/// ~35% on cv6 before this was added (§Perf iteration 2).
#[inline(always)]
pub fn kernel_edge(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR], mr: usize) {
    debug_assert!(mr <= MR);
    match mr {
        1 => kernel_rows::<1>(ap, bp, kb, acc),
        2 => kernel_rows::<2>(ap, bp, kb, acc),
        3 => kernel_rows::<3>(ap, bp, kb, acc),
        4 => kernel_rows::<4>(ap, bp, kb, acc),
        5 => kernel_rows::<5>(ap, bp, kb, acc),
        6 => kernel_rows::<6>(ap, bp, kb, acc),
        7 => kernel_rows::<7>(ap, bp, kb, acc),
        _ => kernel_rows::<MR>(ap, bp, kb, acc),
    }
}

#[inline(always)]
fn kernel_rows<const R: usize>(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    // Local accumulators: LLVM keeps these in vector registers.
    let mut c = [[0.0f32; NR]; R];
    let mut k = 0;
    // 4-way K unroll: fewer loop-carried dependencies, better ILP.
    while k + 4 <= kb {
        for kk in 0..4 {
            let a = &ap[(k + kk) * MR..(k + kk) * MR + MR];
            let b = &bp[(k + kk) * NR..(k + kk) * NR + NR];
            for r in 0..R {
                let ar = a[r];
                for j in 0..NR {
                    c[r][j] += ar * b[j];
                }
            }
        }
        k += 4;
    }
    while k < kb {
        let a = &ap[k * MR..k * MR + MR];
        let b = &bp[k * NR..k * NR + NR];
        for r in 0..R {
            let ar = a[r];
            for j in 0..NR {
                c[r][j] += ar * b[j];
            }
        }
        k += 1;
    }
    for r in 0..R {
        acc[r * NR..r * NR + NR].copy_from_slice(&c[r]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_matches_naive() {
        let kb = 13;
        let mut ap = vec![0.0f32; kb * MR];
        let mut bp = vec![0.0f32; kb * NR];
        for (i, v) in ap.iter_mut().enumerate() {
            *v = (i % 7) as f32 - 3.0;
        }
        for (i, v) in bp.iter_mut().enumerate() {
            *v = (i % 5) as f32 * 0.5 - 1.0;
        }
        let mut acc = [0.0f32; MR * NR];
        kernel(&ap, &bp, kb, &mut acc);
        for r in 0..MR {
            for c in 0..NR {
                let want: f32 = (0..kb).map(|k| ap[k * MR + r] * bp[k * NR + c]).sum();
                assert!(
                    (acc[r * NR + c] - want).abs() < 1e-4,
                    "r={r} c={c}: {} vs {want}",
                    acc[r * NR + c]
                );
            }
        }
    }

    #[test]
    fn kernel_zero_k() {
        let mut acc = [1.0f32; MR * NR];
        kernel(&[], &[], 0, &mut acc);
        assert!(acc.iter().all(|&v| v == 0.0));
    }
}
