//! Single-precision GEMM substrate — our stand-in for OpenBLAS/cuBLAS.
//!
//! The paper's whole point is that both im2col and MEC reduce convolution
//! to `sgemm` calls; MEC additionally requires the BLAS *leading dimension*
//! trick: its vertical partitions P,Q,R,… of the lowered matrix L are
//! overlapping sub-matrices specified by a start pointer and
//! `ld = i_h·k_w·i_c` (paper §3.2). So the one hard requirement here is
//! supporting **row stride ≠ row length** on all of A, B, C.
//!
//! Implementation: classic Goto-style blocking (KC×MC×NC panels, packed A
//! and B, an MR×nr register micro-kernel dispatched at runtime to the
//! best `std::arch` backend — see [`micro`]), with the MC loop
//! parallelized through the caller's
//! [`Parallelism`](crate::threadpool::Parallelism) handle (persistent
//! pool workers; tiny GEMMs stay inline) — the same structure OpenBLAS
//! uses, scaled down.

pub mod micro;
pub mod pack;
pub mod q16;

pub use micro::KernelBackend;
pub use q16::{
    gemm_prepacked_batch_i16, gemm_prepacked_ex_i16, gemm_prepacked_i16, MatRefI16, PackedBI16,
    Q16Epilogue,
};

use crate::memory::aligned::{AlignedVec, ALIGN};
use crate::threadpool::Parallelism;
use micro::{MR, NR_MAX};

/// Immutable matrix view: `rows × cols` with row stride `rs`
/// (`rs >= cols`; `rs > cols` expresses BLAS `ld` sub-matrices).
#[derive(Debug, Clone, Copy)]
pub struct MatRef<'a> {
    pub data: &'a [f32],
    pub rows: usize,
    pub cols: usize,
    pub rs: usize,
}

impl<'a> MatRef<'a> {
    pub fn new(data: &'a [f32], rows: usize, cols: usize) -> MatRef<'a> {
        MatRef::strided(data, rows, cols, cols)
    }

    pub fn strided(data: &'a [f32], rows: usize, cols: usize, rs: usize) -> MatRef<'a> {
        assert!(rs >= cols, "row stride {rs} < cols {cols}");
        if rows > 0 {
            assert!(
                (rows - 1) * rs + cols <= data.len(),
                "view {rows}x{cols} (rs={rs}) exceeds buffer of {}",
                data.len()
            );
        }
        MatRef { data, rows, cols, rs }
    }

    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.rs + c]
    }

    /// Sub-view of rows `r0..r0+nr`, cols `c0..c0+nc`.
    pub fn sub(&self, r0: usize, nr: usize, c0: usize, nc: usize) -> MatRef<'a> {
        assert!(r0 + nr <= self.rows && c0 + nc <= self.cols);
        MatRef::strided(&self.data[r0 * self.rs + c0..], nr, nc, self.rs)
    }
}

/// Mutable matrix view with row stride.
#[derive(Debug)]
pub struct MatMut<'a> {
    pub data: &'a mut [f32],
    pub rows: usize,
    pub cols: usize,
    pub rs: usize,
}

impl<'a> MatMut<'a> {
    pub fn new(data: &'a mut [f32], rows: usize, cols: usize) -> MatMut<'a> {
        MatMut::strided(data, rows, cols, cols)
    }

    pub fn strided(data: &'a mut [f32], rows: usize, cols: usize, rs: usize) -> MatMut<'a> {
        assert!(rs >= cols);
        if rows > 0 {
            assert!((rows - 1) * rs + cols <= data.len());
        }
        MatMut { data, rows, cols, rs }
    }

    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.rs + c]
    }

    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.rs + c] = v;
    }

    pub fn as_ref(&self) -> MatRef<'_> {
        MatRef {
            data: self.data,
            rows: self.rows,
            cols: self.cols,
            rs: self.rs,
        }
    }
}

/// Cache-blocking parameters. Tunable for the §Perf pass and the
/// `ablation_gemm` bench.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockSizes {
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
}

impl Default for BlockSizes {
    fn default() -> Self {
        // Sized for ~32 KB L1 / 256 KB-1 MB L2: A panel MC×KC ≈ 128 KB,
        // B panel KC×NC ≈ 512 KB.
        BlockSizes {
            mc: 128,
            kc: 256,
            nc: 512,
        }
    }
}

/// `C = A × B` (beta = 0), single-threaded, default blocking.
pub fn gemm(a: MatRef<'_>, b: MatRef<'_>, c: &mut MatMut<'_>) {
    gemm_ex(a, b, c, 1.0, 0.0, &Parallelism::inline(), BlockSizes::default());
}

/// `C = alpha·A×B + beta·C` with an explicit parallelism handle and
/// blocking.
///
/// Dimensions: A is m×k, B is k×n, C is m×n (all row-major views).
/// Parallelism: the M dimension is split across the handle's thread
/// budget (row panels are independent); each participant packs its own A
/// panels, B panels are packed once per (KC,NC) tile and shared
/// read-only. Loops too small to pay a pool wake-up run inline (grain
/// heuristic), with identical partitioning either way.
pub fn gemm_ex(
    a: MatRef<'_>,
    b: MatRef<'_>,
    c: &mut MatMut<'_>,
    alpha: f32,
    beta: f32,
    par: &Parallelism,
    bs: BlockSizes,
) {
    let (m, k) = (a.rows, a.cols);
    let n = b.cols;
    assert_eq!(b.rows, k, "gemm: A cols {k} != B rows {}", b.rows);
    assert_eq!(c.rows, m, "gemm: C rows");
    assert_eq!(c.cols, n, "gemm: C cols");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        scale_c(c, beta);
        return;
    }

    // Apply beta once up front so the micro-kernel can always accumulate.
    scale_c(c, beta);

    let crs = c.rs;
    // Parallel partitioning: threads write disjoint row panels of C,
    // rebuilt from a SharedSlice (see threadpool docs for the contract).
    let c_shared = crate::threadpool::SharedSlice::new(c.data);

    let row_panels: Vec<(usize, usize)> = split_ranges(m, par.threads());
    let nthreads = row_panels.len();

    // Pack B once per (pc, jc) tile, shared across row panels. To keep the
    // code lock-free we let each thread pack B redundantly only when
    // running multi-threaded would contend; measurement (§Perf) showed
    // per-thread packing of B is cheap relative to the FLOPs at the sizes
    // the conv layers produce, and it avoids a barrier.
    let panel_macs = m.div_ceil(nthreads) * k * n;
    par.parallel_for_macs(nthreads, panel_macs, |t| {
        let (r0, r1) = row_panels[t];
        if r0 == r1 {
            return;
        }
        // Rebuild this thread's disjoint C row panel.
        let c_data: &mut [f32] = c_shared.slice();
        let mut c_panel = MatMut::strided(
            &mut c_data[r0 * crs..],
            r1 - r0,
            n,
            crs,
        );
        let a_panel = a.sub(r0, r1 - r0, 0, k);
        gemm_serial(a_panel, b, &mut c_panel, alpha, bs);
    });
}

/// B packed once for reuse across many GEMM calls that share the same
/// right-hand side — MEC's exact situation: the kernel matrix K is
/// multiplied by `o_h` (Solution A) or `i_n·o_h` (Solution B)
/// overlapping partitions of L. Packing K per call cost ~2× on cv6-like
/// shapes (§Perf); packing once removes that entirely.
///
/// Layout: tiles in (pc, jc) loop order; tile (pc, jc) holds the
/// `kb × nb` block packed into nr-column strips for the recorded
/// [`KernelBackend`] (see [`pack::pack_b`]), each tile starting on a
/// 64-byte boundary.
#[derive(Debug, Clone)]
pub struct PackedB {
    pub k: usize,
    pub n: usize,
    pub bs: BlockSizes,
    backend: KernelBackend,
    data: AlignedVec<f32>,
    /// Start offset of each (pc-block, jc-block) tile.
    tile_offsets: Vec<usize>,
    n_blocks: usize,
}

impl PackedB {
    /// Pack the whole of B for the process-wide active backend.
    pub fn pack(b: MatRef<'_>, bs: BlockSizes) -> PackedB {
        Self::pack_with(b, bs, KernelBackend::active())
    }

    /// Pack the whole of B into `backend`-width strips. Consumers
    /// dispatch on [`backend()`](Self::backend), so buffer layout and
    /// kernel always agree — this is also how the equivalence tests
    /// force a specific backend without touching the environment.
    pub fn pack_with(b: MatRef<'_>, bs: BlockSizes, backend: KernelBackend) -> PackedB {
        let nr = backend.nr();
        let (k, n) = (b.rows, b.cols);
        let k_blocks = k.div_ceil(bs.kc).max(1);
        let n_blocks = n.div_ceil(bs.nc).max(1);
        let mut data = AlignedVec::new();
        let mut tile_offsets = Vec::with_capacity(k_blocks * n_blocks);
        for pb in 0..k_blocks {
            let pc = pb * bs.kc;
            let kb = bs.kc.min(k - pc);
            for jb in 0..n_blocks {
                let jc = jb * bs.nc;
                let nb = bs.nc.min(n - jc);
                // Keep every tile cache-line aligned, not just the base.
                let start = data.len().next_multiple_of(ALIGN / 4);
                tile_offsets.push(start);
                let tile_len = nb.div_ceil(nr) * kb * nr;
                data.resize(start + tile_len, 0.0);
                pack::pack_b(b.sub(pc, kb, jc, nb), &mut data[start..], nr);
            }
        }
        let _ = k_blocks; // implicit in tile_offsets length
        PackedB {
            k,
            n,
            bs,
            backend,
            data,
            tile_offsets,
            n_blocks,
        }
    }

    /// The kernel backend these strips were packed for.
    pub fn backend(&self) -> KernelBackend {
        self.backend
    }

    fn tile(&self, pb: usize, jb: usize) -> &[f32] {
        let idx = pb * self.n_blocks + jb;
        let start = self.tile_offsets[idx];
        let end = self
            .tile_offsets
            .get(idx + 1)
            .copied()
            .unwrap_or(self.data.len());
        let t = &self.data[start..end];
        debug_assert!(
            t.is_empty() || t.as_ptr() as usize % ALIGN == 0,
            "PackedB tile lost {ALIGN}-byte alignment"
        );
        t
    }

    /// Bytes held by the packed copy.
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// `C = A × pb` with B pre-packed (beta=0), serial. A's packing scratch
/// is a reused thread-local buffer — the serving hot path allocates
/// nothing here after warmup.
pub fn gemm_prepacked(a: MatRef<'_>, pb: &PackedB, c: &mut MatMut<'_>) {
    assert_eq!(a.cols, pb.k, "gemm_prepacked: A cols vs packed B rows");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, pb.n);
    scale_c(c, 0.0);
    gemm_serial_inner(a, BSource::Packed(pb), c, 1.0, pb.bs, pb.backend);
}

/// `C = A × pb + beta·C` with B pre-packed, serial — the accumulating
/// twin of [`gemm_prepacked`]. kn2row's shifted 1×1 products sum
/// directly into the output through this (beta=0 on the first kernel
/// position overwrites, beta=1 afterwards accumulates), which is what
/// lets that algorithm run with zero workspace.
pub fn gemm_prepacked_beta(a: MatRef<'_>, pb: &PackedB, c: &mut MatMut<'_>, beta: f32) {
    assert_eq!(a.cols, pb.k, "gemm_prepacked_beta: A cols vs packed B rows");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, pb.n);
    scale_c(c, beta);
    gemm_serial_inner(a, BSource::Packed(pb), c, 1.0, pb.bs, pb.backend);
}

/// `C = A × pb` with B pre-packed, parallelized over row panels of C —
/// the plan-execute path of im2col (one big GEMM, kernel matrix packed
/// once at plan time). Thread partitioning matches [`gemm_ex`] exactly
/// (same row panels, same tile walk), so results are bit-identical to
/// the raw-B path at any thread count.
pub fn gemm_prepacked_ex(a: MatRef<'_>, pb: &PackedB, c: &mut MatMut<'_>, par: &Parallelism) {
    assert_eq!(a.cols, pb.k, "gemm_prepacked_ex: A cols vs packed B rows");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, pb.n);
    if par.threads() <= 1 {
        gemm_prepacked(a, pb, c);
        return;
    }
    let (m, k) = (a.rows, a.cols);
    let n = pb.n;
    if m == 0 || n == 0 {
        return;
    }
    scale_c(c, 0.0);
    let crs = c.rs;
    let c_shared = crate::threadpool::SharedSlice::new(c.data);
    let row_panels: Vec<(usize, usize)> = split_ranges(m, par.threads());
    let nthreads = row_panels.len();
    let panel_macs = m.div_ceil(nthreads) * k * n;
    par.parallel_for_macs(nthreads, panel_macs, |t| {
        let (r0, r1) = row_panels[t];
        if r0 == r1 {
            return;
        }
        let c_data: &mut [f32] = c_shared.slice();
        let mut c_panel = MatMut::strided(&mut c_data[r0 * crs..], r1 - r0, n, crs);
        let a_panel = a.sub(r0, r1 - r0, 0, k);
        gemm_serial_inner(a_panel, BSource::Packed(pb), &mut c_panel, 1.0, pb.bs, pb.backend);
    });
}

/// Batched `C[i] = A[i] × pb` with the batch loop INSIDE the (pc, jc)
/// tile loops, so each packed-B tile is streamed from memory once and
/// reused (warm) across all batch entries.
///
/// §Perf iteration 3: MEC's Solution A issues `o_h` gemms whose A
/// matrices are tiny (`m = i_n·o_w`, e.g. 5 on cv12) while K is large
/// (9.4 MB on cv12) — per-gemm K traffic dominated. This fused order
/// cut cv12 from 9.5 ms to ~7 ms mobile. Serial by design (the mobile
/// platform); the threaded path parallelizes over batch entries instead.
pub fn gemm_prepacked_batch(a: &[MatRef<'_>], pb: &PackedB, c: &mut [MatMut<'_>]) {
    assert_eq!(a.len(), c.len());
    for (ai, ci) in a.iter().zip(c.iter_mut()) {
        assert_eq!(ai.cols, pb.k);
        assert_eq!(ci.rows, ai.rows);
        assert_eq!(ci.cols, pb.n);
        scale_c(ci, 0.0);
    }
    let bs = pb.bs;
    let k = pb.k;
    let n = pb.n;
    let backend = pb.backend;
    let nrw = backend.nr();
    SCRATCH.with(|scratch| {
        let mut guard = scratch.borrow_mut();
        let (packed_a, _) = &mut *guard;
        let max_m = a.iter().map(|x| x.rows).max().unwrap_or(0);
        let pa_len = bs.mc.min(max_m.max(1)).next_multiple_of(MR) * bs.kc.min(k);
        if packed_a.len() < pa_len {
            packed_a.resize(pa_len, 0.0);
        }
        let mut acc = [0.0f32; MR * NR_MAX];
        let mut pc = 0;
        let mut pb_idx = 0;
        while pc < k {
            let kb = bs.kc.min(k - pc);
            let mut jc = 0;
            let mut jb_idx = 0;
            while jc < n {
                let nb = bs.nc.min(n - jc);
                let b_tile = pb.tile(pb_idx, jb_idx);
                // Batch loop inside the tile: B tile stays cache-warm.
                for (ai, ci) in a.iter().zip(c.iter_mut()) {
                    let m = ai.rows;
                    let mut ic = 0;
                    while ic < m {
                        let mb = bs.mc.min(m - ic);
                        pack::pack_a(ai.sub(ic, mb, pc, kb), &mut packed_a[..]);
                        let mut jr = 0;
                        while jr < nb {
                            let nr = nrw.min(nb - jr);
                            let bp = &b_tile[(jr / nrw) * kb * nrw..(jr / nrw + 1) * kb * nrw];
                            let mut ir = 0;
                            while ir < mb {
                                let mr = MR.min(mb - ir);
                                let ap =
                                    &packed_a[(ir / MR) * kb * MR..(ir / MR + 1) * kb * MR];
                                if mr == MR {
                                    micro::kernel(backend, ap, bp, kb, &mut acc);
                                } else {
                                    micro::kernel_edge(backend, ap, bp, kb, &mut acc, mr);
                                }
                                for r in 0..mr {
                                    let crow = (ic + ir + r) * ci.rs + jc + jr;
                                    for col in 0..nr {
                                        ci.data[crow + col] += acc[r * nrw + col];
                                    }
                                }
                                ir += MR;
                            }
                            jr += nrw;
                        }
                        ic += bs.mc;
                    }
                }
                jc += bs.nc;
                jb_idx += 1;
            }
            pc += bs.kc;
            pb_idx += 1;
        }
    });
}

/// Serial blocked gemm over one row panel: C += alpha·A×B (beta already
/// applied by the caller). B is packed per (pc, jc) tile for the
/// process-wide active backend.
fn gemm_serial(a: MatRef<'_>, b: MatRef<'_>, c: &mut MatMut<'_>, alpha: f32, bs: BlockSizes) {
    gemm_serial_inner(a, BSource::Raw(b), c, alpha, bs, KernelBackend::active());
}

enum BSource<'a> {
    Raw(MatRef<'a>),
    Packed(&'a PackedB),
}

thread_local! {
    /// Reused packing scratch (A always; B when not prepacked), 64-byte
    /// aligned for the SIMD kernels.
    static SCRATCH: std::cell::RefCell<(AlignedVec<f32>, AlignedVec<f32>)> =
        const { std::cell::RefCell::new((AlignedVec::new(), AlignedVec::new())) };
}

fn gemm_serial_inner(
    a: MatRef<'_>,
    b: BSource<'_>,
    c: &mut MatMut<'_>,
    alpha: f32,
    bs: BlockSizes,
    backend: KernelBackend,
) {
    let (m, k) = (a.rows, a.cols);
    let n = c.cols;
    let nrw = backend.nr();
    SCRATCH.with(|scratch| {
        let mut guard = scratch.borrow_mut();
        let (packed_a, packed_b) = &mut *guard;
        let pa_len = bs.mc.min(m).next_multiple_of(MR) * bs.kc.min(k);
        if packed_a.len() < pa_len {
            packed_a.resize(pa_len, 0.0);
        }
        let pb_len = bs.kc.min(k) * bs.nc.min(n).next_multiple_of(nrw);
        if matches!(b, BSource::Raw(_)) && packed_b.len() < pb_len {
            packed_b.resize(pb_len, 0.0);
        }
        let mut acc = [0.0f32; MR * NR_MAX];

        let mut pc = 0;
        let mut pb_idx = 0;
        while pc < k {
            let kb = bs.kc.min(k - pc);
            let mut jc = 0;
            let mut jb_idx = 0;
            while jc < n {
                let nb = bs.nc.min(n - jc);
                let b_tile: &[f32] = match &b {
                    BSource::Raw(braw) => {
                        pack::pack_b(braw.sub(pc, kb, jc, nb), &mut packed_b[..], nrw);
                        &packed_b[..]
                    }
                    BSource::Packed(p) => p.tile(pb_idx, jb_idx),
                };
                let mut ic = 0;
                while ic < m {
                    let mb = bs.mc.min(m - ic);
                    pack::pack_a(a.sub(ic, mb, pc, kb), &mut packed_a[..]);
                    // Macro-kernel: packed A (mb×kb) times packed B (kb×nb).
                    // Packed layouts (see pack.rs): A strips of MR rows at
                    // offset (ir/MR)·kb·MR, B strips of nr cols at
                    // offset (jr/nr)·kb·nr; both zero-padded at the edges.
                    let mut jr = 0;
                    while jr < nb {
                        let nr = nrw.min(nb - jr);
                        let bp = &b_tile[(jr / nrw) * kb * nrw..(jr / nrw + 1) * kb * nrw];
                        let mut ir = 0;
                        while ir < mb {
                            let mr = MR.min(mb - ir);
                            let ap = &packed_a[(ir / MR) * kb * MR..(ir / MR + 1) * kb * MR];
                            if mr == MR {
                                micro::kernel(backend, ap, bp, kb, &mut acc);
                            } else {
                                micro::kernel_edge(backend, ap, bp, kb, &mut acc, mr);
                            }
                            // Accumulate into C with alpha.
                            for r in 0..mr {
                                let crow = (ic + ir + r) * c.rs + jc + jr;
                                for col in 0..nr {
                                    c.data[crow + col] += alpha * acc[r * nrw + col];
                                }
                            }
                            ir += MR;
                        }
                        jr += nrw;
                    }
                    ic += bs.mc;
                }
                jc += bs.nc;
                jb_idx += 1;
            }
            pc += bs.kc;
            pb_idx += 1;
        }
    });
}

fn scale_c(c: &mut MatMut<'_>, beta: f32) {
    if beta == 1.0 {
        return;
    }
    for r in 0..c.rows {
        let row = &mut c.data[r * c.rs..r * c.rs + c.cols];
        if beta == 0.0 {
            row.fill(0.0);
        } else {
            for v in row.iter_mut() {
                *v *= beta;
            }
        }
    }
}

/// Split `0..n` into at most `parts` contiguous ranges of near-equal size.
pub fn split_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.max(1).min(n.max(1));
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Reference triple-loop gemm (used by tests to validate the blocked one).
pub fn gemm_reference(a: MatRef<'_>, b: MatRef<'_>, c: &mut MatMut<'_>, alpha: f32, beta: f32) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut s = 0f32;
            for p in 0..a.cols {
                s += a.at(i, p) * b.at(p, j);
            }
            let v = alpha * s + beta * c.at(i, j);
            c.set(i, j, v);
        }
    }
}

/// Batched gemm: `C[i] = A[i] × B` for a shared B — the shape MEC's
/// Solution B needs (`i_n·o_h` small gemms against the same kernel matrix,
/// paper's `cublasSgemmBatched` note in §4). Parallelized over the batch.
pub fn gemm_batched_shared_b(
    a: &[MatRef<'_>],
    b: MatRef<'_>,
    c: &mut [MatMut<'_>],
    par: &Parallelism,
    bs: BlockSizes,
) {
    assert_eq!(a.len(), c.len());
    let n = a.len();
    // Each batch entry is independent; parallelize across entries and run
    // each gemm serially inside (small inputs — matches the paper's GPU
    // batched-gemm trade-off discussion, §3.3 Solution B).
    let c_cell: Vec<SendPtr> = c.iter_mut().map(|m| SendPtr(m.data.as_mut_ptr())).collect();
    let metas: Vec<(usize, usize, usize, usize)> = c
        .iter()
        .map(|m| (m.rows, m.cols, m.rs, m.data.len()))
        .collect();
    let entry_macs = a
        .iter()
        .map(|ai| ai.rows * ai.cols * b.cols)
        .max()
        .unwrap_or(0);
    par.parallel_for_macs(n, entry_macs, |i| {
        scale_and_mul(a[i], b, &c_cell[i], metas[i], bs);
    });
}

fn scale_and_mul(
    a: MatRef<'_>,
    b: MatRef<'_>,
    cptr: &SendPtr,
    meta: (usize, usize, usize, usize),
    bs: BlockSizes,
) {
    let (rows, cols, rs, len) = meta;
    // SAFETY: `cptr` was taken from a live `MatMut` whose buffer holds
    // exactly `len` f32s (meta carries that matrix's own dimensions), and
    // `gemm_batch` hands each batch entry's pointer to exactly one
    // `parallel_for_macs` task — no aliasing across workers; the scoped
    // dispatch keeps the borrowed `c` slice alive until every task joins.
    let data: &mut [f32] = unsafe { std::slice::from_raw_parts_mut(cptr.0, len) };
    let mut c = MatMut::strided(data, rows, cols, rs);
    scale_c(&mut c, 0.0);
    gemm_serial(a, b, &mut c, 1.0, bs);
}

/// Raw pointer wrapper that asserts Send; used to hand disjoint C panels to
/// scoped worker threads.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: all call sites partition C into non-overlapping row ranges or
// distinct batch entries, so no two threads ever touch the same element,
// and the scoped dispatch joins every worker before the borrow ends.
unsafe impl Send for SendPtr {}
// SAFETY: as above — a shared `&SendPtr` only ever copies the pointer
// value out; element access stays partitioned per worker.
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{assert_allclose, Rng};

    fn random_mat(rng: &mut Rng, rows: usize, cols: usize) -> Vec<f32> {
        let mut v = vec![0.0; rows * cols];
        rng.fill_uniform(&mut v, -1.0, 1.0);
        v
    }

    fn check_blocked_vs_reference(m: usize, k: usize, n: usize, threads: usize, bs: BlockSizes) {
        let mut rng = Rng::new((m * 1000 + k * 100 + n) as u64);
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let mut c1 = vec![0.5; m * n]; // non-zero to exercise beta=0 reset
        let mut c2 = vec![0.5; m * n];
        gemm_ex(
            MatRef::new(&a, m, k),
            MatRef::new(&b, k, n),
            &mut MatMut::new(&mut c1, m, n),
            1.0,
            0.0,
            &Parallelism::new(threads),
            bs,
        );
        gemm_reference(
            MatRef::new(&a, m, k),
            MatRef::new(&b, k, n),
            &mut MatMut::new(&mut c2, m, n),
            1.0,
            0.0,
        );
        assert_allclose(&c1, &c2, 1e-4, &format!("gemm {m}x{k}x{n} t={threads}"));
    }

    #[test]
    fn blocked_matches_reference_small() {
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (8, 8, 8), (17, 13, 9), (5, 64, 3)] {
            check_blocked_vs_reference(m, k, n, 1, BlockSizes::default());
        }
    }

    #[test]
    fn blocked_matches_reference_odd_blocking() {
        // Block sizes smaller than the matrix force all edge paths.
        let bs = BlockSizes { mc: 5, kc: 7, nc: 6 };
        for (m, k, n) in [(11, 15, 13), (24, 21, 19), (8, 7, 33)] {
            check_blocked_vs_reference(m, k, n, 1, bs);
        }
    }

    #[test]
    fn blocked_matches_reference_threaded() {
        check_blocked_vs_reference(64, 48, 32, 4, BlockSizes::default());
        check_blocked_vs_reference(33, 17, 29, 3, BlockSizes { mc: 8, kc: 8, nc: 8 });
    }

    #[test]
    fn strided_views_work() {
        // A is a sub-matrix of a bigger buffer (the MEC ld trick).
        let mut rng = Rng::new(99);
        let big = random_mat(&mut rng, 10, 20);
        let a = MatRef::strided(&big[3..], 6, 7, 20); // 6x7 view at col 3
        let b = random_mat(&mut rng, 7, 4);
        let mut c1 = vec![0.0; 6 * 4];
        let mut c2 = vec![0.0; 6 * 4];
        gemm(a, MatRef::new(&b, 7, 4), &mut MatMut::new(&mut c1, 6, 4));
        gemm_reference(
            a,
            MatRef::new(&b, 7, 4),
            &mut MatMut::new(&mut c2, 6, 4),
            1.0,
            0.0,
        );
        assert_allclose(&c1, &c2, 1e-4, "strided gemm");
    }

    #[test]
    fn alpha_beta_semantics() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [1.0f32, 0.0, 0.0, 1.0];
        let mut c = [10.0f32, 20.0, 30.0, 40.0];
        // C = 2*A*I + 0.5*C
        gemm_ex(
            MatRef::new(&a, 2, 2),
            MatRef::new(&b, 2, 2),
            &mut MatMut::new(&mut c, 2, 2),
            2.0,
            0.5,
            &Parallelism::inline(),
            BlockSizes::default(),
        );
        assert_eq!(c, [7.0, 14.0, 21.0, 28.0]);
    }

    #[test]
    fn batched_shared_b_matches_serial() {
        let mut rng = Rng::new(7);
        let b = random_mat(&mut rng, 9, 4);
        let bref = MatRef::new(&b, 9, 4);
        let a_bufs: Vec<Vec<f32>> = (0..6).map(|_| random_mat(&mut rng, 5, 9)).collect();
        let mut c_bufs: Vec<Vec<f32>> = (0..6).map(|_| vec![1.0; 5 * 4]).collect();
        let mut expected: Vec<Vec<f32>> = Vec::new();
        for abuf in &a_bufs {
            let mut c = vec![0.0; 5 * 4];
            gemm_reference(
                MatRef::new(abuf, 5, 9),
                bref,
                &mut MatMut::new(&mut c, 5, 4),
                1.0,
                0.0,
            );
            expected.push(c);
        }
        {
            let a_refs: Vec<MatRef<'_>> = a_bufs.iter().map(|v| MatRef::new(v, 5, 9)).collect();
            let mut c_refs: Vec<MatMut<'_>> =
                c_bufs.iter_mut().map(|v| MatMut::new(v, 5, 4)).collect();
            gemm_batched_shared_b(
                &a_refs,
                bref,
                &mut c_refs,
                &Parallelism::new(3),
                BlockSizes::default(),
            );
        }
        for (got, want) in c_bufs.iter().zip(&expected) {
            assert_allclose(got, want, 1e-4, "batched");
        }
    }

    #[test]
    fn prepacked_ex_matches_raw_gemm_bitwise() {
        // The plan path (PackedB once, threaded execute) must be
        // bit-identical to the one-shot raw-B path at any thread count.
        let mut rng = Rng::new(123);
        let (m, k, n) = (37, 29, 21);
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let bs = BlockSizes { mc: 16, kc: 8, nc: 12 };
        let mut want = vec![0.0; m * n];
        gemm_ex(
            MatRef::new(&a, m, k),
            MatRef::new(&b, k, n),
            &mut MatMut::new(&mut want, m, n),
            1.0,
            0.0,
            &Parallelism::inline(),
            bs,
        );
        let pb = PackedB::pack(MatRef::new(&b, k, n), bs);
        for threads in [1usize, 3, 8] {
            let mut got = vec![0.5; m * n];
            gemm_prepacked_ex(
                MatRef::new(&a, m, k),
                &pb,
                &mut MatMut::new(&mut got, m, n),
                &Parallelism::new(threads),
            );
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn split_ranges_partition() {
        for (n, p) in [(10, 3), (7, 7), (5, 9), (0, 4), (100, 1)] {
            let rs = split_ranges(n, p);
            let mut covered = 0;
            let mut prev_end = 0;
            for &(s, e) in &rs {
                assert_eq!(s, prev_end);
                covered += e - s;
                prev_end = e;
            }
            assert_eq!(covered, n, "n={n} p={p}");
        }
    }

    #[test]
    fn k_zero_applies_beta() {
        let a: [f32; 0] = [];
        let b: [f32; 0] = [];
        let mut c = [3.0f32, 3.0];
        gemm_ex(
            MatRef::new(&a, 2, 0),
            MatRef::new(&b, 0, 1),
            &mut MatMut::new(&mut c, 2, 1),
            1.0,
            0.0,
            &Parallelism::inline(),
            BlockSizes::default(),
        );
        assert_eq!(c, [0.0, 0.0]);
    }
}
