//! Panel packing for the blocked GEMM.
//!
//! Packing copies a cache-block of A/B into a contiguous, micro-kernel-
//! friendly layout once per block, so the O(m·n·k) inner loops touch only
//! unit-stride memory. Crucially for MEC, packing reads *strided* views —
//! this is where the BLAS `ld` trick (overlapping partitions of the
//! lowered matrix L, paper §3.2) meets the hardware.
//!
//! A strips are always MR rows (shared by every kernel backend); B strip
//! width is the dispatching backend's `nr` (8, or 16 on AVX-512), passed
//! explicitly so a packed buffer and the kernel that consumes it always
//! agree.

use super::micro::MR;
use super::MatRef;

/// Pack an A block (`mb × kb`, arbitrary row stride) into strips of MR
/// rows: strip `i` occupies `kb·MR` floats at offset `i·kb·MR`, laid out
/// k-major (`[k][r]`), zero-padded when `mb % MR != 0`.
pub fn pack_a(a: MatRef<'_>, out: &mut [f32]) {
    let (mb, kb) = (a.rows, a.cols);
    let strips = mb.div_ceil(MR);
    assert!(out.len() >= strips * kb * MR, "pack_a buffer too small");
    for s in 0..strips {
        let r0 = s * MR;
        let rows = MR.min(mb - r0);
        let dst = &mut out[s * kb * MR..(s + 1) * kb * MR];
        if rows == MR {
            for k in 0..kb {
                let d = &mut dst[k * MR..k * MR + MR];
                for r in 0..MR {
                    d[r] = a.data[(r0 + r) * a.rs + k];
                }
            }
        } else {
            for k in 0..kb {
                let d = &mut dst[k * MR..k * MR + MR];
                for (r, slot) in d.iter_mut().enumerate() {
                    *slot = if r < rows { a.data[(r0 + r) * a.rs + k] } else { 0.0 };
                }
            }
        }
    }
}

/// Pack a B block (`kb × nb`) into strips of `nr` columns: strip `j`
/// occupies `kb·nr` floats at offset `j·kb·nr`, laid out k-major
/// (`[k][c]`), zero-padded when `nb % nr != 0`. `nr` is the consuming
/// backend's strip width ([`KernelBackend::nr`](super::KernelBackend::nr)).
pub fn pack_b(b: MatRef<'_>, out: &mut [f32], nr: usize) {
    let (kb, nb) = (b.rows, b.cols);
    let strips = nb.div_ceil(nr);
    assert!(out.len() >= strips * kb * nr, "pack_b buffer too small");
    for s in 0..strips {
        let c0 = s * nr;
        let cols = nr.min(nb - c0);
        let dst = &mut out[s * kb * nr..(s + 1) * kb * nr];
        if cols == nr {
            for k in 0..kb {
                let src = &b.data[k * b.rs + c0..k * b.rs + c0 + nr];
                dst[k * nr..k * nr + nr].copy_from_slice(src);
            }
        } else {
            for k in 0..kb {
                let d = &mut dst[k * nr..k * nr + nr];
                for (c, slot) in d.iter_mut().enumerate() {
                    *slot = if c < cols { b.data[k * b.rs + c0 + c] } else { 0.0 };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const NR: usize = 8;

    #[test]
    fn pack_a_layout_and_padding() {
        // 3x2 matrix inside a wider buffer (rs=4).
        let buf: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let a = MatRef::strided(&buf, 3, 2, 4);
        let mut out = vec![-1.0; MR * 2];
        pack_a(a, &mut out);
        // k=0 column: rows 0..3 = buf[0], buf[4], buf[8], pad zeros.
        assert_eq!(&out[0..MR], &[0.0, 4.0, 8.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        // k=1 column: buf[1], buf[5], buf[9].
        assert_eq!(&out[MR..2 * MR], &[1.0, 5.0, 9.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn pack_b_layout_and_padding() {
        // 2x3 matrix, strided.
        let buf: Vec<f32> = (0..10).map(|x| x as f32).collect();
        let b = MatRef::strided(&buf, 2, 3, 5);
        let mut out = vec![-1.0; 2 * NR];
        pack_b(b, &mut out, NR);
        // k=0 row: 0,1,2 then zero pad.
        assert_eq!(&out[0..NR], &[0.0, 1.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        // k=1 row: 5,6,7.
        assert_eq!(&out[NR..2 * NR], &[5.0, 6.0, 7.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn pack_b_wide_strip() {
        // nr=16 (the AVX-512 width): one strip, zero-padded past col 2.
        let buf: Vec<f32> = (0..8).map(|x| x as f32).collect();
        let b = MatRef::new(&buf, 4, 2);
        let mut out = vec![-1.0; 4 * 16];
        pack_b(b, &mut out, 16);
        assert_eq!(&out[0..3], &[0.0, 1.0, 0.0]);
        assert!(out[2..16].iter().all(|&v| v == 0.0));
        assert_eq!(&out[16..18], &[2.0, 3.0]);
    }

    #[test]
    fn pack_a_multiple_strips() {
        let rows = MR + 3;
        let buf: Vec<f32> = (0..rows * 2).map(|x| x as f32).collect();
        let a = MatRef::new(&buf, rows, 2);
        let mut out = vec![0.0; 2 * 2 * MR];
        pack_a(a, &mut out);
        // Strip 1, k=0, r=0 is row MR, col 0 => buf[MR*2].
        assert_eq!(out[2 * MR], (MR * 2) as f32);
    }
}
