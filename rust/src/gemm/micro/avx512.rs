//! AVX-512 micro-kernels: 8×16 f32 tiles on `_mm512_fmadd_ps`, 8×16 Q15
//! tiles on `_mm256_mulhrs_epi16` widened through `_mm512_cvtepi16_epi32`.
//!
//! Compiled only under the `mec_avx512` cfg (build.rs: rustc ≥ 1.89,
//! where the 512-bit intrinsics are stable). The wider 16-column strip
//! halves the number of B loads per FLOP relative to AVX2 and doubles
//! the accumulator tile to 8 zmm registers — still well inside the 32
//! architectural registers.

use super::{MR, NR_MAX};

use std::arch::x86_64::*;

/// Strip width of the AVX-512 backend (`KernelBackend::Avx512.nr()`).
const NR: usize = 16;

/// First `mr` rows of the 8×16 f32 tile; rows at stride `NR` in `acc`.
///
/// # Safety
/// The CPU must support AVX-512F and AVX-512BW
/// (`KernelBackend::Avx512.available()`).
#[target_feature(enable = "avx2,avx512f,avx512bw")]
pub unsafe fn kernel_f32(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR_MAX], mr: usize) {
    match mr {
        1 => rows_f32::<1>(ap, bp, kb, acc),
        2 => rows_f32::<2>(ap, bp, kb, acc),
        3 => rows_f32::<3>(ap, bp, kb, acc),
        4 => rows_f32::<4>(ap, bp, kb, acc),
        5 => rows_f32::<5>(ap, bp, kb, acc),
        6 => rows_f32::<6>(ap, bp, kb, acc),
        7 => rows_f32::<7>(ap, bp, kb, acc),
        _ => rows_f32::<MR>(ap, bp, kb, acc),
    }
}

#[inline(always)]
unsafe fn rows_f32<const R: usize>(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR_MAX]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    let mut c = [_mm512_setzero_ps(); R];
    let a = ap.as_ptr();
    let b = bp.as_ptr();
    for k in 0..kb {
        let bv = _mm512_loadu_ps(b.add(k * NR));
        for r in 0..R {
            let av = _mm512_set1_ps(*a.add(k * MR + r));
            c[r] = _mm512_fmadd_ps(av, bv, c[r]);
        }
    }
    for (r, &v) in c.iter().enumerate() {
        _mm512_storeu_ps(acc.as_mut_ptr().add(r * NR), v);
    }
}

/// First `mr` rows of the 8×16 Q15 tile; rows at stride `NR` in `acc`.
///
/// # Safety
/// The CPU must support AVX-512F and AVX-512BW
/// (`KernelBackend::Avx512.available()`).
#[target_feature(enable = "avx2,avx512f,avx512bw")]
pub unsafe fn kernel_i16(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR_MAX], mr: usize) {
    match mr {
        1 => rows_i16::<1>(ap, bp, kb, acc),
        2 => rows_i16::<2>(ap, bp, kb, acc),
        3 => rows_i16::<3>(ap, bp, kb, acc),
        4 => rows_i16::<4>(ap, bp, kb, acc),
        5 => rows_i16::<5>(ap, bp, kb, acc),
        6 => rows_i16::<6>(ap, bp, kb, acc),
        7 => rows_i16::<7>(ap, bp, kb, acc),
        _ => rows_i16::<MR>(ap, bp, kb, acc),
    }
}

#[inline(always)]
unsafe fn rows_i16<const R: usize>(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR_MAX]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    let mut c = [_mm512_setzero_si512(); R];
    let a = ap.as_ptr();
    let b = bp.as_ptr();
    for k in 0..kb {
        let bv = _mm256_loadu_si256(b.add(k * NR) as *const __m256i);
        for r in 0..R {
            let av = _mm256_set1_epi16(*a.add(k * MR + r));
            // 16 rounded Q15 products (AVX2 mulhrs), widened to one zmm
            // of i32 lanes (AVX-512F) and accumulated.
            let p = _mm256_mulhrs_epi16(av, bv);
            c[r] = _mm512_add_epi32(c[r], _mm512_cvtepi16_epi32(p));
        }
    }
    for (r, &v) in c.iter().enumerate() {
        _mm512_storeu_si512(acc.as_mut_ptr().add(r * NR) as *mut __m512i, v);
    }
}
