//! AVX-512 micro-kernels: 8×16 f32 tiles on `_mm512_fmadd_ps`, 8×16 Q15
//! tiles on `_mm256_mulhrs_epi16` widened through `_mm512_cvtepi16_epi32`.
//!
//! Compiled only under the `mec_avx512` cfg (build.rs: rustc ≥ 1.89,
//! where the 512-bit intrinsics are stable). The wider 16-column strip
//! halves the number of B loads per FLOP relative to AVX2 and doubles
//! the accumulator tile to 8 zmm registers — still well inside the 32
//! architectural registers.

use super::{MR, NR_MAX};

use std::arch::x86_64::*;

/// Strip width of the AVX-512 backend (`KernelBackend::Avx512.nr()`).
const NR: usize = 16;

/// First `mr` rows of the 8×16 f32 tile; rows at stride `NR` in `acc`.
///
/// # Safety
/// The CPU must support AVX-512F and AVX-512BW
/// (`KernelBackend::Avx512.available()`).
#[target_feature(enable = "avx2,avx512f,avx512bw")]
pub unsafe fn kernel_f32(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR_MAX], mr: usize) {
    // SAFETY: `rows_f32` is `#[inline(always)]`, so its intrinsics compile
    // inside this fn's AVX-512 window; its bounds requirements (`ap` ≥
    // kb·MR, `bp` ≥ kb·NR) are exactly this fn's own documented contract.
    unsafe {
        match mr {
            1 => rows_f32::<1>(ap, bp, kb, acc),
            2 => rows_f32::<2>(ap, bp, kb, acc),
            3 => rows_f32::<3>(ap, bp, kb, acc),
            4 => rows_f32::<4>(ap, bp, kb, acc),
            5 => rows_f32::<5>(ap, bp, kb, acc),
            6 => rows_f32::<6>(ap, bp, kb, acc),
            7 => rows_f32::<7>(ap, bp, kb, acc),
            _ => rows_f32::<MR>(ap, bp, kb, acc),
        }
    }
}

/// # Safety
/// Caller must have AVX-512F/BW enabled and `ap`/`bp` packed as
/// documented on [`kernel_f32`].
#[inline(always)]
unsafe fn rows_f32<const R: usize>(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR_MAX]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    // SAFETY: register-only zeroing; the feature window comes from the
    // `#[target_feature]` caller this fn is always inlined into.
    let mut c = [unsafe { _mm512_setzero_ps() }; R];
    let a = ap.as_ptr();
    let b = bp.as_ptr();
    for k in 0..kb {
        // SAFETY: k < kb and `bp` holds kb strips of NR floats
        // (debug-asserted above), so the unaligned 16-lane load reads
        // b[k·NR .. k·NR+16] fully in bounds.
        let bv = unsafe { _mm512_loadu_ps(b.add(k * NR)) };
        for r in 0..R {
            // SAFETY: r < R ≤ MR and k < kb, and `ap` holds kb columns of
            // MR floats, so a + k·MR + r points at a readable f32.
            let av = unsafe { _mm512_set1_ps(*a.add(k * MR + r)) };
            // SAFETY: FMA on register operands only.
            c[r] = unsafe { _mm512_fmadd_ps(av, bv, c[r]) };
        }
    }
    for (r, &v) in c.iter().enumerate() {
        // SAFETY: r ≤ MR−1 and NR == NR_MAX, so the 16-lane store ends at
        // r·NR + 16 ≤ (MR−1)·NR + NR = MR·NR_MAX, inside `acc`.
        unsafe { _mm512_storeu_ps(acc.as_mut_ptr().add(r * NR), v) };
    }
}

/// First `mr` rows of the 8×16 Q15 tile; rows at stride `NR` in `acc`.
///
/// # Safety
/// The CPU must support AVX-512F and AVX-512BW
/// (`KernelBackend::Avx512.available()`).
#[target_feature(enable = "avx2,avx512f,avx512bw")]
pub unsafe fn kernel_i16(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR_MAX], mr: usize) {
    // SAFETY: `rows_i16` is `#[inline(always)]`, so its intrinsics compile
    // inside this fn's AVX2+AVX-512 window; its bounds requirements are
    // exactly this fn's own documented contract.
    unsafe {
        match mr {
            1 => rows_i16::<1>(ap, bp, kb, acc),
            2 => rows_i16::<2>(ap, bp, kb, acc),
            3 => rows_i16::<3>(ap, bp, kb, acc),
            4 => rows_i16::<4>(ap, bp, kb, acc),
            5 => rows_i16::<5>(ap, bp, kb, acc),
            6 => rows_i16::<6>(ap, bp, kb, acc),
            7 => rows_i16::<7>(ap, bp, kb, acc),
            _ => rows_i16::<MR>(ap, bp, kb, acc),
        }
    }
}

/// # Safety
/// Caller must have AVX2 and AVX-512F/BW enabled and `ap`/`bp` packed as
/// documented on [`kernel_i16`].
#[inline(always)]
unsafe fn rows_i16<const R: usize>(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR_MAX]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    // SAFETY: register-only zeroing inside the caller's AVX-512 window.
    let mut c = [unsafe { _mm512_setzero_si512() }; R];
    let a = ap.as_ptr();
    let b = bp.as_ptr();
    for k in 0..kb {
        // SAFETY: k < kb and `bp` holds kb strips of NR i16s
        // (debug-asserted above), so the unaligned 32-byte load reads
        // b[k·NR .. k·NR+16] fully in bounds.
        let bv = unsafe { _mm256_loadu_si256(b.add(k * NR) as *const __m256i) };
        for r in 0..R {
            // SAFETY: r < R ≤ MR and k < kb, and `ap` holds kb columns of
            // MR i16s, so a + k·MR + r points at a readable i16.
            let av = unsafe { _mm256_set1_epi16(*a.add(k * MR + r)) };
            // 16 rounded Q15 products (AVX2 mulhrs), widened to one zmm
            // of i32 lanes (AVX-512F) and accumulated.
            // SAFETY: register-only arithmetic.
            let p = unsafe { _mm256_mulhrs_epi16(av, bv) };
            // SAFETY: register-only arithmetic (widen + add).
            c[r] = unsafe { _mm512_add_epi32(c[r], _mm512_cvtepi16_epi32(p)) };
        }
    }
    for (r, &v) in c.iter().enumerate() {
        // SAFETY: r ≤ MR−1 and NR == NR_MAX, so the 16-lane i32 store ends
        // at r·NR + 16 ≤ (MR−1)·NR + NR = MR·NR_MAX, inside `acc`.
        unsafe { _mm512_storeu_si512(acc.as_mut_ptr().add(r * NR) as *mut __m512i, v) };
    }
}
