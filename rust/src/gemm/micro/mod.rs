//! The MR×nr register micro-kernel, behind runtime CPU-feature dispatch.
//!
//! This is the single hottest loop in the repository — every convolution
//! algorithm except `direct` funnels >95% of its FLOPs through here — so
//! it is the one place the crate drops to explicit `std::arch` SIMD. The
//! paper's speedup claims (§5, Tables 3–4) assume a BLAS-quality sgemm
//! underneath the compact lowering; autovectorized scalar code leaves
//! that headroom on the table.
//!
//! # Backends
//!
//! * [`scalar`] — the portable const-generic kernels (LLVM autovectorizes
//!   the NR-wide inner loop). Always compiled, always available; the
//!   reference the other backends are tested against.
//! * [`avx2`] — 8×8 f32 FMA tile (`_mm256_fmadd_ps`) and an i16 tile on
//!   `_mm_mulhrs_epi16`, whose hardware rounded-Q15 multiply is bitwise
//!   the scalar `(a·b + 2¹⁴) >> 15`.
//! * [`avx512`] — 8×16 tiles on 512-bit vectors. Compiled only when the
//!   build script detects rustc ≥ 1.89 (stable `_mm512_*` intrinsics);
//!   gated by the `mec_avx512` cfg.
//! * [`neon`] — aarch64 8×8 tiles (`vfmaq_f32`, `vqrdmulhq_s16`).
//!
//! All backends share `MR = 8` rows, so the A-packing layout is
//! backend-independent; only the B strip width `nr` varies (16 on
//! AVX-512, 8 elsewhere). Accumulator tiles are `MR × NR_MAX` arrays and
//! row `r` of a backend's result lives at `acc[r * backend.nr() ..]`.
//!
//! # Selection
//!
//! [`KernelBackend::active`] detects the best backend once per process
//! (`is_x86_feature_detected!` / `is_aarch64_feature_detected!`), honors
//! a `MEC_KERNEL=scalar|avx2|avx512|neon` override (falling back with a
//! warning if the named backend is unavailable), and caches the result.
//! Packed-B buffers record the backend they were packed for, so a plan's
//! GEMMs always run the kernel matching their strip layout.
//!
//! The i16 kernels compute `acc[r][c] = Σ_k (ap·bp + 2¹⁴) >> 15` — each
//! widened product is rounded-shifted back into Q15 before i32
//! accumulation (overflow-proof for K ≤ 2¹⁵; the packers assert it). The
//! quantizer never produces −32768 (`QParams::QMAX` clamp), which is the
//! one input where `mulhrs`/`vqrdmulh` and the scalar shift disagree, so
//! every backend is bitwise-identical on reachable inputs.

use std::fmt;
use std::sync::OnceLock;

mod scalar;

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
mod avx2;

#[cfg(all(target_arch = "x86_64", mec_avx512))]
mod avx512;

#[cfg(target_arch = "aarch64")]
mod neon;

/// Rows per micro-tile — shared by every backend so packed-A strips are
/// backend-independent.
pub const MR: usize = 8;

/// Widest `nr` of any backend; accumulator tiles are sized `MR × NR_MAX`
/// so one stack array serves every dispatch target.
pub const NR_MAX: usize = 16;

/// A compiled-in micro-kernel implementation, selected at plan time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelBackend {
    /// Portable autovectorized kernels — always available.
    Scalar,
    /// x86-64 AVX2 + FMA, 8×8 tiles.
    Avx2,
    /// x86-64 AVX-512F/BW, 8×16 tiles (needs rustc ≥ 1.89 at build time).
    Avx512,
    /// aarch64 NEON, 8×8 tiles.
    Neon,
}

impl KernelBackend {
    /// All variants, best-first (detection order).
    const PREFERENCE: [KernelBackend; 4] = [
        KernelBackend::Avx512,
        KernelBackend::Avx2,
        KernelBackend::Neon,
        KernelBackend::Scalar,
    ];

    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Avx2 => "avx2",
            KernelBackend::Avx512 => "avx512",
            KernelBackend::Neon => "neon",
        }
    }

    /// Case-insensitive name lookup (env `MEC_KERNEL`).
    pub fn parse(s: &str) -> Option<KernelBackend> {
        Some(match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => KernelBackend::Scalar,
            "avx2" => KernelBackend::Avx2,
            "avx512" => KernelBackend::Avx512,
            "neon" => KernelBackend::Neon,
            _ => return None,
        })
    }

    /// Rows per micro-tile (identical across backends).
    pub fn mr(self) -> usize {
        MR
    }

    /// Columns per micro-tile: the B-strip width this backend packs and
    /// the accumulator row stride it writes.
    pub fn nr(self) -> usize {
        match self {
            KernelBackend::Avx512 => 16,
            _ => 8,
        }
    }

    /// Whether this backend is both compiled into the binary and
    /// supported by the CPU we are running on.
    ///
    /// Under Miri only [`Scalar`](Self::Scalar) reports available:
    /// `is_x86_feature_detected!` is unsupported by the interpreter, and
    /// the `std::arch` intrinsic bodies could not be executed anyway —
    /// the `cargo +nightly miri test` leg checks the portable kernels
    /// plus all the surrounding unsafe plumbing (packing, arenas,
    /// threadpool) with the scalar backend forced here.
    pub fn available(self) -> bool {
        #[cfg(miri)]
        {
            return self == KernelBackend::Scalar;
        }
        #[cfg_attr(miri, allow(unreachable_code))]
        match self {
            KernelBackend::Scalar => true,
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            KernelBackend::Avx2 => {
                is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
            }
            #[cfg(all(target_arch = "x86_64", mec_avx512))]
            KernelBackend::Avx512 => {
                is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512bw")
            }
            #[cfg(target_arch = "aarch64")]
            KernelBackend::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            // Variants not compiled for this target/toolchain.
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Best backend the host supports (no env override).
    pub fn detect() -> KernelBackend {
        for b in Self::PREFERENCE {
            if b.available() {
                return b;
            }
        }
        KernelBackend::Scalar
    }

    /// The process-wide backend: `MEC_KERNEL` override if set and
    /// available (a warning is printed and detection takes over if not),
    /// otherwise [`detect`](Self::detect). Resolved once and cached —
    /// plans built at different times agree on strip layout.
    pub fn active() -> KernelBackend {
        static ACTIVE: OnceLock<KernelBackend> = OnceLock::new();
        *ACTIVE.get_or_init(|| {
            if let Ok(v) = std::env::var("MEC_KERNEL") {
                match KernelBackend::parse(&v) {
                    Some(b) if b.available() => return b,
                    Some(b) => eprintln!(
                        "mec: MEC_KERNEL={} is not available on this host/build; \
                         falling back to {}",
                        b.name(),
                        KernelBackend::detect().name()
                    ),
                    None => eprintln!(
                        "mec: MEC_KERNEL={v:?} is not one of scalar|avx2|avx512|neon; \
                         falling back to {}",
                        KernelBackend::detect().name()
                    ),
                }
            }
            KernelBackend::detect()
        })
    }

    /// Every backend the host can run — what the cross-backend
    /// equivalence suite iterates. Always contains [`Scalar`](Self::Scalar).
    pub fn all_available() -> Vec<KernelBackend> {
        Self::PREFERENCE
            .into_iter()
            .filter(|b| b.available())
            .collect()
    }
}

impl fmt::Display for KernelBackend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Compute the full-height tile:
/// `acc[r·nr + c] = Σ_k ap[k·MR + r] · bp[k·nr + c]` with
/// `nr = backend.nr()`.
///
/// * `ap`: packed A strip, `kb·MR` floats, column-of-strip major.
/// * `bp`: packed B strip, `kb·nr` floats, row-of-strip major — packed
///   for the **same** backend (see [`pack_b`](super::pack::pack_b)).
/// * The caller adds `acc` into C (applying alpha and edge masking).
///
/// `backend` must be [`available`](KernelBackend::available) — callers
/// get it from [`KernelBackend::active`] or a packed buffer that
/// recorded it (debug builds assert).
#[inline(always)]
pub fn kernel(
    backend: KernelBackend,
    ap: &[f32],
    bp: &[f32],
    kb: usize,
    acc: &mut [f32; MR * NR_MAX],
) {
    kernel_edge(backend, ap, bp, kb, acc, MR);
}

/// Edge variant of [`kernel`]: compute only the first `mr` rows. MEC's
/// Solution A/B gemms have `m = o_w` (often 5–14, paper Table 2), so the
/// MR-strip tail is a large fraction of the work — computing padded rows
/// cost ~35% on cv6 before this was added (§Perf iteration 2).
///
/// `mr` must be in `1..=MR`: every macro-kernel strip has at least one
/// real row. `mr == 0` used to fall through to the full-MR kernel and
/// compute 8 rows of garbage; it now zeroes `acc` (debug builds assert).
#[inline(always)]
pub fn kernel_edge(
    backend: KernelBackend,
    ap: &[f32],
    bp: &[f32],
    kb: usize,
    acc: &mut [f32; MR * NR_MAX],
    mr: usize,
) {
    debug_assert!(
        (1..=MR).contains(&mr),
        "kernel_edge: mr={mr} out of range 1..=MR"
    );
    debug_assert!(backend.available(), "kernel_edge: {backend} unavailable");
    if mr == 0 {
        acc.fill(0.0);
        return;
    }
    match backend {
        KernelBackend::Scalar => scalar::kernel_f32(ap, bp, kb, acc, mr),
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        // SAFETY: the `available()` contract above — the backend was
        // feature-detected on this CPU before being handed out.
        KernelBackend::Avx2 => unsafe { avx2::kernel_f32(ap, bp, kb, acc, mr) },
        #[cfg(all(target_arch = "x86_64", mec_avx512))]
        // SAFETY: as above.
        KernelBackend::Avx512 => unsafe { avx512::kernel_f32(ap, bp, kb, acc, mr) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        KernelBackend::Neon => unsafe { neon::kernel_f32(ap, bp, kb, acc, mr) },
        #[allow(unreachable_patterns)]
        other => {
            debug_assert!(false, "kernel_edge: {other} not compiled for this target");
            scalar::kernel_f32(ap, bp, kb, acc, mr)
        }
    }
}

/// Q15 fixed-point variant of [`kernel`]: i16 operands, i32 accumulators.
///
/// `acc[r·nr + c] = Σ_k (ap[k·MR+r] · bp[k·nr+c] + 2¹⁴) >> 15`. The
/// caller folds the 2¹⁵ into its dequantization scale
/// (`scale_a · scale_b · 32768`). Bitwise-identical across backends for
/// operands ≥ −32767 (the quantizer's whole range).
#[inline(always)]
pub fn kernel_i16(
    backend: KernelBackend,
    ap: &[i16],
    bp: &[i16],
    kb: usize,
    acc: &mut [i32; MR * NR_MAX],
) {
    kernel_edge_i16(backend, ap, bp, kb, acc, MR);
}

/// Edge variant of [`kernel_i16`]: compute only the first `mr` rows.
/// Same `1..=MR` contract as [`kernel_edge`].
#[inline(always)]
pub fn kernel_edge_i16(
    backend: KernelBackend,
    ap: &[i16],
    bp: &[i16],
    kb: usize,
    acc: &mut [i32; MR * NR_MAX],
    mr: usize,
) {
    debug_assert!(
        (1..=MR).contains(&mr),
        "kernel_edge_i16: mr={mr} out of range 1..=MR"
    );
    debug_assert!(backend.available(), "kernel_edge_i16: {backend} unavailable");
    if mr == 0 {
        acc.fill(0);
        return;
    }
    match backend {
        KernelBackend::Scalar => scalar::kernel_i16(ap, bp, kb, acc, mr),
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        // SAFETY: the `available()` contract — feature-detected backend.
        KernelBackend::Avx2 => unsafe { avx2::kernel_i16(ap, bp, kb, acc, mr) },
        #[cfg(all(target_arch = "x86_64", mec_avx512))]
        // SAFETY: as above.
        KernelBackend::Avx512 => unsafe { avx512::kernel_i16(ap, bp, kb, acc, mr) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        KernelBackend::Neon => unsafe { neon::kernel_i16(ap, bp, kb, acc, mr) },
        #[allow(unreachable_patterns)]
        other => {
            debug_assert!(false, "kernel_edge_i16: {other} not compiled for this target");
            scalar::kernel_i16(ap, bp, kb, acc, mr)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f32_fixture(kb: usize, nr: usize) -> (Vec<f32>, Vec<f32>) {
        let mut ap = vec![0.0f32; kb * MR];
        let mut bp = vec![0.0f32; kb * nr];
        for (i, v) in ap.iter_mut().enumerate() {
            *v = (i % 7) as f32 - 3.0;
        }
        for (i, v) in bp.iter_mut().enumerate() {
            *v = (i % 5) as f32 * 0.5 - 1.0;
        }
        (ap, bp)
    }

    fn i16_fixture(kb: usize, nr: usize) -> (Vec<i16>, Vec<i16>) {
        let ap = (0..kb * MR)
            .map(|i| ((i as i32 * 2477) % 65535 - 32767) as i16)
            .collect();
        let bp = (0..kb * nr)
            .map(|i| ((i as i32 * 4391) % 65535 - 32767) as i16)
            .collect();
        (ap, bp)
    }

    #[test]
    fn backend_names_roundtrip() {
        for b in KernelBackend::PREFERENCE {
            assert_eq!(KernelBackend::parse(b.name()), Some(b));
            assert_eq!(format!("{b}"), b.name());
        }
        assert_eq!(KernelBackend::parse(" AVX2 "), Some(KernelBackend::Avx2));
        assert_eq!(KernelBackend::parse("sse"), None);
    }

    #[test]
    fn scalar_is_always_available_and_detection_is_consistent() {
        assert!(KernelBackend::Scalar.available());
        let all = KernelBackend::all_available();
        assert!(all.contains(&KernelBackend::Scalar));
        assert!(KernelBackend::detect().available());
        assert!(KernelBackend::active().available());
        for b in all {
            assert_eq!(b.mr(), MR);
            assert!(b.nr() == 8 || b.nr() == 16);
            assert!(b.nr() <= NR_MAX);
        }
    }

    #[test]
    fn kernel_matches_naive_on_every_available_backend() {
        let kb = 13;
        for backend in KernelBackend::all_available() {
            let nr = backend.nr();
            let (ap, bp) = f32_fixture(kb, nr);
            let mut acc = [0.0f32; MR * NR_MAX];
            kernel(backend, &ap, &bp, kb, &mut acc);
            for r in 0..MR {
                for c in 0..nr {
                    let want: f32 = (0..kb).map(|k| ap[k * MR + r] * bp[k * nr + c]).sum();
                    assert!(
                        (acc[r * nr + c] - want).abs() < 1e-4,
                        "{backend} r={r} c={c}: {} vs {want}",
                        acc[r * nr + c]
                    );
                }
            }
        }
    }

    #[test]
    fn kernel_zero_k_zeroes_the_tile() {
        for backend in KernelBackend::all_available() {
            let nr = backend.nr();
            let mut acc = [1.0f32; MR * NR_MAX];
            kernel(backend, &[], &[], 0, &mut acc);
            for r in 0..MR {
                assert!(
                    acc[r * nr..r * nr + nr].iter().all(|&v| v == 0.0),
                    "{backend} row {r} not zeroed"
                );
            }
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "kernel_edge: mr=0"))]
    fn kernel_edge_rejects_zero_rows() {
        // Debug builds assert; release builds must zero the accumulator
        // instead of computing MR garbage rows (the old fall-through bug).
        let mut acc = [7.0f32; MR * NR_MAX];
        kernel_edge(
            KernelBackend::Scalar,
            &[1.0; MR],
            &[1.0; NR_MAX],
            1,
            &mut acc,
            0,
        );
        assert!(acc.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn kernel_edge_all_valid_rows_match_full() {
        let kb = 9;
        for backend in KernelBackend::all_available() {
            let nr = backend.nr();
            let mut ap = vec![0.0f32; kb * MR];
            let mut bp = vec![0.0f32; kb * nr];
            for (i, v) in ap.iter_mut().enumerate() {
                *v = ((i * 7) % 11) as f32 - 5.0;
            }
            for (i, v) in bp.iter_mut().enumerate() {
                *v = ((i * 3) % 13) as f32 * 0.25 - 1.5;
            }
            let mut full = [0.0f32; MR * NR_MAX];
            kernel(backend, &ap, &bp, kb, &mut full);
            for mr in 1..=MR {
                let mut edge = [f32::NAN; MR * NR_MAX];
                kernel_edge(backend, &ap, &bp, kb, &mut edge, mr);
                for r in 0..mr {
                    assert_eq!(
                        &edge[r * nr..r * nr + nr],
                        &full[r * nr..r * nr + nr],
                        "{backend} mr={mr} r={r}"
                    );
                }
            }
        }
    }

    #[test]
    fn kernel_i16_matches_naive_shifted_sum_bitwise() {
        let kb = 13;
        for backend in KernelBackend::all_available() {
            let nr = backend.nr();
            let (ap, bp) = i16_fixture(kb, nr);
            let mut acc = [0i32; MR * NR_MAX];
            kernel_i16(backend, &ap, &bp, kb, &mut acc);
            for r in 0..MR {
                for c in 0..nr {
                    let want: i32 = (0..kb)
                        .map(|k| (ap[k * MR + r] as i32 * bp[k * nr + c] as i32 + (1 << 14)) >> 15)
                        .sum();
                    assert_eq!(acc[r * nr + c], want, "{backend} r={r} c={c}");
                }
            }
        }
    }

    #[test]
    fn kernel_edge_i16_matches_full_rows() {
        let kb = 6;
        for backend in KernelBackend::all_available() {
            let nr = backend.nr();
            let ap: Vec<i16> = (0..kb * MR)
                .map(|i| (i as i32 * 911 % 3000 - 1500) as i16)
                .collect();
            let bp: Vec<i16> = (0..kb * nr)
                .map(|i| (i as i32 * 577 % 3000 - 1500) as i16)
                .collect();
            let mut full = [0i32; MR * NR_MAX];
            kernel_i16(backend, &ap, &bp, kb, &mut full);
            for mr in 1..=MR {
                let mut edge = [0i32; MR * NR_MAX];
                kernel_edge_i16(backend, &ap, &bp, kb, &mut edge, mr);
                for r in 0..mr {
                    assert_eq!(
                        &edge[r * nr..r * nr + nr],
                        &full[r * nr..r * nr + nr],
                        "{backend} mr={mr}"
                    );
                }
            }
        }
    }

    #[test]
    fn i16_extreme_operands_stay_bitwise_equal_across_backends() {
        // The quantizer's full reachable range, including the ±32767
        // corners where rounded-Q15 hardware paths could diverge.
        let kb = 4;
        let patterns: [i16; 8] = [32767, -32767, 32766, -32766, 1, -1, 0, 16384];
        let scalar_nr = KernelBackend::Scalar.nr();
        let mut want = [0i32; MR * NR_MAX];
        {
            let ap: Vec<i16> = (0..kb * MR).map(|i| patterns[i % 8]).collect();
            let bp: Vec<i16> = (0..kb * scalar_nr).map(|i| patterns[(i + 3) % 8]).collect();
            kernel_i16(KernelBackend::Scalar, &ap, &bp, kb, &mut want);
        }
        for backend in KernelBackend::all_available() {
            if backend.nr() != scalar_nr {
                continue; // different strip layout; covered by the naive test
            }
            let ap: Vec<i16> = (0..kb * MR).map(|i| patterns[i % 8]).collect();
            let bp: Vec<i16> = (0..kb * scalar_nr).map(|i| patterns[(i + 3) % 8]).collect();
            let mut acc = [0i32; MR * NR_MAX];
            kernel_i16(backend, &ap, &bp, kb, &mut acc);
            assert_eq!(
                &acc[..MR * scalar_nr],
                &want[..MR * scalar_nr],
                "{backend} diverges from scalar on extreme operands"
            );
        }
    }
}
