//! aarch64 NEON micro-kernels: 8×8 f32 tiles on `vfmaq_f32` (two q-regs
//! per row), 8×8 Q15 tiles on `vqrdmulhq_s16`.
//!
//! `vqrdmulh` computes `sat((2·a·b + 2¹⁵) >> 16)` per lane — equal to the
//! scalar `(a·b + 2¹⁴) >> 15` for every operand pair except `(−32768)²`,
//! which the quantizer never produces (`QParams::QMAX` clamps to
//! ±32767). The i16 backend is therefore bitwise-compatible with scalar.

use super::{MR, NR_MAX};

use std::arch::aarch64::*;

/// Strip width of the NEON backend (`KernelBackend::Neon.nr()`).
const NR: usize = 8;

/// First `mr` rows of the 8×8 f32 tile; rows at stride `NR` in `acc`.
///
/// # Safety
/// The CPU must support NEON (`KernelBackend::Neon.available()`).
#[target_feature(enable = "neon")]
pub unsafe fn kernel_f32(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR_MAX], mr: usize) {
    match mr {
        1 => rows_f32::<1>(ap, bp, kb, acc),
        2 => rows_f32::<2>(ap, bp, kb, acc),
        3 => rows_f32::<3>(ap, bp, kb, acc),
        4 => rows_f32::<4>(ap, bp, kb, acc),
        5 => rows_f32::<5>(ap, bp, kb, acc),
        6 => rows_f32::<6>(ap, bp, kb, acc),
        7 => rows_f32::<7>(ap, bp, kb, acc),
        _ => rows_f32::<MR>(ap, bp, kb, acc),
    }
}

#[inline(always)]
unsafe fn rows_f32<const R: usize>(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR_MAX]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    // Two 128-bit accumulators per row (8 f32 columns).
    let mut lo = [vdupq_n_f32(0.0); R];
    let mut hi = [vdupq_n_f32(0.0); R];
    let a = ap.as_ptr();
    let b = bp.as_ptr();
    for k in 0..kb {
        let b_lo = vld1q_f32(b.add(k * NR));
        let b_hi = vld1q_f32(b.add(k * NR + 4));
        for r in 0..R {
            let av = vdupq_n_f32(*a.add(k * MR + r));
            lo[r] = vfmaq_f32(lo[r], av, b_lo);
            hi[r] = vfmaq_f32(hi[r], av, b_hi);
        }
    }
    for r in 0..R {
        vst1q_f32(acc.as_mut_ptr().add(r * NR), lo[r]);
        vst1q_f32(acc.as_mut_ptr().add(r * NR + 4), hi[r]);
    }
}

/// First `mr` rows of the 8×8 Q15 tile; rows at stride `NR` in `acc`.
///
/// # Safety
/// The CPU must support NEON (`KernelBackend::Neon.available()`).
#[target_feature(enable = "neon")]
pub unsafe fn kernel_i16(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR_MAX], mr: usize) {
    match mr {
        1 => rows_i16::<1>(ap, bp, kb, acc),
        2 => rows_i16::<2>(ap, bp, kb, acc),
        3 => rows_i16::<3>(ap, bp, kb, acc),
        4 => rows_i16::<4>(ap, bp, kb, acc),
        5 => rows_i16::<5>(ap, bp, kb, acc),
        6 => rows_i16::<6>(ap, bp, kb, acc),
        7 => rows_i16::<7>(ap, bp, kb, acc),
        _ => rows_i16::<MR>(ap, bp, kb, acc),
    }
}

#[inline(always)]
unsafe fn rows_i16<const R: usize>(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR_MAX]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    let mut lo = [vdupq_n_s32(0); R];
    let mut hi = [vdupq_n_s32(0); R];
    let a = ap.as_ptr();
    let b = bp.as_ptr();
    for k in 0..kb {
        let bv = vld1q_s16(b.add(k * NR));
        for r in 0..R {
            let av = vdupq_n_s16(*a.add(k * MR + r));
            // Rounded Q15 product per i16 lane, widened and accumulated.
            let p = vqrdmulhq_s16(av, bv);
            lo[r] = vaddq_s32(lo[r], vmovl_s16(vget_low_s16(p)));
            hi[r] = vaddq_s32(hi[r], vmovl_high_s16(p));
        }
    }
    for r in 0..R {
        vst1q_s32(acc.as_mut_ptr().add(r * NR), lo[r]);
        vst1q_s32(acc.as_mut_ptr().add(r * NR + 4), hi[r]);
    }
}
