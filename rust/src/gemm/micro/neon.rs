//! aarch64 NEON micro-kernels: 8×8 f32 tiles on `vfmaq_f32` (two q-regs
//! per row), 8×8 Q15 tiles on `vqrdmulhq_s16`.
//!
//! `vqrdmulh` computes `sat((2·a·b + 2¹⁵) >> 16)` per lane — equal to the
//! scalar `(a·b + 2¹⁴) >> 15` for every operand pair except `(−32768)²`,
//! which the quantizer never produces (`QParams::QMAX` clamps to
//! ±32767). The i16 backend is therefore bitwise-compatible with scalar.

use super::{MR, NR_MAX};

use std::arch::aarch64::*;

/// Strip width of the NEON backend (`KernelBackend::Neon.nr()`).
const NR: usize = 8;

/// First `mr` rows of the 8×8 f32 tile; rows at stride `NR` in `acc`.
///
/// # Safety
/// The CPU must support NEON (`KernelBackend::Neon.available()`).
#[target_feature(enable = "neon")]
pub unsafe fn kernel_f32(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR_MAX], mr: usize) {
    // SAFETY: `rows_f32` is `#[inline(always)]`, so its intrinsics compile
    // inside this fn's NEON window; its bounds requirements (`ap` ≥ kb·MR,
    // `bp` ≥ kb·NR) are exactly this fn's own documented contract.
    unsafe {
        match mr {
            1 => rows_f32::<1>(ap, bp, kb, acc),
            2 => rows_f32::<2>(ap, bp, kb, acc),
            3 => rows_f32::<3>(ap, bp, kb, acc),
            4 => rows_f32::<4>(ap, bp, kb, acc),
            5 => rows_f32::<5>(ap, bp, kb, acc),
            6 => rows_f32::<6>(ap, bp, kb, acc),
            7 => rows_f32::<7>(ap, bp, kb, acc),
            _ => rows_f32::<MR>(ap, bp, kb, acc),
        }
    }
}

/// # Safety
/// Caller must have NEON enabled and `ap`/`bp` packed as documented on
/// [`kernel_f32`].
#[inline(always)]
unsafe fn rows_f32<const R: usize>(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR_MAX]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    // Two 128-bit accumulators per row (8 f32 columns).
    // SAFETY: register-only zeroing; the feature window comes from the
    // `#[target_feature]` caller this fn is always inlined into.
    let mut lo = [unsafe { vdupq_n_f32(0.0) }; R];
    // SAFETY: as above.
    let mut hi = [unsafe { vdupq_n_f32(0.0) }; R];
    let a = ap.as_ptr();
    let b = bp.as_ptr();
    for k in 0..kb {
        // SAFETY: k < kb and `bp` holds kb strips of NR floats
        // (debug-asserted above), so both 4-lane loads read
        // b[k·NR .. k·NR+8] fully in bounds.
        let b_lo = unsafe { vld1q_f32(b.add(k * NR)) };
        // SAFETY: as above (upper half of the same strip).
        let b_hi = unsafe { vld1q_f32(b.add(k * NR + 4)) };
        for r in 0..R {
            // SAFETY: r < R ≤ MR and k < kb, and `ap` holds kb columns of
            // MR floats, so a + k·MR + r points at a readable f32.
            let av = unsafe { vdupq_n_f32(*a.add(k * MR + r)) };
            // SAFETY: FMA on register operands only.
            lo[r] = unsafe { vfmaq_f32(lo[r], av, b_lo) };
            // SAFETY: as above.
            hi[r] = unsafe { vfmaq_f32(hi[r], av, b_hi) };
        }
    }
    for r in 0..R {
        // SAFETY: r ≤ MR−1 and NR < NR_MAX, so the pair of 4-lane stores
        // ends at r·NR + 8 ≤ (MR−1)·NR + 8 < MR·NR_MAX, inside `acc`.
        unsafe { vst1q_f32(acc.as_mut_ptr().add(r * NR), lo[r]) };
        // SAFETY: as above.
        unsafe { vst1q_f32(acc.as_mut_ptr().add(r * NR + 4), hi[r]) };
    }
}

/// First `mr` rows of the 8×8 Q15 tile; rows at stride `NR` in `acc`.
///
/// # Safety
/// The CPU must support NEON (`KernelBackend::Neon.available()`).
#[target_feature(enable = "neon")]
pub unsafe fn kernel_i16(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR_MAX], mr: usize) {
    // SAFETY: `rows_i16` is `#[inline(always)]`, so its intrinsics compile
    // inside this fn's NEON window; its bounds requirements are exactly
    // this fn's own documented contract.
    unsafe {
        match mr {
            1 => rows_i16::<1>(ap, bp, kb, acc),
            2 => rows_i16::<2>(ap, bp, kb, acc),
            3 => rows_i16::<3>(ap, bp, kb, acc),
            4 => rows_i16::<4>(ap, bp, kb, acc),
            5 => rows_i16::<5>(ap, bp, kb, acc),
            6 => rows_i16::<6>(ap, bp, kb, acc),
            7 => rows_i16::<7>(ap, bp, kb, acc),
            _ => rows_i16::<MR>(ap, bp, kb, acc),
        }
    }
}

/// # Safety
/// Caller must have NEON enabled and `ap`/`bp` packed as documented on
/// [`kernel_i16`].
#[inline(always)]
unsafe fn rows_i16<const R: usize>(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR_MAX]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    // SAFETY: register-only zeroing inside the caller's NEON window.
    let mut lo = [unsafe { vdupq_n_s32(0) }; R];
    // SAFETY: as above.
    let mut hi = [unsafe { vdupq_n_s32(0) }; R];
    let a = ap.as_ptr();
    let b = bp.as_ptr();
    for k in 0..kb {
        // SAFETY: k < kb and `bp` holds kb strips of NR i16s
        // (debug-asserted above), so the 8-lane load reads
        // b[k·NR .. k·NR+8] fully in bounds.
        let bv = unsafe { vld1q_s16(b.add(k * NR)) };
        for r in 0..R {
            // SAFETY: r < R ≤ MR and k < kb, and `ap` holds kb columns of
            // MR i16s, so a + k·MR + r points at a readable i16.
            let av = unsafe { vdupq_n_s16(*a.add(k * MR + r)) };
            // Rounded Q15 product per i16 lane, widened and accumulated.
            // SAFETY: register-only arithmetic.
            let p = unsafe { vqrdmulhq_s16(av, bv) };
            // SAFETY: register-only arithmetic (widen low half + add).
            lo[r] = unsafe { vaddq_s32(lo[r], vmovl_s16(vget_low_s16(p))) };
            // SAFETY: register-only arithmetic (widen high half + add).
            hi[r] = unsafe { vaddq_s32(hi[r], vmovl_high_s16(p)) };
        }
    }
    for r in 0..R {
        // SAFETY: r ≤ MR−1 and NR < NR_MAX, so the pair of 4-lane i32
        // stores ends at r·NR + 8 ≤ (MR−1)·NR + 8 < MR·NR_MAX, inside
        // `acc`.
        unsafe { vst1q_s32(acc.as_mut_ptr().add(r * NR), lo[r]) };
        // SAFETY: as above.
        unsafe { vst1q_s32(acc.as_mut_ptr().add(r * NR + 4), hi[r]) };
    }
}
