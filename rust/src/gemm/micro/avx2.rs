//! AVX2 + FMA micro-kernels: 8×8 f32 tiles on `_mm256_fmadd_ps`, 8×8
//! Q15 tiles on `_mm_mulhrs_epi16`.
//!
//! `mulhrs` computes `((a·b >> 14) + 1) >> 1` per lane — algebraically
//! identical to the scalar path's `(a·b + 2¹⁴) >> 15` for every operand
//! pair except `(−32768)²`, which the quantizer never produces
//! (`QParams::QMAX` clamps to ±32767). The i16 backend is therefore
//! bitwise-compatible with scalar.

use super::{MR, NR_MAX};

#[cfg(target_arch = "x86")]
use std::arch::x86::*;
#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Strip width of the AVX2 backend (`KernelBackend::Avx2.nr()`).
const NR: usize = 8;

/// First `mr` rows of the 8×8 f32 tile; rows at stride `NR` in `acc`.
///
/// # Safety
/// The CPU must support AVX2 and FMA (`KernelBackend::Avx2.available()`).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn kernel_f32(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR_MAX], mr: usize) {
    // SAFETY: `rows_f32` is `#[inline(always)]`, so its intrinsics compile
    // inside this fn's AVX2+FMA window; its bounds requirements (`ap` ≥
    // kb·MR, `bp` ≥ kb·NR) are exactly this fn's own documented contract.
    unsafe {
        match mr {
            1 => rows_f32::<1>(ap, bp, kb, acc),
            2 => rows_f32::<2>(ap, bp, kb, acc),
            3 => rows_f32::<3>(ap, bp, kb, acc),
            4 => rows_f32::<4>(ap, bp, kb, acc),
            5 => rows_f32::<5>(ap, bp, kb, acc),
            6 => rows_f32::<6>(ap, bp, kb, acc),
            7 => rows_f32::<7>(ap, bp, kb, acc),
            _ => rows_f32::<MR>(ap, bp, kb, acc),
        }
    }
}

/// Inlined into the `#[target_feature]` caller, so the intrinsics compile
/// with AVX2+FMA enabled (`#[inline(always)]` and `#[target_feature]` are
/// mutually exclusive on the same fn).
///
/// # Safety
/// Caller must have AVX2+FMA enabled and `ap`/`bp` packed as documented
/// on [`kernel_f32`].
#[inline(always)]
unsafe fn rows_f32<const R: usize>(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR_MAX]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    // SAFETY: register-only zeroing; the feature window comes from the
    // `#[target_feature]` caller this fn is always inlined into.
    let mut c = [unsafe { _mm256_setzero_ps() }; R];
    let a = ap.as_ptr();
    let b = bp.as_ptr();
    for k in 0..kb {
        // SAFETY: k < kb and `bp` holds kb strips of NR floats
        // (debug-asserted above), so the unaligned 8-lane load reads
        // b[k·NR .. k·NR+8] fully in bounds.
        let bv = unsafe { _mm256_loadu_ps(b.add(k * NR)) };
        for r in 0..R {
            // SAFETY: r < R ≤ MR and k < kb, and `ap` holds kb columns of
            // MR floats, so a + k·MR + r points at a readable f32.
            let av = unsafe { _mm256_set1_ps(*a.add(k * MR + r)) };
            // SAFETY: FMA on register operands only.
            c[r] = unsafe { _mm256_fmadd_ps(av, bv, c[r]) };
        }
    }
    for (r, &v) in c.iter().enumerate() {
        // SAFETY: r ≤ MR−1 and NR < NR_MAX, so the 8-lane store ends at
        // r·NR + 8 ≤ (MR−1)·NR + 8 < MR·NR_MAX, inside `acc`.
        unsafe { _mm256_storeu_ps(acc.as_mut_ptr().add(r * NR), v) };
    }
}

/// First `mr` rows of the 8×8 Q15 tile; rows at stride `NR` in `acc`.
///
/// # Safety
/// The CPU must support AVX2 (`KernelBackend::Avx2.available()`).
#[target_feature(enable = "avx2")]
pub unsafe fn kernel_i16(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR_MAX], mr: usize) {
    // SAFETY: `rows_i16` is `#[inline(always)]`, so its intrinsics compile
    // inside this fn's AVX2 window; its bounds requirements are exactly
    // this fn's own documented contract.
    unsafe {
        match mr {
            1 => rows_i16::<1>(ap, bp, kb, acc),
            2 => rows_i16::<2>(ap, bp, kb, acc),
            3 => rows_i16::<3>(ap, bp, kb, acc),
            4 => rows_i16::<4>(ap, bp, kb, acc),
            5 => rows_i16::<5>(ap, bp, kb, acc),
            6 => rows_i16::<6>(ap, bp, kb, acc),
            7 => rows_i16::<7>(ap, bp, kb, acc),
            _ => rows_i16::<MR>(ap, bp, kb, acc),
        }
    }
}

/// # Safety
/// Caller must have AVX2 enabled and `ap`/`bp` packed as documented on
/// [`kernel_i16`].
#[inline(always)]
unsafe fn rows_i16<const R: usize>(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR_MAX]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    // SAFETY: register-only zeroing inside the caller's AVX2 window.
    let mut c = [unsafe { _mm256_setzero_si256() }; R];
    let a = ap.as_ptr();
    let b = bp.as_ptr();
    for k in 0..kb {
        // SAFETY: k < kb and `bp` holds kb strips of NR i16s
        // (debug-asserted above), so the unaligned 16-byte load reads
        // b[k·NR .. k·NR+8] fully in bounds.
        let bv = unsafe { _mm_loadu_si128(b.add(k * NR) as *const __m128i) };
        for r in 0..R {
            // SAFETY: r < R ≤ MR and k < kb, and `ap` holds kb columns of
            // MR i16s, so a + k·MR + r points at a readable i16.
            let av = unsafe { _mm_set1_epi16(*a.add(k * MR + r)) };
            // Rounded Q15 product per i16 lane, widened to i32 lanes and
            // accumulated — the FMA-shaped loop the scalar rounding shift
            // used to block.
            // SAFETY: register-only arithmetic (mulhrs, widen, add).
            let p = unsafe { _mm_mulhrs_epi16(av, bv) };
            // SAFETY: register-only arithmetic.
            c[r] = unsafe { _mm256_add_epi32(c[r], _mm256_cvtepi16_epi32(p)) };
        }
    }
    for (r, &v) in c.iter().enumerate() {
        // SAFETY: r ≤ MR−1 and NR < NR_MAX, so the 8-lane i32 store ends
        // at r·NR + 8 ≤ (MR−1)·NR + 8 < MR·NR_MAX, inside `acc`.
        unsafe { _mm256_storeu_si256(acc.as_mut_ptr().add(r * NR) as *mut __m256i, v) };
    }
}
