//! Portable micro-kernels — the autovectorized fallback and the
//! reference implementation every SIMD backend is tested against.
//!
//! Written so LLVM auto-vectorizes the inner NR-wide loop into SIMD f32
//! lanes; MR×NR accumulators live in registers across the whole K loop.

use super::{MR, NR_MAX};

/// Strip width of the scalar backend (`KernelBackend::Scalar.nr()`).
const NR: usize = 8;

/// First `mr` rows of the 8×8 f32 tile; rows at stride `NR` in `acc`.
#[inline(always)]
pub fn kernel_f32(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR_MAX], mr: usize) {
    match mr {
        1 => kernel_rows::<1>(ap, bp, kb, acc),
        2 => kernel_rows::<2>(ap, bp, kb, acc),
        3 => kernel_rows::<3>(ap, bp, kb, acc),
        4 => kernel_rows::<4>(ap, bp, kb, acc),
        5 => kernel_rows::<5>(ap, bp, kb, acc),
        6 => kernel_rows::<6>(ap, bp, kb, acc),
        7 => kernel_rows::<7>(ap, bp, kb, acc),
        _ => kernel_rows::<MR>(ap, bp, kb, acc),
    }
}

#[inline(always)]
fn kernel_rows<const R: usize>(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR_MAX]) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    // Local accumulators: LLVM keeps these in vector registers.
    let mut c = [[0.0f32; NR]; R];
    // Fixed-size array windows (`&[f32; MR]`/`&[f32; NR]`) over slices
    // pre-cut to exactly kb: the iterators carry the trip count and the
    // window length checks fold away, leaving the inner loops with no
    // bounds checks at all. 4-way K unroll kept: fewer loop-carried
    // dependencies, better ILP.
    let kb4 = kb - kb % 4;
    for (a, b) in ap[..kb4 * MR]
        .chunks_exact(4 * MR)
        .zip(bp[..kb4 * NR].chunks_exact(4 * NR))
    {
        for kk in 0..4 {
            let a: &[f32; MR] = a[kk * MR..(kk + 1) * MR].try_into().unwrap();
            let b: &[f32; NR] = b[kk * NR..(kk + 1) * NR].try_into().unwrap();
            for r in 0..R {
                let ar = a[r];
                for j in 0..NR {
                    c[r][j] += ar * b[j];
                }
            }
        }
    }
    for (a, b) in ap[kb4 * MR..kb * MR]
        .chunks_exact(MR)
        .zip(bp[kb4 * NR..kb * NR].chunks_exact(NR))
    {
        let a: &[f32; MR] = a.try_into().unwrap();
        let b: &[f32; NR] = b.try_into().unwrap();
        for r in 0..R {
            let ar = a[r];
            for j in 0..NR {
                c[r][j] += ar * b[j];
            }
        }
    }
    for (row, src) in c.iter().enumerate() {
        acc[row * NR..row * NR + NR].copy_from_slice(src);
    }
}

/// First `mr` rows of the 8×8 Q15 tile; rows at stride `NR` in `acc`.
#[inline(always)]
pub fn kernel_i16(ap: &[i16], bp: &[i16], kb: usize, acc: &mut [i32; MR * NR_MAX], mr: usize) {
    match mr {
        1 => kernel_rows_i16::<1>(ap, bp, kb, acc),
        2 => kernel_rows_i16::<2>(ap, bp, kb, acc),
        3 => kernel_rows_i16::<3>(ap, bp, kb, acc),
        4 => kernel_rows_i16::<4>(ap, bp, kb, acc),
        5 => kernel_rows_i16::<5>(ap, bp, kb, acc),
        6 => kernel_rows_i16::<6>(ap, bp, kb, acc),
        7 => kernel_rows_i16::<7>(ap, bp, kb, acc),
        _ => kernel_rows_i16::<MR>(ap, bp, kb, acc),
    }
}

#[inline(always)]
fn kernel_rows_i16<const R: usize>(
    ap: &[i16],
    bp: &[i16],
    kb: usize,
    acc: &mut [i32; MR * NR_MAX],
) {
    debug_assert!(ap.len() >= kb * MR);
    debug_assert!(bp.len() >= kb * NR);
    let mut c = [[0i32; NR]; R];
    // Same bounds-check-free array-window shape as the f32 kernel.
    let kb4 = kb - kb % 4;
    for (a, b) in ap[..kb4 * MR]
        .chunks_exact(4 * MR)
        .zip(bp[..kb4 * NR].chunks_exact(4 * NR))
    {
        for kk in 0..4 {
            let a: &[i16; MR] = a[kk * MR..(kk + 1) * MR].try_into().unwrap();
            let b: &[i16; NR] = b[kk * NR..(kk + 1) * NR].try_into().unwrap();
            for r in 0..R {
                let ar = a[r] as i32;
                for j in 0..NR {
                    c[r][j] += (ar * b[j] as i32 + (1 << 14)) >> 15;
                }
            }
        }
    }
    for (a, b) in ap[kb4 * MR..kb * MR]
        .chunks_exact(MR)
        .zip(bp[kb4 * NR..kb * NR].chunks_exact(NR))
    {
        let a: &[i16; MR] = a.try_into().unwrap();
        let b: &[i16; NR] = b.try_into().unwrap();
        for r in 0..R {
            let ar = a[r] as i32;
            for j in 0..NR {
                c[r][j] += (ar * b[j] as i32 + (1 << 14)) >> 15;
            }
        }
    }
    for (row, src) in c.iter().enumerate() {
        acc[row * NR..row * NR + NR].copy_from_slice(src);
    }
}
