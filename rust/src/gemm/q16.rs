//! 16-bit fixed-point GEMM substrate — the q16 sibling of the f32 path.
//!
//! The paper's §4 evaluates MEC in "16-bit fixed point" as well as f32:
//! with the lowering already compact, operand precision is the remaining
//! memory lever, and halving the bytes through the same L roughly halves
//! the lowering/packing traffic. This module mirrors the f32 pipeline
//! one-for-one so the conv plans can swap precisions without changing
//! shape logic:
//!
//! * [`MatRefI16`] — strided i16 views (the BLAS `ld` trick works
//!   unchanged on the quantized L).
//! * [`pack_a_i16`] / [`pack_b_i16`] — the panel packers, i16 lanes.
//! * [`PackedBI16`] — plan-time prepacked kernel matrices, recording the
//!   [`KernelBackend`] whose strip width they were packed for.
//! * [`gemm_prepacked_i16`] / [`gemm_prepacked_ex_i16`] /
//!   [`gemm_prepacked_batch_i16`] — the prepacked GEMMs, writing
//!   dequantized f32 into C through a [`Q16Epilogue`] that supports
//!   per-output-column (per-output-channel) kernel scales.
//!
//! Arithmetic: i16 × i16 widened to i32, each product rounded-shifted
//! back to Q15 before accumulation (see
//! [`micro::kernel_i16`](super::micro::kernel_i16) — `mulhrs` on AVX2,
//! `vqrdmulh` on NEON, a rounding shift on scalar; bitwise-identical
//! across backends), so i32 accumulators cannot overflow for any
//! `K ≤ 2¹⁵` (asserted at pack time). The epilogue's `global` scale must
//! fold in the Q15 product shift: `scale_a · scale_b · 32768`.

use super::micro::{self, KernelBackend, MR, NR_MAX};
use super::{scale_c, split_ranges, BlockSizes, MatMut};
use crate::memory::aligned::{AlignedVec, ALIGN};
use crate::threadpool::{Parallelism, SharedSlice};

/// Immutable i16 matrix view: `rows × cols` with row stride `rs`
/// (`rs >= cols`; `rs > cols` expresses BLAS `ld` sub-matrices — MEC's
/// overlapping partitions of the quantized L).
#[derive(Debug, Clone, Copy)]
pub struct MatRefI16<'a> {
    pub data: &'a [i16],
    pub rows: usize,
    pub cols: usize,
    pub rs: usize,
}

impl<'a> MatRefI16<'a> {
    pub fn new(data: &'a [i16], rows: usize, cols: usize) -> MatRefI16<'a> {
        MatRefI16::strided(data, rows, cols, cols)
    }

    pub fn strided(data: &'a [i16], rows: usize, cols: usize, rs: usize) -> MatRefI16<'a> {
        assert!(rs >= cols, "row stride {rs} < cols {cols}");
        if rows > 0 {
            assert!(
                (rows - 1) * rs + cols <= data.len(),
                "view {rows}x{cols} (rs={rs}) exceeds buffer of {}",
                data.len()
            );
        }
        MatRefI16 { data, rows, cols, rs }
    }

    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> i16 {
        self.data[r * self.rs + c]
    }

    /// Sub-view of rows `r0..r0+nr`, cols `c0..c0+nc`.
    pub fn sub(&self, r0: usize, nr: usize, c0: usize, nc: usize) -> MatRefI16<'a> {
        assert!(r0 + nr <= self.rows && c0 + nc <= self.cols);
        MatRefI16::strided(&self.data[r0 * self.rs + c0..], nr, nc, self.rs)
    }
}

/// Dequantization applied as the i32 accumulators are written back to
/// f32 C. `global` carries the activation scale and the Q15 product
/// shift (`scale_a · 32768`, times the kernel's per-tensor scale when
/// `per_col` is absent); `per_col[j]` is output column `j`'s kernel
/// scale. Borrowing the plan-resident scale table keeps the execute hot
/// path allocation-free.
#[derive(Debug, Clone, Copy)]
pub struct Q16Epilogue<'a> {
    pub global: f32,
    pub per_col: Option<&'a [f32]>,
}

impl Q16Epilogue<'_> {
    /// A single per-tensor scale for every output column.
    pub fn uniform(scale: f32) -> Q16Epilogue<'static> {
        Q16Epilogue {
            global: scale,
            per_col: None,
        }
    }

    /// The dequantization factor for output column `col` of C.
    #[inline(always)]
    pub fn at(&self, col: usize) -> f32 {
        match self.per_col {
            Some(s) => self.global * s[col],
            None => self.global,
        }
    }
}

/// Pack an i16 A block into MR-row strips, k-major, zero-padded — the
/// exact layout of [`pack::pack_a`](super::pack::pack_a) in i16 lanes.
pub fn pack_a_i16(a: MatRefI16<'_>, out: &mut [i16]) {
    let (mb, kb) = (a.rows, a.cols);
    let strips = mb.div_ceil(MR);
    assert!(out.len() >= strips * kb * MR, "pack_a_i16 buffer too small");
    for s in 0..strips {
        let r0 = s * MR;
        let rows = MR.min(mb - r0);
        let dst = &mut out[s * kb * MR..(s + 1) * kb * MR];
        for k in 0..kb {
            let d = &mut dst[k * MR..k * MR + MR];
            for (r, slot) in d.iter_mut().enumerate() {
                *slot = if r < rows { a.data[(r0 + r) * a.rs + k] } else { 0 };
            }
        }
    }
}

/// Pack an i16 B block into `nr`-column strips, k-major, zero-padded —
/// the exact layout of [`pack::pack_b`](super::pack::pack_b) in i16
/// lanes. `nr` is the consuming backend's strip width.
pub fn pack_b_i16(b: MatRefI16<'_>, out: &mut [i16], nr: usize) {
    let (kb, nb) = (b.rows, b.cols);
    let strips = nb.div_ceil(nr);
    assert!(out.len() >= strips * kb * nr, "pack_b_i16 buffer too small");
    for s in 0..strips {
        let c0 = s * nr;
        let cols = nr.min(nb - c0);
        let dst = &mut out[s * kb * nr..(s + 1) * kb * nr];
        for k in 0..kb {
            let d = &mut dst[k * nr..k * nr + nr];
            for (c, slot) in d.iter_mut().enumerate() {
                *slot = if c < cols { b.data[k * b.rs + c0 + c] } else { 0 };
            }
        }
    }
}

/// A quantized B operand packed once for reuse — the q16 twin of
/// [`PackedB`](super::PackedB), holding i16 tiles in the same
/// (pc, jc) order, each tile starting on a 64-byte boundary.
#[derive(Debug, Clone)]
pub struct PackedBI16 {
    pub k: usize,
    pub n: usize,
    pub bs: BlockSizes,
    backend: KernelBackend,
    data: AlignedVec<i16>,
    tile_offsets: Vec<usize>,
    n_blocks: usize,
}

impl PackedBI16 {
    /// Pack the whole of B for the process-wide active backend. Asserts
    /// the Q15 accumulator depth bound (`k ≤ 2¹⁵` — far above any
    /// cv-layer `k_h·k_w·i_c`).
    pub fn pack(b: MatRefI16<'_>, bs: BlockSizes) -> PackedBI16 {
        Self::pack_with(b, bs, KernelBackend::active())
    }

    /// Pack the whole of B into `backend`-width strips (see
    /// [`PackedB::pack_with`](super::PackedB::pack_with)).
    pub fn pack_with(b: MatRefI16<'_>, bs: BlockSizes, backend: KernelBackend) -> PackedBI16 {
        let nr = backend.nr();
        let (k, n) = (b.rows, b.cols);
        assert!(
            k <= 1 << 15,
            "q16 gemm: reduction depth {k} exceeds the i32-accumulator bound 2^15"
        );
        let k_blocks = k.div_ceil(bs.kc).max(1);
        let n_blocks = n.div_ceil(bs.nc).max(1);
        let mut data = AlignedVec::new();
        let mut tile_offsets = Vec::with_capacity(k_blocks * n_blocks);
        for pb in 0..k_blocks {
            let pc = pb * bs.kc;
            let kb = bs.kc.min(k - pc);
            for jb in 0..n_blocks {
                let jc = jb * bs.nc;
                let nb = bs.nc.min(n - jc);
                // Keep every tile cache-line aligned, not just the base.
                let start = data.len().next_multiple_of(ALIGN / 2);
                tile_offsets.push(start);
                let tile_len = nb.div_ceil(nr) * kb * nr;
                data.resize(start + tile_len, 0);
                pack_b_i16(b.sub(pc, kb, jc, nb), &mut data[start..], nr);
            }
        }
        PackedBI16 {
            k,
            n,
            bs,
            backend,
            data,
            tile_offsets,
            n_blocks,
        }
    }

    /// The kernel backend these strips were packed for.
    pub fn backend(&self) -> KernelBackend {
        self.backend
    }

    fn tile(&self, pb: usize, jb: usize) -> &[i16] {
        let idx = pb * self.n_blocks + jb;
        let start = self.tile_offsets[idx];
        let end = self
            .tile_offsets
            .get(idx + 1)
            .copied()
            .unwrap_or(self.data.len());
        let t = &self.data[start..end];
        debug_assert!(
            t.is_empty() || t.as_ptr() as usize % ALIGN == 0,
            "PackedBI16 tile lost {ALIGN}-byte alignment"
        );
        t
    }

    /// Bytes held by the packed copy — half the f32 pack's for the same
    /// matrix.
    pub fn bytes(&self) -> usize {
        self.data.len() * 2
    }
}

thread_local! {
    /// Reused i16 A-packing scratch (B is always prepacked on the q16
    /// path, so there is no raw-B scratch), 64-byte aligned.
    static SCRATCH_I16: std::cell::RefCell<AlignedVec<i16>> =
        const { std::cell::RefCell::new(AlignedVec::new()) };
}

/// `C = ep · (Aq × PBq)` with B pre-packed (beta = 0), serial: i16
/// operands, i32 accumulation, f32 writeback through the epilogue.
pub fn gemm_prepacked_i16(
    a: MatRefI16<'_>,
    pb: &PackedBI16,
    c: &mut MatMut<'_>,
    ep: Q16Epilogue<'_>,
) {
    assert_eq!(a.cols, pb.k, "gemm_prepacked_i16: A cols vs packed B rows");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, pb.n);
    debug_assert!(ep.per_col.is_none_or(|s| s.len() >= pb.n));
    scale_c(c, 0.0);
    gemm_serial_prepacked_i16(a, pb, c, ep);
}

/// Threaded [`gemm_prepacked_i16`], parallelized over row panels of C —
/// the q16 twin of [`gemm_prepacked_ex`](super::gemm_prepacked_ex), with
/// the identical partitioning (same row panels, same tile walk), so
/// results are bit-identical to the serial path at any thread count.
pub fn gemm_prepacked_ex_i16(
    a: MatRefI16<'_>,
    pb: &PackedBI16,
    c: &mut MatMut<'_>,
    ep: Q16Epilogue<'_>,
    par: &Parallelism,
) {
    assert_eq!(a.cols, pb.k, "gemm_prepacked_ex_i16: A cols vs packed B rows");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, pb.n);
    if par.threads() <= 1 {
        gemm_prepacked_i16(a, pb, c, ep);
        return;
    }
    let (m, k) = (a.rows, a.cols);
    let n = pb.n;
    if m == 0 || n == 0 {
        return;
    }
    debug_assert!(ep.per_col.is_none_or(|s| s.len() >= n));
    scale_c(c, 0.0);
    let crs = c.rs;
    let c_shared = SharedSlice::new(c.data);
    let row_panels: Vec<(usize, usize)> = split_ranges(m, par.threads());
    let nthreads = row_panels.len();
    let panel_macs = m.div_ceil(nthreads) * k * n;
    par.parallel_for_macs(nthreads, panel_macs, |t| {
        let (r0, r1) = row_panels[t];
        if r0 == r1 {
            return;
        }
        let c_data: &mut [f32] = c_shared.slice();
        let mut c_panel = MatMut::strided(&mut c_data[r0 * crs..], r1 - r0, n, crs);
        let a_panel = a.sub(r0, r1 - r0, 0, k);
        gemm_serial_prepacked_i16(a_panel, pb, &mut c_panel, ep);
    });
}

/// Batched `C[i] = ep · (Aq[i] × PBq)` with the batch loop inside the
/// (pc, jc) tile loops — the q16 twin of
/// [`gemm_prepacked_batch`](super::gemm_prepacked_batch) (MEC's mobile
/// path: each packed-K tile streams from memory once across all
/// partitions).
pub fn gemm_prepacked_batch_i16(
    a: &[MatRefI16<'_>],
    pb: &PackedBI16,
    c: &mut [MatMut<'_>],
    ep: Q16Epilogue<'_>,
) {
    assert_eq!(a.len(), c.len());
    for (ai, ci) in a.iter().zip(c.iter_mut()) {
        assert_eq!(ai.cols, pb.k);
        assert_eq!(ci.rows, ai.rows);
        assert_eq!(ci.cols, pb.n);
        scale_c(ci, 0.0);
    }
    debug_assert!(ep.per_col.is_none_or(|s| s.len() >= pb.n));
    let bs = pb.bs;
    let k = pb.k;
    let n = pb.n;
    let backend = pb.backend;
    let nrw = backend.nr();
    SCRATCH_I16.with(|scratch| {
        let packed_a = &mut *scratch.borrow_mut();
        let max_m = a.iter().map(|x| x.rows).max().unwrap_or(0);
        let pa_len = bs.mc.min(max_m.max(1)).next_multiple_of(MR) * bs.kc.min(k);
        if packed_a.len() < pa_len {
            packed_a.resize(pa_len, 0);
        }
        let mut acc = [0i32; MR * NR_MAX];
        let mut pc = 0;
        let mut pb_idx = 0;
        while pc < k {
            let kb = bs.kc.min(k - pc);
            let mut jc = 0;
            let mut jb_idx = 0;
            while jc < n {
                let nb = bs.nc.min(n - jc);
                let b_tile = pb.tile(pb_idx, jb_idx);
                for (ai, ci) in a.iter().zip(c.iter_mut()) {
                    let m = ai.rows;
                    let mut ic = 0;
                    while ic < m {
                        let mb = bs.mc.min(m - ic);
                        pack_a_i16(ai.sub(ic, mb, pc, kb), &mut packed_a[..]);
                        let mut jr = 0;
                        while jr < nb {
                            let nr = nrw.min(nb - jr);
                            let bp = &b_tile[(jr / nrw) * kb * nrw..(jr / nrw + 1) * kb * nrw];
                            let mut ir = 0;
                            while ir < mb {
                                let mr = MR.min(mb - ir);
                                let ap =
                                    &packed_a[(ir / MR) * kb * MR..(ir / MR + 1) * kb * MR];
                                if mr == MR {
                                    micro::kernel_i16(backend, ap, bp, kb, &mut acc);
                                } else {
                                    micro::kernel_edge_i16(backend, ap, bp, kb, &mut acc, mr);
                                }
                                for r in 0..mr {
                                    let crow = (ic + ir + r) * ci.rs + jc + jr;
                                    for col in 0..nr {
                                        ci.data[crow + col] +=
                                            ep.at(jc + jr + col) * acc[r * nrw + col] as f32;
                                    }
                                }
                                ir += MR;
                            }
                            jr += nrw;
                        }
                        ic += bs.mc;
                    }
                }
                jc += bs.nc;
                jb_idx += 1;
            }
            pc += bs.kc;
            pb_idx += 1;
        }
    });
}

/// Serial blocked q16 gemm over one row panel: C += ep·(Aq × tiles of
/// PBq); beta already applied by the caller.
fn gemm_serial_prepacked_i16(
    a: MatRefI16<'_>,
    pb: &PackedBI16,
    c: &mut MatMut<'_>,
    ep: Q16Epilogue<'_>,
) {
    let (m, k) = (a.rows, a.cols);
    let n = c.cols;
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let bs = pb.bs;
    let backend = pb.backend;
    let nrw = backend.nr();
    SCRATCH_I16.with(|scratch| {
        let packed_a = &mut *scratch.borrow_mut();
        let pa_len = bs.mc.min(m).next_multiple_of(MR) * bs.kc.min(k);
        if packed_a.len() < pa_len {
            packed_a.resize(pa_len, 0);
        }
        let mut acc = [0i32; MR * NR_MAX];
        let mut pc = 0;
        let mut pb_idx = 0;
        while pc < k {
            let kb = bs.kc.min(k - pc);
            let mut jc = 0;
            let mut jb_idx = 0;
            while jc < n {
                let nb = bs.nc.min(n - jc);
                let b_tile = pb.tile(pb_idx, jb_idx);
                let mut ic = 0;
                while ic < m {
                    let mb = bs.mc.min(m - ic);
                    pack_a_i16(a.sub(ic, mb, pc, kb), &mut packed_a[..]);
                    let mut jr = 0;
                    while jr < nb {
                        let nr = nrw.min(nb - jr);
                        let bp = &b_tile[(jr / nrw) * kb * nrw..(jr / nrw + 1) * kb * nrw];
                        let mut ir = 0;
                        while ir < mb {
                            let mr = MR.min(mb - ir);
                            let ap = &packed_a[(ir / MR) * kb * MR..(ir / MR + 1) * kb * MR];
                            if mr == MR {
                                micro::kernel_i16(backend, ap, bp, kb, &mut acc);
                            } else {
                                micro::kernel_edge_i16(backend, ap, bp, kb, &mut acc, mr);
                            }
                            for r in 0..mr {
                                let crow = (ic + ir + r) * c.rs + jc + jr;
                                for col in 0..nr {
                                    c.data[crow + col] +=
                                        ep.at(jc + jr + col) * acc[r * nrw + col] as f32;
                                }
                            }
                            ir += MR;
                        }
                        jr += nrw;
                    }
                    ic += bs.mc;
                }
                jc += bs.nc;
                jb_idx += 1;
            }
            pc += bs.kc;
            pb_idx += 1;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Naive fixed-point reference: the exact per-product rounded shift
    /// the micro-kernel performs, so blocked results must match bitwise.
    fn reference_q15(a: &MatRefI16<'_>, b: &[i16], n: usize, c: &mut [f32], scale: f32) {
        for i in 0..a.rows {
            for j in 0..n {
                let mut s = 0i32;
                for p in 0..a.cols {
                    s += (a.at(i, p) as i32 * b[p * n + j] as i32 + (1 << 14)) >> 15;
                }
                c[i * n + j] = scale * s as f32;
            }
        }
    }

    fn random_q(rng: &mut Rng, len: usize) -> Vec<i16> {
        (0..len)
            .map(|_| (rng.range(0, 2 * 32767 + 1) as i32 - 32767) as i16)
            .collect()
    }

    #[test]
    fn prepacked_i16_matches_reference_exactly() {
        let mut rng = Rng::new(0x916);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 13, 9), (5, 64, 3), (33, 21, 19)] {
            let a = random_q(&mut rng, m * k);
            let b = random_q(&mut rng, k * n);
            let bs = BlockSizes { mc: 8, kc: 8, nc: 8 };
            let pb = PackedBI16::pack(MatRefI16::new(&b, k, n), bs);
            let scale = 3.1e-9f32;
            let mut got = vec![0.5f32; m * n]; // non-zero: exercises beta=0
            gemm_prepacked_i16(
                MatRefI16::new(&a, m, k),
                &pb,
                &mut MatMut::new(&mut got, m, n),
                Q16Epilogue::uniform(scale),
            );
            let mut want = vec![0.0f32; m * n];
            reference_q15(&MatRefI16::new(&a, m, k), &b, n, &mut want, scale);
            // Integer accumulation is exact; the only float op is the
            // final scale-multiply, identical on both sides... except the
            // blocked path adds per-k-block partial dequants. Compare with
            // a tight absolute tolerance instead of bitwise.
            for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= scale * 4.0 + w.abs() * 1e-6,
                    "({m},{k},{n}) elem {i}: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn prepacked_ex_i16_matches_serial_bitwise() {
        let mut rng = Rng::new(0x917);
        let (m, k, n) = (37, 29, 21);
        let a = random_q(&mut rng, m * k);
        let b = random_q(&mut rng, k * n);
        let bs = BlockSizes { mc: 16, kc: 8, nc: 12 };
        let pb = PackedBI16::pack(MatRefI16::new(&b, k, n), bs);
        let scale = 1.7e-9f32;
        let mut want = vec![0.0f32; m * n];
        gemm_prepacked_i16(
            MatRefI16::new(&a, m, k),
            &pb,
            &mut MatMut::new(&mut want, m, n),
            Q16Epilogue::uniform(scale),
        );
        for threads in [2usize, 3, 8] {
            let mut got = vec![0.25f32; m * n];
            gemm_prepacked_ex_i16(
                MatRefI16::new(&a, m, k),
                &pb,
                &mut MatMut::new(&mut got, m, n),
                Q16Epilogue::uniform(scale),
                &Parallelism::new(threads),
            );
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn batch_i16_matches_per_entry_serial() {
        let mut rng = Rng::new(0x918);
        let (m, k, n) = (5, 18, 6);
        let b = random_q(&mut rng, k * n);
        let bs = BlockSizes { mc: 4, kc: 7, nc: 5 };
        let pb = PackedBI16::pack(MatRefI16::new(&b, k, n), bs);
        let scale = 2.5e-9f32;
        let a_bufs: Vec<Vec<i16>> = (0..4).map(|_| random_q(&mut rng, m * k)).collect();
        let mut expected = Vec::new();
        for abuf in &a_bufs {
            let mut c = vec![0.0f32; m * n];
            gemm_prepacked_i16(
                MatRefI16::new(abuf, m, k),
                &pb,
                &mut MatMut::new(&mut c, m, n),
                Q16Epilogue::uniform(scale),
            );
            expected.push(c);
        }
        let mut c_bufs: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0f32; m * n]).collect();
        {
            let a_refs: Vec<MatRefI16<'_>> =
                a_bufs.iter().map(|v| MatRefI16::new(v, m, k)).collect();
            let mut c_refs: Vec<MatMut<'_>> =
                c_bufs.iter_mut().map(|v| MatMut::new(v, m, n)).collect();
            gemm_prepacked_batch_i16(&a_refs, &pb, &mut c_refs, Q16Epilogue::uniform(scale));
        }
        for (got, want) in c_bufs.iter().zip(&expected) {
            assert_eq!(got, want);
        }
    }

    #[test]
    fn per_column_scales_apply_to_the_matching_output_column() {
        // One distinct scale per output column; every path (serial,
        // threaded, batched) must multiply column j by per_col[j].
        let mut rng = Rng::new(0x91a);
        let (m, k, n) = (9, 11, 5);
        let a = random_q(&mut rng, m * k);
        let b = random_q(&mut rng, k * n);
        let bs = BlockSizes { mc: 4, kc: 4, nc: 3 };
        let pb = PackedBI16::pack(MatRefI16::new(&b, k, n), bs);
        let global = 2.0e-9f32;
        let per_col: Vec<f32> = (0..n).map(|j| 1.0 + j as f32 * 0.5).collect();
        let ep = Q16Epilogue {
            global,
            per_col: Some(&per_col),
        };
        // Reference: uniform gemm at scale `global`, scaled per column.
        let mut base = vec![0.0f32; m * n];
        gemm_prepacked_i16(
            MatRefI16::new(&a, m, k),
            &pb,
            &mut MatMut::new(&mut base, m, n),
            Q16Epilogue::uniform(global),
        );
        let want: Vec<f32> = base
            .iter()
            .enumerate()
            .map(|(i, &v)| v * per_col[i % n])
            .collect();
        let mut got = vec![0.0f32; m * n];
        gemm_prepacked_i16(
            MatRefI16::new(&a, m, k),
            &pb,
            &mut MatMut::new(&mut got, m, n),
            ep,
        );
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= w.abs() * 1e-5 + 1e-12,
                "serial elem {i}: {g} vs {w}"
            );
        }
        let mut got_t = vec![0.0f32; m * n];
        gemm_prepacked_ex_i16(
            MatRefI16::new(&a, m, k),
            &pb,
            &mut MatMut::new(&mut got_t, m, n),
            ep,
            &Parallelism::new(3),
        );
        assert_eq!(got_t, got, "threaded per-col path");
        let mut got_b = vec![1.0f32; m * n];
        {
            let a_refs = [MatRefI16::new(&a, m, k)];
            let mut c_refs = [MatMut::new(&mut got_b, m, n)];
            gemm_prepacked_batch_i16(&a_refs, &pb, &mut c_refs, ep);
        }
        assert_eq!(got_b, got, "batched per-col path");
    }

    #[test]
    fn strided_views_support_the_ld_trick() {
        // A view into a wider i16 buffer (MEC's overlapping partitions).
        let mut rng = Rng::new(0x919);
        let big = random_q(&mut rng, 10 * 20);
        let a = MatRefI16::strided(&big[3..], 6, 7, 20);
        let b = random_q(&mut rng, 7 * 4);
        let pb = PackedBI16::pack(MatRefI16::new(&b, 7, 4), BlockSizes::default());
        let scale = 1e-9f32;
        let mut got = vec![0.0f32; 6 * 4];
        gemm_prepacked_i16(
            a,
            &pb,
            &mut MatMut::new(&mut got, 6, 4),
            Q16Epilogue::uniform(scale),
        );
        let mut want = vec![0.0f32; 6 * 4];
        reference_q15(&a, &b, 4, &mut want, scale);
        for (&g, &w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= scale * 2.0, "{g} vs {w}");
        }
    }

    #[test]
    fn pack_layouts_mirror_f32_packers() {
        const NR: usize = 8;
        // pack_a_i16: 3x2 inside rs=4.
        let buf: Vec<i16> = (0..12).collect();
        let a = MatRefI16::strided(&buf, 3, 2, 4);
        let mut out = vec![-1i16; MR * 2];
        pack_a_i16(a, &mut out);
        assert_eq!(&out[0..MR], &[0, 4, 8, 0, 0, 0, 0, 0]);
        assert_eq!(&out[MR..2 * MR], &[1, 5, 9, 0, 0, 0, 0, 0]);
        // pack_b_i16: 2x3 strided rs=5.
        let buf: Vec<i16> = (0..10).collect();
        let b = MatRefI16::strided(&buf, 2, 3, 5);
        let mut out = vec![-1i16; 2 * NR];
        pack_b_i16(b, &mut out, NR);
        assert_eq!(&out[0..NR], &[0, 1, 2, 0, 0, 0, 0, 0]);
        assert_eq!(&out[NR..2 * NR], &[5, 6, 7, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn packed_b_bytes_halve_f32() {
        // Both packs use the active backend, so strip widths match and
        // the i16 copy is exactly half the bytes.
        let b: Vec<i16> = vec![1; 16 * 24];
        let pb = PackedBI16::pack(MatRefI16::new(&b, 16, 24), BlockSizes::default());
        let bf: Vec<f32> = vec![1.0; 16 * 24];
        let pf = super::super::PackedB::pack(
            super::super::MatRef::new(&bf, 16, 24),
            BlockSizes::default(),
        );
        assert_eq!(pb.bytes() * 2, pf.bytes());
        assert_eq!(pb.backend(), pf.backend());
    }

    #[test]
    #[should_panic(expected = "accumulator bound")]
    fn pack_rejects_overdeep_reduction() {
        let b = vec![0i16; (1 << 15) + 1];
        let _ = PackedBI16::pack(MatRefI16::new(&b, (1 << 15) + 1, 1), BlockSizes::default());
    }
}
