//! Empirical algorithm selection: measure admissible algorithms on the
//! real geometry, cache the winner per shape. This is what frameworks do
//! at model-load time (cuDNN's `FindAlgorithm` vs `GetAlgorithm`), and it
//! subsumes cost-model error at the price of a one-time measurement.
//!
//! Measurement is **plan-amortized**: each candidate is planned once
//! (prepacking measured separately as `plan_ns`) and timed on repeated
//! `execute` calls against a pre-sized arena — the steady-state serving
//! cost, which is what the tuner should be ranking.

use super::{Plan, Planner};
use crate::conv::{AlgoKind, ConvContext, ConvPlan, Convolution};
use crate::memory::{Arena, Budget};
use crate::tensor::quant::Precision;
use crate::tensor::{ConvShape, Kernel, Tensor};
use crate::util::Rng;
use std::collections::HashMap;
use std::time::Instant;

/// Measured timing for one algorithm on one shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Measurement {
    pub algo: AlgoKind,
    pub workspace_bytes: usize,
    /// One-time cost of building the plan (prepack/transform).
    pub plan_ns: f64,
    /// Median steady-state execute time.
    pub median_ns: f64,
}

/// Measure-and-cache selector.
pub struct AutoTuner {
    planner: Planner,
    /// Repetitions per candidate (median taken).
    pub reps: usize,
    cache: HashMap<(ConvShape, usize, Precision), Plan>,
}

impl AutoTuner {
    pub fn new() -> AutoTuner {
        AutoTuner {
            planner: Planner::new(),
            reps: 3,
            cache: HashMap::new(),
        }
    }

    /// Measure every admissible algorithm on `shape` (random data):
    /// plan once, warm once, then time `reps` executes.
    pub fn measure_all(
        &self,
        shape: &ConvShape,
        budget: &Budget,
        ctx: &ConvContext,
    ) -> Vec<Measurement> {
        let mut rng = Rng::new(0x7e57);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut out = Tensor::zeros(shape.output());
        let mut results = Vec::new();
        for candidate in self.planner.admissible(shape, budget, ctx) {
            let algo = candidate.algo.build();
            let t_plan = Instant::now();
            let plan = algo.plan(ctx, shape, &kernel);
            let plan_ns = t_plan.elapsed().as_nanos() as f64;
            let mut arena = Arena::with_capacity(plan.workspace_elems());
            // Warmup (faults pages, fills caches).
            plan.execute(&input, &mut arena, &mut out);
            let mut times: Vec<f64> = Vec::with_capacity(self.reps);
            for _ in 0..self.reps {
                let t0 = Instant::now();
                plan.execute(&input, &mut arena, &mut out);
                times.push(t0.elapsed().as_nanos() as f64);
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            results.push(Measurement {
                algo: candidate.algo,
                workspace_bytes: candidate.workspace_bytes,
                plan_ns,
                median_ns: times[times.len() / 2],
            });
        }
        results
    }

    /// Best measured plan for `shape` under `budget`, cached per
    /// `(shape, budget.limit, ctx.precision)`.
    pub fn tune(&mut self, shape: &ConvShape, budget: &Budget, ctx: &ConvContext) -> Plan {
        let key = (*shape, budget.limit(), ctx.precision);
        if let Some(p) = self.cache.get(&key) {
            return p.clone();
        }
        let measured = self.measure_all(shape, budget, ctx);
        let best = measured
            .into_iter()
            .min_by(|a, b| a.median_ns.partial_cmp(&b.median_ns).unwrap())
            .expect("direct always admissible");
        let plan = Plan {
            algo: best.algo,
            workspace_bytes: best.workspace_bytes,
            est_ns: best.median_ns,
        };
        self.cache.insert(key, plan.clone());
        plan
    }

    pub fn cached_plans(&self) -> usize {
        self.cache.len()
    }
}

impl Default for AutoTuner {
    fn default() -> Self {
        AutoTuner::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{KernelShape, Nhwc};

    fn small_shape() -> ConvShape {
        ConvShape::new(Nhwc::new(1, 12, 12, 4), KernelShape::new(3, 3, 4, 8), 1, 1)
    }

    #[test]
    fn measures_all_admissible() {
        let tuner = AutoTuner::new();
        let ms = tuner.measure_all(&small_shape(), &Budget::unlimited(), &ConvContext::default());
        // direct, im2col, mec, winograd, fft, indirect, kn2row, smm all
        // support this shape.
        assert_eq!(ms.len(), 8);
        assert!(ms.iter().all(|m| m.median_ns > 0.0));
        // Plan time is measured for every candidate (zero-work plans like
        // direct may round to ~0, but the field must be populated ≥ 0).
        assert!(ms.iter().all(|m| m.plan_ns >= 0.0));
    }

    #[test]
    fn tune_caches() {
        let mut tuner = AutoTuner::new();
        let ctx = ConvContext::default();
        let p1 = tuner.tune(&small_shape(), &Budget::unlimited(), &ctx);
        assert_eq!(tuner.cached_plans(), 1);
        let p2 = tuner.tune(&small_shape(), &Budget::unlimited(), &ctx);
        assert_eq!(tuner.cached_plans(), 1);
        assert_eq!(p1, p2);
    }

    #[test]
    fn tune_respects_budget() {
        let mut tuner = AutoTuner::new();
        let ctx = ConvContext::default();
        let plan = tuner.tune(&small_shape(), &Budget::new(0), &ctx);
        // Budget 0 admits the zero-workspace family (direct, kn2row,
        // smm); whichever measured fastest, it must cost no workspace.
        assert!(matches!(
            plan.algo,
            AlgoKind::Direct | AlgoKind::Kn2row | AlgoKind::SmmConv
        ));
        assert_eq!(plan.workspace_bytes, 0);
    }

    #[test]
    fn q16_measures_only_quantized_candidates() {
        use crate::tensor::Precision;
        let tuner = AutoTuner::new();
        let ctx = ConvContext::default().with_precision(Precision::Q16);
        let ms = tuner.measure_all(&small_shape(), &Budget::unlimited(), &ctx);
        // direct, im2col, mec, indirect — winograd/fft/kn2row/smm
        // excluded under q16.
        assert_eq!(ms.len(), 4);
        assert!(ms.iter().all(|m| m.algo.supports_precision(Precision::Q16)));
    }
}
