//! Algorithm planning under a memory budget.
//!
//! The paper's motivation (§1) is deployment on memory-constrained
//! devices; this module makes that operational, cuDNN-style: given a
//! convolution geometry and a device [`Budget`], choose the fastest
//! algorithm whose **workspace fits**. Two selectors:
//!
//! * [`CostModel`] — analytic: FLOPs through the GEMM roofline plus
//!   lowering/transform byte traffic (calibrated coefficients; zero
//!   measurement cost).
//! * [`AutoTuner`] — empirical: measure each admissible algorithm on the
//!   real geometry once and cache the winner (what production frameworks
//!   do at model-load time).

// Planning is pure computation over shapes and costs: no unsafe, ever
// (enforced — see the crate-level unsafe policy and tools/unsafe-audit).
#![forbid(unsafe_code)]

pub mod autotune;

pub use autotune::{AutoTuner, Measurement};

use crate::conv::{AlgoKind, ConvContext, ConvPlan, Convolution};
use crate::gemm::KernelBackend;
use crate::memory::Budget;
use crate::tensor::quant::Precision;
use crate::tensor::{ConvShape, Kernel};
use crate::threadpool::GrainModel;

/// The outcome of planning one convolution.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub algo: AlgoKind,
    pub workspace_bytes: usize,
    /// Estimated (cost model) or measured (autotuner) runtime in ns.
    pub est_ns: f64,
}

/// Why a *forced* algorithm choice cannot run on a geometry under a
/// budget and context — the typed rejection
/// [`Engine::builder`](crate::engine::Engine::builder) surfaces for an
/// `algo_override` instead of a mid-run panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlanError {
    /// The algorithm does not support the geometry (e.g. Winograd
    /// F(2×2,3×3) off 3×3/stride-1).
    UnsupportedGeometry { algo: AlgoKind, shape: String },
    /// The algorithm has no execution path for the requested precision
    /// (Winograd/FFT under q16).
    UnsupportedPrecision { algo: AlgoKind, precision: Precision },
    /// The algorithm's workspace exceeds the memory budget.
    BudgetExceeded {
        algo: AlgoKind,
        workspace_bytes: usize,
        limit: usize,
    },
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::UnsupportedGeometry { algo, shape } => {
                write!(f, "{} does not support {shape}", algo.name())
            }
            PlanError::UnsupportedPrecision { algo, precision } => write!(
                f,
                "{} has no {precision} path (q16 covers direct/im2col/mec/indirect)",
                algo.name()
            ),
            PlanError::BudgetExceeded {
                algo,
                workspace_bytes,
                limit,
            } => write!(
                f,
                "{} needs a {workspace_bytes} B workspace, over the {limit} B budget",
                algo.name()
            ),
        }
    }
}

impl std::error::Error for PlanError {}

/// Analytic cost model. Units are abstract "ns" — only *ratios* matter
/// for selection; coefficients were calibrated once against the bench
/// harness on the dev host (see EXPERIMENTS.md §Planner).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// ns per multiply-add through the blocked GEMM.
    pub ns_per_mac: f64,
    /// ns per multiply-add through the direct loop nest (no blocking,
    /// poor locality — empirically ~6-10x worse than GEMM).
    pub ns_per_mac_direct: f64,
    /// ns per multiply-add through SMM-Conv's zero-packing scalar-matrix
    /// stream: contiguous autovectorized `k_c` runs but no register
    /// blocking, so it lands between the micro-kernel GEMM and the
    /// direct nest, and scales with the backend only partially (the
    /// compiler vectorizes the inner loop, the outer stream stays
    /// scalar).
    pub ns_per_mac_smm: f64,
    /// ns per byte moved by lowering/transform/repack loops.
    pub ns_per_byte_moved: f64,
    /// Fixed overhead per GEMM call (matters for MEC Solution B's
    /// `i_n·o_h` small calls — the paper's T-threshold trade-off).
    pub ns_per_gemm_call: f64,
    /// ns per complex butterfly in FFT transforms.
    pub ns_per_butterfly: f64,
    /// The micro-kernel register-tile rows the estimates assume — per
    /// backend ([`CostModel::for_backend`]), observability for the engine
    /// report and benches.
    pub tile_mr: usize,
    /// The micro-kernel register-tile columns (backend strip width).
    pub tile_nr: usize,
}

impl Default for CostModel {
    /// Calibrated for the micro-kernel backend the runtime dispatch
    /// selected on this host ([`KernelBackend::active`], overridable via
    /// `MEC_KERNEL`).
    fn default() -> Self {
        CostModel::for_backend(KernelBackend::active())
    }
}

impl CostModel {
    /// Coefficients for a specific micro-kernel backend. The scalar base
    /// (0.45 ns/MAC) was calibrated on the dev host; the explicit SIMD
    /// tiles multiply GEMM throughput without touching the byte-traffic
    /// or dispatch terms (lowering is scalar copies either way), so only
    /// `ns_per_mac` and the advertised tile shape vary per backend.
    pub fn for_backend(backend: KernelBackend) -> CostModel {
        let simd = match backend {
            KernelBackend::Scalar => 1.0,
            KernelBackend::Avx2 | KernelBackend::Neon => 1.6,
            KernelBackend::Avx512 => 2.4,
        };
        CostModel {
            ns_per_mac: 0.45 / simd,
            ns_per_mac_direct: 2.8,
            ns_per_mac_smm: 1.4 / (0.5 + 0.5 * simd),
            ns_per_byte_moved: 0.25,
            ns_per_gemm_call: 800.0,
            ns_per_butterfly: 4.0,
            tile_mr: crate::gemm::micro::MR,
            tile_nr: backend.nr(),
        }
    }
    /// The threading grain derived from this cost model: the same
    /// calibrated coefficients that rank algorithms also decide when a
    /// parallel loop is too small to pay a pool wake-up
    /// ([`Parallelism`](crate::threadpool::Parallelism)'s inline fast
    /// path). The dispatch figure models publish + wake + completion
    /// barrier of the parked pool — a few GEMM-call overheads, far below
    /// a thread spawn.
    pub fn grain_model(&self) -> GrainModel {
        GrainModel {
            ns_per_mac: self.ns_per_mac,
            ns_per_byte: self.ns_per_byte_moved,
            dispatch_ns: 5.0 * self.ns_per_gemm_call,
        }
    }

    /// One-time plan cost of `algo` on `shape`: kernel packing, filter
    /// transforms, kernel spectra. Paid at model load, amortized across
    /// every `execute` — the planner ranks by [`Self::estimate_ns`]
    /// (steady-state) and reports this separately.
    pub fn estimate_plan_ns(&self, algo: AlgoKind, shape: &ConvShape) -> f64 {
        let k = shape.kernel;
        let kernel_bytes = (k.len() * 4) as f64;
        match algo {
            AlgoKind::Direct => 0.0,
            // PackedB::pack: one read + one write of the kernel matrix.
            AlgoKind::Im2col
            | AlgoKind::Mec
            | AlgoKind::MecSolutionA
            | AlgoKind::MecSolutionB => 2.0 * kernel_bytes * self.ns_per_byte_moved,
            // U = G g Gᵀ per (i, o): ~32 mul-adds each, plus (chunked)
            // the 16 transpose+pack copies.
            AlgoKind::Winograd | AlgoKind::WinogradChunked => {
                let u_elems = (16 * k.kc * k.ic) as f64;
                32.0 * (k.kc * k.ic) as f64 * self.ns_per_mac
                    + 4.0 * u_elems * self.ns_per_byte_moved
            }
            // One padded 2-D FFT per (i, o) kernel slice.
            AlgoKind::Fft => {
                let (ph, pw) = crate::conv::fft_conv::fft_grid(shape);
                let grid = (ph * pw) as f64;
                (k.ic * k.kc) as f64 * grid * grid.log2().max(1.0) * self.ns_per_butterfly
            }
            // PackedB::pack of the same kernel matrix as im2col, plus
            // writing the o_h·k_h·k_w indirection buffer.
            AlgoKind::Indirect => {
                let table_bytes = (shape.oh() * k.kh * k.kw * 8) as f64;
                (2.0 * kernel_bytes + table_bytes) * self.ns_per_byte_moved
            }
            // k_h·k_w pointwise PackedB::packs — the same total kernel
            // bytes, re-blocked per position.
            AlgoKind::Kn2row => 2.0 * kernel_bytes * self.ns_per_byte_moved,
            // Zero packing: the plan only clones the kernel.
            AlgoKind::SmmConv => 2.0 * kernel_bytes * self.ns_per_byte_moved,
        }
    }

    /// Estimate runtime of `algo` on `shape` (single thread; the planner
    /// divides by an efficiency-discounted thread count). F32 grid; the
    /// precision-aware planner path goes through
    /// [`Self::estimate_ns_prec`].
    pub fn estimate_ns(&self, algo: AlgoKind, shape: &ConvShape) -> f64 {
        self.estimate_ns_prec(algo, shape, Precision::F32)
    }

    /// Precision-aware runtime estimate: the lowering/repack byte-traffic
    /// terms scale with the operand width (q16 moves half the bytes
    /// through the same compact L — the paper's fixed-point argument),
    /// while MAC and per-call terms are precision-neutral on this
    /// substrate. Winograd/FFT have no q16 path, so their estimates are
    /// always the f32 figures.
    pub fn estimate_ns_prec(&self, algo: AlgoKind, shape: &ConvShape, precision: Precision) -> f64 {
        let macs = shape.macs() as f64;
        let bpe = precision.bytes_per_elem() as f64;
        let out_bytes = (shape.output().len() * 4) as f64;
        match algo {
            AlgoKind::Direct => macs * self.ns_per_mac_direct,
            AlgoKind::Im2col => {
                let lowered = shape.im2col_lowered_elems() as f64 * bpe;
                // write L + read L in gemm (cache reuse folded into
                // ns_per_mac) + one gemm call.
                lowered * self.ns_per_byte_moved + macs * self.ns_per_mac + self.ns_per_gemm_call
            }
            AlgoKind::Mec | AlgoKind::MecSolutionA | AlgoKind::MecSolutionB => {
                let lowered = shape.mec_lowered_elems() as f64 * bpe;
                // Model the Algorithm-2 line-8 dispatch for the auto
                // variant with the SAME precision-aware availability
                // predicate Mec::resolve uses (one definition, no drift);
                // T is the default 100 here — the cost model has no ctx.
                let solution_a = match algo {
                    AlgoKind::MecSolutionA => true,
                    AlgoKind::MecSolutionB => false,
                    _ => {
                        shape.ow() <= 100
                            && crate::conv::mec::solution_a_available_p(shape, precision)
                    }
                };
                let calls = if solution_a {
                    shape.oh() as f64
                } else {
                    (shape.input.n * shape.oh()) as f64
                };
                let repack = if solution_a { 2.0 * out_bytes } else { 0.0 };
                lowered * self.ns_per_byte_moved
                    + macs * self.ns_per_mac
                    + calls * self.ns_per_gemm_call
                    + repack * self.ns_per_byte_moved
            }
            AlgoKind::Winograd | AlgoKind::WinogradChunked => {
                // 16/36 of the direct multiplies go through gemm, plus
                // transform traffic over U/V/M.
                let p = crate::conv::winograd::tile_count(shape) as f64;
                let k = shape.kernel;
                let gemm_macs = 16.0 * k.kc as f64 * k.ic as f64 * p;
                let transform_bytes =
                    (16.0 * (k.kc * k.ic) as f64 + 32.0 * (k.ic as f64 + k.kc as f64) * p) * 4.0;
                gemm_macs * self.ns_per_mac
                    + transform_bytes * self.ns_per_byte_moved * 2.0
                    + 16.0 * self.ns_per_gemm_call
            }
            AlgoKind::Fft => {
                let (ph, pw) = crate::conv::fft_conv::fft_grid(shape);
                let grid = (ph * pw) as f64;
                let log2 = grid.log2().max(1.0);
                let k = shape.kernel;
                let n = shape.input.n as f64;
                // transforms: ic·kc kernel + n·ic input + n·kc inverse
                let transforms = (k.ic * k.kc) as f64 + n * k.ic as f64 + n * k.kc as f64;
                let pointwise = n * (k.ic * k.kc) as f64 * grid;
                transforms * grid * log2 * self.ns_per_butterfly
                    + pointwise * self.ns_per_mac * 4.0
            }
            AlgoKind::Indirect => {
                // The gather moves the same bytes as im2col's lowering
                // (every receptive-field element copied once, operand
                // width included), but through cache-resident lane
                // strips; then one prepacked GEMM per output row.
                let gathered = shape.im2col_lowered_elems() as f64 * bpe;
                let rows = (shape.input.n * shape.oh()) as f64;
                gathered * self.ns_per_byte_moved
                    + macs * self.ns_per_mac
                    + rows * self.ns_per_gemm_call
            }
            AlgoKind::Kn2row => {
                // No lowering at all: k_h·k_w accumulating pointwise
                // GEMMs per output row. The output row is written once
                // and re-touched per extra kernel position, but it stays
                // cache-resident across positions — charge the first
                // write/read full and each re-touch a quarter.
                let k = shape.kernel;
                let positions = (k.kh * k.kw) as f64;
                let rows = (shape.input.n * shape.oh()) as f64;
                macs * self.ns_per_mac
                    + rows * positions * self.ns_per_gemm_call
                    + out_bytes * (2.0 + 0.25 * (positions - 1.0)) * self.ns_per_byte_moved
            }
            AlgoKind::SmmConv => {
                // Zero packing, zero workspace: every MAC through the
                // scalar-matrix stream, plus one streaming pass over
                // input and output.
                let in_bytes = (shape.input.len() * 4) as f64;
                macs * self.ns_per_mac_smm + (in_bytes + out_bytes) * self.ns_per_byte_moved
            }
        }
    }
}

/// Planner: admissibility (supported + within budget) then cost ranking.
#[derive(Debug, Clone, Default)]
pub struct Planner {
    pub cost: CostModel,
}

impl Planner {
    pub fn new() -> Planner {
        Planner::default()
    }

    /// Algorithms admissible for `shape` under `budget` in the context's
    /// precision, drawn from the full decision menu ([`AlgoKind::MENU`]:
    /// the paper's five systems plus indirect/kn2row/SMM): supported
    /// geometry, workspace within budget, and an execution path for
    /// `ctx.precision` (under q16 Winograd/FFT/kn2row/SMM report
    /// unsupported and the planner falls back to the quantized GEMM
    /// family — `direct` keeps the fallback non-empty).
    pub fn admissible(&self, shape: &ConvShape, budget: &Budget, ctx: &ConvContext) -> Vec<Plan> {
        let mut out = Vec::new();
        for kind in AlgoKind::MENU {
            if !kind.supports_precision(ctx.precision) {
                continue;
            }
            let algo = kind.build();
            if !algo.supports(shape) {
                continue;
            }
            // Precision-aware footprint: q16's halved lowering buffers
            // genuinely relax tight budgets (the paper's fixed-point
            // memory win), instead of admitting on the f32 figure.
            let ws = algo.workspace_bytes_prec(shape, ctx.precision);
            if !budget.allows(ws) {
                continue;
            }
            out.push(Plan {
                algo: kind,
                workspace_bytes: ws,
                est_ns: self.cost.estimate_ns_prec(kind, shape, ctx.precision),
            });
        }
        out
    }

    /// Pick the estimated-fastest admissible algorithm. `direct` has zero
    /// workspace (and runs in every precision), so there is always at
    /// least one plan.
    pub fn plan(&self, shape: &ConvShape, budget: &Budget, ctx: &ConvContext) -> Plan {
        let mut best: Option<Plan> = None;
        for mut p in self.admissible(shape, budget, ctx) {
            // Thread scaling with a 75% parallel-efficiency discount.
            let t = ctx.threads() as f64;
            p.est_ns /= 1.0 + 0.75 * (t - 1.0);
            match &best {
                Some(b) if b.est_ns <= p.est_ns => {}
                _ => best = Some(p),
            }
        }
        best.expect("direct always admissible")
    }

    /// Validate a *forced* algorithm choice (an engine `algo_override`)
    /// on `shape` under `budget` and `ctx`: supported geometry, an
    /// execution path for the context precision, and workspace within
    /// budget. Returns the same [`Plan`] record [`Planner::plan`] would,
    /// or the typed reason the choice is inadmissible.
    pub fn validate_choice(
        &self,
        algo: AlgoKind,
        shape: &ConvShape,
        budget: &Budget,
        ctx: &ConvContext,
    ) -> Result<Plan, PlanError> {
        if !algo.supports_precision(ctx.precision) {
            return Err(PlanError::UnsupportedPrecision {
                algo,
                precision: ctx.precision,
            });
        }
        let built = algo.build();
        if !built.supports(shape) {
            return Err(PlanError::UnsupportedGeometry {
                algo,
                shape: shape.describe(),
            });
        }
        let ws = built.workspace_bytes_prec(shape, ctx.precision);
        if !budget.allows(ws) {
            return Err(PlanError::BudgetExceeded {
                algo,
                workspace_bytes: ws,
                limit: budget.limit(),
            });
        }
        Ok(Plan {
            algo,
            workspace_bytes: ws,
            est_ns: self.cost.estimate_ns_prec(algo, shape, ctx.precision),
        })
    }

    /// Plan straight to an executable [`ConvPlan`]: pick the algorithm
    /// under the budget, then prepack `kernel` for it. This is what
    /// `Model::plan` runs per conv layer at load time.
    pub fn plan_conv(
        &self,
        shape: &ConvShape,
        budget: &Budget,
        ctx: &ConvContext,
        kernel: &Kernel,
    ) -> Box<dyn ConvPlan> {
        let chosen = self.plan(shape, budget, ctx);
        chosen.algo.build().plan(ctx, shape, kernel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{KernelShape, Nhwc};

    fn cv6() -> ConvShape {
        ConvShape::new(
            Nhwc::new(1, 12, 12, 256),
            KernelShape::new(3, 3, 256, 512),
            1,
            1,
        )
    }

    #[test]
    fn zero_workspace_family_admissible_at_budget_zero() {
        // A zero budget used to leave only direct; kn2row and SMM share
        // its end of the memory axis now, so tight-budget fallback no
        // longer means the slowest loop nest.
        let p = Planner::new();
        let plans = p.admissible(&cv6(), &Budget::new(0), &ConvContext::default());
        let algos: Vec<AlgoKind> = plans.iter().map(|pl| pl.algo).collect();
        assert_eq!(
            algos,
            vec![AlgoKind::Direct, AlgoKind::Kn2row, AlgoKind::SmmConv]
        );
        assert!(plans.iter().all(|pl| pl.workspace_bytes == 0));
        // direct stays the universal floor in every precision.
        let q16 = ConvContext::default().with_precision(crate::tensor::Precision::Q16);
        let q16_plans = p.admissible(&cv6(), &Budget::new(0), &q16);
        assert_eq!(q16_plans.len(), 1);
        assert_eq!(q16_plans[0].algo, AlgoKind::Direct);
    }

    #[test]
    fn budget_excludes_hungry_algorithms() {
        let p = Planner::new();
        let shape = cv6();
        let mec_bytes = AlgoKind::Mec.build().workspace_bytes(&shape);
        let im2col_bytes = AlgoKind::Im2col.build().workspace_bytes(&shape);
        assert!(mec_bytes < im2col_bytes);
        // Budget between MEC and im2col: plan must avoid im2col.
        let budget = Budget::new((mec_bytes + im2col_bytes) / 2);
        let plan = p.plan(&shape, &budget, &ConvContext::default());
        assert_ne!(plan.algo, AlgoKind::Im2col);
        assert!(plan.workspace_bytes <= budget.limit());
    }

    #[test]
    fn unlimited_budget_prefers_gemm_family_over_direct() {
        let p = Planner::new();
        let plan = p.plan(&cv6(), &Budget::unlimited(), &ConvContext::default());
        assert_ne!(plan.algo, AlgoKind::Direct, "{plan:?}");
    }

    #[test]
    fn winograd_not_offered_for_non_3x3() {
        let p = Planner::new();
        let shape = ConvShape::new(
            Nhwc::new(1, 227, 227, 3),
            KernelShape::new(11, 11, 3, 96),
            4,
            4,
        );
        assert!(p
            .admissible(&shape, &Budget::unlimited(), &ConvContext::default())
            .iter()
            .all(|pl| pl.algo != AlgoKind::Winograd));
    }

    #[test]
    fn q16_excludes_winograd_and_fft() {
        let p = Planner::new();
        let ctx = ConvContext::default().with_precision(crate::tensor::Precision::Q16);
        let plans = p.admissible(&cv6(), &Budget::unlimited(), &ctx);
        assert!(!plans.is_empty());
        for pl in &plans {
            assert!(
                pl.algo.supports_precision(crate::tensor::Precision::Q16),
                "{:?} offered under q16",
                pl.algo
            );
        }
        // The fallback still prefers the quantized GEMM family to direct.
        let chosen = p.plan(&cv6(), &Budget::unlimited(), &ctx);
        assert!(matches!(chosen.algo, AlgoKind::Mec | AlgoKind::Im2col), "{chosen:?}");
    }

    #[test]
    fn q16_budget_admits_halved_lowering() {
        // A budget between the q16 and f32 MEC footprints: the f32
        // planner must fall back to direct, while the q16 planner keeps
        // the quantized GEMM family — the paper's fixed-point memory win
        // made operational.
        let p = Planner::new();
        let shape = cv6();
        let f32_mec = AlgoKind::Mec.build().workspace_bytes(&shape);
        let budget = Budget::new(f32_mec / 2 + f32_mec / 8);
        let f32_plan = p.plan(&shape, &budget, &ConvContext::default());
        // The f32 planner loses the whole lowering family to the budget
        // (its best remaining option is the zero-workspace tier) ...
        assert!(
            !matches!(f32_plan.algo, AlgoKind::Mec | AlgoKind::Im2col | AlgoKind::Indirect),
            "{f32_plan:?}"
        );
        let q16_ctx = ConvContext::default().with_precision(crate::tensor::Precision::Q16);
        let q16_plan = p.plan(&shape, &budget, &q16_ctx);
        assert!(
            matches!(q16_plan.algo, AlgoKind::Mec | AlgoKind::Im2col),
            "{q16_plan:?}"
        );
        assert!(q16_plan.workspace_bytes <= budget.limit());
    }

    #[test]
    fn q16_halves_the_bytes_moved_term() {
        // The estimate's lowering-traffic term must shrink under q16 —
        // MEC and im2col both get cheaper, direct is unchanged.
        let cm = CostModel::default();
        let s = cv6();
        use crate::tensor::Precision;
        for algo in [AlgoKind::Mec, AlgoKind::Im2col] {
            assert!(
                cm.estimate_ns_prec(algo, &s, Precision::Q16)
                    < cm.estimate_ns_prec(algo, &s, Precision::F32),
                "{algo:?}"
            );
        }
        assert_eq!(
            cm.estimate_ns_prec(AlgoKind::Direct, &s, Precision::Q16),
            cm.estimate_ns(AlgoKind::Direct, &s)
        );
        // And the f32 delegate agrees with the old signature.
        assert_eq!(
            cm.estimate_ns(AlgoKind::Mec, &s),
            cm.estimate_ns_prec(AlgoKind::Mec, &s, Precision::F32)
        );
    }

    #[test]
    fn mec_estimated_cheaper_than_im2col_when_overlapping() {
        // The cost model must reflect the paper's core claim: fewer bytes
        // moved -> faster, same MACs.
        let cm = CostModel::default();
        let shape = cv6();
        assert!(cm.estimate_ns(AlgoKind::Mec, &shape) < cm.estimate_ns(AlgoKind::Im2col, &shape));
    }

    #[test]
    fn plan_conv_returns_executable_plan_within_budget() {
        let p = Planner::new();
        let shape = cv6();
        let kernel = crate::tensor::Kernel::zeros(shape.kernel);
        let budget = Budget::new(AlgoKind::Mec.build().workspace_bytes(&shape));
        let plan = p.plan_conv(&shape, &budget, &ConvContext::default(), &kernel);
        assert!(plan.workspace_bytes() <= budget.limit());
        assert_eq!(plan.shape(), &shape);
    }

    #[test]
    fn plan_time_is_one_time_cost_only() {
        let cm = CostModel::default();
        let shape = cv6();
        // Direct has nothing to prepack; everyone else pays something,
        // and plan cost must be far below a single execute.
        assert_eq!(cm.estimate_plan_ns(AlgoKind::Direct, &shape), 0.0);
        for algo in [
            AlgoKind::Im2col,
            AlgoKind::Mec,
            AlgoKind::Winograd,
            AlgoKind::Fft,
            AlgoKind::Indirect,
            AlgoKind::Kn2row,
            AlgoKind::SmmConv,
        ] {
            let plan_ns = cm.estimate_plan_ns(algo, &shape);
            assert!(plan_ns > 0.0, "{algo:?}");
            assert!(
                plan_ns < cm.estimate_ns(algo, &shape),
                "{algo:?}: plan {plan_ns} should amortize vs execute {}",
                cm.estimate_ns(algo, &shape)
            );
        }
    }

    #[test]
    fn validate_choice_accepts_admissible_and_matches_plan_record() {
        let p = Planner::new();
        let shape = cv6();
        let ctx = ConvContext::default();
        let plan = p
            .validate_choice(AlgoKind::Mec, &shape, &Budget::unlimited(), &ctx)
            .unwrap();
        assert_eq!(plan.algo, AlgoKind::Mec);
        let listed = p
            .admissible(&shape, &Budget::unlimited(), &ctx)
            .into_iter()
            .find(|pl| pl.algo == AlgoKind::Mec)
            .unwrap();
        assert_eq!(plan, listed);
    }

    #[test]
    fn validate_choice_rejects_with_typed_reasons() {
        let p = Planner::new();
        let shape = cv6();
        let ctx = ConvContext::default();
        // Budget smaller than MEC's workspace.
        let err = p
            .validate_choice(AlgoKind::Mec, &shape, &Budget::new(16), &ctx)
            .unwrap_err();
        assert!(
            matches!(err, PlanError::BudgetExceeded { algo: AlgoKind::Mec, limit: 16, .. }),
            "{err:?}"
        );
        // Winograd has no q16 path.
        let q16 = ConvContext::default().with_precision(crate::tensor::Precision::Q16);
        let err = p
            .validate_choice(AlgoKind::Winograd, &shape, &Budget::unlimited(), &q16)
            .unwrap_err();
        assert!(matches!(err, PlanError::UnsupportedPrecision { .. }), "{err:?}");
        // Winograd off 3x3/s=1 geometry.
        let big_k = ConvShape::new(
            Nhwc::new(1, 227, 227, 3),
            KernelShape::new(11, 11, 3, 96),
            4,
            4,
        );
        let err = p
            .validate_choice(AlgoKind::Winograd, &big_k, &Budget::unlimited(), &ctx)
            .unwrap_err();
        assert!(matches!(err, PlanError::UnsupportedGeometry { .. }), "{err:?}");
        // Errors render human-readable reasons.
        assert!(err.to_string().contains("winograd"));
    }

    #[test]
    fn grain_model_tracks_cost_model_coefficients() {
        // threadpool::GrainModel::default() delegates here; pin the
        // derivation so the grain always follows the calibrated model.
        let cm = CostModel::default();
        let g = cm.grain_model();
        assert_eq!(g.ns_per_mac, cm.ns_per_mac);
        assert_eq!(g.ns_per_byte, cm.ns_per_byte_moved);
        assert!(g.dispatch_ns > 0.0);
        assert_eq!(crate::threadpool::GrainModel::default(), g);
    }

    #[test]
    fn indirect_wins_cv1_under_a_tight_budget() {
        // The acceptance fixture for the indirect algorithm: cv1's big
        // image + stride 4 make im2col's lowering 4.4 MB and MEC's
        // 1.6 MB, while indirect's lane strips stay under 0.7 MB. Under
        // a 1 MB budget the lowering family is inadmissible and indirect
        // beats the zero-workspace tier on time.
        let p = Planner::new();
        let shape = crate::bench::workload::by_name("cv1").unwrap().shape(1, 1);
        let budget = Budget::new(1 << 20);
        let ws = |k: AlgoKind| k.build().workspace_bytes(&shape);
        assert!(ws(AlgoKind::Indirect) < budget.limit());
        assert!(ws(AlgoKind::Mec) > budget.limit());
        assert!(ws(AlgoKind::Im2col) > budget.limit());
        let plan = p.plan(&shape, &budget, &ConvContext::default());
        assert_eq!(plan.algo, AlgoKind::Indirect, "{plan:?}");
        // And the memory win that put it there: an order of magnitude
        // under Eq. 2 on this geometry.
        assert!(ws(AlgoKind::Indirect) * 6 < ws(AlgoKind::Im2col));
    }

    #[test]
    fn kn2row_wins_the_pointwise_fixture() {
        // The acceptance fixture for kn2row: on a 1×1-kernel layer the
        // decomposition is a single unshifted GEMM, so it gets im2col's
        // compute without any lowered copy — the estimate must prefer it
        // over every lowering (which all pay Eq. 2/3 traffic for nothing)
        // even with no budget pressure.
        let p = Planner::new();
        let shape = crate::bench::workload::by_name("pw1").unwrap().shape(1, 1);
        assert_eq!(shape.kernel.kh * shape.kernel.kw, 1);
        let plan = p.plan(&shape, &Budget::unlimited(), &ConvContext::default());
        assert_eq!(plan.algo, AlgoKind::Kn2row, "{plan:?}");
        assert_eq!(plan.workspace_bytes, 0);
    }

    #[test]
    fn smm_prices_between_gemm_and_direct_and_scales_per_backend() {
        // for_backend honesty for the new entries: SMM's ns/MAC must sit
        // strictly between the micro-kernel GEMM's and the direct
        // nest's on every backend, and improve with wider backends
        // (partially — the stream is only compiler-vectorized).
        for b in [KernelBackend::Scalar, KernelBackend::Avx2, KernelBackend::Avx512] {
            let cm = CostModel::for_backend(b);
            assert!(cm.ns_per_mac < cm.ns_per_mac_smm, "{b:?}");
            assert!(cm.ns_per_mac_smm < cm.ns_per_mac_direct, "{b:?}");
        }
        let scalar = CostModel::for_backend(KernelBackend::Scalar);
        let wide = CostModel::for_backend(KernelBackend::Avx512);
        assert!(wide.ns_per_mac_smm < scalar.ns_per_mac_smm);
        // The backend gap must be milder than the GEMM family's: zero
        // packing means SMM keeps more of its cost scalar.
        assert!(
            scalar.ns_per_mac_smm / wide.ns_per_mac_smm
                < scalar.ns_per_mac / wide.ns_per_mac
        );
    }

    #[test]
    fn eq4_memory_relation_no_overlap() {
        // k_h <= s_h: MEC's L is not smaller (paper §3.4) — the planner's
        // admissibility sees that via workspace_bytes.
        let shape = ConvShape::new(Nhwc::new(1, 32, 32, 8), KernelShape::new(3, 3, 8, 8), 3, 3);
        assert!(shape.mec_lowered_elems() >= shape.im2col_lowered_elems());
    }
}
