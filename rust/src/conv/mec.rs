//! MEC — Memory-efficient Convolution (paper §3, Algorithm 2).
//!
//! The contribution: lower the input **once per vertical strip** instead of
//! once per output position. L has shape `i_n × o_w × i_h × k_w × i_c`
//! (Eq. 3) — smaller than im2col's lowered matrix by ≈`k_h` whenever
//! kernel instances overlap vertically (`k_h > s_h`, Eq. 4). The vertical
//! redundancy im2col materializes is *recovered* arithmetically: the `o_h`
//! row-blocks of the output are products of **overlapping** sub-matrices
//! of L — partition `h` starts `s_h·k_w·i_c` columns after partition
//! `h-1` and is addressed with the BLAS leading-dimension trick
//! (`ld = i_h·k_w·i_c`), so no bytes move between GEMMs.
//!
//! Mini-batch handling (§3.3) gives two schedules:
//! * **Solution A** (lines 9–19): `o_h` *large* GEMMs over all samples at
//!   once, producing `h-n-w-c` order, then an in-place-style repack to
//!   `n-h-w-c` reusing L as the auxiliary buffer (valid while `|O| ≤ |L|`).
//! * **Solution B** (lines 21–25): `i_n·o_h` *small* GEMMs (one per
//!   sample per output row), directly producing `n-h-w-c` — the batched-
//!   GEMM shape (`cublasSgemmBatched` in the paper's GPU code).
//! The dispatch threshold `T` (line 8, `o_w ≤ T`) trades GEMM size
//! against count; the paper found ~100 good on GPUs (`ablation_t`
//! re-derives this).
//!
//! Plan/execute: the A/B dispatch and the kernel-matrix packing
//! ([`PackedKernel`], shared across a layer's per-batch-size plans) are
//! input-independent, so [`MecPlan`] resolves and prepacks them once;
//! execute only lowers, multiplies, and (Solution A) repacks —
//! allocating nothing.
//!
//! Precision: the paper's 16-bit fixed-point grid rides the identical
//! schedule — the lowering quantizes while it copies (halving |L|'s
//! bytes), the overlapping-partition `ld` trick works unchanged on the
//! i16 L, and the GEMMs widen into i32. Solution A's repack stays f32
//! (the output is f32 post-dequantization), so q16 Solution A always
//! carries a separate `repack-aux` region instead of reusing L.

use super::{
    downcast_prepack, AlgoKind, ConvContext, ConvPlan, Convolution, KernelPrepack, PackedKernel,
};
use crate::gemm::{
    gemm_prepacked, gemm_prepacked_batch, gemm_prepacked_batch_i16, gemm_prepacked_i16,
    KernelBackend, MatMut, MatRef, MatRefI16, PackedB, PackedBI16, Q16Epilogue,
};
use crate::memory::WorkspaceLayout;
use crate::threadpool::Parallelism;
use crate::tensor::quant::{f32_as_i16_mut, i16_slots, Precision, QParams};
use crate::tensor::{ConvShape, Kernel, Tensor};
use std::sync::Arc;

/// Which mini-batch schedule to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Solution {
    /// Algorithm 2 line 8: A if `o_w ≤ T` and the repack aux fits, else B.
    Auto,
    A,
    B,
}

pub struct Mec {
    solution: Solution,
}

impl Mec {
    pub fn auto() -> Mec {
        Mec { solution: Solution::Auto }
    }

    pub fn solution_a() -> Mec {
        Mec { solution: Solution::A }
    }

    pub fn solution_b() -> Mec {
        Mec { solution: Solution::B }
    }

    /// Resolve the schedule for a geometry (Algorithm 2 line 8). The
    /// availability condition is precision-aware: f32 Solution A reuses L
    /// as the repack aux (`|O| ≤ |L|`); q16 Solution A needs a separate
    /// f32 aux next to the halved i16 L, and stays Auto-eligible only
    /// while that total still fits the analytic Eq. 3 budget.
    pub fn resolve(&self, ctx: &ConvContext, shape: &ConvShape) -> Solution {
        match self.solution {
            Solution::Auto => {
                if shape.ow() <= ctx.mec_t && solution_a_available_p(shape, ctx.precision) {
                    Solution::A
                } else {
                    Solution::B
                }
            }
            s => s,
        }
    }

    /// The compact lowering (Algorithm 2 lines 4–6): for each `(n, w)`,
    /// copy the `i_h × k_w × i_c` strip starting at column `s_w·w` into
    /// `L[n, w]`. Each copy is `k_w·i_c` contiguous floats per input row —
    /// this is why MEC wants n-h-w-c layout (§3.3). Exposed for the
    /// lowering-only bench (Fig. 4f's 85%-faster-lowering claim).
    pub fn lower(ctx: &ConvContext, shape: &ConvShape, input: &Tensor, l: &mut [f32]) {
        let s = *shape;
        let ow = s.ow();
        let k = s.kernel;
        let ish = s.input;
        let strip = k.kw * k.ic; // bytes copied per input row
        let row_len = ish.h * strip; // one L row: i_h·k_w·i_c
        assert_eq!(l.len(), ish.n * ow * row_len);
        let in_data = input.data();
        let lp = crate::threadpool::SharedSlice::new(l);

        // One task per (n, w) pair; h loop inside for cache-friendly runs.
        // Grain: each task moves row_len floats (read + write).
        ctx.par.parallel_for_bytes(ish.n * ow, row_len * 8, |t| {
            let l_data: &mut [f32] = lp.slice();
            let n = t / ow;
            let w = t % ow;
            let dst_base = t * row_len;
            let src_col = s.sw * w * k.ic;
            for h in 0..ish.h {
                let src = ish.index(n, h, 0, 0) + src_col;
                let dst = dst_base + h * strip;
                l_data[dst..dst + strip].copy_from_slice(&in_data[src..src + strip]);
            }
        });
    }

    /// Quantizing variant of [`Mec::lower`]: the identical strip walk,
    /// but each element is quantized into the i16 L with `qp`'s scale —
    /// Eq. 3's compact lowering at half the bytes.
    pub fn lower_q16(
        ctx: &ConvContext,
        shape: &ConvShape,
        input: &Tensor,
        qp: QParams,
        l: &mut [i16],
    ) {
        let s = *shape;
        let ow = s.ow();
        let k = s.kernel;
        let ish = s.input;
        let strip = k.kw * k.ic;
        let row_len = ish.h * strip;
        assert_eq!(l.len(), ish.n * ow * row_len);
        let in_data = input.data();
        let lp = crate::threadpool::SharedSlice::new(l);

        // Grain: each task reads row_len f32 and writes row_len i16.
        ctx.par.parallel_for_bytes(ish.n * ow, row_len * 6, |t| {
            let l_data: &mut [i16] = lp.slice();
            let n = t / ow;
            let w = t % ow;
            let dst_base = t * row_len;
            let src_col = s.sw * w * k.ic;
            for h in 0..ish.h {
                let src = ish.index(n, h, 0, 0) + src_col;
                let dst = dst_base + h * strip;
                for (d, &v) in l_data[dst..dst + strip]
                    .iter_mut()
                    .zip(&in_data[src..src + strip])
                {
                    *d = qp.quantize(v);
                }
            }
        });
    }
}

/// `|O| ≤ |L|` — f32 Solution A needs L as the repack aux (Alg. 2 line 8).
/// Batch-independent: both sides scale linearly in `i_n`.
pub fn solution_a_available(shape: &ConvShape) -> bool {
    shape.output().len() <= shape.mec_lowered_elems()
}

/// Precision-aware Solution-A availability — the ONE definition of the
/// Algorithm-2 line-8 aux condition, shared by [`Mec::resolve`] and the
/// planner's [`CostModel`](crate::planner::CostModel) so the cost
/// estimate can never model a schedule the plan won't execute. f32
/// reuses L as the repack aux; q16's f32 aux must fit beside the halved
/// i16 L within the analytic Eq. 3 budget.
pub fn solution_a_available_p(shape: &ConvShape, precision: Precision) -> bool {
    match precision {
        Precision::F32 => solution_a_available(shape),
        Precision::Q16 => {
            i16_slots(shape.mec_lowered_elems()) + shape.output().len()
                <= shape.mec_lowered_elems()
        }
    }
}

impl Convolution for Mec {
    fn name(&self) -> &'static str {
        match self.solution {
            Solution::Auto => "mec",
            Solution::A => "mec-a",
            Solution::B => "mec-b",
        }
    }

    fn supports(&self, _shape: &ConvShape) -> bool {
        true
    }

    /// Eq. (3): `i_n·o_w·i_h·k_w·i_c` floats. Solution A's aux space *is*
    /// L (the paper's trick); only a pinned Solution A on a geometry where
    /// `|O| > |L|` needs a separate aux.
    fn workspace_elems(&self, shape: &ConvShape) -> usize {
        let l = shape.mec_lowered_elems();
        match self.solution {
            Solution::A if !solution_a_available(shape) => l + shape.output().len(),
            _ => l,
        }
    }

    /// Under q16 the lowered L is stored in i16 lanes (half the Eq. 3
    /// bytes) and Solution A carries a separate f32 repack aux. For the
    /// pinned variants this is exactly the plan's layout; for Auto it is
    /// the max over the schedules the `T` dispatch can resolve to (the
    /// cost model has no `ctx`), so budget admission never under-counts.
    fn workspace_bytes_prec(&self, shape: &ConvShape, precision: Precision) -> usize {
        match precision {
            Precision::F32 => self.workspace_bytes(shape),
            Precision::Q16 => {
                let slots = i16_slots(shape.mec_lowered_elems());
                let aux = match self.solution {
                    Solution::B => 0,
                    Solution::A => shape.output().len(),
                    Solution::Auto => {
                        if solution_a_available_p(shape, Precision::Q16) {
                            shape.output().len()
                        } else {
                            0
                        }
                    }
                };
                (slots + aux) * 4
            }
        }
    }

    fn prepack(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        kernel: &Kernel,
    ) -> Arc<dyn KernelPrepack> {
        Arc::new(PackedKernel::pack(ctx, shape, kernel))
    }

    fn plan_shared(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        prepack: Arc<dyn KernelPrepack>,
    ) -> Box<dyn ConvPlan> {
        let packed_k: Arc<PackedKernel> = downcast_prepack(prepack, "mec");
        let solution = self.resolve(ctx, shape);
        let mut layout = WorkspaceLayout::new();
        match &*packed_k {
            PackedKernel::F32(_) => {
                layout.push("lowered", shape.mec_lowered_elems());
                // Pinned Solution A where |O| > |L|: the h-n-w-c → n-h-w-c
                // repack cannot reuse L and needs its own region.
                if solution == Solution::A && !solution_a_available(shape) {
                    layout.push("repack-aux", shape.output().len());
                }
            }
            PackedKernel::Q16 { .. } => {
                layout.push_i16("lowered", shape.mec_lowered_elems());
                // The i16 L cannot host the f32 repack, so q16 Solution A
                // always carries a separate aux region.
                if solution == Solution::A {
                    layout.push("repack-aux", shape.output().len());
                }
            }
        }
        Box::new(MecPlan {
            ctx: ctx.clone(),
            shape: *shape,
            kind: match self.solution {
                Solution::Auto => AlgoKind::Mec,
                Solution::A => AlgoKind::MecSolutionA,
                Solution::B => AlgoKind::MecSolutionB,
            },
            solution,
            packed_k,
            layout,
        })
    }
}

/// Plan for MEC: the Algorithm-2 line-8 dispatch resolved, the kernel
/// matrix packed once (shared, precision-resolved), and the Eq. (3)
/// lowered region (+ optional repack aux) laid out.
pub struct MecPlan {
    ctx: ConvContext,
    shape: ConvShape,
    kind: AlgoKind,
    solution: Solution,
    packed_k: Arc<PackedKernel>,
    layout: WorkspaceLayout,
}

impl MecPlan {
    /// The schedule this plan resolved to (observability / tests).
    pub fn solution(&self) -> Solution {
        self.solution
    }
}

impl ConvPlan for MecPlan {
    fn algo(&self) -> AlgoKind {
        self.kind
    }

    fn shape(&self) -> &ConvShape {
        &self.shape
    }

    fn layout(&self) -> &WorkspaceLayout {
        &self.layout
    }

    fn resident_bytes(&self) -> usize {
        self.packed_k.bytes()
    }

    fn shared_prepack(&self) -> Option<Arc<dyn KernelPrepack>> {
        Some(Arc::clone(&self.packed_k) as Arc<dyn KernelPrepack>)
    }

    fn kernel_backend(&self) -> Option<KernelBackend> {
        Some(self.packed_k.backend())
    }

    fn execute_in(&self, input: &Tensor, scratch: &mut [f32], output: &mut Tensor) {
        self.execute_with(&self.ctx, input, scratch, output);
    }

    fn execute_in_par(
        &self,
        input: &Tensor,
        scratch: &mut [f32],
        output: &mut Tensor,
        par: &Parallelism,
    ) {
        // Cap this execute at the session's thread budget without
        // re-planning: the clamped handle shares the plan's pool, and the
        // workspace layout (sized for the plan-time budget) stays valid
        // because the budget only ever shrinks.
        let ctx = self
            .ctx
            .clone()
            .with_parallelism(self.ctx.par.with_budget(par.threads()));
        self.execute_with(&ctx, input, scratch, output);
    }
}

impl MecPlan {
    /// The execute body, parameterized on the context so per-session
    /// thread caps ([`ConvPlan::execute_in_par`]) reuse the exact same
    /// code path as the plan-default [`ConvPlan::execute_in`].
    fn execute_with(
        &self,
        ctx: &ConvContext,
        input: &Tensor,
        scratch: &mut [f32],
        output: &mut Tensor,
    ) {
        let s = self.shape;
        assert_eq!(output.shape(), s.output());
        assert_eq!(input.shape(), s.input);
        let total = self.layout.total_elems();
        let buf = &mut scratch[..total];
        match &*self.packed_k {
            PackedKernel::F32(pk) => match self.solution {
                Solution::A => {
                    let l_elems = s.mec_lowered_elems();
                    let (l, aux) = if total > l_elems {
                        let (l, aux) = buf.split_at_mut(l_elems);
                        (l, Some(aux))
                    } else {
                        (buf, None)
                    };
                    run_solution_a(ctx, &s, input, pk, l, aux, output);
                }
                Solution::B => run_solution_b(ctx, &s, input, pk, buf, output),
                Solution::Auto => unreachable!("plan() always resolves the schedule"),
            },
            PackedKernel::Q16 { packed, col_scales } => {
                // Activation scale: the calibrated static one when the
                // plan was built from a calibrated model, else the
                // dynamic per-execute abs-max. The epilogue folds the
                // Q15 product shift (2^15) back out globally and applies
                // each output channel's own kernel scale per column.
                let qa = ctx
                    .act_qparams
                    .unwrap_or_else(|| QParams::from_slice(input.data()));
                let ep = Q16Epilogue {
                    global: qa.scale * 32768.0,
                    per_col: Some(col_scales),
                };
                let l_slots = i16_slots(s.mec_lowered_elems());
                match self.solution {
                    Solution::A => {
                        let (l_f32, aux) = buf.split_at_mut(l_slots);
                        let l = &mut f32_as_i16_mut(l_f32)[..s.mec_lowered_elems()];
                        Mec::lower_q16(ctx, &s, input, qa, l);
                        run_gemms_a_q16(ctx, &s, packed, ep, l, output);
                        repack_hnwc_to_nhwc(ctx, &s, aux, output);
                    }
                    Solution::B => {
                        let l = &mut f32_as_i16_mut(&mut buf[..l_slots])[..s.mec_lowered_elems()];
                        Mec::lower_q16(ctx, &s, input, qa, l);
                        run_gemms_b_q16(ctx, &s, packed, ep, l, output);
                    }
                    Solution::Auto => unreachable!("plan() always resolves the schedule"),
                }
            }
        }
    }
}

/// Solution A (Algorithm 2 lines 9–19): `o_h` big GEMMs over the whole
/// mini-batch producing `h-n-w-c`, then repack to `n-h-w-c` via aux.
/// `aux_sep` is `Some` only for pinned-A geometries where `|O| > |L|`.
fn run_solution_a(
    ctx: &ConvContext,
    s: &ConvShape,
    input: &Tensor,
    packed_k: &PackedB,
    l: &mut [f32],
    aux_sep: Option<&mut [f32]>,
    output: &mut Tensor,
) {
    let (oh, ow) = (s.oh(), s.ow());
    let k = s.kernel;
    let n = s.input.n;
    let o_elems = s.output().len();
    let l_rows = n * ow; // L as i_n·o_w × i_h·k_w·i_c (line 9)
    let l_cols = s.input.h * k.kw * k.ic;
    let kdim = k.kh * k.kw * k.ic;
    let step = s.sh * k.kw * k.ic; // partition shift (line 12)

    Mec::lower(ctx, s, input, l);

    // Lines 10-13: O[h] = L[0:i_n·o_w, step·h : step·h + k_h·k_w·i_c] × K,
    // one gemm per output row h; O interpreted as o_h × (i_n·o_w·k_c).
    //
    // §Perf: K is shared by all o_h gemms — packed ONCE at plan time
    // (PackedB) instead of per call; this is what the paper gets for free
    // from BLAS keeping its packing internal, and it roughly halved MEC
    // runtime on cv6.
    let out_row = n * ow * k.kc;
    if ctx.threads() <= 1 {
        // Mobile path (§Perf iteration 3): fuse the o_h gemms so each
        // packed-K tile is streamed once and reused across partitions —
        // K traffic dominates when m = i_n·o_w is small (cv11/cv12).
        let l_ref: &[f32] = l;
        let a_views: Vec<MatRef<'_>> = (0..oh)
            .map(|h| MatRef::strided(&l_ref[step * h..], l_rows, kdim, l_cols))
            .collect();
        let mut c_views: Vec<MatMut<'_>> = output
            .data_mut()
            .chunks_exact_mut(out_row)
            .map(|chunk| MatMut::new(chunk, l_rows, k.kc))
            .collect();
        gemm_prepacked_batch(&a_views, packed_k, &mut c_views);
    } else {
        let out = crate::threadpool::SharedSlice::new(output.data_mut());
        let l_ref: &[f32] = l;
        // Each h writes a disjoint row of the h-n-w-c output; grain =
        // one (i_n·o_w × k_h·k_w·i_c × k_c) GEMM per row.
        ctx.par.parallel_for_macs(oh, l_rows * kdim * k.kc, |h| {
            let out_data: &mut [f32] = out.slice();
            let a = MatRef::strided(&l_ref[step * h..], l_rows, kdim, l_cols);
            let mut c = MatMut::new(&mut out_data[h * out_row..(h + 1) * out_row], l_rows, k.kc);
            gemm_prepacked(a, packed_k, &mut c);
        });
    }

    // Lines 14-19: repack h-n-w-c -> n-h-w-c using L (or separate aux).
    let aux: &mut [f32] = match aux_sep {
        Some(a) => a,
        None => &mut l[..o_elems],
    };
    repack_hnwc_to_nhwc(ctx, s, aux, output);
}

/// The q16 twin of Solution A's GEMM stage: the same `o_h` overlapping
/// partitions of the (now i16) L, widening GEMMs, dequantized f32 out.
fn run_gemms_a_q16(
    ctx: &ConvContext,
    s: &ConvShape,
    packed_k: &PackedBI16,
    ep: Q16Epilogue<'_>,
    l: &[i16],
    output: &mut Tensor,
) {
    let (oh, ow) = (s.oh(), s.ow());
    let k = s.kernel;
    let n = s.input.n;
    let l_rows = n * ow;
    let l_cols = s.input.h * k.kw * k.ic;
    let kdim = k.kh * k.kw * k.ic;
    let step = s.sh * k.kw * k.ic;
    let out_row = n * ow * k.kc;
    if ctx.threads() <= 1 {
        let a_views: Vec<MatRefI16<'_>> = (0..oh)
            .map(|h| MatRefI16::strided(&l[step * h..], l_rows, kdim, l_cols))
            .collect();
        let mut c_views: Vec<MatMut<'_>> = output
            .data_mut()
            .chunks_exact_mut(out_row)
            .map(|chunk| MatMut::new(chunk, l_rows, k.kc))
            .collect();
        gemm_prepacked_batch_i16(&a_views, packed_k, &mut c_views, ep);
    } else {
        let out = crate::threadpool::SharedSlice::new(output.data_mut());
        ctx.par.parallel_for_macs(oh, l_rows * kdim * k.kc, |h| {
            let out_data: &mut [f32] = out.slice();
            let a = MatRefI16::strided(&l[step * h..], l_rows, kdim, l_cols);
            let mut c = MatMut::new(&mut out_data[h * out_row..(h + 1) * out_row], l_rows, k.kc);
            gemm_prepacked_i16(a, packed_k, &mut c, ep);
        });
    }
}

/// Algorithm 2 lines 14-19: repack the h-n-w-c GEMM output to n-h-w-c
/// through `aux` (L in f32 Solution A, a dedicated region otherwise).
fn repack_hnwc_to_nhwc(ctx: &ConvContext, s: &ConvShape, aux: &mut [f32], output: &mut Tensor) {
    let (oh, ow) = (s.oh(), s.ow());
    let k = s.kernel;
    let n = s.input.n;
    let o_elems = s.output().len();
    let aux = &mut aux[..o_elems];
    aux.copy_from_slice(&output.data()[..o_elems]); // line 14: L = O
    let chunk = ow * k.kc; // o_w·k_c contiguous run per (n,h)
    let out = crate::threadpool::SharedSlice::new(output.data_mut());
    let aux_ref: &[f32] = aux;
    // Grain: each task copies one o_w·k_c run (read + write).
    ctx.par.parallel_for_bytes(n * oh, chunk * 8, |t| {
        let out_data: &mut [f32] = out.slice();
        let nn = t / oh;
        let h = t % oh;
        // L viewed as o_h × i_n × (o_w·k_c): O[n,h,:] = L[h,n,:] (line 18)
        let src = (h * n + nn) * chunk;
        let dst = (nn * oh + h) * chunk;
        out_data[dst..dst + chunk].copy_from_slice(&aux_ref[src..src + chunk]);
    });
}

/// Solution B (Algorithm 2 lines 21–25): per-sample batched GEMMs
/// directly in n-h-w-c. `i_n·o_h` gemms of `o_w × (k_h·k_w·i_c) × k_c`.
fn run_solution_b(
    ctx: &ConvContext,
    s: &ConvShape,
    input: &Tensor,
    packed_k: &PackedB,
    l: &mut [f32],
    output: &mut Tensor,
) {
    let (oh, ow) = (s.oh(), s.ow());
    let k = s.kernel;
    let n = s.input.n;
    let l_cols = s.input.h * k.kw * k.ic;
    let kdim = k.kh * k.kw * k.ic;
    let step = s.sh * k.kw * k.ic;
    let sample_l = ow * l_cols; // one sample's L block (o_w × i_h·k_w·i_c)

    Mec::lower(ctx, s, input, l);

    // §Perf: shared K packed once at plan time across the i_n·o_h batched
    // gemms (the cublasSgemmBatched analogue: one kernel image, many
    // activations).
    let chunk = ow * k.kc;
    if ctx.threads() <= 1 {
        // Mobile path: fused batch order keeps each K tile cache-warm
        // across all i_n·o_h partitions (§Perf iteration 3).
        let l_ref: &[f32] = l;
        let a_views: Vec<MatRef<'_>> = (0..n * oh)
            .map(|t| {
                let nn = t / oh;
                let h = t % oh;
                MatRef::strided(&l_ref[nn * sample_l + step * h..], ow, kdim, l_cols)
            })
            .collect();
        let mut c_views: Vec<MatMut<'_>> = output
            .data_mut()
            .chunks_exact_mut(chunk)
            .map(|ch| MatMut::new(ch, ow, k.kc))
            .collect();
        gemm_prepacked_batch(&a_views, packed_k, &mut c_views);
    } else {
        let out = crate::threadpool::SharedSlice::new(output.data_mut());
        let l_ref: &[f32] = l;
        // The paper's "i_n·o_h parallel/batched gemm calls with smaller
        // inputs" — each writes the contiguous O[n][h] row block. Grain:
        // one o_w × k_h·k_w·i_c × k_c GEMM per task (tens of µs or far
        // less on cv11/cv12-like shapes — exactly the loops the inline
        // cutoff exists for).
        ctx.par.parallel_for_macs(n * oh, ow * kdim * k.kc, |t| {
            let out_data: &mut [f32] = out.slice();
            let nn = t / oh;
            let h = t % oh;
            let a = MatRef::strided(&l_ref[nn * sample_l + step * h..], ow, kdim, l_cols);
            let dst = (nn * oh + h) * chunk;
            let mut c = MatMut::new(&mut out_data[dst..dst + chunk], ow, k.kc);
            gemm_prepacked(a, packed_k, &mut c);
        });
    }
}

/// The q16 twin of Solution B: per-sample batched widening GEMMs over the
/// i16 L, directly in n-h-w-c.
fn run_gemms_b_q16(
    ctx: &ConvContext,
    s: &ConvShape,
    packed_k: &PackedBI16,
    ep: Q16Epilogue<'_>,
    l: &[i16],
    output: &mut Tensor,
) {
    let (oh, ow) = (s.oh(), s.ow());
    let k = s.kernel;
    let n = s.input.n;
    let l_cols = s.input.h * k.kw * k.ic;
    let kdim = k.kh * k.kw * k.ic;
    let step = s.sh * k.kw * k.ic;
    let sample_l = ow * l_cols;
    let chunk = ow * k.kc;
    if ctx.threads() <= 1 {
        let a_views: Vec<MatRefI16<'_>> = (0..n * oh)
            .map(|t| {
                let nn = t / oh;
                let h = t % oh;
                MatRefI16::strided(&l[nn * sample_l + step * h..], ow, kdim, l_cols)
            })
            .collect();
        let mut c_views: Vec<MatMut<'_>> = output
            .data_mut()
            .chunks_exact_mut(chunk)
            .map(|ch| MatMut::new(ch, ow, k.kc))
            .collect();
        gemm_prepacked_batch_i16(&a_views, packed_k, &mut c_views, ep);
    } else {
        let out = crate::threadpool::SharedSlice::new(output.data_mut());
        ctx.par.parallel_for_macs(n * oh, ow * kdim * k.kc, |t| {
            let out_data: &mut [f32] = out.slice();
            let nn = t / oh;
            let h = t % oh;
            let a = MatRefI16::strided(&l[nn * sample_l + step * h..], ow, kdim, l_cols);
            let dst = (nn * oh + h) * chunk;
            let mut c = MatMut::new(&mut out_data[dst..dst + chunk], ow, k.kc);
            gemm_prepacked_i16(a, packed_k, &mut c, ep);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::Direct;
    use crate::memory::Workspace;
    use crate::tensor::{KernelShape, Nhwc};
    use crate::util::{assert_allclose, Rng};

    fn fig2_shape() -> ConvShape {
        ConvShape::new(Nhwc::new(1, 7, 7, 1), KernelShape::new(3, 3, 1, 1), 1, 1)
    }

    #[test]
    fn fig2_lowered_dimensions() {
        // Paper Fig. 2: L is 5 × 21 (54% smaller than im2col's 25 × 9).
        let s = fig2_shape();
        assert_eq!(s.mec_lowered_elems(), 5 * 21);
        assert_eq!(Mec::auto().workspace_elems(&s), 105);
    }

    #[test]
    fn fig2_lowering_content() {
        // Partition A = I[0:7, 0:3] is row 0 of L; B = I[0:7, 1:4] row 1.
        let s = fig2_shape();
        let input = Tensor::from_fn(s.input, |_, h, w, _| (h * 7 + w) as f32);
        let mut l = vec![0.0; 105];
        Mec::lower(&ConvContext::default(), &s, &input, &mut l);
        // Row 0 (partition A): rows of I[*, 0:3] concatenated.
        assert_eq!(&l[0..6], &[0., 1., 2., 7., 8., 9.]);
        assert_eq!(&l[18..21], &[42., 43., 44.]);
        // Row 1 (partition B): I[*, 1:4].
        assert_eq!(&l[21..24], &[1., 2., 3.]);
    }

    #[test]
    fn vertical_partitions_share_storage() {
        // P = L[0:5, 0:9], Q = L[0:5, 3:12]: Q's first row must equal
        // P's first row shifted by s_h·k_w = 3 — the ld trick.
        let s = fig2_shape();
        let input = Tensor::from_fn(s.input, |_, h, w, _| (h * 7 + w) as f32);
        let mut l = vec![0.0; 105];
        Mec::lower(&ConvContext::default(), &s, &input, &mut l);
        let p = MatRef::strided(&l, 5, 9, 21);
        let q = MatRef::strided(&l[3..], 5, 9, 21);
        for r in 0..5 {
            for c in 0..6 {
                assert_eq!(q.at(r, c), p.at(r, c + 3));
            }
        }
    }

    fn check_vs_direct(shape: ConvShape, solution: Solution, threads: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let ctx = ConvContext::default().with_threads(threads);
        let mut want = Tensor::zeros(shape.output());
        let mut got = Tensor::zeros(shape.output());
        let mut ws = Workspace::new();
        Direct.run(&ctx, &shape, &input, &kernel, &mut ws, &mut want);
        let mec = Mec { solution };
        mec.run(&ctx, &shape, &input, &kernel, &mut ws, &mut got);
        assert_allclose(
            got.data(),
            want.data(),
            1e-4,
            &format!("{:?} {}", solution, shape.describe()),
        );
    }

    #[test]
    fn solution_a_matches_direct() {
        for (n, ih, iw, ic, kh, kw, kc, sh, sw, seed) in [
            (1usize, 7, 7, 1, 3, 3, 1, 1, 1, 1u64),
            (2, 9, 8, 3, 3, 2, 4, 2, 1, 2),
            (4, 10, 10, 2, 5, 5, 3, 1, 1, 3),
            (1, 12, 6, 3, 4, 3, 2, 3, 2, 4),
        ] {
            let shape = ConvShape::new(
                Nhwc::new(n, ih, iw, ic),
                KernelShape::new(kh, kw, ic, kc),
                sh,
                sw,
            );
            check_vs_direct(shape, Solution::A, 1, seed);
            check_vs_direct(shape, Solution::A, 3, seed);
        }
    }

    #[test]
    fn solution_b_matches_direct() {
        for (n, ih, iw, ic, kh, kw, kc, sh, sw, seed) in [
            (1usize, 7, 7, 1, 3, 3, 1, 1, 1, 11u64),
            (3, 9, 8, 3, 3, 2, 4, 2, 1, 12),
            (2, 24, 24, 4, 5, 5, 8, 1, 1, 13),
            (1, 8, 15, 2, 2, 4, 3, 2, 3, 14),
        ] {
            let shape = ConvShape::new(
                Nhwc::new(n, ih, iw, ic),
                KernelShape::new(kh, kw, ic, kc),
                sh,
                sw,
            );
            check_vs_direct(shape, Solution::B, 1, seed);
            check_vs_direct(shape, Solution::B, 4, seed);
        }
    }

    #[test]
    fn solutions_agree_with_each_other() {
        let shape = ConvShape::new(Nhwc::new(2, 14, 14, 3), KernelShape::new(3, 3, 3, 5), 1, 1);
        let mut rng = Rng::new(31);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let ctx = ConvContext::default();
        let mut oa = Tensor::zeros(shape.output());
        let mut ob = Tensor::zeros(shape.output());
        let mut ws = Workspace::new();
        Mec::solution_a().run(&ctx, &shape, &input, &kernel, &mut ws, &mut oa);
        Mec::solution_b().run(&ctx, &shape, &input, &kernel, &mut ws, &mut ob);
        assert_allclose(oa.data(), ob.data(), 1e-5, "A vs B");
    }

    #[test]
    fn auto_dispatch_follows_line8() {
        let ctx = ConvContext::default(); // T = 100
        // o_w = 5 <= 100 and |O| (25) <= |L| (105) -> Solution A.
        assert_eq!(Mec::auto().resolve(&ctx, &fig2_shape()), Solution::A);
        // Huge o_w -> Solution B.
        let wide = ConvShape::new(Nhwc::new(1, 7, 300, 1), KernelShape::new(3, 3, 1, 1), 1, 1);
        assert!(wide.ow() > 100);
        assert_eq!(Mec::auto().resolve(&ctx, &wide), Solution::B);
        // |O| > |L| (many output channels) -> Solution B even if o_w small.
        let fat = ConvShape::new(Nhwc::new(1, 7, 7, 1), KernelShape::new(3, 3, 1, 64), 1, 1);
        assert!(!solution_a_available(&fat));
        assert_eq!(Mec::auto().resolve(&ctx, &fat), Solution::B);
        // T tunable.
        let t4 = ConvContext::default().with_mec_t(4);
        assert_eq!(Mec::auto().resolve(&t4, &fig2_shape()), Solution::B);
    }

    #[test]
    fn q16_auto_dispatch_accounts_for_separate_aux() {
        // fig2: i16_slots(105) + 25 = 53 + 25 = 78 <= 105 -> still A.
        let q16 = ConvContext::default().with_precision(Precision::Q16);
        assert_eq!(Mec::auto().resolve(&q16, &fig2_shape()), Solution::A);
        // |O| close to |L|: f32 still picks A, q16 must fall to B because
        // half-L + aux would exceed the Eq. 3 budget.
        let tight = ConvShape::new(Nhwc::new(1, 7, 7, 1), KernelShape::new(3, 3, 1, 3), 1, 1);
        assert!(solution_a_available(&tight)); // 75 <= 105
        assert_eq!(Mec::auto().resolve(&ConvContext::default(), &tight), Solution::A);
        assert_eq!(Mec::auto().resolve(&q16, &tight), Solution::B); // 53+75 > 105
    }

    #[test]
    fn plan_resolves_dispatch_once() {
        // The plan freezes the Algorithm-2 line-8 decision at plan time.
        let ctx = ConvContext::default();
        let s = fig2_shape();
        let kernel = Kernel::zeros(s.kernel);
        let plan = Mec::auto().plan(&ctx, &s, &kernel);
        assert_eq!(plan.algo(), AlgoKind::Mec);
        assert_eq!(plan.workspace_elems(), s.mec_lowered_elems());
        // Pinned A on |O| > |L| gets the separate repack-aux region.
        let fat = ConvShape::new(Nhwc::new(1, 7, 7, 1), KernelShape::new(3, 3, 1, 64), 1, 1);
        let fat_kernel = Kernel::zeros(fat.kernel);
        let plan_a = Mec::solution_a().plan(&ctx, &fat, &fat_kernel);
        assert_eq!(
            plan_a.workspace_elems(),
            fat.mec_lowered_elems() + fat.output().len()
        );
        assert!(plan_a.layout().region("repack-aux").is_some());
    }

    #[test]
    fn q16_plan_halves_lowered_and_keeps_aux() {
        let s = fig2_shape();
        let kernel = Kernel::zeros(s.kernel);
        let q16 = ConvContext::default().with_precision(Precision::Q16);
        let plan = Mec::auto().plan(&q16, &s, &kernel);
        let lowered = plan.layout().region("lowered").unwrap().elems;
        assert_eq!(lowered, s.mec_lowered_elems().div_ceil(2));
        // Auto resolved to A under q16 (see dispatch test) -> aux present.
        assert_eq!(
            plan.layout().region("repack-aux").unwrap().elems,
            s.output().len()
        );
    }

    #[test]
    fn q16_solutions_match_direct_within_quantization_noise() {
        for (solution, threads, seed) in [
            (Solution::A, 1usize, 0x70u64),
            (Solution::A, 3, 0x71),
            (Solution::B, 1, 0x72),
            (Solution::B, 4, 0x73),
        ] {
            let shape = ConvShape::new(Nhwc::new(2, 10, 9, 3), KernelShape::new(3, 3, 3, 4), 1, 1);
            let mut rng = Rng::new(seed);
            let input = Tensor::random(shape.input, &mut rng);
            let kernel = Kernel::random(shape.kernel, &mut rng);
            let mut want = Tensor::zeros(shape.output());
            Direct.run(
                &ConvContext::default(),
                &shape,
                &input,
                &kernel,
                &mut Workspace::new(),
                &mut want,
            );
            let ctx = ConvContext::default()
                .with_threads(threads)
                .with_precision(Precision::Q16);
            let plan = Mec { solution }.plan(&ctx, &shape, &kernel);
            // Plain Vec scratch (not a tracked Arena): unit tests must not
            // perturb the global tracker the memory tests assert against.
            let mut scratch = vec![0.0f32; plan.workspace_elems()];
            let mut got = Tensor::zeros(shape.output());
            plan.execute_in(&input, &mut scratch, &mut got);
            assert_allclose(
                got.data(),
                want.data(),
                1e-3,
                &format!("q16 {:?} t={threads}", solution),
            );
        }
    }

    #[test]
    fn pinned_a_works_when_o_exceeds_l() {
        // |O| > |L|: pinned Solution A must allocate separate aux and
        // still be correct.
        let shape = ConvShape::new(Nhwc::new(1, 7, 7, 1), KernelShape::new(3, 3, 1, 64), 1, 1);
        assert!(!solution_a_available(&shape));
        assert_eq!(
            Mec::solution_a().workspace_elems(&shape),
            shape.mec_lowered_elems() + shape.output().len()
        );
        check_vs_direct(shape, Solution::A, 2, 41);
    }

    #[test]
    fn workspace_is_eq3_and_smaller_than_eq2_when_overlapping() {
        // cv5 geometry: 24x24x96, 5x5x256, s=1.
        let s = ConvShape::new(
            Nhwc::new(1, 24, 24, 96),
            KernelShape::new(5, 5, 96, 256),
            1,
            1,
        );
        let mec = Mec::auto().workspace_elems(&s);
        assert_eq!(mec, 20 * 24 * 5 * 96); // o_w·i_h·k_w·i_c
        assert!(mec < crate::conv::im2col::Im2col.workspace_elems(&s));
    }

    #[test]
    fn batch_in_solution_a_interleaves_correctly() {
        // Regression guard for the h-n-w-c -> n-h-w-c repack: use a batch
        // where each sample is constant so any mixup is visible.
        let shape = ConvShape::new(Nhwc::new(3, 5, 5, 1), KernelShape::new(3, 3, 1, 2), 1, 1);
        let input = Tensor::from_fn(shape.input, |n, _, _, _| (n + 1) as f32);
        let kernel = Kernel::from_fn(shape.kernel, |_, _, _, o| if o == 0 { 1.0 } else { 2.0 });
        let ctx = ConvContext::default();
        let mut out = Tensor::zeros(shape.output());
        let mut ws = Workspace::new();
        Mec::solution_a().run(&ctx, &shape, &input, &kernel, &mut ws, &mut out);
        for n in 0..3 {
            let base = 9.0 * (n + 1) as f32; // 3x3 ones window
            for h in 0..shape.oh() {
                for w in 0..shape.ow() {
                    assert_eq!(out.at(n, h, w, 0), base, "n={n}");
                    assert_eq!(out.at(n, h, w, 1), 2.0 * base, "n={n}");
                }
            }
        }
    }
}
