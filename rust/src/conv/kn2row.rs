//! kn2row convolution (Anderson et al., "Low-memory GEMM-based
//! convolution algorithms for deep neural networks") — the accumulating
//! variant.
//!
//! A k_h×k_w convolution is k_h·k_w pointwise (1×1) convolutions whose
//! outputs land shifted by (u, v). Each pointwise conv is a GEMM with
//! the input pixels as A (`o_w × i_c`, row stride `s_w·i_c`) and kernel
//! position (u, v)'s `i_c × k_c` slice as B — in NHWC that slice is a
//! contiguous block of the kernel tensor, so all k_h·k_w B-operands are
//! prepacked at plan time with no rearrangement. Execute accumulates
//! the shifted products **directly into the output rows**
//! ([`gemm_prepacked_beta`]: beta=0 on the first position, 1 after), so
//! the algorithm's workspace is exactly zero — the limiting case of the
//! family's "near-zero workspace" claim, with the accumulator being the
//! output itself rather than an arena region.
//!
//! Where it wins: 1×1-heavy geometries (the decomposition is a single
//! unshifted GEMM — im2col's result without im2col's lowered copy of
//! the input) and any tight-budget geometry where direct would
//! otherwise be the only admissible choice. f32-only: the i16 GEMM
//! substrate has no accumulating epilogue (requantizing partial sums
//! per kernel position would compound rounding), so the planner
//! excludes it under q16.

use super::{downcast_prepack, AlgoKind, ConvContext, ConvPlan, Convolution, KernelPrepack};
use crate::gemm::{gemm_prepacked_beta, KernelBackend, MatMut, MatRef, PackedB};
use crate::memory::WorkspaceLayout;
use crate::tensor::{ConvShape, Kernel, Tensor};
use crate::threadpool::{Parallelism, SharedSlice};
use std::any::Any;
use std::sync::Arc;

pub struct Kn2row;

/// kn2row's prepack: kernel position (u, v) ↦ packed `i_c × k_c` GEMM
/// B-operand, in (u·k_w + v) order. Batch-independent, Arc-shared across
/// per-batch-size plans like every other prepack.
pub struct Kn2rowPrepack {
    pub slices: Vec<PackedB>,
}

impl KernelPrepack for Kn2rowPrepack {
    fn bytes(&self) -> usize {
        self.slices.iter().map(|p| p.bytes()).sum()
    }

    fn into_any_arc(self: Arc<Self>) -> Arc<dyn Any + Send + Sync> {
        self
    }
}

impl Convolution for Kn2row {
    fn name(&self) -> &'static str {
        "kn2row"
    }

    fn supports(&self, _shape: &ConvShape) -> bool {
        true
    }

    /// Zero: the shifted 1×1 products accumulate in the output tensor
    /// itself (see module docs) — kn2row shares direct's end of the
    /// paper's memory/performance trade-off while keeping GEMM compute.
    fn workspace_elems(&self, _shape: &ConvShape) -> usize {
        0
    }

    fn prepack(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        kernel: &Kernel,
    ) -> Arc<dyn KernelPrepack> {
        assert_eq!(kernel.shape(), shape.kernel);
        let k = shape.kernel;
        let data = kernel.data();
        let block = k.ic * k.kc;
        let slices = (0..k.kh * k.kw)
            .map(|p| {
                // NHWC kernel layout: position (u, v)'s i_c×k_c slice is
                // the contiguous block starting at index (u·k_w+v)·i_c·k_c.
                PackedB::pack(MatRef::new(&data[p * block..(p + 1) * block], k.ic, k.kc), ctx.blocks)
            })
            .collect();
        Arc::new(Kn2rowPrepack { slices })
    }

    fn plan_shared(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        prepack: Arc<dyn KernelPrepack>,
    ) -> Box<dyn ConvPlan> {
        let prepack: Arc<Kn2rowPrepack> = downcast_prepack(prepack, "kn2row");
        let k = shape.kernel;
        assert_eq!(prepack.slices.len(), k.kh * k.kw);
        assert!(prepack.slices.iter().all(|p| p.k == k.ic && p.n == k.kc));
        Box::new(Kn2rowPlan {
            ctx: ctx.clone(),
            shape: *shape,
            prepack,
            layout: WorkspaceLayout::new(),
        })
    }
}

/// Plan for kn2row: k_h·k_w prepacked pointwise B-operands; empty
/// workspace layout (the output is the accumulator).
pub struct Kn2rowPlan {
    ctx: ConvContext,
    shape: ConvShape,
    prepack: Arc<Kn2rowPrepack>,
    layout: WorkspaceLayout,
}

impl ConvPlan for Kn2rowPlan {
    fn algo(&self) -> AlgoKind {
        AlgoKind::Kn2row
    }

    fn shape(&self) -> &ConvShape {
        &self.shape
    }

    fn layout(&self) -> &WorkspaceLayout {
        &self.layout
    }

    fn resident_bytes(&self) -> usize {
        self.prepack.bytes()
    }

    fn shared_prepack(&self) -> Option<Arc<dyn KernelPrepack>> {
        Some(Arc::clone(&self.prepack) as Arc<dyn KernelPrepack>)
    }

    fn kernel_backend(&self) -> Option<KernelBackend> {
        self.prepack.slices.first().map(|p| p.backend())
    }

    fn execute_in(&self, input: &Tensor, _scratch: &mut [f32], output: &mut Tensor) {
        self.execute_with(&self.ctx, input, output);
    }

    fn execute_in_par(
        &self,
        input: &Tensor,
        _scratch: &mut [f32],
        output: &mut Tensor,
        par: &Parallelism,
    ) {
        // Session thread cap: clamp into the plan-time budget, sharing
        // the plan's pool (see MecPlan::execute_in_par).
        let ctx = self
            .ctx
            .clone()
            .with_parallelism(self.ctx.par.with_budget(par.threads()));
        self.execute_with(&ctx, input, output);
    }
}

impl Kn2rowPlan {
    fn execute_with(&self, ctx: &ConvContext, input: &Tensor, output: &mut Tensor) {
        let s = self.shape;
        let k = s.kernel;
        let (oh, ow) = (s.oh(), s.ow());
        let ish = s.input;
        assert_eq!(output.shape(), s.output());
        assert_eq!(input.shape(), ish);

        let in_data = input.data();
        let slices = &self.prepack.slices;
        let out = SharedSlice::new(output.data_mut());

        // Parallelize over (n, o_h): each task owns a disjoint output
        // row and runs its k_h·k_w accumulating pointwise GEMMs in a
        // fixed (u, v) order, so results are bitwise identical at any
        // thread count. Grain: the full row's MACs.
        let row_macs = ow * k.kh * k.kw * k.ic * k.kc;
        ctx.par.parallel_for_macs(ish.n * oh, row_macs, |r| {
            let (n, y) = (r / oh, r % oh);
            let out_data: &mut [f32] = out.slice();
            let c_rows = &mut out_data[r * ow * k.kc..(r + 1) * ow * k.kc];
            for u in 0..k.kh {
                for v in 0..k.kw {
                    // A = the o_w input pixels this row reads at kernel
                    // position (u, v): row stride s_w·i_c walks x.
                    let a0 = ish.index(n, y * s.sh + u, v, 0);
                    let a = MatRef::strided(&in_data[a0..], ow, k.ic, s.sw * ish.c);
                    let mut c = MatMut::new(c_rows, ow, k.kc);
                    // First position overwrites (stale output is never
                    // read), the rest accumulate the shifted products.
                    let beta = if u == 0 && v == 0 { 0.0 } else { 1.0 };
                    gemm_prepacked_beta(a, &slices[u * k.kw + v], &mut c, beta);
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::Direct;
    use crate::memory::Workspace;
    use crate::tensor::{KernelShape, Nhwc};
    use crate::util::{assert_allclose, Rng};

    #[test]
    fn zero_workspace_like_direct() {
        let shape = ConvShape::new(Nhwc::new(1, 9, 9, 4), KernelShape::new(3, 3, 4, 8), 1, 1);
        assert_eq!(Convolution::workspace_elems(&Kn2row, &shape), 0);
        let kernel = Kernel::zeros(shape.kernel);
        let plan = Kn2row.plan(&ConvContext::default(), &shape, &kernel);
        assert_eq!(plan.workspace_elems(), 0);
        assert!(plan.layout().regions().is_empty());
        // The resident prepack is the k_h·k_w pointwise slices — same
        // operand count as the kernel itself, just re-blocked.
        assert!(plan.resident_bytes() >= shape.kernel.len() * 4);
    }

    #[test]
    fn one_by_one_kernel_is_a_single_unshifted_gemm() {
        // The decomposition's best case: 1×1 conv = exactly one GEMM and
        // the shifted-accumulation loop degenerates to beta=0.
        let shape = ConvShape::new(Nhwc::new(2, 6, 6, 4), KernelShape::new(1, 1, 4, 8), 1, 1);
        let mut rng = Rng::new(41);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let ctx = ConvContext::default();
        let mut want = Tensor::zeros(shape.output());
        let mut got = Tensor::zeros(shape.output());
        let mut ws = Workspace::new();
        Direct.run(&ctx, &shape, &input, &kernel, &mut ws, &mut want);
        Kn2row.run(&ctx, &shape, &input, &kernel, &mut ws, &mut got);
        assert_allclose(got.data(), want.data(), 1e-4, &shape.describe());
    }

    #[test]
    fn matches_direct_on_random_geometries() {
        let mut rng = Rng::new(42);
        for (n, ih, iw, ic, kh, kw, kc, sh, sw) in [
            (1usize, 7, 7, 1, 3, 3, 1, 1, 1),
            (2, 9, 8, 3, 3, 2, 4, 2, 1),
            (1, 12, 12, 2, 5, 5, 3, 2, 2),
            (3, 6, 6, 4, 1, 1, 8, 1, 1),
            (1, 11, 5, 2, 4, 3, 2, 3, 2),
        ] {
            let shape = ConvShape::new(
                Nhwc::new(n, ih, iw, ic),
                KernelShape::new(kh, kw, ic, kc),
                sh,
                sw,
            );
            let input = Tensor::random(shape.input, &mut rng);
            let kernel = Kernel::random(shape.kernel, &mut rng);
            let ctx = ConvContext::default().with_threads(2);
            let mut want = Tensor::zeros(shape.output());
            let mut got = Tensor::zeros(shape.output());
            let mut ws = Workspace::new();
            Direct.run(&ctx, &shape, &input, &kernel, &mut ws, &mut want);
            Kn2row.run(&ctx, &shape, &input, &kernel, &mut ws, &mut got);
            assert_allclose(got.data(), want.data(), 1e-4, &shape.describe());
        }
    }

    #[test]
    fn stale_output_is_never_read() {
        // beta=0 on the first kernel position must overwrite whatever the
        // output tensor held — accumulating into garbage would only show
        // up on reuse, not first run.
        let shape = ConvShape::new(Nhwc::new(1, 7, 7, 2), KernelShape::new(3, 3, 2, 3), 2, 2);
        let mut rng = Rng::new(43);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let ctx = ConvContext::default();
        let plan = Kn2row.plan(&ctx, &shape, &kernel);
        let mut first = Tensor::zeros(shape.output());
        plan.execute_in(&input, &mut [], &mut first);
        let mut dirty = Tensor::from_fn(shape.output(), |_, _, _, _| 1e6);
        plan.execute_in(&input, &mut [], &mut dirty);
        assert_eq!(first, dirty);
    }
}
