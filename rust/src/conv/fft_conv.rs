//! FFT-based convolution — the `FFT.gpu` baseline (paper §2.2; Mathieu et
//! al. 2013; Vasilache et al. 2014).
//!
//! Convolution in the spatial domain is pointwise multiplication in the
//! frequency domain. The catch the paper leans on (Fig. 4e): **every
//! kernel must be padded up to the input size** before transforming, so
//! the temporary spectra occupy
//! `(i_c·k_c + i_c + …) · P_h·P_w` complex values — enormous when the
//! kernel (3×3) is much smaller than the input (224×224). That blow-up is
//! exactly what this module reproduces and what `fig4e` measures.
//!
//! CNN "convolution" is cross-correlation; we convert it to true (linear)
//! convolution by flipping the kernel, evaluate it circularly on a grid
//! padded to the next power of two ≥ `i + k - 1` (no wrap-around), and
//! read the valid window with stride.
//!
//! Plan/execute: kernel spectra are input-independent. When they fit
//! under `ctx.fft_cache_cap_bytes`, the **plan** transforms every kernel
//! once and holds the spectra (the cuFFT "plan + cached filter FFT"
//! deployment shape) — execute transforms only the input and runs the
//! pointwise/inverse stages. Above the cap, the plan fixes streaming
//! mode: kernels are re-transformed per output channel to stay runnable
//! on small hosts. The analytic `workspace_elems` still reports the
//! paper-model (all spectra live) footprint — that is the Fig. 4e
//! quantity — while the plan's own layout reflects what execute actually
//! touches.

use super::{downcast_prepack, AlgoKind, ConvContext, ConvPlan, Convolution, KernelPrepack};
use crate::fft::{fft2d, next_pow2, pointwise_mul_acc, C32};
use crate::memory::WorkspaceLayout;
use crate::tensor::{ConvShape, Kernel, Tensor};
use crate::threadpool::{Parallelism, SharedSlice};
use std::any::Any;
use std::sync::Arc;

pub struct FftConv;

/// The cached-vs-streaming decision plus its kernel-side data (cached:
/// every kernel spectrum; streaming: the raw kernel) — batch-independent
/// (spectra size is `i_c·k_c·P_h·P_w`, no `i_n` term), so a layer's
/// per-batch-size plans share one copy and one mode.
pub struct FftPrepack {
    mode: Mode,
}

impl KernelPrepack for FftPrepack {
    fn bytes(&self) -> usize {
        match &self.mode {
            Mode::Cached { kspec } => kspec.len() * 4,
            Mode::Streaming { kernel } => kernel.bytes(),
        }
    }

    fn into_any_arc(self: Arc<Self>) -> Arc<dyn Any + Send + Sync> {
        self
    }
}

/// Padded FFT grid for a geometry: next pow2 of `i + k - 1` per axis.
pub fn fft_grid(s: &ConvShape) -> (usize, usize) {
    (
        next_pow2(s.input.h + s.kernel.kh - 1),
        next_pow2(s.input.w + s.kernel.kw - 1),
    )
}

/// Complex values per spectrum.
fn spectrum_len(s: &ConvShape) -> usize {
    let (ph, pw) = fft_grid(s);
    ph * pw
}

/// Floats for the paper-model footprint: the fully-parallel GPU
/// formulation holds kernel spectra `i_c·k_c` **plus the whole batch's**
/// input spectra `i_n·i_c` and output accumulators `i_n·k_c` at once
/// (that is what lets cuFFT batch its transforms), each `P_h·P_w`
/// complex = 2 floats. Our CPU execution streams over samples and so
/// allocates less; `workspace_elems` reports the paper model, which is
/// the Fig. 4e quantity.
fn cached_workspace_elems(s: &ConvShape) -> usize {
    let sp = spectrum_len(s);
    let (ic, kc) = (s.kernel.ic, s.kernel.kc);
    let n = s.input.n;
    2 * sp * (ic * kc + n * ic + n * kc + 2)
}

/// Bytes the cached mode would hold resident: every kernel spectrum,
/// `i_c·k_c` complex planes of `P_h·P_w` — what `fft_cache_cap_bytes`
/// actually caps. Deliberately **batch-independent** (no `i_n` term), so
/// the cached-vs-streaming decision frozen into a layer's shared
/// [`FftPrepack`] is the same for every batch size the layer serves.
pub fn kernel_spectra_bytes(s: &ConvShape) -> usize {
    2 * spectrum_len(s) * s.kernel.ic * s.kernel.kc * 4
}

/// Would the cached mode fit under the cap?
pub fn uses_cache(ctx: &ConvContext, s: &ConvShape) -> bool {
    kernel_spectra_bytes(s) <= ctx.fft_cache_cap_bytes
}

impl Convolution for FftConv {
    fn name(&self) -> &'static str {
        "fft"
    }

    fn supports(&self, _s: &ConvShape) -> bool {
        true
    }

    /// Paper-model footprint (kernels padded to input size, all spectra
    /// live) — the quantity Fig. 4e plots.
    fn workspace_elems(&self, s: &ConvShape) -> usize {
        cached_workspace_elems(s)
    }

    fn prepack(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        kernel: &Kernel,
    ) -> Arc<dyn KernelPrepack> {
        assert_eq!(kernel.shape(), shape.kernel);
        let sp = spectrum_len(shape);
        let (ic, kc) = (shape.kernel.ic, shape.kernel.kc);
        let mode = if uses_cache(ctx, shape) {
            // ---- plan-time: every kernel spectrum, once ----
            let mut kspec = vec![0.0f32; 2 * sp * ic * kc];
            {
                let kshared = SharedSlice::new(&mut kspec);
                ctx.par.parallel_for(ic * kc, |t| {
                    let kb = kshared.slice();
                    let (i, o) = (t / kc, t % kc);
                    let spec = as_c32(&mut kb[2 * sp * t..2 * sp * (t + 1)]);
                    kernel_spectrum(shape, kernel, i, o, spec);
                });
            }
            Mode::Cached { kspec }
        } else {
            // Streaming: keep the raw kernel; spectra recomputed per
            // output channel at execute.
            Mode::Streaming {
                kernel: kernel.clone(),
            }
        };
        Arc::new(FftPrepack { mode })
    }

    fn plan_shared(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        prepack: Arc<dyn KernelPrepack>,
    ) -> Box<dyn ConvPlan> {
        let prepack: Arc<FftPrepack> = downcast_prepack(prepack, "fft");
        let sp = spectrum_len(shape);
        let (ic, kc) = (shape.kernel.ic, shape.kernel.kc);
        // The cached spectra are sized by the padded grid (input h/w), so
        // prepacks are shareable across batch sizes only — reject reuse
        // across a different spatial geometry instead of mis-indexing.
        match &prepack.mode {
            Mode::Cached { kspec } => assert_eq!(
                kspec.len(),
                2 * sp * ic * kc,
                "fft: shared prepack built for a different padded grid"
            ),
            Mode::Streaming { kernel } => assert_eq!(
                kernel.shape(),
                shape.kernel,
                "fft: shared prepack built for a different kernel geometry"
            ),
        }
        let threads = ctx.threads();
        let mut layout = WorkspaceLayout::new();
        layout.push("input-spectra", 2 * sp * ic);
        match &prepack.mode {
            // Per-thread inverse-transform accumulators.
            Mode::Cached { .. } => {
                layout.push("accumulators", 2 * sp * threads);
            }
            // Streaming: per-thread (accumulator + kernel scratch) lanes.
            Mode::Streaming { .. } => {
                layout.push("stream-scratch", 2 * sp * 2 * threads);
            }
        }
        Box::new(FftConvPlan {
            ctx: ctx.clone(),
            shape: *shape,
            prepack,
            layout,
        })
    }
}

enum Mode {
    /// Kernel spectra precomputed at plan time (fits the cache cap).
    Cached { kspec: Vec<f32> },
    /// Over the cap: keep the raw kernel, stream its transforms.
    Streaming { kernel: Kernel },
}

/// Plan for FFT-based convolution: cached-vs-streaming mode resolved, and
/// (in cached mode) every kernel spectrum precomputed — both shared.
pub struct FftConvPlan {
    ctx: ConvContext,
    shape: ConvShape,
    prepack: Arc<FftPrepack>,
    layout: WorkspaceLayout,
}

impl FftConvPlan {
    /// Whether this plan holds precomputed kernel spectra.
    pub fn is_cached(&self) -> bool {
        matches!(self.prepack.mode, Mode::Cached { .. })
    }
}

impl ConvPlan for FftConvPlan {
    fn algo(&self) -> AlgoKind {
        AlgoKind::Fft
    }

    fn shape(&self) -> &ConvShape {
        &self.shape
    }

    fn layout(&self) -> &WorkspaceLayout {
        &self.layout
    }

    fn resident_bytes(&self) -> usize {
        self.prepack.bytes()
    }

    fn shared_prepack(&self) -> Option<Arc<dyn KernelPrepack>> {
        Some(Arc::clone(&self.prepack) as Arc<dyn KernelPrepack>)
    }

    fn execute_in(&self, input: &Tensor, scratch: &mut [f32], output: &mut Tensor) {
        self.execute_with(&self.ctx, input, scratch, output);
    }

    fn execute_in_par(
        &self,
        input: &Tensor,
        scratch: &mut [f32],
        output: &mut Tensor,
        par: &Parallelism,
    ) {
        // Session thread cap: clamp into the plan-time budget. The
        // per-thread scratch lanes were sized for the plan budget, and the
        // clamp only ever shrinks the thread count, so the capped execute
        // uses a prefix of the laid-out lanes.
        let ctx = self
            .ctx
            .clone()
            .with_parallelism(self.ctx.par.with_budget(par.threads()));
        self.execute_with(&ctx, input, scratch, output);
    }
}

impl FftConvPlan {
    fn execute_with(
        &self,
        ctx: &ConvContext,
        input: &Tensor,
        scratch: &mut [f32],
        output: &mut Tensor,
    ) {
        let s = self.shape;
        assert_eq!(output.shape(), s.output());
        assert_eq!(input.shape(), s.input);
        match &self.prepack.mode {
            Mode::Cached { kspec } => {
                run_cached(ctx, &s, input, kspec, scratch, output);
            }
            Mode::Streaming { kernel } => {
                run_streaming(ctx, &s, input, kernel, scratch, output);
            }
        }
    }
}

/// Transform one kernel slice (i, o), flipped, into `spec`.
fn kernel_spectrum(s: &ConvShape, kernel: &Kernel, i: usize, o: usize, spec: &mut [C32]) {
    let (ph, pw) = fft_grid(s);
    let k = s.kernel;
    spec.fill(C32::ZERO);
    for u in 0..k.kh {
        for v in 0..k.kw {
            // Flip: correlation -> convolution.
            spec[(k.kh - 1 - u) * pw + (k.kw - 1 - v)] = C32::new(kernel.at(u, v, i, o), 0.0);
        }
    }
    fft2d(spec, ph, pw, false);
}

/// Transform one input channel of sample n into `spec`.
fn input_spectrum(s: &ConvShape, input: &Tensor, n: usize, i: usize, spec: &mut [C32]) {
    let (ph, pw) = fft_grid(s);
    let ish = s.input;
    spec.fill(C32::ZERO);
    for y in 0..ish.h {
        for x in 0..ish.w {
            spec[y * pw + x] = C32::new(input.at(n, y, x, i), 0.0);
        }
    }
    fft2d(spec, ph, pw, false);
}

/// Interpret a float slice as complex (len/2 C32s) — workspace is f32.
fn as_c32(buf: &mut [f32]) -> &mut [C32] {
    assert_eq!(buf.len() % 2, 0);
    // SAFETY: C32 is repr(Rust) of two f32 with align 4 and no padding —
    // identical layout to [f32; 2].
    unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut C32, buf.len() / 2) }
}

/// Read-only variant of [`as_c32`].
fn as_c32_ref(buf: &[f32]) -> &[C32] {
    assert_eq!(buf.len() % 2, 0);
    // SAFETY: same layout argument as `as_c32`.
    unsafe { std::slice::from_raw_parts(buf.as_ptr() as *const C32, buf.len() / 2) }
}

fn run_cached(
    ctx: &ConvContext,
    s: &ConvShape,
    input: &Tensor,
    kspec: &[f32],
    scratch: &mut [f32],
    output: &mut Tensor,
) {
    let sp = spectrum_len(s);
    let (ic, kc) = (s.kernel.ic, s.kernel.kc);
    let n = s.input.n;
    let threads = ctx.threads();

    let (xbuf, accbuf) = scratch[..2 * sp * (ic + threads)].split_at_mut(2 * sp * ic);

    for nn in 0..n {
        // Input spectra for this sample.
        {
            let xshared = SharedSlice::new(xbuf);
            ctx.par.parallel_for(ic, |i| {
                let xb = xshared.slice();
                let spec = as_c32(&mut xb[2 * sp * i..2 * sp * (i + 1)]);
                input_spectrum(s, input, nn, i, spec);
            });
        }
        // Accumulate + inverse per output channel (per-thread acc).
        let (ph, pw) = fft_grid(s);
        let xref: &[f32] = xbuf;
        let acc_shared = SharedSlice::new(accbuf);
        let out_shared = SharedSlice::new(output.data_mut());
        ctx.par.parallel_for_with_id(kc, |tid, o| {
            let accb = acc_shared.slice();
            let acc = as_c32(&mut accb[2 * sp * tid..2 * sp * (tid + 1)]);
            acc.fill(C32::ZERO);
            for i in 0..ic {
                let x = as_c32_ref(&xref[2 * sp * i..2 * sp * (i + 1)]);
                let kf = as_c32_ref(&kspec[2 * sp * (i * kc + o)..2 * sp * (i * kc + o + 1)]);
                pointwise_mul_acc(acc, x, kf);
            }
            fft2d(acc, ph, pw, true);
            // Each o writes disjoint output entries (channel stride).
            scatter_into(s, acc, nn, o, out_shared.slice());
        });
    }
}

/// scatter_output but writing into a raw output slice (parallel path).
fn scatter_into(s: &ConvShape, acc: &[C32], n: usize, o: usize, out: &mut [f32]) {
    let (_, pw) = fft_grid(s);
    let (oh, ow) = (s.oh(), s.ow());
    let k = s.kernel;
    let osh = s.output();
    for y in 0..oh {
        let row = (y * s.sh + k.kh - 1) * pw + (k.kw - 1);
        for x in 0..ow {
            out[osh.index(n, y, x, o)] = acc[row + x * s.sw].re;
        }
    }
}

fn run_streaming(
    ctx: &ConvContext,
    s: &ConvShape,
    input: &Tensor,
    kernel: &Kernel,
    scratch: &mut [f32],
    output: &mut Tensor,
) {
    let sp = spectrum_len(s);
    let (ic, kc) = (s.kernel.ic, s.kernel.kc);
    let n = s.input.n;
    let threads = ctx.threads();

    let (xbuf, lanes) = scratch[..2 * sp * (ic + 2 * threads)].split_at_mut(2 * sp * ic);

    let (ph, pw) = fft_grid(s);
    for nn in 0..n {
        {
            let xshared = SharedSlice::new(xbuf);
            ctx.par.parallel_for(ic, |i| {
                let xb = xshared.slice();
                let spec = as_c32(&mut xb[2 * sp * i..2 * sp * (i + 1)]);
                input_spectrum(s, input, nn, i, spec);
            });
        }
        let xref: &[f32] = xbuf;
        let scratch_shared = SharedSlice::new(lanes);
        let out_shared = SharedSlice::new(output.data_mut());
        ctx.par.parallel_for_with_id(kc, |tid, o| {
            let sb = scratch_shared.slice();
            let lane = &mut sb[2 * sp * 2 * tid..2 * sp * 2 * (tid + 1)];
            let (acc_f, kf_f) = lane.split_at_mut(2 * sp);
            let acc = as_c32(acc_f);
            let kf = as_c32(kf_f);
            acc.fill(C32::ZERO);
            for i in 0..ic {
                kernel_spectrum(s, kernel, i, o, kf);
                let x = as_c32_ref(&xref[2 * sp * i..2 * sp * (i + 1)]);
                pointwise_mul_acc(acc, x, kf);
            }
            fft2d(acc, ph, pw, true);
            scatter_into(s, acc, nn, o, out_shared.slice());
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::Direct;
    use crate::memory::Workspace;
    use crate::tensor::{KernelShape, Nhwc};
    use crate::util::{assert_allclose, Rng};

    fn check(shape: ConvShape, threads: usize, cap: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut ctx = ConvContext::default().with_threads(threads);
        ctx.fft_cache_cap_bytes = cap;
        let mut want = Tensor::zeros(shape.output());
        let mut got = Tensor::zeros(shape.output());
        let mut ws = Workspace::new();
        Direct.run(&ctx, &shape, &input, &kernel, &mut ws, &mut want);
        FftConv.run(&ctx, &shape, &input, &kernel, &mut ws, &mut got);
        assert_allclose(got.data(), want.data(), 1e-3, &shape.describe());
    }

    #[test]
    fn matches_direct_cached_mode() {
        for (n, ih, iw, ic, kh, kw, kc, sh, sw, seed) in [
            (1usize, 7, 7, 1, 3, 3, 1, 1, 1, 1u64),
            (2, 9, 8, 2, 3, 2, 3, 1, 1, 2),
            (1, 12, 10, 3, 5, 5, 2, 2, 2, 3),
            (1, 8, 8, 2, 3, 3, 4, 3, 1, 4),
        ] {
            let shape = ConvShape::new(
                Nhwc::new(n, ih, iw, ic),
                KernelShape::new(kh, kw, ic, kc),
                sh,
                sw,
            );
            check(shape, 1, usize::MAX, seed);
            check(shape, 3, usize::MAX, seed);
        }
    }

    #[test]
    fn matches_direct_streaming_mode() {
        let shape = ConvShape::new(Nhwc::new(2, 10, 10, 3), KernelShape::new(3, 3, 3, 4), 1, 1);
        check(shape, 1, 0, 7); // cap 0 -> always stream
        check(shape, 2, 0, 7);
    }

    #[test]
    fn plan_mode_follows_cache_cap() {
        let shape = ConvShape::new(Nhwc::new(1, 8, 8, 2), KernelShape::new(3, 3, 2, 3), 1, 1);
        let kernel = Kernel::zeros(shape.kernel);
        let plan = FftConv.plan(&ConvContext::default(), &shape, &kernel);
        // Default 256 MB cap: tiny geometry caches its spectra at plan
        // time, so execute's scratch excludes the i_c·k_c kernel planes.
        assert!(plan.workspace_elems() < Convolution::workspace_elems(&FftConv, &shape));
        let mut tight = ConvContext::default();
        tight.fft_cache_cap_bytes = 0;
        let streaming = FftConv.plan(&tight, &shape, &kernel);
        assert!(streaming.layout().region("stream-scratch").is_some());
    }

    #[test]
    fn grid_is_linear_conv_safe() {
        let s = ConvShape::new(Nhwc::new(1, 7, 7, 1), KernelShape::new(3, 3, 1, 1), 1, 1);
        let (ph, pw) = fft_grid(&s);
        assert!(ph >= 9 && pw >= 9);
        assert_eq!((ph, pw), (16, 16));
    }

    #[test]
    fn paper_model_overhead_dwarfs_mec_for_small_kernels() {
        // cv7-like scaled: 56x56x3 -> 3x3x8: FFT spectra must be much
        // bigger than MEC's L (Fig. 4e's qualitative claim).
        let s = ConvShape::new(Nhwc::new(1, 56, 56, 3), KernelShape::new(3, 3, 3, 8), 1, 1);
        let fft = Convolution::workspace_elems(&FftConv, &s);
        let mec = s.mec_lowered_elems();
        assert!(fft > 5 * mec, "fft={fft} mec={mec}");
    }
}
