//! Memory-optimized Winograd F(2×2,3×3) — the paper's actual `Wino.cpu`.
//!
//! §4 of the paper: "We took an open-source Winograd-based convolution
//! and **optimized it to reduce memory-overhead for CPU**". The fully
//! materialized formulation (`winograd.rs`, their GPU shape) holds all
//! 16 V/M planes at once; that costs ~16×(i_c+k_c)·P floats and is why
//! our Fig-4b Wino column initially showed 22× MEC instead of the
//! paper's 5.9×. This variant processes the tile dimension in **chunks**:
//! V and M exist only for `chunk` tiles at a time, while the transformed
//! kernel U (shared by all tiles) is plan-resident.
//!
//! Workspace: `16·k_c·i_c + chunk·16·(i_c + k_c)` floats (analytic,
//! budgeted) — for the paper's 3×3 layers this lands within a small
//! factor of MEC's L, reproducing the ~5.9× relationship (see
//! `memory_accounting` tests). At plan time, U and its 16 GEMM-prepacked
//! copies become plan-resident (paid once at model load like any other
//! prepacked weight), so per-call scratch is just the V/M chunk.

use super::winograd::{kernel_transform, tile_count};
use super::{downcast_prepack, AlgoKind, ConvContext, ConvPlan, Convolution, KernelPrepack};
use crate::gemm::{gemm_prepacked, KernelBackend, MatMut, MatRef, PackedB};
use crate::memory::WorkspaceLayout;
use crate::tensor::{ConvShape, Kernel, Tensor};
use crate::threadpool::{Parallelism, SharedSlice};
use std::any::Any;
use std::sync::Arc;

/// Tiles processed per chunk. 64 ⇒ V/M chunks of 16·64·(i_c+k_c) floats:
/// cache-resident for every cv layer while keeping gemm m=chunk efficient.
pub const DEFAULT_CHUNK: usize = 64;

pub struct WinogradChunked {
    pub chunk: usize,
}

impl Default for WinogradChunked {
    fn default() -> Self {
        WinogradChunked { chunk: DEFAULT_CHUNK }
    }
}

impl WinogradChunked {
    pub fn new(chunk: usize) -> WinogradChunked {
        WinogradChunked { chunk: chunk.max(1) }
    }
}

/// U transformed and GEMM-prepacked per xy (16 `PackedB`s) —
/// batch-independent, shared across a layer's per-batch-size plans.
pub struct WinogradChunkedPrepack {
    pub packed_u: Vec<PackedB>,
}

impl KernelPrepack for WinogradChunkedPrepack {
    fn bytes(&self) -> usize {
        self.packed_u.iter().map(|p| p.bytes()).sum()
    }

    fn into_any_arc(self: Arc<Self>) -> Arc<dyn Any + Send + Sync> {
        self
    }
}

impl Convolution for WinogradChunked {
    fn name(&self) -> &'static str {
        "winograd-chunked"
    }

    fn supports(&self, s: &ConvShape) -> bool {
        s.kernel.kh == 3 && s.kernel.kw == 3 && s.sh == 1 && s.sw == 1
    }

    /// U + one chunk of V and M — the budgeted total. A plan holds U
    /// (and its packs) as plan-resident memory
    /// ([`ConvPlan::resident_bytes`]); per-call scratch is the V/M chunk.
    fn workspace_elems(&self, s: &ConvShape) -> usize {
        let (ic, kc) = (s.kernel.ic, s.kernel.kc);
        let ch = self.chunk.min(tile_count(s)).max(1);
        16 * kc * ic + ch * 16 * (ic + kc)
    }

    fn prepack(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        kernel: &Kernel,
    ) -> Arc<dyn KernelPrepack> {
        assert!(
            self.supports(shape),
            "winograd-chunked: unsupported geometry {}",
            shape.describe()
        );
        assert_eq!(kernel.shape(), shape.kernel);
        let (ic, kc) = (shape.kernel.ic, shape.kernel.kc);

        // ---- plan-time: U once, then the 16 per-xy GEMM packs ----
        let mut u = vec![0.0f32; 16 * kc * ic];
        kernel_transform(ctx, kernel, ic, kc, &mut u);
        // gemm computes M_chunk (chunk×kc) = V_chunk (chunk×ic) × U (ic×kc):
        // U is stored [xy][o][i], so build each (ic × kc) view by a
        // one-time transpose copy, then pack it for gemm reuse.
        let packed_u: Vec<PackedB> = (0..16)
            .map(|xy| {
                let mut ut = vec![0.0f32; ic * kc];
                for o in 0..kc {
                    for i in 0..ic {
                        ut[i * kc + o] = u[xy * kc * ic + o * ic + i];
                    }
                }
                PackedB::pack(MatRef::new(&ut, ic, kc), ctx.blocks)
            })
            .collect();
        Arc::new(WinogradChunkedPrepack { packed_u })
    }

    fn plan_shared(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        prepack: Arc<dyn KernelPrepack>,
    ) -> Box<dyn ConvPlan> {
        assert!(
            self.supports(shape),
            "winograd-chunked: unsupported geometry {}",
            shape.describe()
        );
        let prepack: Arc<WinogradChunkedPrepack> = downcast_prepack(prepack, "winograd-chunked");
        assert_eq!(prepack.packed_u.len(), 16);
        let (ic, kc) = (shape.kernel.ic, shape.kernel.kc);
        let p_total = tile_count(shape);
        let chunk = self.chunk.min(p_total).max(1);
        let mut layout = WorkspaceLayout::new();
        layout.push("input-transform", chunk * 16 * ic);
        layout.push("products", chunk * 16 * kc);
        Box::new(WinogradChunkedPlan {
            ctx: ctx.clone(),
            shape: *shape,
            chunk,
            prepack,
            layout,
        })
    }
}

/// Plan for tile-chunked F(2×2,3×3): the 16 transformed-and-prepacked
/// filter matrices resident (shared), one chunk of V/M laid out.
pub struct WinogradChunkedPlan {
    ctx: ConvContext,
    shape: ConvShape,
    chunk: usize,
    prepack: Arc<WinogradChunkedPrepack>,
    layout: WorkspaceLayout,
}

impl ConvPlan for WinogradChunkedPlan {
    fn algo(&self) -> AlgoKind {
        AlgoKind::WinogradChunked
    }

    fn shape(&self) -> &ConvShape {
        &self.shape
    }

    fn layout(&self) -> &WorkspaceLayout {
        &self.layout
    }

    fn resident_bytes(&self) -> usize {
        self.prepack.bytes()
    }

    fn shared_prepack(&self) -> Option<Arc<dyn KernelPrepack>> {
        Some(Arc::clone(&self.prepack) as Arc<dyn KernelPrepack>)
    }

    fn kernel_backend(&self) -> Option<KernelBackend> {
        // The 16 per-xy filter packs carry the backend their strips were
        // packed for; all share it, so report the first.
        Some(self.prepack.packed_u[0].backend())
    }

    fn execute_in(&self, input: &Tensor, scratch: &mut [f32], output: &mut Tensor) {
        self.execute_with(&self.ctx, input, scratch, output);
    }

    fn execute_in_par(
        &self,
        input: &Tensor,
        scratch: &mut [f32],
        output: &mut Tensor,
        par: &Parallelism,
    ) {
        // Session thread cap: clamp into the plan-time budget, sharing
        // the plan's pool (see MecPlan::execute_in_par).
        let ctx = self
            .ctx
            .clone()
            .with_parallelism(self.ctx.par.with_budget(par.threads()));
        self.execute_with(&ctx, input, scratch, output);
    }
}

impl WinogradChunkedPlan {
    fn execute_with(
        &self,
        ctx: &ConvContext,
        input: &Tensor,
        scratch: &mut [f32],
        output: &mut Tensor,
    ) {
        let s = self.shape;
        assert_eq!(output.shape(), s.output());
        assert_eq!(input.shape(), s.input);
        let (ic, kc) = (s.kernel.ic, s.kernel.kc);
        let (oh, ow) = (s.oh(), s.ow());
        let (th, tw) = (oh.div_ceil(2), ow.div_ceil(2));
        let p_total = s.input.n * th * tw;
        let chunk = self.chunk;

        let (v, m) = scratch[..chunk * 16 * (ic + kc)].split_at_mut(chunk * 16 * ic);

        let ish = s.input;
        let osh = s.output();
        let in_data = input.data();
        let out_shared = SharedSlice::new(output.data_mut());
        let v_shared = SharedSlice::new(v);
        let m_shared = SharedSlice::new(m);

        let mut start = 0;
        while start < p_total {
            let len = chunk.min(p_total - start);
            // ---- input transform for tiles [start, start+len) ----
            {
                ctx.par.parallel_for_bytes(len, ic * 160, |t| {
                    let v_data = v_shared.slice();
                    let tile = start + t;
                    let n = tile / (th * tw);
                    let ty = (tile / tw) % th;
                    let tx = tile % tw;
                    let (y0, x0) = (2 * ty, 2 * tx);
                    for i in 0..ic {
                        let mut d = [[0.0f32; 4]; 4];
                        for (r, drow) in d.iter_mut().enumerate() {
                            let y = y0 + r;
                            if y >= ish.h {
                                continue;
                            }
                            for (c, dval) in drow.iter_mut().enumerate() {
                                let x = x0 + c;
                                if x < ish.w {
                                    *dval = in_data[ish.index(n, y, x, i)];
                                }
                            }
                        }
                        let mut t1 = [[0.0f32; 4]; 4];
                        for c in 0..4 {
                            t1[0][c] = d[0][c] - d[2][c];
                            t1[1][c] = d[1][c] + d[2][c];
                            t1[2][c] = d[2][c] - d[1][c];
                            t1[3][c] = d[1][c] - d[3][c];
                        }
                        for (r, row) in t1.iter().enumerate() {
                            let out4 = [
                                row[0] - row[2],
                                row[1] + row[2],
                                row[2] - row[1],
                                row[1] - row[3],
                            ];
                            for (c, &val) in out4.iter().enumerate() {
                                let xy = r * 4 + c;
                                // V chunk layout: [t][xy][i] (row t = one tile)
                                v_data[(t * 16 + xy) * ic + i] = val;
                            }
                        }
                    }
                });
            }
            // ---- 16 gemms: M[xy] (len×kc) = V[xy] (len×ic) × U (ic×kc) ----
            {
                let v_ref: &[f32] = v_shared.slice();
                ctx.par.parallel_for_macs(16, len * ic * kc, |xy| {
                    let m_data = m_shared.slice();
                    // Gather V rows for this xy: strided view with
                    // rs = 16·ic starting at xy·ic.
                    let a = MatRef::strided(&v_ref[xy * ic..], len, ic, 16 * ic);
                    let mut c = MatMut::strided(
                        &mut m_data[xy * kc..],
                        len,
                        kc,
                        16 * kc,
                    );
                    gemm_prepacked(a, &self.prepack.packed_u[xy], &mut c);
                });
            }
            // ---- output transform for this chunk ----
            {
                let m_ref: &[f32] = m_shared.slice();
                ctx.par.parallel_for_bytes(len, kc * 160, |t| {
                    let out_data = out_shared.slice();
                    let tile = start + t;
                    let n = tile / (th * tw);
                    let ty = (tile / tw) % th;
                    let tx = tile % tw;
                    let (y0, x0) = (2 * ty, 2 * tx);
                    for o in 0..kc {
                        let mut mm = [[0.0f32; 4]; 4];
                        for (r, mrow) in mm.iter_mut().enumerate() {
                            for (c, mval) in mrow.iter_mut().enumerate() {
                                let xy = r * 4 + c;
                                // M chunk layout: [t][xy][o]
                                *mval = m_ref[(t * 16 + xy) * kc + o];
                            }
                        }
                        let mut t1 = [[0.0f32; 4]; 2];
                        for c in 0..4 {
                            t1[0][c] = mm[0][c] + mm[1][c] + mm[2][c];
                            t1[1][c] = mm[1][c] - mm[2][c] - mm[3][c];
                        }
                        for (r, trow) in t1.iter().enumerate() {
                            let y = y0 + r;
                            if y >= osh.h {
                                continue;
                            }
                            let vals =
                                [trow[0] + trow[1] + trow[2], trow[1] - trow[2] - trow[3]];
                            for (c, &val) in vals.iter().enumerate() {
                                let x = x0 + c;
                                if x < osh.w {
                                    out_data[osh.index(n, y, x, o)] = val;
                                }
                            }
                        }
                    }
                });
            }
            start += len;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::Direct;
    use crate::conv::winograd::Winograd;
    use crate::memory::Workspace;
    use crate::tensor::{KernelShape, Nhwc};
    use crate::util::{assert_allclose, Rng};

    fn check(n: usize, ih: usize, iw: usize, ic: usize, kc: usize, chunk: usize, seed: u64) {
        let shape = ConvShape::new(
            Nhwc::new(n, ih, iw, ic),
            KernelShape::new(3, 3, ic, kc),
            1,
            1,
        );
        let mut rng = Rng::new(seed);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let ctx = ConvContext::default();
        let mut want = Tensor::zeros(shape.output());
        let mut got = Tensor::zeros(shape.output());
        let mut ws = Workspace::new();
        Direct.run(&ctx, &shape, &input, &kernel, &mut ws, &mut want);
        WinogradChunked::new(chunk).run(&ctx, &shape, &input, &kernel, &mut ws, &mut got);
        assert_allclose(got.data(), want.data(), 1e-3, &shape.describe());
    }

    #[test]
    fn matches_direct_various_chunks() {
        check(1, 8, 8, 2, 3, 1, 1); // chunk 1: max chunking
        check(1, 8, 8, 2, 3, 3, 2); // chunk smaller than tile count
        check(2, 10, 7, 3, 4, 64, 3); // chunk larger than tile count
        check(1, 7, 7, 1, 1, 2, 4); // odd output, clipping
    }

    #[test]
    fn matches_full_winograd() {
        let shape = ConvShape::new(Nhwc::new(2, 12, 12, 4), KernelShape::new(3, 3, 4, 5), 1, 1);
        let mut rng = Rng::new(9);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let ctx = ConvContext::default();
        let mut full = Tensor::zeros(shape.output());
        let mut chunked = Tensor::zeros(shape.output());
        let mut ws = Workspace::new();
        Winograd.run(&ctx, &shape, &input, &kernel, &mut ws, &mut full);
        WinogradChunked::default().run(&ctx, &shape, &input, &kernel, &mut ws, &mut chunked);
        assert_allclose(chunked.data(), full.data(), 1e-4, "chunked vs full");
    }

    #[test]
    fn memory_is_near_paper_ratio_vs_mec() {
        // Paper Fig 4b: Wino.cpu ≈ 5.9× MEC's memory on cv6-cv12 average.
        // The chunked variant must land in that regime (full variant is
        // far hungrier — all 16 V/M planes at once).
        let mut ratios = Vec::new();
        for w in crate::bench::workload::suite() {
            let shape = w.shape(1, 1);
            let wino = WinogradChunked::default();
            if !Convolution::supports(&wino, &shape) {
                continue;
            }
            let r = Convolution::workspace_elems(&wino, &shape) as f64
                / shape.mec_lowered_elems() as f64;
            ratios.push(r);
        }
        let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
        // The floor is the transformed-kernel plane U = 16·k_c·i_c floats
        // (irreducible: every Winograd impl stores all transformed
        // filters — plans hold it resident, the analytic total counts
        // it), which alone is ~10-38x MEC's L on the fat late layers
        // (cv6/cv12) and ~0.1x on the thin early ones. The paper's 5.9x
        // average sits inside this spread; assert the regime.
        assert!(
            avg > 1.0 && avg < 20.0,
            "chunked winograd / MEC memory ratio avg {avg} out of plausible range"
        );
        // And chunking must beat the fully-materialized formulation badly.
        let full_avg: f64 = crate::bench::workload::suite()
            .iter()
            .filter(|w| w.kh == 3 && w.s == 1)
            .map(|w| {
                let shape = w.shape(1, 1);
                Convolution::workspace_elems(&Winograd, &shape) as f64
                    / Convolution::workspace_elems(&WinogradChunked::default(), &shape) as f64
            })
            .sum::<f64>()
            / 7.0;
        assert!(full_avg > 2.0, "chunking should shrink Winograd, avg {full_avg}");
    }

    #[test]
    fn threaded_matches_single() {
        let shape = ConvShape::new(Nhwc::new(1, 14, 14, 3), KernelShape::new(3, 3, 3, 4), 1, 1);
        let mut rng = Rng::new(11);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut ws = Workspace::new();
        let mut o1 = Tensor::zeros(shape.output());
        let mut o4 = Tensor::zeros(shape.output());
        let w = WinogradChunked::default();
        w.run(&ConvContext::default(), &shape, &input, &kernel, &mut ws, &mut o1);
        w.run(
            &ConvContext::default().with_threads(4),
            &shape,
            &input,
            &kernel,
            &mut ws,
            &mut o4,
        );
        assert_eq!(o1.data(), o4.data());
    }
}
