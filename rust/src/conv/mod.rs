//! Convolution algorithms — the paper's subject matter.
//!
//! One module per algorithm the paper evaluates (§4):
//!
//! | module      | paper name            | role |
//! |-------------|-----------------------|------|
//! | [`direct`]  | direct convolution    | zero-overhead oracle |
//! | [`im2col`]  | Conv.cpu / Conv.gpu   | baseline lowering (Eq. 2) |
//! | [`mec`]     | MEC.cpu / MEC.gpu     | **the contribution** (Alg. 2, Eq. 3) |
//! | [`winograd`]| Wino.cpu / Wino.gpu   | F(2×2, 3×3) baseline |
//! | [`fft_conv`]| FFT.gpu               | frequency-domain baseline |
//!
//! Beyond the paper's own systems, the menu carries the related-work
//! lowering strategies the planner chooses among per geometry:
//!
//! | module      | origin                               | role |
//! |-------------|--------------------------------------|------|
//! | [`indirect`]| Indirect Convolution (Dukhan)        | pointer-buffer gather, O(k²·o_h) plan memory |
//! | [`kn2row`]  | kn2row (Anderson et al.)             | 1×1-decomposed accumulating GEMM, zero workspace |
//! | [`smm`]     | SMM-Conv-style scalar streaming      | zero-packing scalar×row accumulation |
//!
//! # Plan / execute split
//!
//! The API is two-phase, cuDNN-graph style (see `ARCHITECTURE.md`):
//!
//! * [`Convolution::plan`] runs **once per (geometry, context)** — at
//!   model load. It resolves every data-independent decision (MEC's
//!   Solution A/B + `T` dispatch, FFT cached-vs-streaming mode), performs
//!   every kernel-side precomputation (GEMM B-operand packing via
//!   [`PackedB`](crate::gemm::PackedB), Winograd filter transforms, FFT
//!   kernel spectra), and emits a [`WorkspaceLayout`] of named offsets
//!   into a single scratch buffer.
//! * [`ConvPlan::execute`] runs **per request** and allocates and
//!   recomputes nothing: scratch comes from a caller-owned
//!   [`Arena`], prepacked operands come from the plan.
//!
//! The one-shot [`Convolution::run`] (and the [`convolve`] helper) is a
//! thin plan-then-execute wrapper, so the two paths are the same code and
//! produce bit-identical outputs by construction. The explicit-workspace
//! request (`workspace_elems` = the paper's memory-overhead, §3.4) is
//! unchanged — that is still what the planner budgets against and what
//! the memory benches report.

pub mod direct;
pub mod fft_conv;
pub mod im2col;
pub mod indirect;
pub mod kn2row;
pub mod mec;
pub mod smm;
pub mod winograd;
pub mod winograd_chunked;

use crate::gemm::{BlockSizes, KernelBackend, MatRef, MatRefI16, PackedB, PackedBI16};
use crate::memory::{Arena, Workspace, WorkspaceLayout};
use crate::tensor::quant::{Precision, QParams};
use crate::tensor::{ConvShape, Kernel, Tensor};
use crate::threadpool::Parallelism;
use std::any::Any;
use std::sync::Arc;

/// Execution environment for a convolution call.
#[derive(Debug, Clone)]
pub struct ConvContext {
    /// Parallel-execution handle for the loops (paper: OpenMP threads /
    /// GPU blocks): a shared persistent [`Pool`](crate::threadpool::Pool)
    /// plus a thread budget. A budget of 1 (no pool, no workers) models
    /// the paper's Mobile platform. Contexts cloned from one another —
    /// e.g. every [`Session`](crate::engine::Session) of an engine —
    /// share the same pool; steady-state execution never spawns OS
    /// threads.
    pub par: Parallelism,
    /// GEMM cache-blocking parameters (ablation_gemm sweeps these).
    pub blocks: BlockSizes,
    /// MEC's Solution A/B dispatch threshold `T` (Algorithm 2 line 8).
    /// The paper found ~100 good for GPUs.
    pub mec_t: usize,
    /// Cap on cached FFT kernel spectra; above this the FFT algorithm
    /// streams kernel transforms instead of caching them.
    pub fft_cache_cap_bytes: usize,
    /// Execution precision of the GEMM-lowering family (paper §4's two
    /// grids): `F32`, or `Q16` (i16 storage, i32 accumulate, symmetric
    /// per-tensor scales — kernels quantized at plan time, activations
    /// per execute). `direct` always runs f32 (the reference oracle);
    /// Winograd/FFT have no q16 path, so the planner excludes them under
    /// `Q16` and falls back to the quantized GEMM family.
    pub precision: Precision,
    /// Calibrated static activation scale for q16 plans. `None` (the
    /// default) keeps the dynamic per-execute abs-max pass; `Some` bakes
    /// the scale into the plan so serving skips that pass entirely. Set
    /// per conv node by the model when the engine was built with a
    /// calibration set; ignored under `F32`.
    pub act_qparams: Option<QParams>,
}

impl Default for ConvContext {
    fn default() -> Self {
        ConvContext {
            par: Parallelism::inline(),
            blocks: BlockSizes::default(),
            mec_t: 100,
            fft_cache_cap_bytes: 256 << 20,
            precision: Precision::F32,
            act_qparams: None,
        }
    }
}

impl ConvContext {
    /// Paper "Mobile" platform: 1 thread, batch handled by caller.
    pub fn mobile() -> ConvContext {
        ConvContext::default()
    }

    /// Paper "Server" platform: all cores (or the `MEC_THREADS` env
    /// override, so bench/CI runs can pin the thread count).
    pub fn server() -> ConvContext {
        let t = threads_env().unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
        ConvContext::default().with_threads(t)
    }

    /// The thread budget of the parallel loops (≥ 1; 1 = fully inline).
    pub fn threads(&self) -> usize {
        self.par.threads()
    }

    /// Set the thread budget, spawning a persistent worker pool for
    /// budgets > 1. The pool's inline-vs-dispatch grain is sized from
    /// the planner's calibrated [`CostModel`](crate::planner::CostModel)
    /// so loops too small to pay a pool wake-up run on the caller.
    pub fn with_threads(mut self, t: usize) -> ConvContext {
        self.par = Parallelism::with_grain(t, crate::planner::CostModel::default().grain_model());
        self
    }

    /// Replace the parallelism handle wholesale (e.g. a budget-capped
    /// clone sharing an existing pool).
    pub fn with_parallelism(mut self, par: Parallelism) -> ConvContext {
        self.par = par;
        self
    }

    pub fn with_mec_t(mut self, t: usize) -> ConvContext {
        self.mec_t = t;
        self
    }

    pub fn with_precision(mut self, p: Precision) -> ConvContext {
        self.precision = p;
        self
    }

    /// Bake a calibrated static activation scale into plans built under
    /// this context (q16 serving skips the per-execute abs-max pass).
    pub fn with_act_qparams(mut self, q: QParams) -> ConvContext {
        self.act_qparams = Some(q);
        self
    }
}

/// The ONE parser of the `MEC_THREADS` thread-pin env var (`Some(t)` for
/// a valid integer ≥ 1, `None` otherwise): [`ConvContext::server`], the
/// bench harness ([`bench_threads`](crate::bench::harness::bench_threads),
/// which adds a warning for set-but-invalid values), and the dispatch
/// microbench all read it through here so the parse cannot drift.
pub fn threads_env() -> Option<usize> {
    std::env::var("MEC_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&t| t >= 1)
}

/// A batch-independent kernel-side precomputation: the prepacked GEMM
/// B-operand (im2col/MEC), Winograd's transformed filters U, FFT kernel
/// spectra, or direct's owned kernel copy. Everything a plan holds that
/// depends only on `(kernel, context)` — never on the batch size — lives
/// behind this trait, so the model can build it **once per layer** and
/// `Arc`-share it across every per-batch-size [`ConvPlan`] (dynamic
/// batching used to duplicate these per cached geometry).
pub trait KernelPrepack: Send + Sync {
    /// Resident bytes held by the shared prepack (counted once per layer,
    /// not per plan).
    fn bytes(&self) -> usize;

    /// Type recovery for [`Convolution::plan_shared`].
    fn into_any_arc(self: Arc<Self>) -> Arc<dyn Any + Send + Sync>;
}

/// Downcast a shared prepack to the algorithm's concrete type; panics
/// with the algorithm name when handed a foreign prepack.
pub(crate) fn downcast_prepack<T: Send + Sync + 'static>(
    prepack: Arc<dyn KernelPrepack>,
    algo: &str,
) -> Arc<T> {
    prepack
        .into_any_arc()
        .downcast::<T>()
        .unwrap_or_else(|_| panic!("{algo}: shared prepack built by a different algorithm"))
}

/// The prepacked GEMM B-operand for the kernel matrix
/// (`k_h·k_w·i_c × k_c`), in the planned precision — the shared prepack
/// of both the im2col and MEC plans. Q16 quantizes the kernel once here
/// with **per-output-channel** symmetric scales (column `c` of the kernel
/// matrix is output channel `c`; each gets its own abs-max scale, so a
/// channel of small weights is not crushed by one loud channel
/// elsewhere), applied at execute time through the
/// [`Q16Epilogue`](crate::gemm::Q16Epilogue)'s `per_col` table — execute
/// never touches the f32 weights.
pub enum PackedKernel {
    F32(PackedB),
    Q16 {
        packed: PackedBI16,
        /// Per-output-channel kernel scales, `shape.kernel.kc` entries;
        /// borrowed by the epilogue (no per-execute allocation).
        col_scales: Vec<f32>,
    },
}

impl PackedKernel {
    pub fn pack(ctx: &ConvContext, shape: &ConvShape, kernel: &Kernel) -> PackedKernel {
        assert_eq!(kernel.shape(), shape.kernel);
        let k = shape.kernel;
        let kdim = k.kh * k.kw * k.ic;
        match ctx.precision {
            Precision::F32 => PackedKernel::F32(PackedB::pack(
                MatRef::new(kernel.data(), kdim, k.kc),
                ctx.blocks,
            )),
            Precision::Q16 => {
                let data = kernel.data();
                let mut q = vec![0i16; data.len()];
                let mut col_scales = Vec::with_capacity(k.kc);
                for c in 0..k.kc {
                    let mut abs_max = 0f32;
                    for r in 0..kdim {
                        abs_max = abs_max.max(data[r * k.kc + c].abs());
                    }
                    let qc = QParams::from_abs_max(abs_max);
                    for r in 0..kdim {
                        q[r * k.kc + c] = qc.quantize(data[r * k.kc + c]);
                    }
                    col_scales.push(qc.scale);
                }
                PackedKernel::Q16 {
                    packed: PackedBI16::pack(MatRefI16::new(&q, kdim, k.kc), ctx.blocks),
                    col_scales,
                }
            }
        }
    }

    /// Bytes of the packed operand itself (the per-channel scale table is
    /// bookkeeping, not operand storage — the exact-halving tests compare
    /// operand bytes against the f32 pack).
    pub fn bytes(&self) -> usize {
        match self {
            PackedKernel::F32(p) => p.bytes(),
            PackedKernel::Q16 { packed, .. } => packed.bytes(),
        }
    }

    pub fn precision(&self) -> Precision {
        match self {
            PackedKernel::F32(_) => Precision::F32,
            PackedKernel::Q16 { .. } => Precision::Q16,
        }
    }

    /// The micro-kernel backend the operand was packed for.
    pub fn backend(&self) -> KernelBackend {
        match self {
            PackedKernel::F32(p) => p.backend(),
            PackedKernel::Q16 { packed, .. } => packed.backend(),
        }
    }
}

impl KernelPrepack for PackedKernel {
    fn bytes(&self) -> usize {
        PackedKernel::bytes(self)
    }

    fn into_any_arc(self: Arc<Self>) -> Arc<dyn Any + Send + Sync> {
        self
    }
}

/// A prepared convolution: geometry resolved, kernel-side operands
/// prepacked/transformed, workspace layout fixed. Built once by
/// [`Convolution::plan`]; [`ConvPlan::execute`] is the allocation-free
/// hot path.
pub trait ConvPlan: Send + Sync {
    /// The algorithm this plan executes.
    fn algo(&self) -> AlgoKind;

    /// The geometry the plan was built for.
    fn shape(&self) -> &ConvShape;

    /// The plan's scratch-memory map (named regions in one buffer).
    fn layout(&self) -> &WorkspaceLayout;

    /// Scratch floats `execute` needs — the layout total. For algorithms
    /// whose kernel-side precomputation moved into the plan (Winograd
    /// filter transforms, FFT spectra) this is *smaller* than the
    /// one-shot algorithm's analytic `workspace_elems`.
    fn workspace_elems(&self) -> usize {
        self.layout().total_elems()
    }

    /// Same in bytes.
    fn workspace_bytes(&self) -> usize {
        self.workspace_elems() * std::mem::size_of::<f32>()
    }

    /// Bytes the plan itself holds resident (prepacked kernel matrices,
    /// transformed filters, cached spectra, owned kernel copies) —
    /// model-load memory, paid once, carved out of the algorithm-level
    /// analytic `workspace_elems` where applicable. `resident_bytes` +
    /// `workspace_bytes` ≈ the algorithm's total footprint beyond I/K/O.
    /// Since prepacks are `Arc`-shared, plans for several batch sizes of
    /// one layer report the same resident bytes but hold one copy.
    fn resident_bytes(&self) -> usize {
        0
    }

    /// The shared batch-independent prepack this plan executes with —
    /// what the model's per-layer prepack cache hands out, and what the
    /// sharing tests compare by pointer.
    fn shared_prepack(&self) -> Option<Arc<dyn KernelPrepack>> {
        None
    }

    /// The micro-kernel backend this plan's GEMMs dispatch to, when the
    /// algorithm runs through the GEMM substrate (`None` for direct /
    /// FFT, whose inner loops are not micro-kernel shaped). Reported per
    /// layer by the engine so serving logs say which ISA actually ran.
    fn kernel_backend(&self) -> Option<KernelBackend> {
        None
    }

    /// Core entry point: run the convolution with caller-provided scratch
    /// of at least [`Self::workspace_elems`] floats. Writes every output
    /// element; reads no stale scratch. Performs no allocation and no
    /// kernel repacking/transforms.
    fn execute_in(&self, input: &Tensor, scratch: &mut [f32], output: &mut Tensor);

    /// [`execute_in`](Self::execute_in) under a caller thread cap: run
    /// with at most `par.threads()` threads (clamped to the plan's own
    /// budget — a cap can shrink parallelism, never grow it past what the
    /// plan's workspace was sized for). This is how per-session budgets
    /// (`Engine::session_with_threads`) reach the inner loops exactly
    /// instead of only capping session-side batch loops. The default
    /// ignores the cap — correct for serial plans; every parallel
    /// algorithm overrides it.
    fn execute_in_par(
        &self,
        input: &Tensor,
        scratch: &mut [f32],
        output: &mut Tensor,
        par: &Parallelism,
    ) {
        let _ = par;
        self.execute_in(input, scratch, output);
    }

    /// Run the convolution against a shared [`Arena`]. The arena grows to
    /// the layout total on first use (tracked); after that, repeated
    /// calls allocate zero tracked bytes.
    fn execute(&self, input: &Tensor, arena: &mut Arena, output: &mut Tensor) {
        let elems = self.workspace_elems();
        self.execute_in(input, arena.slice(elems), output);
    }

    /// [`execute`](Self::execute) under a caller thread cap (see
    /// [`execute_in_par`](Self::execute_in_par)).
    fn execute_par(
        &self,
        input: &Tensor,
        arena: &mut Arena,
        output: &mut Tensor,
        par: &Parallelism,
    ) {
        let elems = self.workspace_elems();
        self.execute_in_par(input, arena.slice(elems), output, par);
    }
}

/// A convolution algorithm with an explicit-workspace, two-phase API.
pub trait Convolution: Send + Sync {
    /// Short name used in reports ("MEC.cpu" style naming lives in the
    /// bench layer; this is the algorithm identity).
    fn name(&self) -> &'static str;

    /// Whether this algorithm can handle the geometry (e.g. Winograd
    /// F(2×2,3×3) requires k=3×3, s=1 — paper §4).
    fn supports(&self, shape: &ConvShape) -> bool;

    /// Temporary floats needed beyond I, K, O — the paper's
    /// "memory-overhead" (§3.4), exact per algorithm. This is the
    /// *analytic, algorithm-level* figure the planner budgets with;
    /// a plan's own `workspace_elems` can be smaller when kernel-side
    /// buffers moved to plan time.
    fn workspace_elems(&self, shape: &ConvShape) -> usize;

    /// Same in bytes.
    fn workspace_bytes(&self, shape: &ConvShape) -> usize {
        self.workspace_elems(shape) * std::mem::size_of::<f32>()
    }

    /// Analytic workspace in bytes under `precision` — what a
    /// precision-aware planner budgets with. Defaults to the f32 figure;
    /// the GEMM-lowering family overrides it so the halved i16 buffers
    /// genuinely relax tight budgets (the paper's fixed-point memory
    /// win), matching the plan's actual layout for that precision.
    fn workspace_bytes_prec(&self, shape: &ConvShape, precision: Precision) -> usize {
        let _ = precision;
        self.workspace_bytes(shape)
    }

    /// Build the batch-independent kernel-side prepack for this algorithm
    /// (everything `plan` precomputes that does not depend on the batch
    /// size). The model builds this once per layer and shares it across
    /// per-batch-size plans via [`Convolution::plan_shared`].
    fn prepack(&self, ctx: &ConvContext, shape: &ConvShape, kernel: &Kernel)
        -> Arc<dyn KernelPrepack>;

    /// Build a plan around an externally shared prepack. The prepack must
    /// come from this algorithm's [`Convolution::prepack`] under an
    /// equivalent context and the same kernel; a foreign prepack panics.
    fn plan_shared(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        prepack: Arc<dyn KernelPrepack>,
    ) -> Box<dyn ConvPlan>;

    /// Build a reusable plan: resolve dispatch, prepack/transform the
    /// kernel, fix the workspace layout. Pays all setup cost once so
    /// [`ConvPlan::execute`] can amortize it across every request.
    /// (A thin prepack-then-plan_shared composition, so the one-shot and
    /// shared paths are the same code.)
    fn plan(&self, ctx: &ConvContext, shape: &ConvShape, kernel: &Kernel) -> Box<dyn ConvPlan> {
        self.plan_shared(ctx, shape, self.prepack(ctx, shape, kernel))
    }

    /// One-shot convenience: plan, then execute out of `ws`. Kept for
    /// tests/examples and cold paths; the serving stack holds plans
    /// directly. `output` must be pre-allocated to `shape.output()`.
    fn run(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        input: &Tensor,
        kernel: &Kernel,
        ws: &mut Workspace,
        output: &mut Tensor,
    ) {
        let plan = self.plan(ctx, shape, kernel);
        let scratch = ws.take_uninit(plan.workspace_elems());
        plan.execute_in(input, scratch, output);
    }
}

/// Algorithm identifiers for CLI/planner/config use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgoKind {
    Direct,
    Im2col,
    /// MEC with automatic Solution A/B dispatch (Algorithm 2 line 8).
    Mec,
    /// MEC pinned to Solution A (h-n-w-c gemm + repack).
    MecSolutionA,
    /// MEC pinned to Solution B (per-sample batched gemms).
    MecSolutionB,
    /// Fully-materialized F(2×2,3×3) — the paper's Wino.gpu formulation.
    Winograd,
    /// Tile-chunked F(2×2,3×3) — the paper's memory-optimized Wino.cpu.
    WinogradChunked,
    Fft,
    /// Indirect Convolution (Dukhan): plan-time offset buffer into the
    /// input replaces im2col's lowered matrix; execute gathers one
    /// fixed-size row strip per task and GEMMs it against the shared
    /// prepacked kernel. Pointer memory is O(k_h·k_w·o_h), independent of
    /// batch and lowering size.
    Indirect,
    /// kn2row (Anderson et al.): the k×k conv as k² accumulating 1×1
    /// GEMMs shifted into the output — near-zero workspace.
    Kn2row,
    /// SMM-Conv-style scalar-matrix accumulation: zero packing, zero
    /// workspace, streaming over kernel positions.
    SmmConv,
}

/// Error for [`AlgoKind::from_str`]: the offending input plus the list of
/// accepted names.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseAlgoError(pub String);

impl std::fmt::Display for ParseAlgoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown algorithm {:?} (expected one of: direct, im2col, mec, mec-a, mec-b, winograd, winograd-chunked, fft, indirect, kn2row, smm)",
            self.0
        )
    }
}

impl std::error::Error for ParseAlgoError {}

impl AlgoKind {
    pub const ALL: [AlgoKind; 11] = [
        AlgoKind::Direct,
        AlgoKind::Im2col,
        AlgoKind::Mec,
        AlgoKind::MecSolutionA,
        AlgoKind::MecSolutionB,
        AlgoKind::Winograd,
        AlgoKind::WinogradChunked,
        AlgoKind::Fft,
        AlgoKind::Indirect,
        AlgoKind::Kn2row,
        AlgoKind::SmmConv,
    ];

    /// The subset benchmarked as distinct systems in the paper.
    pub const PAPER: [AlgoKind; 5] = [
        AlgoKind::Direct,
        AlgoKind::Im2col,
        AlgoKind::Mec,
        AlgoKind::Winograd,
        AlgoKind::Fft,
    ];

    /// The planner's full decision menu: the paper's five systems plus
    /// the related-work lowerings (indirect, kn2row, SMM). MEC's pinned
    /// A/B variants and the fully-materialized Winograd stay out — they
    /// are ablation handles, dominated by their auto-dispatching parents.
    pub const MENU: [AlgoKind; 8] = [
        AlgoKind::Direct,
        AlgoKind::Im2col,
        AlgoKind::Mec,
        AlgoKind::Winograd,
        AlgoKind::Fft,
        AlgoKind::Indirect,
        AlgoKind::Kn2row,
        AlgoKind::SmmConv,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::Direct => "direct",
            AlgoKind::Im2col => "im2col",
            AlgoKind::Mec => "mec",
            AlgoKind::MecSolutionA => "mec-a",
            AlgoKind::MecSolutionB => "mec-b",
            AlgoKind::Winograd => "winograd",
            AlgoKind::WinogradChunked => "winograd-chunked",
            AlgoKind::Fft => "fft",
            AlgoKind::Indirect => "indirect",
            AlgoKind::Kn2row => "kn2row",
            AlgoKind::SmmConv => "smm",
        }
    }

    /// Case-insensitive name lookup (accepts the aliases the CLI and
    /// config files have historically used). `FromStr` delegates here so
    /// callers can also write `s.parse::<AlgoKind>()?`.
    pub fn parse(s: &str) -> Option<AlgoKind> {
        Some(match s.trim().to_ascii_lowercase().as_str() {
            "direct" => AlgoKind::Direct,
            "im2col" | "conv" => AlgoKind::Im2col,
            "mec" => AlgoKind::Mec,
            "mec-a" | "mec_a" => AlgoKind::MecSolutionA,
            "mec-b" | "mec_b" => AlgoKind::MecSolutionB,
            "winograd" | "wino" => AlgoKind::Winograd,
            "winograd-chunked" | "wino-cpu" => AlgoKind::WinogradChunked,
            "fft" => AlgoKind::Fft,
            "indirect" | "indirect-conv" => AlgoKind::Indirect,
            "kn2row" | "kn2row-as" => AlgoKind::Kn2row,
            "smm" | "smm-conv" | "smmconv" => AlgoKind::SmmConv,
            _ => return None,
        })
    }

    /// Whether the algorithm has an execution path for precision `p`.
    /// The GEMM-lowering family (im2col, every MEC variant, indirect —
    /// which quantizes while gathering exactly like im2col quantizes
    /// while lowering) runs q16; `direct` stays the f32 reference;
    /// Winograd and FFT are f32-only (their transforms have no
    /// fixed-point formulation here), and kn2row/SMM accumulate straight
    /// into the f32 output (no i16 accumulating GEMM exists), so a q16
    /// planner treats those as unsupported and falls back.
    pub fn supports_precision(&self, p: Precision) -> bool {
        match p {
            Precision::F32 => true,
            Precision::Q16 => matches!(
                self,
                AlgoKind::Direct
                    | AlgoKind::Im2col
                    | AlgoKind::Mec
                    | AlgoKind::MecSolutionA
                    | AlgoKind::MecSolutionB
                    | AlgoKind::Indirect
            ),
        }
    }

    /// Instantiate the algorithm.
    pub fn build(&self) -> Box<dyn Convolution> {
        match self {
            AlgoKind::Direct => Box::new(direct::Direct),
            AlgoKind::Im2col => Box::new(im2col::Im2col),
            AlgoKind::Mec => Box::new(mec::Mec::auto()),
            AlgoKind::MecSolutionA => Box::new(mec::Mec::solution_a()),
            AlgoKind::MecSolutionB => Box::new(mec::Mec::solution_b()),
            AlgoKind::Winograd => Box::new(winograd::Winograd),
            AlgoKind::WinogradChunked => Box::new(winograd_chunked::WinogradChunked::default()),
            AlgoKind::Fft => Box::new(fft_conv::FftConv),
            AlgoKind::Indirect => Box::new(indirect::IndirectConv),
            AlgoKind::Kn2row => Box::new(kn2row::Kn2row),
            AlgoKind::SmmConv => Box::new(smm::SmmConv),
        }
    }
}

impl std::fmt::Display for AlgoKind {
    /// The canonical CLI name — guaranteed to round-trip through
    /// [`AlgoKind::parse`] (asserted for every variant in the unit
    /// tests), so `--algo {k}` always works.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for AlgoKind {
    type Err = ParseAlgoError;

    fn from_str(s: &str) -> Result<AlgoKind, ParseAlgoError> {
        AlgoKind::parse(s).ok_or_else(|| ParseAlgoError(s.to_string()))
    }
}

/// Convenience: run `algo` on fresh workspace, returning the output.
/// A thin plan-then-execute wrapper — identical code path to holding a
/// [`ConvPlan`] and executing it against an [`Arena`].
pub fn convolve(
    algo: AlgoKind,
    ctx: &ConvContext,
    shape: &ConvShape,
    input: &Tensor,
    kernel: &Kernel,
) -> Tensor {
    let a = algo.build();
    assert!(
        a.supports(shape),
        "{} does not support geometry {}",
        a.name(),
        shape.describe()
    );
    let mut ws = Workspace::new();
    let mut out = Tensor::zeros(shape.output());
    a.run(ctx, shape, input, kernel, &mut ws, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_names_roundtrip() {
        for k in AlgoKind::ALL {
            assert_eq!(AlgoKind::parse(k.name()), Some(k));
            // Display is the CLI spelling: parse(display(k)) == k for
            // every variant, so new menu entries can't silently break
            // the `--algo` flag.
            assert_eq!(AlgoKind::parse(&k.to_string()), Some(k), "{k}");
            assert_eq!(k.to_string().parse::<AlgoKind>(), Ok(k), "{k}");
        }
        assert_eq!(AlgoKind::parse("nope"), None);
    }

    #[test]
    fn parse_is_case_insensitive() {
        assert_eq!(AlgoKind::parse("MEC"), Some(AlgoKind::Mec));
        assert_eq!(AlgoKind::parse("Im2Col"), Some(AlgoKind::Im2col));
        assert_eq!(AlgoKind::parse("  WINO-CPU "), Some(AlgoKind::WinogradChunked));
        assert_eq!(AlgoKind::parse("MEC_A"), Some(AlgoKind::MecSolutionA));
    }

    #[test]
    fn from_str_delegates_to_parse() {
        assert_eq!("fft".parse::<AlgoKind>(), Ok(AlgoKind::Fft));
        assert_eq!("Direct".parse::<AlgoKind>(), Ok(AlgoKind::Direct));
        let err = "bogus".parse::<AlgoKind>().unwrap_err();
        assert!(err.to_string().contains("bogus"));
        assert!(err.to_string().contains("winograd"));
    }

    #[test]
    fn contexts() {
        assert_eq!(ConvContext::mobile().threads(), 1);
        assert!(ConvContext::server().threads() >= 1);
        // Budgets > 1 carry a shared pool; budget 1 spawns nothing.
        assert!(ConvContext::default().with_threads(3).par.pool().is_some());
        assert!(ConvContext::mobile().par.pool().is_none());
        assert_eq!(ConvContext::default().mec_t, 100);
        assert_eq!(ConvContext::default().precision, Precision::F32);
        assert_eq!(
            ConvContext::default().with_precision(Precision::Q16).precision,
            Precision::Q16
        );
    }

    #[test]
    fn precision_support_matrix() {
        for k in AlgoKind::ALL {
            assert!(k.supports_precision(Precision::F32), "{}", k.name());
        }
        for k in [
            AlgoKind::Direct,
            AlgoKind::Im2col,
            AlgoKind::Mec,
            AlgoKind::MecSolutionA,
            AlgoKind::MecSolutionB,
            AlgoKind::Indirect,
        ] {
            assert!(k.supports_precision(Precision::Q16), "{}", k.name());
        }
        for k in [
            AlgoKind::Winograd,
            AlgoKind::WinogradChunked,
            AlgoKind::Fft,
            AlgoKind::Kn2row,
            AlgoKind::SmmConv,
        ] {
            assert!(!k.supports_precision(Precision::Q16), "{}", k.name());
        }
    }

    #[test]
    fn packed_kernel_follows_context_precision_and_halves_bytes() {
        use crate::tensor::{KernelShape, Nhwc};
        let shape = ConvShape::new(Nhwc::new(1, 8, 8, 3), KernelShape::new(3, 3, 3, 8), 1, 1);
        let mut rng = crate::util::Rng::new(0x51);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let f = PackedKernel::pack(&ConvContext::default(), &shape, &kernel);
        let q = PackedKernel::pack(
            &ConvContext::default().with_precision(Precision::Q16),
            &shape,
            &kernel,
        );
        assert_eq!(f.precision(), Precision::F32);
        assert_eq!(q.precision(), Precision::Q16);
        assert_eq!(q.bytes() * 2, f.bytes());
    }

    #[test]
    #[should_panic(expected = "different algorithm")]
    fn foreign_prepack_is_rejected() {
        use crate::tensor::{KernelShape, Nhwc};
        let shape = ConvShape::new(Nhwc::new(1, 7, 7, 1), KernelShape::new(3, 3, 1, 1), 1, 1);
        let kernel = Kernel::zeros(shape.kernel);
        let ctx = ConvContext::default();
        // A direct prepack handed to im2col must panic, not mis-execute.
        let foreign = AlgoKind::Direct.build().prepack(&ctx, &shape, &kernel);
        let _ = AlgoKind::Im2col.build().plan_shared(&ctx, &shape, foreign);
    }
}
