//! Convolution algorithms — the paper's subject matter.
//!
//! One module per algorithm the paper evaluates (§4):
//!
//! | module      | paper name            | role |
//! |-------------|-----------------------|------|
//! | [`direct`]  | direct convolution    | zero-overhead oracle |
//! | [`im2col`]  | Conv.cpu / Conv.gpu   | baseline lowering (Eq. 2) |
//! | [`mec`]     | MEC.cpu / MEC.gpu     | **the contribution** (Alg. 2, Eq. 3) |
//! | [`winograd`]| Wino.cpu / Wino.gpu   | F(2×2, 3×3) baseline |
//! | [`fft_conv`]| FFT.gpu               | frequency-domain baseline |
//!
//! All implement [`Convolution`]: a cuDNN-style API where the caller asks
//! for the workspace size up front (that *is* the paper's memory-overhead
//! metric) and provides the scratch explicitly, so the planner can enforce
//! device budgets and the tracker can measure true peaks.

pub mod direct;
pub mod fft_conv;
pub mod im2col;
pub mod mec;
pub mod winograd;
pub mod winograd_chunked;

use crate::gemm::BlockSizes;
use crate::memory::Workspace;
use crate::tensor::{ConvShape, Kernel, Tensor};

/// Execution environment for a convolution call.
#[derive(Debug, Clone)]
pub struct ConvContext {
    /// Worker threads for the parallel loops (paper: OpenMP threads /
    /// GPU blocks). `1` models the paper's Mobile platform.
    pub threads: usize,
    /// GEMM cache-blocking parameters (ablation_gemm sweeps these).
    pub blocks: BlockSizes,
    /// MEC's Solution A/B dispatch threshold `T` (Algorithm 2 line 8).
    /// The paper found ~100 good for GPUs.
    pub mec_t: usize,
    /// Cap on cached FFT kernel spectra; above this the FFT algorithm
    /// streams kernel transforms instead of caching them.
    pub fft_cache_cap_bytes: usize,
}

impl Default for ConvContext {
    fn default() -> Self {
        ConvContext {
            threads: 1,
            blocks: BlockSizes::default(),
            mec_t: 100,
            fft_cache_cap_bytes: 256 << 20,
        }
    }
}

impl ConvContext {
    /// Paper "Mobile" platform: 1 thread, batch handled by caller.
    pub fn mobile() -> ConvContext {
        ConvContext::default()
    }

    /// Paper "Server" platform: all cores.
    pub fn server() -> ConvContext {
        ConvContext {
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            ..ConvContext::default()
        }
    }

    pub fn with_threads(mut self, t: usize) -> ConvContext {
        self.threads = t;
        self
    }

    pub fn with_mec_t(mut self, t: usize) -> ConvContext {
        self.mec_t = t;
        self
    }
}

/// A convolution algorithm with an explicit-workspace API.
pub trait Convolution: Send + Sync {
    /// Short name used in reports ("MEC.cpu" style naming lives in the
    /// bench layer; this is the algorithm identity).
    fn name(&self) -> &'static str;

    /// Whether this algorithm can handle the geometry (e.g. Winograd
    /// F(2×2,3×3) requires k=3×3, s=1 — paper §4).
    fn supports(&self, shape: &ConvShape) -> bool;

    /// Temporary floats needed beyond I, K, O — the paper's
    /// "memory-overhead" (§3.4), exact per algorithm.
    fn workspace_elems(&self, shape: &ConvShape) -> usize;

    /// Same in bytes.
    fn workspace_bytes(&self, shape: &ConvShape) -> usize {
        self.workspace_elems(shape) * std::mem::size_of::<f32>()
    }

    /// Run the convolution. `output` must be pre-allocated to
    /// `shape.output()`; `ws` is grown as needed (callers reuse it across
    /// calls — the serving hot path allocates nothing).
    fn run(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        input: &Tensor,
        kernel: &Kernel,
        ws: &mut Workspace,
        output: &mut Tensor,
    );
}

/// Algorithm identifiers for CLI/planner/config use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgoKind {
    Direct,
    Im2col,
    /// MEC with automatic Solution A/B dispatch (Algorithm 2 line 8).
    Mec,
    /// MEC pinned to Solution A (h-n-w-c gemm + repack).
    MecSolutionA,
    /// MEC pinned to Solution B (per-sample batched gemms).
    MecSolutionB,
    /// Fully-materialized F(2×2,3×3) — the paper's Wino.gpu formulation.
    Winograd,
    /// Tile-chunked F(2×2,3×3) — the paper's memory-optimized Wino.cpu.
    WinogradChunked,
    Fft,
}

impl AlgoKind {
    pub const ALL: [AlgoKind; 8] = [
        AlgoKind::Direct,
        AlgoKind::Im2col,
        AlgoKind::Mec,
        AlgoKind::MecSolutionA,
        AlgoKind::MecSolutionB,
        AlgoKind::Winograd,
        AlgoKind::WinogradChunked,
        AlgoKind::Fft,
    ];

    /// The subset benchmarked as distinct systems in the paper.
    pub const PAPER: [AlgoKind; 5] = [
        AlgoKind::Direct,
        AlgoKind::Im2col,
        AlgoKind::Mec,
        AlgoKind::Winograd,
        AlgoKind::Fft,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::Direct => "direct",
            AlgoKind::Im2col => "im2col",
            AlgoKind::Mec => "mec",
            AlgoKind::MecSolutionA => "mec-a",
            AlgoKind::MecSolutionB => "mec-b",
            AlgoKind::Winograd => "winograd",
            AlgoKind::WinogradChunked => "winograd-chunked",
            AlgoKind::Fft => "fft",
        }
    }

    pub fn parse(s: &str) -> Option<AlgoKind> {
        Some(match s {
            "direct" => AlgoKind::Direct,
            "im2col" | "conv" => AlgoKind::Im2col,
            "mec" => AlgoKind::Mec,
            "mec-a" | "mec_a" => AlgoKind::MecSolutionA,
            "mec-b" | "mec_b" => AlgoKind::MecSolutionB,
            "winograd" | "wino" => AlgoKind::Winograd,
            "winograd-chunked" | "wino-cpu" => AlgoKind::WinogradChunked,
            "fft" => AlgoKind::Fft,
            _ => return None,
        })
    }

    /// Instantiate the algorithm.
    pub fn build(&self) -> Box<dyn Convolution> {
        match self {
            AlgoKind::Direct => Box::new(direct::Direct),
            AlgoKind::Im2col => Box::new(im2col::Im2col),
            AlgoKind::Mec => Box::new(mec::Mec::auto()),
            AlgoKind::MecSolutionA => Box::new(mec::Mec::solution_a()),
            AlgoKind::MecSolutionB => Box::new(mec::Mec::solution_b()),
            AlgoKind::Winograd => Box::new(winograd::Winograd),
            AlgoKind::WinogradChunked => Box::new(winograd_chunked::WinogradChunked::default()),
            AlgoKind::Fft => Box::new(fft_conv::FftConv),
        }
    }
}

/// Convenience: run `algo` on fresh workspace, returning the output.
pub fn convolve(
    algo: AlgoKind,
    ctx: &ConvContext,
    shape: &ConvShape,
    input: &Tensor,
    kernel: &Kernel,
) -> Tensor {
    let a = algo.build();
    assert!(
        a.supports(shape),
        "{} does not support geometry {}",
        a.name(),
        shape.describe()
    );
    let mut ws = Workspace::new();
    let mut out = Tensor::zeros(shape.output());
    a.run(ctx, shape, input, kernel, &mut ws, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_names_roundtrip() {
        for k in AlgoKind::ALL {
            assert_eq!(AlgoKind::parse(k.name()), Some(k));
        }
        assert_eq!(AlgoKind::parse("nope"), None);
    }

    #[test]
    fn contexts() {
        assert_eq!(ConvContext::mobile().threads, 1);
        assert!(ConvContext::server().threads >= 1);
        assert_eq!(ConvContext::default().mec_t, 100);
    }
}
