//! Direct convolution (paper Fig. 1a): the straightforward 7-loop nest.
//! Zero memory overhead, poor arithmetic intensity — the correctness
//! oracle every other algorithm is tested against, and the "no overhead"
//! end of the paper's memory/performance trade-off.

use super::{downcast_prepack, AlgoKind, ConvContext, ConvPlan, Convolution, KernelPrepack};
use crate::memory::WorkspaceLayout;
use crate::tensor::{ConvShape, Kernel, Tensor};
use crate::threadpool::Parallelism;
use std::any::Any;
use std::sync::Arc;

pub struct Direct;

/// Direct's "prepack" is just an owned kernel copy (self-contained plans,
/// see ARCHITECTURE.md) — shared so per-batch-size plans hold one copy.
pub struct DirectPrepack {
    pub kernel: Kernel,
}

impl KernelPrepack for DirectPrepack {
    fn bytes(&self) -> usize {
        self.kernel.bytes()
    }

    fn into_any_arc(self: Arc<Self>) -> Arc<dyn Any + Send + Sync> {
        self
    }
}

impl Convolution for Direct {
    fn name(&self) -> &'static str {
        "direct"
    }

    fn supports(&self, _shape: &ConvShape) -> bool {
        true
    }

    fn workspace_elems(&self, _shape: &ConvShape) -> usize {
        0 // the defining property (paper §3.1)
    }

    fn prepack(
        &self,
        _ctx: &ConvContext,
        shape: &ConvShape,
        kernel: &Kernel,
    ) -> Arc<dyn KernelPrepack> {
        assert_eq!(kernel.shape(), shape.kernel);
        Arc::new(DirectPrepack {
            kernel: kernel.clone(),
        })
    }

    fn plan_shared(
        &self,
        ctx: &ConvContext,
        shape: &ConvShape,
        prepack: Arc<dyn KernelPrepack>,
    ) -> Box<dyn ConvPlan> {
        let prepack: Arc<DirectPrepack> = downcast_prepack(prepack, "direct");
        assert_eq!(prepack.kernel.shape(), shape.kernel);
        Box::new(DirectPlan {
            ctx: ctx.clone(),
            shape: *shape,
            prepack,
            layout: WorkspaceLayout::new(),
        })
    }
}

/// Plan for the direct loop nest: nothing to precompute beyond the shared
/// kernel copy; the layout is empty (zero workspace).
pub struct DirectPlan {
    ctx: ConvContext,
    shape: ConvShape,
    prepack: Arc<DirectPrepack>,
    layout: WorkspaceLayout,
}

impl ConvPlan for DirectPlan {
    fn algo(&self) -> AlgoKind {
        AlgoKind::Direct
    }

    fn shape(&self) -> &ConvShape {
        &self.shape
    }

    fn layout(&self) -> &WorkspaceLayout {
        &self.layout
    }

    fn resident_bytes(&self) -> usize {
        self.prepack.bytes()
    }

    fn shared_prepack(&self) -> Option<Arc<dyn KernelPrepack>> {
        Some(Arc::clone(&self.prepack) as Arc<dyn KernelPrepack>)
    }

    fn execute_in(&self, input: &Tensor, _scratch: &mut [f32], output: &mut Tensor) {
        self.execute_with(&self.ctx, input, output);
    }

    fn execute_in_par(
        &self,
        input: &Tensor,
        _scratch: &mut [f32],
        output: &mut Tensor,
        par: &Parallelism,
    ) {
        // Session thread cap: clamp into the plan-time budget, sharing
        // the plan's pool (see MecPlan::execute_in_par).
        let ctx = self
            .ctx
            .clone()
            .with_parallelism(self.ctx.par.with_budget(par.threads()));
        self.execute_with(&ctx, input, output);
    }
}

impl DirectPlan {
    fn execute_with(&self, ctx: &ConvContext, input: &Tensor, output: &mut Tensor) {
        let s = self.shape;
        let (oh, ow) = (s.oh(), s.ow());
        let out_shape = s.output();
        assert_eq!(output.shape(), out_shape);
        assert_eq!(input.shape(), s.input);
        let k = s.kernel;
        let ish = s.input;

        let in_data = input.data();
        let k_data = self.prepack.kernel.data();
        let out = crate::threadpool::SharedSlice::new(output.data_mut());

        // Parallelize over (n, oh): each task writes a disjoint output
        // row. Grain: o_w·k_h·k_w·i_c·k_c MACs per row.
        let row_macs = ow * k.kh * k.kw * k.ic * k.kc;
        ctx.par.parallel_for_macs(ish.n * oh, row_macs, |t| {
            let n = t / oh;
            let y = t % oh;
            let out_data: &mut [f32] = out.slice();
            for x in 0..ow {
                let out_off = out_shape.index(n, y, x, 0);
                let acc = &mut out_data[out_off..out_off + k.kc];
                acc.fill(0.0);
                for u in 0..k.kh {
                    for v in 0..k.kw {
                        let in_off = ish.index(n, y * s.sh + u, x * s.sw + v, 0);
                        let in_px = &in_data[in_off..in_off + k.ic];
                        let k_off = k.index(u, v, 0, 0);
                        for (i, &iv) in in_px.iter().enumerate() {
                            let k_row = &k_data[k_off + i * k.kc..k_off + i * k.kc + k.kc];
                            for (o, acc_o) in acc.iter_mut().enumerate() {
                                *acc_o += iv * k_row[o];
                            }
                        }
                    }
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::Workspace;
    use crate::tensor::{KernelShape, Nhwc};

    /// The worked example from paper Fig. 1(a): 7×7 input of a simple
    /// pattern, 3×3 ones-ish kernel. We use a delta kernel and a sum
    /// kernel to check geometry exactly.
    #[test]
    fn delta_kernel_is_identity_window() {
        let shape = ConvShape::new(Nhwc::new(1, 5, 5, 1), KernelShape::new(3, 3, 1, 1), 1, 1);
        let input = Tensor::from_fn(shape.input, |_, h, w, _| (h * 5 + w) as f32);
        // Kernel = 1 at center (1,1), else 0 -> output = center crop.
        let kernel = Kernel::from_fn(shape.kernel, |h, w, _, _| {
            if h == 1 && w == 1 {
                1.0
            } else {
                0.0
            }
        });
        let mut out = Tensor::zeros(shape.output());
        Direct.run(
            &ConvContext::default(),
            &shape,
            &input,
            &kernel,
            &mut Workspace::new(),
            &mut out,
        );
        for y in 0..3 {
            for x in 0..3 {
                assert_eq!(out.at(0, y, x, 0), input.at(0, y + 1, x + 1, 0));
            }
        }
    }

    #[test]
    fn ones_kernel_sums_window_with_stride() {
        let shape = ConvShape::new(Nhwc::new(1, 6, 6, 1), KernelShape::new(2, 2, 1, 1), 2, 2);
        let input = Tensor::from_fn(shape.input, |_, _, _, _| 1.0);
        let kernel = Kernel::from_fn(shape.kernel, |_, _, _, _| 1.0);
        let mut out = Tensor::zeros(shape.output());
        Direct.run(
            &ConvContext::default(),
            &shape,
            &input,
            &kernel,
            &mut Workspace::new(),
            &mut out,
        );
        assert_eq!(out.shape(), Nhwc::new(1, 3, 3, 1));
        assert!(out.data().iter().all(|&v| v == 4.0));
    }

    #[test]
    fn channels_sum_and_outputs_separate() {
        // 2 input channels, 3 output channels; kernel picks channel sums.
        let shape = ConvShape::new(Nhwc::new(1, 3, 3, 2), KernelShape::new(1, 1, 2, 3), 1, 1);
        let input = Tensor::from_fn(shape.input, |_, h, w, c| (h + w) as f32 + c as f32);
        let kernel = Kernel::from_fn(shape.kernel, |_, _, i, o| ((i + 1) * (o + 1)) as f32);
        let mut out = Tensor::zeros(shape.output());
        Direct.run(
            &ConvContext::default(),
            &shape,
            &input,
            &kernel,
            &mut Workspace::new(),
            &mut out,
        );
        for h in 0..3 {
            for w in 0..3 {
                let (c0, c1) = ((h + w) as f32, (h + w) as f32 + 1.0);
                for o in 0..3 {
                    let want = c0 * (o + 1) as f32 + c1 * 2.0 * (o + 1) as f32;
                    assert_eq!(out.at(0, h, w, o), want, "h={h} w={w} o={o}");
                }
            }
        }
    }

    #[test]
    fn multithreaded_matches_single() {
        let shape = ConvShape::new(Nhwc::new(2, 9, 11, 3), KernelShape::new(3, 3, 3, 5), 2, 1);
        let mut rng = crate::util::Rng::new(1);
        let input = Tensor::random(shape.input, &mut rng);
        let kernel = Kernel::random(shape.kernel, &mut rng);
        let mut o1 = Tensor::zeros(shape.output());
        let mut o4 = Tensor::zeros(shape.output());
        let mut ws = Workspace::new();
        Direct.run(&ConvContext::default(), &shape, &input, &kernel, &mut ws, &mut o1);
        Direct.run(
            &ConvContext::default().with_threads(4),
            &shape,
            &input,
            &kernel,
            &mut ws,
            &mut o4,
        );
        assert_eq!(o1, o4);
    }

    #[test]
    fn zero_workspace() {
        let shape = ConvShape::new(Nhwc::new(1, 7, 7, 1), KernelShape::new(3, 3, 1, 1), 1, 1);
        assert_eq!(Convolution::workspace_elems(&Direct, &shape), 0);
        assert!(Direct.supports(&shape));
        // The plan mirrors the algorithm: empty layout, zero scratch.
        let kernel = Kernel::zeros(shape.kernel);
        let plan = Direct.plan(&ConvContext::default(), &shape, &kernel);
        assert_eq!(plan.workspace_elems(), 0);
        assert_eq!(plan.algo(), AlgoKind::Direct);
        assert_eq!(plan.shape(), &shape);
        assert!(plan.layout().regions().is_empty());
    }
}
